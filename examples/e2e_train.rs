//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Full-stack proof that all three layers compose: the paper-scale fleet
//! (N = 120 devices) trains the femnist-like CNN for several hundred
//! rounds through the Pallas/JAX AOT artifacts under LROA control, and
//! the loss/accuracy curves plus the modeled-latency ledger are logged.
//! A Uni-S run on identical channel realizations is included as the
//! headline latency comparison; both runs are one `exp` sweep and execute
//! concurrently.
//!
//! ```text
//! cargo run --release --example e2e_train              # 300 rounds
//! cargo run --release --example e2e_train -- --rounds 1000
//! ```

use lroa::config::Policy;
use lroa::exp::SweepSpec;
use lroa::fl::SimMode;
use lroa::harness::{self, Args};

fn main() -> lroa::Result<()> {
    let args = Args::parse();
    args.reject_envs("e2e_train")?;
    let dataset = args.dataset.clone().unwrap_or_else(|| "femnist".into());

    let spec = SweepSpec {
        datasets: vec![dataset.clone()],
        policies: vec![Policy::Lroa, Policy::UniformStatic],
        rounds: Some(args.rounds.unwrap_or(300)),
        mode: SimMode::Full,
        ..SweepSpec::default()
    };
    let session = args
        .experiment(spec)
        .base_with(|ds| {
            let mut cfg = args.config(ds)?;
            cfg.train.samples_per_device = (50, 150);
            cfg.train.eval_every = 10;
            Ok(cfg)
        })
        .build()?;
    println!(
        "=== end-to-end driver: {} rounds, N={} ===",
        session.cells()[0].cfg.train.rounds,
        session.cells()[0].cfg.system.num_devices
    );
    println!("{}", session.cells()[0].cfg.dump());

    let recs = harness::recorders(session.run()?.results);
    let (lroa, unis) = (&recs[0], &recs[1]);

    let dir = args.out_dir("e2e");
    harness::save_all(&dir, &recs)?;

    println!("\nloss curve (LROA):");
    println!("round,train_loss,test_loss,test_accuracy,total_time_s");
    for r in lroa.rounds.iter().filter(|r| !r.test_accuracy.is_nan()) {
        println!(
            "{},{:.4},{:.4},{:.4},{:.1}",
            r.round, r.train_loss, r.test_loss, r.test_accuracy, r.total_time_s
        );
    }

    harness::print_latency_table(&recs);
    let saving = (1.0 - lroa.total_time_s() / unis.total_time_s()) * 100.0;
    println!("LROA saves {saving:.1}% modeled training latency vs Uni-S (paper: ~49.9% on FEMNIST)");
    println!("CSV under {}", dir.display());
    Ok(())
}
