//! Figures 1 & 2: LROA vs Uni-D / Uni-S / DivFL on both datasets.
//!
//! Reproduces the paper's headline evaluation — testing accuracy vs.
//! modeled runtime (a) and vs. communication round (b) for all four
//! policies, with all policies seeing identical channel realizations.
//! Paper numbers: LROA saves 20.8% / 50.1% total latency vs Uni-D / Uni-S
//! on CIFAR-10 and 15.3% / 49.9% on FEMNIST.
//!
//! The four policies are one `exp` sweep cell per scheme and run
//! concurrently (`--threads` controls the pool).
//!
//! ```text
//! cargo run --release --example fig1_2_baselines                # both datasets, quick scale
//! cargo run --release --example fig1_2_baselines -- --dataset cifar --rounds 300
//! cargo run --release --example fig1_2_baselines -- --full      # paper scale
//! ```

use lroa::config::Policy;
use lroa::exp::SweepSpec;
use lroa::fl::SimMode;
use lroa::harness::{self, Args};

fn main() -> lroa::Result<()> {
    let args = Args::parse();
    for dataset in args.datasets() {
        let fig = if dataset == "cifar" { "fig1" } else { "fig2" };
        println!("=== {fig}: {dataset} ===");

        let spec = SweepSpec {
            datasets: vec![dataset.clone()],
            policies: Policy::ALL.to_vec(),
            mode: SimMode::Full,
            ..SweepSpec::default()
        };
        let scenarios = spec.expand_with(|ds| args.config(ds))?;
        let recs = harness::recorders(args.run(scenarios)?);

        harness::save_all(&args.out_dir(fig), &recs)?;
        harness::print_series(&recs);
        harness::print_latency_table(&recs);
    }
    Ok(())
}
