//! Figures 1 & 2: LROA vs the registered baselines on both datasets.
//!
//! Reproduces the paper's headline evaluation — testing accuracy vs.
//! modeled runtime (a) and vs. communication round (b) for every
//! registered policy, with all policies seeing identical channel
//! realizations.  Paper numbers: LROA saves 20.8% / 50.1% total latency
//! vs Uni-D / Uni-S on CIFAR-10 and 15.3% / 49.9% on FEMNIST.
//!
//! Each policy is one cell of an `exp::Experiment` and runs concurrently
//! (`--threads` controls the pool).  Pass `--envs=static,ge,avail,drift`
//! (or `all`) to stress the same comparison under dynamic environments.
//!
//! ```text
//! cargo run --release --example fig1_2_baselines                # both datasets, quick scale
//! cargo run --release --example fig1_2_baselines -- --dataset cifar --rounds 300
//! cargo run --release --example fig1_2_baselines -- --envs=all  # policy × environment grid
//! cargo run --release --example fig1_2_baselines -- --full      # paper scale
//! ```

use lroa::config::Policy;
use lroa::exp::SweepSpec;
use lroa::fl::SimMode;
use lroa::harness::{self, Args};

fn main() -> lroa::Result<()> {
    let args = Args::parse();
    let envs = args.validated_envs()?;
    for dataset in args.datasets() {
        let fig = if dataset == "cifar" { "fig1" } else { "fig2" };
        println!("=== {fig}: {dataset} ===");

        let spec = SweepSpec {
            datasets: vec![dataset.clone()],
            policies: Policy::ALL.to_vec(),
            envs: envs.clone(),
            mode: SimMode::Full,
            ..SweepSpec::default()
        };
        let results = args.experiment(spec).run()?.results;
        let recs: Vec<_> = results.iter().map(|r| r.recorder.clone()).collect();

        harness::save_all(&args.out_dir(fig), &recs)?;
        harness::print_series(&recs);

        // One latency table per environment: the "vs LROA" savings column
        // only makes sense against the same environment's LROA row.  The
        // rows are matched on each cell's actual env kind (scenario
        // metadata), not on label strings or expansion order.
        if envs.len() <= 1 {
            harness::print_latency_table(&recs);
        } else {
            for env in &envs {
                println!("--- environment: {} ---", env.kind);
                let env_recs: Vec<_> = results
                    .iter()
                    .filter(|r| r.scenario.cfg.env.kind == env.kind)
                    .map(|r| r.recorder.clone())
                    .collect();
                harness::print_latency_table(&env_recs);
            }
        }
    }
    Ok(())
}
