//! Figure 3: accuracy vs. total time under the λ sweep.
//!
//! The paper varies µ (λ = µ·λ₀) over {1, 10, 50, 100} for CIFAR-10 and
//! {0.3, 0.5, 5, 10} for FEMNIST at fixed ν = 1e5, showing that larger λ
//! buys accuracy at the cost of total time, while λ → 0 destabilizes
//! training (resource-only control).  The µ axis is one `exp` sweep.
//!
//! ```text
//! cargo run --release --example fig3_lambda -- --dataset femnist
//! ```

use lroa::config::Policy;
use lroa::exp::SweepSpec;
use lroa::fl::SimMode;
use lroa::harness::{self, Args};

fn main() -> lroa::Result<()> {
    let args = Args::parse();
    args.reject_envs("fig3_lambda")?;
    for dataset in args.datasets() {
        let mus: Vec<f64> = if dataset == "cifar" {
            vec![1.0, 10.0, 50.0, 100.0]
        } else {
            vec![0.3, 0.5, 5.0, 10.0]
        };
        println!("=== fig3 ({dataset}): mu sweep {mus:?} ===");

        let spec = SweepSpec {
            datasets: vec![dataset.clone()],
            policies: vec![Policy::Lroa],
            mus: mus.clone(),
            nus: vec![1e5],
            mode: SimMode::Full,
            ..SweepSpec::default()
        };
        let recs = harness::recorders(args.experiment(spec).run()?.results);

        harness::save_all(&args.out_dir("fig3"), &recs)?;
        harness::print_series(&recs);
        println!("{:<26} {:>14} {:>12}", "mu", "total time [s]", "final acc");
        for (rec, &mu) in recs.iter().zip(&mus) {
            println!("{:<26} {:>14.1} {:>12.4}", mu, rec.total_time_s(), rec.final_accuracy());
        }
        println!();
    }
    Ok(())
}
