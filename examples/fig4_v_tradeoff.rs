//! Figure 4: the V trade-off — time-averaged energy (a, c) and
//! time-averaged objective (b, d) vs. rounds for ν ∈ {1e3, 1e4, 1e5, 1e6}.
//!
//! Pure control-plane experiment (no learning needed): larger V favors
//! the objective at the cost of slower convergence of the time-average
//! energy toward the budget Ē — the classic Lyapunov O(1/V)/O(V) split.
//! Runs on the full 120-device fleet over the paper horizons.  The
//! ν × seed grid is one `exp` sweep; `--repeats` seeds (paper: 30) run
//! concurrently and average per ν.
//!
//! ```text
//! cargo run --release --example fig4_v_tradeoff -- --repeats 30
//! ```

use lroa::config::Policy;
use lroa::exp::{mean_series_over, SweepSpec};
use lroa::harness::Args;

fn main() -> lroa::Result<()> {
    let args = Args::parse();
    args.reject_envs("fig4_v_tradeoff")?;
    let nus = [1e3, 1e4, 1e5, 1e6];
    for dataset in args.datasets() {
        println!("=== fig4 ({dataset}): nu sweep, {} repeat(s) ===", args.repeats);
        // Paper budgets (not quick-scaled): the regime where (16) binds.
        let budget = if dataset == "cifar" { 15.0 } else { 5.0 };

        let spec = SweepSpec {
            datasets: vec![dataset.clone()],
            policies: vec![Policy::Lroa],
            nus: nus.to_vec(),
            seeds: (1..=args.repeats as u64).collect(),
            ..SweepSpec::default()
        };
        let results = args
            .experiment(spec)
            .base_with(|ds| {
                let mut cfg = args.config(ds)?;
                // Control-plane-only: use the paper horizons even in
                // quick mode, and the paper's data density (CIFAR's
                // 50k/120 ≈ 417 samples/device) so the energy constraint
                // (16) actually binds — that is the regime where V
                // matters.
                cfg.train.rounds = args
                    .rounds
                    .unwrap_or(if ds == "cifar" { 2000 } else { 1000 });
                cfg.train.samples_per_device = (300, 500);
                cfg.system.energy_budget_j = budget;
                Ok(cfg)
            })
            .run()?
            .results;

        // Seed-average the two series per ν; a mismatched repeat (e.g.
        // a truncated resumed cell) errors with the cell label attached.
        let mut rows: Vec<(f64, Vec<f64>, Vec<f64>)> = Vec::new();
        for &nu in &nus {
            let of_nu = |r: &&lroa::exp::ScenarioResult| r.scenario.cfg.control.nu == nu;
            let repeats = results.iter().filter(of_nu).count();
            assert_eq!(repeats, args.repeats, "missing repeats for nu={nu}");
            let energy = mean_series_over(results.iter().filter(of_nu), |rec| {
                rec.time_avg_energy()
            })?;
            let objective = mean_series_over(results.iter().filter(of_nu), |rec| {
                rec.time_avg_objective()
            })?;
            rows.push((nu, energy, objective));
            let (e, o) = (
                rows.last().unwrap().1.last().unwrap(),
                rows.last().unwrap().2.last().unwrap(),
            );
            eprintln!("[fig4] {dataset} nu={nu:.0e}: time-avg energy {e:.3} J (budget {budget} J), objective {o:.3}");
        }

        // CSV in the paper's series shape.
        let dir = std::path::PathBuf::from("runs/fig4");
        std::fs::create_dir_all(&dir)?;
        let mut csv = String::from("round");
        for &nu in &nus {
            csv += &format!(",energy_nu{nu:.0e},objective_nu{nu:.0e}");
        }
        csv.push('\n');
        let len = rows[0].1.len();
        for t in 0..len {
            csv += &t.to_string();
            for (_, e, o) in &rows {
                csv += &format!(",{:.6},{:.6}", e[t], o[t]);
            }
            csv.push('\n');
        }
        let path = dir.join(format!("{dataset}.csv"));
        std::fs::write(&path, csv)?;

        println!("\n{:<10} {:>22} {:>22}  (budget {budget} J)", "nu", "final time-avg energy", "final time-avg obj");
        for (nu, e, o) in &rows {
            println!("{:<10.0e} {:>22.3} {:>22.3}", nu, e.last().unwrap(), o.last().unwrap());
        }
        println!("series: {}\n", path.display());
    }
    Ok(())
}
