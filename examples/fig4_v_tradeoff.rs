//! Figure 4: the V trade-off — time-averaged energy (a, c) and
//! time-averaged objective (b, d) vs. rounds for ν ∈ {1e3, 1e4, 1e5, 1e6}.
//!
//! Pure control-plane experiment (no learning needed): larger V favors
//! the objective at the cost of slower convergence of the time-average
//! energy toward the budget Ē — the classic Lyapunov O(1/V)/O(V) split.
//! Runs on the full 120-device fleet over the paper horizons and averages
//! `--repeats` seeds (paper: 30).
//!
//! ```text
//! cargo run --release --example fig4_v_tradeoff -- --repeats 30
//! ```

use lroa::config::Policy;
use lroa::fl::{Server, SimMode};
use lroa::harness::Args;
use lroa::metrics::{mean_series, Recorder};

fn run_once(args: &Args, dataset: &str, nu: f64, seed: u64) -> lroa::Result<Recorder> {
    let mut cfg = args.config(dataset)?;
    cfg.control.nu = nu;
    cfg.train.policy = Policy::Lroa;
    cfg.train.seed = seed;
    // Control-plane-only: use the paper horizons even in quick mode, and
    // the paper's data density (CIFAR's 50k/120 ≈ 417 samples/device) so
    // the energy constraint (16) actually binds — that is the regime
    // where V matters.
    cfg.train.rounds = args.rounds.unwrap_or(if dataset == "cifar" { 2000 } else { 1000 });
    cfg.train.samples_per_device = (300, 500);
    cfg.system.energy_budget_j = if dataset == "cifar" { 15.0 } else { 5.0 };
    let mut server = Server::new(cfg, SimMode::ControlPlaneOnly)?;
    server.run()?;
    Ok(std::mem::take(&mut server.recorder))
}

fn main() -> lroa::Result<()> {
    let args = Args::parse();
    let nus = [1e3, 1e4, 1e5, 1e6];
    for dataset in args.datasets() {
        println!("=== fig4 ({dataset}): nu sweep, {} repeat(s) ===", args.repeats);
        // Same budget run_once uses (paper defaults, not quick-scaled).
        let budget = if dataset == "cifar" { 15.0 } else { 5.0 };

        let mut rows: Vec<(f64, Vec<f64>, Vec<f64>)> = Vec::new();
        for &nu in &nus {
            let mut energy_series = Vec::new();
            let mut objective_series = Vec::new();
            for rep in 0..args.repeats {
                let rec = run_once(&args, &dataset, nu, 1 + rep as u64)?;
                energy_series.push(rec.time_avg_energy());
                objective_series.push(rec.time_avg_objective());
            }
            rows.push((nu, mean_series(&energy_series), mean_series(&objective_series)));
            let (e, o) = (rows.last().unwrap().1.last().unwrap(), rows.last().unwrap().2.last().unwrap());
            eprintln!("[fig4] {dataset} nu={nu:.0e}: time-avg energy {e:.3} J (budget {budget} J), objective {o:.3}");
        }

        // CSV in the paper's series shape.
        let dir = std::path::PathBuf::from("runs/fig4");
        std::fs::create_dir_all(&dir)?;
        let mut csv = String::from("round");
        for &nu in &nus {
            csv += &format!(",energy_nu{nu:.0e},objective_nu{nu:.0e}");
        }
        csv.push('\n');
        let len = rows[0].1.len();
        for t in 0..len {
            csv += &t.to_string();
            for (_, e, o) in &rows {
                csv += &format!(",{:.6},{:.6}", e[t], o[t]);
            }
            csv.push('\n');
        }
        let path = dir.join(format!("{dataset}.csv"));
        std::fs::write(&path, csv)?;

        println!("\n{:<10} {:>22} {:>22}  (budget {budget} J)", "nu", "final time-avg energy", "final time-avg obj");
        for (nu, e, o) in &rows {
            println!("{:<10.0e} {:>22.3} {:>22.3}", nu, e.last().unwrap(), o.last().unwrap());
        }
        println!("series: {}\n", path.display());
    }
    Ok(())
}
