//! Figures 5 & 6: impact of the sampling frequency K ∈ {2, 4, 6}.
//!
//! For each K, LROA and Uni-D run full training; the paper grid-searches
//! µ ∈ {0.1, 1, 10} × ν ∈ {1e4, 1e5, 1e6} per K and reports the best
//! time/accuracy trade-off.  Quick mode uses the default (µ=1, ν=1e5);
//! `--grid` enables the full 3×3 search per K as in the paper.  The whole
//! K × policy (× µ × ν) grid is one `exp` sweep run in parallel.
//!
//! ```text
//! cargo run --release --example fig5_6_k -- --dataset cifar
//! cargo run --release --example fig5_6_k -- --grid --full    # paper scale
//! ```

use lroa::config::Policy;
use lroa::exp::{ScenarioResult, SweepSpec};
use lroa::fl::SimMode;
use lroa::harness::{self, Args};
use lroa::metrics::Recorder;

/// §VII-B.3 model selection: prefer clearly-higher accuracy, break near-
/// ties (within one point) by total modeled time.
fn better(candidate: &Recorder, best: &Recorder) -> bool {
    let (ba, ca) = (best.final_accuracy(), candidate.final_accuracy());
    ca > ba + 0.01 || ((ca - ba).abs() <= 0.01 && candidate.total_time_s() < best.total_time_s())
}

fn main() -> lroa::Result<()> {
    let args = Args::parse();
    args.reject_envs("fig5_6_k")?;
    let grid_search = args.flag("--grid");
    let ks = [2usize, 4, 6];

    for dataset in args.datasets() {
        println!("=== fig5/6 ({dataset}): K sweep {ks:?}, grid={grid_search} ===");

        let spec = SweepSpec {
            datasets: vec![dataset.clone()],
            policies: vec![Policy::Lroa, Policy::UniformDynamic],
            ks: ks.to_vec(),
            mus: if grid_search { vec![0.1, 1.0, 10.0] } else { vec![1.0] },
            nus: if grid_search { vec![1e4, 1e5, 1e6] } else { vec![1e5] },
            mode: SimMode::Full,
            ..SweepSpec::default()
        };
        let results = args.experiment(spec).run()?.results;

        // Pick the best grid point per (policy, K), as in §VII-B.3.
        let mut all: Vec<Recorder> = Vec::new();
        for &k in &ks {
            for policy in [Policy::Lroa, Policy::UniformDynamic] {
                let cell: Vec<&ScenarioResult> = results
                    .iter()
                    .filter(|r| {
                        r.scenario.cfg.system.k == k && r.scenario.cfg.train.policy == policy
                    })
                    .collect();
                let best = cell
                    .iter()
                    .copied()
                    .fold(None::<&ScenarioResult>, |best, r| match best {
                        Some(b) if !better(&r.recorder, &b.recorder) => Some(b),
                        _ => Some(r),
                    })
                    .expect("at least one grid point per (policy, K)");
                let mut rec = best.recorder.clone();
                rec.label = format!("{}-{dataset}-K{k}", policy.name());
                all.push(rec);
            }
        }

        harness::save_all(&args.out_dir("fig5_6"), &all)?;
        harness::print_series(&all);
        println!(
            "{:<22} {:>14} {:>12}   (expect: larger K => more time, higher final acc; LROA < Uni-D time at each K)",
            "run", "total time [s]", "final acc"
        );
        for rec in &all {
            println!("{:<22} {:>14.1} {:>12.4}", rec.label, rec.total_time_s(), rec.final_accuracy());
        }
        println!();
    }
    Ok(())
}
