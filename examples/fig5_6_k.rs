//! Figures 5 & 6: impact of the sampling frequency K ∈ {2, 4, 6}.
//!
//! For each K, LROA and Uni-D run full training; the paper grid-searches
//! µ ∈ {0.1, 1, 10} × ν ∈ {1e4, 1e5, 1e6} per K and reports the best
//! time/accuracy trade-off.  Quick mode uses the default (µ=1, ν=1e5);
//! `--grid` enables the full 3×3 search per K as in the paper.
//!
//! ```text
//! cargo run --release --example fig5_6_k -- --dataset cifar
//! cargo run --release --example fig5_6_k -- --grid --full    # paper scale
//! ```

use lroa::config::Policy;
use lroa::fl::SimMode;
use lroa::harness::{self, Args};
use lroa::metrics::Recorder;

fn main() -> lroa::Result<()> {
    let args = Args::parse();
    let grid_search = std::env::args().any(|a| a == "--grid");
    let ks = [2usize, 4, 6];

    for dataset in args.datasets() {
        println!("=== fig5/6 ({dataset}): K sweep {ks:?}, grid={grid_search} ===");
        let mut all: Vec<Recorder> = Vec::new();

        for &k in &ks {
            for (policy, pname) in [(Policy::Lroa, "LROA"), (Policy::UniformDynamic, "Uni-D")] {
                let grid: Vec<(f64, f64)> = if grid_search {
                    [0.1, 1.0, 10.0]
                        .iter()
                        .flat_map(|&mu| [1e4, 1e5, 1e6].iter().map(move |&nu| (mu, nu)))
                        .collect()
                } else {
                    vec![(1.0, 1e5)]
                };

                // Pick the best (accuracy-filtered, min total time) as in §VII-B.3.
                let mut best: Option<Recorder> = None;
                for (mu, nu) in grid {
                    let mut cfg = args.config(&dataset)?;
                    cfg.system.k = k;
                    cfg.control.mu = mu;
                    cfg.control.nu = nu;
                    let label = format!("{pname}-{dataset}-K{k}-mu{mu}-nu{nu:.0e}");
                    let rec = harness::run_policy(cfg, policy, SimMode::Full, &label)?;
                    let better = match &best {
                        None => true,
                        Some(b) => {
                            // Accuracy within 1 point of the best seen -> prefer faster.
                            let (ba, ra) = (b.final_accuracy(), rec.final_accuracy());
                            ra > ba + 0.01
                                || ((ra - ba).abs() <= 0.01 && rec.total_time_s() < b.total_time_s())
                        }
                    };
                    if better {
                        best = Some(rec);
                    }
                }
                let mut rec = best.expect("at least one grid point");
                rec.label = format!("{pname}-{dataset}-K{k}");
                all.push(rec);
            }
        }

        harness::save_all(&args.out_dir("fig5_6"), &all)?;
        harness::print_series(&all);
        println!(
            "{:<22} {:>14} {:>12}   (expect: larger K => more time, higher final acc; LROA < Uni-D time at each K)",
            "run", "total time [s]", "final acc"
        );
        for rec in &all {
            println!("{:<22} {:>14.1} {:>12.4}", rec.label, rec.total_time_s(), rec.final_accuracy());
        }
        println!();
    }
    Ok(())
}
