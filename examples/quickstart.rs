//! Quickstart: a small end-to-end LROA run, embedded through the
//! `exp::session` API.
//!
//! 16 devices, femnist-like task, 30 rounds of full federated training
//! through the AOT artifacts, with the evaluation checkpoints printed.
//! Run:
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! cargo run --release --example quickstart -- --sim   # control plane only,
//!                                                     # no artifacts needed
//! ```
//!
//! `--sim` is what CI drives: its CSV must be byte-identical to the same
//! cell run through `lroa sweep` (both are consumers of the one session
//! engine).

use lroa::config::Policy;
use lroa::exp::{Experiment, ProgressObserver, SweepSpec};
use lroa::fl::SimMode;
use lroa::harness::Args;

fn main() -> lroa::Result<()> {
    let args = Args::parse();
    args.reject_envs("quickstart")?;
    let mode = if args.flag("--sim") {
        SimMode::ControlPlaneOnly
    } else {
        SimMode::Full
    };
    let spec = SweepSpec {
        datasets: vec!["femnist".into()],
        policies: vec![Policy::Lroa],
        mode,
        ..SweepSpec::default()
    };
    let session = Experiment::from_spec(spec)
        .base_with(|ds| {
            // Paper defaults, not the harness's quick-mode scaling: the
            // quickstart demonstrates LROA under the real 5 J budget.
            let mut cfg = lroa::config::Config::for_dataset(ds)?;
            cfg.system.num_devices = 16;
            cfg.train.rounds = args.rounds.unwrap_or(30);
            cfg.train.samples_per_device = (40, 100);
            cfg.train.test_samples = 256;
            cfg.train.eval_every = 5;
            cfg.apply_cli(&std::env::args().collect::<Vec<_>>())?;
            Ok(cfg)
        })
        .threads(args.threads)
        .observe(ProgressObserver::new())
        .build()?;
    println!("{}", session.cells()[0].cfg.dump());

    let report = session.run()?;
    let rec = &report.results[0].recorder;

    println!("{:>6} {:>12} {:>10} {:>10} {:>10}", "round", "time [s]", "trainloss", "acc", "queue");
    for r in rec.rounds.iter().filter(|r| !r.test_accuracy.is_nan()) {
        println!(
            "{:>6} {:>12.1} {:>10.4} {:>10.4} {:>10.2}",
            r.round, r.total_time_s, r.train_loss, r.test_accuracy, r.mean_queue
        );
    }

    println!(
        "\nfinished: modeled latency {:.1}s, final accuracy {:.4}",
        rec.total_time_s(),
        rec.final_accuracy()
    );
    std::fs::create_dir_all("runs/quickstart")?;
    rec.write_csv(std::path::Path::new("runs/quickstart/lroa.csv"))?;
    println!("per-round metrics: runs/quickstart/lroa.csv");
    Ok(())
}
