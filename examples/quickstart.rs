//! Quickstart: a small end-to-end LROA run.
//!
//! 16 devices, femnist-like task, 30 rounds of full federated training
//! through the AOT artifacts, with per-eval progress printed.  Run:
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use lroa::config::{Config, Policy};
use lroa::fl::{Server, SimMode};

fn main() -> lroa::Result<()> {
    let mut cfg = Config::for_dataset("femnist")?;
    cfg.system.num_devices = 16;
    cfg.train.rounds = 30;
    cfg.train.samples_per_device = (40, 100);
    cfg.train.test_samples = 256;
    cfg.train.eval_every = 5;
    cfg.train.policy = Policy::Lroa;
    cfg.apply_cli(&std::env::args().collect::<Vec<_>>())?;
    cfg.validate()?;

    println!("{}", cfg.dump());
    let mut server = Server::new(cfg, SimMode::Full)?;
    println!("λ = {:.3e}, V = {:.3e}\n", server.lambda, server.v);
    println!("{:>6} {:>12} {:>10} {:>10} {:>10}", "round", "time [s]", "trainloss", "acc", "queue");

    for t in 0..server.cfg.train.rounds {
        server.round(t)?;
        let rec = server.recorder.rounds.last().unwrap();
        if !rec.test_accuracy.is_nan() {
            println!(
                "{:>6} {:>12.1} {:>10.4} {:>10.4} {:>10.2}",
                t, rec.total_time_s, rec.train_loss, rec.test_accuracy, rec.mean_queue
            );
        }
    }

    let rec = &server.recorder;
    println!(
        "\nfinished: modeled latency {:.1}s, final accuracy {:.4}",
        rec.total_time_s(),
        rec.final_accuracy()
    );
    std::fs::create_dir_all("runs/quickstart")?;
    rec.write_csv(std::path::Path::new("runs/quickstart/lroa.csv"))?;
    println!("per-round metrics: runs/quickstart/lroa.csv");
    Ok(())
}
