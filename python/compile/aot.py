"""AOT bridge: lower the L2 entry points to HLO text artifacts.

``python -m compile.aot --out-dir ../artifacts`` writes, per model variant:

    artifacts/<variant>/init.hlo.txt
    artifacts/<variant>/train_step.hlo.txt
    artifacts/<variant>/eval_batch.hlo.txt
    artifacts/<variant>/aggregate.hlo.txt

plus ``artifacts/manifest.json`` describing every artifact's shapes so the
rust runtime can marshal buffers without re-deriving model geometry.

Interchange format is HLO **text**, not serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  Lowering uses ``return_tuple=True``
so the rust side always unwraps a tuple.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def entry_points(cfg: M.ModelConfig):
    """(name, fn, example_args) for every exported computation of a variant."""
    d = cfg.dim
    h, w = cfg.input_hw
    c = cfg.input_c
    bt, be, km = cfg.train_batch, cfg.eval_batch, cfg.k_max

    return [
        (
            "init",
            lambda seed: (M.init(cfg, seed),),
            (_spec((), jnp.int32),),
        ),
        (
            "train_step",
            lambda t, m, x, y, lr: M.train_step(cfg, t, m, x, y, lr),
            (
                _spec((d,)),
                _spec((d,)),
                _spec((bt, h, w, c)),
                _spec((bt,), jnp.int32),
                _spec((), jnp.float32),
            ),
        ),
        (
            "eval_batch",
            lambda t, x, y, mask: M.eval_batch(cfg, t, x, y, mask),
            (
                _spec((d,)),
                _spec((be, h, w, c)),
                _spec((be,), jnp.int32),
                _spec((be,)),
            ),
        ),
        (
            "aggregate",
            lambda t, deltas, coefs: (M.aggregate(cfg, t, deltas, coefs),),
            (_spec((d,)), _spec((km, d)), _spec((km,))),
        ),
    ]


def manifest_entry(cfg: M.ModelConfig) -> dict:
    return {
        "dim": cfg.dim,
        "model_bits": cfg.model_bits,
        "input_hw": list(cfg.input_hw),
        "input_c": cfg.input_c,
        "num_classes": cfg.num_classes,
        "train_batch": cfg.train_batch,
        "eval_batch": cfg.eval_batch,
        "k_max": cfg.k_max,
        "layers": [
            {"name": s.name, "shape": list(s.shape), "size": s.size}
            for s in cfg.layers
        ],
        "artifacts": ["init", "train_step", "eval_batch", "aggregate"],
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--variants", default="femnist,cifar", help="comma-separated variant names"
    )
    args = parser.parse_args()

    variants = [v for v in args.variants.split(",") if v]
    manifest = {"format": "hlo-text", "variants": {}}

    for name in variants:
        cfg = M.VARIANTS[name]
        out_dir = os.path.join(args.out_dir, name)
        os.makedirs(out_dir, exist_ok=True)
        for fn_name, fn, example in entry_points(cfg):
            lowered = jax.jit(fn).lower(*example)
            text = to_hlo_text(lowered)
            path = os.path.join(out_dir, f"{fn_name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            print(f"[aot] {name}/{fn_name}: d={cfg.dim} -> {path} ({len(text)} chars)")
        manifest["variants"][name] = manifest_entry(cfg)

    man_path = os.path.join(args.out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {man_path}")


if __name__ == "__main__":
    main()
