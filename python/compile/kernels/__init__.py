"""Layer-1 Pallas kernels for the LROA federated-learning stack.

Every kernel is authored for TPU idioms (MXU-shaped tiles, VMEM block
schedules expressed via ``BlockSpec``) but lowered with ``interpret=True``
so the emitted HLO runs on any PJRT backend, including the rust CPU client
on the request path.  Correctness oracles live in :mod:`ref` and are
enforced by ``python/tests``.
"""

from .aggregate import weighted_aggregate
from .matmul import matmul_bias_act
from .sgd_momentum import sgd_momentum_update

__all__ = ["matmul_bias_act", "sgd_momentum_update", "weighted_aggregate"]
