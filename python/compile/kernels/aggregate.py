"""Weighted client-delta aggregation (Pallas, eq. (4) of the paper).

Server-side model aggregation under adaptive sampling:

    theta' = theta + sum_k coef_k * delta_k,
    coef_k = w_{n_k} / (K * q_{n_k})     (inverse-probability re-weighting)

``deltas`` arrives stacked ``[K_max, d]``; unused slots carry ``coef = 0``
so one compiled artifact serves every sampling frequency ``K <= K_max``.
The kernel blocks the parameter axis and keeps the K reduction inside a
block — a single pass over HBM (K+1 streams in, 1 out), the fusion a CUDA
version would get from a custom reduction kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# See sgd_momentum.py: one block per call on the CPU interpret path,
# VMEM-sized blocks under the TPU profile.
import os as _os

BLOCK = 65_536 if _os.environ.get("LROA_BLOCK_PROFILE", "cpu") == "tpu" else 1 << 21


def _agg_kernel(theta_ref, deltas_ref, coefs_ref, o_ref):
    # deltas block: [K_max, blk]; coefs: [K_max].  The reduction stays in
    # VMEM registers; jnp.dot maps it onto the vector unit.
    acc = jnp.dot(coefs_ref[...], deltas_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = theta_ref[...] + acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def weighted_aggregate(
    theta: jax.Array,
    deltas: jax.Array,
    coefs: jax.Array,
    *,
    block: int = BLOCK,
) -> jax.Array:
    """``theta + coefs @ deltas`` over the flat parameter axis.

    Args:
      theta: ``[d]`` flat global model.
      deltas: ``[K_max, d]`` stacked client model deltas.
      coefs: ``[K_max]`` aggregation coefficients (0 for unused slots).

    Returns:
      ``[d]`` updated flat global model.
    """
    if theta.ndim != 1 or deltas.ndim != 2 or coefs.ndim != 1:
        raise ValueError(f"bad ranks: t{theta.shape} d{deltas.shape} c{coefs.shape}")
    if deltas.shape != (coefs.shape[0], theta.shape[0]):
        raise ValueError(f"shape mismatch: t{theta.shape} d{deltas.shape} c{coefs.shape}")

    d = theta.shape[0]
    k_max = coefs.shape[0]
    blk = min(block, d)
    rem = (-d) % blk

    theta_p = jnp.pad(theta, (0, rem)) if rem else theta
    deltas_p = jnp.pad(deltas, ((0, 0), (0, rem))) if rem else deltas

    out = pl.pallas_call(
        _agg_kernel,
        grid=((d + rem) // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((k_max, blk), lambda i: (0, i)),
            pl.BlockSpec((k_max,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d + rem,), theta.dtype),
        interpret=True,
    )(theta_p, deltas_p, coefs)

    return out[:d]
