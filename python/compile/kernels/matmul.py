"""Tiled matmul with fused bias + activation (Pallas, TPU-idiom).

This is the compute hot-spot of every dense layer in the model's forward
and backward passes.  The CUDA analogue would tile for shared memory and
tensor cores; here the same insight is expressed for the TPU memory
hierarchy:

* the grid iterates over ``(M/bm, N/bn, K/bk)`` output/reduction tiles,
* ``BlockSpec`` index maps describe the HBM -> VMEM schedule (the role
  threadblock indexing plays on GPU),
* partial products accumulate in an f32 VMEM scratch tile that is only
  written back on the last reduction step (input-tile double buffering is
  provided by the Pallas pipeline),
* bias add + activation are fused into the epilogue so activations never
  round-trip through HBM.

Lowered with ``interpret=True``: on CPU-PJRT the kernel executes as plain
HLO; on a real TPU the identical source compiles to a Mosaic kernel
targeting the 128x128 MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes come in two profiles:
#
# * ``tpu`` — 128-multiple tiles sized for ~16 MB VMEM with double
#   buffering; what the identical kernel source would use when compiled
#   by Mosaic for the real MXU.
# * ``cpu`` (default here) — the artifacts in this repo execute the
#   interpret-lowered HLO on the CPU PJRT client, where every grid step
#   pays a while-loop + dynamic-slice round trip; covering each axis with
#   as few blocks as possible is ~14x faster end-to-end (see
#   EXPERIMENTS.md §Perf).  ``BLOCK_M`` is set above any activation-row
#   count we emit so the M axis is never split or padded.
#
# Select with LROA_BLOCK_PROFILE=tpu|cpu at AOT time.
import os as _os

if _os.environ.get("LROA_BLOCK_PROFILE", "cpu") == "tpu":
    BLOCK_M, BLOCK_N, BLOCK_K = 256, 128, 128
else:
    BLOCK_M, BLOCK_N, BLOCK_K = 1 << 20, 512, 4096

ACTIVATIONS = ("linear", "relu", "tanh")


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, activation: str, n_k: int):
    """One (bm, bn) output tile; grid axis 2 walks the K reduction."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU-shaped partial product, accumulated in f32 regardless of input dtype.
    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k_step == n_k - 1)
    def _epilogue():
        acc = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        elif activation == "tanh":
            acc = jnp.tanh(acc)
        o_ref[...] = acc.astype(o_ref.dtype)


def _pad_to(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    rem = (-x.shape[axis]) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "block_k")
)
def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    activation: str = "linear",
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
) -> jax.Array:
    """``act(x @ w + b)`` computed by the tiled Pallas kernel.

    Args:
      x: ``[M, K]`` input activations.
      w: ``[K, N]`` weights.
      b: ``[N]`` bias.
      activation: one of ``linear | relu | tanh``.

    Returns:
      ``[M, N]`` activations with the dtype of ``x``.
    """
    if activation not in ACTIVATIONS:
        raise ValueError(f"activation must be one of {ACTIVATIONS}, got {activation!r}")
    if x.ndim != 2 or w.ndim != 2 or b.ndim != 1:
        raise ValueError(f"bad ranks: x{x.shape} w{w.shape} b{b.shape}")
    if x.shape[1] != w.shape[0] or w.shape[1] != b.shape[0]:
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")

    m, k = x.shape
    _, n = w.shape

    # Shrink blocks for small problems so no axis pads beyond one tile.
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)

    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    bp = _pad_to(b, bn, 0)

    mp, kp = xp.shape
    np_ = wp.shape[1]
    n_k = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, activation=activation, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bn,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        # f32 accumulator tile held in VMEM across the K reduction.
        scratch_shapes=[pl.MemorySpace.ANY((bm, bn), jnp.float32)],
        interpret=True,
    )(xp, wp, bp)

    return out[:m, :n]
