"""Pure-jnp correctness oracles for the Pallas kernels.

These are the specification: the kernels in this package must match the
oracles to float tolerance across shapes and dtypes.  ``python/tests``
enforces the equivalence with hypothesis-driven shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_bias_act_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, *, activation: str = "linear"
) -> jax.Array:
    """Oracle for :func:`kernels.matmul.matmul_bias_act`."""
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "tanh":
        out = jnp.tanh(out)
    elif activation != "linear":
        raise ValueError(f"unknown activation {activation!r}")
    return out.astype(x.dtype)


def sgd_momentum_update_ref(
    params: jax.Array,
    momentum: jax.Array,
    grad: jax.Array,
    lr: jax.Array,
    *,
    rho: float = 0.9,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for :func:`kernels.sgd_momentum.sgd_momentum_update`."""
    m_new = rho * momentum + grad
    p_new = params - lr * m_new
    return p_new, m_new


def weighted_aggregate_ref(
    theta: jax.Array, deltas: jax.Array, coefs: jax.Array
) -> jax.Array:
    """Oracle for :func:`kernels.aggregate.weighted_aggregate`."""
    return theta + jnp.einsum("k,kd->d", coefs, deltas).astype(theta.dtype)
