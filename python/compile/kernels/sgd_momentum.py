"""Fused SGD-with-momentum parameter update (Pallas, bandwidth-bound).

The paper's local update (Algorithm 1 line 9) runs E epochs of momentum
SGD on each selected device.  Updating ``d``-dimensional parameters costs
three HBM streams (params, momentum, grad) when fused — an unfused
implementation pays five (momentum read/write, param read/write, grad
read).  The kernel blocks the flat parameter vector into VMEM-tile-sized
chunks and performs the classic (PyTorch-convention) update in one pass:

    m' = rho * m + g
    p' = p - lr * m'

``lr`` arrives as a scalar carried in SMEM-style (1,)-blocked memory so the
same compiled artifact serves every round of the decayed LR schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8 sublanes x 128 lanes = 1024-element f32 VMEM tile.  On the CPU
# interpret path each grid step costs a while-loop iteration, so the
# default block covers the full flat vector (<= 2^21 params); the TPU
# profile uses VMEM-sized 64k blocks.
import os as _os

BLOCK = 65_536 if _os.environ.get("LROA_BLOCK_PROFILE", "cpu") == "tpu" else 1 << 21


def _sgd_kernel(lr_ref, p_ref, m_ref, g_ref, po_ref, mo_ref, *, rho: float):
    lr = lr_ref[0]
    m_new = rho * m_ref[...] + g_ref[...]
    mo_ref[...] = m_new
    po_ref[...] = p_ref[...] - lr * m_new


@functools.partial(jax.jit, static_argnames=("rho", "block"))
def sgd_momentum_update(
    params: jax.Array,
    momentum: jax.Array,
    grad: jax.Array,
    lr: jax.Array,
    *,
    rho: float = 0.9,
    block: int = BLOCK,
) -> tuple[jax.Array, jax.Array]:
    """One fused momentum-SGD step over flat f32 vectors.

    Args:
      params: ``[d]`` flat parameters.
      momentum: ``[d]`` flat momentum buffer.
      grad: ``[d]`` flat gradient.
      lr: scalar learning rate (traced, so one artifact serves the schedule).
      rho: momentum coefficient (paper: 0.9).

    Returns:
      ``(params', momentum')``.
    """
    if params.ndim != 1 or params.shape != momentum.shape or params.shape != grad.shape:
        raise ValueError(
            f"flat vectors required: p{params.shape} m{momentum.shape} g{grad.shape}"
        )
    d = params.shape[0]
    blk = min(block, d)
    rem = (-d) % blk
    pad = lambda v: jnp.pad(v, (0, rem)) if rem else v  # noqa: E731
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1)

    p_new, m_new = pl.pallas_call(
        functools.partial(_sgd_kernel, rho=rho),
        grid=((d + rem) // blk,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # lr broadcast to every block
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d + rem,), params.dtype),
            jax.ShapeDtypeStruct((d + rem,), momentum.dtype),
        ],
        interpret=True,
    )(lr_arr, pad(params), pad(momentum), pad(grad))

    return p_new[:d], m_new[:d]
