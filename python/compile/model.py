"""Layer-2 JAX model: federated CNN train/eval/aggregate steps.

The paper trains a ResNet-18 on CIFAR-10 and a 6.6M-param CNN on FEMNIST.
We substitute two compact CNNs on synthetic non-IID tasks (see
DESIGN.md §4) with the identical federated semantics:

* ``init(seed) -> theta``                      flat-parameter He init,
* ``train_step(theta, m, x, y, lr)``           one momentum-SGD minibatch,
* ``eval_batch(theta, x, y, mask)``            masked loss-sum / correct-count,
* ``aggregate(theta, deltas, coefs)``          eq. (4) re-weighted aggregation.

All entry points operate on the **flat** parameter vector so the rust
coordinator treats model state as an opaque ``Vec<f32>``.

Pallas is the compute hot-spot in *both* directions: every dense layer
(including convolutions, routed through im2col patches) is a
``custom_vjp`` whose forward and backward matmuls are the L1 Pallas
kernel, and the optimizer update / server aggregation are the fused L1
elementwise kernels.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.aggregate import weighted_aggregate
from .kernels.matmul import matmul_bias_act
from .kernels.sgd_momentum import sgd_momentum_update

# ---------------------------------------------------------------------------
# Pallas-backed dense layer with custom VJP (kernel on fwd AND bwd paths).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x: jax.Array, w: jax.Array, b: jax.Array, activation: str) -> jax.Array:
    """``act(x @ w + b)`` via the Pallas tiled-matmul kernel."""
    return matmul_bias_act(x, w, b, activation=activation)


def _dense_fwd(x, w, b, activation):
    out = matmul_bias_act(x, w, b, activation=activation)
    return out, (x, w, out)


def _dense_bwd(activation, res, dy):
    x, w, out = res
    if activation == "relu":
        g = dy * (out > 0).astype(dy.dtype)
    elif activation == "tanh":
        g = dy * (1.0 - out * out)
    else:  # linear
        g = dy
    zero_k = jnp.zeros((w.shape[0],), dtype=g.dtype)
    zero_n = jnp.zeros((w.shape[1],), dtype=g.dtype)
    # dx = g @ w.T, dw = x.T @ g — both through the Pallas kernel.
    dx = matmul_bias_act(g, w.T, zero_k, activation="linear")
    dw = matmul_bias_act(x.T, g, zero_n, activation="linear")
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)


# ---------------------------------------------------------------------------
# Parameter spec / flat <-> tree plumbing.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    """One named parameter tensor in the flat layout."""

    name: str
    shape: tuple[int, ...]

    @property
    def size(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out


@dataclass(frozen=True)
class ModelConfig:
    """Architecture + export-time shape configuration for one variant."""

    name: str
    input_hw: tuple[int, int]
    input_c: int
    num_classes: int
    conv_channels: tuple[int, ...]
    conv_kernel: int
    hidden: int
    train_batch: int
    eval_batch: int
    k_max: int
    layers: tuple[LayerSpec, ...] = field(default=(), compare=False)

    def __post_init__(self):
        object.__setattr__(self, "layers", tuple(self._build_layers()))

    def _build_layers(self):
        h, w = self.input_hw
        c_in = self.input_c
        specs = []
        for i, c_out in enumerate(self.conv_channels):
            k = self.conv_kernel
            specs.append(LayerSpec(f"conv{i}_w", (k * k * c_in, c_out)))
            specs.append(LayerSpec(f"conv{i}_b", (c_out,)))
            # 'SAME' conv followed by 2x2 max-pool.
            h, w = h // 2, w // 2
            c_in = c_out
        flat = h * w * c_in
        specs.append(LayerSpec("fc0_w", (flat, self.hidden)))
        specs.append(LayerSpec("fc0_b", (self.hidden,)))
        specs.append(LayerSpec("fc1_w", (self.hidden, self.num_classes)))
        specs.append(LayerSpec("fc1_b", (self.num_classes,)))
        return specs

    @property
    def dim(self) -> int:
        """Total flat parameter count ``d``."""
        return sum(s.size for s in self.layers)

    @property
    def model_bits(self) -> int:
        """Model update size in bits (paper's ``M = 32 d``)."""
        return 32 * self.dim


VARIANTS: dict[str, ModelConfig] = {
    # FEMNIST-like: 28x28x1, 62 classes (digits+upper+lower), writer-shift
    # non-IID.  ~114k params.
    "femnist": ModelConfig(
        name="femnist",
        input_hw=(28, 28),
        input_c=1,
        num_classes=62,
        conv_channels=(8, 16),
        conv_kernel=5,
        hidden=128,
        train_batch=32,
        eval_batch=64,
        k_max=8,
    ),
    # CIFAR-like: 32x32x3, 10 classes, Dirichlet(0.5) label-skew.  ~140k params.
    "cifar": ModelConfig(
        name="cifar",
        input_hw=(32, 32),
        input_c=3,
        num_classes=10,
        conv_channels=(16, 32),
        conv_kernel=3,
        hidden=64,
        train_batch=32,
        eval_batch=64,
        k_max=8,
    ),
}


def unflatten(cfg: ModelConfig, theta: jax.Array) -> dict[str, jax.Array]:
    """Slice the flat vector into named parameter tensors."""
    params = {}
    off = 0
    for spec in cfg.layers:
        params[spec.name] = lax.dynamic_slice_in_dim(theta, off, spec.size).reshape(
            spec.shape
        )
        off += spec.size
    return params


def flatten_tree(cfg: ModelConfig, tree: dict[str, jax.Array]) -> jax.Array:
    """Concatenate named tensors back into the flat layout."""
    return jnp.concatenate([tree[s.name].reshape(-1) for s in cfg.layers])


# ---------------------------------------------------------------------------
# Forward pass.
# ---------------------------------------------------------------------------


def _conv_block(x: jax.Array, w: jax.Array, b: jax.Array, kernel: int) -> jax.Array:
    """SAME conv (as im2col patches + Pallas dense) + ReLU + 2x2 max-pool.

    ``conv_general_dilated_patches`` is a plain (differentiable) XLA data
    movement op; all FLOPs flow through the Pallas matmul.
    """
    n, h, wd, c = x.shape
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(kernel, kernel),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # [N, H, W, C*k*k]
    cols = patches.reshape(n * h * wd, c * kernel * kernel)
    out = dense(cols, w, b, "relu").reshape(n, h, wd, w.shape[1])
    # 2x2 max-pool, stride 2.
    return lax.reduce_window(
        out, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(cfg: ModelConfig, theta: jax.Array, x: jax.Array) -> jax.Array:
    """Logits for a batch ``x: [B, H, W, C]`` under flat params ``theta``."""
    p = unflatten(cfg, theta)
    h = x
    for i in range(len(cfg.conv_channels)):
        h = _conv_block(h, p[f"conv{i}_w"], p[f"conv{i}_b"], cfg.conv_kernel)
    h = h.reshape(h.shape[0], -1)
    h = dense(h, p["fc0_w"], p["fc0_b"], "relu")
    return dense(h, p["fc1_w"], p["fc1_b"], "linear")


def _cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-example cross-entropy, numerically stable."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return logz - gold


# ---------------------------------------------------------------------------
# Exported entry points.
# ---------------------------------------------------------------------------


def loss_fn(cfg: ModelConfig, theta: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.mean(_cross_entropy(forward(cfg, theta, x), y))


def train_step(
    cfg: ModelConfig,
    theta: jax.Array,
    momentum: jax.Array,
    x: jax.Array,
    y: jax.Array,
    lr: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One momentum-SGD minibatch step on flat parameters.

    Returns ``(theta', momentum', batch_loss)``.
    """
    loss, grad = jax.value_and_grad(lambda t: loss_fn(cfg, t, x, y))(theta)
    theta_new, m_new = sgd_momentum_update(theta, momentum, grad, lr, rho=0.9)
    return theta_new, m_new, loss


def eval_batch(
    cfg: ModelConfig,
    theta: jax.Array,
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Masked evaluation: ``(sum of ce loss, count of correct)`` over mask==1."""
    logits = forward(cfg, theta, x)
    ce = _cross_entropy(logits, y)
    pred = jnp.argmax(logits, axis=-1)
    loss_sum = jnp.sum(ce * mask)
    correct = jnp.sum((pred == y).astype(jnp.float32) * mask)
    return loss_sum, correct


def init(cfg: ModelConfig, seed: jax.Array) -> jax.Array:
    """He-initialized flat parameter vector from an int32 seed scalar."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for spec in cfg.layers:
        key, sub = jax.random.split(key)
        if spec.name.endswith("_b"):
            chunks.append(jnp.zeros(spec.size, jnp.float32))
        else:
            fan_in = spec.shape[0]
            std = jnp.sqrt(2.0 / fan_in)
            # Damp the classifier head so initial logits are near zero and
            # the starting loss sits at ~log(num_classes).
            if spec.name == "fc1_w":
                std = std * 0.1
            chunks.append(jax.random.normal(sub, (spec.size,), jnp.float32) * std)
    return jnp.concatenate(chunks)


def aggregate(
    cfg: ModelConfig, theta: jax.Array, deltas: jax.Array, coefs: jax.Array
) -> jax.Array:
    """Eq. (4): ``theta + sum_k coef_k * delta_k`` via the Pallas kernel."""
    del cfg
    return weighted_aggregate(theta, deltas, coefs)
