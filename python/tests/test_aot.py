"""AOT pipeline: lowering works, HLO text is parseable, manifest is honest."""

import json

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def femnist():
    return M.VARIANTS["femnist"]


def test_entry_points_cover_contract(femnist):
    names = [name for name, _, _ in aot.entry_points(femnist)]
    assert names == ["init", "train_step", "eval_batch", "aggregate"]


def test_manifest_entry_is_consistent(femnist):
    entry = aot.manifest_entry(femnist)
    assert entry["dim"] == femnist.dim
    assert entry["model_bits"] == 32 * femnist.dim
    assert sum(l["size"] for l in entry["layers"]) == femnist.dim
    json.dumps(entry)  # must be serializable


@pytest.mark.parametrize("fn_name", ["init", "aggregate"])
def test_small_entry_points_lower_to_hlo_text(femnist, fn_name):
    eps = {name: (fn, ex) for name, fn, ex in aot.entry_points(femnist)}
    fn, example = eps[fn_name]
    lowered = jax.jit(fn).lower(*example)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text


def test_lowered_init_executes(femnist):
    eps = {name: (fn, ex) for name, fn, ex in aot.entry_points(femnist)}
    fn, _ = eps["init"]
    (theta,) = jax.jit(fn)(jnp.int32(0))
    assert theta.shape == (femnist.dim,)
    assert bool(jnp.isfinite(theta).all())


def test_artifacts_on_disk_match_manifest_if_built():
    import os

    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man_path = os.path.join(root, "manifest.json")
    if not os.path.exists(man_path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(man_path) as f:
        man = json.load(f)
    assert man["format"] == "hlo-text"
    for name, entry in man["variants"].items():
        cfg = M.VARIANTS[name]
        assert entry["dim"] == cfg.dim
        for fn_name in entry["artifacts"]:
            path = os.path.join(root, name, f"{fn_name}.hlo.txt")
            assert os.path.exists(path), path
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule")
