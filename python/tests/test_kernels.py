"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (and the activation set) and asserts allclose
against ref.py — the core correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_bias_act, sgd_momentum_update, weighted_aggregate
from compile.kernels.ref import (
    matmul_bias_act_ref,
    sgd_momentum_update_ref,
    weighted_aggregate_ref,
)

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# matmul + bias + activation
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    act=st.sampled_from(["linear", "relu", "tanh"]),
)
def test_matmul_matches_ref_across_shapes(m, k, n, act):
    x = rand(1, (m, k))
    w = rand(2, (k, n))
    b = rand(3, (n,))
    out = matmul_bias_act(x, w, b, activation=act)
    ref = matmul_bias_act_ref(x, w, b, activation=act)
    assert out.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(128, 128, 128), (65, 130, 67), (1, 1, 1), (256, 64, 32)])
def test_matmul_block_boundary_shapes(shape):
    m, k, n = shape
    x = rand(4, (m, k))
    w = rand(5, (k, n))
    b = rand(6, (n,))
    out = matmul_bias_act(x, w, b, activation="relu")
    ref = matmul_bias_act_ref(x, w, b, activation="relu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_matmul_custom_blocks():
    x, w, b = rand(7, (100, 40)), rand(8, (40, 60)), rand(9, (60,))
    out = matmul_bias_act(x, w, b, activation="linear", block_m=32, block_n=16, block_k=8)
    ref = matmul_bias_act_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_matmul_bf16_inputs_accumulate_in_f32():
    x = rand(10, (64, 64), jnp.bfloat16)
    w = rand(11, (64, 64), jnp.bfloat16)
    b = rand(12, (64,), jnp.bfloat16)
    out = matmul_bias_act(x, w, b, activation="linear")
    assert out.dtype == jnp.bfloat16
    ref = matmul_bias_act_ref(x, w, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=5e-2, atol=5e-2
    )


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul_bias_act(rand(1, (4, 5)), rand(2, (6, 7)), rand(3, (7,)))
    with pytest.raises(ValueError):
        matmul_bias_act(rand(1, (4, 5)), rand(2, (5, 7)), rand(3, (8,)))
    with pytest.raises(ValueError):
        matmul_bias_act(rand(1, (4, 5)), rand(2, (5, 7)), rand(3, (7,)), activation="gelu")


# ---------------------------------------------------------------------------
# fused SGD momentum
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(1, 40_000),
    rho=st.sampled_from([0.0, 0.5, 0.9, 0.99]),
    lr=st.floats(1e-4, 1.0),
)
def test_sgd_matches_ref(d, rho, lr):
    p = rand(20, (d,))
    m = rand(21, (d,), scale=0.1)
    g = rand(22, (d,), scale=0.5)
    p2, m2 = sgd_momentum_update(p, m, g, jnp.float32(lr), rho=rho)
    pr, mr = sgd_momentum_update_ref(p, m, g, jnp.float32(lr), rho=rho)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(pr), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(mr), rtol=1e-6, atol=1e-6)


def test_sgd_block_boundaries():
    for d in [8192, 8193, 16384, 123]:
        p, m, g = rand(23, (d,)), rand(24, (d,)), rand(25, (d,))
        p2, m2 = sgd_momentum_update(p, m, g, jnp.float32(0.1))
        pr, mr = sgd_momentum_update_ref(p, m, g, jnp.float32(0.1))
        np.testing.assert_allclose(np.asarray(p2), np.asarray(pr), rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(m2), np.asarray(mr), rtol=1e-6, atol=1e-6)


def test_sgd_lr_is_traced_not_baked():
    # Same compiled fn must serve different lr values.
    d = 1000
    p, m, g = rand(26, (d,)), jnp.zeros(d), rand(27, (d,))
    p_a, _ = sgd_momentum_update(p, m, g, jnp.float32(0.1))
    p_b, _ = sgd_momentum_update(p, m, g, jnp.float32(0.2))
    delta_a = np.asarray(p - p_a)
    delta_b = np.asarray(p - p_b)
    np.testing.assert_allclose(2 * delta_a, delta_b, rtol=1e-5, atol=1e-6)


def test_sgd_rejects_shape_mismatch():
    with pytest.raises(ValueError):
        sgd_momentum_update(rand(1, (10,)), rand(2, (11,)), rand(3, (10,)), jnp.float32(0.1))


# ---------------------------------------------------------------------------
# weighted aggregation (eq. 4)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(d=st.integers(1, 30_000), k=st.integers(1, 8))
def test_aggregate_matches_ref(d, k):
    theta = rand(30, (d,))
    deltas = rand(31, (k, d), scale=0.3)
    coefs = rand(32, (k,), scale=2.0)
    out = weighted_aggregate(theta, deltas, coefs)
    ref = weighted_aggregate_ref(theta, deltas, coefs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_aggregate_zero_coefs_are_identity():
    d, k = 5000, 4
    theta = rand(33, (d,))
    deltas = rand(34, (k, d))
    out = weighted_aggregate(theta, deltas, jnp.zeros(k))
    np.testing.assert_allclose(np.asarray(out), np.asarray(theta), rtol=1e-7)


def test_aggregate_is_linear_in_coefs():
    d, k = 2048, 3
    theta = jnp.zeros(d)
    deltas = rand(35, (k, d))
    c1 = jnp.array([1.0, 0.0, 0.0])
    c2 = jnp.array([0.0, 2.0, 0.5])
    a = weighted_aggregate(theta, deltas, c1)
    b = weighted_aggregate(theta, deltas, c2)
    ab = weighted_aggregate(theta, deltas, c1 + c2)
    np.testing.assert_allclose(np.asarray(a + b), np.asarray(ab), rtol=1e-5, atol=1e-6)


def test_aggregate_rejects_bad_shapes():
    with pytest.raises(ValueError):
        weighted_aggregate(rand(1, (10,)), rand(2, (3, 11)), rand(3, (3,)))
    with pytest.raises(ValueError):
        weighted_aggregate(rand(1, (10,)), rand(2, (3, 10)), rand(3, (4,)))
