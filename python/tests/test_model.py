"""L2 model correctness: shapes, gradients, training signal, exports."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels.ref import matmul_bias_act_ref

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module", params=["femnist", "cifar"])
def cfg(request):
    return M.VARIANTS[request.param]


def batch_for(cfg, b, seed=0):
    h, w = cfg.input_hw
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, h, w, cfg.input_c))
    y = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0, cfg.num_classes)
    return x, y


def test_dim_matches_layer_sum(cfg):
    assert cfg.dim == sum(s.size for s in cfg.layers)
    assert cfg.model_bits == 32 * cfg.dim


def test_init_shapes_and_stats(cfg):
    theta = M.init(cfg, jnp.int32(0))
    assert theta.shape == (cfg.dim,)
    p = M.unflatten(cfg, theta)
    for spec in cfg.layers:
        assert p[spec.name].shape == spec.shape
        if spec.name.endswith("_b"):
            assert float(jnp.abs(p[spec.name]).max()) == 0.0
    # He init: weight std ~ sqrt(2/fan_in).
    w = p["fc0_w"]
    expect = np.sqrt(2.0 / w.shape[0])
    assert 0.5 * expect < float(w.std()) < 1.5 * expect


def test_flatten_unflatten_roundtrip(cfg):
    theta = M.init(cfg, jnp.int32(3))
    tree = M.unflatten(cfg, theta)
    back = M.flatten_tree(cfg, tree)
    np.testing.assert_array_equal(np.asarray(theta), np.asarray(back))


def test_forward_shapes(cfg):
    theta = M.init(cfg, jnp.int32(1))
    x, _ = batch_for(cfg, 4)
    logits = M.forward(cfg, theta, x)
    assert logits.shape == (4, cfg.num_classes)
    assert bool(jnp.isfinite(logits).all())


def test_initial_loss_near_log_classes(cfg):
    theta = M.init(cfg, jnp.int32(2))
    x, y = batch_for(cfg, 16)
    loss = M.loss_fn(cfg, theta, x, y)
    expect = np.log(cfg.num_classes)
    assert 0.3 * expect < float(loss) < 3.0 * expect


def test_dense_custom_vjp_matches_pure_jnp_grads(cfg):
    """The Pallas-backed dense (fwd+bwd) must differentiate like jnp."""
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (6, 20))
    w = jax.random.normal(jax.random.PRNGKey(10), (20, 8)) * 0.2
    b = jax.random.normal(jax.random.PRNGKey(11), (8,)) * 0.1

    def loss_pallas(w, b):
        return jnp.sum(M.dense(x, w, b, "relu") ** 2)

    def loss_ref(w, b):
        return jnp.sum(matmul_bias_act_ref(x, w, b, activation="relu") ** 2)

    gp = jax.grad(loss_pallas, argnums=(0, 1))(w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1))(w, b)
    np.testing.assert_allclose(np.asarray(gp[0]), np.asarray(gr[0]), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gp[1]), np.asarray(gr[1]), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("act", ["linear", "relu", "tanh"])
def test_dense_activations_differentiate(act):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 10))
    w = jax.random.normal(jax.random.PRNGKey(1), (10, 5)) * 0.3
    b = jnp.zeros(5)
    g = jax.grad(lambda w: jnp.sum(M.dense(x, w, b, act)))(w)
    assert bool(jnp.isfinite(g).all())
    assert float(jnp.abs(g).max()) > 0.0


def test_train_step_reduces_loss_on_fixed_batch(cfg):
    theta = M.init(cfg, jnp.int32(5))
    mom = jnp.zeros_like(theta)
    x, y = batch_for(cfg, 8, seed=42)
    losses = []
    for _ in range(6):
        theta, mom, loss = M.train_step(cfg, theta, mom, x, y, jnp.float32(0.05))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_train_step_momentum_accumulates(cfg):
    theta = M.init(cfg, jnp.int32(6))
    mom = jnp.zeros_like(theta)
    x, y = batch_for(cfg, 8)
    _, mom1, _ = M.train_step(cfg, theta, mom, x, y, jnp.float32(0.05))
    assert float(jnp.abs(mom1).max()) > 0.0


def test_eval_batch_mask(cfg):
    theta = M.init(cfg, jnp.int32(7))
    x, y = batch_for(cfg, 10)
    full = M.eval_batch(cfg, theta, x, y, jnp.ones(10))
    none = M.eval_batch(cfg, theta, x, y, jnp.zeros(10))
    half_mask = jnp.array([1.0] * 5 + [0.0] * 5)
    half = M.eval_batch(cfg, theta, x, y, half_mask)
    assert float(none[0]) == 0.0 and float(none[1]) == 0.0
    assert 0.0 < float(half[0]) < float(full[0])
    assert 0 <= float(full[1]) <= 10


def test_aggregate_entry_point(cfg):
    theta = M.init(cfg, jnp.int32(8))
    k = cfg.k_max
    deltas = jax.random.normal(jax.random.PRNGKey(12), (k, cfg.dim)) * 0.01
    coefs = jnp.zeros(k).at[0].set(0.5).at[1].set(0.25)
    out = M.aggregate(cfg, theta, deltas, coefs)
    expect = theta + 0.5 * deltas[0] + 0.25 * deltas[1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-6)
