//! Bench: eq. (4) aggregation — the PJRT Pallas-kernel artifact vs a
//! native rust loop, across model sizes, plus the surrounding buffer
//! marshalling. Shows where the server-side aggregation time goes.

use lroa::bench::bencher_from_args;
use lroa::runtime::Engine;

/// Native reference: theta + sum_k coef_k * delta_k.
fn native_aggregate(theta: &[f32], deltas: &[&[f32]], coefs: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.extend_from_slice(theta);
    for (delta, &c) in deltas.iter().zip(coefs) {
        for (o, &d) in out.iter_mut().zip(*delta) {
            *o += c * d;
        }
    }
}

fn main() {
    let mut b = bencher_from_args();

    // Native aggregation across model sizes (the last is the paper's
    // FEMNIST CNN size, 6.6M params).
    for &d in &[111_902usize, 1_000_000, 6_603_710] {
        let theta: Vec<f32> = (0..d).map(|i| (i as f32 * 1e-4).sin()).collect();
        let d0: Vec<f32> = theta.iter().map(|x| x * 0.01).collect();
        let d1: Vec<f32> = theta.iter().map(|x| x * -0.02).collect();
        let coefs = [0.6f32, 1.2];
        let mut out = Vec::with_capacity(d);
        b.bench(&format!("aggregate/native/d={d}"), || {
            native_aggregate(&theta, &[&d0, &d1], &coefs, &mut out);
            out.len()
        });
    }

    // PJRT kernel artifact (includes literal marshalling both ways).
    if std::path::Path::new("artifacts/manifest.json").exists() {
        for variant in ["femnist", "cifar"] {
            let eng = Engine::from_dir(std::path::Path::new("artifacts"), variant).unwrap();
            let d = eng.dim();
            let theta: Vec<f32> = (0..d).map(|i| (i as f32 * 1e-4).sin()).collect();
            let d0: Vec<f32> = theta.iter().map(|x| x * 0.01).collect();
            let d1: Vec<f32> = theta.iter().map(|x| x * -0.02).collect();
            let coefs = [0.6f32, 1.2];
            b.bench(&format!("aggregate/pjrt-pallas/{variant}(d={d})"), || {
                eng.aggregate(&theta, &[&d0, &d1], &coefs).unwrap()
            });
        }
    } else {
        eprintln!("artifacts missing: skipping PJRT aggregation bench");
    }

    b.report();
}
