//! Bench: one full simulated round (control + sampling + queues + metrics)
//! for every policy, control-plane-only — the coordinator's request path
//! with the PJRT compute excluded.  Plus one full-stack round (with PJRT
//! local training) when artifacts are present.

use lroa::bench::bencher_from_args;
use lroa::config::{Config, Policy};
use lroa::fl::{Server, SimMode};

fn main() {
    let mut b = bencher_from_args();

    for policy in [
        Policy::Lroa,
        Policy::UniformDynamic,
        Policy::UniformStatic,
        Policy::DivFl,
    ] {
        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.train.policy = policy;
        cfg.train.rounds = 1_000_000; // never reached; we drive rounds manually
        let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        let mut t = 0usize;
        b.bench(&format!("round/control-plane/{policy}"), || {
            server.round(t).unwrap();
            t += 1;
        });
    }

    // Full-stack round including PJRT local training, if artifacts exist.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let mut cfg = Config::for_dataset("femnist").unwrap();
        cfg.system.num_devices = 24;
        cfg.train.policy = Policy::Lroa;
        cfg.train.samples_per_device = (40, 80);
        cfg.train.test_samples = 64;
        cfg.train.rounds = 1_000_000;
        cfg.train.eval_every = 1_000_000_007; // exclude evaluation from the loop cost
        let mut server = Server::new(cfg, SimMode::Full).unwrap();
        let mut t = 1usize; // t=0 would evaluate (t % eval_every == 0)
        b.bench("round/full-stack/LROA+pjrt", || {
            server.round(t).unwrap();
            t += 1;
        });
    } else {
        eprintln!("artifacts missing: skipping full-stack round bench");
    }

    b.report();
}
