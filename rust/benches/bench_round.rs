//! Bench: one full simulated round (control + sampling + queues + metrics)
//! for every policy, control-plane-only — the coordinator's request path
//! with the PJRT compute excluded.  Plus the local-training fan-out at
//! pool widths 1 / 2 / auto (synthetic per-client workload, so the
//! speedup is tracked without artifacts), and one full-stack round (with
//! PJRT local training, sequential vs parallel) when artifacts exist.

use lroa::bench::bencher_from_args;
use lroa::config::{Config, Policy};
use lroa::fl::{Server, SimMode};
use lroa::par;
use lroa::rng::Rng;

/// Synthetic stand-in for one client's local-training compute: enough
/// RNG-driven arithmetic (~a few hundred µs) that thread scheduling
/// overhead is visible relative to real work.
fn synthetic_client_work(client: usize, rng: &mut Rng) -> u64 {
    let mut acc = client as u64;
    for _ in 0..40_000 {
        acc = acc.wrapping_add((rng.normal().to_bits()).rotate_left(7));
    }
    acc
}

fn main() {
    let mut b = bencher_from_args();

    for policy in [
        Policy::Lroa,
        Policy::UniformDynamic,
        Policy::UniformStatic,
        Policy::DivFl,
    ] {
        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.train.policy = policy;
        cfg.train.rounds = 1_000_000; // never reached; we drive rounds manually
        let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        let mut t = 0usize;
        b.bench(&format!("round/control-plane/{policy}"), || {
            server.round(t).unwrap();
            t += 1;
        });
    }

    // Local-training fan-out: sequential vs parallel over 8 synthetic
    // clients.  The ratio of these rows is the round-path speedup the
    // scoped-thread fan-out buys (results are bitwise identical by
    // construction; see par::fan_out).
    let clients = 8usize;
    let make_jobs = || -> Vec<(usize, Rng)> {
        let mut root = Rng::new(99);
        (0..clients).map(|c| (c, root.fork(c as u64))).collect()
    };
    let widths = [1usize, 2, par::auto_threads().min(clients)];
    for &threads in &widths {
        b.bench(&format!("round/fanout-{clients}clients/threads={threads}"), || {
            par::fan_out(make_jobs(), threads, || (), |_, (c, mut rng)| {
                Ok(synthetic_client_work(c, &mut rng))
            })
            .unwrap()
        });
    }

    // Full-stack round including PJRT local training, if artifacts exist:
    // sequential (train_threads=1) vs auto-width parallel.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        for (tag, threads) in [("seq", 1usize), ("par", 0usize)] {
            let mut cfg = Config::for_dataset("femnist").unwrap();
            cfg.system.num_devices = 24;
            cfg.train.policy = Policy::Lroa;
            cfg.train.samples_per_device = (40, 80);
            cfg.train.test_samples = 64;
            cfg.train.rounds = 1_000_000;
            cfg.train.eval_every = 1_000_000_007; // exclude evaluation from the loop cost
            cfg.train.train_threads = threads;
            let mut server = Server::new(cfg, SimMode::Full).unwrap();
            let mut t = 1usize; // t=0 would evaluate (t % eval_every == 0)
            b.bench(&format!("round/full-stack/LROA+pjrt/{tag}"), || {
                server.round(t).unwrap();
                t += 1;
            });
        }
    } else {
        eprintln!("artifacts missing: skipping full-stack round bench");
    }

    b.report();
}
