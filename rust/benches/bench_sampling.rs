//! Bench: client selection — K-with-replacement categorical sampling and
//! the DivFL greedy facility-location loop (the paper's most expensive
//! baseline selector, O(N²·K) naive) across fleet sizes.

use lroa::bench::bencher_from_args;
use lroa::rng::Rng;
use lroa::sampling::{
    p2c_marginals, sample_by_probability, softmax_distribution, DivFlState, Projector,
};

fn main() {
    let mut b = bencher_from_args();

    for &n in &[120usize, 480, 1920] {
        let mut rng = Rng::new(3);
        let probs: Vec<f64> = {
            let raw: Vec<f64> = (0..n).map(|_| rng.range(0.1, 1.0)).collect();
            let s: f64 = raw.iter().sum();
            raw.into_iter().map(|x| x / s).collect()
        };
        let weights = vec![1.0 / n as f64; n];
        for &k in &[2usize, 6] {
            b.bench(&format!("sample/with-replacement/N={n}/K={k}"), || {
                sample_by_probability(&probs, &weights, k, &mut rng)
            });
        }
    }

    // DivFL greedy (warm state: all clients embedded).
    for &n in &[120usize, 480] {
        let mut st = DivFlState::new(n, 32);
        let proj = Projector::new(32, 1);
        let mut rng = Rng::new(5);
        for i in 0..n {
            let delta: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
            st.observe(i, proj.project(&delta));
        }
        let weights = vec![1.0 / n as f64; n];
        for &k in &[2usize, 6] {
            b.bench(&format!("sample/divfl-greedy/N={n}/K={k}"), || {
                st.select(&weights, k)
            });
        }
    }

    // Marginal kernels: P2C's exact per-slot marginals and the bandit's
    // softmax distribution (one update per round each).
    for &n in &[120usize, 480, 1920] {
        let mut rng = Rng::new(11);
        let scores: Vec<f64> = (0..n).map(|_| rng.range(0.0, 1.0)).collect();
        b.bench(&format!("sample/p2c-marginals/N={n}"), || {
            p2c_marginals(&scores)
        });
        b.bench(&format!("sample/bandit-distribution/N={n}"), || {
            softmax_distribution(&scores, 0.25, 0.05)
        });
    }

    // Embedding projection of a full model delta.
    let proj = Projector::new(32, 9);
    let delta: Vec<f32> = (0..136_874).map(|i| (i as f32 * 1e-3).sin()).collect();
    b.bench("sample/divfl-project/d=136874", || proj.project(&delta));

    b.report();
}
