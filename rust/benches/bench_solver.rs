//! Bench: Algorithm 2 (the per-round LROA solve) vs fleet size, plus the
//! individual f/p/q blocks.  The control plane must stay far below the
//! modeled per-round latency (seconds) — targets: << 10 ms at N = 120.

use lroa::bench::bencher_from_args;
use lroa::config::{ControlConfig, SystemConfig};
use lroa::control::{freq, power, sum, LroaSolver};
use lroa::rng::Rng;
use lroa::system::Fleet;

fn main() {
    let mut b = bencher_from_args();
    let model_bits = 32.0 * 136_874.0;

    for &n in &[30usize, 120, 480, 1920] {
        let sys = SystemConfig {
            num_devices: n,
            ..SystemConfig::default()
        };
        let mut rng = Rng::new(7);
        let fleet = Fleet::generate(&sys, (50, 400), &mut rng);
        let h: Vec<f64> = (0..n).map(|_| rng.range(0.01, 0.5)).collect();
        let queues: Vec<f64> = (0..n).map(|_| rng.range(0.0, 20.0)).collect();
        let mut solver = LroaSolver::new(sys, ControlConfig::default(), 10.0, 1e4, model_bits);

        b.bench(&format!("algorithm2/N={n}"), || {
            solver.solve_round(&fleet.devices, fleet.weights(), &h, &queues)
        });
    }

    // Block-level breakdown at the paper's N = 120.
    let n = 120;
    let sys = SystemConfig::default();
    let mut rng = Rng::new(9);
    let fleet = Fleet::generate(&sys, (50, 400), &mut rng);
    let h: Vec<f64> = (0..n).map(|_| rng.range(0.01, 0.5)).collect();
    let queues: Vec<f64> = (0..n).map(|_| rng.range(0.0, 20.0)).collect();
    let q = vec![1.0 / n as f64; n];
    let mut out = Vec::new();
    b.bench("block/theorem2-freq", || {
        freq::solve_freqs(&fleet.devices, 1e4, &q, &queues, 2, &mut out)
    });
    b.bench("block/theorem3-power", || {
        power::solve_powers(&fleet.devices, 1e4, &q, &h, &queues, 2, sys.noise_w, &mut out)
    });
    let a2: Vec<f64> = (0..n).map(|i| 10.0 + i as f64).collect();
    let a3: Vec<f64> = fleet.weights().iter().map(|w| 1e4 * 10.0 * w * w).collect();
    let e: Vec<f64> = queues.clone();
    b.bench("block/sum-q", || {
        sum::solve(&q, &a2, &a3, &e, 2, 1e-6, 1e-6, 200)
    });

    b.report();
}
