//! Self-contained timing harness for `cargo bench`.
//!
//! The offline registry carries no `criterion`; this module provides the
//! subset the benches need — warmup, calibrated iteration counts, robust
//! statistics (median / p10 / p90), and aligned human-readable reporting —
//! with zero dependencies.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl Sample {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median.as_secs_f64()
    }
}

/// Minimal criterion-like bench runner.
pub struct Bencher {
    /// Target measurement time per case.
    pub measure_time: Duration,
    /// Warmup time per case.
    pub warmup_time: Duration,
    /// Number of timed batches (statistics samples).
    pub batches: usize,
    results: Vec<Sample>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            measure_time: Duration::from_secs(2),
            warmup_time: Duration::from_millis(300),
            batches: 20,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick preset for CI / smoke runs.
    pub fn quick() -> Self {
        Self {
            measure_time: Duration::from_millis(400),
            warmup_time: Duration::from_millis(100),
            batches: 8,
            results: Vec::new(),
        }
    }

    /// Time `f`, preventing the compiler from discarding its result.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Sample {
        // Warmup + calibration: how many iters fit in one batch?
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup_time || warm_iters < 1 {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
        let batch_time = self.measure_time.as_secs_f64() / self.batches as f64;
        let iters_per_batch = ((batch_time / per_iter).ceil() as u64).max(1);

        let mut batch_means: Vec<f64> = Vec::with_capacity(self.batches);
        for _ in 0..self.batches {
            let t0 = Instant::now();
            for _ in 0..iters_per_batch {
                std::hint::black_box(f());
            }
            batch_means.push(t0.elapsed().as_secs_f64() / iters_per_batch as f64);
        }
        batch_means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |p: f64| -> Duration {
            let idx = ((batch_means.len() - 1) as f64 * p).round() as usize;
            Duration::from_secs_f64(batch_means[idx])
        };
        let mean =
            Duration::from_secs_f64(batch_means.iter().sum::<f64>() / batch_means.len() as f64);
        let sample = Sample {
            name: name.to_string(),
            iters: iters_per_batch * self.batches as u64,
            median: pick(0.5),
            p10: pick(0.1),
            p90: pick(0.9),
            mean,
        };
        self.results.push(sample);
        self.results.last().unwrap()
    }

    /// Print the aligned report for all cases run so far.
    pub fn report(&self) {
        let width = self
            .results
            .iter()
            .map(|s| s.name.len())
            .max()
            .unwrap_or(4)
            .max(4);
        println!(
            "{:width$}  {:>12} {:>12} {:>12} {:>10}",
            "name",
            "median",
            "p10",
            "p90",
            "iters",
            width = width
        );
        for s in &self.results {
            println!(
                "{:width$}  {:>12} {:>12} {:>12} {:>10}",
                s.name,
                fmt_duration(s.median),
                fmt_duration(s.p10),
                fmt_duration(s.p90),
                s.iters,
                width = width
            );
        }
    }

    pub fn results(&self) -> &[Sample] {
        &self.results
    }
}

/// Human duration: ns/µs/ms/s with 3 significant places.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Human duration from raw nanoseconds — the shape trace summaries and
/// bench JSON reports carry ([`fmt_duration`] over `Duration` values).
pub fn fmt_ns(ns: f64) -> String {
    fmt_duration(Duration::from_nanos(ns.max(0.0) as u64))
}

/// `--quick` flag helper shared by the bench binaries.
pub fn bencher_from_args() -> Bencher {
    if std::env::args().any(|a| a == "--quick") || std::env::var("LROA_BENCH_QUICK").is_ok() {
        Bencher::quick()
    } else {
        Bencher::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            measure_time: Duration::from_millis(50),
            warmup_time: Duration::from_millis(5),
            batches: 4,
            results: Vec::new(),
        };
        let s = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i) * i);
            }
            acc
        });
        assert!(s.median.as_nanos() > 0);
        assert!(s.p10 <= s.median && s.median <= s.p90);
        assert!(s.iters >= 4);
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50ms");
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
        assert!(fmt_duration(Duration::from_micros(12)).ends_with("µs"));
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(-3.0), "0ns");
        assert_eq!(fmt_ns(2e9), "2.00s");
    }
}
