//! Experiment configuration: paper §VII-A defaults, config files, CLI overrides.
//!
//! Format is an INI/TOML-subset (`[section]` + `key = value` lines with
//! `#` comments), parsed without external deps.  Every knob can also be
//! overridden on the command line as `--section.key=value`, and the
//! effective config is dumped at the top of each run for provenance.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use crate::Result;

/// Which policy drives sampling + resource allocation (paper §VII-A plus
/// the related-work baselines the ROADMAP names).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// LROA: adaptive sampling + dynamic `f`/`p` (the paper's method).
    Lroa,
    /// Uni-D: uniform sampling, LROA's dynamic `f`/`p`.
    UniformDynamic,
    /// Uni-S: uniform sampling, static mid-power + energy-balance `f`.
    UniformStatic,
    /// DivFL: submodular diverse selection, static resources (as adapted in the paper).
    DivFl,
    /// Greedy-channel: the K best-`h_n^t` reachable devices, static resources
    /// (the fast-convergence scheduling baseline of Shi et al.).
    GreedyChannel,
    /// Round-robin: cycle through the fleet K devices at a time, static
    /// resources (the fairness anchor).
    RoundRobin,
    /// Power-of-two-choices: per slot, sample two devices uniformly and
    /// keep the better channel — the classic load-balancing sampler.
    PowerOfTwoChoices,
    /// Contextual bandit: UCB-scored softmax sampling over per-device
    /// context vectors (recent observed gains, availability streak,
    /// virtual energy-queue backlog), with exact selection marginals so
    /// eq. (4) aggregation stays unbiased (knobs: `[bandit]`).
    Bandit,
    /// Thompson sampling over the bandit's context vector: one Gaussian
    /// posterior draw per device, mapped through the same exact softmax
    /// marginals so eq. (4) stays unbiased (knobs: `[thompson]`).
    Thompson,
    /// LinUCB: ridge-regression contextual UCB sharing one d×d design
    /// matrix across devices, Sherman–Morrison rank-1 updates (knobs:
    /// `[linucb]`).
    LinUcb,
    /// Convergence-aware scheduling in the spirit of Shi et al.: selection
    /// weighted by staleness × last observed update norm (softmax knobs
    /// shared with `[bandit]`).
    ConvAware,
    /// Oracle: clairvoyant latency lower bound (best reachable device at
    /// `f_max`/`p_max`, foresight tie-breaking via `Environment::peek`) —
    /// the regret anchor of `lroa regret`.
    Oracle,
    /// Oracle-E: the clairvoyant *and* budget-feasible anchor — per round
    /// it solves the same queue-priced energy-constrained resource
    /// problem as LROA (Theorem 2/3 kernels) before picking the fastest
    /// device, splitting regret into online + budget components.
    OracleEnergy,
}

impl Policy {
    /// Every scheme, registry order (LROA first — the comparison anchor).
    pub const ALL: [Policy; 13] = [
        Policy::Lroa,
        Policy::UniformDynamic,
        Policy::UniformStatic,
        Policy::DivFl,
        Policy::GreedyChannel,
        Policy::RoundRobin,
        Policy::PowerOfTwoChoices,
        Policy::Bandit,
        Policy::Thompson,
        Policy::LinUcb,
        Policy::ConvAware,
        Policy::Oracle,
        Policy::OracleEnergy,
    ];

    pub fn parse(s: &str) -> Result<Policy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "lroa" => Policy::Lroa,
            "uni-d" | "unid" | "uniform-dynamic" => Policy::UniformDynamic,
            "uni-s" | "unis" | "uniform-static" => Policy::UniformStatic,
            "divfl" => Policy::DivFl,
            "greedy" | "greedy-channel" => Policy::GreedyChannel,
            "rr" | "round-robin" | "roundrobin" => Policy::RoundRobin,
            "p2c" | "power-of-two" | "power-of-two-choices" => Policy::PowerOfTwoChoices,
            "bandit" | "ucb" | "contextual-bandit" => Policy::Bandit,
            "thompson" | "ts" | "thompson-sampling" => Policy::Thompson,
            "linucb" | "lin-ucb" => Policy::LinUcb,
            "conv-aware" | "convaware" | "conv" => Policy::ConvAware,
            "oracle" => Policy::Oracle,
            "oracle-e" | "oraclee" | "oracle-energy" => Policy::OracleEnergy,
            other => anyhow::bail!(
                "unknown policy {other:?} \
                 (lroa|uni-d|uni-s|divfl|greedy|rr|p2c|bandit|thompson|linucb|conv-aware|oracle|oracle-e)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Lroa => "LROA",
            Policy::UniformDynamic => "Uni-D",
            Policy::UniformStatic => "Uni-S",
            Policy::DivFl => "DivFL",
            Policy::GreedyChannel => "Greedy",
            Policy::RoundRobin => "RR",
            Policy::PowerOfTwoChoices => "P2C",
            Policy::Bandit => "Bandit",
            Policy::Thompson => "Thompson",
            Policy::LinUcb => "LinUCB",
            Policy::ConvAware => "Conv-Aware",
            Policy::Oracle => "Oracle",
            Policy::OracleEnergy => "Oracle-E",
        }
    }
}

impl fmt::Display for Policy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Which dynamic-environment model realizes the per-round system
/// randomness (see [`crate::env`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnvKind {
    /// The paper's IID exponential channel, always-on fleet (default).
    Static,
    /// Two-state Gilbert–Elliott Markov fading per device.
    GilbertElliott,
    /// Markov device dropout/arrival: the candidate set `N^t` varies.
    Availability,
    /// Slow random-walk drift on per-device compute/energy parameters.
    Drift,
    /// Replay of a recorded channel/availability log (`env.trace_path`).
    Trace,
    /// Adversarial worst-case channel: degrades the gains a greedy
    /// scheduler would chase, informed by the previous round's selection.
    Adversarial,
    /// Composite: layers several mechanisms (`env.compose`, e.g.
    /// `avail+ge+drift` or a scenario preset like `diurnal`) with
    /// AND-availability / layered-gain merge semantics (see
    /// [`crate::env::CompositeEnv`]).
    Composite,
}

impl EnvKind {
    /// Every environment, registry order (static first — the paper's setting).
    pub const ALL: [EnvKind; 7] = [
        EnvKind::Static,
        EnvKind::GilbertElliott,
        EnvKind::Availability,
        EnvKind::Drift,
        EnvKind::Trace,
        EnvKind::Adversarial,
        EnvKind::Composite,
    ];

    /// The environments that need no external input (`all` in env lists
    /// expands to these; `trace` must be named explicitly with its log).
    pub const SYNTHETIC: [EnvKind; 5] = [
        EnvKind::Static,
        EnvKind::GilbertElliott,
        EnvKind::Availability,
        EnvKind::Drift,
        EnvKind::Adversarial,
    ];

    pub fn parse(s: &str) -> Result<EnvKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "static" => EnvKind::Static,
            "ge" | "gilbert-elliott" | "gilbertelliott" => EnvKind::GilbertElliott,
            "avail" | "availability" => EnvKind::Availability,
            "drift" => EnvKind::Drift,
            "trace" => EnvKind::Trace,
            "adv" | "adversarial" => EnvKind::Adversarial,
            "compose" | "composite" => EnvKind::Composite,
            other => {
                anyhow::bail!("unknown env {other:?} (static|ge|avail|drift|trace|adv|compose)")
            }
        })
    }

    /// Parse a comma list of environment names; `all` expands to every
    /// *synthetic* environment (trace needs a log, so it is never implied).
    /// The one list rule shared by `lroa sweep --envs` and the
    /// figure-harness `--envs` flag; the sweep axis itself is the richer
    /// [`crate::exp::EnvSel`], which also accepts `trace:<path>`.
    pub fn parse_list(val: &str) -> Result<Vec<EnvKind>> {
        if val == "all" {
            return Ok(EnvKind::SYNTHETIC.to_vec());
        }
        val.split(',').map(EnvKind::parse).collect()
    }

    pub fn name(&self) -> &'static str {
        match self {
            EnvKind::Static => "static",
            EnvKind::GilbertElliott => "ge",
            EnvKind::Availability => "avail",
            EnvKind::Drift => "drift",
            EnvKind::Trace => "trace",
            EnvKind::Adversarial => "adv",
            EnvKind::Composite => "compose",
        }
    }
}

impl fmt::Display for EnvKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Dynamic-environment parameters (`[env]` section).  Only the knobs of
/// the selected [`EnvKind`] matter; the rest are inert.
#[derive(Clone, Debug)]
pub struct EnvConfig {
    /// Which environment realizes the round randomness.
    pub kind: EnvKind,
    /// Gilbert–Elliott: P(good → bad) per round.
    pub ge_p_bad: f64,
    /// Gilbert–Elliott: P(bad → good) per round.
    pub ge_p_good: f64,
    /// Gilbert–Elliott: bad-state mean gain as a fraction of `channel_mean`.
    pub ge_bad_scale: f64,
    /// Availability: P(online → offline) per round.
    pub avail_p_drop: f64,
    /// Availability: P(offline → online) per round.
    pub avail_p_join: f64,
    /// Drift: per-round log-space random-walk step size.
    pub drift_sigma: f64,
    /// Drift: multiplier clamp band around the base parameters.
    pub drift_clip: (f64, f64),
    /// Trace: path of the recorded channel/availability CSV
    /// (`round,device,gain[,available]`; see `tests/fixtures/README.md`).
    pub trace_path: String,
    /// Adversarial: multiplier applied to a targeted device's gain
    /// (clamped to the clip floor).
    pub adv_degrade: f64,
    /// Adversarial: number of devices degraded per round; 0 = `2K`
    /// (the previous selection plus greedy's predicted next picks).
    pub adv_targets: usize,
    /// Composite: `+`-separated child mechanisms (`avail+ge+drift`) or a
    /// scenario preset name (`diurnal` | `flashcrowd` | `outage`); see
    /// [`parse_compose_spec`].
    pub compose: String,
    /// Composite shadowing: fraction of the log-normal shadow-fading
    /// variance shared across the fleet (0 = independent per device,
    /// 1 = one common field; co-located devices fade together).
    pub shadow_rho: f64,
    /// Composite shadowing: log-space standard deviation of the shadow
    /// field multiplied onto the merged gains (0 = shadowing off).
    pub shadow_std: f64,
}

impl Default for EnvConfig {
    fn default() -> Self {
        Self {
            kind: EnvKind::Static,
            ge_p_bad: 0.15,
            ge_p_good: 0.45,
            ge_bad_scale: 0.1,
            avail_p_drop: 0.05,
            avail_p_join: 0.25,
            drift_sigma: 0.02,
            drift_clip: (0.5, 2.0),
            trace_path: String::new(),
            adv_degrade: 0.2,
            adv_targets: 0,
            compose: "avail+ge+drift".to_string(),
            shadow_rho: 0.5,
            shadow_std: 0.0,
        }
    }
}

/// One mechanism inside a composite environment (`env.compose`, axis
/// syntax `compose:<a>+<b>+...`).  Every registry environment except
/// `compose` itself is admissible; the three scenario generators
/// (`diurnal` | `flashcrowd` | `outage`, built in
/// [`crate::env::scenario`]) are composite-only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComposeChild {
    Static,
    GilbertElliott,
    Availability,
    Drift,
    Trace,
    Adversarial,
    Diurnal,
    FlashCrowd,
    Outage,
}

impl ComposeChild {
    pub fn parse(s: &str) -> Result<ComposeChild> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "static" => ComposeChild::Static,
            "ge" | "gilbert-elliott" | "gilbertelliott" => ComposeChild::GilbertElliott,
            "avail" | "availability" => ComposeChild::Availability,
            "drift" => ComposeChild::Drift,
            "trace" => ComposeChild::Trace,
            "adv" | "adversarial" => ComposeChild::Adversarial,
            "diurnal" => ComposeChild::Diurnal,
            "flashcrowd" => ComposeChild::FlashCrowd,
            "outage" => ComposeChild::Outage,
            other => anyhow::bail!(
                "unknown composite child {other:?} \
                 (static|ge|avail|drift|trace|adv|diurnal|flashcrowd|outage)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ComposeChild::Static => "static",
            ComposeChild::GilbertElliott => "ge",
            ComposeChild::Availability => "avail",
            ComposeChild::Drift => "drift",
            ComposeChild::Trace => "trace",
            ComposeChild::Adversarial => "adv",
            ComposeChild::Diurnal => "diurnal",
            ComposeChild::FlashCrowd => "flashcrowd",
            ComposeChild::Outage => "outage",
        }
    }

    /// Whether the mechanism restricts the per-round candidate set (so a
    /// composite containing it makes `queue_gate_offline` meaningful).
    pub fn shapes_availability(&self) -> bool {
        matches!(
            self,
            ComposeChild::Availability
                | ComposeChild::Trace
                | ComposeChild::Diurnal
                | ComposeChild::FlashCrowd
                | ComposeChild::Outage
        )
    }
}

/// Named composite presets: `compose:<preset>` expands to the listed
/// child spec before parsing.  The spec string itself (not the
/// expansion) is what `env.compose` stores and hashes.
pub const COMPOSE_PRESETS: &[(&str, &str)] = &[
    // Timezone-staggered daily availability cycles over fading channels.
    ("diurnal", "diurnal+ge"),
    // Long quiet baseline punctuated by near-total mass-join windows.
    ("flashcrowd", "flashcrowd+ge"),
    // Correlated regional blackouts on top of fading + compute drift.
    ("outage", "outage+ge+drift"),
];

/// Parse a composite child spec (`a+b+c`, or a preset name from
/// [`COMPOSE_PRESETS`]) into its mechanism list: non-empty, duplicates
/// rejected.  Shared by config validation, fingerprint hashing, the
/// sweep-axis parser, and the composite constructor itself.
pub fn parse_compose_spec(spec: &str) -> Result<Vec<ComposeChild>> {
    let spec = spec.trim();
    let expanded = COMPOSE_PRESETS
        .iter()
        .find(|(name, _)| *name == spec)
        .map(|(_, children)| *children)
        .unwrap_or(spec);
    anyhow::ensure!(!expanded.is_empty(), "empty composite child spec");
    let mut out: Vec<ComposeChild> = Vec::new();
    for part in expanded.split('+') {
        let child = ComposeChild::parse(part.trim())?;
        anyhow::ensure!(
            !out.contains(&child),
            "duplicate composite child {:?} in {spec:?}",
            child.name()
        );
        out.push(child);
    }
    Ok(out)
}

impl EnvConfig {
    /// The parsed child list of `env.compose` (presets expanded).
    pub fn compose_children(&self) -> Result<Vec<ComposeChild>> {
        parse_compose_spec(&self.compose)
            .map_err(|e| anyhow::anyhow!("env.compose {:?}: {e}", self.compose))
    }
}

/// Contextual-bandit scheduler knobs (`[bandit]` section).  Inert unless
/// `train.policy = bandit`; see [`crate::control::policy`] for how the
/// scores and the exact sampling marginals are formed.
#[derive(Clone, Debug)]
pub struct BanditConfig {
    /// UCB exploration-bonus coefficient `c` in
    /// `c·sqrt(ln(t+1) / (1 + pulls_n))`.
    pub ucb_c: f64,
    /// Softmax temperature mapping scores to sampling probabilities
    /// (lower = greedier).
    pub temp: f64,
    /// Uniform exploration floor ε mixed into the softmax (keeps every
    /// marginal strictly positive, so eq. (4) coefficients stay finite).
    pub eps: f64,
    /// EMA factor for the recent-observed-gain context feature.
    pub gain_ema: f64,
    /// Mixing weight of the context prior vs the empirical pulled-arm
    /// reward in the exploitation term (1 = pure context, 0 = pure
    /// reward history).
    pub ctx_weight: f64,
}

impl Default for BanditConfig {
    fn default() -> Self {
        Self {
            ucb_c: 0.5,
            temp: 0.25,
            eps: 0.05,
            gain_ema: 0.3,
            ctx_weight: 0.5,
        }
    }
}

/// Thompson-sampling scheduler knobs (`[thompson]` section).  Inert
/// unless `train.policy = thompson`.  The posterior draws come from a
/// policy-owned RNG stream, so the exact softmax marginals are a pure
/// function of the observed history (see [`crate::control::policy`]).
#[derive(Clone, Debug)]
pub struct ThompsonConfig {
    /// Posterior standard deviation of an unpulled arm; shrinks as
    /// `prior_std / sqrt(1 + pulls)`.
    pub prior_std: f64,
    /// Softmax temperature mapping posterior draws to marginals.
    pub temp: f64,
    /// Uniform exploration floor ε mixed into the softmax.
    pub eps: f64,
    /// EMA factor for the recent-observed-gain context feature.
    pub gain_ema: f64,
}

impl Default for ThompsonConfig {
    fn default() -> Self {
        Self {
            prior_std: 0.3,
            temp: 0.25,
            eps: 0.05,
            gain_ema: 0.3,
        }
    }
}

/// LinUCB scheduler knobs (`[linucb]` section).  Inert unless
/// `train.policy = linucb`.  One shared ridge design matrix over the
/// bandit's d=3 context features (gain EMA, availability streak, queue
/// headroom), maintained by Sherman–Morrison rank-1 updates.
#[derive(Clone, Debug)]
pub struct LinUcbConfig {
    /// Confidence-width multiplier α on `sqrt(xᵀ A⁻¹ x)`.
    pub alpha: f64,
    /// Ridge regularizer: the design matrix starts at `ridge · I`.
    pub ridge: f64,
    /// Softmax temperature mapping UCB scores to marginals.
    pub temp: f64,
    /// Uniform exploration floor ε mixed into the softmax.
    pub eps: f64,
    /// EMA factor for the recent-observed-gain context feature.
    pub gain_ema: f64,
}

impl Default for LinUcbConfig {
    fn default() -> Self {
        Self {
            alpha: 0.5,
            ridge: 1.0,
            temp: 0.25,
            eps: 0.05,
            gain_ema: 0.3,
        }
    }
}

/// Mobile-edge system parameters (paper §III + §VII-A defaults).
#[derive(Clone, Debug)]
pub struct SystemConfig {
    /// Number of edge devices `N`.
    pub num_devices: usize,
    /// Sampling frequency `K` (draws with replacement per round).
    pub k: usize,
    /// Local epochs `E`.
    pub local_epochs: usize,
    /// Total uplink bandwidth `B` [Hz].
    pub bandwidth_hz: f64,
    /// Background noise power `N0` [W].
    pub noise_w: f64,
    /// Mean of the exponential channel gain `h_n^t`.
    pub channel_mean: f64,
    /// Channel-gain outlier clip (paper: [0.01, 0.5]).
    pub channel_clip: (f64, f64),
    /// Transmit power bounds `p_min`/`p_max` [W].
    pub p_min_w: f64,
    pub p_max_w: f64,
    /// CPU frequency bounds `f_min`/`f_max` [Hz].
    pub f_min_hz: f64,
    pub f_max_hz: f64,
    /// Effective capacitance coefficient `alpha_n`.
    pub alpha: f64,
    /// CPU cycles per sample `c_n`.
    pub cycles_per_sample: f64,
    /// Per-device, per-round energy budget `Ē_n` [J].
    pub energy_budget_j: f64,
    /// Model update size `M` [bits]; 0 = take from the artifact manifest.
    pub model_bits: f64,
    /// Downlink rate `r_{n,d}` [bit/s]; the paper ignores download cost
    /// ("we ignore download cost and only consider upload time"), so 0
    /// disables the download term.
    pub downlink_bps: f64,
    /// Degree of *device* heterogeneity: each device's `c_n`, `alpha_n`
    /// and bounds are scaled by Uniform[1-h, 1+h].  0 reproduces the
    /// paper's homogeneous default ("all devices ... same communication
    /// and computation resources, except for different channels").
    pub hardware_spread: f64,
    /// Extra per-device energy-budget heterogeneity on top of
    /// `hardware_spread`: `Ē_n` is scaled by Uniform[1-s, 1+s] with
    /// `s = hardware_spread + budget_spread` (same single jitter draw,
    /// so 0 is bitwise-identical to the old behavior).  A first-class
    /// sweep axis (`--budget_spreads`) for evaluating the learned
    /// schedulers under budget heterogeneity.
    pub budget_spread: f64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        // Paper §VII-A, CIFAR-10 column.
        Self {
            num_devices: 120,
            k: 2,
            local_epochs: 2,
            bandwidth_hz: 1e6,
            noise_w: 0.01,
            channel_mean: 0.1,
            channel_clip: (0.01, 0.5),
            p_min_w: 0.001,
            p_max_w: 0.1,
            f_min_hz: 1.0e9,
            f_max_hz: 2.0e9,
            alpha: 2e-28,
            cycles_per_sample: 3.0e9,
            energy_budget_j: 15.0,
            model_bits: 0.0,
            downlink_bps: 0.0,
            hardware_spread: 0.0,
            budget_spread: 0.0,
        }
    }
}

impl SystemConfig {
    /// FEMNIST column of §VII-A.
    pub fn femnist() -> Self {
        Self {
            cycles_per_sample: 2.0e9,
            energy_budget_j: 5.0,
            ..Self::default()
        }
    }
}

/// LROA control knobs (paper §VI + §VII-B.1).
#[derive(Clone, Debug)]
pub struct ControlConfig {
    /// λ scale factor µ (λ = µ·λ0).
    pub mu: f64,
    /// V scale factor ν (V = ν·V0).
    pub nu: f64,
    /// Explicit λ override (>0 wins over µ·λ0).
    pub lambda_explicit: f64,
    /// Explicit V override (>0 wins over ν·V0).
    pub v_explicit: f64,
    /// Outer-loop stopping tolerance ε0 (Algorithm 2).
    pub eps_outer: f64,
    /// SUM inner-loop stopping tolerance ε1.
    pub eps_inner: f64,
    /// Iteration caps (defensive; the loops converge well before these).
    pub max_outer_iters: usize,
    pub max_inner_iters: usize,
    /// Probability floor keeping `q_n` in (0, 1].
    pub q_min: f64,
    /// Warm-start Algorithm 2 from the previous round's fixed point
    /// (default).  `false` restores the paper's cold midpoint/uniform
    /// initialization every round — the parity anchor.
    pub warm_start: bool,
    /// Gate virtual-queue arrivals on round candidacy (default): a
    /// device outside `N^t` is frozen — it neither accrues the
    /// `(1-(1-q)^K)E` charge nor the `-Ē_n` budget credit, so its
    /// backlog is flat across an outage.  `false` restores the old
    /// advance-everyone semantics — the bitwise parity anchor.
    pub queue_gate_offline: bool,
    /// Cost-objective weight `c ≥ 0` (Luo-et-al.-style cost-effective
    /// FL): the drift-plus-penalty trade-off becomes
    /// `V·(T + c·E) + queue drift`, i.e. every queue price is shifted to
    /// `Q_n + V·c`, so the existing virtual-queue machinery prices total
    /// energy against latency.  0 (default) is the paper's pure-latency
    /// objective, bitwise-identical to pre-knob behavior.
    pub cost_weight: f64,
}

impl Default for ControlConfig {
    fn default() -> Self {
        Self {
            mu: 1.0,
            nu: 1e5,
            lambda_explicit: 0.0,
            v_explicit: 0.0,
            eps_outer: 1e-4,
            eps_inner: 1e-6,
            max_outer_iters: 50,
            max_inner_iters: 200,
            q_min: 1e-6,
            warm_start: true,
            queue_gate_offline: true,
            cost_weight: 0.0,
        }
    }
}

/// Federated training loop parameters (paper §VII-A).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model/dataset variant: `femnist` or `cifar`.
    pub dataset: String,
    /// Total communication rounds `T`.
    pub rounds: usize,
    /// Initial learning rate (paper: 0.05 CIFAR, 0.1 FEMNIST).
    pub lr0: f64,
    /// LR is halved at these fractions of `rounds` (paper: 50%, 75%).
    pub lr_decay_at: (f64, f64),
    /// Samples per synthetic device: lo..hi (uniform; FEMNIST filter is >= 50).
    pub samples_per_device: (usize, usize),
    /// Test-set size for global evaluation.
    pub test_samples: usize,
    /// Evaluate every this many rounds.
    pub eval_every: usize,
    /// Master seed.
    pub seed: u64,
    /// Policy under test.
    pub policy: Policy,
    /// Class-separation / noise ratio of the synthetic task (higher = easier).
    pub data_snr: f64,
    /// Worker threads for parallel local client training:
    /// 0 = one per core, 1 = sequential.  Any value yields bitwise-
    /// identical results (per-client RNGs are forked up front).
    pub train_threads: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            dataset: "cifar".into(),
            rounds: 2000,
            lr0: 0.05,
            lr_decay_at: (0.5, 0.75),
            samples_per_device: (50, 400),
            test_samples: 1024,
            eval_every: 10,
            seed: 1,
            policy: Policy::Lroa,
            data_snr: 1.5,
            train_threads: 0,
        }
    }
}

/// The full experiment configuration.
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub system: SystemConfig,
    pub control: ControlConfig,
    pub train: TrainConfig,
    pub env: EnvConfig,
    pub bandit: BanditConfig,
    pub thompson: ThompsonConfig,
    pub linucb: LinUcbConfig,
    /// Where AOT artifacts live.
    pub artifacts_dir: String,
    /// Where run outputs (CSV/JSON) go.
    pub out_dir: String,
}

impl Config {
    /// Paper defaults for a dataset name (`cifar` | `femnist`).
    pub fn for_dataset(dataset: &str) -> Result<Config> {
        let mut cfg = Config {
            artifacts_dir: "artifacts".into(),
            out_dir: "runs".into(),
            ..Config::default()
        };
        match dataset {
            "cifar" => {
                cfg.train.dataset = "cifar".into();
                cfg.train.rounds = 2000;
                cfg.train.lr0 = 0.05;
                // 10-class label-skew task: harder SNR so accuracy climbs
                // gradually over the horizon instead of saturating.
                cfg.train.data_snr = 0.4;
            }
            "femnist" => {
                cfg.system = SystemConfig::femnist();
                cfg.train.dataset = "femnist".into();
                cfg.train.rounds = 1000;
                cfg.train.lr0 = 0.1;
            }
            other => anyhow::bail!("unknown dataset {other:?} (cifar|femnist)"),
        }
        Ok(cfg)
    }

    /// Load from a `[section] key = value` file, over the paper defaults.
    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)?;
        let kvs = parse_ini(&text)?;
        let mut cfg = match kvs.get("train.dataset").map(String::as_str) {
            Some(ds) => Config::for_dataset(ds)?,
            None => Config::for_dataset("cifar")?,
        };
        for (k, v) in &kvs {
            cfg.set(k, v)?;
        }
        Ok(cfg)
    }

    /// Apply `--section.key=value` CLI overrides (skips non-config args).
    pub fn apply_cli<S: AsRef<str>>(&mut self, args: &[S]) -> Result<()> {
        for a in args {
            let a = a.as_ref();
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    if k.contains('.') {
                        self.set(k, v)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Set one dotted key. Unknown keys are hard errors (typo safety).
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        let f = || -> Result<f64> {
            val.parse::<f64>()
                .map_err(|e| anyhow::anyhow!("bad float for {key}: {e}"))
        };
        let u = || -> Result<usize> {
            val.parse::<usize>()
                .map_err(|e| anyhow::anyhow!("bad int for {key}: {e}"))
        };
        let b = || -> Result<bool> {
            match val {
                "true" | "1" | "on" | "yes" => Ok(true),
                "false" | "0" | "off" | "no" => Ok(false),
                _ => Err(anyhow::anyhow!("bad bool for {key}: {val:?}")),
            }
        };
        match key {
            "system.num_devices" => self.system.num_devices = u()?,
            "system.k" => self.system.k = u()?,
            "system.local_epochs" => self.system.local_epochs = u()?,
            "system.bandwidth_hz" => self.system.bandwidth_hz = f()?,
            "system.noise_w" => self.system.noise_w = f()?,
            "system.channel_mean" => self.system.channel_mean = f()?,
            "system.channel_clip_lo" => self.system.channel_clip.0 = f()?,
            "system.channel_clip_hi" => self.system.channel_clip.1 = f()?,
            "system.p_min_w" => self.system.p_min_w = f()?,
            "system.p_max_w" => self.system.p_max_w = f()?,
            "system.f_min_hz" => self.system.f_min_hz = f()?,
            "system.f_max_hz" => self.system.f_max_hz = f()?,
            "system.alpha" => self.system.alpha = f()?,
            "system.cycles_per_sample" => self.system.cycles_per_sample = f()?,
            "system.energy_budget_j" => self.system.energy_budget_j = f()?,
            "system.model_bits" => self.system.model_bits = f()?,
            "system.downlink_bps" => self.system.downlink_bps = f()?,
            "system.hardware_spread" => self.system.hardware_spread = f()?,
            "system.budget_spread" => self.system.budget_spread = f()?,
            "control.mu" => self.control.mu = f()?,
            "control.nu" => self.control.nu = f()?,
            "control.lambda" => self.control.lambda_explicit = f()?,
            "control.v" => self.control.v_explicit = f()?,
            "control.eps_outer" => self.control.eps_outer = f()?,
            "control.eps_inner" => self.control.eps_inner = f()?,
            "control.max_outer_iters" => self.control.max_outer_iters = u()?,
            "control.max_inner_iters" => self.control.max_inner_iters = u()?,
            "control.q_min" => self.control.q_min = f()?,
            "control.warm_start" => self.control.warm_start = b()?,
            "control.queue_gate_offline" => self.control.queue_gate_offline = b()?,
            "control.cost_weight" => self.control.cost_weight = f()?,
            "train.dataset" => self.train.dataset = val.into(),
            "train.rounds" => self.train.rounds = u()?,
            "train.lr0" => self.train.lr0 = f()?,
            "train.lr_decay_at_1" => self.train.lr_decay_at.0 = f()?,
            "train.lr_decay_at_2" => self.train.lr_decay_at.1 = f()?,
            "train.samples_lo" => self.train.samples_per_device.0 = u()?,
            "train.samples_hi" => self.train.samples_per_device.1 = u()?,
            "train.test_samples" => self.train.test_samples = u()?,
            "train.eval_every" => self.train.eval_every = u()?,
            "train.seed" => self.train.seed = val.parse()?,
            "train.policy" => self.train.policy = Policy::parse(val)?,
            "train.data_snr" => self.train.data_snr = f()?,
            "train.train_threads" => self.train.train_threads = u()?,
            "env.kind" => self.env.kind = EnvKind::parse(val)?,
            "env.ge_p_bad" => self.env.ge_p_bad = f()?,
            "env.ge_p_good" => self.env.ge_p_good = f()?,
            "env.ge_bad_scale" => self.env.ge_bad_scale = f()?,
            "env.avail_p_drop" => self.env.avail_p_drop = f()?,
            "env.avail_p_join" => self.env.avail_p_join = f()?,
            "env.drift_sigma" => self.env.drift_sigma = f()?,
            "env.drift_lo" => self.env.drift_clip.0 = f()?,
            "env.drift_hi" => self.env.drift_clip.1 = f()?,
            "env.trace_path" => self.env.trace_path = val.into(),
            "env.adv_degrade" => self.env.adv_degrade = f()?,
            "env.adv_targets" => self.env.adv_targets = u()?,
            "env.compose" => self.env.compose = val.into(),
            "env.shadow_rho" => self.env.shadow_rho = f()?,
            "env.shadow_std" => self.env.shadow_std = f()?,
            "bandit.ucb_c" => self.bandit.ucb_c = f()?,
            "bandit.temp" => self.bandit.temp = f()?,
            "bandit.eps" => self.bandit.eps = f()?,
            "bandit.gain_ema" => self.bandit.gain_ema = f()?,
            "bandit.ctx_weight" => self.bandit.ctx_weight = f()?,
            "thompson.prior_std" => self.thompson.prior_std = f()?,
            "thompson.temp" => self.thompson.temp = f()?,
            "thompson.eps" => self.thompson.eps = f()?,
            "thompson.gain_ema" => self.thompson.gain_ema = f()?,
            "linucb.alpha" => self.linucb.alpha = f()?,
            "linucb.ridge" => self.linucb.ridge = f()?,
            "linucb.temp" => self.linucb.temp = f()?,
            "linucb.eps" => self.linucb.eps = f()?,
            "linucb.gain_ema" => self.linucb.gain_ema = f()?,
            "run.artifacts_dir" => self.artifacts_dir = val.into(),
            "run.out_dir" => self.out_dir = val.into(),
            other => anyhow::bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// Sanity-check invariants before a run.
    pub fn validate(&self) -> Result<()> {
        let s = &self.system;
        anyhow::ensure!(s.num_devices > 0, "num_devices must be > 0");
        anyhow::ensure!(s.k > 0 && s.k <= s.num_devices, "need 0 < K <= N");
        anyhow::ensure!(s.p_min_w > 0.0 && s.p_min_w <= s.p_max_w, "bad power bounds");
        anyhow::ensure!(s.f_min_hz > 0.0 && s.f_min_hz <= s.f_max_hz, "bad freq bounds");
        anyhow::ensure!(
            s.channel_clip.0 > 0.0 && s.channel_clip.0 < s.channel_clip.1,
            "bad channel clip"
        );
        anyhow::ensure!(s.bandwidth_hz > 0.0 && s.noise_w > 0.0, "bad B/N0");
        anyhow::ensure!(s.energy_budget_j > 0.0, "bad energy budget");
        anyhow::ensure!(
            (0.0..1.0).contains(&s.budget_spread),
            "system.budget_spread must be in [0, 1)"
        );
        let c = &self.control;
        anyhow::ensure!(c.q_min > 0.0 && c.q_min < 1.0 / s.num_devices as f64, "bad q_min");
        anyhow::ensure!(c.eps_outer > 0.0 && c.eps_inner > 0.0, "bad tolerances");
        anyhow::ensure!(
            c.cost_weight >= 0.0 && c.cost_weight.is_finite(),
            "control.cost_weight must be finite and >= 0"
        );
        let t = &self.train;
        anyhow::ensure!(t.rounds > 0 && t.lr0 > 0.0, "bad train params");
        anyhow::ensure!(
            t.samples_per_device.0 > 0 && t.samples_per_device.0 <= t.samples_per_device.1,
            "bad samples_per_device"
        );
        let e = &self.env;
        // A composite layers child mechanisms, so the kind-gated checks
        // below treat an included child the same as selecting that kind
        // directly.  Parsing the spec is itself the first check.
        let kids: Vec<ComposeChild> = if e.kind == EnvKind::Composite {
            e.compose_children()?
        } else {
            Vec::new()
        };
        for (name, p) in [
            ("env.ge_p_bad", e.ge_p_bad),
            ("env.ge_p_good", e.ge_p_good),
            ("env.avail_p_drop", e.avail_p_drop),
            ("env.avail_p_join", e.avail_p_join),
        ] {
            anyhow::ensure!((0.0..=1.0).contains(&p), "{name} must be in [0, 1]");
        }
        anyhow::ensure!(
            e.ge_bad_scale > 0.0 && e.ge_bad_scale <= 1.0,
            "env.ge_bad_scale must be in (0, 1]"
        );
        // The bad-state mean must clear the clip floor, or the clipped-
        // exponential rejection sampler stalls (acceptance ~ e^{-lo/mean}).
        // Only enforced when the GE environment is actually selected —
        // the other environments never touch this knob.
        anyhow::ensure!(
            !(e.kind == EnvKind::GilbertElliott || kids.contains(&ComposeChild::GilbertElliott))
                || e.ge_bad_scale * s.channel_mean >= s.channel_clip.0 - 1e-12,
            "env.ge_bad_scale * channel_mean ({}) is below the channel clip floor ({}); \
             rejection sampling the bad-state gain would stall",
            e.ge_bad_scale * s.channel_mean,
            s.channel_clip.0
        );
        anyhow::ensure!(e.drift_sigma >= 0.0, "env.drift_sigma must be >= 0");
        anyhow::ensure!(
            e.drift_clip.0 > 0.0 && e.drift_clip.0 <= 1.0 && e.drift_clip.1 >= 1.0,
            "env.drift clamp band must straddle 1"
        );
        anyhow::ensure!(
            !(e.kind == EnvKind::Trace || kids.contains(&ComposeChild::Trace))
                || !e.trace_path.is_empty(),
            "env.kind=trace requires env.trace_path (the recorded channel CSV)"
        );
        anyhow::ensure!(
            e.adv_degrade > 0.0 && e.adv_degrade <= 1.0,
            "env.adv_degrade must be in (0, 1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&e.shadow_rho),
            "env.shadow_rho must be in [0, 1]"
        );
        anyhow::ensure!(
            e.shadow_std.is_finite() && e.shadow_std >= 0.0,
            "env.shadow_std must be finite and >= 0"
        );
        let b = &self.bandit;
        anyhow::ensure!(b.ucb_c >= 0.0, "bandit.ucb_c must be >= 0");
        anyhow::ensure!(b.temp > 0.0, "bandit.temp must be > 0");
        anyhow::ensure!(
            (0.0..1.0).contains(&b.eps),
            "bandit.eps must be in [0, 1)"
        );
        anyhow::ensure!(
            b.gain_ema > 0.0 && b.gain_ema <= 1.0,
            "bandit.gain_ema must be in (0, 1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&b.ctx_weight),
            "bandit.ctx_weight must be in [0, 1]"
        );
        let ts = &self.thompson;
        anyhow::ensure!(ts.prior_std >= 0.0, "thompson.prior_std must be >= 0");
        anyhow::ensure!(ts.temp > 0.0, "thompson.temp must be > 0");
        anyhow::ensure!(
            (0.0..1.0).contains(&ts.eps),
            "thompson.eps must be in [0, 1)"
        );
        anyhow::ensure!(
            ts.gain_ema > 0.0 && ts.gain_ema <= 1.0,
            "thompson.gain_ema must be in (0, 1]"
        );
        let lu = &self.linucb;
        anyhow::ensure!(lu.alpha >= 0.0, "linucb.alpha must be >= 0");
        anyhow::ensure!(lu.ridge > 0.0, "linucb.ridge must be > 0");
        anyhow::ensure!(lu.temp > 0.0, "linucb.temp must be > 0");
        anyhow::ensure!(
            (0.0..1.0).contains(&lu.eps),
            "linucb.eps must be in [0, 1)"
        );
        anyhow::ensure!(
            lu.gain_ema > 0.0 && lu.gain_ema <= 1.0,
            "linucb.gain_ema must be in (0, 1]"
        );
        Ok(())
    }

    /// FNV-1a 64 over the full-precision `Debug` repr (f64 `Debug`
    /// round-trips, unlike the display-rounded [`Config::dump`]): a
    /// provenance hash for sweep manifests and `--resume` sidecars where
    /// any behavior-relevant knob change — however small — must change
    /// the hash.  Pure locations (`out_dir`, `artifacts_dir`) are
    /// cleared first; `artifacts_dir` matters only to Full-mode runs and
    /// is folded in by `Scenario::fingerprint` there.
    pub fn hash_hex(&self) -> String {
        let mut c = self.clone();
        c.out_dir = String::new();
        c.artifacts_dir = String::new();
        // Thread width is bitwise behavior-irrelevant (per-client RNGs
        // are forked up front; see `par`), so it must not invalidate a
        // resume done on a machine with a different pool width.
        c.train.train_threads = 0;
        // Env knobs of unselected kinds are inert (each environment
        // reads only its own knobs — keep this in sync with `crate::env`
        // if that ever changes): reset them to defaults so they can't
        // spuriously invalidate a `--resume`.
        let d = EnvConfig::default();
        // Under a composite kind, a child mechanism reads the same knobs
        // it would standalone — those stay live; everything else resets.
        let kids: Vec<ComposeChild> = if c.env.kind == EnvKind::Composite {
            c.env.compose_children().unwrap_or_default()
        } else {
            Vec::new()
        };
        if c.env.kind != EnvKind::GilbertElliott && !kids.contains(&ComposeChild::GilbertElliott) {
            c.env.ge_p_bad = d.ge_p_bad;
            c.env.ge_p_good = d.ge_p_good;
            c.env.ge_bad_scale = d.ge_bad_scale;
        }
        if c.env.kind != EnvKind::Availability && !kids.contains(&ComposeChild::Availability) {
            c.env.avail_p_drop = d.avail_p_drop;
            c.env.avail_p_join = d.avail_p_join;
        }
        if c.env.kind != EnvKind::Drift && !kids.contains(&ComposeChild::Drift) {
            c.env.drift_sigma = d.drift_sigma;
            c.env.drift_clip = d.drift_clip;
        }
        if c.env.kind != EnvKind::Trace && !kids.contains(&ComposeChild::Trace) {
            c.env.trace_path = d.trace_path.clone();
        }
        if c.env.kind != EnvKind::Adversarial && !kids.contains(&ComposeChild::Adversarial) {
            c.env.adv_degrade = d.adv_degrade;
            c.env.adv_targets = d.adv_targets;
        }
        if c.env.kind != EnvKind::Composite {
            c.env.compose = d.compose.clone();
            c.env.shadow_rho = d.shadow_rho;
            c.env.shadow_std = d.shadow_std;
        } else if c.env.shadow_std == 0.0 {
            // Shadowing off is bitwise inert, so the correlation knob is
            // resume-neutral until `shadow_std` turns the field on.
            c.env.shadow_rho = d.shadow_rho;
        }
        // Bandit knobs are only read by the bandit policy (and the
        // conv-aware scheduler, which shares the softmax knobs) — inert
        // (and resume-neutral) everywhere else, like unselected env knobs.
        if !matches!(c.train.policy, Policy::Bandit | Policy::ConvAware) {
            c.bandit = BanditConfig::default();
        }
        if c.train.policy != Policy::Thompson {
            c.thompson = ThompsonConfig::default();
        }
        if c.train.policy != Policy::LinUcb {
            c.linucb = LinUcbConfig::default();
        }
        // Warm start only affects the iterative Algorithm-2 solve, which
        // only the LROA policy runs (`solve_uniform_dynamic` is a single
        // exact pass).
        if c.train.policy != Policy::Lroa {
            c.control.warm_start = ControlConfig::default().warm_start;
        }
        // The cost-objective weight shifts queue prices, which only the
        // solver-backed policies consume.
        if !matches!(
            c.train.policy,
            Policy::Lroa | Policy::UniformDynamic | Policy::OracleEnergy
        ) {
            c.control.cost_weight = ControlConfig::default().cost_weight;
        }
        // Queue gating can only bite when the environment can take a
        // device offline; every other env has a full candidate set each
        // round, where gated and ungated updates are identical.  A
        // composite can shrink candidacy only through an
        // availability-shaping child.
        if !matches!(c.env.kind, EnvKind::Availability | EnvKind::Trace)
            && !kids.iter().any(ComposeChild::shapes_availability)
        {
            c.control.queue_gate_offline = ControlConfig::default().queue_gate_offline;
        }
        let repr = format!("{c:?}");
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in repr.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        format!("{h:016x}")
    }

    /// Human/machine-readable dump of every effective knob.
    pub fn dump(&self) -> String {
        let s = &self.system;
        let c = &self.control;
        let t = &self.train;
        let e = &self.env;
        let b = &self.bandit;
        let ts = &self.thompson;
        let lu = &self.linucb;
        format!(
            "[system] N={} K={} E={} B={:.3e} N0={} h_mean={} clip=({},{}) p=({},{}) f=({:.2e},{:.2e}) alpha={:.2e} c_n={:.2e} Ebar={} M_bits={} dl_bps={} spread={} budget_spread={}\n\
             [control] mu={} nu={} lambda*={} V*={} eps=({},{}) iters=({},{}) q_min={} warm_start={} queue_gate_offline={} cost_weight={}\n\
             [train] dataset={} rounds={} lr0={} decay=({},{}) samples=({},{}) test={} eval_every={} seed={} policy={} snr={} threads={}\n\
             [env] kind={} ge=({},{},{}) avail=({},{}) drift=({},{},{}) trace={:?} adv=({},{}) compose={:?} shadow=({},{})\n\
             [bandit] ucb_c={} temp={} eps={} gain_ema={} ctx_weight={}\n\
             [thompson] prior_std={} temp={} eps={} gain_ema={}\n\
             [linucb] alpha={} ridge={} temp={} eps={} gain_ema={}\n\
             [run] artifacts_dir={}",
            s.num_devices, s.k, s.local_epochs, s.bandwidth_hz, s.noise_w, s.channel_mean,
            s.channel_clip.0, s.channel_clip.1, s.p_min_w, s.p_max_w, s.f_min_hz, s.f_max_hz,
            s.alpha, s.cycles_per_sample, s.energy_budget_j, s.model_bits, s.downlink_bps,
            s.hardware_spread, s.budget_spread,
            c.mu, c.nu, c.lambda_explicit, c.v_explicit, c.eps_outer, c.eps_inner,
            c.max_outer_iters, c.max_inner_iters, c.q_min, c.warm_start, c.queue_gate_offline,
            c.cost_weight,
            t.dataset, t.rounds, t.lr0, t.lr_decay_at.0, t.lr_decay_at.1,
            t.samples_per_device.0, t.samples_per_device.1, t.test_samples, t.eval_every,
            t.seed, t.policy, t.data_snr, t.train_threads,
            e.kind, e.ge_p_bad, e.ge_p_good, e.ge_bad_scale, e.avail_p_drop, e.avail_p_join,
            e.drift_sigma, e.drift_clip.0, e.drift_clip.1, e.trace_path, e.adv_degrade,
            e.adv_targets, e.compose, e.shadow_rho, e.shadow_std,
            b.ucb_c, b.temp, b.eps, b.gain_ema, b.ctx_weight,
            ts.prior_std, ts.temp, ts.eps, ts.gain_ema,
            lu.alpha, lu.ridge, lu.temp, lu.eps, lu.gain_ema,
            self.artifacts_dir,
        )
    }
}

/// Parse `[section]` + `key = value` lines into dotted keys.
fn parse_ini(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        out.insert(key, v.trim().trim_matches('"').to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults() {
        let cfg = Config::for_dataset("cifar").unwrap();
        assert_eq!(cfg.system.num_devices, 120);
        assert_eq!(cfg.system.k, 2);
        assert_eq!(cfg.system.local_epochs, 2);
        assert_eq!(cfg.system.energy_budget_j, 15.0);
        assert_eq!(cfg.system.cycles_per_sample, 3.0e9);
        assert_eq!(cfg.train.rounds, 2000);
        assert_eq!(cfg.train.lr0, 0.05);

        let fem = Config::for_dataset("femnist").unwrap();
        assert_eq!(fem.system.energy_budget_j, 5.0);
        assert_eq!(fem.system.cycles_per_sample, 2.0e9);
        assert_eq!(fem.train.rounds, 1000);
        assert_eq!(fem.train.lr0, 0.1);
        assert!(fem.validate().is_ok());
    }

    #[test]
    fn ini_parse_and_set() {
        let text = r#"
            # comment
            [system]
            k = 4
            bandwidth_hz = 2e6   # inline comment
            [train]
            dataset = "femnist"
            policy = lroa
        "#;
        let kvs = parse_ini(text).unwrap();
        assert_eq!(kvs.get("system.k").unwrap(), "4");
        assert_eq!(kvs.get("train.dataset").unwrap(), "femnist");

        let mut cfg = Config::for_dataset("femnist").unwrap();
        for (k, v) in &kvs {
            cfg.set(k, v).unwrap();
        }
        assert_eq!(cfg.system.k, 4);
        assert_eq!(cfg.system.bandwidth_hz, 2e6);
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.apply_cli(&["--control.mu=10", "--train.rounds=50", "positional", "--flag"])
            .unwrap();
        assert_eq!(cfg.control.mu, 10.0);
        assert_eq!(cfg.train.rounds, 50);
    }

    #[test]
    fn unknown_key_is_error() {
        let mut cfg = Config::default();
        assert!(cfg.set("system.doesnotexist", "1").is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.system.k = 0;
        assert!(cfg.validate().is_err());
        cfg.system.k = 500; // > N
        assert!(cfg.validate().is_err());
        let mut cfg2 = Config::for_dataset("cifar").unwrap();
        cfg2.system.p_min_w = -1.0;
        assert!(cfg2.validate().is_err());
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("lroa").unwrap(), Policy::Lroa);
        assert_eq!(Policy::parse("Uni-D").unwrap(), Policy::UniformDynamic);
        assert_eq!(Policy::parse("uni-s").unwrap(), Policy::UniformStatic);
        assert_eq!(Policy::parse("divfl").unwrap(), Policy::DivFl);
        assert_eq!(Policy::parse("greedy-channel").unwrap(), Policy::GreedyChannel);
        assert_eq!(Policy::parse("round-robin").unwrap(), Policy::RoundRobin);
        assert_eq!(Policy::parse("rr").unwrap(), Policy::RoundRobin);
        assert_eq!(Policy::parse("p2c").unwrap(), Policy::PowerOfTwoChoices);
        assert_eq!(
            Policy::parse("power-of-two-choices").unwrap(),
            Policy::PowerOfTwoChoices
        );
        assert_eq!(Policy::parse("bandit").unwrap(), Policy::Bandit);
        assert_eq!(Policy::parse("contextual-bandit").unwrap(), Policy::Bandit);
        assert_eq!(Policy::parse("thompson").unwrap(), Policy::Thompson);
        assert_eq!(Policy::parse("ts").unwrap(), Policy::Thompson);
        assert_eq!(Policy::parse("linucb").unwrap(), Policy::LinUcb);
        assert_eq!(Policy::parse("lin-ucb").unwrap(), Policy::LinUcb);
        assert_eq!(Policy::parse("conv-aware").unwrap(), Policy::ConvAware);
        assert_eq!(Policy::parse("conv").unwrap(), Policy::ConvAware);
        assert_eq!(Policy::parse("oracle").unwrap(), Policy::Oracle);
        assert_eq!(Policy::parse("oracle-e").unwrap(), Policy::OracleEnergy);
        assert_eq!(Policy::parse("oracle-energy").unwrap(), Policy::OracleEnergy);
        assert!(Policy::parse("nope").is_err());
    }

    #[test]
    fn bandit_knobs_override_validate_and_stay_inert_off_policy() {
        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.apply_cli(&["--bandit.ucb_c=1.5", "--bandit.temp=0.1", "--bandit.eps=0.2"])
            .unwrap();
        assert_eq!(cfg.bandit.ucb_c, 1.5);
        assert_eq!(cfg.bandit.temp, 0.1);
        assert_eq!(cfg.bandit.eps, 0.2);
        assert!(cfg.validate().is_ok());
        cfg.bandit.temp = 0.0;
        assert!(cfg.validate().is_err());
        cfg.bandit.temp = 0.25;
        cfg.bandit.eps = 1.0;
        assert!(cfg.validate().is_err());
        cfg.bandit.eps = 0.05;
        cfg.bandit.gain_ema = 0.0;
        assert!(cfg.validate().is_err());

        // Inert unless the bandit policy is selected: same hash, so a
        // resumed grid never re-runs non-bandit cells over a knob edit.
        let a = Config::for_dataset("cifar").unwrap();
        let mut b = a.clone();
        b.bandit.ucb_c = 9.0;
        assert_eq!(a.hash_hex(), b.hash_hex());
        let mut c = a.clone();
        c.train.policy = Policy::Bandit;
        let mut d = c.clone();
        d.bandit.ucb_c = 9.0;
        assert_ne!(c.hash_hex(), d.hash_hex());
    }

    #[test]
    fn thompson_and_linucb_knobs_override_validate_and_stay_inert_off_policy() {
        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.apply_cli(&[
            "--thompson.prior_std=0.7",
            "--thompson.temp=0.1",
            "--linucb.alpha=1.5",
            "--linucb.ridge=2.0",
        ])
        .unwrap();
        assert_eq!(cfg.thompson.prior_std, 0.7);
        assert_eq!(cfg.thompson.temp, 0.1);
        assert_eq!(cfg.linucb.alpha, 1.5);
        assert_eq!(cfg.linucb.ridge, 2.0);
        assert!(cfg.validate().is_ok());
        cfg.thompson.temp = 0.0;
        assert!(cfg.validate().is_err());
        cfg.thompson.temp = 0.25;
        cfg.linucb.ridge = 0.0;
        assert!(cfg.validate().is_err());

        // Inert unless the matching policy is selected (resume-neutral).
        let a = Config::for_dataset("cifar").unwrap();
        let mut b = a.clone();
        b.thompson.prior_std = 9.0;
        b.linucb.alpha = 9.0;
        assert_eq!(a.hash_hex(), b.hash_hex());
        for (policy, knob) in [
            (Policy::Thompson, "thompson.prior_std"),
            (Policy::LinUcb, "linucb.alpha"),
        ] {
            let mut c = a.clone();
            c.train.policy = policy;
            let mut d = c.clone();
            d.set(knob, "9.0").unwrap();
            assert_ne!(c.hash_hex(), d.hash_hex(), "{knob} must be live");
        }
        // Conv-aware shares the bandit softmax knobs, so they are live
        // under it too.
        let mut c = a.clone();
        c.train.policy = Policy::ConvAware;
        let mut d = c.clone();
        d.bandit.temp = 0.9;
        assert_ne!(c.hash_hex(), d.hash_hex());
    }

    #[test]
    fn queue_gate_and_cost_weight_hash_only_where_live() {
        let a = Config::for_dataset("cifar").unwrap();
        // Static env: gating can never bite, so the knob is resume-neutral.
        let mut b = a.clone();
        b.control.queue_gate_offline = false;
        assert_eq!(a.hash_hex(), b.hash_hex());
        // Availability env: candidacy varies, the knob is live.
        let mut c = a.clone();
        c.env.kind = EnvKind::Availability;
        let mut d = c.clone();
        d.control.queue_gate_offline = false;
        assert_ne!(c.hash_hex(), d.hash_hex());

        // cost_weight is live for the solver-backed policies only.
        assert_eq!(a.train.policy, Policy::Lroa);
        let mut e = a.clone();
        e.control.cost_weight = 0.3;
        assert_ne!(a.hash_hex(), e.hash_hex());
        let mut f = a.clone();
        f.train.policy = Policy::GreedyChannel;
        let mut g = f.clone();
        g.control.cost_weight = 0.3;
        assert_eq!(f.hash_hex(), g.hash_hex());
        // Negative or non-finite weights are rejected.
        let mut h = a.clone();
        h.control.cost_weight = -0.1;
        assert!(h.validate().is_err());
    }

    #[test]
    fn budget_spread_overrides_and_validates() {
        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.apply_cli(&["--system.budget_spread=0.4"]).unwrap();
        assert_eq!(cfg.system.budget_spread, 0.4);
        assert!(cfg.validate().is_ok());
        cfg.system.budget_spread = 1.0;
        assert!(cfg.validate().is_err());
        // Always live: it shapes the fleet itself.
        let a = Config::for_dataset("cifar").unwrap();
        let mut b = a.clone();
        b.system.budget_spread = 0.4;
        assert_ne!(a.hash_hex(), b.hash_hex());
    }

    #[test]
    fn env_kind_parse_and_default() {
        assert_eq!(EnvKind::parse("static").unwrap(), EnvKind::Static);
        assert_eq!(EnvKind::parse("ge").unwrap(), EnvKind::GilbertElliott);
        assert_eq!(EnvKind::parse("gilbert-elliott").unwrap(), EnvKind::GilbertElliott);
        assert_eq!(EnvKind::parse("avail").unwrap(), EnvKind::Availability);
        assert_eq!(EnvKind::parse("drift").unwrap(), EnvKind::Drift);
        assert_eq!(EnvKind::parse("trace").unwrap(), EnvKind::Trace);
        assert_eq!(EnvKind::parse("adv").unwrap(), EnvKind::Adversarial);
        assert_eq!(EnvKind::parse("adversarial").unwrap(), EnvKind::Adversarial);
        assert!(EnvKind::parse("nope").is_err());
        // The paper's setting is the default everywhere.
        assert_eq!(Config::for_dataset("cifar").unwrap().env.kind, EnvKind::Static);
        assert_eq!(EnvConfig::default().kind, EnvKind::Static);
    }

    #[test]
    fn env_overrides_and_validation() {
        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.apply_cli(&["--env.kind=ge", "--env.ge_p_bad=0.3", "--env.drift_lo=0.8"])
            .unwrap();
        assert_eq!(cfg.env.kind, EnvKind::GilbertElliott);
        assert_eq!(cfg.env.ge_p_bad, 0.3);
        assert_eq!(cfg.env.drift_clip.0, 0.8);
        assert!(cfg.validate().is_ok());

        cfg.env.avail_p_drop = 1.5;
        assert!(cfg.validate().is_err());
        cfg.env.avail_p_drop = 0.05;
        cfg.env.drift_clip = (0.5, 0.9); // band must straddle 1
        assert!(cfg.validate().is_err());
        cfg.env.drift_clip = (0.5, 2.0);
        assert!(cfg.validate().is_ok());
        // A bad-state mean below the clip floor would stall the sampler.
        cfg.env.ge_bad_scale = 1e-3;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn env_parse_list() {
        assert_eq!(
            EnvKind::parse_list("static,ge").unwrap(),
            vec![EnvKind::Static, EnvKind::GilbertElliott]
        );
        // `all` expands to the synthetic set: trace needs a log file, so
        // it is never implied.
        assert_eq!(
            EnvKind::parse_list("all").unwrap(),
            EnvKind::SYNTHETIC.to_vec()
        );
        assert!(!EnvKind::SYNTHETIC.contains(&EnvKind::Trace));
        assert!(EnvKind::parse_list("static,nope").is_err());
    }

    #[test]
    fn trace_env_requires_a_path_and_adv_knobs_validate() {
        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.env.kind = EnvKind::Trace;
        assert!(cfg.validate().is_err(), "trace without a path must fail");
        cfg.env.trace_path = "somewhere.csv".into();
        assert!(cfg.validate().is_ok());

        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.env.kind = EnvKind::Adversarial;
        assert!(cfg.validate().is_ok());
        cfg.env.adv_degrade = 0.0;
        assert!(cfg.validate().is_err());
        cfg.env.adv_degrade = 1.5;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn trace_and_adv_knobs_are_inert_unless_selected() {
        let a = Config::for_dataset("cifar").unwrap();
        let mut b = a.clone();
        b.env.trace_path = "elsewhere.csv".into(); // inert: kind is static
        b.env.adv_degrade = 0.5;
        assert_eq!(a.hash_hex(), b.hash_hex());
        let mut c = a.clone();
        c.env.kind = EnvKind::Adversarial;
        let mut d = c.clone();
        d.env.adv_degrade = 0.5; // live once adv is selected
        assert_ne!(c.hash_hex(), d.hash_hex());
    }

    #[test]
    fn config_hash_tracks_every_knob() {
        let a = Config::for_dataset("cifar").unwrap();
        let mut b = a.clone();
        assert_eq!(a.hash_hex(), b.hash_hex());
        b.env.kind = EnvKind::Drift;
        assert_ne!(a.hash_hex(), b.hash_hex());
        let mut c = a.clone();
        c.train.seed = 99;
        assert_ne!(a.hash_hex(), c.hash_hex());
        // Sub-display-precision changes still change the hash (the hash
        // is over the round-trip Debug repr, not the rounded dump).
        let mut d = a.clone();
        d.system.alpha *= 1.0 + 1e-12;
        assert_ne!(a.hash_hex(), d.hash_hex());
        // Pure locations, thread width, and inert env knobs do not.
        let mut e = a.clone();
        e.out_dir = "elsewhere".into();
        e.artifacts_dir = "elsewhere".into();
        e.train.train_threads = 8; // bitwise-irrelevant by the par contract
        e.env.ge_p_good = 0.9; // inert: kind is static
        assert_eq!(a.hash_hex(), e.hash_hex());
        let mut f = a.clone();
        f.env.kind = EnvKind::GilbertElliott;
        let mut g = f.clone();
        g.env.ge_p_good = 0.9; // live once GE is selected
        assert_ne!(f.hash_hex(), g.hash_hex());
        // warm_start is live under the (default) LROA policy, inert for
        // policies that never run the iterative Algorithm-2 solve.
        assert_eq!(a.train.policy, Policy::Lroa);
        let mut w = a.clone();
        w.control.warm_start = false;
        assert_ne!(a.hash_hex(), w.hash_hex());
        let mut ws = a.clone();
        ws.train.policy = Policy::UniformStatic;
        let mut wt = ws.clone();
        wt.control.warm_start = false; // inert: Uni-S never iterates
        assert_eq!(ws.hash_hex(), wt.hash_hex());
    }

    #[test]
    fn compose_kind_and_spec_parse() {
        assert_eq!(EnvKind::parse("compose").unwrap(), EnvKind::Composite);
        assert_eq!(EnvKind::parse("composite").unwrap(), EnvKind::Composite);
        assert_eq!(EnvKind::Composite.name(), "compose");
        // Composite joins the full registry set but not the `all`
        // shorthand: a composite needs a child spec to mean anything.
        assert!(EnvKind::ALL.contains(&EnvKind::Composite));
        assert!(!EnvKind::SYNTHETIC.contains(&EnvKind::Composite));

        let kids = parse_compose_spec("avail+ge+drift").unwrap();
        assert_eq!(
            kids,
            vec![
                ComposeChild::Availability,
                ComposeChild::GilbertElliott,
                ComposeChild::Drift
            ]
        );
        // Aliases mirror EnvKind::parse, order is preserved.
        assert_eq!(
            parse_compose_spec("gilbert-elliott+adversarial").unwrap(),
            vec![ComposeChild::GilbertElliott, ComposeChild::Adversarial]
        );
        // Presets expand to documented child lists.
        assert_eq!(
            parse_compose_spec("diurnal").unwrap(),
            vec![ComposeChild::Diurnal, ComposeChild::GilbertElliott]
        );
        assert_eq!(
            parse_compose_spec("flashcrowd").unwrap(),
            vec![ComposeChild::FlashCrowd, ComposeChild::GilbertElliott]
        );
        assert_eq!(
            parse_compose_spec("outage").unwrap(),
            vec![
                ComposeChild::Outage,
                ComposeChild::GilbertElliott,
                ComposeChild::Drift
            ]
        );
        for (name, spec) in COMPOSE_PRESETS {
            assert_eq!(
                parse_compose_spec(name).unwrap(),
                parse_compose_spec(spec).unwrap()
            );
        }
        // Errors: empty, duplicate child, unknown mechanism.
        assert!(parse_compose_spec("").is_err());
        assert!(parse_compose_spec("ge+ge").is_err());
        assert!(parse_compose_spec("avail+nope").is_err());
    }

    #[test]
    fn compose_and_shadow_knobs_validate_and_hash_only_where_live() {
        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.apply_cli(&[
            "--env.kind=compose",
            "--env.compose=outage",
            "--env.shadow_rho=0.9",
            "--env.shadow_std=0.4",
        ])
        .unwrap();
        assert_eq!(cfg.env.kind, EnvKind::Composite);
        assert_eq!(cfg.env.compose, "outage");
        assert_eq!(cfg.env.shadow_rho, 0.9);
        assert_eq!(cfg.env.shadow_std, 0.4);
        assert!(cfg.validate().is_ok());
        cfg.env.shadow_rho = 1.5;
        assert!(cfg.validate().is_err());
        cfg.env.shadow_rho = 0.9;
        cfg.env.shadow_std = -0.1;
        assert!(cfg.validate().is_err());
        cfg.env.shadow_std = 0.4;
        // A composite spec that fails to parse is caught at validate time.
        cfg.env.compose = "ge+nope".into();
        assert!(cfg.validate().is_err());
        // Child prerequisites apply through the composite: a trace child
        // needs a path, a ge child needs the floor headroom.
        cfg.env.compose = "trace+ge".into();
        cfg.env.trace_path = String::new();
        assert!(cfg.validate().is_err());
        cfg.env.trace_path = "somewhere.csv".into();
        assert!(cfg.validate().is_ok());

        // Inert unless the composite kind is selected (resume-neutral).
        let a = Config::for_dataset("cifar").unwrap();
        let mut b = a.clone();
        b.env.compose = "outage".into();
        b.env.shadow_rho = 0.9;
        b.env.shadow_std = 0.4;
        assert_eq!(a.hash_hex(), b.hash_hex());
        // Live once composite is selected: spec and shadow knobs.
        let mut c = a.clone();
        c.env.kind = EnvKind::Composite;
        let mut d = c.clone();
        d.env.compose = "diurnal".into();
        assert_ne!(c.hash_hex(), d.hash_hex());
        let mut e = c.clone();
        e.env.shadow_std = 0.4;
        assert_ne!(c.hash_hex(), e.hash_hex());
        // The correlation knob is resume-neutral while shadowing is off
        // (std = 0 is bitwise inert) and live once the field is on.
        let mut e2 = c.clone();
        e2.env.shadow_rho = 0.9;
        assert_eq!(c.hash_hex(), e2.hash_hex());
        let mut e3 = e.clone();
        e3.env.shadow_rho = 0.9;
        assert_ne!(e.hash_hex(), e3.hash_hex());
        // Child knobs are live exactly for the children in the spec:
        // default spec avail+ge+drift has no adv child, so adv_degrade
        // stays inert while ge/avail/drift knobs bite.
        let mut f = c.clone();
        f.env.adv_degrade = 0.5;
        assert_eq!(c.hash_hex(), f.hash_hex());
        let mut g = c.clone();
        g.env.ge_p_good = 0.9;
        assert_ne!(c.hash_hex(), g.hash_hex());
        let mut h = c.clone();
        h.env.avail_p_drop = 0.2;
        assert_ne!(c.hash_hex(), h.hash_hex());
        // The offline-queue gate is live when any child shapes
        // availability (default spec has avail).
        let mut q = c.clone();
        q.control.queue_gate_offline = false;
        assert_ne!(c.hash_hex(), q.hash_hex());
        // ...and inert for a pure-channel composite.
        let mut r = c.clone();
        r.env.compose = "ge+drift".into();
        let mut s = r.clone();
        s.control.queue_gate_offline = false;
        assert_eq!(r.hash_hex(), s.hash_hex());
    }
}
