//! Theorem 2: closed-form optimal CPU frequency (sub-problem P2.1.1).
//!
//! Per device the P2.1.1 objective is
//! `Ω₁/f + Ω₂ f²` with `Ω₁ = V E q c D` (latency price) and
//! `Ω₂ = ½ Q s E α c D` (energy price, `s = 1-(1-q)^K`), minimized at
//! `f' = (Ω₁ / 2Ω₂)^{1/3} = (V q / (Q s α))^{1/3}`, clipped to
//! `[f_min, f_max]`.

use crate::system::{selection_probability, Device, FleetSoA};

/// The unclipped stationary point `(V q / (Q s α))^{1/3}`; `+inf` when the
/// energy price `Q s` vanishes (empty queue ⇒ run flat out).
#[inline]
pub fn stationary_freq(v: f64, q_n: f64, queue: f64, k: usize, alpha: f64) -> f64 {
    let sel = selection_probability(q_n, k);
    let denom = queue * sel * alpha;
    if denom <= 0.0 {
        return f64::INFINITY;
    }
    (v * q_n / denom).cbrt()
}

/// Theorem 2 solution for one device.
#[inline]
pub fn optimal_freq(dev: &Device, v: f64, q_n: f64, queue: f64, k: usize) -> f64 {
    stationary_freq(v, q_n, queue, k, dev.alpha).clamp(dev.f_min_hz, dev.f_max_hz)
}

/// Theorem 2 for the whole fleet.
pub fn solve_freqs(devices: &[Device], v: f64, q: &[f64], queues: &[f64], k: usize, out: &mut Vec<f64>) {
    out.clear();
    out.extend(
        devices
            .iter()
            .zip(q.iter().zip(queues))
            .map(|(dev, (&qn, &queue))| optimal_freq(dev, v, qn, queue, k)),
    );
}

/// Theorem 2 over the SoA fleet view — the solver hot-loop variant.
/// Same per-device arithmetic as [`solve_freqs`] (pinned bitwise by
/// `soa_solve_matches_aos`), but reads the contiguous `alpha`/bounds
/// slices instead of striding over `Device` structs.
pub fn solve_freqs_soa(
    soa: &FleetSoA,
    v: f64,
    q: &[f64],
    queues: &[f64],
    k: usize,
    out: &mut Vec<f64>,
) {
    let n = soa.len();
    assert!(q.len() == n && queues.len() == n);
    out.clear();
    for i in 0..n {
        out.push(
            stationary_freq(v, q[i], queues[i], k, soa.alpha[i])
                .clamp(soa.f_min_hz[i], soa.f_max_hz[i]),
        );
    }
}

/// The per-device P2.1.1 objective (used by tests and the alternating
/// loop's convergence diagnostics).
pub fn p211_objective(
    dev: &Device,
    local_epochs: usize,
    v: f64,
    q_n: f64,
    queue: f64,
    k: usize,
    f_hz: f64,
) -> f64 {
    let ecd = local_epochs as f64 * dev.cycles_per_sample * dev.data_size as f64;
    let sel = selection_probability(q_n, k);
    queue * sel * dev.alpha * ecd * f_hz * f_hz / 2.0 + v * q_n * ecd / f_hz
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> Device {
        Device {
            id: 0,
            data_size: 200,
            cycles_per_sample: 3.0e9,
            alpha: 2e-28,
            f_min_hz: 1.0e9,
            f_max_hz: 2.0e9,
            p_min_w: 0.001,
            p_max_w: 0.1,
            energy_budget_j: 15.0,
        }
    }

    #[test]
    fn matches_formula() {
        let d = dev();
        let (v, q, queue, k) = (1e5, 0.01, 3.0, 2);
        let sel = 1.0 - (1.0 - 0.01f64).powi(2);
        let expect = (v * q / (queue * sel * d.alpha)).cbrt();
        let f = stationary_freq(v, q, queue, k, d.alpha);
        assert!((f - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn empty_queue_runs_flat_out() {
        let d = dev();
        assert_eq!(optimal_freq(&d, 1e5, 0.1, 0.0, 2), d.f_max_hz);
    }

    #[test]
    fn stationary_point_minimizes_objective_numerically() {
        let d = dev();
        let (v, q, k, e) = (2.0e4, 0.05, 2, 2);
        // Pick a queue level that puts the stationary point inside the box.
        let mut queue = 1.0;
        let mut fstar = optimal_freq(&d, v, q, queue, k);
        // Scan queue until interior.
        for _ in 0..60 {
            if fstar > d.f_min_hz * 1.01 && fstar < d.f_max_hz * 0.99 {
                break;
            }
            queue *= if fstar >= d.f_max_hz * 0.99 { 2.0 } else { 0.5 };
            fstar = optimal_freq(&d, v, q, queue, k);
        }
        assert!(
            fstar > d.f_min_hz * 1.01 && fstar < d.f_max_hz * 0.99,
            "could not find interior point, fstar={fstar}"
        );
        let obj_star = p211_objective(&d, e, v, q, queue, k, fstar);
        // Grid scan: no frequency beats the closed form.
        let mut best_grid = f64::INFINITY;
        for i in 0..=2000 {
            let f = d.f_min_hz + (d.f_max_hz - d.f_min_hz) * i as f64 / 2000.0;
            best_grid = best_grid.min(p211_objective(&d, e, v, q, queue, k, f));
        }
        assert!(obj_star <= best_grid + best_grid.abs() * 1e-6);
    }

    #[test]
    fn boundary_projection() {
        let d = dev();
        // Huge queue price -> clamp at f_min.
        assert_eq!(optimal_freq(&d, 1.0, 0.01, 1e12, 2), d.f_min_hz);
        // Tiny queue price -> clamp at f_max.
        assert_eq!(optimal_freq(&d, 1e12, 0.5, 1e-12, 2), d.f_max_hz);
    }

    #[test]
    fn monotonicity_in_prices() {
        let d = dev();
        // More queue pressure -> lower frequency (save energy).
        let f_lo_q = optimal_freq(&d, 1e5, 0.05, 1.0, 2);
        let f_hi_q = optimal_freq(&d, 1e5, 0.05, 100.0, 2);
        assert!(f_hi_q <= f_lo_q);
        // Larger V (latency matters more) -> higher frequency.
        let f_lo_v = optimal_freq(&d, 1e3, 0.05, 10.0, 2);
        let f_hi_v = optimal_freq(&d, 1e6, 0.05, 10.0, 2);
        assert!(f_hi_v >= f_lo_v);
    }

    #[test]
    fn fleet_solve_matches_per_device() {
        let devs: Vec<Device> = (0..5).map(|id| Device { id, ..dev() }).collect();
        let q = [0.1, 0.2, 0.3, 0.2, 0.2];
        let queues = [0.0, 1.0, 5.0, 10.0, 0.5];
        let mut out = Vec::new();
        solve_freqs(&devs, 1e5, &q, &queues, 2, &mut out);
        for i in 0..5 {
            assert_eq!(out[i], optimal_freq(&devs[i], 1e5, q[i], queues[i], 2));
        }
    }

    #[test]
    fn soa_solve_matches_aos() {
        let devs: Vec<Device> = (0..5)
            .map(|id| Device {
                id,
                alpha: 2e-28 * (1.0 + id as f64 * 0.2),
                ..dev()
            })
            .collect();
        let weights = [0.2; 5];
        let q = [0.1, 0.2, 0.3, 0.2, 0.2];
        let queues = [0.0, 1.0, 5.0, 10.0, 0.5];
        let mut soa = FleetSoA::new();
        soa.fill(&devs, &weights, 2, 1e5, 1.0);
        let (mut aos, mut via_soa) = (Vec::new(), Vec::new());
        solve_freqs(&devs, 1e5, &q, &queues, 2, &mut aos);
        solve_freqs_soa(&soa, 1e5, &q, &queues, 2, &mut via_soa);
        assert_eq!(aos, via_soa, "Theorem 2 SoA port must be bitwise identical");
    }
}
