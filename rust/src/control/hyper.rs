//! λ₀ / V₀ estimation — the hyper-parameter rule of §VII-B.1.
//!
//! The paper anchors the two LROA knobs at data-derived scales:
//!
//! * `λ₀ = T₀ / F₀` where `T₀` is the estimated per-round time at midpoint
//!   controls and `F₀` the sampling-error surrogate `Σ w_n²/q_n` at
//!   `q = w` (which is exactly `Σ w_n = 1`, kept in general form here);
//! * `V₀ = a₀² / (T₀ + λ F₀)` where `a₀` estimates the per-round energy
//!   residual of eq. (20) at midpoint controls (and `Q₀ = a₀`).
//!
//! Runtime then scales them: `λ = µ λ₀`, `V = ν V₀`.

use crate::config::SystemConfig;
use crate::system::{selection_probability, Device, RoundCosts};

/// Estimated per-round quantities at midpoint controls and mean channel.
#[derive(Clone, Debug)]
pub struct HyperEstimate {
    pub t0: f64,
    pub f0: f64,
    pub a0: f64,
    pub lambda0: f64,
}

impl HyperEstimate {
    /// `V₀` for a given final λ (= µ·λ₀).
    pub fn v0(&self, lambda: f64) -> f64 {
        self.a0 * self.a0 / (self.t0 + lambda * self.f0)
    }
}

/// Compute the §VII-B.1 estimates for a fleet.
pub fn estimate(cfg: &SystemConfig, devices: &[Device], weights: &[f64], model_bits: f64) -> HyperEstimate {
    let n = devices.len();
    let f_mid: Vec<f64> = devices.iter().map(|d| 0.5 * (d.f_min_hz + d.f_max_hz)).collect();
    let p_mid: Vec<f64> = devices.iter().map(|d| 0.5 * (d.p_min_w + d.p_max_w)).collect();
    let h_mean = vec![cfg.channel_mean; n];

    let costs = RoundCosts::evaluate(cfg, devices, model_bits, &h_mean, &f_mid, &p_mid);

    // T0: mean per-device round time at midpoint controls.
    let t0 = costs.time_s.iter().sum::<f64>() / n as f64;

    // F0: Σ w²/q at q = w  (= Σ w = 1 exactly; kept generic).
    let f0: f64 = weights.iter().map(|&w| if w > 0.0 { w } else { 0.0 }).sum();

    // a0: mean |expected energy residual| at uniform sampling (eq. 20).
    let sel = selection_probability(1.0 / n as f64, cfg.k);
    let a0 = devices
        .iter()
        .enumerate()
        .map(|(i, d)| (sel * costs.energy_j[i] - d.energy_budget_j).abs())
        .sum::<f64>()
        / n as f64;

    HyperEstimate {
        t0,
        f0,
        a0,
        lambda0: t0 / f0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::rng::Rng;
    use crate::system::Fleet;

    #[test]
    fn estimates_are_positive_and_sane() {
        let cfg = SystemConfig::default();
        let mut rng = Rng::new(3);
        let fleet = Fleet::generate(&cfg, (50, 400), &mut rng);
        let est = estimate(&cfg, &fleet.devices, fleet.weights(), 32.0 * 140_000.0);
        assert!(est.t0 > 0.0, "t0 {}", est.t0);
        assert!((est.f0 - 1.0).abs() < 1e-12, "f0 {}", est.f0);
        assert!(est.a0 > 0.0);
        assert!((est.lambda0 - est.t0).abs() < 1e-9); // λ0 = T0 when F0 = 1
        let v0 = est.v0(est.lambda0);
        assert!(v0 > 0.0 && v0.is_finite());
    }

    #[test]
    fn lambda0_tracks_round_time_scale() {
        // Slower CPUs (larger c_n) -> larger T0 -> larger λ0.
        let fast = SystemConfig::default();
        let slow = SystemConfig {
            cycles_per_sample: 3.0 * fast.cycles_per_sample,
            ..fast.clone()
        };
        let mut rng = Rng::new(4);
        let fleet_fast = Fleet::generate(&fast, (200, 200), &mut rng);
        let mut rng = Rng::new(4);
        let fleet_slow = Fleet::generate(&slow, (200, 200), &mut rng);
        let m = 32.0 * 140_000.0;
        let est_fast = estimate(&fast, &fleet_fast.devices, fleet_fast.weights(), m);
        let est_slow = estimate(&slow, &fleet_slow.devices, fleet_slow.weights(), m);
        assert!(est_slow.lambda0 > est_fast.lambda0);
    }

    #[test]
    fn v0_decreases_with_lambda() {
        let cfg = SystemConfig::default();
        let mut rng = Rng::new(5);
        let fleet = Fleet::generate(&cfg, (100, 300), &mut rng);
        let est = estimate(&cfg, &fleet.devices, fleet.weights(), 3.2e6);
        assert!(est.v0(1.0) > est.v0(100.0));
    }
}
