//! Algorithm 2: the LROA per-round solver (alternating minimization).
//!
//! Outer loop alternates the closed-form `f` block (Theorem 2), the
//! root-found `p` block (Theorem 3) and the SUM `q` block (P2.2) until the
//! joint iterate stabilizes within `ε₀`.  Initialization follows the
//! paper: `f⁰ = (f_min+f_max)/2`, `p⁰ = (p_min+p_max)/2`, `q⁰ = 1/N`.

use std::time::Instant;

use super::{freq, power, sum};
use crate::config::{ControlConfig, SystemConfig};
use crate::system::{selection_probability, Device, RoundCosts};

/// Per-round control decisions for the whole fleet.
#[derive(Clone, Debug)]
pub struct Controls {
    /// CPU frequency `f_n^t` [Hz].
    pub f_hz: Vec<f64>,
    /// Transmit power `p_n^t` [W].
    pub p_w: Vec<f64>,
    /// Sampling probabilities `q_n^t` (sum to 1).
    pub q: Vec<f64>,
}

impl Controls {
    /// Midpoint/uniform initialization (Algorithm 2 line 1).
    pub fn midpoint(devices: &[Device]) -> Controls {
        let n = devices.len();
        Controls {
            f_hz: devices.iter().map(|d| 0.5 * (d.f_min_hz + d.f_max_hz)).collect(),
            p_w: devices.iter().map(|d| 0.5 * (d.p_min_w + d.p_max_w)).collect(),
            q: vec![1.0 / n as f64; n],
        }
    }
}

/// Diagnostics from one [`LroaSolver::solve_round`] call.
#[derive(Clone, Debug, Default)]
pub struct SolverStats {
    pub outer_iters: usize,
    pub inner_iters: usize,
    /// Final P2 objective (drift-plus-penalty surrogate value).
    pub objective: f64,
    pub solve_time_s: f64,
}

/// The online controller: holds the static problem data and solves P2
/// each round given the fresh channel draw and queue backlogs.
pub struct LroaSolver {
    pub sys: SystemConfig,
    pub ctl: ControlConfig,
    /// λ (already scaled: µ·λ₀ or the explicit override).
    pub lambda: f64,
    /// V (already scaled: ν·V₀ or the explicit override).
    pub v: f64,
    /// Model size in bits.
    pub model_bits: f64,
    // Reusable scratch (hot path: one solve per round).
    scratch_a2: Vec<f64>,
    scratch_a3: Vec<f64>,
    scratch_e: Vec<f64>,
}

impl LroaSolver {
    pub fn new(sys: SystemConfig, ctl: ControlConfig, lambda: f64, v: f64, model_bits: f64) -> Self {
        Self {
            sys,
            ctl,
            lambda,
            v,
            model_bits,
            scratch_a2: Vec::new(),
            scratch_a3: Vec::new(),
            scratch_e: Vec::new(),
        }
    }

    /// Algorithm 2: solve P2 for round `t`.
    ///
    /// * `devices` / `weights` — the fleet and its data weights `w_n`;
    /// * `h` — this round's channel gains;
    /// * `queues` — virtual queue backlogs `Q_n^t`.
    pub fn solve_round(
        &mut self,
        devices: &[Device],
        weights: &[f64],
        h: &[f64],
        queues: &[f64],
    ) -> (Controls, SolverStats) {
        let t0 = Instant::now();
        let n = devices.len();
        let k = self.sys.k;
        let mut ctrl = Controls::midpoint(devices);
        let mut stats = SolverStats::default();

        // A3 never changes across the outer loop.
        self.scratch_a3.clear();
        self.scratch_a3
            .extend(weights.iter().map(|w| self.v * self.lambda * w * w));

        let mut prev_f = ctrl.f_hz.clone();
        let mut prev_p = ctrl.p_w.clone();
        let mut prev_q = ctrl.q.clone();

        for _ in 0..self.ctl.max_outer_iters {
            stats.outer_iters += 1;

            // f and p blocks (Theorems 2-3) under fixed q.
            freq::solve_freqs(devices, self.v, &ctrl.q, queues, k, &mut ctrl.f_hz);
            power::solve_powers(
                devices,
                self.v,
                &ctrl.q,
                h,
                queues,
                k,
                self.sys.noise_w,
                &mut ctrl.p_w,
            );

            // Refresh T_n and E_n under the new (f, p).
            let costs = RoundCosts::evaluate(
                &self.sys,
                devices,
                self.model_bits,
                h,
                &ctrl.f_hz,
                &ctrl.p_w,
            );

            // q block: SUM on P2.2 with A2 = V·T_n, e = Q_n·E_n.
            self.scratch_a2.clear();
            self.scratch_a2
                .extend(costs.time_s.iter().map(|t| self.v * t));
            self.scratch_e.clear();
            self.scratch_e
                .extend(queues.iter().zip(&costs.energy_j).map(|(qu, e)| qu * e));

            let res = sum::solve(
                &ctrl.q,
                &self.scratch_a2,
                &self.scratch_a3,
                &self.scratch_e,
                k,
                self.ctl.q_min,
                self.ctl.eps_inner,
                self.ctl.max_inner_iters,
            );
            stats.inner_iters += res.iters;
            ctrl.q = res.q;

            // Joint convergence: relative change per block (the blocks
            // live on wildly different scales: Hz, W, probabilities).
            let delta = rel_change(&prev_f, &ctrl.f_hz)
                + rel_change(&prev_p, &ctrl.p_w)
                + rel_change(&prev_q, &ctrl.q);
            prev_f.clone_from(&ctrl.f_hz);
            prev_p.clone_from(&ctrl.p_w);
            prev_q.clone_from(&ctrl.q);
            if delta <= self.ctl.eps_outer {
                break;
            }
        }

        stats.objective = self.p2_objective(devices, weights, h, queues, &ctrl);
        stats.solve_time_s = t0.elapsed().as_secs_f64();
        let _ = n;
        (ctrl, stats)
    }

    /// Uni-D baseline: uniform `q = 1/N`, dynamic `f`/`p`.  With `q`
    /// fixed, the `f` and `p` blocks are exact in a single pass.
    pub fn solve_uniform_dynamic(
        &mut self,
        devices: &[Device],
        h: &[f64],
        queues: &[f64],
    ) -> (Controls, SolverStats) {
        let t0 = Instant::now();
        let k = self.sys.k;
        let mut ctrl = Controls::midpoint(devices);
        freq::solve_freqs(devices, self.v, &ctrl.q, queues, k, &mut ctrl.f_hz);
        power::solve_powers(
            devices,
            self.v,
            &ctrl.q,
            h,
            queues,
            k,
            self.sys.noise_w,
            &mut ctrl.p_w,
        );
        let stats = SolverStats {
            outer_iters: 1,
            inner_iters: 0,
            objective: 0.0,
            solve_time_s: t0.elapsed().as_secs_f64(),
        };
        (ctrl, stats)
    }

    /// The P2 drift-plus-penalty value under given controls (diagnostics).
    pub fn p2_objective(
        &self,
        devices: &[Device],
        weights: &[f64],
        h: &[f64],
        queues: &[f64],
        ctrl: &Controls,
    ) -> f64 {
        let costs =
            RoundCosts::evaluate(&self.sys, devices, self.model_bits, h, &ctrl.f_hz, &ctrl.p_w);
        let mut acc = 0.0;
        for i in 0..devices.len() {
            let sel = selection_probability(ctrl.q[i], self.sys.k);
            acc += self.v
                * (ctrl.q[i] * costs.time_s[i]
                    + self.lambda * weights[i] * weights[i] / ctrl.q[i]);
            acc += queues[i] * (sel * costs.energy_j[i] - devices[i].energy_budget_j);
        }
        acc
    }
}

fn rel_change(prev: &[f64], cur: &[f64]) -> f64 {
    let num: f64 = prev
        .iter()
        .zip(cur)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    let den: f64 = prev.iter().map(|a| a * a).sum::<f64>().sqrt().max(1e-300);
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ControlConfig, SystemConfig};
    use crate::rng::Rng;
    use crate::system::Fleet;

    fn setup(n: usize) -> (SystemConfig, Fleet, Vec<f64>, Vec<f64>) {
        let sys = SystemConfig {
            num_devices: n,
            ..SystemConfig::default()
        };
        let mut rng = Rng::new(11);
        let fleet = Fleet::generate(&sys, (50, 400), &mut rng);
        let h: Vec<f64> = (0..n).map(|_| rng.range(0.01, 0.5)).collect();
        let queues: Vec<f64> = (0..n).map(|_| rng.range(0.0, 20.0)).collect();
        (sys, fleet, h, queues)
    }

    fn solver(sys: &SystemConfig) -> LroaSolver {
        LroaSolver::new(
            sys.clone(),
            ControlConfig::default(),
            10.0,  // lambda
            1e4,   // V
            32.0 * 140_000.0,
        )
    }

    #[test]
    fn controls_feasible() {
        let (sys, fleet, h, queues) = setup(60);
        let mut s = solver(&sys);
        let (ctrl, stats) = s.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        assert!(stats.outer_iters >= 1);
        let sum_q: f64 = ctrl.q.iter().sum();
        assert!((sum_q - 1.0).abs() < 1e-6, "sum q = {sum_q}");
        for (i, d) in fleet.devices.iter().enumerate() {
            assert!(ctrl.f_hz[i] >= d.f_min_hz && ctrl.f_hz[i] <= d.f_max_hz);
            assert!(ctrl.p_w[i] >= d.p_min_w && ctrl.p_w[i] <= d.p_max_w);
            assert!(ctrl.q[i] > 0.0 && ctrl.q[i] <= 1.0);
        }
    }

    #[test]
    fn converges_before_cap() {
        let (sys, fleet, h, queues) = setup(120);
        let mut s = solver(&sys);
        let (_, stats) = s.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        assert!(
            stats.outer_iters < s.ctl.max_outer_iters,
            "hit outer cap: {}",
            stats.outer_iters
        );
    }

    #[test]
    fn beats_midpoint_and_uniform_controls() {
        let (sys, fleet, h, queues) = setup(80);
        let mut s = solver(&sys);
        let (_ctrl, stats) = s.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        let mid = Controls::midpoint(&fleet.devices);
        let mid_obj = s.p2_objective(&fleet.devices, fleet.weights(), &h, &queues, &mid);
        assert!(
            stats.objective <= mid_obj + mid_obj.abs() * 1e-9,
            "solver {} vs midpoint {}",
            stats.objective,
            mid_obj
        );
    }

    #[test]
    fn stragglers_get_lower_sampling_probability() {
        let (sys, mut fleet, mut h, queues) = setup(40);
        // Same data everywhere so only the channel differs.
        for d in fleet.devices.iter_mut() {
            d.data_size = 200;
        }
        let n = fleet.devices.len();
        let sizes = vec![200; n];
        let mut rng = Rng::new(5);
        let fleet = Fleet::from_data_sizes(&sys, &sizes, &mut rng);
        // Device 0: terrible channel. Device 1: great channel.
        h[0] = 0.01;
        h[1] = 0.5;
        let mut s = solver(&sys);
        let (ctrl, _) = s.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        assert!(
            ctrl.q[0] < ctrl.q[1],
            "straggler q {} should be < good-channel q {}",
            ctrl.q[0],
            ctrl.q[1]
        );
    }

    #[test]
    fn empty_queues_run_flat_out() {
        let (sys, fleet, h, _) = setup(20);
        let queues = vec![0.0; 20];
        let mut s = solver(&sys);
        let (ctrl, _) = s.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        for (i, d) in fleet.devices.iter().enumerate() {
            assert_eq!(ctrl.f_hz[i], d.f_max_hz);
            assert_eq!(ctrl.p_w[i], d.p_max_w);
        }
    }

    #[test]
    fn queue_pressure_reduces_energy() {
        let (sys, fleet, h, _) = setup(30);
        let mut s = solver(&sys);
        let (c_free, _) = s.solve_round(&fleet.devices, fleet.weights(), &h, &vec![0.0; 30]);
        let (c_tight, _) = s.solve_round(&fleet.devices, fleet.weights(), &h, &vec![1e4; 30]);
        let e = |c: &Controls| -> f64 {
            let costs = RoundCosts::evaluate(&s.sys, &fleet.devices, s.model_bits, &h, &c.f_hz, &c.p_w);
            costs.energy_j.iter().sum()
        };
        assert!(e(&c_tight) < e(&c_free), "tight {} free {}", e(&c_tight), e(&c_free));
    }

    #[test]
    fn uniform_dynamic_is_uniform() {
        let (sys, fleet, h, queues) = setup(25);
        let mut s = solver(&sys);
        let (ctrl, _) = s.solve_uniform_dynamic(&fleet.devices, &h, &queues);
        for &q in &ctrl.q {
            assert!((q - 1.0 / 25.0).abs() < 1e-12);
        }
    }

    #[test]
    fn deterministic() {
        let (sys, fleet, h, queues) = setup(50);
        let mut s1 = solver(&sys);
        let mut s2 = solver(&sys);
        let (c1, _) = s1.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        let (c2, _) = s2.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        assert_eq!(c1.q, c2.q);
        assert_eq!(c1.f_hz, c2.f_hz);
        assert_eq!(c1.p_w, c2.p_w);
    }
}
