//! Algorithm 2: the LROA per-round solver (alternating minimization).
//!
//! Outer loop alternates the closed-form `f` block (Theorem 2), the
//! root-found `p` block (Theorem 3) and the SUM `q` block (P2.2) until the
//! joint iterate stabilizes within `ε₀`.  Initialization follows the
//! paper: `f⁰ = (f_min+f_max)/2`, `p⁰ = (p_min+p_max)/2`, `q⁰ = 1/N` —
//! unless `[control] warm_start` (default on) lets the solver resume
//! from the previous round's fixed point, which typically converges in
//! 1–2 outer iterations instead of re-deriving the same point from the
//! midpoint every round.
//!
//! The hot path is allocation-free: the fleet is mirrored once per round
//! into a [`FleetSoA`] view and every outer iteration runs the Theorem
//! 2/3 kernels, the cost model and the SUM loop over slices backed by
//! solver-owned scratch.

use std::time::Instant;

use super::{freq, power, sum};
use crate::config::{ControlConfig, SystemConfig};
use crate::system::{round_costs_into, selection_probability, Device, FleetSoA, RoundCosts};

/// Per-round control decisions for the whole fleet.
#[derive(Clone, Debug)]
pub struct Controls {
    /// CPU frequency `f_n^t` [Hz].
    pub f_hz: Vec<f64>,
    /// Transmit power `p_n^t` [W].
    pub p_w: Vec<f64>,
    /// Sampling probabilities `q_n^t` (sum to 1).
    pub q: Vec<f64>,
}

impl Controls {
    /// Midpoint/uniform initialization (Algorithm 2 line 1).
    ///
    /// Panics on an empty candidate set: `1/N` with `N = 0` would
    /// silently seed the solver with NaN probabilities.
    pub fn midpoint(devices: &[Device]) -> Controls {
        assert!(
            !devices.is_empty(),
            "Controls::midpoint: empty candidate set (q = 1/N is undefined for N = 0)"
        );
        let n = devices.len();
        Controls {
            f_hz: devices.iter().map(|d| 0.5 * (d.f_min_hz + d.f_max_hz)).collect(),
            p_w: devices.iter().map(|d| 0.5 * (d.p_min_w + d.p_max_w)).collect(),
            q: vec![1.0 / n as f64; n],
        }
    }
}

/// Diagnostics from one [`LroaSolver::solve_round`] call.
#[derive(Clone, Debug, Default)]
pub struct SolverStats {
    pub outer_iters: usize,
    pub inner_iters: usize,
    /// Final P2 objective (drift-plus-penalty surrogate value).
    pub objective: f64,
    pub solve_time_s: f64,
    /// Whether this round's outer loop started from the previous
    /// round's stored fixed point (vs the paper's cold midpoint).
    pub warm_start_hit: bool,
}

/// The online controller: holds the static problem data and solves P2
/// each round given the fresh channel draw and queue backlogs.
pub struct LroaSolver {
    pub sys: SystemConfig,
    pub ctl: ControlConfig,
    /// λ (already scaled: µ·λ₀ or the explicit override).
    pub lambda: f64,
    /// V (already scaled: ν·V₀ or the explicit override).
    pub v: f64,
    /// Model size in bits.
    pub model_bits: f64,
    // Reusable state (hot path: one solve per round; zero heap
    // allocation per outer iteration once at high-water capacity).
    soa: FleetSoA,
    scratch_time: Vec<f64>,
    scratch_energy: Vec<f64>,
    scratch_a2: Vec<f64>,
    scratch_e: Vec<f64>,
    scratch_price: Vec<f64>,
    prev_f: Vec<f64>,
    prev_p: Vec<f64>,
    prev_q: Vec<f64>,
    sum_scratch: sum::SumScratch,
    // Warm-start store, keyed by *global* device id (via
    // `solve_round_on`) so carried iterates survive candidate-set churn;
    // devices that drop out keep their last fixed point for re-entry.
    warm_f: Vec<f64>,
    warm_p: Vec<f64>,
    warm_q: Vec<f64>,
    warm_valid: Vec<bool>,
    last_ids: Vec<usize>,
    cur_ids: Vec<usize>,
    has_warm: bool,
}

impl LroaSolver {
    pub fn new(sys: SystemConfig, ctl: ControlConfig, lambda: f64, v: f64, model_bits: f64) -> Self {
        Self {
            sys,
            ctl,
            lambda,
            v,
            model_bits,
            soa: FleetSoA::new(),
            scratch_time: Vec::new(),
            scratch_energy: Vec::new(),
            scratch_a2: Vec::new(),
            scratch_e: Vec::new(),
            scratch_price: Vec::new(),
            prev_f: Vec::new(),
            prev_p: Vec::new(),
            prev_q: Vec::new(),
            sum_scratch: sum::SumScratch::default(),
            warm_f: Vec::new(),
            warm_p: Vec::new(),
            warm_q: Vec::new(),
            warm_valid: Vec::new(),
            last_ids: Vec::new(),
            cur_ids: Vec::new(),
            has_warm: false,
        }
    }

    /// Algorithm 2: solve P2 for round `t`.
    ///
    /// * `devices` / `weights` — the fleet and its data weights `w_n`;
    /// * `h` — this round's channel gains;
    /// * `queues` — virtual queue backlogs `Q_n^t`.
    ///
    /// Warm state is keyed by position (`0..N`); a caller whose
    /// candidate set changes between rounds should use
    /// [`Self::solve_round_on`] so the carry follows the devices.
    pub fn solve_round(
        &mut self,
        devices: &[Device],
        weights: &[f64],
        h: &[f64],
        queues: &[f64],
    ) -> (Controls, SolverStats) {
        self.solve_round_impl(None, devices, weights, h, queues)
    }

    /// [`Self::solve_round`] over a compacted candidate set: `ids[j]` is
    /// the global device id behind position `j` of every input slice.
    /// With `warm_start` on, the previous fixed point is gathered through
    /// those ids (newcomers seed at the midpoint, `q` is renormalized
    /// onto the simplex), so availability churn doesn't scramble the
    /// carry.  With identity ids this is exactly `solve_round`.
    pub fn solve_round_on(
        &mut self,
        ids: &[usize],
        devices: &[Device],
        weights: &[f64],
        h: &[f64],
        queues: &[f64],
    ) -> (Controls, SolverStats) {
        self.solve_round_impl(Some(ids), devices, weights, h, queues)
    }

    fn solve_round_impl(
        &mut self,
        ids: Option<&[usize]>,
        devices: &[Device],
        weights: &[f64],
        h: &[f64],
        queues: &[f64],
    ) -> (Controls, SolverStats) {
        let t0 = Instant::now();
        assert!(
            !devices.is_empty(),
            "LroaSolver::solve_round: empty candidate set (no devices to schedule)"
        );
        let n = devices.len();
        assert!(weights.len() == n && h.len() == n && queues.len() == n);
        if let Some(ids) = ids {
            assert_eq!(ids.len(), n, "LroaSolver: ids/devices length mismatch");
        }
        let k = self.sys.k;

        // Mirror the candidate set into the SoA view; `soa.vlw2` is the
        // round-constant A3 = V·λ·w² vector.
        self.soa
            .fill(devices, weights, self.sys.local_epochs, self.v, self.lambda);
        self.cur_ids.clear();
        match ids {
            Some(ids) => self.cur_ids.extend_from_slice(ids),
            None => self.cur_ids.extend(0..n),
        }

        let mut ctrl = self.initial_iterate(devices);
        let mut stats = SolverStats {
            warm_start_hit: self.ctl.warm_start && self.has_warm,
            ..SolverStats::default()
        };

        // Cost-objective mode (`[control] cost_weight`): the effective
        // per-device energy price handed to the Theorem 2/3 kernels and
        // the SUM e-coefficient is `Q_n + V·w_E` — the queues keep
        // enforcing the budgets while the flat `V·w_E` term makes the
        // drift-plus-penalty trade *total* energy against latency.  The
        // scratch is taken out of `self` for the borrow checker; with
        // `cost_weight = 0` the prices alias `queues` directly, so the
        // default is bitwise the plain Algorithm 2.
        let price_store = {
            let mut store = std::mem::take(&mut self.scratch_price);
            if self.ctl.cost_weight != 0.0 {
                let vw = self.v * self.ctl.cost_weight;
                store.clear();
                store.extend(queues.iter().map(|qu| qu + vw));
            }
            store
        };
        let prices: &[f64] = if self.ctl.cost_weight != 0.0 {
            &price_store
        } else {
            queues
        };

        self.prev_f.clear();
        self.prev_f.extend_from_slice(&ctrl.f_hz);
        self.prev_p.clear();
        self.prev_p.extend_from_slice(&ctrl.p_w);
        self.prev_q.clear();
        self.prev_q.extend_from_slice(&ctrl.q);

        for _ in 0..self.ctl.max_outer_iters {
            stats.outer_iters += 1;

            // f and p blocks (Theorems 2-3) under fixed q, at the
            // effective energy prices.
            freq::solve_freqs_soa(&self.soa, self.v, &ctrl.q, prices, k, &mut ctrl.f_hz);
            power::solve_powers_soa(
                &self.soa,
                self.v,
                &ctrl.q,
                h,
                prices,
                k,
                self.sys.noise_w,
                &mut ctrl.p_w,
            );

            // Refresh T_n and E_n under the new (f, p), into scratch.
            round_costs_into(
                &self.sys,
                &self.soa,
                self.model_bits,
                h,
                &ctrl.f_hz,
                &ctrl.p_w,
                &mut self.scratch_time,
                &mut self.scratch_energy,
            );

            // q block: SUM on P2.2 with A2 = V·T_n, e = price_n·E_n.
            let v = self.v;
            self.scratch_a2.clear();
            self.scratch_a2.extend(self.scratch_time.iter().map(|t| v * t));
            self.scratch_e.clear();
            self.scratch_e
                .extend(prices.iter().zip(&self.scratch_energy).map(|(qu, e)| qu * e));

            let (inner, _) = sum::solve_in_place(
                &mut ctrl.q,
                &self.scratch_a2,
                &self.soa.vlw2,
                &self.scratch_e,
                k,
                self.ctl.q_min,
                self.ctl.eps_inner,
                self.ctl.max_inner_iters,
                &mut self.sum_scratch,
            );
            stats.inner_iters += inner;

            // Joint convergence: relative change per block (the blocks
            // live on wildly different scales: Hz, W, probabilities).
            let delta = rel_change(&self.prev_f, &ctrl.f_hz)
                + rel_change(&self.prev_p, &ctrl.p_w)
                + rel_change(&self.prev_q, &ctrl.q);
            self.prev_f.clone_from(&ctrl.f_hz);
            self.prev_p.clone_from(&ctrl.p_w);
            self.prev_q.clone_from(&ctrl.q);
            if delta <= self.ctl.eps_outer {
                break;
            }
        }

        stats.objective = if stats.outer_iters > 0 {
            // `scratch_time`/`scratch_energy` already hold T_n/E_n under
            // the final (f, p) — same accumulation as `p2_objective`
            // without its re-evaluation of the cost model.
            let mut acc = 0.0;
            for i in 0..n {
                let sel = selection_probability(ctrl.q[i], k);
                acc += self.v
                    * (ctrl.q[i] * self.scratch_time[i]
                        + self.lambda * weights[i] * weights[i] / ctrl.q[i]);
                acc += queues[i] * (sel * self.scratch_energy[i] - self.soa.energy_budget_j[i]);
            }
            // The cost-mode energy penalty (gated so the default
            // accumulation stays bitwise untouched).
            if self.ctl.cost_weight != 0.0 {
                let vw = self.v * self.ctl.cost_weight;
                for i in 0..n {
                    acc += vw * selection_probability(ctrl.q[i], k) * self.scratch_energy[i];
                }
            }
            acc
        } else {
            self.p2_objective(devices, weights, h, queues, &ctrl)
        };
        self.scratch_price = price_store;

        if self.ctl.warm_start {
            let max_id = self.cur_ids.iter().copied().max().unwrap_or(0);
            if self.warm_f.len() <= max_id {
                self.warm_f.resize(max_id + 1, 0.0);
                self.warm_p.resize(max_id + 1, 0.0);
                self.warm_q.resize(max_id + 1, 0.0);
                self.warm_valid.resize(max_id + 1, false);
            }
            for (j, &id) in self.cur_ids.iter().enumerate() {
                self.warm_f[id] = ctrl.f_hz[j];
                self.warm_p[id] = ctrl.p_w[j];
                self.warm_q[id] = ctrl.q[j];
                self.warm_valid[id] = true;
            }
            std::mem::swap(&mut self.last_ids, &mut self.cur_ids);
            self.has_warm = true;
        }

        stats.solve_time_s = t0.elapsed().as_secs_f64();
        (ctrl, stats)
    }

    /// The initial iterate for this round's outer loop: the paper's cold
    /// midpoint, or — with `warm_start` on and a stored fixed point —
    /// the previous round's `(f, p, q)` gathered through `cur_ids`.
    fn initial_iterate(&self, devices: &[Device]) -> Controls {
        if !(self.ctl.warm_start && self.has_warm) {
            return Controls::midpoint(devices);
        }
        let m = devices.len();
        let mut ctrl = Controls {
            f_hz: Vec::with_capacity(m),
            p_w: Vec::with_capacity(m),
            q: Vec::with_capacity(m),
        };
        if self.last_ids == self.cur_ids {
            // Unchanged candidate set: resume verbatim from the stored
            // fixed point (already feasible and on the simplex).
            for &id in &self.cur_ids {
                ctrl.f_hz.push(self.warm_f[id]);
                ctrl.p_w.push(self.warm_p[id]);
                ctrl.q.push(self.warm_q[id]);
            }
            return ctrl;
        }
        // Candidate set changed: gather known devices (clamped to the
        // possibly-drifted boxes), seed newcomers at the midpoint, and
        // renormalize q onto the truncated simplex.
        for (j, &id) in self.cur_ids.iter().enumerate() {
            let d = &devices[j];
            if id < self.warm_valid.len() && self.warm_valid[id] {
                ctrl.f_hz.push(self.warm_f[id].clamp(d.f_min_hz, d.f_max_hz));
                ctrl.p_w.push(self.warm_p[id].clamp(d.p_min_w, d.p_max_w));
                ctrl.q.push(self.warm_q[id]);
            } else {
                ctrl.f_hz.push(0.5 * (d.f_min_hz + d.f_max_hz));
                ctrl.p_w.push(0.5 * (d.p_min_w + d.p_max_w));
                ctrl.q.push(1.0 / m as f64);
            }
        }
        let s: f64 = ctrl.q.iter().sum();
        if s.is_finite() && s > 0.0 {
            for q in ctrl.q.iter_mut() {
                *q = (*q / s).clamp(self.ctl.q_min, 1.0);
            }
        } else {
            for q in ctrl.q.iter_mut() {
                *q = 1.0 / m as f64;
            }
        }
        ctrl
    }

    /// Uni-D baseline: uniform `q = 1/N`, dynamic `f`/`p`.  With `q`
    /// fixed, the `f` and `p` blocks are exact in a single pass.
    pub fn solve_uniform_dynamic(
        &mut self,
        devices: &[Device],
        h: &[f64],
        queues: &[f64],
    ) -> (Controls, SolverStats) {
        let t0 = Instant::now();
        let k = self.sys.k;
        let mut ctrl = Controls::midpoint(devices);
        // Same effective energy prices as `solve_round` (cost mode).
        let price_store = {
            let mut store = std::mem::take(&mut self.scratch_price);
            if self.ctl.cost_weight != 0.0 {
                let vw = self.v * self.ctl.cost_weight;
                store.clear();
                store.extend(queues.iter().map(|qu| qu + vw));
            }
            store
        };
        let prices: &[f64] = if self.ctl.cost_weight != 0.0 {
            &price_store
        } else {
            queues
        };
        freq::solve_freqs(devices, self.v, &ctrl.q, prices, k, &mut ctrl.f_hz);
        power::solve_powers(
            devices,
            self.v,
            &ctrl.q,
            h,
            prices,
            k,
            self.sys.noise_w,
            &mut ctrl.p_w,
        );
        self.scratch_price = price_store;
        let stats = SolverStats {
            outer_iters: 1,
            inner_iters: 0,
            objective: 0.0,
            solve_time_s: t0.elapsed().as_secs_f64(),
            warm_start_hit: false,
        };
        (ctrl, stats)
    }

    /// The P2 drift-plus-penalty value under given controls (diagnostics).
    pub fn p2_objective(
        &self,
        devices: &[Device],
        weights: &[f64],
        h: &[f64],
        queues: &[f64],
        ctrl: &Controls,
    ) -> f64 {
        let costs =
            RoundCosts::evaluate(&self.sys, devices, self.model_bits, h, &ctrl.f_hz, &ctrl.p_w);
        let mut acc = 0.0;
        for i in 0..devices.len() {
            let sel = selection_probability(ctrl.q[i], self.sys.k);
            acc += self.v
                * (ctrl.q[i] * costs.time_s[i]
                    + self.lambda * weights[i] * weights[i] / ctrl.q[i]);
            acc += queues[i] * (sel * costs.energy_j[i] - devices[i].energy_budget_j);
            if self.ctl.cost_weight != 0.0 {
                acc += self.v * self.ctl.cost_weight * sel * costs.energy_j[i];
            }
        }
        acc
    }
}

fn rel_change(prev: &[f64], cur: &[f64]) -> f64 {
    let num: f64 = prev
        .iter()
        .zip(cur)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    let den: f64 = prev.iter().map(|a| a * a).sum::<f64>().sqrt().max(1e-300);
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ControlConfig, SystemConfig};
    use crate::rng::Rng;
    use crate::system::Fleet;

    fn setup(n: usize) -> (SystemConfig, Fleet, Vec<f64>, Vec<f64>) {
        let sys = SystemConfig {
            num_devices: n,
            ..SystemConfig::default()
        };
        let mut rng = Rng::new(11);
        let fleet = Fleet::generate(&sys, (50, 400), &mut rng);
        let h: Vec<f64> = (0..n).map(|_| rng.range(0.01, 0.5)).collect();
        let queues: Vec<f64> = (0..n).map(|_| rng.range(0.0, 20.0)).collect();
        (sys, fleet, h, queues)
    }

    fn solver(sys: &SystemConfig) -> LroaSolver {
        LroaSolver::new(
            sys.clone(),
            ControlConfig::default(),
            10.0,  // lambda
            1e4,   // V
            32.0 * 140_000.0,
        )
    }

    fn cold_solver(sys: &SystemConfig) -> LroaSolver {
        LroaSolver::new(
            sys.clone(),
            ControlConfig {
                warm_start: false,
                ..ControlConfig::default()
            },
            10.0,
            1e4,
            32.0 * 140_000.0,
        )
    }

    #[test]
    fn controls_feasible() {
        let (sys, fleet, h, queues) = setup(60);
        let mut s = solver(&sys);
        let (ctrl, stats) = s.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        assert!(stats.outer_iters >= 1);
        let sum_q: f64 = ctrl.q.iter().sum();
        assert!((sum_q - 1.0).abs() < 1e-6, "sum q = {sum_q}");
        for (i, d) in fleet.devices.iter().enumerate() {
            assert!(ctrl.f_hz[i] >= d.f_min_hz && ctrl.f_hz[i] <= d.f_max_hz);
            assert!(ctrl.p_w[i] >= d.p_min_w && ctrl.p_w[i] <= d.p_max_w);
            assert!(ctrl.q[i] > 0.0 && ctrl.q[i] <= 1.0);
        }
    }

    #[test]
    fn converges_before_cap() {
        let (sys, fleet, h, queues) = setup(120);
        let mut s = solver(&sys);
        let (_, stats) = s.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        assert!(
            stats.outer_iters < s.ctl.max_outer_iters,
            "hit outer cap: {}",
            stats.outer_iters
        );
    }

    #[test]
    fn beats_midpoint_and_uniform_controls() {
        let (sys, fleet, h, queues) = setup(80);
        let mut s = solver(&sys);
        let (_ctrl, stats) = s.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        let mid = Controls::midpoint(&fleet.devices);
        let mid_obj = s.p2_objective(&fleet.devices, fleet.weights(), &h, &queues, &mid);
        assert!(
            stats.objective <= mid_obj + mid_obj.abs() * 1e-9,
            "solver {} vs midpoint {}",
            stats.objective,
            mid_obj
        );
    }

    #[test]
    fn stragglers_get_lower_sampling_probability() {
        let (sys, mut fleet, mut h, queues) = setup(40);
        // Same data everywhere so only the channel differs.
        for d in fleet.devices.iter_mut() {
            d.data_size = 200;
        }
        let n = fleet.devices.len();
        let sizes = vec![200; n];
        let mut rng = Rng::new(5);
        let fleet = Fleet::from_data_sizes(&sys, &sizes, &mut rng);
        // Device 0: terrible channel. Device 1: great channel.
        h[0] = 0.01;
        h[1] = 0.5;
        let mut s = solver(&sys);
        let (ctrl, _) = s.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        assert!(
            ctrl.q[0] < ctrl.q[1],
            "straggler q {} should be < good-channel q {}",
            ctrl.q[0],
            ctrl.q[1]
        );
    }

    #[test]
    fn empty_queues_run_flat_out() {
        let (sys, fleet, h, _) = setup(20);
        let queues = vec![0.0; 20];
        let mut s = solver(&sys);
        let (ctrl, _) = s.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        for (i, d) in fleet.devices.iter().enumerate() {
            assert_eq!(ctrl.f_hz[i], d.f_max_hz);
            assert_eq!(ctrl.p_w[i], d.p_max_w);
        }
    }

    #[test]
    fn queue_pressure_reduces_energy() {
        let (sys, fleet, h, _) = setup(30);
        let mut s = solver(&sys);
        let (c_free, _) = s.solve_round(&fleet.devices, fleet.weights(), &h, &vec![0.0; 30]);
        let (c_tight, _) = s.solve_round(&fleet.devices, fleet.weights(), &h, &vec![1e4; 30]);
        let e = |c: &Controls| -> f64 {
            let costs = RoundCosts::evaluate(&s.sys, &fleet.devices, s.model_bits, &h, &c.f_hz, &c.p_w);
            costs.energy_j.iter().sum()
        };
        assert!(e(&c_tight) < e(&c_free), "tight {} free {}", e(&c_tight), e(&c_free));
    }

    #[test]
    fn uniform_dynamic_is_uniform() {
        let (sys, fleet, h, queues) = setup(25);
        let mut s = solver(&sys);
        let (ctrl, _) = s.solve_uniform_dynamic(&fleet.devices, &h, &queues);
        for &q in &ctrl.q {
            assert!((q - 1.0 / 25.0).abs() < 1e-12);
        }
    }

    #[test]
    fn deterministic() {
        let (sys, fleet, h, queues) = setup(50);
        let mut s1 = solver(&sys);
        let mut s2 = solver(&sys);
        let (c1, _) = s1.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        let (c2, _) = s2.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        assert_eq!(c1.q, c2.q);
        assert_eq!(c1.f_hz, c2.f_hz);
        assert_eq!(c1.p_w, c2.p_w);
    }

    #[test]
    fn warm_start_resumes_from_the_stored_fixed_point() {
        let (sys, fleet, h, queues) = setup(50);
        let mut s = solver(&sys);
        let (c1, st1) = s.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        // Second solve on identical inputs starts at the fixed point:
        // it must agree with the cold answer and converge immediately.
        let (c2, st2) = s.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        assert!(
            st2.outer_iters <= 2 && st2.outer_iters < st1.outer_iters,
            "warm restart did not cut outer iters: {} -> {}",
            st1.outer_iters,
            st2.outer_iters
        );
        let drift = rel_change(&c1.f_hz, &c2.f_hz)
            + rel_change(&c1.p_w, &c2.p_w)
            + rel_change(&c1.q, &c2.q);
        assert!(
            drift <= 100.0 * s.ctl.eps_outer,
            "warm and cold fixed points diverged: rel drift {drift}"
        );
        let sum_q: f64 = c2.q.iter().sum();
        assert!((sum_q - 1.0).abs() < 1e-6, "warm q left the simplex: {sum_q}");
    }

    #[test]
    fn cold_solver_is_stateless() {
        let (sys, fleet, h, queues) = setup(35);
        let mut s = cold_solver(&sys);
        let (c1, st1) = s.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        let (c2, st2) = s.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        assert_eq!(c1.f_hz, c2.f_hz);
        assert_eq!(c1.p_w, c2.p_w);
        assert_eq!(c1.q, c2.q);
        assert_eq!(st1.outer_iters, st2.outer_iters);
        // ... and matches a fresh solver bit-for-bit.
        let mut fresh = cold_solver(&sys);
        let (c3, _) = fresh.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        assert_eq!(c1.q, c3.q);
        assert_eq!(c1.f_hz, c3.f_hz);
        assert_eq!(c1.p_w, c3.p_w);
    }

    #[test]
    fn identity_ids_match_the_plain_entry_point() {
        let (sys, fleet, h, queues) = setup(30);
        let ids: Vec<usize> = (0..30).collect();
        let queues2: Vec<f64> = queues.iter().map(|q| q * 1.7 + 0.3).collect();
        let mut s1 = solver(&sys);
        let mut s2 = solver(&sys);
        for qs in [&queues, &queues2] {
            let (c1, st1) = s1.solve_round(&fleet.devices, fleet.weights(), &h, qs);
            let (c2, st2) = s2.solve_round_on(&ids, &fleet.devices, fleet.weights(), &h, qs);
            assert_eq!(c1.f_hz, c2.f_hz);
            assert_eq!(c1.p_w, c2.p_w);
            assert_eq!(c1.q, c2.q);
            assert_eq!(st1.outer_iters, st2.outer_iters);
        }
    }

    #[test]
    fn warm_start_renormalizes_q_when_the_candidate_set_changes() {
        let (sys, fleet, h, queues) = setup(12);
        let mut s = solver(&sys);
        let ids: Vec<usize> = (0..12).collect();
        s.solve_round_on(&ids, &fleet.devices, fleet.weights(), &h, &queues);
        // Shrink to the odd devices: the warm carry must gather through
        // ids and put q back on the simplex.
        let sub: Vec<usize> = (0..12).filter(|i| i % 2 == 1).collect();
        let devs: Vec<Device> = sub.iter().map(|&i| fleet.devices[i].clone()).collect();
        let wsum: f64 = sub.iter().map(|&i| fleet.weights()[i]).sum();
        let w: Vec<f64> = sub.iter().map(|&i| fleet.weights()[i] / wsum).collect();
        let hh: Vec<f64> = sub.iter().map(|&i| h[i]).collect();
        let qq: Vec<f64> = sub.iter().map(|&i| queues[i]).collect();
        let (ctrl, stats) = s.solve_round_on(&sub, &devs, &w, &hh, &qq);
        assert!(stats.outer_iters >= 1);
        let sum_q: f64 = ctrl.q.iter().sum();
        assert!((sum_q - 1.0).abs() < 1e-6, "sum q = {sum_q}");
        for (i, d) in devs.iter().enumerate() {
            assert!(ctrl.f_hz[i] >= d.f_min_hz && ctrl.f_hz[i] <= d.f_max_hz);
            assert!(ctrl.p_w[i] >= d.p_min_w && ctrl.p_w[i] <= d.p_max_w);
            assert!(ctrl.q[i] > 0.0 && ctrl.q[i] <= 1.0);
        }
        // Grow back to the full set (devices 0,2,.. re-enter from the
        // store, everyone renormalizes): still a valid distribution.
        let (ctrl2, _) = s.solve_round_on(&ids, &fleet.devices, fleet.weights(), &h, &queues);
        let sum_q2: f64 = ctrl2.q.iter().sum();
        assert!((sum_q2 - 1.0).abs() < 1e-6, "sum q = {sum_q2}");
    }

    #[test]
    fn warm_and_cold_agree_on_the_fixed_point_across_rounds() {
        let (sys, fleet, h, _) = setup(40);
        let mut warm = solver(&sys);
        let mut cold = cold_solver(&sys);
        let mut rng = Rng::new(77);
        let (mut warm_iters, mut cold_iters) = (0usize, 0usize);
        for round in 0..12 {
            let queues: Vec<f64> = (0..40).map(|_| rng.range(0.0, 30.0)).collect();
            let hh: Vec<f64> = h.iter().map(|&x| (x * (1.0 + 0.05 * round as f64)).min(0.6)).collect();
            let (cw, sw) = warm.solve_round(&fleet.devices, fleet.weights(), &hh, &queues);
            let (cc, sc) = cold.solve_round(&fleet.devices, fleet.weights(), &hh, &queues);
            warm_iters += sw.outer_iters;
            cold_iters += sc.outer_iters;
            let drift = rel_change(&cc.f_hz, &cw.f_hz)
                + rel_change(&cc.p_w, &cw.p_w)
                + rel_change(&cc.q, &cw.q);
            assert!(
                drift <= 100.0 * warm.ctl.eps_outer,
                "round {round}: warm/cold fixed points diverged (rel drift {drift})"
            );
        }
        assert!(
            warm_iters < cold_iters,
            "warm start did not reduce total outer iters: {warm_iters} vs {cold_iters}"
        );
    }

    fn cost_solver(sys: &SystemConfig, cost_weight: f64) -> LroaSolver {
        LroaSolver::new(
            sys.clone(),
            ControlConfig {
                cost_weight,
                ..ControlConfig::default()
            },
            10.0,
            1e4,
            32.0 * 140_000.0,
        )
    }

    #[test]
    fn cost_weight_zero_is_bitwise_the_baseline() {
        let (sys, fleet, h, queues) = setup(40);
        let mut base = solver(&sys);
        let mut zero = cost_solver(&sys, 0.0);
        let (c1, s1) = base.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        let (c2, s2) = zero.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        assert_eq!(c1.f_hz, c2.f_hz);
        assert_eq!(c1.p_w, c2.p_w);
        assert_eq!(c1.q, c2.q);
        assert_eq!(s1.objective, s2.objective);
        let (u1, _) = base.solve_uniform_dynamic(&fleet.devices, &h, &queues);
        let (u2, _) = zero.solve_uniform_dynamic(&fleet.devices, &h, &queues);
        assert_eq!(u1.f_hz, u2.f_hz);
        assert_eq!(u1.p_w, u2.p_w);
    }

    #[test]
    fn cost_weight_prices_total_energy() {
        // With empty queues the plain solver runs flat out (energy is
        // free); the cost objective keeps pricing it, so the controls
        // back off and the round energy drops.
        let (sys, fleet, h, _) = setup(30);
        let queues = vec![0.0; 30];
        let mut base = solver(&sys);
        let mut cost = cost_solver(&sys, 1.0);
        let model_bits = base.model_bits;
        let (c_free, _) = base.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        let (c_cost, _) = cost.solve_round(&fleet.devices, fleet.weights(), &h, &queues);
        let energy = |c: &Controls| -> f64 {
            let costs =
                RoundCosts::evaluate(&sys, &fleet.devices, model_bits, &h, &c.f_hz, &c.p_w);
            costs.energy_j.iter().sum()
        };
        let (e_free, e_cost) = (energy(&c_free), energy(&c_cost));
        assert!(
            e_cost < e_free,
            "cost mode should cut round energy: {e_cost} vs {e_free}"
        );
        assert!(
            fleet
                .devices
                .iter()
                .enumerate()
                .any(|(i, d)| c_cost.f_hz[i] < d.f_max_hz || c_cost.p_w[i] < d.p_max_w),
            "cost mode left every device at full resources"
        );
        // The uniform-dynamic baseline throttles the same way.
        let (u_free, _) = base.solve_uniform_dynamic(&fleet.devices, &h, &queues);
        let (u_cost, _) = cost.solve_uniform_dynamic(&fleet.devices, &h, &queues);
        assert!(energy(&u_cost) < energy(&u_free));
        // And the recorded objective prices the energy term.
        let obj_base = base.p2_objective(&fleet.devices, fleet.weights(), &h, &queues, &c_free);
        let obj_cost = cost.p2_objective(&fleet.devices, fleet.weights(), &h, &queues, &c_free);
        assert!(obj_cost > obj_base, "same controls must cost more under cost mode");
    }

    #[test]
    #[should_panic(expected = "empty candidate set")]
    fn midpoint_panics_on_an_empty_candidate_set() {
        Controls::midpoint(&[]);
    }

    #[test]
    #[should_panic(expected = "empty candidate set")]
    fn solve_round_panics_on_an_empty_candidate_set() {
        let (sys, ..) = setup(4);
        let mut s = solver(&sys);
        s.solve_round(&[], &[], &[], &[]);
    }
}
