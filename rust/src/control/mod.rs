//! The paper's contribution: Lyapunov-based online control (LROA).
//!
//! * [`queues`] — virtual energy-consumption queues, eqs. (19)–(20);
//! * [`freq`] — Theorem 2: closed-form optimal CPU frequency (P2.1.1);
//! * [`power`] — Theorem 3: optimal transmit power by root-finding (P2.1.2);
//! * [`sum`] — the SUM solver for sampling probabilities (P2.2);
//! * [`lroa`] — Algorithm 2: the alternating outer loop tying it together;
//! * [`hyper`] — the λ₀ / V₀ estimation rule of §VII-B.1;
//! * [`static_alloc`] — the Uni-S baseline's static resource policy;
//! * [`policy`] — the [`RoundPolicy`] trait, the four scheme impls, and
//!   the name → constructor registry the server dispatches through.

pub mod freq;
pub mod hyper;
pub mod lroa;
pub mod policy;
pub mod power;
pub mod queues;
pub mod static_alloc;
pub mod sum;

pub use lroa::{Controls, LroaSolver, SolverStats};
pub use policy::{PolicyInit, RoundContext, RoundPlan, RoundPolicy};
pub use queues::VirtualQueues;

/// Per-round control decisions for every device.
pub fn objective_terms(q: &[f64], times: &[f64], lambda: f64, weights: &[f64]) -> f64 {
    // Σ_n ( q_n T_n + λ w_n² / q_n )  — the P1 integrand.  Devices with
    // q_n = 0 are outside this round's candidate set (unreachable under a
    // dynamic environment) and contribute nothing; every in-problem q_n
    // carries the solver's q_min floor, so the division is safe.
    q.iter()
        .zip(times)
        .zip(weights)
        .filter(|((qn, _), _)| **qn > 0.0)
        .map(|((qn, tn), wn)| qn * tn + lambda * wn * wn / qn)
        .sum()
}
