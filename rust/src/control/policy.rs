//! Pluggable round policies: one trait, four schemes, one registry.
//!
//! A [`RoundPolicy`] owns everything scheme-specific about one
//! communication round — how resources `(f, p)` are allocated, how the
//! sampling distribution `q` is chosen, and how the participant multiset
//! `K^t` is drawn.  The FL server is policy-agnostic: it hands the policy
//! a [`RoundContext`] (fleet, channel draw, queue backlogs) and receives a
//! [`RoundPlan`] back.  Adding a new scheduling scheme is one impl plus
//! one [`REGISTRY`] line; no server code changes.
//!
//! The registered schemes mirror the paper's §VII-A comparison plus the
//! scheduling baselines the related work suggests and the clairvoyant
//! regret anchor:
//!
//! | name     | resources `(f, p)`        | sampling `q` / selection      |
//! |----------|---------------------------|-------------------------------|
//! | LROA     | Algorithm 2 (dynamic)     | Algorithm 2 probabilities     |
//! | Uni-D    | Algorithm 2 at `q = 1/N`  | uniform with replacement      |
//! | Uni-S    | static energy balance     | uniform with replacement      |
//! | DivFL    | static energy balance     | greedy facility location      |
//! | Greedy   | static energy balance     | K best-channel devices        |
//! | RR       | static energy balance     | round-robin over global ids   |
//! | P2C      | static energy balance     | power-of-two-choices draws    |
//! | Bandit   | static energy balance     | UCB-scored softmax marginals  |
//! | Thompson | static energy balance     | posterior-draw softmax        |
//! | LinUCB   | static energy balance     | ridge-UCB softmax marginals   |
//! | Conv-Aware | static energy balance   | staleness×update-norm softmax |
//! | Oracle   | `f_max` / `p_max`         | the min-latency device        |
//! | Oracle-E | Theorem 2/3 at `q = 1`    | the min-latency device        |
//!
//! The contextual bandit ([`ContextualBanditPolicy`]) scores each
//! reachable device from a per-device context vector drawn from the
//! environment registry's observable surface — the EMA of its observed
//! gains, its availability streak, and its virtual energy-queue backlog
//! ([`crate::control::queues`]) — plus a UCB exploration bonus over its
//! pull count, then samples `K` slots from the exact softmax marginals
//! ([`crate::sampling::softmax_distribution`]).  Because the marginals
//! are exact, the eq. (4) coefficients `w_n / (K q_n)` keep the
//! aggregate unbiased, exactly like `p2c`'s.  Rewards (the realized
//! relative speed of the pulled devices) flow back through
//! [`RoundPolicy::observe_round`].
//!
//! `Oracle-E` ([`OracleEnergyPolicy`]) is the *budget-feasible*
//! clairvoyant anchor: like the oracle it runs the single fastest
//! reachable device and peeks at next-round gains for tie-breaking, but
//! its resources come from the same queue-priced Theorem 2/3 kernels
//! ([`crate::control::freq`], [`crate::control::power`]) LROA uses —
//! at `q = 1` for the device it will run — so its virtual queues, and
//! therefore its time-average energy, stay bounded by the same budgets
//! the online policies are held to.  `lroa regret` uses both anchors to
//! decompose each online cell's regret into `regret_online`
//! (vs Oracle-E: the price of not knowing the future) and
//! `regret_budget` (Oracle-E vs Oracle: the price of the energy
//! constraint itself).
//!
//! The oracle is the latency **lower bound**: with the current channel
//! known at decision time (as every policy sees), the per-round makespan
//! is minimized by running the single fastest reachable device at full
//! resources, so no policy can complete the horizon sooner on the same
//! environment stream.  `lroa regret` reports each online policy's gap
//! against it.  When the environment is previewable the oracle also
//! reads next-round gains ([`RoundContext::next_h`], fed by
//! [`crate::env::Environment::peek`]) to break exact latency ties in
//! favor of devices whose channel is about to degrade — foresight that
//! never costs it the current round.
//!
//! Under a dynamic environment ([`crate::env`]) the server hands the
//! policy only the *reachable* sub-problem: every slice in
//! [`RoundContext`] is indexed by candidate **position**, and
//! [`RoundContext::ids`] maps positions back to global device ids (the
//! identity when the whole fleet is reachable).  Stateful selectors that
//! key on global identity (DivFL's embeddings, RR's cursor) must go
//! through `ids`.

use crate::config::{
    BanditConfig, ControlConfig, LinUcbConfig, Policy, SystemConfig, ThompsonConfig,
};
use crate::control::{freq, power, static_alloc, Controls, LroaSolver, SolverStats};
use crate::rng::Rng;
use crate::sampling::{self, DivFlState, Projector, Selection};
use crate::system::{Device, RoundCosts};
use crate::Result;

/// DivFL update-embedding dimensionality (random projection target).
const DIVFL_EMBED_DIM: usize = 32;

/// Everything a policy may read when planning round `t`.
pub struct RoundContext<'a> {
    /// Round index.
    pub t: usize,
    /// Sampling frequency `K`.
    pub k: usize,
    /// The candidate devices (this round's reachable set `N^t`).
    pub devices: &'a [Device],
    /// Data weights `w_n` over the candidates (sum to 1).
    pub weights: &'a [f64],
    /// Global device id per candidate position (identity when every
    /// device is reachable; see [`crate::env`]).
    pub ids: &'a [usize],
    /// This round's channel gains `h_n^t` (candidate positions).
    pub h: &'a [f64],
    /// Virtual-queue backlogs `Q_n^t` (candidate positions).
    pub backlogs: &'a [f64],
    /// Next round's channel gains (candidate positions), when the
    /// environment is previewable AND the policy asked for foresight
    /// ([`RoundPolicy::wants_peek`]); `None` otherwise.  Only the oracle
    /// reads it.
    pub next_h: Option<&'a [f64]>,
}

/// A policy's decisions for one round.
pub struct RoundPlan {
    /// Resource controls `(f, p)` and the sampling distribution `q`.
    pub controls: Controls,
    /// Solver diagnostics (zeroed for closed-form baselines).
    pub stats: SolverStats,
    /// The sampled participant multiset plus eq. (4) coefficients.
    pub selection: Selection,
    /// Per-device participation marginals the virtual queues and the
    /// energy ledger use: the sampling distribution for the stochastic
    /// schemes (sums to 1), uniform `1/N` for DivFL and RR (their
    /// long-run average), and a 0/1 indicator for Greedy's
    /// deterministic top-K.  The recorded P1 objective instead uses
    /// `controls.q`, the sampling distribution proper.
    pub q_eff: Vec<f64>,
}

/// One scheduling scheme's behaviour across rounds.
///
/// The sampling RNG is passed in by the server (not stored here) so that
/// every policy consumes the *same* random stream the pre-trait server
/// did — policy comparisons on shared seeds stay exactly reproducible.
pub trait RoundPolicy: Send {
    /// Registry name (also the run-label prefix).
    fn name(&self) -> &'static str;

    /// Plan round `ctx.t`: solve for controls and draw the participants.
    fn plan(&mut self, ctx: &RoundContext<'_>, rng: &mut Rng) -> RoundPlan;

    /// Feed back one participant's model delta after local training.
    /// Only stateful selectors (DivFL) care; the default ignores it.
    fn observe_update(&mut self, _client: usize, _delta: &[f32]) {}

    /// Feed back the round's realized costs after the cost-model stage:
    /// `selected` is the unique participant set in **global** device ids
    /// and `costs` is fleet-indexed.  Fires in every sim mode (unlike
    /// [`RoundPolicy::observe_update`], which needs local training to
    /// run).  Only learning policies (the bandit) care; the default
    /// ignores it.
    fn observe_round(&mut self, _selected: &[usize], _costs: &RoundCosts) {}

    /// Whether the server should attempt an [`crate::env::Environment::peek`]
    /// and populate [`RoundContext::next_h`].  Default false: online
    /// policies must not see the future (that is the paper's whole
    /// premise); only the oracle anchor opts in.
    fn wants_peek(&self) -> bool {
        false
    }
}

fn uniform_q(n: usize) -> Vec<f64> {
    vec![1.0 / n as f64; n]
}

// ---------------------------------------------------------------------------
// LROA — the paper's method.
// ---------------------------------------------------------------------------

/// Algorithm 2 resources + probability-driven sampling.
pub struct LroaPolicy {
    solver: LroaSolver,
}

impl LroaPolicy {
    pub fn new(init: &PolicyInit<'_>) -> Self {
        Self {
            solver: init.solver(),
        }
    }
}

impl RoundPolicy for LroaPolicy {
    fn name(&self) -> &'static str {
        "LROA"
    }

    fn plan(&mut self, ctx: &RoundContext<'_>, rng: &mut Rng) -> RoundPlan {
        // Solve on the compacted set but key warm state by the global
        // ids, so the carried iterate follows devices through
        // availability churn.
        let (controls, stats) = self.solver.solve_round_on(
            ctx.ids,
            ctx.devices,
            ctx.weights,
            ctx.h,
            ctx.backlogs,
        );
        let selection =
            sampling::sample_by_probability(&controls.q, ctx.weights, ctx.k, rng);
        let q_eff = controls.q.clone();
        RoundPlan {
            controls,
            stats,
            selection,
            q_eff,
        }
    }
}

// ---------------------------------------------------------------------------
// Uni-D — uniform sampling, dynamic resources.
// ---------------------------------------------------------------------------

/// Uniform sampling with LROA's dynamic `f`/`p` blocks at `q = 1/N`.
pub struct UniformDynamicPolicy {
    solver: LroaSolver,
}

impl UniformDynamicPolicy {
    pub fn new(init: &PolicyInit<'_>) -> Self {
        Self {
            solver: init.solver(),
        }
    }
}

impl RoundPolicy for UniformDynamicPolicy {
    fn name(&self) -> &'static str {
        "Uni-D"
    }

    fn plan(&mut self, ctx: &RoundContext<'_>, rng: &mut Rng) -> RoundPlan {
        let (controls, stats) = self
            .solver
            .solve_uniform_dynamic(ctx.devices, ctx.h, ctx.backlogs);
        let n = ctx.devices.len();
        let selection = sampling::sample_uniform(n, ctx.weights, ctx.k, rng);
        RoundPlan {
            controls,
            stats,
            selection,
            q_eff: uniform_q(n),
        }
    }
}

// ---------------------------------------------------------------------------
// Uni-S — uniform sampling, static resources.
// ---------------------------------------------------------------------------

/// Uniform sampling with the static mid-power / energy-balance allocation.
pub struct UniformStaticPolicy {
    sys: SystemConfig,
    model_bits: f64,
}

impl UniformStaticPolicy {
    pub fn new(init: &PolicyInit<'_>) -> Self {
        Self {
            sys: init.sys.clone(),
            model_bits: init.model_bits,
        }
    }
}

impl RoundPolicy for UniformStaticPolicy {
    fn name(&self) -> &'static str {
        "Uni-S"
    }

    fn plan(&mut self, ctx: &RoundContext<'_>, rng: &mut Rng) -> RoundPlan {
        let controls =
            static_alloc::solve_static(&self.sys, ctx.devices, self.model_bits, ctx.h);
        let n = ctx.devices.len();
        let selection = sampling::sample_uniform(n, ctx.weights, ctx.k, rng);
        RoundPlan {
            controls,
            stats: SolverStats::default(),
            selection,
            q_eff: uniform_q(n),
        }
    }
}

// ---------------------------------------------------------------------------
// DivFL — diverse submodular selection, static resources.
// ---------------------------------------------------------------------------

/// Greedy facility-location selection over stale update embeddings.
pub struct DivFlPolicy {
    sys: SystemConfig,
    model_bits: f64,
    state: DivFlState,
    projector: Projector,
}

impl DivFlPolicy {
    pub fn new(init: &PolicyInit<'_>) -> Self {
        Self {
            sys: init.sys.clone(),
            model_bits: init.model_bits,
            state: DivFlState::new(init.sys.num_devices, DIVFL_EMBED_DIM),
            projector: Projector::new(DIVFL_EMBED_DIM, init.seed ^ 0xD1F1),
        }
    }
}

impl RoundPolicy for DivFlPolicy {
    fn name(&self) -> &'static str {
        "DivFL"
    }

    fn plan(&mut self, ctx: &RoundContext<'_>, _rng: &mut Rng) -> RoundPlan {
        let controls =
            static_alloc::solve_static(&self.sys, ctx.devices, self.model_bits, ctx.h);
        let selection = self.state.select_among(ctx.ids, ctx.weights, ctx.k);
        RoundPlan {
            controls,
            stats: SolverStats::default(),
            selection,
            q_eff: uniform_q(ctx.devices.len()),
        }
    }

    fn observe_update(&mut self, client: usize, delta: &[f32]) {
        self.state.observe(client, self.projector.project(delta));
    }
}

// ---------------------------------------------------------------------------
// Greedy-channel — best instantaneous channels, static resources.
// ---------------------------------------------------------------------------

/// Pick the `K` reachable devices with the best channel gains `h_n^t`
/// (the fast-convergence scheduling heuristic of Shi et al.), with the
/// static energy-balance resource allocation and FedAvg aggregation.
pub struct GreedyChannelPolicy {
    sys: SystemConfig,
    model_bits: f64,
}

impl GreedyChannelPolicy {
    pub fn new(init: &PolicyInit<'_>) -> Self {
        Self {
            sys: init.sys.clone(),
            model_bits: init.model_bits,
        }
    }
}

impl RoundPolicy for GreedyChannelPolicy {
    fn name(&self) -> &'static str {
        "Greedy"
    }

    fn plan(&mut self, ctx: &RoundContext<'_>, _rng: &mut Rng) -> RoundPlan {
        let controls =
            static_alloc::solve_static(&self.sys, ctx.devices, self.model_bits, ctx.h);
        let n = ctx.devices.len();
        let k = ctx.k.min(n);
        // Best h first; ties broken by position for determinism — a
        // total order, so the bounded-heap top-K returns exactly what
        // the old "sort the whole pool, truncate" produced, in O(n log k)
        // (the fleet-scale path: no full sort over 1M candidates).
        let order = sampling::top_k_by(n, k, |a, b| {
            ctx.h[b]
                .partial_cmp(&ctx.h[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let selection = sampling::fedavg_selection(order, ctx.weights);
        // Greedy's selection is deterministic and concentrated, so its
        // participation marginals are a 0/1 indicator — not uniform —
        // and the energy ledger / virtual queues charge exactly the
        // devices it actually uses.
        let mut q_eff = vec![0.0; n];
        for &m in &selection.members {
            q_eff[m] = 1.0;
        }
        RoundPlan {
            controls,
            stats: SolverStats::default(),
            selection,
            q_eff,
        }
    }
}

// ---------------------------------------------------------------------------
// Round-robin — fairness anchor, static resources.
// ---------------------------------------------------------------------------

/// Cycle through the fleet `K` devices at a time, in global-id order.
///
/// The cursor lives in *global* id space, so under a dynamic candidate
/// set the policy picks the next `K` reachable devices at or after the
/// cursor (cyclically) and advances past the last one — unreachable
/// devices are simply skipped, not starved.
pub struct RoundRobinPolicy {
    sys: SystemConfig,
    model_bits: f64,
    n_total: usize,
    cursor: usize,
}

impl RoundRobinPolicy {
    pub fn new(init: &PolicyInit<'_>) -> Self {
        Self {
            sys: init.sys.clone(),
            model_bits: init.model_bits,
            n_total: init.sys.num_devices,
            cursor: 0,
        }
    }
}

impl RoundPolicy for RoundRobinPolicy {
    fn name(&self) -> &'static str {
        "RR"
    }

    fn plan(&mut self, ctx: &RoundContext<'_>, _rng: &mut Rng) -> RoundPlan {
        let controls =
            static_alloc::solve_static(&self.sys, ctx.devices, self.model_bits, ctx.h);
        let n = ctx.devices.len();
        let k = ctx.k.min(n);
        // Cyclic distance of each candidate's global id from the cursor:
        // distinct ids make the key injective, so this is a total order
        // and the bounded-heap top-K equals the old full sort+truncate.
        let (cursor, n_total) = (self.cursor, self.n_total);
        let key = |pos: usize| (ctx.ids[pos] + n_total - cursor) % n_total;
        let order = sampling::top_k_by(n, k, |a, b| key(a).cmp(&key(b)));
        self.cursor = (ctx.ids[order[k - 1]] + 1) % n_total;
        let selection = sampling::fedavg_selection(order, ctx.weights);
        RoundPlan {
            controls,
            stats: SolverStats::default(),
            selection,
            q_eff: uniform_q(n),
        }
    }
}

// ---------------------------------------------------------------------------
// Power-of-two-choices — two uniform probes per slot, keep the better
// channel, static resources.
// ---------------------------------------------------------------------------

/// The classic load-balancing sampler as a scheduling baseline: each of
/// the `K` slots draws two devices uniformly and keeps the better
/// instantaneous channel.  Exact per-slot marginals
/// ([`sampling::p2c_marginals`]) serve as both the round's sampling
/// distribution (P1 objective) and the eq. (4) coefficients, so the
/// aggregate stays unbiased.
pub struct PowerOfTwoPolicy {
    sys: SystemConfig,
    model_bits: f64,
}

impl PowerOfTwoPolicy {
    pub fn new(init: &PolicyInit<'_>) -> Self {
        Self {
            sys: init.sys.clone(),
            model_bits: init.model_bits,
        }
    }
}

impl RoundPolicy for PowerOfTwoPolicy {
    fn name(&self) -> &'static str {
        "P2C"
    }

    fn plan(&mut self, ctx: &RoundContext<'_>, rng: &mut Rng) -> RoundPlan {
        let mut controls =
            static_alloc::solve_static(&self.sys, ctx.devices, self.model_bits, ctx.h);
        let q = sampling::p2c_marginals(ctx.h);
        let selection = sampling::sample_power_of_two(ctx.h, &q, ctx.weights, ctx.k, rng);
        controls.q = q.clone();
        RoundPlan {
            controls,
            stats: SolverStats::default(),
            selection,
            q_eff: q,
        }
    }
}

// ---------------------------------------------------------------------------
// Contextual bandit — UCB-scored softmax sampling over per-device context
// vectors, static resources.
// ---------------------------------------------------------------------------

/// Saturation constant of the availability-streak feature: a device
/// candidate for this many consecutive rounds scores 0.5 on the feature.
const BANDIT_STREAK_HALF: f64 = 8.0;

/// Contextual UCB scheduler (the bandit-style scheduling of Shi et al.,
/// adapted to the dynamic-environment registry).
///
/// Per round, every reachable device gets a score
///
/// `score_n = (1-w)·exploit_n + w·prior_n + c·sqrt(ln(t+1)/(1+pulls_n))`
///
/// where `prior_n` averages three context features drawn from what the
/// environment lets an online scheduler observe — the EMA of the
/// device's past gains, its availability streak, and its energy-queue
/// headroom `1/(1 + Q_n/Ē_n)` — and `exploit_n` is the empirical mean
/// reward of its pulls (the realized relative speed fed back through
/// [`RoundPolicy::observe_round`]; the context prior cold-starts unpulled
/// arms).  Scores map to *exact* sampling marginals via
/// [`sampling::softmax_distribution`], so the eq. (4) coefficients stay
/// unbiased, and the same marginals price the queues (`q_eff`) and the
/// recorded P1 objective (`controls.q`).
///
/// All state is keyed by **global** device id, so the scheduler keeps
/// learning across rounds where the candidate set (`RoundContext::ids`)
/// shifts under it.
pub struct ContextualBanditPolicy {
    sys: SystemConfig,
    model_bits: f64,
    knobs: BanditConfig,
    /// Rounds planned so far (drives the UCB log term and streaks).
    t: usize,
    /// EMA of observed gains per global id.
    ema_h: Vec<f64>,
    seen: Vec<bool>,
    /// Round stamp of each device's last candidacy + its current
    /// consecutive-candidacy streak.
    last_seen: Vec<usize>,
    streak: Vec<u32>,
    /// Pull statistics per global id (updated by `observe_round`).
    pulls: Vec<u64>,
    reward_sum: Vec<f64>,
    /// The candidate ids of the round most recently planned — the
    /// reward baseline in `observe_round` is the best latency among
    /// devices the scheduler could actually have picked.
    last_candidates: Vec<usize>,
}

impl ContextualBanditPolicy {
    pub fn new(init: &PolicyInit<'_>) -> Self {
        let n = init.sys.num_devices;
        Self {
            sys: init.sys.clone(),
            model_bits: init.model_bits,
            knobs: init.bandit.clone(),
            t: 0,
            ema_h: vec![0.0; n],
            seen: vec![false; n],
            last_seen: vec![0; n],
            streak: vec![0; n],
            pulls: vec![0; n],
            reward_sum: vec![0.0; n],
            last_candidates: Vec::new(),
        }
    }
}

impl RoundPolicy for ContextualBanditPolicy {
    fn name(&self) -> &'static str {
        "Bandit"
    }

    fn plan(&mut self, ctx: &RoundContext<'_>, rng: &mut Rng) -> RoundPlan {
        self.t += 1;
        let n = ctx.devices.len();
        // Context update over this round's candidates: gain EMAs and
        // availability streaks (absence resets a streak to 1 on return).
        let a = self.knobs.gain_ema;
        for (pos, &g) in ctx.ids.iter().enumerate() {
            self.ema_h[g] = if self.seen[g] {
                (1.0 - a) * self.ema_h[g] + a * ctx.h[pos]
            } else {
                ctx.h[pos]
            };
            self.seen[g] = true;
            self.streak[g] = if self.last_seen[g] + 1 == self.t {
                self.streak[g] + 1
            } else {
                1
            };
            self.last_seen[g] = self.t;
        }

        let (clip_lo, clip_hi) = self.sys.channel_clip;
        let span = (clip_hi - clip_lo).max(f64::MIN_POSITIVE);
        let scores: Vec<f64> = (0..n)
            .map(|pos| {
                let g = ctx.ids[pos];
                let gain = ((self.ema_h[g] - clip_lo) / span).clamp(0.0, 1.0);
                let streak = self.streak[g] as f64;
                let avail = streak / (streak + BANDIT_STREAK_HALF);
                let budget = ctx.devices[pos].energy_budget_j.max(f64::MIN_POSITIVE);
                let headroom = 1.0 / (1.0 + ctx.backlogs[pos] / budget);
                let prior = (gain + avail + headroom) / 3.0;
                let exploit = if self.pulls[g] > 0 {
                    self.reward_sum[g] / self.pulls[g] as f64
                } else {
                    prior
                };
                let mean = (1.0 - self.knobs.ctx_weight) * exploit
                    + self.knobs.ctx_weight * prior;
                mean + self.knobs.ucb_c
                    * (((self.t + 1) as f64).ln() / (1.0 + self.pulls[g] as f64)).sqrt()
            })
            .collect();
        let q = sampling::softmax_distribution(&scores, self.knobs.temp, self.knobs.eps);

        let mut controls =
            static_alloc::solve_static(&self.sys, ctx.devices, self.model_bits, ctx.h);
        // The exact marginals are both the recorded sampling distribution
        // (P1 objective) and the queue/energy marginals.
        controls.q = q.clone();
        let selection = sampling::sample_by_probability(&q, ctx.weights, ctx.k, rng);
        self.last_candidates.clear();
        self.last_candidates.extend_from_slice(ctx.ids);
        RoundPlan {
            controls,
            stats: SolverStats::default(),
            selection,
            q_eff: q,
        }
    }

    fn observe_round(&mut self, selected: &[usize], costs: &RoundCosts) {
        // Reward = relative speed of the pulled device against the best
        // candidate this round, in (0, 1] — computable online (the
        // scheduler saw every candidate's gain at decision time), no
        // foresight involved.
        let Some(t_best) = reward_baseline(&self.last_candidates, costs) else {
            return;
        };
        for &g in selected {
            self.pulls[g] += 1;
            self.reward_sum[g] += relative_speed(t_best, costs.time_s[g]);
        }
    }
}

// ---------------------------------------------------------------------------
// The learned-scheduler shelf — Thompson sampling, LinUCB, and the
// convergence-aware scheme share the bandit's observable context (gain
// EMA, availability streak, queue headroom) and its exact-softmax
// marginal mapping, so every member keeps eq. (4) unbiased.
// ---------------------------------------------------------------------------

/// Latency floor for the relative-speed reward.  An adversarially
/// degraded channel can drive a modeled latency to zero, a denormal, or
/// NaN; flooring both sides of the ratio keeps every reward finite and
/// in `[0, 1]` instead of dividing by zero or poisoning `reward_sum`
/// with NaN forever.  Real latencies are ≫ this, so the floor is
/// value-neutral for any non-degenerate round.
const LATENCY_FLOOR_S: f64 = 1e-30;

/// The shared reward baseline: best floored *finite* candidate latency
/// this round, or `None` when no candidate latency is finite (nothing
/// to learn from — skip the update rather than ingest garbage).
fn reward_baseline(candidates: &[usize], costs: &RoundCosts) -> Option<f64> {
    let t_best = candidates
        .iter()
        .map(|&g| costs.time_s[g])
        .filter(|t| t.is_finite())
        .fold(f64::INFINITY, f64::min);
    (t_best.is_finite() && t_best > 0.0).then(|| t_best.max(LATENCY_FLOOR_S))
}

/// Clamped relative speed `t_best / T_g ∈ [0, 1]`: 0 for an unreachable
/// (infinite or NaN latency) device, never NaN or ∞ itself.
fn relative_speed(t_best: f64, t_g: f64) -> f64 {
    if !t_g.is_finite() {
        return 0.0;
    }
    (t_best / t_g.max(LATENCY_FLOOR_S)).min(1.0)
}

/// Per-device context state shared by the learned schedulers, keyed by
/// **global** id so learning survives candidate-set churn: the gain EMA,
/// the availability streak, and the candidate set of the most recently
/// planned round (the reward baseline in `observe_round`).
struct ContextTracker {
    /// EMA factor for the gain feature.
    gain_ema: f64,
    /// Rounds planned so far (drives the streak bookkeeping).
    t: usize,
    ema_h: Vec<f64>,
    seen: Vec<bool>,
    last_seen: Vec<usize>,
    streak: Vec<u32>,
    last_candidates: Vec<usize>,
}

impl ContextTracker {
    fn new(n: usize, gain_ema: f64) -> Self {
        Self {
            gain_ema,
            t: 0,
            ema_h: vec![0.0; n],
            seen: vec![false; n],
            last_seen: vec![0; n],
            streak: vec![0; n],
            last_candidates: Vec::new(),
        }
    }

    /// Advance one round: update gain EMAs and availability streaks over
    /// this round's candidates (absence resets a streak to 1 on return)
    /// and remember the candidate set for the reward baseline.
    fn begin_round(&mut self, ctx: &RoundContext<'_>) {
        self.t += 1;
        let a = self.gain_ema;
        for (pos, &g) in ctx.ids.iter().enumerate() {
            self.ema_h[g] = if self.seen[g] {
                (1.0 - a) * self.ema_h[g] + a * ctx.h[pos]
            } else {
                ctx.h[pos]
            };
            self.seen[g] = true;
            self.streak[g] = if self.last_seen[g] + 1 == self.t {
                self.streak[g] + 1
            } else {
                1
            };
            self.last_seen[g] = self.t;
        }
        self.last_candidates.clear();
        self.last_candidates.extend_from_slice(ctx.ids);
    }

    /// The bandit's three context features for candidate `pos`, each in
    /// `[0, 1]`: normalized gain EMA, streak saturation, queue headroom.
    fn features(&self, sys: &SystemConfig, ctx: &RoundContext<'_>, pos: usize) -> [f64; 3] {
        let g = ctx.ids[pos];
        let (clip_lo, clip_hi) = sys.channel_clip;
        let span = (clip_hi - clip_lo).max(f64::MIN_POSITIVE);
        let gain = ((self.ema_h[g] - clip_lo) / span).clamp(0.0, 1.0);
        let streak = self.streak[g] as f64;
        let avail = streak / (streak + BANDIT_STREAK_HALF);
        let budget = ctx.devices[pos].energy_budget_j.max(f64::MIN_POSITIVE);
        let headroom = 1.0 / (1.0 + ctx.backlogs[pos] / budget);
        [gain, avail, headroom]
    }
}

/// Thompson sampling over the shared context — one Gaussian posterior
/// draw per reachable device, mapped through the same exact softmax
/// marginals as the bandit so the eq. (4) coefficients stay unbiased.
///
/// Arm `g` keeps `(pulls, reward_sum)`; its posterior mean is the
/// empirical reward (the context prior, the mean of the three features,
/// for unpulled arms) and its posterior std shrinks as
/// `prior_std / sqrt(1 + pulls)`.  Draws come from a policy-owned RNG
/// forked off the master seed, so the planned marginals are a pure
/// function of the observed history — the server's shared sampling
/// stream is consumed only by the final K selection draws, keeping
/// cross-policy comparisons on shared seeds honest.
pub struct ThompsonPolicy {
    sys: SystemConfig,
    model_bits: f64,
    knobs: ThompsonConfig,
    ctx_state: ContextTracker,
    pulls: Vec<u64>,
    reward_sum: Vec<f64>,
    posterior_rng: Rng,
}

impl ThompsonPolicy {
    pub fn new(init: &PolicyInit<'_>) -> Self {
        let n = init.sys.num_devices;
        Self {
            sys: init.sys.clone(),
            model_bits: init.model_bits,
            knobs: init.thompson.clone(),
            ctx_state: ContextTracker::new(n, init.thompson.gain_ema),
            pulls: vec![0; n],
            reward_sum: vec![0.0; n],
            posterior_rng: Rng::new(init.seed ^ 0x7503_0A11),
        }
    }
}

impl RoundPolicy for ThompsonPolicy {
    fn name(&self) -> &'static str {
        "Thompson"
    }

    fn plan(&mut self, ctx: &RoundContext<'_>, rng: &mut Rng) -> RoundPlan {
        self.ctx_state.begin_round(ctx);
        let n = ctx.devices.len();
        let scores: Vec<f64> = (0..n)
            .map(|pos| {
                let g = ctx.ids[pos];
                let f = self.ctx_state.features(&self.sys, ctx, pos);
                let prior = (f[0] + f[1] + f[2]) / 3.0;
                let mean = if self.pulls[g] > 0 {
                    self.reward_sum[g] / self.pulls[g] as f64
                } else {
                    prior
                };
                let std = self.knobs.prior_std / (1.0 + self.pulls[g] as f64).sqrt();
                mean + std * self.posterior_rng.normal()
            })
            .collect();
        let q = sampling::softmax_distribution(&scores, self.knobs.temp, self.knobs.eps);
        let mut controls =
            static_alloc::solve_static(&self.sys, ctx.devices, self.model_bits, ctx.h);
        controls.q = q.clone();
        let selection = sampling::sample_by_probability(&q, ctx.weights, ctx.k, rng);
        RoundPlan {
            controls,
            stats: SolverStats::default(),
            selection,
            q_eff: q,
        }
    }

    fn observe_round(&mut self, selected: &[usize], costs: &RoundCosts) {
        let Some(t_best) = reward_baseline(&self.ctx_state.last_candidates, costs) else {
            return;
        };
        for &g in selected {
            self.pulls[g] += 1;
            self.reward_sum[g] += relative_speed(t_best, costs.time_s[g]);
        }
    }
}

/// Context dimensionality of [`LinUcbPolicy`] — the tracker's features.
const LINUCB_DIM: usize = 3;

/// LinUCB — ridge-regression contextual UCB over the shared features.
///
/// One `d×d` design matrix is shared across all devices (the reward
/// model is a single linear map from context to relative speed, not one
/// per arm), held directly in inverse form and maintained by
/// Sherman–Morrison rank-1 updates, so a round costs `O(N·d²)` with no
/// per-round allocation.  Score = `θᵀx + α·sqrt(xᵀ A⁻¹ x)` with
/// `θ = A⁻¹ b`; scores map to exact softmax marginals like every other
/// shelf member.
pub struct LinUcbPolicy {
    sys: SystemConfig,
    model_bits: f64,
    knobs: LinUcbConfig,
    ctx_state: ContextTracker,
    /// Inverse design matrix `A⁻¹` (row-major `d×d`), initialized to
    /// `I/ridge` and kept exact under rank-1 reward updates.
    a_inv: [f64; LINUCB_DIM * LINUCB_DIM],
    /// Reward-weighted context sum `b = Σ r·x`.
    b: [f64; LINUCB_DIM],
    /// Each device's last planned context row (flat `n×d`), read back by
    /// `observe_round` when the device's reward arrives.
    last_x: Vec<f64>,
    /// Score scratch, reused across rounds.
    scores: Vec<f64>,
}

impl LinUcbPolicy {
    pub fn new(init: &PolicyInit<'_>) -> Self {
        let n = init.sys.num_devices;
        let mut a_inv = [0.0; LINUCB_DIM * LINUCB_DIM];
        for i in 0..LINUCB_DIM {
            a_inv[i * LINUCB_DIM + i] = 1.0 / init.linucb.ridge;
        }
        Self {
            sys: init.sys.clone(),
            model_bits: init.model_bits,
            knobs: init.linucb.clone(),
            ctx_state: ContextTracker::new(n, init.linucb.gain_ema),
            a_inv,
            b: [0.0; LINUCB_DIM],
            last_x: vec![0.0; n * LINUCB_DIM],
            scores: Vec::new(),
        }
    }

    /// `A⁻¹ x` (the matrix is symmetric — `A = ridge·I + Σ xxᵀ`).
    fn a_inv_mul(&self, x: &[f64; LINUCB_DIM]) -> [f64; LINUCB_DIM] {
        let mut out = [0.0; LINUCB_DIM];
        for i in 0..LINUCB_DIM {
            for j in 0..LINUCB_DIM {
                out[i] += self.a_inv[i * LINUCB_DIM + j] * x[j];
            }
        }
        out
    }
}

impl RoundPolicy for LinUcbPolicy {
    fn name(&self) -> &'static str {
        "LinUCB"
    }

    fn plan(&mut self, ctx: &RoundContext<'_>, rng: &mut Rng) -> RoundPlan {
        self.ctx_state.begin_round(ctx);
        let n = ctx.devices.len();
        let theta = self.a_inv_mul(&self.b);
        self.scores.clear();
        for pos in 0..n {
            let g = ctx.ids[pos];
            let x = self.ctx_state.features(&self.sys, ctx, pos);
            self.last_x[g * LINUCB_DIM..(g + 1) * LINUCB_DIM].copy_from_slice(&x);
            let ax = self.a_inv_mul(&x);
            let mut fit = 0.0;
            let mut var = 0.0;
            for i in 0..LINUCB_DIM {
                fit += theta[i] * x[i];
                var += x[i] * ax[i];
            }
            self.scores.push(fit + self.knobs.alpha * var.max(0.0).sqrt());
        }
        let q = sampling::softmax_distribution(&self.scores, self.knobs.temp, self.knobs.eps);
        let mut controls =
            static_alloc::solve_static(&self.sys, ctx.devices, self.model_bits, ctx.h);
        controls.q = q.clone();
        let selection = sampling::sample_by_probability(&q, ctx.weights, ctx.k, rng);
        RoundPlan {
            controls,
            stats: SolverStats::default(),
            selection,
            q_eff: q,
        }
    }

    fn observe_round(&mut self, selected: &[usize], costs: &RoundCosts) {
        let Some(t_best) = reward_baseline(&self.ctx_state.last_candidates, costs) else {
            return;
        };
        for &g in selected {
            let r = relative_speed(t_best, costs.time_s[g]);
            let mut x = [0.0; LINUCB_DIM];
            x.copy_from_slice(&self.last_x[g * LINUCB_DIM..(g + 1) * LINUCB_DIM]);
            // Sherman–Morrison: A⁻¹ ← A⁻¹ − (A⁻¹x)(A⁻¹x)ᵀ / (1 + xᵀA⁻¹x).
            // `denom ≥ 1` always (A⁻¹ is positive definite), so the
            // update is unconditionally stable.
            let ax = self.a_inv_mul(&x);
            let denom = 1.0 + ax.iter().zip(&x).map(|(a, b)| a * b).sum::<f64>();
            for i in 0..LINUCB_DIM {
                for j in 0..LINUCB_DIM {
                    self.a_inv[i * LINUCB_DIM + j] -= ax[i] * ax[j] / denom;
                }
            }
            for i in 0..LINUCB_DIM {
                self.b[i] += r * x[i];
            }
        }
    }
}

/// EMA factor of the convergence-aware scheme's update-norm signal.
const CONV_NORM_EMA: f64 = 0.3;

/// Convergence-aware scheduling: selection weighted by
/// `staleness × last observed update norm` (the gradient-information
/// heuristic of Shi et al., arXiv 1911.00856) — a client that has not
/// contributed recently *and* whose updates were large when it did is
/// the one most likely to move the global model.
///
/// Scores are `ln(staleness · norm_ema)`, so the softmax marginals obey
/// a power law in the priority (temperature sets the exponent; the
/// scheme shares the `[bandit]` softmax knobs).  Update norms only flow
/// in Full simulation mode via [`RoundPolicy::observe_update`]; cold
/// devices carry a norm of 1, so in ControlPlaneOnly mode the scheme
/// degrades gracefully to pure staleness (age-based) weighting.
pub struct ConvAwarePolicy {
    sys: SystemConfig,
    model_bits: f64,
    temp: f64,
    eps: f64,
    /// Rounds planned so far (the staleness clock).
    t: usize,
    /// Round stamp of each device's last selection (0 = never picked,
    /// maximal staleness).
    last_picked: Vec<usize>,
    /// EMA of observed per-client update L2 norms.
    norm_ema: Vec<f64>,
    has_norm: Vec<bool>,
}

impl ConvAwarePolicy {
    pub fn new(init: &PolicyInit<'_>) -> Self {
        let n = init.sys.num_devices;
        Self {
            sys: init.sys.clone(),
            model_bits: init.model_bits,
            temp: init.bandit.temp,
            eps: init.bandit.eps,
            t: 0,
            last_picked: vec![0; n],
            norm_ema: vec![1.0; n],
            has_norm: vec![false; n],
        }
    }
}

impl RoundPolicy for ConvAwarePolicy {
    fn name(&self) -> &'static str {
        "Conv-Aware"
    }

    fn plan(&mut self, ctx: &RoundContext<'_>, rng: &mut Rng) -> RoundPlan {
        self.t += 1;
        let n = ctx.devices.len();
        let scores: Vec<f64> = (0..n)
            .map(|pos| {
                let g = ctx.ids[pos];
                let staleness = (self.t - self.last_picked[g]) as f64;
                (staleness * self.norm_ema[g]).max(f64::MIN_POSITIVE).ln()
            })
            .collect();
        let q = sampling::softmax_distribution(&scores, self.temp, self.eps);
        let mut controls =
            static_alloc::solve_static(&self.sys, ctx.devices, self.model_bits, ctx.h);
        controls.q = q.clone();
        let selection = sampling::sample_by_probability(&q, ctx.weights, ctx.k, rng);
        RoundPlan {
            controls,
            stats: SolverStats::default(),
            selection,
            q_eff: q,
        }
    }

    fn observe_update(&mut self, client: usize, delta: &[f32]) {
        let norm = delta
            .iter()
            .map(|&d| {
                let d = d as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt();
        if !norm.is_finite() {
            return;
        }
        self.norm_ema[client] = if self.has_norm[client] {
            (1.0 - CONV_NORM_EMA) * self.norm_ema[client] + CONV_NORM_EMA * norm
        } else {
            norm
        };
        self.has_norm[client] = true;
    }

    fn observe_round(&mut self, selected: &[usize], _costs: &RoundCosts) {
        for &g in selected {
            self.last_picked[g] = self.t;
        }
    }
}

/// Position of the latency-minimal device; exact ties break toward the
/// device whose *next-round* gain is lower when foresight is available.
/// Shared by both clairvoyant anchors — tie-breaking never changes the
/// current round's makespan, so the lower-bound arguments survive.
fn min_latency_pick(times: &[f64], next_h: Option<&[f64]>) -> usize {
    let mut best = 0usize;
    for i in 1..times.len() {
        if times[i] < times[best] {
            best = i;
        } else if times[i] == times[best] {
            if let Some(nh) = next_h {
                if nh[i] < nh[best] {
                    best = i;
                }
            }
        }
    }
    best
}

// ---------------------------------------------------------------------------
// Oracle — the clairvoyant latency lower bound (regret anchor).
// ---------------------------------------------------------------------------

/// Fill every slot with the single fastest reachable device at full
/// resources (`f_max`, `p_max`).
///
/// Per-device latency is monotone decreasing in both `f` and `p`, so
/// `T_n(f_max, p_max)` is each device's floor, and a round's makespan is
/// bounded below by `min_n T_n(f_max, p_max)` for **any** selection any
/// policy can make.  The oracle achieves that bound every round, which
/// makes its cumulative latency a true lower bound on the same
/// environment stream — the anchor `lroa regret` measures against.  It
/// deliberately ignores energy budgets (its queues may grow without
/// bound): it answers "how fast could the horizon possibly finish",
/// nothing else.
///
/// Foresight: when [`RoundContext::next_h`] is populated
/// (previewable environment), exact latency ties break toward the
/// device whose *next* gain is lower — use a channel while it lasts.
/// Tie-breaking never changes the current round's makespan, so the
/// bound survives.
pub struct OraclePolicy {
    sys: SystemConfig,
    model_bits: f64,
}

impl OraclePolicy {
    pub fn new(init: &PolicyInit<'_>) -> Self {
        Self {
            sys: init.sys.clone(),
            model_bits: init.model_bits,
        }
    }
}

impl RoundPolicy for OraclePolicy {
    fn name(&self) -> &'static str {
        "Oracle"
    }

    fn wants_peek(&self) -> bool {
        true
    }

    fn plan(&mut self, ctx: &RoundContext<'_>, _rng: &mut Rng) -> RoundPlan {
        let n = ctx.devices.len();
        let f_hz: Vec<f64> = ctx.devices.iter().map(|d| d.f_max_hz).collect();
        let p_w: Vec<f64> = ctx.devices.iter().map(|d| d.p_max_w).collect();
        let times: Vec<f64> = (0..n)
            .map(|i| {
                crate::system::round_time_s(
                    &self.sys,
                    &ctx.devices[i],
                    self.model_bits,
                    ctx.h[i],
                    f_hz[i],
                    p_w[i],
                )
            })
            .collect();
        // K copies of the single fastest device: the makespan is exactly
        // `min_n T_n`, and the K equal 1/K coefficients aggregate to its
        // plain delta.
        let best = min_latency_pick(&times, ctx.next_h);
        let selection = sampling::fedavg_selection(vec![best; ctx.k], ctx.weights);
        let mut q_eff = vec![0.0; n];
        q_eff[best] = 1.0;
        RoundPlan {
            // Uniform q keeps the recorded P1 objective finite and
            // comparable; the ledgers charge through q_eff.
            controls: Controls {
                f_hz,
                p_w,
                q: vec![1.0 / n as f64; n],
            },
            stats: SolverStats::default(),
            selection,
            q_eff,
        }
    }
}

// ---------------------------------------------------------------------------
// Oracle-E — the clairvoyant *and* budget-feasible anchor.
// ---------------------------------------------------------------------------

/// Run the single fastest reachable device, but at the latency-minimal
/// resources that respect its energy prices — the same per-round
/// energy-constrained problem LROA solves.
///
/// For every candidate the Theorem 2 and Theorem 3 kernels
/// ([`freq::optimal_freq`], [`power::optimal_power`]) are evaluated at
/// `q = 1` (the device, if picked, participates surely) under its
/// current virtual-queue backlog, and the device with the smallest
/// resulting latency wins; ties break on foresight exactly like
/// [`OraclePolicy`].  Empty queues price energy at zero, so the plan
/// degenerates to the unconstrained oracle's `f_max`/`p_max`; as a
/// hammered device's backlog grows the kernels throttle it, its latency
/// rises, and the anchor rotates — the Lyapunov mechanism that keeps
/// its time-average energy within the same budgets `Ē_n` the online
/// policies are held to.  Its `q_eff` is the 0/1 indicator of the one
/// device it uses, so the queues charge the *full* realized draw.
///
/// Since per-device latency is monotone decreasing in `f` and `p`,
/// every round satisfies `T_oracle ≤ T_oracle_e ≤ T_policy-feasible`,
/// which is what makes `regret_budget = T_oracle_e − T_oracle` a
/// non-negative series on shared environment streams.
pub struct OracleEnergyPolicy {
    sys: SystemConfig,
    model_bits: f64,
    /// V — the latency price the kernels trade against queue-priced
    /// energy (the cell's scaled value, shared with its LROA run).
    v: f64,
    /// The cost-mode flat energy price `V·cost_weight` (0 by default),
    /// added to every backlog so the anchor faces the same effective
    /// prices as the cost-objective LROA run it bounds.
    cost_vw: f64,
}

impl OracleEnergyPolicy {
    pub fn new(init: &PolicyInit<'_>) -> Self {
        Self {
            sys: init.sys.clone(),
            model_bits: init.model_bits,
            v: init.v,
            cost_vw: init.v * init.ctl.cost_weight,
        }
    }
}

impl RoundPolicy for OracleEnergyPolicy {
    fn name(&self) -> &'static str {
        "Oracle-E"
    }

    fn wants_peek(&self) -> bool {
        true
    }

    fn plan(&mut self, ctx: &RoundContext<'_>, _rng: &mut Rng) -> RoundPlan {
        let n = ctx.devices.len();
        let mut f_hz = Vec::with_capacity(n);
        let mut p_w = Vec::with_capacity(n);
        let mut times = Vec::with_capacity(n);
        for i in 0..n {
            let d = &ctx.devices[i];
            // Backlogs are non-negative, so adding a zero cost_vw is
            // value-exact — the default plan is bitwise the old one.
            let price = ctx.backlogs[i] + self.cost_vw;
            let f = freq::optimal_freq(d, self.v, 1.0, price, ctx.k);
            let p = power::optimal_power(
                d,
                self.v,
                1.0,
                ctx.h[i],
                price,
                ctx.k,
                self.sys.noise_w,
            );
            times.push(crate::system::round_time_s(
                &self.sys,
                d,
                self.model_bits,
                ctx.h[i],
                f,
                p,
            ));
            f_hz.push(f);
            p_w.push(p);
        }
        let best = min_latency_pick(&times, ctx.next_h);
        let selection = sampling::fedavg_selection(vec![best; ctx.k], ctx.weights);
        let mut q_eff = vec![0.0; n];
        q_eff[best] = 1.0;
        RoundPlan {
            // Uniform q keeps the recorded P1 objective finite and
            // comparable (as for the oracle); the ledgers charge q_eff.
            controls: Controls {
                f_hz,
                p_w,
                q: vec![1.0 / n as f64; n],
            },
            stats: SolverStats::default(),
            selection,
            q_eff,
        }
    }
}

// ---------------------------------------------------------------------------
// Registry.
// ---------------------------------------------------------------------------

/// Everything a policy constructor may need.
pub struct PolicyInit<'a> {
    pub sys: &'a SystemConfig,
    pub ctl: &'a ControlConfig,
    /// Contextual-bandit knobs (`[bandit]`; read by the bandit and, for
    /// the shared softmax temperature/floor, by Conv-Aware — by value,
    /// the struct is five floats).
    pub bandit: BanditConfig,
    /// Thompson-sampling knobs (`[thompson]`; only Thompson reads them).
    pub thompson: ThompsonConfig,
    /// LinUCB knobs (`[linucb]`; only LinUCB reads them).
    pub linucb: LinUcbConfig,
    /// λ, already scaled (µ·λ₀ or explicit override).
    pub lambda: f64,
    /// V, already scaled (ν·V₀ or explicit override).
    pub v: f64,
    /// Model update size in bits.
    pub model_bits: f64,
    /// Master seed (policies derive sub-seeds from it).
    pub seed: u64,
}

impl PolicyInit<'_> {
    /// A fresh Algorithm 2 solver over this run's problem data.
    fn solver(&self) -> LroaSolver {
        LroaSolver::new(
            self.sys.clone(),
            self.ctl.clone(),
            self.lambda,
            self.v,
            self.model_bits,
        )
    }
}

type PolicyCtor = fn(&PolicyInit<'_>) -> Box<dyn RoundPolicy>;

/// One registry row: scheme id, canonical name, constructor.
pub struct PolicySpec {
    pub id: Policy,
    pub name: &'static str,
    pub build: PolicyCtor,
}

fn build_lroa(init: &PolicyInit<'_>) -> Box<dyn RoundPolicy> {
    Box::new(LroaPolicy::new(init))
}

fn build_uniform_dynamic(init: &PolicyInit<'_>) -> Box<dyn RoundPolicy> {
    Box::new(UniformDynamicPolicy::new(init))
}

fn build_uniform_static(init: &PolicyInit<'_>) -> Box<dyn RoundPolicy> {
    Box::new(UniformStaticPolicy::new(init))
}

fn build_divfl(init: &PolicyInit<'_>) -> Box<dyn RoundPolicy> {
    Box::new(DivFlPolicy::new(init))
}

fn build_greedy_channel(init: &PolicyInit<'_>) -> Box<dyn RoundPolicy> {
    Box::new(GreedyChannelPolicy::new(init))
}

fn build_round_robin(init: &PolicyInit<'_>) -> Box<dyn RoundPolicy> {
    Box::new(RoundRobinPolicy::new(init))
}

fn build_power_of_two(init: &PolicyInit<'_>) -> Box<dyn RoundPolicy> {
    Box::new(PowerOfTwoPolicy::new(init))
}

fn build_bandit(init: &PolicyInit<'_>) -> Box<dyn RoundPolicy> {
    Box::new(ContextualBanditPolicy::new(init))
}

fn build_thompson(init: &PolicyInit<'_>) -> Box<dyn RoundPolicy> {
    Box::new(ThompsonPolicy::new(init))
}

fn build_linucb(init: &PolicyInit<'_>) -> Box<dyn RoundPolicy> {
    Box::new(LinUcbPolicy::new(init))
}

fn build_conv_aware(init: &PolicyInit<'_>) -> Box<dyn RoundPolicy> {
    Box::new(ConvAwarePolicy::new(init))
}

fn build_oracle(init: &PolicyInit<'_>) -> Box<dyn RoundPolicy> {
    Box::new(OraclePolicy::new(init))
}

fn build_oracle_energy(init: &PolicyInit<'_>) -> Box<dyn RoundPolicy> {
    Box::new(OracleEnergyPolicy::new(init))
}

/// The name → constructor registry all dispatch goes through.
pub const REGISTRY: &[PolicySpec] = &[
    PolicySpec {
        id: Policy::Lroa,
        name: "LROA",
        build: build_lroa,
    },
    PolicySpec {
        id: Policy::UniformDynamic,
        name: "Uni-D",
        build: build_uniform_dynamic,
    },
    PolicySpec {
        id: Policy::UniformStatic,
        name: "Uni-S",
        build: build_uniform_static,
    },
    PolicySpec {
        id: Policy::DivFl,
        name: "DivFL",
        build: build_divfl,
    },
    PolicySpec {
        id: Policy::GreedyChannel,
        name: "Greedy",
        build: build_greedy_channel,
    },
    PolicySpec {
        id: Policy::RoundRobin,
        name: "RR",
        build: build_round_robin,
    },
    PolicySpec {
        id: Policy::PowerOfTwoChoices,
        name: "P2C",
        build: build_power_of_two,
    },
    PolicySpec {
        id: Policy::Bandit,
        name: "Bandit",
        build: build_bandit,
    },
    PolicySpec {
        id: Policy::Thompson,
        name: "Thompson",
        build: build_thompson,
    },
    PolicySpec {
        id: Policy::LinUcb,
        name: "LinUCB",
        build: build_linucb,
    },
    PolicySpec {
        id: Policy::ConvAware,
        name: "Conv-Aware",
        build: build_conv_aware,
    },
    PolicySpec {
        id: Policy::Oracle,
        name: "Oracle",
        build: build_oracle,
    },
    PolicySpec {
        id: Policy::OracleEnergy,
        name: "Oracle-E",
        build: build_oracle_energy,
    },
];

/// Build the registered policy for a config [`Policy`] id.
pub fn build(policy: Policy, init: &PolicyInit<'_>) -> Box<dyn RoundPolicy> {
    let spec = REGISTRY
        .iter()
        .find(|s| s.id == policy)
        .expect("every Policy variant is registered");
    (spec.build)(init)
}

/// Build a policy by name or alias.  The alias table lives in one place
/// — [`Policy::parse`] — so CLI, config files, and the registry can
/// never drift apart.
pub fn from_name(name: &str, init: &PolicyInit<'_>) -> Result<Box<dyn RoundPolicy>> {
    Ok(build(Policy::parse(name)?, init))
}

/// Canonical names of every registered policy, registry order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|s| s.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::system::Fleet;

    fn setup() -> (SystemConfig, ControlConfig, Fleet, Vec<f64>, Vec<f64>) {
        let sys = SystemConfig {
            num_devices: 12,
            ..SystemConfig::default()
        };
        let ctl = ControlConfig::default();
        let mut rng = Rng::new(9);
        let fleet = Fleet::generate(&sys, (50, 200), &mut rng);
        let h: Vec<f64> = (0..12).map(|_| rng.range(0.01, 0.5)).collect();
        let backlogs = vec![1.0; 12];
        (sys, ctl, fleet, h, backlogs)
    }

    #[test]
    fn registry_covers_every_policy_variant() {
        for policy in Policy::ALL {
            assert!(
                REGISTRY.iter().any(|s| s.id == policy),
                "{policy} missing from registry"
            );
        }
        assert_eq!(
            names(),
            vec![
                "LROA", "Uni-D", "Uni-S", "DivFL", "Greedy", "RR", "P2C", "Bandit",
                "Thompson", "LinUCB", "Conv-Aware", "Oracle", "Oracle-E"
            ]
        );
    }

    #[test]
    fn from_name_accepts_aliases_and_rejects_unknown() {
        let (sys, ctl, ..) = setup();
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 1,
        };
        for alias in [
            "lroa",
            "LROA",
            "uni-d",
            "Uni-S",
            "divfl",
            "uniform-dynamic",
            "greedy-channel",
            "round-robin",
            "p2c",
            "power-of-two-choices",
            "bandit",
            "contextual-bandit",
            "thompson",
            "ts",
            "thompson-sampling",
            "linucb",
            "lin-ucb",
            "conv-aware",
            "convaware",
            "conv",
            "oracle",
            "oracle-e",
            "oracle-energy",
        ] {
            assert!(from_name(alias, &init).is_ok(), "{alias}");
        }
        assert!(from_name("nope", &init).is_err());
    }

    #[test]
    fn every_policy_produces_a_feasible_plan() {
        let (sys, ctl, fleet, h, backlogs) = setup();
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let ids: Vec<usize> = (0..12).collect();
        for spec in REGISTRY {
            let mut policy = (spec.build)(&init);
            let mut rng = Rng::new(42);
            let ctx = RoundContext {
                t: 0,
                k: sys.k,
                devices: &fleet.devices,
                weights: fleet.weights(),
                ids: &ids,
                h: &h,
                backlogs: &backlogs,
                next_h: None,
            };
            let plan = policy.plan(&ctx, &mut rng);
            assert_eq!(policy.name(), spec.name);
            assert_eq!(plan.q_eff.len(), 12, "{}", spec.name);
            assert_eq!(plan.selection.members.len(), sys.k, "{}", spec.name);
            let sum_q: f64 = plan.q_eff.iter().sum();
            if spec.id == Policy::GreedyChannel {
                // 0/1 participation indicator over the K selected devices.
                assert_eq!(sum_q, sys.k as f64, "{}: sum q {sum_q}", spec.name);
                assert!(plan.q_eff.iter().all(|&q| q == 0.0 || q == 1.0));
            } else {
                assert!((sum_q - 1.0).abs() < 1e-6, "{}: sum q {sum_q}", spec.name);
            }
            for (i, d) in fleet.devices.iter().enumerate() {
                assert!(plan.controls.f_hz[i] >= d.f_min_hz - 1e-9);
                assert!(plan.controls.f_hz[i] <= d.f_max_hz + 1e-9);
                assert!(plan.controls.p_w[i] >= d.p_min_w - 1e-12);
                assert!(plan.controls.p_w[i] <= d.p_max_w + 1e-12);
            }
        }
    }

    #[test]
    fn baseline_policies_share_the_sampling_stream() {
        // Uni-D and Uni-S consume the RNG identically: same draws in,
        // same members out (the paper's shared-channel comparison needs
        // schemes to be swappable without perturbing the random stream).
        let (sys, ctl, fleet, h, backlogs) = setup();
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let ids: Vec<usize> = (0..12).collect();
        let ctx = RoundContext {
            t: 0,
            k: sys.k,
            devices: &fleet.devices,
            weights: fleet.weights(),
            ids: &ids,
            h: &h,
            backlogs: &backlogs,
            next_h: None,
        };
        let mut unid = build(Policy::UniformDynamic, &init);
        let mut unis = build(Policy::UniformStatic, &init);
        let mut rng_a = Rng::new(5);
        let mut rng_b = Rng::new(5);
        let plan_a = unid.plan(&ctx, &mut rng_a);
        let plan_b = unis.plan(&ctx, &mut rng_b);
        assert_eq!(plan_a.selection.members, plan_b.selection.members);
    }

    #[test]
    fn greedy_channel_picks_the_best_gains() {
        let (sys, ctl, fleet, mut h, backlogs) = setup();
        h[4] = 0.49;
        h[9] = 0.48; // the two best channels by construction
        for (i, v) in h.iter_mut().enumerate() {
            if i != 4 && i != 9 {
                *v = v.min(0.4);
            }
        }
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let ids: Vec<usize> = (0..12).collect();
        let ctx = RoundContext {
            t: 0,
            k: 2,
            devices: &fleet.devices,
            weights: fleet.weights(),
            ids: &ids,
            h: &h,
            backlogs: &backlogs,
            next_h: None,
        };
        let mut policy = build(Policy::GreedyChannel, &init);
        let plan = policy.plan(&ctx, &mut Rng::new(1));
        assert_eq!(plan.selection.members, vec![4, 9]);
        let s: f64 = plan.selection.coefs.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn round_robin_cycles_through_every_device() {
        let (sys, ctl, fleet, h, backlogs) = setup();
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let ids: Vec<usize> = (0..12).collect();
        let mut policy = build(Policy::RoundRobin, &init);
        let mut seen = std::collections::BTreeSet::new();
        let mut rng = Rng::new(1);
        for t in 0..6 {
            let ctx = RoundContext {
                t,
                k: 2,
                devices: &fleet.devices,
                weights: fleet.weights(),
                ids: &ids,
                h: &h,
                backlogs: &backlogs,
                next_h: None,
            };
            let plan = policy.plan(&ctx, &mut rng);
            assert_eq!(plan.selection.members.len(), 2);
            seen.extend(plan.selection.members.iter().copied());
        }
        assert_eq!(seen.len(), 12, "6 rounds × K=2 must cover all 12 devices");
    }

    #[test]
    fn oracle_achieves_the_per_round_latency_floor() {
        let (sys, ctl, fleet, h, backlogs) = setup();
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let ids: Vec<usize> = (0..12).collect();
        let ctx = RoundContext {
            t: 0,
            k: 2,
            devices: &fleet.devices,
            weights: fleet.weights(),
            ids: &ids,
            h: &h,
            backlogs: &backlogs,
            next_h: None,
        };
        let mut policy = build(Policy::Oracle, &init);
        assert!(policy.wants_peek());
        let plan = policy.plan(&ctx, &mut Rng::new(1));
        // All slots the same device, coefs aggregate to its plain delta.
        let best = plan.selection.members[0];
        assert!(plan.selection.members.iter().all(|&m| m == best));
        let s: f64 = plan.selection.coefs.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        // Full resources, and no device could have been faster.
        let t_best = crate::system::round_time_s(
            &sys,
            &fleet.devices[best],
            3.2e6,
            h[best],
            fleet.devices[best].f_max_hz,
            fleet.devices[best].p_max_w,
        );
        for (i, d) in fleet.devices.iter().enumerate() {
            assert_eq!(plan.controls.f_hz[i], d.f_max_hz);
            assert_eq!(plan.controls.p_w[i], d.p_max_w);
            let t_i = crate::system::round_time_s(&sys, d, 3.2e6, h[i], d.f_max_hz, d.p_max_w);
            assert!(t_best <= t_i, "device {i} beats the oracle's pick");
        }
    }

    #[test]
    fn oracle_foresight_breaks_exact_ties_toward_the_fading_channel() {
        // Two identical devices with identical gains this round: without
        // foresight the lower position wins; with next_h the one about
        // to fade is used first.
        let (sys, ctl, _, mut h, backlogs) = setup();
        // Fully homogeneous fleet (spread 0, equal data sizes), so equal
        // h means exactly equal latency.
        let mut rng = Rng::new(9);
        let fleet = Fleet::generate(&sys, (100, 100), &mut rng);
        h[3] = 0.2;
        h[7] = 0.2;
        for (i, v) in h.iter_mut().enumerate() {
            if i != 3 && i != 7 {
                *v = 0.05; // clearly slower
            }
        }
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let ids: Vec<usize> = (0..12).collect();
        let next_h: Vec<f64> = (0..12).map(|i| if i == 7 { 0.01 } else { 0.4 }).collect();
        let ctx_blind = RoundContext {
            t: 0,
            k: 2,
            devices: &fleet.devices,
            weights: fleet.weights(),
            ids: &ids,
            h: &h,
            backlogs: &backlogs,
            next_h: None,
        };
        let ctx_peek = RoundContext {
            t: 0,
            k: 2,
            devices: &fleet.devices,
            weights: fleet.weights(),
            ids: &ids,
            h: &h,
            backlogs: &backlogs,
            next_h: Some(&next_h),
        };
        let mut policy = build(Policy::Oracle, &init);
        let blind = policy.plan(&ctx_blind, &mut Rng::new(1));
        assert_eq!(blind.selection.members[0], 3, "position breaks blind ties");
        let peeked = policy.plan(&ctx_peek, &mut Rng::new(1));
        assert_eq!(
            peeked.selection.members[0], 7,
            "foresight uses the channel that is about to fade"
        );
    }

    #[test]
    fn p2c_marginals_drive_objective_and_queues() {
        let (sys, ctl, fleet, h, backlogs) = setup();
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let ids: Vec<usize> = (0..12).collect();
        let ctx = RoundContext {
            t: 0,
            k: 3,
            devices: &fleet.devices,
            weights: fleet.weights(),
            ids: &ids,
            h: &h,
            backlogs: &backlogs,
            next_h: None,
        };
        let mut policy = build(Policy::PowerOfTwoChoices, &init);
        assert!(!policy.wants_peek());
        let plan = policy.plan(&ctx, &mut Rng::new(9));
        let expect = crate::sampling::p2c_marginals(&h);
        assert_eq!(plan.controls.q, expect);
        assert_eq!(plan.q_eff, expect);
        assert_eq!(plan.selection.members.len(), 3);
        // Better channels carry strictly larger marginals.
        let mut idx: Vec<usize> = (0..12).collect();
        idx.sort_by(|&a, &b| h[a].partial_cmp(&h[b]).unwrap());
        for w in idx.windows(2) {
            assert!(plan.q_eff[w[0]] < plan.q_eff[w[1]]);
        }
    }

    #[test]
    fn round_robin_skips_unreachable_devices() {
        let (sys, ctl, fleet, h, _backlogs) = setup();
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        // Candidate set {1, 5, 7} out of 12: positions 0..3.
        let ids = vec![1usize, 5, 7];
        let sub_devices: Vec<_> = ids.iter().map(|&i| fleet.devices[i].clone()).collect();
        let w = vec![1.0 / 3.0; 3];
        let sub_h: Vec<f64> = ids.iter().map(|&i| h[i]).collect();
        let sub_b = vec![1.0; 3];
        let ctx = RoundContext {
            t: 0,
            k: 2,
            devices: &sub_devices,
            weights: &w,
            ids: &ids,
            h: &sub_h,
            backlogs: &sub_b,
            next_h: None,
        };
        let mut policy = build(Policy::RoundRobin, &init);
        let plan = policy.plan(&ctx, &mut Rng::new(1));
        // Cursor starts at 0: the nearest reachable ids are 1 and 5,
        // i.e. positions 0 and 1.
        assert_eq!(plan.selection.members, vec![0, 1]);
    }

    #[test]
    fn bandit_marginals_match_empirical_frequencies() {
        // The bandit's q_eff are its *exact* selection marginals: 1e5
        // independent draws from fresh policies at the same context must
        // reproduce them within 1% — the p2c contract, mirrored.
        let (sys, ctl, fleet, h, backlogs) = setup();
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let ids: Vec<usize> = (0..12).collect();
        let ctx = RoundContext {
            t: 0,
            k: 1,
            devices: &fleet.devices,
            weights: fleet.weights(),
            ids: &ids,
            h: &h,
            backlogs: &backlogs,
            next_h: None,
        };
        // Reference marginals from one fresh policy (the scores are a
        // pure function of the initial state + context, never of the rng).
        let reference = build(Policy::Bandit, &init).plan(&ctx, &mut Rng::new(1));
        let q = reference.q_eff.clone();
        assert_eq!(reference.controls.q, q, "marginals must drive the objective");
        assert!((q.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(q.iter().all(|&v| v > 0.0), "eps floor keeps marginals positive");
        // eq. (4) coefficients follow w/(K q) exactly.
        let w = fleet.weights();
        for (slot, &m) in reference.selection.members.iter().enumerate() {
            let expect = w[m] / (ctx.k as f64 * q[m]);
            assert!((reference.selection.coefs[slot] - expect).abs() < 1e-12);
        }

        let trials = 100_000;
        let mut counts = vec![0usize; 12];
        let mut rng = Rng::new(33);
        for _ in 0..trials {
            let plan = build(Policy::Bandit, &init).plan(&ctx, &mut rng);
            counts[plan.selection.members[0]] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let emp = c as f64 / trials as f64;
            assert!(
                (emp - q[i]).abs() < 0.01,
                "device {i}: empirical {emp} vs marginal {}",
                q[i]
            );
        }
    }

    #[test]
    fn bandit_learns_to_favor_the_fast_device() {
        // Homogeneous fleet, device 4 holds the best channel every
        // round: with rewards flowing back through observe_round the
        // bandit's marginal on device 4 must end up the largest.
        let (sys, ctl, _, mut h, backlogs) = setup();
        let mut rng = Rng::new(9);
        let fleet = crate::system::Fleet::generate(&sys, (100, 100), &mut rng);
        for (i, v) in h.iter_mut().enumerate() {
            *v = if i == 4 { 0.49 } else { 0.05 };
        }
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig {
                ucb_c: 0.1,
                temp: 0.1,
                ..BanditConfig::default()
            },
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let ids: Vec<usize> = (0..12).collect();
        let mut policy = build(Policy::Bandit, &init);
        let mut sample_rng = Rng::new(5);
        let mut last_q = Vec::new();
        for t in 0..80 {
            let ctx = RoundContext {
                t,
                k: 1,
                devices: &fleet.devices,
                weights: fleet.weights(),
                ids: &ids,
                h: &h,
                backlogs: &backlogs,
                next_h: None,
            };
            let plan = policy.plan(&ctx, &mut sample_rng);
            let costs = crate::system::RoundCosts::evaluate(
                &sys,
                &fleet.devices,
                3.2e6,
                &h,
                &plan.controls.f_hz,
                &plan.controls.p_w,
            );
            policy.observe_round(&plan.selection.unique_members(), &costs);
            last_q = plan.q_eff;
        }
        let best = last_q
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(best, 4, "bandit should converge on the best channel: {last_q:?}");
        assert!(
            last_q[4] > 1.5 / 12.0,
            "marginal on the learned arm should clear uniform: {}",
            last_q[4]
        );
    }

    #[test]
    fn oracle_e_runs_flat_out_on_empty_queues_and_throttles_under_pressure() {
        let (sys, ctl, fleet, h, _) = setup();
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let ids: Vec<usize> = (0..12).collect();
        let mut policy = build(Policy::OracleEnergy, &init);
        assert!(policy.wants_peek());

        // Empty queues: energy is free, so the plan coincides with the
        // unconstrained oracle (full resources, same pick).
        let zeros = vec![0.0; 12];
        let ctx = RoundContext {
            t: 0,
            k: 2,
            devices: &fleet.devices,
            weights: fleet.weights(),
            ids: &ids,
            h: &h,
            backlogs: &zeros,
            next_h: None,
        };
        let plan = policy.plan(&ctx, &mut Rng::new(1));
        for (i, d) in fleet.devices.iter().enumerate() {
            assert_eq!(plan.controls.f_hz[i], d.f_max_hz);
            assert_eq!(plan.controls.p_w[i], d.p_max_w);
        }
        let oracle_plan = build(Policy::Oracle, &init).plan(&ctx, &mut Rng::new(1));
        assert_eq!(plan.selection.members, oracle_plan.selection.members);
        let s: f64 = plan.selection.coefs.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert_eq!(plan.q_eff.iter().sum::<f64>(), 1.0, "0/1 indicator on one device");

        // Crushing backlogs: the Theorem 2/3 kernels saturate at the
        // resource floors — the budget constraint visibly bites.
        let heavy = vec![1e12; 12];
        let ctx = RoundContext {
            t: 1,
            k: 2,
            devices: &fleet.devices,
            weights: fleet.weights(),
            ids: &ids,
            h: &h,
            backlogs: &heavy,
            next_h: None,
        };
        let plan = policy.plan(&ctx, &mut Rng::new(1));
        for (i, d) in fleet.devices.iter().enumerate() {
            assert_eq!(plan.controls.f_hz[i], d.f_min_hz);
            assert_eq!(plan.controls.p_w[i], d.p_min_w);
        }
    }

    #[test]
    fn oracle_e_never_beats_the_unconstrained_oracle_per_round() {
        // Pointwise budget dominance: under any backlog vector the
        // energy-feasible anchor's makespan is at least the oracle's
        // floor — the theorem behind `regret_budget >= 0`.
        let (sys, ctl, fleet, h, _) = setup();
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let ids: Vec<usize> = (0..12).collect();
        let mut oracle_e = build(Policy::OracleEnergy, &init);
        let mut rng = Rng::new(13);
        for trial in 0..20 {
            // Wide backlog range: some trials leave the kernels at the
            // full-resource corner, others throttle all the way to the
            // floors — the bound must hold across the whole spectrum.
            let backlogs: Vec<f64> = (0..12).map(|_| rng.range(0.0, 1e7)).collect();
            let ctx = RoundContext {
                t: trial,
                k: 2,
                devices: &fleet.devices,
                weights: fleet.weights(),
                ids: &ids,
                h: &h,
                backlogs: &backlogs,
                next_h: None,
            };
            let plan = oracle_e.plan(&ctx, &mut Rng::new(1));
            let chosen = plan.selection.members[0];
            let t_oe = crate::system::round_time_s(
                &sys,
                &fleet.devices[chosen],
                3.2e6,
                h[chosen],
                plan.controls.f_hz[chosen],
                plan.controls.p_w[chosen],
            );
            let t_o = fleet
                .devices
                .iter()
                .enumerate()
                .map(|(i, d)| {
                    crate::system::round_time_s(&sys, d, 3.2e6, h[i], d.f_max_hz, d.p_max_w)
                })
                .fold(f64::INFINITY, f64::min);
            assert!(
                t_oe >= t_o - 1e-12,
                "trial {trial}: oracle-e {t_oe} beat the latency floor {t_o}"
            );
        }
    }

    #[test]
    fn bandit_reward_survives_an_adversarially_degraded_channel() {
        // A zero, denormal, infinite, or NaN modeled latency must never
        // poison the reward statistics: every reward stays finite and in
        // [0, 1], and the next plan still emits a valid distribution.
        let (sys, ctl, fleet, h, backlogs) = setup();
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let ids: Vec<usize> = (0..12).collect();
        let ctx = RoundContext {
            t: 0,
            k: 2,
            devices: &fleet.devices,
            weights: fleet.weights(),
            ids: &ids,
            h: &h,
            backlogs: &backlogs,
            next_h: None,
        };
        for policy_id in [Policy::Bandit, Policy::Thompson, Policy::LinUcb] {
            let mut policy = build(policy_id, &init);
            let mut rng = Rng::new(3);
            policy.plan(&ctx, &mut rng);
            // Degenerate round: device 0 collapsed to zero latency,
            // device 1 is NaN, device 2 unreachable, device 3 normal.
            let mut time_s = vec![1.0; 12];
            time_s[0] = 0.0;
            time_s[1] = f64::NAN;
            time_s[2] = f64::INFINITY;
            let costs = RoundCosts {
                time_s,
                energy_j: vec![0.1; 12],
                ..RoundCosts::default()
            };
            policy.observe_round(&[0, 1, 2, 3], &costs);
            // An all-garbage round (nothing finite) is skipped outright.
            let garbage = RoundCosts {
                time_s: vec![f64::NAN; 12],
                energy_j: vec![0.1; 12],
                ..RoundCosts::default()
            };
            policy.observe_round(&[0, 1], &garbage);
            let plan = policy.plan(&ctx, &mut rng);
            assert!(
                plan.q_eff.iter().all(|q| q.is_finite() && *q > 0.0),
                "{policy_id}: degenerate costs leaked into the marginals: {:?}",
                plan.q_eff
            );
            assert!((plan.q_eff.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
        // The helper contracts directly.
        assert_eq!(relative_speed(1.0, f64::NAN), 0.0);
        assert_eq!(relative_speed(1.0, f64::INFINITY), 0.0);
        assert_eq!(relative_speed(LATENCY_FLOOR_S, 0.0), 1.0);
        assert!(reward_baseline(&[0], &RoundCosts {
            time_s: vec![f64::NAN],
            ..RoundCosts::default()
        })
        .is_none());
    }

    #[test]
    fn thompson_marginals_match_empirical_frequencies() {
        // Thompson's q_eff are exact selection marginals too: the
        // posterior draws come from the policy-owned rng (a pure function
        // of seed + history), so fresh policies at the same context plan
        // identical marginals, and 1e5 shared-stream draws must reproduce
        // them within 1% — the bandit contract, mirrored.
        let (sys, ctl, fleet, h, backlogs) = setup();
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let ids: Vec<usize> = (0..12).collect();
        let ctx = RoundContext {
            t: 0,
            k: 1,
            devices: &fleet.devices,
            weights: fleet.weights(),
            ids: &ids,
            h: &h,
            backlogs: &backlogs,
            next_h: None,
        };
        let reference = build(Policy::Thompson, &init).plan(&ctx, &mut Rng::new(1));
        let q = reference.q_eff.clone();
        assert_eq!(reference.controls.q, q, "marginals must drive the objective");
        assert!((q.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(q.iter().all(|&v| v > 0.0), "eps floor keeps marginals positive");
        let w = fleet.weights();
        for (slot, &m) in reference.selection.members.iter().enumerate() {
            let expect = w[m] / (ctx.k as f64 * q[m]);
            assert!((reference.selection.coefs[slot] - expect).abs() < 1e-12);
        }

        let trials = 100_000;
        let mut counts = vec![0usize; 12];
        let mut rng = Rng::new(33);
        for _ in 0..trials {
            let plan = build(Policy::Thompson, &init).plan(&ctx, &mut rng);
            assert_eq!(plan.q_eff, q, "fresh policy, same seed, same posterior draws");
            counts[plan.selection.members[0]] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let emp = c as f64 / trials as f64;
            assert!(
                (emp - q[i]).abs() < 0.01,
                "device {i}: empirical {emp} vs marginal {}",
                q[i]
            );
        }
    }

    #[test]
    fn linucb_sherman_morrison_matches_direct_solve() {
        // Drive the rank-1 update path with a known (x, r) sequence and
        // check A⁻¹ and θ against the directly accumulated design matrix
        // solved by Gaussian elimination.
        let (sys, ctl, ..) = setup();
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let mut policy = LinUcbPolicy::new(&init);
        let ridge = init.linucb.ridge;
        let xs: [[f64; 3]; 6] = [
            [0.2, 0.7, 0.5],
            [0.9, 0.1, 0.3],
            [0.4, 0.4, 0.8],
            [0.6, 0.2, 0.1],
            [0.3, 0.9, 0.9],
            [0.8, 0.5, 0.2],
        ];
        let rewards = [0.8, 0.3, 0.6, 0.9, 0.2, 0.7];
        let mut a = [[0.0f64; 3]; 3];
        for i in 0..3 {
            a[i][i] = ridge;
        }
        let mut b = [0.0f64; 3];
        for (x, &r) in xs.iter().zip(&rewards) {
            // Route the update through observe_round: device 0 selected
            // with context x; device 1 is the baseline (time 1.0), and
            // device 0's latency 1/r makes the realized reward exactly r.
            policy.last_x[..3].copy_from_slice(x);
            policy.ctx_state.last_candidates = vec![0, 1];
            let costs = RoundCosts {
                time_s: vec![1.0 / r, 1.0],
                ..RoundCosts::default()
            };
            policy.observe_round(&[0], &costs);
            for i in 0..3 {
                for j in 0..3 {
                    a[i][j] += x[i] * x[j];
                }
            }
            for i in 0..3 {
                b[i] += r * x[i];
            }
        }
        // A · A⁻¹ ≈ I.
        for i in 0..3 {
            for j in 0..3 {
                let mut v = 0.0;
                for l in 0..3 {
                    v += a[i][l] * policy.a_inv[l * 3 + j];
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (v - expect).abs() < 1e-9,
                    "(A·A⁻¹)[{i}][{j}] = {v}, expected {expect}"
                );
            }
        }
        // θ from Sherman–Morrison state vs direct Gaussian elimination.
        let theta_sm = policy.a_inv_mul(&policy.b.clone());
        let mut m = [[0.0f64; 4]; 3];
        for i in 0..3 {
            for j in 0..3 {
                m[i][j] = a[i][j];
            }
            m[i][3] = b[i];
        }
        for col in 0..3 {
            let piv = (col..3)
                .max_by(|&x, &y| m[x][col].abs().partial_cmp(&m[y][col].abs()).unwrap())
                .unwrap();
            m.swap(col, piv);
            for row in 0..3 {
                if row != col {
                    let f = m[row][col] / m[col][col];
                    for j in col..4 {
                        m[row][j] -= f * m[col][j];
                    }
                }
            }
        }
        for i in 0..3 {
            let direct = m[i][3] / m[i][i];
            assert!(
                (theta_sm[i] - direct).abs() < 1e-9,
                "theta[{i}]: Sherman–Morrison {} vs direct {direct}",
                theta_sm[i]
            );
        }
        // The policy's own b must match the direct accumulation.
        for i in 0..3 {
            assert!((policy.b[i] - b[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn conv_aware_prefers_stale_high_norm_clients() {
        let (sys, ctl, fleet, h, backlogs) = setup();
        let init = PolicyInit {
            sys: &sys,
            ctl: &ctl,
            bandit: BanditConfig::default(),
            thompson: ThompsonConfig::default(),
            linucb: LinUcbConfig::default(),
            lambda: 1.0,
            v: 1e4,
            model_bits: 3.2e6,
            seed: 7,
        };
        let ids: Vec<usize> = (0..12).collect();
        let ctx = RoundContext {
            t: 0,
            k: 2,
            devices: &fleet.devices,
            weights: fleet.weights(),
            ids: &ids,
            h: &h,
            backlogs: &backlogs,
            next_h: None,
        };
        let mut policy = build(Policy::ConvAware, &init);
        let mut rng = Rng::new(11);
        // Cold start: no norms, no history — pure uniform.
        let plan = policy.plan(&ctx, &mut rng);
        for &q in &plan.q_eff {
            assert!((q - 1.0 / 12.0).abs() < 1e-12, "cold start is uniform: {q}");
        }
        // Everyone but device 5 participated this round; device 5 also
        // showed the largest update when it last ran.
        let picked: Vec<usize> = (0..12).filter(|&g| g != 5).collect();
        let costs = RoundCosts {
            time_s: vec![1.0; 12],
            ..RoundCosts::default()
        };
        policy.observe_round(&picked, &costs);
        for &g in &picked {
            policy.observe_update(g, &[0.1, 0.1]);
        }
        policy.observe_update(5, &[5.0, 5.0]);
        let plan = policy.plan(&ctx, &mut rng);
        let best = plan
            .q_eff
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(best, 5, "stale + high-norm device must dominate: {:?}", plan.q_eff);
        // Staleness alone also separates: device 5 never selected, so
        // even with equal norms its priority is double the others'.
        let mut age_only = build(Policy::ConvAware, &init);
        age_only.plan(&ctx, &mut rng);
        age_only.observe_round(&picked, &costs);
        let plan = age_only.plan(&ctx, &mut rng);
        assert!(
            plan.q_eff[5] > plan.q_eff[0],
            "pure staleness must favor the unpicked device: {:?}",
            plan.q_eff
        );
    }
}
