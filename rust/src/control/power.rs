//! Theorem 3: optimal transmit power (sub-problem P2.1.2).
//!
//! With `x = h p / N₀`, the per-device P2.1.2 objective
//! `Ω₃ (x + A₁) / log₂(1+x)` is convex on `x > 0` (paper, Appendix E) and
//! its stationary point solves
//!
//! `ln(1+x) = (x + A₁) / (1 + x)`,
//!
//! i.e. the root of the strictly increasing `g(x) = (1+x)·ln(1+x) − x − A₁`
//! (`g(0) = −A₁ < 0`, `g'(x) = ln(1+x) > 0`), which we bracket and
//! bisect to machine precision, then clip to `[p_min, p_max]`.

use crate::system::{selection_probability, Device, FleetSoA};

/// `A₁ = V q h / (Q s N₀)` — the latency/energy price ratio of Theorem 3.
#[inline]
pub fn a1(v: f64, q_n: f64, h: f64, queue: f64, k: usize, noise_w: f64) -> f64 {
    let sel = selection_probability(q_n, k);
    let denom = queue * sel * noise_w;
    if denom <= 0.0 {
        f64::INFINITY
    } else {
        v * q_n * h / denom
    }
}

/// `g(x) = (1+x) ln(1+x) − x − A₁`, whose unique positive root is the
/// stationary SNR `x* = h p' / N₀`.
#[inline]
pub fn g(x: f64, a1: f64) -> f64 {
    (1.0 + x) * (1.0 + x).ln() - x - a1
}

/// Solve `g(x) = 0` for `x > 0` by bracket + bisection.
pub fn solve_snr(a1_val: f64) -> f64 {
    if !a1_val.is_finite() {
        return f64::INFINITY;
    }
    if a1_val <= 0.0 {
        return 0.0;
    }
    // Bracket: g is increasing; expand hi until positive.
    let mut lo = 0.0f64;
    let mut hi = 1.0f64;
    while g(hi, a1_val) < 0.0 {
        hi *= 2.0;
        if hi > 1e30 {
            return hi;
        }
    }
    // Bisect to relative precision 1e-12 — the SNR only feeds a clipped
    // power decision, so nanowatt-exactness buys nothing (perf log:
    // early-exit cut Theorem-3 solve time ~3x vs a fixed 200 steps).
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if mid <= lo || mid >= hi || (hi - lo) <= 1e-12 * hi.max(1.0) {
            break;
        }
        if g(mid, a1_val) < 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Theorem 3 solution for one device.
#[inline]
pub fn optimal_power(dev: &Device, v: f64, q_n: f64, h: f64, queue: f64, k: usize, noise_w: f64) -> f64 {
    let a = a1(v, q_n, h, queue, k, noise_w);
    if !a.is_finite() {
        // Empty queue: energy is free, minimize latency -> p_max.
        return dev.p_max_w;
    }
    let x = solve_snr(a);
    let p = x * noise_w / h;
    p.clamp(dev.p_min_w, dev.p_max_w)
}

/// Theorem 3 for the whole fleet.
#[allow(clippy::too_many_arguments)]
pub fn solve_powers(
    devices: &[Device],
    v: f64,
    q: &[f64],
    h: &[f64],
    queues: &[f64],
    k: usize,
    noise_w: f64,
    out: &mut Vec<f64>,
) {
    out.clear();
    out.extend(devices.iter().enumerate().map(|(n, dev)| {
        optimal_power(dev, v, q[n], h[n], queues[n], k, noise_w)
    }));
}

/// Theorem 3 over the SoA fleet view — the solver hot-loop variant.
/// Same per-device arithmetic as [`solve_powers`] (pinned bitwise by
/// `soa_solve_matches_aos`), reading contiguous power-bound slices.
#[allow(clippy::too_many_arguments)]
pub fn solve_powers_soa(
    soa: &FleetSoA,
    v: f64,
    q: &[f64],
    h: &[f64],
    queues: &[f64],
    k: usize,
    noise_w: f64,
    out: &mut Vec<f64>,
) {
    let n = soa.len();
    assert!(q.len() == n && h.len() == n && queues.len() == n);
    out.clear();
    for i in 0..n {
        let a = a1(v, q[i], h[i], queues[i], k, noise_w);
        if !a.is_finite() {
            // Empty queue: energy is free, minimize latency -> p_max.
            out.push(soa.p_max_w[i]);
        } else {
            let x = solve_snr(a);
            let p = x * noise_w / h[i];
            out.push(p.clamp(soa.p_min_w[i], soa.p_max_w[i]));
        }
    }
}

/// Per-device P2.1.2 objective (for tests / diagnostics):
/// `MK (V q + Q s p) / (B log₂(1 + h p / N₀))`.
#[allow(clippy::too_many_arguments)]
pub fn p212_objective(
    model_bits: f64,
    k: usize,
    bandwidth_hz: f64,
    noise_w: f64,
    v: f64,
    q_n: f64,
    h: f64,
    queue: f64,
    p_w: f64,
) -> f64 {
    let sel = selection_probability(q_n, k);
    let rate_term = (1.0 + h * p_w / noise_w).log2();
    model_bits * k as f64 * (v * q_n + queue * sel * p_w) / (bandwidth_hz * rate_term)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> Device {
        Device {
            id: 0,
            data_size: 200,
            cycles_per_sample: 3.0e9,
            alpha: 2e-28,
            f_min_hz: 1.0e9,
            f_max_hz: 2.0e9,
            p_min_w: 0.001,
            p_max_w: 0.1,
            energy_budget_j: 15.0,
        }
    }

    #[test]
    fn root_satisfies_equation() {
        for &a in &[0.01, 0.5, 1.0, 3.0, 10.0, 100.0] {
            let x = solve_snr(a);
            assert!(x > 0.0);
            // ln(1+x) = (x + A1)/(1 + x)
            let lhs = (1.0 + x).ln();
            let rhs = (x + a) / (1.0 + x);
            assert!((lhs - rhs).abs() < 1e-9, "a={a}: lhs={lhs} rhs={rhs}");
        }
    }

    #[test]
    fn root_is_monotone_in_a1() {
        let xs: Vec<f64> = [0.1, 1.0, 10.0, 100.0].iter().map(|&a| solve_snr(a)).collect();
        for w in xs.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn stationary_point_minimizes_objective_numerically() {
        let d = dev();
        let (m, k, b, n0) = (3.58e6, 2usize, 1e6, 0.01);
        let (v, qn, h) = (1e4, 0.05, 0.1);
        // Find a queue level putting p* strictly inside the box.
        let mut queue = 1.0;
        let mut p_star = optimal_power(&d, v, qn, h, queue, k, n0);
        for _ in 0..80 {
            if p_star > d.p_min_w * 1.05 && p_star < d.p_max_w * 0.95 {
                break;
            }
            queue *= if p_star >= d.p_max_w * 0.95 { 2.0 } else { 0.5 };
            p_star = optimal_power(&d, v, qn, h, queue, k, n0);
        }
        assert!(
            p_star > d.p_min_w * 1.05 && p_star < d.p_max_w * 0.95,
            "no interior point found, p*={p_star}"
        );
        let obj_star = p212_objective(m, k, b, n0, v, qn, h, queue, p_star);
        let mut best = f64::INFINITY;
        for i in 1..=5000 {
            let p = d.p_min_w + (d.p_max_w - d.p_min_w) * i as f64 / 5000.0;
            best = best.min(p212_objective(m, k, b, n0, v, qn, h, queue, p));
        }
        assert!(obj_star <= best + best.abs() * 1e-6, "p2.1.2: {obj_star} vs grid {best}");
    }

    #[test]
    fn empty_queue_sends_at_p_max() {
        let d = dev();
        assert_eq!(optimal_power(&d, 1e5, 0.1, 0.1, 0.0, 2, 0.01), d.p_max_w);
    }

    #[test]
    fn heavy_queue_pressure_throttles_power() {
        let d = dev();
        let p_light = optimal_power(&d, 1e5, 0.05, 0.1, 0.1, 2, 0.01);
        let p_heavy = optimal_power(&d, 1e5, 0.05, 0.1, 1e12, 2, 0.01);
        assert!(p_heavy <= p_light);
        assert_eq!(p_heavy, d.p_min_w); // saturates at the lower bound
    }

    #[test]
    fn better_channel_changes_a1_proportionally() {
        let v = 2.0;
        let a_good = a1(v, 0.1, 0.5, 3.0, 2, 0.01);
        let a_bad = a1(v, 0.1, 0.01, 3.0, 2, 0.01);
        assert!((a_good / a_bad - 50.0).abs() < 1e-9);
    }

    #[test]
    fn fleet_solve_matches_per_device() {
        let devs: Vec<Device> = (0..4).map(|id| Device { id, ..dev() }).collect();
        let q = [0.1, 0.2, 0.3, 0.4];
        let h = [0.05, 0.1, 0.2, 0.4];
        let queues = [0.0, 2.0, 5.0, 50.0];
        let mut out = Vec::new();
        solve_powers(&devs, 1e4, &q, &h, &queues, 2, 0.01, &mut out);
        for i in 0..4 {
            assert_eq!(
                out[i],
                optimal_power(&devs[i], 1e4, q[i], h[i], queues[i], 2, 0.01)
            );
        }
    }

    #[test]
    fn soa_solve_matches_aos() {
        let devs: Vec<Device> = (0..4).map(|id| Device { id, ..dev() }).collect();
        let weights = [0.25; 4];
        let q = [0.1, 0.2, 0.3, 0.4];
        let h = [0.05, 0.1, 0.2, 0.4];
        let queues = [0.0, 2.0, 5.0, 50.0];
        let mut soa = crate::system::FleetSoA::new();
        soa.fill(&devs, &weights, 2, 1e4, 1.0);
        let (mut aos, mut via_soa) = (Vec::new(), Vec::new());
        solve_powers(&devs, 1e4, &q, &h, &queues, 2, 0.01, &mut aos);
        solve_powers_soa(&soa, 1e4, &q, &h, &queues, 2, 0.01, &mut via_soa);
        assert_eq!(aos, via_soa, "Theorem 3 SoA port must be bitwise identical");
    }
}
