//! Virtual energy-consumption queues — eqs. (19)–(20).
//!
//! `Q_n^{t+1} = max(Q_n^t + a_n^t, 0)` with
//! `a_n^t = (1 - (1-q_n^t)^K) E_n^t - Ē_n`.  Queue stability implies the
//! time-average energy constraint (16); the drift-plus-penalty solver
//! consumes the backlogs as energy prices.

use crate::system::selection_probability;

/// Per-device virtual queue state.
#[derive(Clone, Debug)]
pub struct VirtualQueues {
    q: Vec<f64>,
    budgets: Vec<f64>,
}

impl VirtualQueues {
    /// `Q^0 = 0` (LROA initialization).
    pub fn new(budgets: Vec<f64>) -> Self {
        Self {
            q: vec![0.0; budgets.len()],
            budgets,
        }
    }

    pub fn backlogs(&self) -> &[f64] {
        &self.q
    }

    /// The per-device budgets `Ē_n` the arrivals are measured against
    /// (read by context-driven schedulers and the invariant suite).
    pub fn budgets(&self) -> &[f64] {
        &self.budgets
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Eq. (20): the expected-energy residual for one device.
    pub fn arrival(&self, n: usize, q_n: f64, k: usize, energy_j: f64) -> f64 {
        selection_probability(q_n, k) * energy_j - self.budgets[n]
    }

    /// Eq. (19): advance all queues given this round's controls and costs.
    ///
    /// `energy_j[n]` is `E_n^t` under the round's `(f, p)` and channel —
    /// the *expected* draw enters the queue (the paper's `a_n^t` uses the
    /// selection probability, not the realized selection).
    pub fn update(&mut self, q_probs: &[f64], k: usize, energy_j: &[f64]) {
        debug_assert_eq!(q_probs.len(), self.q.len());
        debug_assert_eq!(energy_j.len(), self.q.len());
        for n in 0..self.q.len() {
            let a = self.arrival(n, q_probs[n], k, energy_j[n]);
            self.q[n] = (self.q[n] + a).max(0.0);
        }
    }

    /// Eq. (19) restricted to this round's candidate set `N^t`
    /// (`candidates`: sorted global ids): a device outside `N^t` is
    /// frozen — it neither accrues the `(1-(1-q)^K)E` charge (it cannot
    /// be selected) nor the `-Ē_n` budget credit (its budget must not
    /// replenish while it is offline).  [`VirtualQueues::update`] is the
    /// degenerate full-candidacy case, and stays as the
    /// `queue_gate_offline = false` parity anchor.
    pub fn update_candidates(
        &mut self,
        candidates: &[usize],
        q_probs: &[f64],
        k: usize,
        energy_j: &[f64],
    ) {
        debug_assert_eq!(q_probs.len(), self.q.len());
        debug_assert_eq!(energy_j.len(), self.q.len());
        for &n in candidates {
            let a = self.arrival(n, q_probs[n], k, energy_j[n]);
            self.q[n] = (self.q[n] + a).max(0.0);
        }
    }

    /// Quadratic Lyapunov function (21): `L = ½ Σ Q_n²`.
    pub fn lyapunov(&self) -> f64 {
        0.5 * self.q.iter().map(|x| x * x).sum::<f64>()
    }

    pub fn mean_backlog(&self) -> f64 {
        if self.q.is_empty() {
            0.0
        } else {
            self.q.iter().sum::<f64>() / self.q.len() as f64
        }
    }

    pub fn max_backlog(&self) -> f64 {
        self.q.iter().copied().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_empty() {
        let q = VirtualQueues::new(vec![5.0; 4]);
        assert_eq!(q.backlogs(), &[0.0; 4]);
        assert_eq!(q.budgets(), &[5.0; 4]);
        assert_eq!(q.lyapunov(), 0.0);
    }

    #[test]
    fn arrival_matches_eq20() {
        let q = VirtualQueues::new(vec![5.0, 15.0]);
        // sel(0.5, 2) = 0.75; a = 0.75*10 - 5 = 2.5
        assert!((q.arrival(0, 0.5, 2, 10.0) - 2.5).abs() < 1e-12);
        // under budget: a = 0.75*10 - 15 = -7.5
        assert!((q.arrival(1, 0.5, 2, 10.0) + 7.5).abs() < 1e-12);
    }

    #[test]
    fn queue_never_negative() {
        let mut q = VirtualQueues::new(vec![100.0; 3]);
        q.update(&[0.1, 0.1, 0.1], 2, &[1.0, 1.0, 1.0]); // far under budget
        assert_eq!(q.backlogs(), &[0.0; 3]);
    }

    #[test]
    fn queue_grows_when_over_budget() {
        let mut q = VirtualQueues::new(vec![1.0; 2]);
        for _ in 0..5 {
            q.update(&[0.9, 0.9], 2, &[10.0, 10.0]);
        }
        // a = (1-0.01)*10 - 1 = 8.9 per round
        for &b in q.backlogs() {
            assert!((b - 5.0 * 8.9).abs() < 1e-9, "backlog {b}");
        }
        assert!(q.lyapunov() > 0.0);
        assert!((q.mean_backlog() - 44.5).abs() < 1e-9);
        assert!((q.max_backlog() - 44.5).abs() < 1e-9);
    }

    #[test]
    fn gated_update_freezes_non_candidates() {
        // Device 1 is offline: gated, its backlog is flat — no charge,
        // no budget credit.  Ungated (the old semantics), it would drain
        // by Ē every round.
        let mut gated = VirtualQueues::new(vec![1.0; 2]);
        let mut ungated = VirtualQueues::new(vec![1.0; 2]);
        // Build up backlog on both devices first (full candidacy).
        for _ in 0..3 {
            gated.update_candidates(&[0, 1], &[0.9, 0.9], 2, &[10.0, 10.0]);
            ungated.update(&[0.9, 0.9], 2, &[10.0, 10.0]);
        }
        assert_eq!(gated.backlogs(), ungated.backlogs());
        let frozen = gated.backlogs()[1];
        // Device 1 leaves the candidate set (q_prob 0 — cannot be drawn).
        for _ in 0..4 {
            gated.update_candidates(&[0], &[0.9, 0.0], 2, &[10.0, 10.0]);
            ungated.update(&[0.9, 0.0], 2, &[10.0, 10.0]);
        }
        assert_eq!(gated.backlogs()[1], frozen, "offline backlog must be flat");
        // Old semantics: -Ē per offline round.
        assert!((ungated.backlogs()[1] - (frozen - 4.0)).abs() < 1e-9);
        // The online device advances identically under both.
        assert_eq!(gated.backlogs()[0], ungated.backlogs()[0]);
    }

    #[test]
    fn gated_update_with_full_candidacy_matches_update() {
        let mut a = VirtualQueues::new(vec![1.0; 3]);
        let mut b = VirtualQueues::new(vec![1.0; 3]);
        for t in 0..10 {
            let q = [0.2 + 0.05 * t as f64, 0.3, 0.1];
            a.update_candidates(&[0, 1, 2], &q, 2, &[5.0, 6.0, 7.0]);
            b.update(&q, 2, &[5.0, 6.0, 7.0]);
        }
        assert_eq!(a.backlogs(), b.backlogs());
    }

    #[test]
    fn stable_queue_tracks_budget() {
        // If expected energy exactly equals budget, backlog stays at 0.
        let mut q = VirtualQueues::new(vec![7.5; 1]);
        for _ in 0..100 {
            q.update(&[0.5], 2, &[10.0]); // sel=0.75, 0.75*10 = 7.5 = budget
        }
        assert!(q.backlogs()[0].abs() < 1e-9);
    }
}
