//! Uni-S baseline: static resource allocation (paper §VII-A).
//!
//! "the communication power operates at the mid-level and computation
//! consumes the remaining energy": `p = (p_min+p_max)/2` and `f` solves
//!
//! `[E α c D f²/2 + p·M·K / (B log₂(1+hp/N₀))] · (1-(1-1/N)^K) = Ē`,
//!
//! projected to `[f_min, f_max]` when the root falls outside.

use super::lroa::Controls;
use crate::config::SystemConfig;
use crate::system::{selection_probability, upload_time_s, Device};

/// Solve the Uni-S energy-balance frequency for one device, given the
/// per-round selection probability the balance targets.
fn static_freq_with_sel(
    cfg: &SystemConfig,
    dev: &Device,
    model_bits: f64,
    h: f64,
    p_w: f64,
    sel: f64,
) -> f64 {
    let comm_j = p_w * upload_time_s(cfg, model_bits, h, p_w);
    let ecd = dev.cycles_per_round(cfg.local_epochs);
    // E α c D f² / 2 = Ē/sel − comm  ⇒  f = sqrt(2 (Ē/sel − comm) / (α E c D))
    let residual = dev.energy_budget_j / sel - comm_j;
    if residual <= 0.0 {
        return dev.f_min_hz; // comm alone exceeds the budget: floor.
    }
    (2.0 * residual / (dev.alpha * ecd)).sqrt().clamp(dev.f_min_hz, dev.f_max_hz)
}

/// Solve the Uni-S energy-balance frequency for one device under the
/// full-fleet uniform sampling probability `1/N`.
pub fn static_freq(cfg: &SystemConfig, dev: &Device, model_bits: f64, h: f64, p_w: f64) -> f64 {
    let sel = selection_probability(1.0 / cfg.num_devices as f64, cfg.k);
    static_freq_with_sel(cfg, dev, model_bits, h, p_w, sel)
}

/// Uni-S controls over a candidate set (uniform sampling).
///
/// The energy balance targets the *same* selection probability as the
/// returned `q = 1/n` over `devices` — which is the whole fleet in the
/// paper's setting, and the reachable set `N^t` under a dynamic
/// availability environment (so the balance stays consistent with the
/// actual per-round sampling odds).
pub fn solve_static(cfg: &SystemConfig, devices: &[Device], model_bits: f64, h: &[f64]) -> Controls {
    let n = devices.len();
    let sel = selection_probability(1.0 / n as f64, cfg.k);
    let p_w: Vec<f64> = devices.iter().map(|d| 0.5 * (d.p_min_w + d.p_max_w)).collect();
    let f_hz: Vec<f64> = devices
        .iter()
        .enumerate()
        .map(|(i, d)| static_freq_with_sel(cfg, d, model_bits, h[i], p_w[i], sel))
        .collect();
    Controls {
        f_hz,
        p_w,
        q: vec![1.0 / n as f64; n],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::system::{total_energy_j, Fleet};

    #[test]
    fn energy_balance_holds_for_interior_solutions() {
        let cfg = SystemConfig::default();
        let mut rng = Rng::new(2);
        let fleet = Fleet::generate(&cfg, (50, 400), &mut rng);
        let m = 32.0 * 140_000.0;
        let sel = selection_probability(1.0 / cfg.num_devices as f64, cfg.k);
        let mut interior = 0;
        for (i, d) in fleet.devices.iter().enumerate() {
            let h = 0.01 + 0.004 * i as f64 % 0.49;
            let p = 0.5 * (d.p_min_w + d.p_max_w);
            let f = static_freq(&cfg, d, m, h, p);
            if f > d.f_min_hz * 1.0001 && f < d.f_max_hz * 0.9999 {
                interior += 1;
                let e = total_energy_j(&cfg, d, m, h, f, p) * sel;
                assert!(
                    (e - d.energy_budget_j).abs() / d.energy_budget_j < 1e-9,
                    "balance violated: {e} vs {}",
                    d.energy_budget_j
                );
            }
        }
        // The paper's defaults put at least some devices interior.
        let _ = interior;
    }

    #[test]
    fn projection_to_bounds() {
        let cfg = SystemConfig {
            energy_budget_j: 1e9, // effectively unconstrained
            ..SystemConfig::default()
        };
        let mut rng = Rng::new(3);
        let fleet = Fleet::generate(&cfg, (100, 100), &mut rng);
        let d = &fleet.devices[0];
        let f = static_freq(&cfg, d, 3.2e6, 0.1, 0.05);
        assert_eq!(f, d.f_max_hz);

        // The budget lives on the Device, not the config.
        let cfg2 = SystemConfig::default();
        let starved = Device {
            energy_budget_j: 1e-9, // impossible budget
            ..d.clone()
        };
        let f2 = static_freq(&cfg2, &starved, 3.2e6, 0.1, 0.05);
        assert_eq!(f2, starved.f_min_hz);
    }

    #[test]
    fn controls_shape_and_uniformity() {
        let cfg = SystemConfig::default();
        let mut rng = Rng::new(4);
        let fleet = Fleet::generate(&cfg, (50, 400), &mut rng);
        let h = vec![0.1; fleet.len()];
        let ctrl = solve_static(&cfg, &fleet.devices, 3.2e6, &h);
        assert_eq!(ctrl.q.len(), 120);
        for &q in &ctrl.q {
            assert!((q - 1.0 / 120.0).abs() < 1e-15);
        }
        for (i, d) in fleet.devices.iter().enumerate() {
            assert!((ctrl.p_w[i] - 0.5 * (d.p_min_w + d.p_max_w)).abs() < 1e-18);
            assert!(ctrl.f_hz[i] >= d.f_min_hz && ctrl.f_hz[i] <= d.f_max_hz);
        }
    }
}
