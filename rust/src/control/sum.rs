//! Successive upper-bound minimization for the sampling probabilities (P2.2).
//!
//! P2.2 minimizes, over the simplex `Σ q_n = 1`, `q_n ∈ (0, 1]`:
//!
//! `f(q) = Σ_n [ A₂_n q_n + A₃_n / q_n ]  −  Σ_n e_n (1 − q_n)^K`
//!
//! with `A₂_n = V T_n`, `A₃_n = V λ w_n²`, and energy price
//! `e_n = Q_n E_n` (the queue-weighted energy of device `n`; the paper's
//! P2.2 prints `E_n` without `Q_n`, but deriving P2.2 from P2 keeps the
//! queue weight — see DESIGN.md §5.3).
//!
//! The first sum is convex, the second concave; SUM linearizes the
//! concave part at the current iterate `qᵗ` and solves the resulting
//! *separable* convex surrogate exactly: with slope
//! `∇_n = K e_n (1 − q_n^τ)^{K−1} ≥ 0` the surrogate is
//! `Σ_n [ c_n q_n + A₃_n / q_n ]`, `c_n = A₂_n + ∇_n`, whose simplex KKT
//! solution is `q_n(μ) = clamp(√(A₃_n / (c_n + μ)), q_min, 1)` with the
//! multiplier `μ` found by bisection on the strictly decreasing
//! `Σ_n q_n(μ) = 1`.  This replaces the paper's CVX call with an exact
//! O(N log 1/ε) solve.

/// Outcome of one [`solve`] call.
#[derive(Clone, Debug)]
pub struct SumResult {
    pub q: Vec<f64>,
    /// SUM (outer) iterations executed.
    pub iters: usize,
    /// Final objective value `f(q)`.
    pub objective: f64,
}

/// Reusable buffers for [`solve_in_place`], so the SUM loop allocates
/// nothing once warmed up: `c` holds the linearized costs, `next` the
/// surrogate solution, `tmp` the dual-bisection probe.
#[derive(Clone, Debug, Default)]
pub struct SumScratch {
    c: Vec<f64>,
    next: Vec<f64>,
    tmp: Vec<f64>,
}

/// The exact P2.2 objective.
pub fn objective(q: &[f64], a2: &[f64], a3: &[f64], e: &[f64], k: usize) -> f64 {
    let mut acc = 0.0;
    for n in 0..q.len() {
        acc += a2[n] * q[n] + a3[n] / q[n] - e[n] * (1.0 - q[n]).powi(k as i32);
    }
    acc
}

/// Solve the linearized surrogate: minimize `Σ c_n q_n + A₃_n/q_n` on the
/// truncated simplex by KKT + dual bisection.
pub fn solve_surrogate(c: &[f64], a3: &[f64], q_min: f64, out: &mut Vec<f64>) {
    let mut tmp = Vec::with_capacity(c.len());
    solve_surrogate_into(c, a3, q_min, out, &mut tmp);
}

/// [`solve_surrogate`] with a caller-owned bisection probe buffer — the
/// allocation-free variant the solver hot loop uses.
pub fn solve_surrogate_into(
    c: &[f64],
    a3: &[f64],
    q_min: f64,
    out: &mut Vec<f64>,
    tmp: &mut Vec<f64>,
) {
    let n = c.len();
    debug_assert!(n > 0);
    debug_assert!(q_min * n as f64 <= 1.0 + 1e-12, "q_min too large for simplex");

    let q_of = |mu: f64, out: &mut Vec<f64>| {
        out.clear();
        out.extend(c.iter().zip(a3).map(|(&cn, &a3n)| {
            let denom = cn + mu;
            if a3n <= 0.0 || denom <= 0.0 {
                // No pull toward larger q (a3=0) -> floor; non-positive
                // denom -> ceiling (handled by bracket choice below).
                if denom <= 0.0 {
                    1.0
                } else {
                    q_min
                }
            } else {
                (a3n / denom).sqrt().clamp(q_min, 1.0)
            }
        }));
    };
    let sum_q = |mu: f64, tmp: &mut Vec<f64>| -> f64 {
        q_of(mu, tmp);
        tmp.iter().sum()
    };

    // Bracket the multiplier. Lower end: just above -min(c) where the
    // binding component saturates at 1 so Σ >= 1. Upper end: expand until
    // Σ < 1 (always reachable since q -> q_min as mu -> inf).
    let c_min = c.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut lo = -c_min + 1e-18 * c_min.abs().max(1.0);
    if sum_q(lo, &mut *tmp) < 1.0 {
        // Even at the lower bracket the mass is < 1 (can happen when many
        // a3 are zero): distribute the remaining mass by waterfilling the
        // largest-a3 components to 1. Fall back to proportional top-up.
        q_of(lo, out);
        let sum: f64 = out.iter().sum();
        let deficit = 1.0 - sum;
        if deficit > 0.0 {
            let slack: f64 = out.iter().map(|&q| 1.0 - q).sum();
            if slack > 0.0 {
                for q in out.iter_mut() {
                    *q += deficit * (1.0 - *q) / slack;
                }
            }
        }
        return;
    }
    let mut hi = c.iter().cloned().fold(f64::NEG_INFINITY, f64::max).abs() + 1.0;
    while sum_q(hi, &mut *tmp) > 1.0 {
        hi = hi * 4.0 + 1.0;
        if hi > 1e300 {
            break;
        }
    }

    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if mid <= lo || mid >= hi {
            break;
        }
        if sum_q(mid, &mut *tmp) > 1.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    q_of(0.5 * (lo + hi), out);
}

/// Full SUM loop (Algorithm 2 inner loop, lines 6–11).
pub fn solve(
    q0: &[f64],
    a2: &[f64],
    a3: &[f64],
    e: &[f64],
    k: usize,
    q_min: f64,
    eps: f64,
    max_iters: usize,
) -> SumResult {
    let mut q = q0.to_vec();
    let mut scratch = SumScratch::default();
    let (iters, obj) = solve_in_place(&mut q, a2, a3, e, k, q_min, eps, max_iters, &mut scratch);
    SumResult {
        q,
        iters,
        objective: obj,
    }
}

/// [`solve`] over a caller-owned iterate and scratch: `q` enters as the
/// initial iterate and leaves as the SUM fixed point, and nothing is
/// allocated once `scratch` has reached its high-water capacity.
/// Returns `(iters, objective)`.
#[allow(clippy::too_many_arguments)]
pub fn solve_in_place(
    q: &mut Vec<f64>,
    a2: &[f64],
    a3: &[f64],
    e: &[f64],
    k: usize,
    q_min: f64,
    eps: f64,
    max_iters: usize,
    scratch: &mut SumScratch,
) -> (usize, f64) {
    let n = q.len();
    scratch.c.clear();
    scratch.c.resize(n, 0.0);
    let mut iters = 0;

    for _ in 0..max_iters {
        iters += 1;
        // Linearize the concave part at q: slope K e (1-q)^{K-1}.
        for i in 0..n {
            scratch.c[i] = a2[i] + k as f64 * e[i] * (1.0 - q[i]).powi(k as i32 - 1);
        }
        solve_surrogate_into(&scratch.c, a3, q_min, &mut scratch.next, &mut scratch.tmp);
        let delta: f64 = q
            .iter()
            .zip(&scratch.next)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        std::mem::swap(q, &mut scratch.next);
        if delta <= eps {
            break;
        }
    }
    let obj = objective(q, a2, a3, e, k);
    (iters, obj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn uniform(n: usize) -> Vec<f64> {
        vec![1.0 / n as f64; n]
    }

    #[test]
    fn surrogate_satisfies_simplex() {
        let c = vec![1.0, 2.0, 3.0, 4.0];
        let a3 = vec![0.1, 0.2, 0.3, 0.4];
        let mut q = Vec::new();
        solve_surrogate(&c, &a3, 1e-6, &mut q);
        let sum: f64 = q.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "sum {sum}");
        assert!(q.iter().all(|&x| x >= 1e-6 && x <= 1.0));
    }

    #[test]
    fn surrogate_kkt_residual_interior() {
        // For interior coordinates, c_n - a3_n/q_n^2 + mu = 0 must hold for
        // a shared mu -> the quantity (a3_n/q_n^2 - c_n) is equal across n.
        let c = vec![5.0, 7.0, 9.0];
        let a3 = vec![2.0, 3.0, 4.0];
        let mut q = Vec::new();
        solve_surrogate(&c, &a3, 1e-9, &mut q);
        let mu: Vec<f64> = (0..3).map(|i| a3[i] / (q[i] * q[i]) - c[i]).collect();
        for w in mu.windows(2) {
            assert!(
                (w[0] - w[1]).abs() < 1e-6 * (1.0 + w[0].abs()),
                "KKT multipliers differ: {mu:?}"
            );
        }
    }

    #[test]
    fn surrogate_prefers_low_cost_high_weight() {
        // Lower c (faster device) and higher a3 (more data) -> higher q.
        let c = vec![1.0, 10.0];
        let a3 = vec![0.5, 0.5];
        let mut q = Vec::new();
        solve_surrogate(&c, &a3, 1e-6, &mut q);
        assert!(q[0] > q[1], "{q:?}");

        let c = vec![5.0, 5.0];
        let a3 = vec![0.9, 0.1];
        solve_surrogate(&c, &a3, 1e-6, &mut q);
        assert!(q[0] > q[1], "{q:?}");
    }

    #[test]
    fn sum_objective_is_monotone_nonincreasing() {
        let mut rng = Rng::new(42);
        let n = 50;
        let a2: Vec<f64> = (0..n).map(|_| rng.range(1.0, 100.0)).collect();
        let a3: Vec<f64> = (0..n).map(|_| rng.range(0.001, 0.1)).collect();
        let e: Vec<f64> = (0..n).map(|_| rng.range(0.0, 50.0)).collect();
        let k = 2;

        // Trace the objective across SUM iterations manually.
        let mut q = uniform(n);
        let mut prev = objective(&q, &a2, &a3, &e, k);
        let mut c = vec![0.0; n];
        let mut next = Vec::new();
        for _ in 0..30 {
            for i in 0..n {
                c[i] = a2[i] + k as f64 * e[i] * (1.0 - q[i]).powi(k as i32 - 1);
            }
            solve_surrogate(&c, &a3, 1e-9, &mut next);
            std::mem::swap(&mut q, &mut next);
            let cur = objective(&q, &a2, &a3, &e, k);
            assert!(
                cur <= prev + prev.abs() * 1e-9,
                "objective increased: {prev} -> {cur}"
            );
            prev = cur;
        }
    }

    #[test]
    fn sum_beats_uniform_start() {
        let mut rng = Rng::new(7);
        let n = 120;
        let a2: Vec<f64> = (0..n).map(|_| rng.range(10.0, 500.0)).collect();
        let a3: Vec<f64> = (0..n).map(|_| rng.range(1e-4, 1e-2)).collect();
        let e: Vec<f64> = (0..n).map(|_| rng.range(0.0, 100.0)).collect();
        let res = solve(&uniform(n), &a2, &a3, &e, 2, 1e-6, 1e-9, 100);
        let uni_obj = objective(&uniform(n), &a2, &a3, &e, 2);
        assert!(res.objective <= uni_obj, "{} vs uniform {}", res.objective, uni_obj);
        let sum: f64 = res.q.iter().sum();
        assert!((sum - 1.0).abs() < 1e-8);
    }

    #[test]
    fn sum_converges_within_cap() {
        let mut rng = Rng::new(9);
        let n = 120;
        let a2: Vec<f64> = (0..n).map(|_| rng.range(10.0, 500.0)).collect();
        let a3: Vec<f64> = (0..n).map(|_| rng.range(1e-4, 1e-2)).collect();
        let e: Vec<f64> = (0..n).map(|_| rng.range(0.0, 100.0)).collect();
        let res = solve(&uniform(n), &a2, &a3, &e, 2, 1e-6, 1e-8, 200);
        assert!(res.iters < 200, "did not converge: {} iters", res.iters);
    }

    #[test]
    fn zero_energy_prices_reduce_to_convex_exact() {
        // With e = 0 the problem is convex; SUM must converge in ~1 step
        // and match the direct surrogate solve.
        let a2 = vec![3.0, 6.0, 9.0];
        let a3 = vec![0.3, 0.2, 0.1];
        let e = vec![0.0; 3];
        let res = solve(&uniform(3), &a2, &a3, &e, 2, 1e-9, 1e-12, 50);
        let mut direct = Vec::new();
        solve_surrogate(&a2, &a3, 1e-9, &mut direct);
        for (a, b) in res.q.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-9, "{:?} vs {:?}", res.q, direct);
        }
    }

    #[test]
    fn straggler_penalized() {
        // Device 2 is 100x slower (huge A2): gets the smallest q.
        let a2 = vec![10.0, 10.0, 1000.0];
        let a3 = vec![0.1, 0.1, 0.1];
        let e = vec![1.0, 1.0, 1.0];
        let res = solve(&uniform(3), &a2, &a3, &e, 2, 1e-6, 1e-9, 100);
        assert!(res.q[2] < res.q[0] && res.q[2] < res.q[1], "{:?}", res.q);
    }

    #[test]
    fn in_place_solve_matches_the_allocating_wrapper() {
        let mut rng = Rng::new(13);
        let n = 40;
        let a2: Vec<f64> = (0..n).map(|_| rng.range(10.0, 500.0)).collect();
        let a3: Vec<f64> = (0..n).map(|_| rng.range(1e-4, 1e-2)).collect();
        let e: Vec<f64> = (0..n).map(|_| rng.range(0.0, 100.0)).collect();
        let res = solve(&uniform(n), &a2, &a3, &e, 2, 1e-6, 1e-9, 100);
        let mut q = uniform(n);
        let mut scratch = SumScratch::default();
        let (iters, obj) =
            solve_in_place(&mut q, &a2, &a3, &e, 2, 1e-6, 1e-9, 100, &mut scratch);
        assert_eq!(q, res.q, "in-place SUM must be bitwise identical");
        assert_eq!(iters, res.iters);
        assert_eq!(obj, res.objective);
        // Scratch reuse across calls must not perturb the result.
        let mut q2 = uniform(n);
        solve_in_place(&mut q2, &a2, &a3, &e, 2, 1e-6, 1e-9, 100, &mut scratch);
        assert_eq!(q2, res.q);
    }

    #[test]
    fn all_a3_zero_still_returns_valid_distribution() {
        let a2 = vec![1.0, 2.0];
        let a3 = vec![0.0, 0.0];
        let e = vec![0.0, 0.0];
        let res = solve(&uniform(2), &a2, &a3, &e, 2, 1e-6, 1e-9, 10);
        let sum: f64 = res.q.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "q = {:?}", res.q);
        assert!(res.q.iter().all(|&x| x > 0.0 && x <= 1.0));
    }
}
