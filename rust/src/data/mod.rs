//! Synthetic federated datasets (the paper's CIFAR-10 / FEMNIST substitutes).
//!
//! Two non-IID regimes, matching the paper's two benchmarks (DESIGN.md §4):
//!
//! * **cifar-like** — *label skew*: per-client class distributions drawn
//!   from Dirichlet(α=0.5) (Hsu et al., the partition the paper uses);
//! * **femnist-like** — *feature shift*: every client is a "writer" with
//!   its own style transform (rotation / scale / shift) applied to shared
//!   class prototypes, mimicking FEMNIST's natural per-writer non-IID-ness.
//!
//! Samples are **materialized lazily and deterministically**: sample `s`
//! of client `n` is a pure function of `(seed, n, s)`, so a 120-client
//! fleet costs no resident memory beyond the prototypes, and any client
//! can be re-visited bit-identically in any round order.

mod task;

pub use task::{SyntheticTask, TaskKind};
