//! Deterministic lazy synthetic task generator.

use crate::rng::Rng;

/// Which non-IID regime to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Dirichlet label-skew (CIFAR-10 substitute).
    LabelSkew,
    /// Per-writer feature-shift (FEMNIST substitute).
    WriterShift,
}

/// One writer's style transform (femnist-like regime).
#[derive(Clone, Copy, Debug)]
struct Style {
    /// Number of 90° rotations of the H×W grid (0..4).
    rot: u8,
    scale: f32,
    shift: f32,
}

/// A fully-specified synthetic federated task.
pub struct SyntheticTask {
    pub kind: TaskKind,
    pub num_clients: usize,
    pub num_classes: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Per-client dataset sizes `D_n`.
    sizes: Vec<usize>,
    /// Class prototypes, `[num_classes * feats]`.
    prototypes: Vec<f32>,
    /// Per-client label distribution (LabelSkew) or uniform (WriterShift).
    label_probs: Vec<Vec<f64>>,
    /// Per-client style (WriterShift only).
    styles: Vec<Style>,
    /// Signal-to-noise scale: x = (snr·proto + ε) / sqrt(1+snr²).
    snr: f32,
    seed: u64,
}

impl SyntheticTask {
    /// CIFAR-10 substitute: Dirichlet(alpha) label skew over clients.
    #[allow(clippy::too_many_arguments)]
    pub fn label_skew(
        num_clients: usize,
        num_classes: usize,
        (h, w, c): (usize, usize, usize),
        dirichlet_alpha: f64,
        samples_range: (usize, usize),
        snr: f64,
        seed: u64,
    ) -> SyntheticTask {
        let mut rng = Rng::new(seed ^ 0xDA7A_5EED);
        let feats = h * w * c;
        let prototypes = rng.normal_vec_f32(num_classes * feats);
        let label_probs = (0..num_clients)
            .map(|_| rng.dirichlet(dirichlet_alpha, num_classes))
            .collect();
        let (lo, hi) = samples_range;
        let sizes = (0..num_clients).map(|_| lo + rng.below(hi - lo + 1)).collect();
        SyntheticTask {
            kind: TaskKind::LabelSkew,
            num_clients,
            num_classes,
            h,
            w,
            c,
            sizes,
            prototypes,
            label_probs,
            styles: Vec::new(),
            snr: snr as f32,
            seed,
        }
    }

    /// FEMNIST substitute: per-writer style transforms, uniform labels.
    #[allow(clippy::too_many_arguments)]
    pub fn writer_shift(
        num_clients: usize,
        num_classes: usize,
        (h, w, c): (usize, usize, usize),
        samples_range: (usize, usize),
        snr: f64,
        seed: u64,
    ) -> SyntheticTask {
        assert_eq!(h, w, "rotation styles need square inputs");
        let mut rng = Rng::new(seed ^ 0xF3E7_57A7);
        let feats = h * w * c;
        let prototypes = rng.normal_vec_f32(num_classes * feats);
        let styles = (0..num_clients)
            .map(|_| Style {
                rot: rng.below(4) as u8,
                scale: rng.range(0.8, 1.2) as f32,
                shift: rng.range(-0.2, 0.2) as f32,
            })
            .collect();
        let uniform = vec![1.0 / num_classes as f64; num_classes];
        let (lo, hi) = samples_range;
        let sizes = (0..num_clients).map(|_| lo + rng.below(hi - lo + 1)).collect();
        SyntheticTask {
            kind: TaskKind::WriterShift,
            num_clients,
            num_classes,
            h,
            w,
            c,
            sizes,
            prototypes,
            label_probs: vec![uniform; num_clients],
            styles,
            snr: snr as f32,
            seed,
        }
    }

    pub fn feats(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Per-client dataset sizes `D_n` (drives the fleet's data weights).
    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Deterministically materialize sample `idx` of `client` into `x_out`
    /// (length `feats`); returns the label.
    pub fn sample_into(&self, client: usize, idx: usize, x_out: &mut [f32]) -> i32 {
        debug_assert!(client < self.num_clients);
        debug_assert_eq!(x_out.len(), self.feats());
        let key = (client as u64) << 32 | (idx as u64 & 0xFFFF_FFFF);
        let mut rng = Rng::new(self.seed ^ key.wrapping_mul(0x2545_F491_4F6C_DD1D));
        let label = rng.categorical(&self.label_probs[client]) as i32;
        self.render(label as usize, &mut rng, self.styles.get(client).copied(), x_out);
        label
    }

    /// A test sample from the *global* distribution: uniform labels and —
    /// for WriterShift — a fresh, unseen writer style per sample.
    pub fn test_sample_into(&self, idx: usize, x_out: &mut [f32]) -> i32 {
        let mut rng = Rng::new(
            self.seed ^ 0x7E57_DA7A ^ (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let label = rng.below(self.num_classes) as i32;
        let style = match self.kind {
            TaskKind::LabelSkew => None,
            TaskKind::WriterShift => Some(Style {
                rot: rng.below(4) as u8,
                scale: rng.range(0.8, 1.2) as f32,
                shift: rng.range(-0.2, 0.2) as f32,
            }),
        };
        self.render(label as usize, &mut rng, style, x_out);
        label
    }

    /// Fill a training batch for `client` from sample indices.
    pub fn fill_batch(&self, client: usize, indices: &[usize], x_out: &mut [f32], y_out: &mut [i32]) {
        let feats = self.feats();
        debug_assert_eq!(x_out.len(), indices.len() * feats);
        debug_assert_eq!(y_out.len(), indices.len());
        for (slot, &idx) in indices.iter().enumerate() {
            y_out[slot] = self.sample_into(client, idx, &mut x_out[slot * feats..(slot + 1) * feats]);
        }
    }

    /// Materialize the global test set.
    pub fn test_set(&self, n: usize) -> (Vec<f32>, Vec<i32>) {
        let feats = self.feats();
        let mut x = vec![0.0f32; n * feats];
        let mut y = vec![0i32; n];
        for i in 0..n {
            y[i] = self.test_sample_into(i, &mut x[i * feats..(i + 1) * feats]);
        }
        (x, y)
    }

    fn render(&self, label: usize, rng: &mut Rng, style: Option<Style>, x_out: &mut [f32]) {
        let feats = self.feats();
        let proto = &self.prototypes[label * feats..(label + 1) * feats];
        let norm = 1.0 / (1.0 + self.snr * self.snr).sqrt();
        match style {
            None => {
                for (o, &p) in x_out.iter_mut().zip(proto) {
                    *o = (self.snr * p + rng.normal() as f32) * norm;
                }
            }
            Some(s) => {
                // Rotate the prototype grid, then apply the affine style.
                for i in 0..self.h {
                    for j in 0..self.w {
                        let (si, sj) = rotate_index(i, j, self.h, s.rot);
                        for ch in 0..self.c {
                            let src = (si * self.w + sj) * self.c + ch;
                            let dst = (i * self.w + j) * self.c + ch;
                            let v = self.snr * proto[src] * s.scale + s.shift
                                + rng.normal() as f32;
                            x_out[dst] = v * norm;
                        }
                    }
                }
            }
        }
    }
}

/// Source index of destination `(i, j)` under `rot` 90°-rotations of an
/// `n×n` grid.
fn rotate_index(i: usize, j: usize, n: usize, rot: u8) -> (usize, usize) {
    match rot % 4 {
        0 => (i, j),
        1 => (j, n - 1 - i),
        2 => (n - 1 - i, n - 1 - j),
        _ => (n - 1 - j, i),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cifar() -> SyntheticTask {
        SyntheticTask::label_skew(20, 10, (8, 8, 3), 0.5, (50, 100), 1.5, 42)
    }

    fn femnist() -> SyntheticTask {
        SyntheticTask::writer_shift(20, 62, (28, 28, 1), (50, 100), 1.5, 42)
    }

    #[test]
    fn samples_are_deterministic() {
        let t = cifar();
        let mut a = vec![0.0; t.feats()];
        let mut b = vec![0.0; t.feats()];
        let la = t.sample_into(3, 17, &mut a);
        let lb = t.sample_into(3, 17, &mut b);
        assert_eq!(la, lb);
        assert_eq!(a, b);
        // Different index -> different sample.
        let lc = t.sample_into(3, 18, &mut b);
        assert!(a != b || la != lc);
    }

    #[test]
    fn sizes_in_range_and_labels_valid() {
        let t = cifar();
        for (&n, client) in t.sizes().iter().zip(0..) {
            assert!((50..=100).contains(&n));
            let mut x = vec![0.0; t.feats()];
            for idx in 0..5 {
                let y = t.sample_into(client, idx, &mut x);
                assert!((0..10).contains(&y));
                assert!(x.iter().all(|v| v.is_finite()));
            }
        }
    }

    #[test]
    fn label_skew_is_non_iid() {
        // Under Dirichlet(0.5) most clients concentrate: the max class
        // frequency should exceed the IID 1/10 baseline on average.
        let t = cifar();
        let mut x = vec![0.0; t.feats()];
        let mut avg_max = 0.0;
        for client in 0..t.num_clients {
            let mut counts = vec![0usize; 10];
            for idx in 0..60 {
                counts[t.sample_into(client, idx, &mut x) as usize] += 1;
            }
            avg_max += *counts.iter().max().unwrap() as f64 / 60.0;
        }
        avg_max /= t.num_clients as f64;
        assert!(avg_max > 0.3, "avg max class frequency {avg_max} too IID");
    }

    #[test]
    fn writer_shift_differs_between_writers_same_label() {
        let t = femnist();
        // Find a label both writers can produce, compare renderings.
        let mut x0 = vec![0.0; t.feats()];
        let mut x1 = vec![0.0; t.feats()];
        // Render label deterministically via fixed style paths: use two
        // clients with different styles.
        let s0 = t.styles[0];
        let s1 = t.styles[1];
        if s0.rot == s1.rot && (s0.scale - s1.scale).abs() < 1e-3 {
            return; // styles collided in this seed; nothing to compare
        }
        // Force the same label by scanning indices.
        let mut found = None;
        for idx in 0..200 {
            let l0 = t.sample_into(0, idx, &mut x0);
            for jdx in 0..200 {
                let l1 = t.sample_into(1, jdx, &mut x1);
                if l0 == l1 {
                    found = Some((x0.clone(), x1.clone()));
                    break;
                }
            }
            if found.is_some() {
                break;
            }
        }
        let (a, b) = found.expect("same label not found");
        let dist: f32 = a.iter().zip(&b).map(|(u, v)| (u - v) * (u - v)).sum();
        assert!(dist > 1.0, "writers render identically: {dist}");
    }

    #[test]
    fn test_set_is_roughly_class_balanced() {
        let t = cifar();
        let (_, y) = t.test_set(1000);
        let mut counts = vec![0usize; 10];
        for &l in &y {
            counts[l as usize] += 1;
        }
        for &c in &counts {
            assert!((50..200).contains(&c), "class count {c}");
        }
    }

    #[test]
    fn snr_separates_classes() {
        // With snr = 1.5 the nearest-prototype classifier should beat
        // chance comfortably on the test set: the task is learnable.
        let t = cifar();
        let feats = t.feats();
        let (x, y) = t.test_set(300);
        let mut correct = 0;
        let norm = (1.0f32 + t.snr * t.snr).sqrt();
        for i in 0..300 {
            let xi = &x[i * feats..(i + 1) * feats];
            let mut best = (f32::INFINITY, 0usize);
            for cls in 0..10 {
                let p = &t.prototypes[cls * feats..(cls + 1) * feats];
                let d: f32 = xi
                    .iter()
                    .zip(p)
                    .map(|(a, b)| {
                        let diff = a * norm - t.snr * b;
                        diff * diff
                    })
                    .sum();
                if d < best.0 {
                    best = (d, cls);
                }
            }
            if best.1 as i32 == y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / 300.0;
        assert!(acc > 0.8, "nearest-prototype accuracy {acc} too low");
    }

    #[test]
    fn rotate_index_is_a_bijection() {
        let n = 5;
        for rot in 0..4u8 {
            let mut seen = std::collections::BTreeSet::new();
            for i in 0..n {
                for j in 0..n {
                    seen.insert(rotate_index(i, j, n, rot));
                }
            }
            assert_eq!(seen.len(), n * n);
        }
    }

    #[test]
    fn fill_batch_matches_individual_samples() {
        let t = femnist();
        let feats = t.feats();
        let indices = [0usize, 5, 9];
        let mut xb = vec![0.0; 3 * feats];
        let mut yb = vec![0i32; 3];
        t.fill_batch(2, &indices, &mut xb, &mut yb);
        let mut x = vec![0.0; feats];
        for (slot, &idx) in indices.iter().enumerate() {
            let y = t.sample_into(2, idx, &mut x);
            assert_eq!(y, yb[slot]);
            assert_eq!(&xb[slot * feats..(slot + 1) * feats], &x[..]);
        }
    }
}
