//! Adversarial worst-case channel for regret experiments.
//!
//! The paper's claim is that Lyapunov control works *without knowledge
//! of future dynamics*; the sharpest stress of that claim is an
//! adversary that reacts to the scheduler.  This environment draws the
//! same IID clipped-exponential gains as `static` (same
//! [`ChannelProcess`] construction and seed, so the base realization is
//! comparable round for round) and then degrades a budget of devices:
//!
//! * the devices **selected last round** (reported through
//!   [`Environment::observe_selection`]) — punishing schedulers that
//!   ride a good channel, and
//! * the remaining budget goes to the **best current gains** — exactly
//!   the devices a greedy best-channel scheduler would pick next.
//!
//! Degraded gains are multiplied by `env.adv_degrade` and clamped to the
//! clip floor, so they stay inside the paper's outlier band.  The budget
//! is `env.adv_targets` devices (0 = `2K`: the previous selection plus
//! greedy's predicted next picks).
//!
//! Because the next round depends on a selection the server has not made
//! yet, this environment is **not previewable**: [`Environment::peek`]
//! keeps its `None` default, and the oracle regret anchor runs against
//! its own adversary stream (the standard adaptive-adversary regret
//! convention).
//!
//! [`ChannelProcess`]: crate::system::ChannelProcess

use super::{EnvInit, Environment, RoundEnv};
use crate::system::{ChannelProcess, Device};

/// Selection-reactive worst-case channel.
pub struct AdversarialEnv {
    channel: ChannelProcess,
    /// Gain multiplier applied to targeted devices.
    degrade: f64,
    /// Devices degraded per round.
    budget: usize,
    clip_lo: f64,
    /// Unique global ids selected last round (empty before round 1).
    prev_selected: Vec<usize>,
}

impl AdversarialEnv {
    pub fn new(init: &EnvInit<'_>) -> Self {
        let budget = if init.env.adv_targets > 0 {
            init.env.adv_targets
        } else {
            2 * init.sys.k
        };
        Self {
            channel: ChannelProcess::new(init.sys, init.seed),
            degrade: init.env.adv_degrade,
            budget: budget.min(init.sys.num_devices),
            clip_lo: init.sys.channel_clip.0,
            prev_selected: Vec::new(),
        }
    }

    /// The devices degraded this round given the base draw: last round's
    /// selection first, then the best remaining gains up to the budget.
    fn targets(&self, gains: &[f64]) -> Vec<usize> {
        let n = gains.len();
        let mut hit = vec![false; n];
        let mut out = Vec::with_capacity(self.budget);
        for &s in &self.prev_selected {
            if out.len() == self.budget {
                return out;
            }
            if s < n && !hit[s] {
                hit[s] = true;
                out.push(s);
            }
        }
        // Fill with greedy's predicted picks: best gains first, ties
        // broken by id for determinism.
        let mut order: Vec<usize> = (0..n).filter(|&i| !hit[i]).collect();
        order.sort_by(|&a, &b| {
            gains[b]
                .partial_cmp(&gains[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        out.extend(order.into_iter().take(self.budget - out.len()));
        out
    }

    /// Composite hook: the base (pre-degrade) channel draw, used when
    /// this child is the composite's channel owner.
    pub(crate) fn step_channel_into(&mut self, out: &mut Vec<f64>) {
        self.channel.next_round_into(out);
    }

    /// Composite hook: the degrade pass over an arbitrary (merged) gain
    /// vector — the one implementation `next_round` also applies, so the
    /// targeting/clamp semantics cannot diverge.
    pub(crate) fn degrade_gains(&self, gains: &mut [f64]) {
        for t in self.targets(gains) {
            gains[t] = (gains[t] * self.degrade).max(self.clip_lo);
        }
    }
}

impl Environment for AdversarialEnv {
    fn name(&self) -> &'static str {
        "adv"
    }

    fn next_round(&mut self, _base: &[Device]) -> RoundEnv {
        let mut gains = self.channel.next_round();
        self.degrade_gains(&mut gains);
        RoundEnv {
            gains,
            available: None,
            devices: None,
        }
    }

    // peek: deliberately the default `None` — the future depends on the
    // selection the server has not made yet.

    fn observe_selection(&mut self, selected: &[usize]) {
        self.prev_selected = selected.to_vec();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnvConfig, SystemConfig};

    fn build(n: usize, k: usize, env_cfg: &EnvConfig, seed: u64) -> AdversarialEnv {
        let sys = SystemConfig {
            num_devices: n,
            k,
            ..SystemConfig::default()
        };
        AdversarialEnv::new(&EnvInit {
            sys: &sys,
            env: env_cfg,
            seed,
        })
    }

    #[test]
    fn degrades_exactly_the_greedy_targets_before_any_selection() {
        let cfg = EnvConfig::default();
        let mut adv = build(10, 2, &cfg, 3);
        let sys = SystemConfig {
            num_devices: 10,
            k: 2,
            ..SystemConfig::default()
        };
        let mut reference = ChannelProcess::new(&sys, 3);
        let base: Vec<Device> = Vec::new();
        let got = adv.next_round(&base).gains;
        let raw = reference.next_round();
        // Budget 2K = 4: the four best raw gains are degraded, the rest
        // are untouched.
        let mut order: Vec<usize> = (0..10).collect();
        order.sort_by(|&a, &b| raw[b].partial_cmp(&raw[a]).unwrap().then(a.cmp(&b)));
        for (rank, &i) in order.iter().enumerate() {
            if rank < 4 {
                let want = (raw[i] * cfg.adv_degrade).max(0.01);
                assert_eq!(got[i], want, "device {i} should be degraded");
            } else {
                assert_eq!(got[i], raw[i], "device {i} should be untouched");
            }
        }
    }

    #[test]
    fn punishes_the_previous_selection() {
        let cfg = EnvConfig {
            adv_targets: 2,
            ..EnvConfig::default()
        };
        let mut adv = build(12, 2, &cfg, 7);
        let sys = SystemConfig {
            num_devices: 12,
            k: 2,
            ..SystemConfig::default()
        };
        let mut reference = ChannelProcess::new(&sys, 7);
        let base: Vec<Device> = Vec::new();
        adv.next_round(&base);
        reference.next_round();
        // Whatever was selected takes the whole budget next round.
        adv.observe_selection(&[3, 8]);
        let got = adv.next_round(&base).gains;
        let raw = reference.next_round();
        for i in [3usize, 8] {
            assert_eq!(got[i], (raw[i] * cfg.adv_degrade).max(0.01));
        }
        for i in (0..12).filter(|i| ![3, 8].contains(i)) {
            assert_eq!(got[i], raw[i], "device {i}");
        }
    }

    #[test]
    fn gains_stay_in_band_and_runs_are_deterministic() {
        let cfg = EnvConfig {
            adv_degrade: 0.01, // drives degraded gains into the floor
            ..EnvConfig::default()
        };
        let mut a = build(8, 2, &cfg, 5);
        let mut b = build(8, 2, &cfg, 5);
        let base: Vec<Device> = Vec::new();
        for _ in 0..100 {
            let (ra, rb) = (a.next_round(&base), b.next_round(&base));
            assert_eq!(ra.gains, rb.gains);
            assert!(ra.gains.iter().all(|&h| (0.01..=0.5).contains(&h)));
            a.observe_selection(&[1, 2]);
            b.observe_selection(&[1, 2]);
        }
    }

    #[test]
    fn is_not_previewable() {
        let cfg = EnvConfig::default();
        let adv = build(6, 2, &cfg, 1);
        let base: Vec<Device> = Vec::new();
        assert!(adv.peek(&base).is_none());
    }

    #[test]
    fn budget_is_clamped_to_the_fleet() {
        let cfg = EnvConfig {
            adv_targets: 999,
            ..EnvConfig::default()
        };
        let mut adv = build(4, 2, &cfg, 2);
        let base: Vec<Device> = Vec::new();
        let re = adv.next_round(&base);
        assert_eq!(re.gains.len(), 4);
    }
}
