//! Markov device availability: the candidate set `N^t` varies per round.

use super::{EnvInit, EnvSoA, Environment, RoundEnv};
use crate::rng::Rng;
use crate::system::{ChannelProcess, Device};

/// Device dropout/arrival as an independent per-device on/off Markov
/// chain on top of the static channel.
///
/// * Channel gains come from the *same* [`ChannelProcess`] construction
///   (and seed) as the static environment, so the gain realization is
///   identical to `static` round for round — availability masking is the
///   only difference, which isolates its effect in comparisons.
/// * Every device starts online; each round an online device drops with
///   probability `avail_p_drop` and an offline device returns with
///   probability `avail_p_join`.
/// * The server must always be able to sample `K` participants, so if
///   the chain leaves fewer than `K` devices online, offline devices are
///   forced back on in ascending id order until `K` are reachable (a
///   deterministic repair that keeps trajectories reproducible).
#[derive(Clone)]
pub struct AvailabilityEnv {
    channel: ChannelProcess,
    streams: Vec<Rng>,
    online: Vec<bool>,
    p_drop: f64,
    p_join: f64,
    min_online: usize,
}

impl AvailabilityEnv {
    pub fn new(init: &EnvInit<'_>) -> Self {
        let n = init.sys.num_devices;
        let mut root = Rng::new(init.seed ^ 0xA7A1_1AB1_E0FF_11E5);
        Self {
            channel: ChannelProcess::new(init.sys, init.seed),
            streams: (0..n).map(|i| root.fork(i as u64)).collect(),
            online: vec![true; n],
            p_drop: init.env.avail_p_drop,
            p_join: init.env.avail_p_join,
            min_online: init.sys.k.max(1),
        }
    }

    /// Advance every on/off chain one round, then apply the K-repair
    /// (force offline devices back on in ascending id order).  The one
    /// implementation both `next_round` and `step_into` step through,
    /// so the transition/repair semantics can never diverge.
    fn advance_online(&mut self) {
        let (p_drop, p_join) = (self.p_drop, self.p_join);
        for (rng, on) in self.streams.iter_mut().zip(self.online.iter_mut()) {
            *on = super::step_two_state(rng, *on, p_drop, p_join);
        }
        // Repair: guarantee at least K reachable devices.
        let mut count = self.online.iter().filter(|&&b| b).count();
        for on in self.online.iter_mut() {
            if count >= self.min_online {
                break;
            }
            if !*on {
                *on = true;
                count += 1;
            }
        }
    }

    /// Composite hook: advance only the on/off chains (the composite's
    /// channel owner supplies the gains) and return the post-repair mask.
    pub(crate) fn step_mask(&mut self) -> &[bool] {
        self.advance_online();
        &self.online
    }

    /// Composite hook: the shared static-stream channel draw, used when
    /// this child is the composite's channel owner.
    pub(crate) fn step_channel_into(&mut self, out: &mut Vec<f64>) {
        self.channel.next_round_into(out);
    }
}

impl Environment for AvailabilityEnv {
    fn name(&self) -> &'static str {
        "avail"
    }

    fn next_round(&mut self, _base: &[Device]) -> RoundEnv {
        // Gains are drawn for every device (also offline ones) so the
        // channel stream never depends on the availability trajectory.
        let gains = self.channel.next_round();
        self.advance_online();
        let available = (0..self.online.len()).filter(|&i| self.online[i]).collect();
        RoundEnv {
            gains,
            available: Some(available),
            devices: None,
        }
    }

    fn step_into(&mut self, _base: &[Device], out: &mut EnvSoA) {
        // Same order as next_round: all gains first, then the chains.
        self.channel.next_round_into(&mut out.gains);
        self.advance_online();
        out.available.clear();
        out.available
            .extend((0..self.online.len()).filter(|&i| self.online[i]));
        // Like next_round, N^t is reported explicitly even when every
        // device happens to be online — the server's compaction decision
        // keys on the count, not the flag.
        out.all_available = false;
        out.set_undrifted();
    }

    fn peek(&self, base: &[Device]) -> Option<RoundEnv> {
        // Action-independent: stepping a clone previews the stream.
        Some(self.clone().next_round(base))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnvConfig, SystemConfig};

    fn sys(n: usize, k: usize) -> SystemConfig {
        SystemConfig {
            num_devices: n,
            k,
            ..SystemConfig::default()
        }
    }

    #[test]
    fn gains_match_the_static_channel_stream() {
        let sys = sys(15, 2);
        let env_cfg = EnvConfig::default();
        let mut env = AvailabilityEnv::new(&EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 31,
        });
        let mut reference = ChannelProcess::new(&sys, 31);
        let base: Vec<Device> = Vec::new();
        for _ in 0..30 {
            assert_eq!(env.next_round(&base).gains, reference.next_round());
        }
    }

    #[test]
    fn fleet_fluctuates_but_never_starves() {
        let sys = sys(12, 3);
        let env_cfg = EnvConfig {
            avail_p_drop: 0.4,
            avail_p_join: 0.3,
            ..EnvConfig::default()
        };
        let mut env = AvailabilityEnv::new(&EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 8,
        });
        let base: Vec<Device> = Vec::new();
        let mut saw_partial = false;
        for _ in 0..200 {
            let re = env.next_round(&base);
            let av = re.available.expect("avail env always reports N^t");
            assert!(av.len() >= 3, "fewer than K reachable");
            assert!(av.len() <= 12);
            saw_partial |= av.len() < 12;
        }
        assert!(saw_partial, "availability never dropped anyone");
    }

    #[test]
    fn deterministic_per_seed() {
        let sys = sys(10, 2);
        let env_cfg = EnvConfig {
            avail_p_drop: 0.3,
            ..EnvConfig::default()
        };
        let mk = |seed| {
            AvailabilityEnv::new(&EnvInit {
                sys: &sys,
                env: &env_cfg,
                seed,
            })
        };
        let (mut a, mut b) = (mk(4), mk(4));
        let base: Vec<Device> = Vec::new();
        for _ in 0..100 {
            let (ra, rb) = (a.next_round(&base), b.next_round(&base));
            assert_eq!(ra.available, rb.available);
            assert_eq!(ra.gains, rb.gains);
        }
    }
}
