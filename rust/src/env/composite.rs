//! Composite environments: layer several mechanisms into one round
//! process.
//!
//! Real edge fleets stack their dynamics — diurnal availability on
//! Gilbert–Elliott fading on drifting compute — while every other
//! registry environment models exactly one mechanism.  `compose` takes
//! a `+`-separated child spec (`env.compose`, axis syntax
//! `--envs=compose:avail+ge+drift`, presets from
//! [`crate::config::COMPOSE_PRESETS`]) and merges the children under
//! fixed, documented semantics:
//!
//! * **Gains** come from the *channel owner*: `ge` if present, else
//!   `trace`, else `adv`, else the shared static stream (every
//!   remaining mechanism constructs the same-seed
//!   [`crate::system::ChannelProcess`], so they all agree bitwise).
//!   An `adv` child then applies its degrade pass *on the merged
//!   gains* (reacting to the fades the scheduler actually sees), and
//!   correlated shadowing (`env.shadow_std`/`env.shadow_rho`, below)
//!   multiplies last, clamped back into the clip band.
//! * **Availability** is the AND of every child's candidate set,
//!   followed by one K repair (offline devices forced back on in
//!   ascending id order) — children keep their own internal repair, so
//!   a single-child composite is byte-identical to the child alone.
//! * **Drift** overlays pass through from the (at most one) `drift`
//!   child.
//!
//! Each child consumes exactly the RNG streams it would standalone
//! (non-owner channel draws are skipped entirely — they own disjoint
//! forked streams, so skipping them perturbs nothing), which makes
//! `compose:<x>` bitwise identical to `<x>` and keeps composites
//! seed-deterministic and thread-count invariant.  The
//! [`Environment::step_into`] path reuses persistent scratch, so a
//! composite steps alloc-free at steady state even at 100k+ devices.
//!
//! **Correlated shadowing**: with `env.shadow_std > 0`, every device's
//! gain is multiplied by `exp(std · z_n)` where
//! `z_n = sqrt(rho)·z_common + sqrt(1-rho)·z_own` — one log-normal
//! field whose common component (`env.shadow_rho`) makes co-located
//! devices fade together.  The field has its own forked RNG root, so
//! enabling it never perturbs any child's trajectory.
//!
//! **Foresight**: `peek` previews the next round only when *every*
//! child is action-independent; one `adv` child makes the composite's
//! future depend on a selection the server has not made yet, so `peek`
//! degrades to `None` and the oracle anchors lose their foresight —
//! exactly as with a bare `adv`.

use super::adversarial::AdversarialEnv;
use super::availability::AvailabilityEnv;
use super::drift::DriftEnv;
use super::gilbert_elliott::GilbertElliottEnv;
use super::scenario::{DiurnalEnv, FlashCrowdEnv, OutageEnv};
use super::static_env::StaticEnv;
use super::trace::TraceEnv;
use super::{EnvInit, EnvSoA, Environment, RoundEnv};
use crate::config::ComposeChild;
use crate::rng::Rng;
use crate::system::Device;
use crate::Result;

/// One instantiated child mechanism.
enum Child {
    Static(StaticEnv),
    Ge(GilbertElliottEnv),
    Avail(AvailabilityEnv),
    Drift(DriftEnv),
    Trace(TraceEnv),
    Adv(AdversarialEnv),
    Diurnal(DiurnalEnv),
    FlashCrowd(FlashCrowdEnv),
    Outage(OutageEnv),
}

impl Child {
    fn build(kind: ComposeChild, init: &EnvInit<'_>) -> Result<Child> {
        Ok(match kind {
            ComposeChild::Static => Child::Static(StaticEnv::new(init)),
            ComposeChild::GilbertElliott => Child::Ge(GilbertElliottEnv::new(init)),
            ComposeChild::Availability => Child::Avail(AvailabilityEnv::new(init)),
            ComposeChild::Drift => Child::Drift(DriftEnv::new(init)),
            ComposeChild::Trace => Child::Trace(TraceEnv::new(init)?),
            ComposeChild::Adversarial => Child::Adv(AdversarialEnv::new(init)),
            ComposeChild::Diurnal => Child::Diurnal(DiurnalEnv::new(init)),
            ComposeChild::FlashCrowd => Child::FlashCrowd(FlashCrowdEnv::new(init)),
            ComposeChild::Outage => Child::Outage(OutageEnv::new(init)),
        })
    }

    /// Channel-owner priority (lower wins): `ge` realizes its own fading
    /// process, `trace` carries recorded gains, `adv` must pair its
    /// degrade pass with its own base draw when nothing else shapes the
    /// channel; everything else shares the identical static stream.
    fn owner_rank(&self) -> u8 {
        match self {
            Child::Ge(_) => 0,
            Child::Trace(_) => 1,
            Child::Adv(_) => 2,
            _ => 3,
        }
    }

    /// Whether the next round is independent of the server's selection
    /// (the `peek` foresight contract).
    fn action_independent(&self) -> bool {
        !matches!(self, Child::Adv(_))
    }

    fn try_clone(&self) -> Option<Child> {
        Some(match self {
            Child::Static(c) => Child::Static(c.clone()),
            Child::Ge(c) => Child::Ge(c.clone()),
            Child::Avail(c) => Child::Avail(c.clone()),
            Child::Drift(c) => Child::Drift(c.clone()),
            Child::Trace(c) => Child::Trace(c.clone()),
            Child::Adv(_) => return None,
            Child::Diurnal(c) => Child::Diurnal(c.clone()),
            Child::FlashCrowd(c) => Child::FlashCrowd(c.clone()),
            Child::Outage(c) => Child::Outage(c.clone()),
        })
    }
}

/// The correlated log-normal shadow field (module docs above).
#[derive(Clone)]
struct Shadow {
    common: Rng,
    streams: Vec<Rng>,
    w_common: f64,
    w_own: f64,
    std: f64,
    clip: (f64, f64),
}

impl Shadow {
    fn new(init: &EnvInit<'_>) -> Shadow {
        let n = init.sys.num_devices;
        let mut root = Rng::new(init.seed ^ 0x51AD_0E00_F1E1_D005);
        let streams = (0..n).map(|i| root.fork(i as u64)).collect();
        Shadow {
            common: root.fork(n as u64),
            streams,
            w_common: init.env.shadow_rho.sqrt(),
            w_own: (1.0 - init.env.shadow_rho).sqrt(),
            std: init.env.shadow_std,
            clip: init.sys.channel_clip,
        }
    }

    fn apply(&mut self, gains: &mut [f64]) {
        let zc = self.common.normal();
        let (lo, hi) = self.clip;
        for (g, rng) in gains.iter_mut().zip(self.streams.iter_mut()) {
            let z = self.w_common * zc + self.w_own * rng.normal();
            *g = (*g * (self.std * z).exp()).clamp(lo, hi);
        }
    }
}

/// The `compose` environment: see the module docs for the merge
/// semantics.
pub struct CompositeEnv {
    children: Vec<Child>,
    /// Index of the channel-owning child (min `owner_rank`, ties by
    /// spec order).
    owner: usize,
    shadow: Option<Shadow>,
    n: usize,
    min_online: usize,
    // Persistent scratch, so steady-state stepping allocates nothing.
    online: Vec<bool>,
    child_online: Vec<bool>,
    discard_gains: Vec<f64>,
}

impl CompositeEnv {
    pub fn new(init: &EnvInit<'_>) -> Result<Self> {
        let kinds = init.env.compose_children()?;
        let children = kinds
            .iter()
            .map(|&k| Child::build(k, init))
            .collect::<Result<Vec<_>>>()?;
        let owner = children
            .iter()
            .enumerate()
            .min_by_key(|(i, c)| (c.owner_rank(), *i))
            .map(|(i, _)| i)
            .expect("compose spec parsing guarantees at least one child");
        let shadow = (init.env.shadow_std > 0.0).then(|| Shadow::new(init));
        Ok(Self {
            children,
            owner,
            shadow,
            n: init.sys.num_devices,
            min_online: init.sys.k.max(1),
            online: Vec::new(),
            child_online: Vec::new(),
            discard_gains: Vec::new(),
        })
    }

    /// Whether every child is action-independent (so `peek` can preview).
    pub fn previewable(&self) -> bool {
        self.children.iter().all(Child::action_independent)
    }

    fn clone_previewable(&self) -> Option<CompositeEnv> {
        let children = self
            .children
            .iter()
            .map(Child::try_clone)
            .collect::<Option<Vec<_>>>()?;
        Some(CompositeEnv {
            children,
            owner: self.owner,
            shadow: self.shadow.clone(),
            n: self.n,
            min_online: self.min_online,
            online: Vec::new(),
            child_online: Vec::new(),
            discard_gains: Vec::new(),
        })
    }
}

/// AND `mask` into `acc` elementwise.
fn and_mask(acc: &mut [bool], mask: &[bool]) {
    debug_assert_eq!(acc.len(), mask.len());
    for (a, m) in acc.iter_mut().zip(mask) {
        *a &= *m;
    }
}

impl Environment for CompositeEnv {
    fn name(&self) -> &'static str {
        "compose"
    }

    fn next_round(&mut self, base: &[Device]) -> RoundEnv {
        // One implementation: materialize the SoA step, so the two
        // paths cannot diverge.
        let mut soa = EnvSoA::new();
        self.step_into(base, &mut soa);
        let available = if soa.all_available {
            None
        } else {
            Some(soa.available.clone())
        };
        let devices = soa.drifted.then(|| {
            base.iter()
                .enumerate()
                .map(|(i, d)| {
                    let mut d = d.clone();
                    d.f_max_hz = soa.f_max_hz[i];
                    d.alpha = soa.alpha[i];
                    d
                })
                .collect()
        });
        RoundEnv {
            gains: soa.gains,
            available,
            devices,
        }
    }

    fn step_into(&mut self, base: &[Device], out: &mut EnvSoA) {
        let CompositeEnv {
            children,
            owner,
            shadow,
            n,
            min_online,
            online,
            child_online,
            discard_gains,
        } = self;
        let (n, min_online, owner) = (*n, *min_online, *owner);

        // 1. Gains from the channel owner.  A trace owner realizes its
        //    gains together with its mask in the availability pass
        //    below; every other owner draws here.  Non-owner channels
        //    are never drawn — each child's channel lives on disjoint
        //    forked streams, so skipping them perturbs nothing.
        match &mut children[owner] {
            Child::Static(c) => c.step_channel_into(&mut out.gains),
            Child::Ge(c) => c.draw_gains_into(&mut out.gains),
            Child::Avail(c) => c.step_channel_into(&mut out.gains),
            Child::Drift(c) => c.step_channel_into(&mut out.gains),
            Child::Trace(_) => {}
            Child::Adv(c) => c.step_channel_into(&mut out.gains),
            Child::Diurnal(c) => c.step_channel_into(&mut out.gains),
            Child::FlashCrowd(c) => c.step_channel_into(&mut out.gains),
            Child::Outage(c) => c.step_channel_into(&mut out.gains),
        }

        // 2. Availability: AND every child's candidate set.  `explicit`
        //    mirrors each child's own reporting convention (avail-style
        //    mechanisms always report N^t explicitly; trace only when
        //    someone is actually off), so a single-child composite is
        //    byte-identical to the child alone.
        online.clear();
        online.resize(n, true);
        let mut explicit = false;
        for (i, child) in children.iter_mut().enumerate() {
            match child {
                Child::Avail(c) => {
                    and_mask(online, c.step_mask());
                    explicit = true;
                }
                Child::Diurnal(c) => {
                    and_mask(online, c.step_mask());
                    explicit = true;
                }
                Child::FlashCrowd(c) => {
                    and_mask(online, c.step_mask());
                    explicit = true;
                }
                Child::Outage(c) => {
                    and_mask(online, c.step_mask());
                    explicit = true;
                }
                Child::Trace(c) => {
                    let t = c.advance();
                    let gains_buf = if i == owner {
                        &mut out.gains
                    } else {
                        &mut *discard_gains
                    };
                    let any_off = c.realize_into(t, gains_buf, child_online);
                    and_mask(online, child_online);
                    explicit |= any_off;
                }
                Child::Static(_) | Child::Ge(_) | Child::Drift(_) | Child::Adv(_) => {}
            }
        }
        if explicit {
            // One K repair over the intersection (ascending id order) —
            // a no-op for a single child, whose internal repair already
            // guarantees the floor.
            let mut count = online.iter().filter(|&&b| b).count();
            for on in online.iter_mut() {
                if count >= min_online {
                    break;
                }
                if !*on {
                    *on = true;
                    count += 1;
                }
            }
            out.available.clear();
            out.available
                .extend((0..n).filter(|&i| online[i]));
            out.all_available = false;
        } else {
            out.set_all_available();
        }

        // 3. Drift overlay (at most one drift child — duplicates are
        //    rejected at parse time).
        out.set_undrifted();
        for child in children.iter_mut() {
            if let Child::Drift(c) = child {
                let (m_f, m_a) = c.step_walks();
                out.f_max_hz.clear();
                out.f_max_hz.extend(
                    base.iter()
                        .enumerate()
                        .map(|(i, d)| (d.f_max_hz * m_f[i]).max(d.f_min_hz)),
                );
                out.alpha.clear();
                out.alpha
                    .extend(base.iter().enumerate().map(|(i, d)| d.alpha * m_a[i]));
                out.drifted = true;
            }
        }

        // 4. Adversarial degrade on the *merged* gains — when adv is
        //    the owner this is exactly its standalone base-then-degrade
        //    order.
        for child in children.iter() {
            if let Child::Adv(c) = child {
                c.degrade_gains(&mut out.gains);
            }
        }

        // 5. Correlated shadowing, clamped back into the clip band.
        if let Some(sh) = shadow {
            sh.apply(&mut out.gains);
        }
    }

    fn peek(&self, base: &[Device]) -> Option<RoundEnv> {
        // Contract: foresight exists only when every child is
        // action-independent; a selection-reactive child (adv) makes
        // the next round depend on an action the server has not taken.
        if !self.previewable() {
            return None;
        }
        let mut preview = self
            .clone_previewable()
            .expect("previewable composites have only Clone children");
        debug_assert!(
            preview.previewable(),
            "composite peek must stay None under action-dependent children"
        );
        Some(preview.next_round(base))
    }

    fn observe_selection(&mut self, selected: &[usize]) {
        for child in self.children.iter_mut() {
            if let Child::Adv(c) = child {
                c.observe_selection(selected);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnvConfig, SystemConfig};
    use crate::env::{self, EnvKind};
    use crate::system::Fleet;

    fn setup(n: usize, k: usize, compose: &str) -> (SystemConfig, EnvConfig, Fleet) {
        let sys = SystemConfig {
            num_devices: n,
            k,
            ..SystemConfig::default()
        };
        let env_cfg = EnvConfig {
            compose: compose.to_string(),
            avail_p_drop: 0.3,
            avail_p_join: 0.3,
            drift_sigma: 0.05,
            trace_path: crate::test_util::campus_fixture(),
            ..EnvConfig::default()
        };
        let mut rng = Rng::new(4);
        let fleet = Fleet::generate(&sys, (50, 100), &mut rng);
        (sys, env_cfg, fleet)
    }

    /// `compose:<x>` must be byte-identical to `<x>` for every registry
    /// child, on both the RoundEnv and the SoA path.
    #[test]
    fn single_child_composite_is_identical_to_the_child() {
        for child in ["static", "ge", "avail", "drift", "trace", "adv"] {
            let (sys, env_cfg, fleet) = setup(12, 2, child);
            let kind = EnvKind::parse(child).unwrap();
            let init = EnvInit {
                sys: &sys,
                env: &env_cfg,
                seed: 29,
            };
            let mut solo = env::build(kind, &init).unwrap();
            let mut comp = env::build(EnvKind::Composite, &init).unwrap();
            let mut solo_soa = env::build(kind, &init).unwrap();
            let mut comp_soa = env::build(EnvKind::Composite, &init).unwrap();
            let (mut sa, mut sb) = (EnvSoA::new(), EnvSoA::new());
            for round in 0..40 {
                let ra = solo.next_round(&fleet.devices);
                let rb = comp.next_round(&fleet.devices);
                assert_eq!(ra.gains, rb.gains, "{child} gains, round {round}");
                assert_eq!(ra.available, rb.available, "{child} availability");
                match (&ra.devices, &rb.devices) {
                    (None, None) => {}
                    (Some(da), Some(db)) => {
                        for (x, y) in da.iter().zip(db) {
                            assert_eq!(x.f_max_hz, y.f_max_hz, "{child} f_max");
                            assert_eq!(x.alpha, y.alpha, "{child} alpha");
                        }
                    }
                    _ => panic!("{child}: devices overlay mismatch"),
                }
                solo_soa.step_into(&fleet.devices, &mut sa);
                comp_soa.step_into(&fleet.devices, &mut sb);
                assert_eq!(sa.gains, sb.gains, "{child} SoA gains");
                assert_eq!(sa.available, sb.available, "{child} SoA availability");
                assert_eq!(sa.all_available, sb.all_available, "{child} SoA flag");
                // Feed both adversaries the same selection so the
                // reactive paths stay comparable.
                solo.observe_selection(&[0, 1]);
                comp.observe_selection(&[0, 1]);
                solo_soa.observe_selection(&[0, 1]);
                comp_soa.observe_selection(&[0, 1]);
            }
        }
    }

    #[test]
    fn availability_is_the_and_of_the_children() {
        // avail+outage: every device offline under the composite must be
        // offline under at least one child run standalone with the same
        // seed (before the final K repair can only add devices back).
        let (sys, env_cfg, _fleet) = setup(40, 2, "avail+outage");
        let init = EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 9,
        };
        let mut comp = CompositeEnv::new(&init).unwrap();
        let mut avail = AvailabilityEnv::new(&init);
        let mut outage = OutageEnv::new(&init);
        let base: Vec<Device> = Vec::new();
        let mut saw_joint_restriction = false;
        for _ in 0..200 {
            let got = comp.next_round(&base);
            let on_a = avail.step_mask().to_vec();
            let on_o = outage.step_mask().to_vec();
            let sel = got.available.expect("avail child always reports N^t");
            let both: Vec<usize> = (0..40).filter(|&i| on_a[i] && on_o[i]).collect();
            // The composite set is `both` plus possibly K-repaired ids.
            for &i in &both {
                assert!(sel.contains(&i), "device {i} lost from the intersection");
            }
            assert!(sel.len() >= 2);
            saw_joint_restriction |= sel.len() < on_a.iter().filter(|&&b| b).count();
        }
        assert!(saw_joint_restriction, "outage never tightened avail");
    }

    #[test]
    fn peek_is_none_with_an_adversarial_child_and_exact_without() {
        let (sys, env_cfg, fleet) = setup(10, 2, "ge+adv");
        let init = EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 3,
        };
        let comp = CompositeEnv::new(&init).unwrap();
        assert!(!comp.previewable());
        assert!(comp.peek(&fleet.devices).is_none(), "adv child must kill foresight");

        let (sys, env_cfg, fleet) = setup(10, 2, "avail+ge+drift");
        let init = EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 3,
        };
        let mut comp = CompositeEnv::new(&init).unwrap();
        for _ in 0..15 {
            let peeked = comp.peek(&fleet.devices).expect("action-independent composite");
            let actual = comp.next_round(&fleet.devices);
            assert_eq!(peeked.gains, actual.gains);
            assert_eq!(peeked.available, actual.available);
        }
    }

    #[test]
    fn adv_child_degrades_the_merged_fading_gains() {
        // ge+adv: gains must come from the GE fading process with the
        // degrade applied on top — compare against a solo GE stream.
        let (sys, env_cfg, _fleet) = setup(10, 2, "ge+adv");
        let init = EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 21,
        };
        let mut comp = CompositeEnv::new(&init).unwrap();
        let mut ge = GilbertElliottEnv::new(&init);
        let base: Vec<Device> = Vec::new();
        for _ in 0..30 {
            let got = comp.next_round(&base).gains;
            let raw = ge.next_round(&base).gains;
            let mut degraded = 0usize;
            for (g, r) in got.iter().zip(&raw) {
                if g == r {
                    continue;
                }
                let want = (r * env_cfg.adv_degrade).max(sys.channel_clip.0);
                assert_eq!(*g, want, "degraded gain off the ge base");
                degraded += 1;
            }
            assert_eq!(degraded, 4.min(10), "budget 2K must bite on the merged gains");
        }
    }

    #[test]
    fn shadowing_correlates_the_fleet_and_stays_in_band() {
        let mk = |rho: f64| {
            let (sys, mut env_cfg, _fleet) = setup(400, 2, "static");
            env_cfg.shadow_std = 0.6;
            env_cfg.shadow_rho = rho;
            let init = EnvInit {
                sys: &sys,
                env: &env_cfg,
                seed: 17,
            };
            (CompositeEnv::new(&init).unwrap(), sys)
        };
        // Sample the mean log-gain per round; a strongly common field
        // moves the whole fleet together, so the round means spread far
        // more than under independent shadowing.
        let spread = |rho: f64| {
            let (mut env, sys) = mk(rho);
            let base: Vec<Device> = Vec::new();
            let mut means = Vec::new();
            for _ in 0..60 {
                let g = env.next_round(&base).gains;
                for &h in &g {
                    assert!((sys.channel_clip.0..=sys.channel_clip.1).contains(&h));
                }
                means.push(g.iter().map(|h| h.ln()).sum::<f64>() / g.len() as f64);
            }
            let m = means.iter().sum::<f64>() / means.len() as f64;
            means.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / means.len() as f64
        };
        let (corr, indep) = (spread(0.95), spread(0.0));
        assert!(
            corr > 4.0 * indep,
            "common shadow field must move round means: corr={corr} indep={indep}"
        );
    }

    #[test]
    fn zero_shadow_std_is_bitwise_inert() {
        let (sys, mut env_cfg, fleet) = setup(12, 2, "ge");
        let init = EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 8,
        };
        let mut plain = CompositeEnv::new(&init).unwrap();
        env_cfg.shadow_rho = 0.9; // rho alone must change nothing
        let init = EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 8,
        };
        let mut with_rho = CompositeEnv::new(&init).unwrap();
        for _ in 0..20 {
            assert_eq!(
                plain.next_round(&fleet.devices).gains,
                with_rho.next_round(&fleet.devices).gains
            );
        }
    }

    #[test]
    fn presets_expand_and_run() {
        for preset in ["diurnal", "flashcrowd", "outage"] {
            let (sys, env_cfg, fleet) = setup(30, 2, preset);
            let init = EnvInit {
                sys: &sys,
                env: &env_cfg,
                seed: 12,
            };
            let mut env = CompositeEnv::new(&init).unwrap();
            let mut saw_restriction = false;
            for _ in 0..300 {
                let re = env.next_round(&fleet.devices);
                assert_eq!(re.gains.len(), 30);
                if let Some(sel) = &re.available {
                    assert!(sel.len() >= 2, "{preset} starved the server");
                    saw_restriction |= sel.len() < 30;
                }
            }
            assert!(saw_restriction, "{preset} never took anyone offline");
        }
    }

    #[test]
    fn bad_specs_are_rejected() {
        for bad in ["", "ge+ge", "avail+nope", "compose"] {
            let (sys, env_cfg, _fleet) = setup(6, 2, bad);
            let init = EnvInit {
                sys: &sys,
                env: &env_cfg,
                seed: 1,
            };
            assert!(CompositeEnv::new(&init).is_err(), "spec {bad:?} should fail");
        }
    }
}
