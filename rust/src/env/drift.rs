//! Slow random-walk drift on per-device compute/energy parameters.

use super::{EnvInit, EnvSoA, Environment, RoundEnv};
use crate::rng::Rng;
use crate::system::{ChannelProcess, Device};

/// Compute-speed and energy-coefficient drift.
///
/// Each device carries two multiplicative random walks, both in log
/// space so they stay positive and mean-reverting clamps are symmetric:
///
/// * `m_f` scales the maximum CPU frequency `f_max` (thermal throttling,
///   background load);
/// * `m_a` scales the effective capacitance `alpha_n` (supply-voltage /
///   efficiency drift).
///
/// Per round: `m ← clamp(m · exp(σ·z), lo, hi)` with `z ~ N(0,1)`,
/// `σ = drift_sigma`, `(lo, hi) = drift_clip`.  Channel gains come from
/// the same [`ChannelProcess`] construction as the static environment.
/// The drifted parameters are what the cost model (and the round's
/// latency/energy) see; the control policy still planned against
/// whatever the environment reports, so an online controller is graded
/// on how it tracks the drift.
#[derive(Clone)]
pub struct DriftEnv {
    channel: ChannelProcess,
    streams: Vec<Rng>,
    m_f: Vec<f64>,
    m_a: Vec<f64>,
    sigma: f64,
    clip: (f64, f64),
}

impl DriftEnv {
    pub fn new(init: &EnvInit<'_>) -> Self {
        let n = init.sys.num_devices;
        let mut root = Rng::new(init.seed ^ 0xD81F_7000_5EED_0001);
        Self {
            channel: ChannelProcess::new(init.sys, init.seed),
            streams: (0..n).map(|i| root.fork(i as u64)).collect(),
            m_f: vec![1.0; n],
            m_a: vec![1.0; n],
            sigma: init.env.drift_sigma,
            clip: init.env.drift_clip,
        }
    }

    /// Current frequency multipliers; test/inspection hook.
    pub fn freq_multipliers(&self) -> &[f64] {
        &self.m_f
    }

    /// Advance both per-device walks one round — the single stepping
    /// implementation `next_round` and `step_into` share, so the RNG
    /// consumption order can never diverge between the two paths.
    fn advance_walks(&mut self) {
        let (lo, hi) = self.clip;
        for i in 0..self.streams.len() {
            let zf = self.streams[i].normal();
            let za = self.streams[i].normal();
            self.m_f[i] = (self.m_f[i] * (self.sigma * zf).exp()).clamp(lo, hi);
            self.m_a[i] = (self.m_a[i] * (self.sigma * za).exp()).clamp(lo, hi);
        }
    }

    /// Composite hook: advance only the walks (the composite's channel
    /// owner supplies the gains) and expose the round's multipliers.
    pub(crate) fn step_walks(&mut self) -> (&[f64], &[f64]) {
        self.advance_walks();
        (&self.m_f, &self.m_a)
    }

    /// Composite hook: the shared static-stream channel draw, used when
    /// this child is the composite's channel owner.
    pub(crate) fn step_channel_into(&mut self, out: &mut Vec<f64>) {
        self.channel.next_round_into(out);
    }
}

impl Environment for DriftEnv {
    fn name(&self) -> &'static str {
        "drift"
    }

    fn next_round(&mut self, base: &[Device]) -> RoundEnv {
        let gains = self.channel.next_round();
        self.advance_walks();
        let devices = base
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let mut d = d.clone();
                d.f_max_hz = (d.f_max_hz * self.m_f[i]).max(d.f_min_hz);
                d.alpha *= self.m_a[i];
                d
            })
            .collect();
        RoundEnv {
            gains,
            available: None,
            devices: Some(devices),
        }
    }

    fn step_into(&mut self, base: &[Device], out: &mut EnvSoA) {
        self.channel.next_round_into(&mut out.gains);
        self.advance_walks();
        // Same expressions as the per-Device path — only the two
        // parameters the walk actually moves are materialized.
        out.f_max_hz.clear();
        out.f_max_hz.extend(
            base.iter()
                .enumerate()
                .map(|(i, d)| (d.f_max_hz * self.m_f[i]).max(d.f_min_hz)),
        );
        out.alpha.clear();
        out.alpha
            .extend(base.iter().enumerate().map(|(i, d)| d.alpha * self.m_a[i]));
        out.drifted = true;
        out.set_all_available();
    }

    fn peek(&self, base: &[Device]) -> Option<RoundEnv> {
        // Action-independent: stepping a clone previews the stream.
        Some(self.clone().next_round(base))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnvConfig, SystemConfig};
    use crate::system::Fleet;

    fn setup(sigma: f64) -> (SystemConfig, EnvConfig, Fleet) {
        let sys = SystemConfig {
            num_devices: 8,
            ..SystemConfig::default()
        };
        let env_cfg = EnvConfig {
            drift_sigma: sigma,
            ..EnvConfig::default()
        };
        let mut rng = Rng::new(2);
        let fleet = Fleet::generate(&sys, (50, 100), &mut rng);
        (sys, env_cfg, fleet)
    }

    #[test]
    fn parameters_move_but_stay_clamped() {
        let (sys, env_cfg, fleet) = setup(0.1);
        let mut env = DriftEnv::new(&EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 17,
        });
        let mut moved = false;
        for _ in 0..150 {
            let re = env.next_round(&fleet.devices);
            let devs = re.devices.expect("drift returns devices");
            for (d, b) in devs.iter().zip(&fleet.devices) {
                assert!(d.f_max_hz >= d.f_min_hz);
                assert!(d.f_max_hz <= b.f_max_hz * env_cfg.drift_clip.1 * (1.0 + 1e-12));
                assert!(d.alpha >= b.alpha * env_cfg.drift_clip.0 * (1.0 - 1e-12));
                assert!(d.alpha <= b.alpha * env_cfg.drift_clip.1 * (1.0 + 1e-12));
                moved |= d.f_max_hz != b.f_max_hz;
            }
            // Static fields never drift.
            for (d, b) in devs.iter().zip(&fleet.devices) {
                assert_eq!(d.data_size, b.data_size);
                assert_eq!(d.energy_budget_j, b.energy_budget_j);
            }
        }
        assert!(moved, "drift never moved any parameter");
    }

    #[test]
    fn zero_sigma_is_the_identity_walk() {
        let (sys, env_cfg, fleet) = setup(0.0);
        let mut env = DriftEnv::new(&EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 17,
        });
        for _ in 0..20 {
            let re = env.next_round(&fleet.devices);
            for (d, b) in re.devices.unwrap().iter().zip(&fleet.devices) {
                assert_eq!(d.f_max_hz, b.f_max_hz);
                assert_eq!(d.alpha, b.alpha);
            }
        }
        assert!(env.freq_multipliers().iter().all(|&m| m == 1.0));
    }

    #[test]
    fn gains_match_the_static_channel_stream() {
        let (sys, env_cfg, fleet) = setup(0.05);
        let mut env = DriftEnv::new(&EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 23,
        });
        let mut reference = ChannelProcess::new(&sys, 23);
        for _ in 0..20 {
            assert_eq!(env.next_round(&fleet.devices).gains, reference.next_round());
        }
    }
}
