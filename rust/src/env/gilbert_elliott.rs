//! Gilbert–Elliott two-state Markov fading.

use super::{EnvInit, EnvSoA, Environment, RoundEnv};
use crate::rng::Rng;
use crate::system::{draw_clipped_exponential, Device};

/// Per-device two-state (good/bad) Markov channel.
///
/// Each device carries an independent chain: in the *good* state gains
/// are exponential with the paper's `channel_mean`; in the *bad* state
/// the mean drops to `channel_mean * ge_bad_scale` (deep fade).  Both
/// draws pass through the same clipped-exponential kernel as the static
/// channel, so samples stay inside the paper's outlier band.
///
/// Transitions: P(good → bad) = `ge_p_bad`, P(bad → good) = `ge_p_good`;
/// the initial state is drawn from the stationary distribution, so the
/// process has no burn-in transient.  One RNG stream per device (forked
/// from the root exactly like [`crate::system::ChannelProcess`]) carries
/// both the transition and the gain draws, so device `n`'s trajectory is
/// independent of the fleet size.
#[derive(Clone)]
pub struct GilbertElliottEnv {
    streams: Vec<Rng>,
    good: Vec<bool>,
    p_bad: f64,
    p_good: f64,
    good_mean: f64,
    bad_mean: f64,
    clip: (f64, f64),
}

impl GilbertElliottEnv {
    pub fn new(init: &EnvInit<'_>) -> Self {
        let n = init.sys.num_devices;
        let p_bad = init.env.ge_p_bad;
        let p_good = init.env.ge_p_good;
        // Stationary P(good); the all-absorbing corner (both probs 0)
        // degenerates to "always good".
        let pi_good = if p_bad + p_good > 0.0 {
            p_good / (p_bad + p_good)
        } else {
            1.0
        };
        let mut root = Rng::new(init.seed ^ 0x6E11_BE7A_57A7_E5F0);
        let mut streams: Vec<Rng> = (0..n).map(|i| root.fork(i as u64)).collect();
        let good = streams.iter_mut().map(|rng| rng.f64() < pi_good).collect();
        Self {
            streams,
            good,
            p_bad,
            p_good,
            good_mean: init.sys.channel_mean,
            bad_mean: init.sys.channel_mean * init.env.ge_bad_scale,
            clip: init.sys.channel_clip,
        }
    }

    /// Current per-device state (true = good); test/inspection hook.
    pub fn states(&self) -> &[bool] {
        &self.good
    }

    /// One round of the fading process into `out` (clear + extend): the
    /// per-device interleaving — transition draw, then gain draw, on one
    /// stream — is the single implementation both `next_round` and
    /// `step_into` consume, so the two paths cannot drift apart.
    pub(crate) fn draw_gains_into(&mut self, out: &mut Vec<f64>) {
        let (p_bad, p_good) = (self.p_bad, self.p_good);
        let (good_mean, bad_mean, clip) = (self.good_mean, self.bad_mean, self.clip);
        out.clear();
        out.extend(
            self.streams
                .iter_mut()
                .zip(self.good.iter_mut())
                .map(|(rng, good)| {
                    *good = super::step_two_state(rng, *good, p_bad, p_good);
                    let mean = if *good { good_mean } else { bad_mean };
                    draw_clipped_exponential(rng, mean, clip)
                }),
        );
    }
}

impl Environment for GilbertElliottEnv {
    fn name(&self) -> &'static str {
        "ge"
    }

    fn next_round(&mut self, _base: &[Device]) -> RoundEnv {
        let mut gains = Vec::with_capacity(self.streams.len());
        self.draw_gains_into(&mut gains);
        RoundEnv {
            gains,
            available: None,
            devices: None,
        }
    }

    fn step_into(&mut self, _base: &[Device], out: &mut EnvSoA) {
        self.draw_gains_into(&mut out.gains);
        out.set_all_available();
        out.set_undrifted();
    }

    fn peek(&self, base: &[Device]) -> Option<RoundEnv> {
        // Action-independent: stepping a clone previews the stream.
        Some(self.clone().next_round(base))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnvConfig, SystemConfig};

    fn build(seed: u64, env_cfg: &EnvConfig) -> GilbertElliottEnv {
        let sys = SystemConfig {
            num_devices: 20,
            ..SystemConfig::default()
        };
        GilbertElliottEnv::new(&EnvInit {
            sys: &sys,
            env: env_cfg,
            seed,
        })
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let cfg = EnvConfig::default();
        let (mut a, mut b, mut c) = (build(5, &cfg), build(5, &cfg), build(6, &cfg));
        let base: Vec<Device> = Vec::new();
        let mut diverged = false;
        for _ in 0..50 {
            let (ra, rb, rc) = (a.next_round(&base), b.next_round(&base), c.next_round(&base));
            assert_eq!(ra.gains, rb.gains);
            diverged |= ra.gains != rc.gains;
        }
        assert!(diverged, "different seeds should give different fading");
    }

    #[test]
    fn bad_state_drags_the_long_run_mean_down() {
        // With fading the time-average gain must sit clearly below the
        // good-state mean (some rounds are deep fades).
        let cfg = EnvConfig {
            ge_p_bad: 0.4,
            ge_p_good: 0.4,
            ..EnvConfig::default()
        };
        let mut env = build(9, &cfg);
        let base: Vec<Device> = Vec::new();
        let mut sum = 0.0;
        let mut count = 0usize;
        for _ in 0..400 {
            for h in env.next_round(&base).gains {
                sum += h;
                count += 1;
            }
        }
        let mean = sum / count as f64;
        // Static clipped mean is ~0.095; half the time in a deep fade
        // pulls it well under that.
        assert!(mean < 0.08, "fading mean {mean} too close to static");
    }

    #[test]
    fn state_chain_actually_transitions() {
        let cfg = EnvConfig::default();
        let mut env = build(11, &cfg);
        let base: Vec<Device> = Vec::new();
        let start = env.states().to_vec();
        let mut moved = false;
        for _ in 0..60 {
            env.next_round(&base);
            moved |= env.states() != &start[..];
        }
        assert!(moved, "no transition in 60 rounds");
    }
}
