//! `lroa trace import`: convert an external measurement log into the
//! trace-replay schema.
//!
//! Real measurement campaigns rarely log in the replay format
//! (`round,device,gain[,available]`, documented in
//! `tests/fixtures/README.md`): columns carry campaign-specific names,
//! signal strength arrives in dB, timestamps are seconds rather than
//! round indices, and some samples record only presence (no gain).
//! This module bridges that gap deterministically:
//!
//! * **column mapping** — `--round-col/--device-col/--gain-col/
//!   --avail-col` locate the source columns by (case-insensitive)
//!   header name; the availability column is auto-detected as
//!   `available` when present and not named explicitly;
//! * **unit conversion** — `--gain-db` converts dB power ratios to
//!   linear (`10^(g/10)`), then `--gain-scale` multiplies (so
//!   `--gain-db --gain-scale=2` means "dB, then doubled");
//! * **time binning** — with `--round-per=F` the round column is a raw
//!   timestamp and rounds become `floor(t / F)`; samples landing in the
//!   same (round, device) bin aggregate (mean gain, AND availability);
//!   without it the round column must already hold integers;
//! * **gap interpolation** — a row with an empty gain field (or a bin
//!   with only availability samples) keeps its availability step but
//!   gets a gain linearly interpolated between the device's neighboring
//!   measured bins (held flat at the ends), mirroring how the replayer
//!   itself treats sparse rounds;
//! * **normalization** — rounds are rebased so the earliest bin is
//!   round 0, and device keys (arbitrary strings: ids, MACs, hostnames)
//!   are remapped to contiguous track numbers in order of first
//!   appearance.
//!
//! The converted body is round-tripped through the replay parser
//! ([`super::trace`]) **before** anything is written, so an `import`ed
//! file can never fail to load under `--envs=trace:<path>`.

use std::path::PathBuf;

use crate::Result;

/// What to import and how to map it (the `lroa trace import` flags).
#[derive(Clone, Debug)]
pub struct ImportSpec {
    /// Source measurement CSV.
    pub input: PathBuf,
    /// Destination trace CSV (`--out`).
    pub output: PathBuf,
    /// Source column holding the round index or timestamp.
    pub round_col: String,
    /// Source column holding the device key (any string).
    pub device_col: String,
    /// Source column holding the channel gain / signal measurement.
    pub gain_col: String,
    /// Source column holding on/off availability; `None` auto-detects a
    /// column named `available` and otherwise imports availability-less.
    pub avail_col: Option<String>,
    /// Multiplier applied to gains after any dB conversion.
    pub gain_scale: f64,
    /// Treat the gain column as dB: convert via `10^(g/10)` first.
    pub gain_db: bool,
    /// Bin width for timestamp rounds (`round = floor(t / per)`);
    /// `None` requires integer rounds.
    pub round_per: Option<f64>,
}

impl ImportSpec {
    /// Default mapping: the replay schema's own column names, linear
    /// gains, integer rounds.
    pub fn new(input: impl Into<PathBuf>, output: impl Into<PathBuf>) -> Self {
        Self {
            input: input.into(),
            output: output.into(),
            round_col: "round".into(),
            device_col: "device".into(),
            gain_col: "gain".into(),
            avail_col: None,
            gain_scale: 1.0,
            gain_db: false,
            round_per: None,
        }
    }
}

/// What an import produced — the `--json` report body.
#[derive(Clone, Debug)]
pub struct ImportStats {
    /// Output tracks (devices after remapping).
    pub devices: usize,
    /// Distinct output rounds.
    pub rounds: usize,
    /// Output data rows.
    pub rows: usize,
    /// Gains filled by gap interpolation.
    pub interpolated: usize,
    /// Replay period of the output (max round + 1).
    pub period: usize,
    /// Whether the output carries an `available` column.
    pub has_availability: bool,
}

/// One aggregated (round, device) bin.
#[derive(Clone, Copy, Default)]
struct Bin {
    gain_sum: f64,
    gain_n: usize,
    /// AND of the bin's availability samples; `None` = no sample (on).
    avail: Option<bool>,
}

/// Run the import: read, convert, verify against the replay parser,
/// then write `spec.output`.
pub fn import_csv(spec: &ImportSpec) -> Result<ImportStats> {
    let text = std::fs::read_to_string(&spec.input)
        .map_err(|e| anyhow::anyhow!("trace import {:?}: {e}", spec.input))?;
    let (body, mut stats) = convert(spec, &text)?;
    // Round-trip through the replay parser before any byte lands on
    // disk: the import contract is "output always loads".
    let (tracks, period) = super::trace::validate_trace(&body)
        .map_err(|e| anyhow::anyhow!("internal: converted trace failed to re-parse: {e}"))?;
    anyhow::ensure!(
        tracks == stats.devices,
        "internal: converted trace has {tracks} tracks, expected {}",
        stats.devices
    );
    stats.period = period;
    if let Some(parent) = spec.output.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(&spec.output, body)
        .map_err(|e| anyhow::anyhow!("trace import --out={:?}: {e}", spec.output))?;
    Ok(stats)
}

/// Pure conversion: measurement CSV text in, replay-schema CSV body +
/// stats out.  Split from the I/O so tests can exercise every mapping
/// without touching disk.
fn convert(spec: &ImportSpec, text: &str) -> Result<(String, ImportStats)> {
    let mut lines = text.lines().enumerate();
    let header = loop {
        match lines.next() {
            Some((_, l)) if l.trim().is_empty() || l.trim_start().starts_with('#') => continue,
            Some((_, l)) => break l.trim(),
            None => anyhow::bail!("empty input file"),
        }
    };
    let cols: Vec<&str> = header.split(',').map(str::trim).collect();
    let find = |name: &str| cols.iter().position(|c| c.eq_ignore_ascii_case(name));
    let need = |name: &str| {
        find(name).ok_or_else(|| {
            anyhow::anyhow!("input has no column {name:?} (header: {header:?})")
        })
    };
    let round_i = need(&spec.round_col)?;
    let device_i = need(&spec.device_col)?;
    let gain_i = need(&spec.gain_col)?;
    let avail_i = match &spec.avail_col {
        Some(name) => Some(need(name)?),
        None => find("available"),
    };
    anyhow::ensure!(
        spec.gain_scale.is_finite() && spec.gain_scale > 0.0,
        "--gain-scale must be finite and > 0"
    );
    if let Some(per) = spec.round_per {
        anyhow::ensure!(
            per.is_finite() && per > 0.0,
            "--round-per must be finite and > 0"
        );
    }

    // Device keys are arbitrary strings; tracks are numbered in order
    // of first appearance (deterministic, and numeric keys keep their
    // log order instead of sorting lexicographically).
    let mut track_of: std::collections::HashMap<String, usize> = std::collections::HashMap::new();
    let mut keys: Vec<String> = Vec::new();
    let mut bins: Vec<std::collections::BTreeMap<u64, Bin>> = Vec::new();

    for (lineno, raw) in lines {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        anyhow::ensure!(
            fields.len() == cols.len(),
            "line {}: expected {} fields, got {}",
            lineno + 1,
            cols.len(),
            fields.len()
        );
        let t: f64 = fields[round_i].parse().map_err(|e| {
            anyhow::anyhow!("line {}: bad {} value: {e}", lineno + 1, spec.round_col)
        })?;
        anyhow::ensure!(
            t.is_finite() && t >= 0.0,
            "line {}: {} must be finite and >= 0",
            lineno + 1,
            spec.round_col
        );
        let round = match spec.round_per {
            Some(per) => (t / per).floor() as u64,
            None => {
                anyhow::ensure!(
                    t.fract() == 0.0,
                    "line {}: non-integer round {t} (pass --round-per=F to bin timestamps)",
                    lineno + 1
                );
                t as u64
            }
        };
        let key = fields[device_i];
        anyhow::ensure!(!key.is_empty(), "line {}: empty device key", lineno + 1);
        let track = *track_of.entry(key.to_string()).or_insert_with(|| {
            keys.push(key.to_string());
            bins.push(std::collections::BTreeMap::new());
            keys.len() - 1
        });
        let bin = bins[track].entry(round).or_default();
        if !fields[gain_i].is_empty() {
            let mut g: f64 = fields[gain_i].parse().map_err(|e| {
                anyhow::anyhow!("line {}: bad {} value: {e}", lineno + 1, spec.gain_col)
            })?;
            anyhow::ensure!(g.is_finite(), "line {}: non-finite gain", lineno + 1);
            if spec.gain_db {
                g = 10f64.powf(g / 10.0);
            }
            g *= spec.gain_scale;
            anyhow::ensure!(
                g.is_finite() && g > 0.0,
                "line {}: gain must be finite and > 0 after conversion (got {g})",
                lineno + 1
            );
            bin.gain_sum += g;
            bin.gain_n += 1;
        }
        if let Some(ai) = avail_i {
            let field = fields[ai];
            if !field.is_empty() {
                let on = if field == "1" || field.eq_ignore_ascii_case("true") {
                    true
                } else if field == "0" || field.eq_ignore_ascii_case("false") {
                    false
                } else {
                    anyhow::bail!(
                        "line {}: bad availability {field:?} (0|1|true|false)",
                        lineno + 1
                    );
                };
                // AND within the bin: one offline sample marks the bin.
                bin.avail = Some(bin.avail.unwrap_or(true) && on);
            }
        }
    }
    anyhow::ensure!(!bins.is_empty(), "input has no data rows");

    // Rebase rounds so the earliest bin is round 0.
    let r0 = bins
        .iter()
        .filter_map(|b| b.keys().next().copied())
        .min()
        .expect("bins is non-empty");

    let has_avail = avail_i.is_some();
    let mut rows: Vec<(u64, usize, f64, bool)> = Vec::new();
    let mut interpolated = 0usize;
    for (track, device_bins) in bins.iter().enumerate() {
        let rounds: Vec<u64> = device_bins.keys().map(|&r| r - r0).collect();
        let means: Vec<Option<f64>> = device_bins
            .values()
            .map(|b| {
                if b.gain_n > 0 {
                    Some(b.gain_sum / b.gain_n as f64)
                } else {
                    None
                }
            })
            .collect();
        let avails: Vec<bool> = device_bins
            .values()
            .map(|b| b.avail.unwrap_or(true))
            .collect();
        let known: Vec<usize> = (0..means.len()).filter(|&i| means[i].is_some()).collect();
        anyhow::ensure!(
            !known.is_empty(),
            "device {:?} has no gain samples to interpolate from",
            keys[track]
        );
        for i in 0..rounds.len() {
            let gain = match means[i] {
                Some(g) => g,
                None => {
                    interpolated += 1;
                    // Linear between the neighboring measured bins in
                    // round time, held flat past the ends — the same
                    // convention the replayer applies between rounds.
                    let next = known.partition_point(|&k| k < i);
                    if next == 0 {
                        means[known[0]].unwrap()
                    } else if next == known.len() {
                        means[known[known.len() - 1]].unwrap()
                    } else {
                        let (il, ir) = (known[next - 1], known[next]);
                        let (gl, gr) = (means[il].unwrap(), means[ir].unwrap());
                        let frac =
                            (rounds[i] - rounds[il]) as f64 / (rounds[ir] - rounds[il]) as f64;
                        gl + (gr - gl) * frac
                    }
                }
            };
            rows.push((rounds[i], track, gain, avails[i]));
        }
    }
    // Round-major, device-minor: per-device rounds stay ascending (the
    // parser's requirement) and the file reads like a timeline.
    rows.sort_by_key(|&(r, d, _, _)| (r, d));

    let mut body = String::new();
    body.push_str(if has_avail {
        "round,device,gain,available\n"
    } else {
        "round,device,gain\n"
    });
    let mut distinct_rounds = 0usize;
    let mut last_round: Option<u64> = None;
    for &(r, d, g, a) in &rows {
        if last_round != Some(r) {
            distinct_rounds += 1;
            last_round = Some(r);
        }
        if has_avail {
            body.push_str(&format!("{r},{d},{g},{}\n", if a { 1 } else { 0 }));
        } else {
            body.push_str(&format!("{r},{d},{g}\n"));
        }
    }
    let stats = ImportStats {
        devices: keys.len(),
        rounds: distinct_rounds,
        rows: rows.len(),
        interpolated,
        period: 0, // filled from the round-trip parse in import_csv
        has_availability: has_avail,
    };
    Ok((body, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnvConfig, SystemConfig};
    use crate::env::{EnvInit, Environment};

    fn spec() -> ImportSpec {
        ImportSpec::new("in.csv", "out.csv")
    }

    #[test]
    fn identity_schema_passes_through() {
        let (body, stats) = convert(
            &spec(),
            "round,device,gain,available\n0,0,0.1,1\n0,1,0.2,1\n1,0,0.3,0\n",
        )
        .unwrap();
        assert_eq!(
            body,
            "round,device,gain,available\n0,0,0.1,1\n0,1,0.2,1\n1,0,0.3,0\n"
        );
        assert_eq!(stats.devices, 2);
        assert_eq!(stats.rounds, 2);
        assert_eq!(stats.rows, 3);
        assert_eq!(stats.interpolated, 0);
        assert!(stats.has_availability);
    }

    #[test]
    fn column_mapping_db_conversion_and_scale() {
        let mut s = spec();
        s.round_col = "ts".into();
        s.device_col = "node".into();
        s.gain_col = "rssi".into();
        s.avail_col = Some("up".into());
        s.gain_db = true;
        s.gain_scale = 2.0;
        // Columns in scrambled order, extra column ignored, -10 dB = 0.1
        // linear, then doubled.
        let (body, stats) = convert(
            &s,
            "node,extra,rssi,up,ts\nmac-a,x,-10,1,0\nmac-b,x,0,true,0\n",
        )
        .unwrap();
        let rows: Vec<Vec<&str>> = body.lines().map(|l| l.split(',').collect()).collect();
        assert_eq!(rows[0], vec!["round", "device", "gain", "available"]);
        assert_eq!((rows[1][0], rows[1][1], rows[1][3]), ("0", "0", "1"));
        assert!((rows[1][2].parse::<f64>().unwrap() - 0.2).abs() < 1e-12);
        assert_eq!((rows[2][0], rows[2][1], rows[2][3]), ("0", "1", "1"));
        assert!((rows[2][2].parse::<f64>().unwrap() - 2.0).abs() < 1e-12);
        assert_eq!(stats.devices, 2);
        assert!(stats.has_availability);
    }

    #[test]
    fn timestamps_bin_aggregate_and_rebase() {
        let mut s = spec();
        s.round_per = Some(10.0);
        // Bins: t in [10,20) -> raw round 1, [20,30) -> 2; rebased so the
        // earliest bin is round 0.  Two samples in one bin average (the
        // values are binary-exact so the mean prints exactly).
        let (body, stats) = convert(
            &s,
            "round,device,gain\n12.5,7,0.25\n17.0,7,0.75\n24.0,7,0.5\n",
        )
        .unwrap();
        assert_eq!(body, "round,device,gain\n0,0,0.5\n1,0,0.5\n");
        assert_eq!(stats.rounds, 2);
        assert_eq!(stats.rows, 2);
        // Without --round-per, fractional rounds are rejected with a
        // pointer at the flag.
        let err = convert(&spec(), "round,device,gain\n12.5,7,0.1\n").unwrap_err();
        assert!(err.to_string().contains("--round-per"), "{err}");
    }

    #[test]
    fn gaps_interpolate_between_measured_bins() {
        // Device 0: measured 0.25 at round 0 and 0.75 at round 4; round 1
        // has only an availability sample -> interpolated
        // 0.25 + (0.75-0.25)/4 = 0.375 (binary-exact); round 6 is past
        // the last measurement -> held flat at 0.75.
        let (body, stats) = convert(
            &spec(),
            "round,device,gain,available\n\
             0,0,0.25,1\n1,0,,0\n4,0,0.75,1\n6,0,,1\n",
        )
        .unwrap();
        assert_eq!(
            body,
            "round,device,gain,available\n0,0,0.25,1\n1,0,0.375,0\n4,0,0.75,1\n6,0,0.75,1\n"
        );
        assert_eq!(stats.interpolated, 2);
        // A device with availability rows but no gain at all cannot be
        // interpolated.
        let err = convert(
            &spec(),
            "round,device,gain,available\n0,a,0.1,1\n0,b,,1\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("no gain samples"), "{err}");
    }

    #[test]
    fn bin_availability_is_the_and_of_its_samples() {
        let mut s = spec();
        s.round_per = Some(10.0);
        let (body, _) = convert(
            &s,
            "round,device,gain,available\n0,0,0.25,1\n5,0,0.75,0\n9,0,0.5,1\n",
        )
        .unwrap();
        assert_eq!(body, "round,device,gain,available\n0,0,0.5,0\n");
    }

    #[test]
    fn bad_inputs_name_the_line_or_column() {
        let cases: &[(&str, &str)] = &[
            ("", "empty input"),
            ("round,device\n0,0\n", "no column"),
            ("round,device,gain\n", "no data rows"),
            ("round,device,gain\n-1,0,0.1\n", ">= 0"),
            ("round,device,gain\n0,,0.1\n", "empty device"),
            ("round,device,gain\n0,0,nope\n", "bad gain"),
            ("round,device,gain\n0,0,0\n", "> 0"),
            ("round,device,gain,available\n0,0,0.1,maybe\n", "0|1"),
        ];
        for (text, needle) in cases {
            let err = convert(&spec(), text).unwrap_err();
            assert!(
                err.to_string().contains(needle),
                "input {text:?}: error {err} should mention {needle:?}"
            );
        }
    }

    #[test]
    fn imported_file_replays_through_the_trace_env() {
        let dir = std::env::temp_dir().join("lroa_import_test");
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("field_log.csv");
        std::fs::write(
            &input,
            "ts,node,rssi_db,up\n\
             0,gw-1,-10,1\n0,gw-2,-3,1\n\
             30,gw-1,-13,0\n30,gw-2,-3,1\n\
             60,gw-1,-10,1\n60,gw-2,-6,1\n",
        )
        .unwrap();
        let mut s = ImportSpec::new(&input, dir.join("imported.csv"));
        s.round_col = "ts".into();
        s.device_col = "node".into();
        s.gain_col = "rssi_db".into();
        s.avail_col = Some("up".into());
        s.gain_db = true;
        s.round_per = Some(30.0);
        let stats = import_csv(&s).unwrap();
        assert_eq!(stats.devices, 2);
        assert_eq!(stats.period, 3);
        assert!(stats.has_availability);

        // The written file loads and replays under the trace env.
        let sys = SystemConfig {
            num_devices: 2,
            k: 1,
            ..SystemConfig::default()
        };
        let env_cfg = EnvConfig {
            trace_path: s.output.to_string_lossy().into_owned(),
            ..EnvConfig::default()
        };
        let mut env = crate::env::TraceEnv::new(&EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 0,
        })
        .unwrap();
        let base: Vec<crate::system::Device> = Vec::new();
        let r0 = env.next_round(&base);
        assert!((r0.gains[0] - 0.1).abs() < 1e-12);
        assert_eq!(r0.available, None);
        let r1 = env.next_round(&base);
        // gw-1 offline in bin 1 (K floor keeps gw-2's sibling count >= 1).
        assert_eq!(r1.available, Some(vec![1]));
    }
}
