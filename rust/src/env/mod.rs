//! Dynamic edge environments: the per-round realization of system
//! randomness as a pluggable, first-class sweep axis.
//!
//! The paper evaluates LROA only under an IID exponential channel and an
//! always-on fleet, but its claim — Lyapunov-based online control works
//! *without knowledge of future dynamics* — is best stressed under
//! non-stationary conditions.  An [`Environment`] owns everything the
//! physical world decides each round:
//!
//! * the channel gains `h_n^t`,
//! * the reachable candidate set `N^t` (device availability), and
//! * any slow drift of per-device compute/energy parameters.
//!
//! The FL server draws one [`RoundEnv`] per round and hands policies only
//! the available sub-problem; policies never see (and cannot schedule)
//! unreachable devices.  Adding an environment is one impl plus one
//! [`REGISTRY`] line, mirroring [`crate::control::policy`].
//!
//! The seven registered environments:
//!
//! | name      | channel                      | availability     | parameters |
//! |-----------|------------------------------|------------------|------------|
//! | `static`  | IID exponential (the paper)  | always-on        | fixed      |
//! | `ge`      | Gilbert–Elliott Markov fading| always-on        | fixed      |
//! | `avail`   | IID exponential              | Markov on/off    | fixed      |
//! | `drift`   | IID exponential              | always-on        | random walk|
//! | `trace`   | recorded CSV log (replayed)  | from the log     | fixed      |
//! | `adv`     | adversarially degraded exp.  | always-on        | fixed      |
//! | `compose` | from the child spec          | AND of children  | from drift |
//!
//! `compose` ([`CompositeEnv`]) layers any subset of the others — plus
//! the composite-only scenario generators of [`scenario`] (diurnal
//! cycles, flash crowds, regional outages) and an optional correlated
//! shadow-fading field — into one round process, configured by
//! `env.compose` / the `compose:<a>+<b>+...` axis syntax.
//!
//! `static` is bitwise-identical to the pre-env [`ChannelProcess`] path
//! (`tests/policy_parity.rs` proves it), so the paper's figures are
//! untouched by this layer.  `avail`, `drift`, and `adv` reuse the *same*
//! channel construction as `static`, so their gains coincide with (or,
//! for `adv`, start from) the static realization round for round — the
//! masking/drift/degradation is the only delta, which makes robustness
//! comparisons clean.
//!
//! Two trait hooks extend the per-round contract:
//!
//! * [`Environment::peek`] previews the *next* round without advancing
//!   the stream — `Some` only for action-independent environments, whose
//!   future is a pure function of their state; the adversarial channel
//!   returns `None` because its next round depends on the selection it
//!   has not yet observed.  The oracle regret anchor
//!   ([`crate::control::policy`]) is the consumer.
//! * [`Environment::observe_selection`] feeds the realized selection
//!   back after each round; only reactive environments (`adv`) listen.
//!
//! [`ChannelProcess`]: crate::system::ChannelProcess

mod adversarial;
mod availability;
mod composite;
mod drift;
mod gilbert_elliott;
pub mod import;
pub mod scenario;
mod static_env;
mod trace;

pub use adversarial::AdversarialEnv;
pub use availability::AvailabilityEnv;
pub use composite::CompositeEnv;
pub use drift::DriftEnv;
pub use gilbert_elliott::GilbertElliottEnv;
pub use import::{import_csv, ImportSpec, ImportStats};
pub use static_env::StaticEnv;
pub use trace::TraceEnv;

use crate::config::{EnvConfig, EnvKind, SystemConfig};
use crate::rng::Rng;
use crate::system::Device;
use crate::Result;

/// One step of a two-state Markov chain, consuming one uniform draw:
/// from state `A` leave with probability `p_leave`; from state `¬A`
/// return with probability `p_enter`.  Returns the new "in `A`" flag.
/// Shared by the fading (good/bad) and availability (on/off) chains so
/// the transition convention can never diverge between environments.
pub(crate) fn step_two_state(rng: &mut Rng, in_a: bool, p_leave: f64, p_enter: f64) -> bool {
    let u = rng.f64();
    if in_a {
        u >= p_leave
    } else {
        u < p_enter
    }
}

/// One round's environment realization.
pub struct RoundEnv {
    /// Channel gains `h_n^t`, one per device (drawn for *every* device —
    /// also unreachable ones — so gain streams never depend on the
    /// availability trajectory).
    pub gains: Vec<f64>,
    /// Sorted global ids of the devices reachable this round (`N^t`);
    /// `None` means "the whole fleet" — always-on environments return it
    /// so the per-round fast path never allocates an identity map.
    pub available: Option<Vec<usize>>,
    /// Drifted per-device parameters, when the environment moves them;
    /// `None` means "use the base fleet unchanged".
    pub devices: Option<Vec<Device>>,
}

/// Struct-of-arrays view of one round's environment realization — the
/// fleet-scale sibling of [`RoundEnv`], mirroring
/// [`crate::system::FleetSoA`]'s clear + push refill idiom: the server
/// owns one and every [`Environment::step_into`] call refills it in
/// place, so a steady-state round draws a 1M-device environment without
/// touching the heap.
///
/// Only `f_max_hz` and `alpha` appear as drift channels because those
/// are the only per-device parameters any registered environment moves
/// ([`DriftEnv`]); growing the drift surface means adding an array here
/// and a line to the parity tests.
#[derive(Clone, Debug, Default)]
pub struct EnvSoA {
    /// Channel gains `h_n^t`, one per device.
    pub gains: Vec<f64>,
    /// Sorted global ids of the reachable devices; meaningful only when
    /// `all_available` is false (the flag plays [`RoundEnv::available`]'s
    /// `None` role without an allocation).
    pub available: Vec<usize>,
    /// Whole fleet reachable this round (always-on environments).
    pub all_available: bool,
    /// Drifted `f_max_hz` per device; meaningful only when `drifted`.
    pub f_max_hz: Vec<f64>,
    /// Drifted `alpha` per device; meaningful only when `drifted`.
    pub alpha: Vec<f64>,
    /// The environment moved per-device parameters this round.
    pub drifted: bool,
}

impl EnvSoA {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark the whole fleet reachable (clears any stale id list).
    pub fn set_all_available(&mut self) {
        self.available.clear();
        self.all_available = true;
    }

    /// Mark parameters undrifted (clears any stale overlays).
    pub fn set_undrifted(&mut self) {
        self.f_max_hz.clear();
        self.alpha.clear();
        self.drifted = false;
    }

    /// Number of reachable devices, given the fleet size `n`.
    pub fn num_available(&self, n: usize) -> usize {
        if self.all_available {
            n
        } else {
            self.available.len()
        }
    }

    /// Refill from a per-`Device` [`RoundEnv`] — the compatibility
    /// adapter behind the default [`Environment::step_into`], used by
    /// environments without a specialized slice path (`trace`, `adv`).
    /// Clear + extend, so capacity is retained across rounds even
    /// through the adapter.
    pub fn set_from_round(&mut self, round: &RoundEnv) {
        self.gains.clear();
        self.gains.extend_from_slice(&round.gains);
        match &round.available {
            Some(av) => {
                self.available.clear();
                self.available.extend_from_slice(av);
                self.all_available = false;
            }
            None => self.set_all_available(),
        }
        match &round.devices {
            Some(devs) => {
                self.f_max_hz.clear();
                self.f_max_hz.extend(devs.iter().map(|d| d.f_max_hz));
                self.alpha.clear();
                self.alpha.extend(devs.iter().map(|d| d.alpha));
                self.drifted = true;
            }
            None => self.set_undrifted(),
        }
    }
}

/// One dynamic-environment model's behaviour across rounds.
///
/// Environments are stateful (Markov chains, random walks) and own their
/// RNG streams; a fixed seed fully determines the whole trajectory, and
/// per-device streams are forked so device `n`'s realization never
/// depends on the fleet size or on other devices' draws.
pub trait Environment: Send {
    /// Registry name.
    fn name(&self) -> &'static str;

    /// Realize the next round: gains, candidate set, parameter drift.
    /// `base` is the fleet's static parameter set (drift applies on top).
    fn next_round(&mut self, base: &[Device]) -> RoundEnv;

    /// Realize the next round straight into a caller-owned [`EnvSoA`]
    /// (clear + extend refill — alloc-free at stable capacity): the
    /// fleet-scale sibling of [`Environment::next_round`].  Both paths
    /// consume the *same* RNG stream in the *same* order, so one
    /// environment instance stepped through `step_into` is bitwise
    /// identical to a same-seed twin stepped through `next_round` —
    /// `tests/env_determinism.rs` pins this for every registry entry.
    ///
    /// The default adapter delegates to `next_round` (paying its
    /// allocations), which keeps environments without a hot slice path
    /// (`trace`, `adv`) correct by construction; the four synthetic
    /// environments override it with specialized alloc-free impls.
    fn step_into(&mut self, base: &[Device], out: &mut EnvSoA) {
        let round = self.next_round(base);
        out.set_from_round(&round);
    }

    /// Preview the round that the *next* [`Environment::next_round`] call
    /// will realize, without advancing the stream.  Default `None`: the
    /// environment cannot be previewed.  Action-independent environments
    /// implement it by stepping a clone of their state, so a peek
    /// followed by `next_round` returns the identical realization; the
    /// adversarial channel keeps the default because its future depends
    /// on a selection that has not happened yet.
    fn peek(&self, base: &[Device]) -> Option<RoundEnv> {
        let _ = base;
        None
    }

    /// Feed back the round's realized selection (unique global device
    /// ids).  Only reactive environments (`adv`) care; the default
    /// ignores it.
    fn observe_selection(&mut self, _selected: &[usize]) {}
}

/// Everything an environment constructor may need.
pub struct EnvInit<'a> {
    pub sys: &'a SystemConfig,
    pub env: &'a EnvConfig,
    /// Channel-stream seed (the server passes its channel seed here, so
    /// `static` reproduces the pre-env gain streams bitwise).
    pub seed: u64,
}

/// Constructors are fallible: the trace environment parses its log file
/// at build time (missing file / bad schema must surface as a config
/// error, not a panic inside the round loop).
type EnvCtor = fn(&EnvInit<'_>) -> Result<Box<dyn Environment>>;

/// One registry row: environment id, canonical name, constructor.
pub struct EnvSpec {
    pub id: EnvKind,
    pub name: &'static str,
    pub build: EnvCtor,
}

fn build_static(init: &EnvInit<'_>) -> Result<Box<dyn Environment>> {
    Ok(Box::new(StaticEnv::new(init)))
}

fn build_gilbert_elliott(init: &EnvInit<'_>) -> Result<Box<dyn Environment>> {
    Ok(Box::new(GilbertElliottEnv::new(init)))
}

fn build_availability(init: &EnvInit<'_>) -> Result<Box<dyn Environment>> {
    Ok(Box::new(AvailabilityEnv::new(init)))
}

fn build_drift(init: &EnvInit<'_>) -> Result<Box<dyn Environment>> {
    Ok(Box::new(DriftEnv::new(init)))
}

fn build_trace(init: &EnvInit<'_>) -> Result<Box<dyn Environment>> {
    Ok(Box::new(TraceEnv::new(init)?))
}

fn build_adversarial(init: &EnvInit<'_>) -> Result<Box<dyn Environment>> {
    Ok(Box::new(AdversarialEnv::new(init)))
}

fn build_composite(init: &EnvInit<'_>) -> Result<Box<dyn Environment>> {
    Ok(Box::new(CompositeEnv::new(init)?))
}

/// The name → constructor registry all dispatch goes through.
pub const REGISTRY: &[EnvSpec] = &[
    EnvSpec {
        id: EnvKind::Static,
        name: "static",
        build: build_static,
    },
    EnvSpec {
        id: EnvKind::GilbertElliott,
        name: "ge",
        build: build_gilbert_elliott,
    },
    EnvSpec {
        id: EnvKind::Availability,
        name: "avail",
        build: build_availability,
    },
    EnvSpec {
        id: EnvKind::Drift,
        name: "drift",
        build: build_drift,
    },
    EnvSpec {
        id: EnvKind::Trace,
        name: "trace",
        build: build_trace,
    },
    EnvSpec {
        id: EnvKind::Adversarial,
        name: "adv",
        build: build_adversarial,
    },
    EnvSpec {
        id: EnvKind::Composite,
        name: "compose",
        build: build_composite,
    },
];

/// Build the registered environment for a config [`EnvKind`] id.
pub fn build(kind: EnvKind, init: &EnvInit<'_>) -> Result<Box<dyn Environment>> {
    let spec = REGISTRY
        .iter()
        .find(|s| s.id == kind)
        .expect("every EnvKind variant is registered");
    (spec.build)(init)
}

/// Build an environment by name or alias (alias table: [`EnvKind::parse`]).
pub fn from_name(name: &str, init: &EnvInit<'_>) -> Result<Box<dyn Environment>> {
    build(EnvKind::parse(name)?, init)
}

/// Canonical names of every registered environment, registry order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|s| s.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (SystemConfig, EnvConfig) {
        let sys = SystemConfig {
            num_devices: 10,
            ..SystemConfig::default()
        };
        let env = EnvConfig {
            trace_path: crate::test_util::campus_fixture(),
            ..EnvConfig::default()
        };
        (sys, env)
    }

    #[test]
    fn registry_covers_every_env_variant() {
        for kind in EnvKind::ALL {
            assert!(
                REGISTRY.iter().any(|s| s.id == kind),
                "{kind} missing from registry"
            );
        }
        assert_eq!(
            names(),
            vec!["static", "ge", "avail", "drift", "trace", "adv", "compose"]
        );
    }

    #[test]
    fn from_name_accepts_aliases_and_rejects_unknown() {
        let (sys, env) = setup();
        let init = EnvInit {
            sys: &sys,
            env: &env,
            seed: 1,
        };
        for alias in [
            "static",
            "ge",
            "gilbert-elliott",
            "avail",
            "availability",
            "drift",
            "trace",
            "adv",
            "adversarial",
            "compose",
            "composite",
        ] {
            assert!(from_name(alias, &init).is_ok(), "{alias}");
        }
        assert!(from_name("nope", &init).is_err());
    }

    #[test]
    fn trace_build_fails_cleanly_on_a_missing_log() {
        let (sys, mut env) = setup();
        env.trace_path = "/nonexistent/trace.csv".into();
        let init = EnvInit {
            sys: &sys,
            env: &env,
            seed: 1,
        };
        assert!(build(EnvKind::Trace, &init).is_err());
    }

    #[test]
    fn every_env_yields_well_formed_rounds() {
        let (sys, env) = setup();
        let init = EnvInit {
            sys: &sys,
            env: &env,
            seed: 7,
        };
        let mut rng = crate::rng::Rng::new(3);
        let fleet = crate::system::Fleet::generate(&sys, (50, 100), &mut rng);
        for spec in REGISTRY {
            let mut e = (spec.build)(&init).unwrap();
            assert_eq!(e.name(), spec.name);
            for _ in 0..50 {
                let re = e.next_round(&fleet.devices);
                assert_eq!(re.gains.len(), 10, "{}", spec.name);
                let (lo, hi) = sys.channel_clip;
                assert!(
                    re.gains.iter().all(|&h| h >= lo && h <= hi),
                    "{}: gain outside band",
                    spec.name
                );
                if let Some(av) = &re.available {
                    assert!(!av.is_empty(), "{}", spec.name);
                    assert!(
                        av.windows(2).all(|w| w[0] < w[1]),
                        "{}: availability not sorted-unique",
                        spec.name
                    );
                    assert!(
                        av.iter().all(|&i| i < 10),
                        "{}: id out of range",
                        spec.name
                    );
                }
                if let Some(devs) = &re.devices {
                    assert_eq!(devs.len(), 10, "{}", spec.name);
                }
            }
        }
    }

    #[test]
    fn step_into_is_bitwise_identical_to_next_round_for_every_env() {
        // Two same-seed instances of every registered environment, one
        // stepped through the per-`Device` path and one through the SoA
        // path, must realize identical trajectories — gains, candidate
        // set, and drift overlays all bitwise.
        let (sys, env) = setup();
        let init = EnvInit {
            sys: &sys,
            env: &env,
            seed: 23,
        };
        let mut rng = crate::rng::Rng::new(9);
        let fleet = crate::system::Fleet::generate(&sys, (50, 100), &mut rng);
        for spec in REGISTRY {
            let mut aos = (spec.build)(&init).unwrap();
            let mut soa_env = (spec.build)(&init).unwrap();
            let mut soa = EnvSoA::new();
            for t in 0..50 {
                let re = aos.next_round(&fleet.devices);
                soa_env.step_into(&fleet.devices, &mut soa);
                assert_eq!(re.gains, soa.gains, "{} round {t}: gains", spec.name);
                match &re.available {
                    None => assert!(soa.all_available, "{} round {t}", spec.name),
                    Some(av) => {
                        assert!(!soa.all_available, "{} round {t}", spec.name);
                        assert_eq!(av, &soa.available, "{} round {t}: N^t", spec.name);
                    }
                }
                match &re.devices {
                    None => assert!(!soa.drifted, "{} round {t}", spec.name),
                    Some(devs) => {
                        assert!(soa.drifted, "{} round {t}", spec.name);
                        let f: Vec<f64> = devs.iter().map(|d| d.f_max_hz).collect();
                        let a: Vec<f64> = devs.iter().map(|d| d.alpha).collect();
                        assert_eq!(f, soa.f_max_hz, "{} round {t}: f_max", spec.name);
                        assert_eq!(a, soa.alpha, "{} round {t}: alpha", spec.name);
                    }
                }
            }
        }
    }

    #[test]
    fn env_soa_retains_capacity_across_refills() {
        let (sys, env) = setup();
        let init = EnvInit {
            sys: &sys,
            env: &env,
            seed: 3,
        };
        let mut rng = crate::rng::Rng::new(1);
        let fleet = crate::system::Fleet::generate(&sys, (50, 100), &mut rng);
        let mut e = from_name("avail", &init).unwrap();
        let mut soa = EnvSoA::new();
        e.step_into(&fleet.devices, &mut soa);
        let caps = (soa.gains.capacity(), soa.available.capacity());
        for _ in 0..30 {
            e.step_into(&fleet.devices, &mut soa);
        }
        assert_eq!(
            (soa.gains.capacity(), soa.available.capacity()),
            caps,
            "per-round refill must reuse the buffers"
        );
    }

    #[test]
    fn peek_previews_exactly_the_next_round() {
        // For every action-independent environment, peek must equal the
        // next_round that follows it, at every point in the stream; the
        // adversarial channel must refuse to be previewed.
        let (sys, env) = setup();
        let init = EnvInit {
            sys: &sys,
            env: &env,
            seed: 11,
        };
        let mut rng = crate::rng::Rng::new(5);
        let fleet = crate::system::Fleet::generate(&sys, (50, 100), &mut rng);
        for spec in REGISTRY {
            let mut e = (spec.build)(&init).unwrap();
            if spec.id == EnvKind::Adversarial {
                assert!(
                    e.peek(&fleet.devices).is_none(),
                    "adv must not be previewable (its future depends on the selection)"
                );
                continue;
            }
            for t in 0..20 {
                let peeked = e
                    .peek(&fleet.devices)
                    .unwrap_or_else(|| panic!("{}: peek unavailable", spec.name));
                let real = e.next_round(&fleet.devices);
                assert_eq!(peeked.gains, real.gains, "{} round {t}", spec.name);
                assert_eq!(peeked.available, real.available, "{} round {t}", spec.name);
                match (&peeked.devices, &real.devices) {
                    (None, None) => {}
                    (Some(a), Some(b)) => {
                        for (da, db) in a.iter().zip(b) {
                            assert_eq!(da.f_max_hz, db.f_max_hz, "{} round {t}", spec.name);
                            assert_eq!(da.alpha, db.alpha, "{} round {t}", spec.name);
                        }
                    }
                    _ => panic!("{}: peek/next devices disagree", spec.name),
                }
            }
        }
    }
}
