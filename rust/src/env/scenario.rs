//! Population-scale scenario generators: diurnal cycles, flash crowds,
//! regional outages.
//!
//! These are *composite-only* availability mechanisms — they never
//! appear in the env registry on their own, but any of them can be a
//! child of [`crate::env::CompositeEnv`] (`--envs=compose:diurnal+ge`),
//! and the named presets in [`crate::config::COMPOSE_PRESETS`] bundle
//! them with fading/drift into ready-made scenarios:
//!
//! * [`DiurnalEnv`] — every device follows a day/night activity cycle
//!   (period [`DIURNAL_PERIOD`] rounds), staggered across
//!   [`DIURNAL_BUCKETS`] "timezones" so the fleet breathes instead of
//!   blinking.  Per-device on/off Markov chains whose rates track the
//!   cycle give persistence (a device that goes to sleep stays asleep
//!   for a while).
//! * [`FlashCrowdEnv`] — a sparse baseline fleet with periodic
//!   mass-join windows (one [`FLASH_WINDOW`]-round burst per
//!   [`FLASH_CYCLE`]-round cycle, at a seed-determined offset): the
//!   population jumps from ~20% to ~95% online and drains back.
//! * [`OutageEnv`] — devices are spread over [`OUTAGE_REGIONS`]
//!   regions (interleaved by id); each region carries an up/down Markov
//!   chain and a down region takes all of its devices offline at once —
//!   the spatially correlated failure mode individual per-device chains
//!   cannot produce.
//!
//! Shared conventions (same as the `avail` environment): channel gains
//! come from the same-seed [`ChannelProcess`] construction, so the gain
//! stream coincides with `static` round for round and masking is the
//! only effect; if a mechanism leaves fewer than `K` devices online,
//! offline devices are forced back on in ascending id order; all state
//! advances through forked per-device/per-region RNG streams, so
//! trajectories are bitwise seed-deterministic and independent of
//! thread count.
//!
//! [`ChannelProcess`]: crate::system::ChannelProcess

use super::{step_two_state, EnvInit};
use crate::rng::Rng;
use crate::system::ChannelProcess;

/// Rounds per diurnal cycle (one "day").
pub const DIURNAL_PERIOD: usize = 288;
/// Distinct phase offsets ("timezones") devices are assigned to.
pub const DIURNAL_BUCKETS: usize = 24;
/// Mean online fraction of the diurnal cycle.
const DIURNAL_BASE: f64 = 0.55;
/// Peak-to-mean amplitude of the cycle (online fraction swings
/// `BASE ± AMP`).
const DIURNAL_AMP: f64 = 0.40;
/// Relaxation rate of the per-device chains toward the cycle target.
const DIURNAL_RATE: f64 = 0.3;

/// Rounds per flash-crowd cycle.
pub const FLASH_CYCLE: usize = 400;
/// Length of the mass-join window inside each cycle.
pub const FLASH_WINDOW: usize = 40;
const FLASH_P_JOIN_IN: f64 = 0.65;
const FLASH_P_DROP_IN: f64 = 0.02;
const FLASH_P_JOIN_OUT: f64 = 0.03;
const FLASH_P_DROP_OUT: f64 = 0.12;

/// Number of outage regions devices are interleaved across.
pub const OUTAGE_REGIONS: usize = 16;
const OUTAGE_P_FAIL: f64 = 0.02;
const OUTAGE_P_RECOVER: f64 = 0.12;

/// Force offline devices back on in ascending id order until at least
/// `min_online` are reachable — the registry-wide K-repair convention.
fn repair(online: &mut [bool], min_online: usize) {
    let mut count = online.iter().filter(|&&b| b).count();
    for on in online.iter_mut() {
        if count >= min_online {
            break;
        }
        if !*on {
            *on = true;
            count += 1;
        }
    }
}

/// Timezone-staggered day/night availability cycles.
#[derive(Clone)]
pub struct DiurnalEnv {
    channel: ChannelProcess,
    streams: Vec<Rng>,
    /// Timezone bucket of each device (phase offset `b/BUCKETS` cycles).
    buckets: Vec<u16>,
    online: Vec<bool>,
    t: usize,
    min_online: usize,
}

impl DiurnalEnv {
    pub fn new(init: &EnvInit<'_>) -> Self {
        let n = init.sys.num_devices;
        let mut root = Rng::new(init.seed ^ 0xD1CA_11E5_D1A7_0001);
        let mut streams: Vec<Rng> = (0..n).map(|i| root.fork(i as u64)).collect();
        let mut buckets = Vec::with_capacity(n);
        let mut online = Vec::with_capacity(n);
        for rng in streams.iter_mut() {
            let b = ((rng.f64() * DIURNAL_BUCKETS as f64) as usize).min(DIURNAL_BUCKETS - 1);
            buckets.push(b as u16);
            // Start from the cycle's round-0 stationary point, so the
            // diurnal pattern is visible from the first round.
            online.push(rng.f64() < cycle_target(0, b));
        }
        Self {
            channel: ChannelProcess::new(init.sys, init.seed),
            streams,
            buckets,
            online,
            t: 0,
            min_online: init.sys.k.max(1),
        }
    }

    /// Advance every chain one round toward its bucket's cycle target,
    /// then apply the K repair; returns the post-repair mask.
    pub(crate) fn step_mask(&mut self) -> &[bool] {
        let mut targets = [0.0f64; DIURNAL_BUCKETS];
        for (b, target) in targets.iter_mut().enumerate() {
            *target = cycle_target(self.t, b);
        }
        for i in 0..self.streams.len() {
            let target = targets[self.buckets[i] as usize];
            let p_drop = DIURNAL_RATE * (1.0 - target);
            let p_join = DIURNAL_RATE * target;
            self.online[i] = step_two_state(&mut self.streams[i], self.online[i], p_drop, p_join);
        }
        self.t += 1;
        repair(&mut self.online, self.min_online);
        &self.online
    }

    /// Composite hook: the shared static-stream channel draw.
    pub(crate) fn step_channel_into(&mut self, out: &mut Vec<f64>) {
        self.channel.next_round_into(out);
    }
}

/// Target online fraction of bucket `b` at round `t`.
fn cycle_target(t: usize, bucket: usize) -> f64 {
    let phase = std::f64::consts::TAU
        * (t as f64 / DIURNAL_PERIOD as f64 + bucket as f64 / DIURNAL_BUCKETS as f64);
    DIURNAL_BASE + DIURNAL_AMP * phase.sin()
}

/// Sparse baseline fleet with periodic mass-join windows.
#[derive(Clone)]
pub struct FlashCrowdEnv {
    channel: ChannelProcess,
    streams: Vec<Rng>,
    online: Vec<bool>,
    /// Seed of the per-cycle window-offset hash (pure, clone-safe).
    offset_seed: u64,
    t: usize,
    min_online: usize,
}

impl FlashCrowdEnv {
    pub fn new(init: &EnvInit<'_>) -> Self {
        let n = init.sys.num_devices;
        let mut root = Rng::new(init.seed ^ 0xF1A5_8C80_3D11_0002);
        let mut streams: Vec<Rng> = (0..n).map(|i| root.fork(i as u64)).collect();
        // Baseline stationary occupancy outside a window.
        let base = FLASH_P_JOIN_OUT / (FLASH_P_JOIN_OUT + FLASH_P_DROP_OUT);
        let online = streams.iter_mut().map(|rng| rng.f64() < base).collect();
        Self {
            channel: ChannelProcess::new(init.sys, init.seed),
            streams,
            online,
            offset_seed: init.seed ^ 0xF1A5_0FF5_E700_0003,
            t: 0,
            min_online: init.sys.k.max(1),
        }
    }

    /// Whether round `t` falls inside its cycle's flash window (the
    /// window offset is a pure hash of the cycle index, so replay and
    /// peek need no extra state).
    pub(crate) fn in_window(&self, t: usize) -> bool {
        let cycle = (t / FLASH_CYCLE) as u64;
        let mut h = Rng::new(self.offset_seed ^ cycle.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let offset = ((h.f64() * (FLASH_CYCLE - FLASH_WINDOW) as f64) as usize)
            .min(FLASH_CYCLE - FLASH_WINDOW - 1);
        let pos = t % FLASH_CYCLE;
        (offset..offset + FLASH_WINDOW).contains(&pos)
    }

    /// Advance every chain one round (window rates if inside a flash),
    /// then apply the K repair; returns the post-repair mask.
    pub(crate) fn step_mask(&mut self) -> &[bool] {
        let (p_drop, p_join) = if self.in_window(self.t) {
            (FLASH_P_DROP_IN, FLASH_P_JOIN_IN)
        } else {
            (FLASH_P_DROP_OUT, FLASH_P_JOIN_OUT)
        };
        for (rng, on) in self.streams.iter_mut().zip(self.online.iter_mut()) {
            *on = step_two_state(rng, *on, p_drop, p_join);
        }
        self.t += 1;
        repair(&mut self.online, self.min_online);
        &self.online
    }

    /// Composite hook: the shared static-stream channel draw.
    pub(crate) fn step_channel_into(&mut self, out: &mut Vec<f64>) {
        self.channel.next_round_into(out);
    }
}

/// Correlated regional outages: a down region takes every one of its
/// devices offline at once.
#[derive(Clone)]
pub struct OutageEnv {
    channel: ChannelProcess,
    /// One up/down chain per region.
    region_streams: Vec<Rng>,
    region_up: Vec<bool>,
    online: Vec<bool>,
    min_online: usize,
}

impl OutageEnv {
    pub fn new(init: &EnvInit<'_>) -> Self {
        let n = init.sys.num_devices;
        let regions = OUTAGE_REGIONS.min(n.max(1));
        let mut root = Rng::new(init.seed ^ 0x0A7A_6E00_4E61_0004);
        Self {
            channel: ChannelProcess::new(init.sys, init.seed),
            region_streams: (0..regions).map(|r| root.fork(r as u64)).collect(),
            region_up: vec![true; regions],
            online: vec![true; n],
            min_online: init.sys.k.max(1),
        }
    }

    /// Region of device `i` (interleaved by id, so any id prefix spans
    /// every region and the K repair never concentrates in one).
    pub(crate) fn region_of(&self, i: usize) -> usize {
        i % self.region_streams.len()
    }

    /// Advance every region chain one round, project onto devices, then
    /// apply the K repair; returns the post-repair mask.
    pub(crate) fn step_mask(&mut self) -> &[bool] {
        for (rng, up) in self.region_streams.iter_mut().zip(self.region_up.iter_mut()) {
            *up = step_two_state(rng, *up, OUTAGE_P_FAIL, OUTAGE_P_RECOVER);
        }
        let regions = self.region_up.len();
        for (i, on) in self.online.iter_mut().enumerate() {
            *on = self.region_up[i % regions];
        }
        self.t_repair();
        &self.online
    }

    fn t_repair(&mut self) {
        repair(&mut self.online, self.min_online);
    }

    /// Composite hook: the shared static-stream channel draw.
    pub(crate) fn step_channel_into(&mut self, out: &mut Vec<f64>) {
        self.channel.next_round_into(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnvConfig, SystemConfig};

    fn init_for(n: usize, k: usize) -> (SystemConfig, EnvConfig) {
        let sys = SystemConfig {
            num_devices: n,
            k,
            ..SystemConfig::default()
        };
        (sys, EnvConfig::default())
    }

    fn online_count(mask: &[bool]) -> usize {
        mask.iter().filter(|&&b| b).count()
    }

    #[test]
    fn diurnal_cycles_and_respects_the_k_floor() {
        let (sys, env_cfg) = init_for(200, 3);
        let mut env = DiurnalEnv::new(&EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 7,
        });
        // Track the population over one full day: it must swing well
        // above and below the mean and never starve the server.
        let (mut lo, mut hi) = (usize::MAX, 0usize);
        for _ in 0..DIURNAL_PERIOD {
            let c = online_count(env.step_mask());
            assert!(c >= 3, "fewer than K online");
            lo = lo.min(c);
            hi = hi.max(c);
        }
        assert!(
            hi as f64 >= 200.0 * 0.7 && lo as f64 <= 200.0 * 0.45,
            "no diurnal swing: lo={lo} hi={hi}"
        );
    }

    #[test]
    fn diurnal_is_seed_deterministic() {
        let (sys, env_cfg) = init_for(50, 2);
        let mk = |seed| {
            DiurnalEnv::new(&EnvInit {
                sys: &sys,
                env: &env_cfg,
                seed,
            })
        };
        let (mut a, mut b, mut c) = (mk(3), mk(3), mk(4));
        let mut diverged = false;
        for _ in 0..100 {
            let ma = a.step_mask().to_vec();
            assert_eq!(ma, b.step_mask());
            diverged |= ma != c.step_mask();
        }
        assert!(diverged, "different seeds gave identical masks");
    }

    #[test]
    fn flash_crowd_bursts_above_the_baseline() {
        let (sys, env_cfg) = init_for(300, 2);
        let mut env = FlashCrowdEnv::new(&EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 11,
        });
        let mut in_peak = 0usize;
        let mut out_sum = 0usize;
        let mut out_rounds = 0usize;
        for t in 0..FLASH_CYCLE {
            let c = online_count(env.step_mask());
            assert!(c >= 2);
            if env.in_window(t) {
                in_peak = in_peak.max(c);
            } else {
                out_sum += c;
                out_rounds += 1;
            }
        }
        let out_mean = out_sum as f64 / out_rounds as f64;
        assert!(
            in_peak as f64 > 2.0 * out_mean,
            "no flash crowd: peak={in_peak} baseline mean={out_mean}"
        );
    }

    #[test]
    fn outage_takes_whole_regions_down_together() {
        let (sys, env_cfg) = init_for(160, 2);
        let mut env = OutageEnv::new(&EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 5,
        });
        let mut saw_outage = false;
        for _ in 0..400 {
            let mask = env.step_mask().to_vec();
            assert!(online_count(&mask) >= 2);
            // Offline devices must be explained by a down region (the K
            // repair can only force devices ON, never off).
            for (i, &on) in mask.iter().enumerate() {
                if !on {
                    assert!(!env.region_up[env.region_of(i)], "device {i} off in an up region");
                    saw_outage = true;
                }
            }
        }
        assert!(saw_outage, "no region ever failed in 400 rounds");
    }

    #[test]
    fn gains_match_the_static_channel_stream() {
        use crate::system::ChannelProcess;
        let (sys, env_cfg) = init_for(20, 2);
        let mut env = DiurnalEnv::new(&EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 13,
        });
        let mut reference = ChannelProcess::new(&sys, 13);
        let mut buf = Vec::new();
        for _ in 0..20 {
            env.step_channel_into(&mut buf);
            assert_eq!(buf, reference.next_round());
        }
    }
}
