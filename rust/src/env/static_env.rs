//! The paper's environment: IID exponential fading, always-on fleet.

use super::{EnvInit, EnvSoA, Environment, RoundEnv};
use crate::system::{ChannelProcess, Device};

/// IID exponential channel (mean `channel_mean`, clipped), every device
/// reachable every round, no parameter drift.
///
/// This wraps [`ChannelProcess`] with the exact seed the pre-env server
/// used, so trajectories are **bitwise identical** to the pre-env code
/// path — the golden parity tests in `tests/policy_parity.rs` pin this.
#[derive(Clone)]
pub struct StaticEnv {
    channel: ChannelProcess,
}

impl StaticEnv {
    pub fn new(init: &EnvInit<'_>) -> Self {
        Self {
            channel: ChannelProcess::new(init.sys, init.seed),
        }
    }

    /// Composite hook: the channel draw, used when this child is the
    /// composite's channel owner.
    pub(crate) fn step_channel_into(&mut self, out: &mut Vec<f64>) {
        self.channel.next_round_into(out);
    }
}

impl Environment for StaticEnv {
    fn name(&self) -> &'static str {
        "static"
    }

    fn next_round(&mut self, _base: &[Device]) -> RoundEnv {
        RoundEnv {
            gains: self.channel.next_round(),
            available: None,
            devices: None,
        }
    }

    fn step_into(&mut self, _base: &[Device], out: &mut EnvSoA) {
        // Same streams, same draw order as next_round — alloc-free.
        self.channel.next_round_into(&mut out.gains);
        out.set_all_available();
        out.set_undrifted();
    }

    fn peek(&self, base: &[Device]) -> Option<RoundEnv> {
        // Action-independent: stepping a clone previews the stream.
        Some(self.clone().next_round(base))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnvConfig, SystemConfig};

    #[test]
    fn matches_channel_process_bitwise() {
        let sys = SystemConfig::default();
        let env_cfg = EnvConfig::default();
        let init = EnvInit {
            sys: &sys,
            env: &env_cfg,
            seed: 42,
        };
        let mut env = StaticEnv::new(&init);
        let mut reference = ChannelProcess::new(&sys, 42);
        let base: Vec<Device> = Vec::new();
        for _ in 0..25 {
            let re = env.next_round(&base);
            assert_eq!(re.gains, reference.next_round());
            assert!(re.available.is_none(), "static = whole fleet reachable");
            assert!(re.devices.is_none());
        }
    }
}
