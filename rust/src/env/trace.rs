//! Trace replay: a recorded channel/availability log as an environment.
//!
//! Real measurement campaigns (the evaluation style of Shi et al. and
//! Luo et al.) log per-device channel quality at coarse intervals; this
//! environment replays such a log as the round process, so schedulers
//! are graded on *recorded* dynamics instead of synthetic Markov ones.
//!
//! The log is a CSV with header `round,device,gain[,available]`
//! (schema documented in `tests/fixtures/README.md`):
//!
//! * rows may be sparse in `round` — gains are **linearly interpolated**
//!   between a device's recorded samples (and held flat before the first
//!   / after the last sample of a period);
//! * `available` (optional, default 1) is a step function: a device
//!   keeps its last recorded on/off state until the next sample;
//! * the log **wraps cyclically** past its last recorded round, so any
//!   horizon can replay a finite trace;
//! * a fleet larger than the trace maps device `n` onto trace track
//!   `n % tracks` (the standard trace-stretching convention);
//! * if the log leaves fewer than `K` devices online, offline devices
//!   are forced back on in ascending id order (the same deterministic
//!   repair as the `avail` environment).
//!
//! Replay consumes **no randomness** at all, so trajectories are
//! trivially bitwise-identical across seeds, processes, and thread
//! counts, and [`Environment::peek`] is exact (a pure function of the
//! round index).

use std::path::Path;

use super::{EnvInit, Environment, RoundEnv};
use crate::system::Device;
use crate::Result;

/// One recorded sample of one trace track.
#[derive(Clone, Debug)]
struct Sample {
    round: usize,
    gain: f64,
    available: bool,
}

/// Replay of a recorded channel/availability log.
#[derive(Clone)]
pub struct TraceEnv {
    /// Per-track samples, sorted by round, non-empty.
    tracks: Vec<Vec<Sample>>,
    /// Replay period: last recorded round + 1 (the log wraps).
    period: usize,
    /// Next round index to realize.
    t: usize,
    clip: (f64, f64),
    min_online: usize,
    num_devices: usize,
}

impl TraceEnv {
    pub fn new(init: &EnvInit<'_>) -> Result<Self> {
        let path = Path::new(&init.env.trace_path);
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("env.trace_path {path:?}: {e}"))?;
        let tracks = parse_trace(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        let period = tracks
            .iter()
            .flat_map(|t| t.iter().map(|s| s.round))
            .max()
            .expect("parse_trace guarantees at least one sample")
            + 1;
        Ok(Self {
            tracks,
            period,
            t: 0,
            clip: init.sys.channel_clip,
            min_online: init.sys.k.max(1),
            num_devices: init.sys.num_devices,
        })
    }

    /// Number of recorded tracks (fleet devices map onto them modulo).
    pub fn num_tracks(&self) -> usize {
        self.tracks.len()
    }

    /// Replay period in rounds.
    pub fn period(&self) -> usize {
        self.period
    }

    /// Realize round `t` into caller-owned buffers (clear + extend, so
    /// steady-state replay allocates nothing).  Returns `true` iff any
    /// device is still offline after the K repair — the composite env
    /// keys its explicit-list decision on exactly this flag, matching
    /// the `available: None` fast path below.
    pub(crate) fn realize_into(&self, t: usize, gains: &mut Vec<f64>, online: &mut Vec<bool>) -> bool {
        let t_eff = t % self.period;
        let (lo, hi) = self.clip;
        gains.clear();
        online.clear();
        for i in 0..self.num_devices {
            let track = &self.tracks[i % self.tracks.len()];
            let (gain, avail) = sample_track(track, t_eff);
            gains.push(gain.clamp(lo, hi));
            online.push(avail);
        }
        // Repair: guarantee at least K reachable devices.
        let mut count = online.iter().filter(|&&b| b).count();
        for on in online.iter_mut() {
            if count >= self.min_online {
                break;
            }
            if !*on {
                *on = true;
                count += 1;
            }
        }
        count < self.num_devices
    }

    /// Composite hook: consume and return the current round index.
    pub(crate) fn advance(&mut self) -> usize {
        let t = self.t;
        self.t += 1;
        t
    }

    /// Composite hook: the round index `advance` would consume next.
    pub(crate) fn current_round(&self) -> usize {
        self.t
    }

    /// Realize round `t` — a pure function, shared by `next_round` and
    /// `peek`.
    fn round_env(&self, t: usize) -> RoundEnv {
        let mut gains = Vec::with_capacity(self.num_devices);
        let mut online = Vec::with_capacity(self.num_devices);
        let any_off = self.realize_into(t, &mut gains, &mut online);
        let available = if any_off {
            Some((0..self.num_devices).filter(|&i| online[i]).collect())
        } else {
            None
        };
        RoundEnv {
            gains,
            available,
            devices: None,
        }
    }
}

/// Gain (linear interpolation, flat extrapolation) and availability
/// (step function, last sample at or before `t`) of one track at `t`.
fn sample_track(track: &[Sample], t: usize) -> (f64, bool) {
    // Index of the first sample strictly after t.
    let after = track.partition_point(|s| s.round <= t);
    if after == 0 {
        // Before the first sample: hold it flat.
        return (track[0].gain, track[0].available);
    }
    let left = &track[after - 1];
    if after == track.len() || left.round == t {
        return (left.gain, left.available);
    }
    let right = &track[after];
    let frac = (t - left.round) as f64 / (right.round - left.round) as f64;
    let gain = left.gain + (right.gain - left.gain) * frac;
    (gain, left.available)
}

/// Validate a trace CSV body against the documented replay schema with
/// the exact parser [`TraceEnv`] uses, returning `(tracks, period)`.
/// `lroa trace import` round-trips its output through this before
/// writing, so an imported file can never fail to replay.
pub(crate) fn validate_trace(text: &str) -> Result<(usize, usize)> {
    let tracks = parse_trace(text)?;
    let period = tracks
        .iter()
        .flat_map(|t| t.iter().map(|s| s.round))
        .max()
        .expect("parse_trace guarantees at least one sample")
        + 1;
    Ok((tracks.len(), period))
}

/// Parse the `round,device,gain[,available]` CSV into per-track sample
/// lists (sorted by round, device ids contiguous from 0).
fn parse_trace(text: &str) -> Result<Vec<Vec<Sample>>> {
    let mut lines = text.lines().enumerate();
    let header = loop {
        match lines.next() {
            Some((_, l)) if l.trim().is_empty() || l.trim_start().starts_with('#') => continue,
            Some((_, l)) => break l.trim(),
            None => anyhow::bail!("empty trace file"),
        }
    };
    let cols: Vec<&str> = header.split(',').map(str::trim).collect();
    anyhow::ensure!(
        cols.len() >= 3
            && cols[0].eq_ignore_ascii_case("round")
            && cols[1].eq_ignore_ascii_case("device")
            && cols[2].eq_ignore_ascii_case("gain")
            && (cols.len() == 3 || (cols.len() == 4 && cols[3].eq_ignore_ascii_case("available"))),
        "bad trace header {header:?} (expected round,device,gain[,available])"
    );
    let has_avail = cols.len() == 4;

    let mut tracks: Vec<Vec<Sample>> = Vec::new();
    for (lineno, raw) in lines {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        anyhow::ensure!(
            fields.len() == cols.len(),
            "line {}: expected {} fields, got {}",
            lineno + 1,
            cols.len(),
            fields.len()
        );
        let round: usize = fields[0]
            .parse()
            .map_err(|e| anyhow::anyhow!("line {}: bad round: {e}", lineno + 1))?;
        let device: usize = fields[1]
            .parse()
            .map_err(|e| anyhow::anyhow!("line {}: bad device: {e}", lineno + 1))?;
        let gain: f64 = fields[2]
            .parse()
            .map_err(|e| anyhow::anyhow!("line {}: bad gain: {e}", lineno + 1))?;
        anyhow::ensure!(
            gain.is_finite() && gain > 0.0,
            "line {}: gain must be finite and > 0",
            lineno + 1
        );
        let available = if has_avail {
            match fields[3] {
                "0" | "false" => false,
                "1" | "true" => true,
                other => anyhow::bail!("line {}: bad available {other:?} (0|1)", lineno + 1),
            }
        } else {
            true
        };
        if device >= tracks.len() {
            tracks.resize_with(device + 1, Vec::new);
        }
        tracks[device].push(Sample {
            round,
            gain,
            available,
        });
    }
    anyhow::ensure!(!tracks.is_empty(), "trace has no data rows");
    for (d, track) in tracks.iter_mut().enumerate() {
        anyhow::ensure!(
            !track.is_empty(),
            "trace device ids must be contiguous from 0 (device {d} has no rows)"
        );
        track.sort_by_key(|s| s.round);
        anyhow::ensure!(
            track.windows(2).all(|w| w[0].round < w[1].round),
            "device {d} has duplicate rounds"
        );
    }
    Ok(tracks)
}

impl Environment for TraceEnv {
    fn name(&self) -> &'static str {
        "trace"
    }

    fn next_round(&mut self, _base: &[Device]) -> RoundEnv {
        let re = self.round_env(self.t);
        self.t += 1;
        re
    }

    fn peek(&self, _base: &[Device]) -> Option<RoundEnv> {
        // A pure function of the round index: peek is exact and free.
        Some(self.round_env(self.t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnvConfig, SystemConfig};

    fn write_trace(name: &str, body: &str) -> String {
        let dir = std::env::temp_dir().join("lroa_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        std::fs::write(&path, body).unwrap();
        path.to_string_lossy().into_owned()
    }

    fn build(n: usize, k: usize, path: &str) -> Result<TraceEnv> {
        let sys = SystemConfig {
            num_devices: n,
            k,
            ..SystemConfig::default()
        };
        let env = EnvConfig {
            trace_path: path.to_string(),
            ..EnvConfig::default()
        };
        TraceEnv::new(&EnvInit {
            sys: &sys,
            env: &env,
            seed: 0,
        })
    }

    #[test]
    fn interpolates_between_sparse_samples() {
        let path = write_trace(
            "interp.csv",
            "round,device,gain\n0,0,0.10\n4,0,0.30\n0,1,0.20\n",
        );
        let env = build(2, 1, &path).unwrap();
        assert_eq!(env.num_tracks(), 2);
        assert_eq!(env.period(), 5);
        // Device 0: linear from 0.10 at t=0 to 0.30 at t=4.
        let g: Vec<f64> = (0..5).map(|t| env.round_env(t).gains[0]).collect();
        for (t, got) in g.iter().enumerate() {
            let want = 0.10 + 0.05 * t as f64;
            assert!((got - want).abs() < 1e-12, "t={t}: {got} vs {want}");
        }
        // Device 1: single sample held flat.
        assert_eq!(env.round_env(3).gains[1], 0.20);
    }

    #[test]
    fn replay_wraps_cyclically() {
        let path = write_trace(
            "wrap.csv",
            "round,device,gain\n0,0,0.10\n2,0,0.30\n",
        );
        let mut env = build(1, 1, &path).unwrap();
        let base: Vec<Device> = Vec::new();
        let first: Vec<f64> = (0..3).map(|_| env.next_round(&base).gains[0]).collect();
        let second: Vec<f64> = (0..3).map(|_| env.next_round(&base).gains[0]).collect();
        assert_eq!(first, second, "period-3 trace must repeat exactly");
    }

    #[test]
    fn availability_is_a_step_function_with_k_floor() {
        let path = write_trace(
            "avail.csv",
            "round,device,gain,available\n\
             0,0,0.2,1\n2,0,0.2,0\n5,0,0.2,1\n\
             0,1,0.3,1\n\
             0,2,0.1,1\n2,2,0.1,0\n",
        );
        let mut env = build(3, 1, &path).unwrap();
        let base: Vec<Device> = Vec::new();
        let avail: Vec<Option<Vec<usize>>> =
            (0..6).map(|_| env.next_round(&base).available).collect();
        // t=0,1: everyone on -> fast path (None).
        assert_eq!(avail[0], None);
        assert_eq!(avail[1], None);
        // t=2..4: devices 0 and 2 off.
        for t in 2..5 {
            assert_eq!(avail[t], Some(vec![1]), "t={t}");
        }
        // t=5: device 0 back on.
        assert_eq!(avail[5], Some(vec![0, 1]));
    }

    #[test]
    fn k_floor_repairs_an_all_offline_round() {
        let path = write_trace(
            "dead.csv",
            "round,device,gain,available\n0,0,0.2,0\n0,1,0.3,0\n",
        );
        let mut env = build(2, 2, &path).unwrap();
        let base: Vec<Device> = Vec::new();
        let re = env.next_round(&base);
        // Both forced back on -> full fleet -> fast path.
        assert_eq!(re.available, None);
    }

    #[test]
    fn fleet_larger_than_trace_maps_modulo() {
        let path = write_trace(
            "small.csv",
            "round,device,gain\n0,0,0.11\n0,1,0.22\n",
        );
        let mut env = build(5, 1, &path).unwrap();
        let base: Vec<Device> = Vec::new();
        let g = env.next_round(&base).gains;
        assert_eq!(g, vec![0.11, 0.22, 0.11, 0.22, 0.11]);
    }

    #[test]
    fn gains_are_clamped_to_the_clip_band() {
        let path = write_trace(
            "clip.csv",
            "round,device,gain\n0,0,7.5\n1,0,0.0001\n",
        );
        let mut env = build(1, 1, &path).unwrap();
        let base: Vec<Device> = Vec::new();
        assert_eq!(env.next_round(&base).gains[0], 0.5);
        assert_eq!(env.next_round(&base).gains[0], 0.01);
    }

    #[test]
    fn deterministic_and_peek_exact() {
        let path = write_trace(
            "det.csv",
            "round,device,gain\n0,0,0.1\n3,0,0.4\n0,1,0.2\n2,1,0.3\n",
        );
        let mut a = build(2, 1, &path).unwrap();
        let mut b = build(2, 1, &path).unwrap();
        let base: Vec<Device> = Vec::new();
        for _ in 0..10 {
            let pa = a.peek(&base).unwrap();
            let ra = a.next_round(&base);
            let rb = b.next_round(&base);
            assert_eq!(ra.gains, rb.gains);
            assert_eq!(pa.gains, ra.gains);
            assert_eq!(pa.available, ra.available);
        }
    }

    #[test]
    fn bad_traces_are_rejected() {
        for (name, body) in [
            ("empty.csv", ""),
            ("header.csv", "time,device,gain\n0,0,0.1\n"),
            ("no_rows.csv", "round,device,gain\n"),
            ("gap.csv", "round,device,gain\n0,0,0.1\n0,2,0.2\n"),
            ("dup.csv", "round,device,gain\n0,0,0.1\n0,0,0.2\n"),
            ("neg.csv", "round,device,gain\n0,0,-0.1\n"),
            ("bad_avail.csv", "round,device,gain,available\n0,0,0.1,maybe\n"),
        ] {
            let path = write_trace(name, body);
            assert!(build(2, 1, &path).is_err(), "{name} should be rejected");
        }
        assert!(build(2, 1, "/nonexistent/x.csv").is_err());
    }
}
