//! Declarative multi-scenario experiment engine.
//!
//! The paper's evaluation is a grid: policy × environment × K × µ/ν ×
//! seed × dataset, every cell run on shared channel realizations.  This
//! subsystem makes that grid a value instead of a hand-rolled loop, and
//! its execution one embeddable session:
//!
//! * [`spec`] — [`SweepSpec`], the declarative grid, its expansion into
//!   concrete [`Scenario`]s (config + label + group key), and the
//!   machine-readable grid manifest ([`manifest_json`]) the figure
//!   pipeline consumes;
//! * [`session`] — the [`Experiment`] builder that compiles to a
//!   [`Session`]: the one entry path behind `lroa sweep`, `lroa regret`,
//!   the figure harness, and every example.  Cells execute on the
//!   scoped thread pool through the server's step-wise
//!   [`crate::fl::RoundDriver`], deterministically and in grid order at
//!   any pool width, with per-cell wall-clock budgets;
//! * [`observer`] — the streaming [`Observer`] trait and the built-in
//!   sinks (per-cell CSVs + resume sidecars, `manifest.json`,
//!   `summary.json`, progress lines, the `--json` summary stream);
//! * [`runner`] — scenario results and the mean±std seed aggregation
//!   ([`summarize_groups`]), plus the thin pre-session
//!   [`run_scenarios`] compat wrapper;
//! * [`regret`] — the regret planner and decomposition: every online
//!   cell shadowed by the two clairvoyant anchors on the same
//!   environment stream (`lroa regret`, or any [`Experiment`] with
//!   [`Anchors::Both`]).
//!
//! Sweeps are resumable: a resumed session skips cells whose CSV (and
//! matching `.hash` fingerprint) already exists under its out dir, and
//! re-reads them so the summary still aggregates the full grid.

pub mod observer;
pub mod regret;
pub mod runner;
pub mod session;
pub mod spec;

pub use observer::{
    CellResult, CellStart, CsvObserver, GridSummary, JsonObserver, ManifestObserver, Observer,
    ProgressObserver, RoundEvent, SummaryObserver, TraceObserver,
};
pub use runner::{
    mean_series_over, run_scenarios, summarize_groups, GroupSummary, ScenarioResult, Stat,
};
pub use session::{Anchors, Experiment, Session, SessionReport};
pub use spec::{manifest_json, EnvSel, Scenario, SweepSpec};
