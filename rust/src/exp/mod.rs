//! Declarative multi-scenario experiment engine.
//!
//! The paper's evaluation is a grid: policy × environment × K × µ/ν ×
//! seed × dataset, every cell run on shared channel realizations.  This
//! subsystem makes that grid a value instead of a hand-rolled loop:
//!
//! * [`spec`] — [`SweepSpec`], the declarative grid, its expansion into
//!   concrete [`Scenario`]s (config + label + group key), and the
//!   machine-readable grid manifest ([`manifest_json`]) the figure
//!   pipeline consumes;
//! * [`runner`] — the thread-pooled scenario runner (deterministic
//!   per-scenario results, slot-ordered output, per-cell wall-clock
//!   budgets) and the mean±std aggregation of seed repeats;
//! * [`regret`] — the regret planner: shadows every online cell with a
//!   clairvoyant oracle run on the same environment stream and fills
//!   the `regret` CSV column (`lroa regret`).
//!
//! Sweeps are resumable: `lroa sweep --resume` skips cells whose CSV
//! already exists under `--out` (and re-reads them so `summary.json`
//! still aggregates the full grid), so a killed grid continues where it
//! stopped.  The `lroa sweep`/`lroa regret` CLI subcommands, the figure
//! examples, and the harness all sit on top of this module.

pub mod regret;
pub mod runner;
pub mod spec;

pub use runner::{
    run_scenarios, summarize_groups, GroupSummary, ScenarioResult, Stat,
};
pub use spec::{manifest_json, EnvSel, Scenario, SweepSpec};
