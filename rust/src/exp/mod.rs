//! Declarative multi-scenario experiment engine.
//!
//! The paper's evaluation is a grid: policy × environment × K × µ/ν ×
//! seed × dataset, every cell run on shared channel realizations.  This
//! subsystem makes that grid a value instead of a hand-rolled loop:
//!
//! * [`spec`] — [`SweepSpec`], the declarative grid, its expansion into
//!   concrete [`Scenario`]s (config + label + group key), and the
//!   machine-readable grid manifest ([`manifest_json`]) the figure
//!   pipeline consumes;
//! * [`runner`] — the thread-pooled scenario runner (deterministic
//!   per-scenario results, slot-ordered output) and the mean±std
//!   aggregation of seed repeats.
//!
//! Sweeps are resumable: `lroa sweep --resume` skips cells whose CSV
//! already exists under `--out`, so a killed grid continues where it
//! stopped.  The `lroa sweep` CLI subcommand, the figure examples, and
//! the harness all sit on top of this module.

pub mod runner;
pub mod spec;

pub use runner::{
    run_scenarios, summarize_groups, GroupSummary, ScenarioResult, Stat,
};
pub use spec::{manifest_json, Scenario, SweepSpec};
