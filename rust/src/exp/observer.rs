//! Streaming experiment observers: the event surface of the session
//! engine.
//!
//! A [`crate::exp::Session`] does not harvest results at the end of a
//! grid — it *streams* them.  Every sink that used to be hard-wired into
//! the CLI front-ends (per-cell CSV emission and resume sidecars, the
//! grid manifest, `summary.json`, progress lines, the `--json` summary)
//! is an [`Observer`] implementation here, attached by the consumer via
//! [`crate::exp::Experiment::observe`].  Embedders implement the trait
//! themselves to pipe rounds into their own telemetry.
//!
//! Event order, per session run:
//!
//! 1. [`Observer::on_grid_start`] — once, with the full planned grid
//!    (before any cell executes);
//! 2. [`Observer::on_resume`] — once, only on `--resume` runs, with the
//!    skip partition;
//! 3. per fresh cell: [`Observer::on_cell_start`], then (for observers
//!    that opt in via [`Observer::wants_rounds`]) one
//!    [`Observer::on_round`] per round **in round order**, then
//!    [`Observer::on_cell_done`].  Cells run concurrently, so events of
//!    *different* cells interleave; within one cell the order is exact
//!    (pinned by `tests/session_parity.rs`).  Resumed cells re-read from
//!    disk emit no per-cell events — they surface in the grid summary;
//! 4. [`Observer::on_grid_done`] — once, after seed aggregation (and
//!    after the regret decomposition on anchored sessions).
//!
//! Observers run under the session's event lock, so implementations may
//! keep plain mutable state; fallible sinks (`on_cell_done`,
//! `on_grid_start`, `on_grid_done`) fail the session loudly.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use super::runner::{GroupSummary, ScenarioResult};
use super::spec::{manifest_json, Scenario};
use crate::json::{obj, Json};
use crate::metrics::{num_or_null, Recorder, RoundRecord};
use crate::trace::TraceHub;
use crate::Result;

/// A cell is about to execute.
pub struct CellStart<'a> {
    /// Grid position (index into the planned cell list).
    pub cell: usize,
    pub label: &'a str,
    pub group: &'a str,
    /// Total cells in the planned grid (resumed cells included).
    pub cells_total: usize,
}

/// One round of one cell just executed (opt-in via
/// [`Observer::wants_rounds`]).
pub struct RoundEvent<'a> {
    /// Grid position of the cell this round belongs to.
    pub cell: usize,
    pub label: &'a str,
    pub round: usize,
    pub record: &'a RoundRecord,
}

/// A cell finished: its full metrics ledger plus metadata.
pub struct CellResult<'a> {
    /// Grid position.
    pub cell: usize,
    pub scenario: &'a Scenario,
    pub recorder: &'a Recorder,
    /// Host wall-clock of this cell [s].
    pub wall_s: f64,
}

/// The completed grid: per-cell results in grid order plus the
/// seed-aggregated group rows.  On anchored (regret) sessions the
/// recorders carry the populated decomposition columns.
pub struct GridSummary<'a> {
    pub results: &'a [ScenarioResult],
    pub groups: &'a [GroupSummary],
    /// Cells satisfied from existing CSVs by a `--resume` run.
    pub resumed_cells: usize,
}

/// A streaming sink for session events.  All methods default to no-ops;
/// implement the ones you care about.
pub trait Observer: Send {
    /// Opt into per-round [`Observer::on_round`] events.  Off by default
    /// so sessions that only consume cell/grid events never pay the
    /// per-round event dispatch.
    fn wants_rounds(&self) -> bool {
        false
    }

    /// The planned grid, before any cell executes.
    fn on_grid_start(&mut self, _cells: &[Scenario]) -> Result<()> {
        Ok(())
    }

    /// The `--resume` skip partition: `skipped` cells were satisfied from
    /// existing CSVs, `to_run` remain.
    fn on_resume(&mut self, _skipped: usize, _to_run: usize) {}

    fn on_cell_start(&mut self, _ev: &CellStart<'_>) {}

    fn on_round(&mut self, _ev: &RoundEvent<'_>) {}

    fn on_cell_done(&mut self, _ev: &CellResult<'_>) -> Result<()> {
        Ok(())
    }

    fn on_grid_done(&mut self, _summary: &GridSummary<'_>) -> Result<()> {
        Ok(())
    }
}

/// Writes the machine-readable grid manifest (`manifest.json`) the
/// moment the grid starts — before any cell runs, so a crashed or
/// resumed session still documents its full grid (cell labels, config
/// hashes, the CSV `columns` schema, regret anchor links).
#[derive(Debug)]
pub struct ManifestObserver {
    dir: PathBuf,
    quiet: bool,
}

impl ManifestObserver {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self {
            dir: dir.into(),
            quiet: false,
        }
    }

    /// Announce the written manifest on stderr instead of stdout — for
    /// `--json` runs, whose stdout must stay a pure JSON stream.
    pub fn quiet(mut self) -> Self {
        self.quiet = true;
        self
    }
}

impl Observer for ManifestObserver {
    fn on_grid_start(&mut self, cells: &[Scenario]) -> Result<()> {
        std::fs::create_dir_all(&self.dir)?;
        let path = self.dir.join("manifest.json");
        std::fs::write(&path, manifest_json(cells).to_string())?;
        if self.quiet {
            eprintln!("wrote {}", path.display());
        } else {
            println!("wrote {}", path.display());
        }
        Ok(())
    }
}

/// Streams each cell's CSV out the moment it finishes, so a killed grid
/// keeps every completed cell and `--resume` can skip them.  Writes are
/// write-then-rename (a kill mid-write never leaves a truncated CSV that
/// resume would mistake for a finished cell), and the `.hash` sidecar —
/// written last — records the fingerprint the cell actually ran under,
/// so resume re-runs cells whose config has since changed.
#[derive(Debug)]
pub struct CsvObserver {
    dir: PathBuf,
    rewrite_final: bool,
}

impl CsvObserver {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self {
            dir: dir.into(),
            rewrite_final: false,
        }
    }

    /// Rewrite every cell CSV once the grid completes.  Anchored
    /// (regret) sessions need this: cells stream *raw* CSVs as they
    /// finish (decomposition columns still empty), and the final rewrite
    /// lands the populated columns — so a completed run never ships a
    /// CSV without them, while a crashed run still keeps its evidence.
    pub fn rewrite_final(mut self) -> Self {
        self.rewrite_final = true;
        self
    }
}

impl Observer for CsvObserver {
    fn on_cell_done(&mut self, ev: &CellResult<'_>) -> Result<()> {
        std::fs::create_dir_all(&self.dir)?;
        let tmp = self.dir.join(format!("{}.csv.tmp", ev.recorder.label));
        ev.recorder.write_csv(&tmp)?;
        std::fs::rename(&tmp, self.dir.join(format!("{}.csv", ev.recorder.label)))?;
        std::fs::write(
            self.dir.join(format!("{}.hash", ev.recorder.label)),
            ev.scenario.fingerprint(),
        )?;
        Ok(())
    }

    fn on_grid_done(&mut self, summary: &GridSummary<'_>) -> Result<()> {
        if self.rewrite_final {
            for r in summary.results {
                // Same write-then-rename discipline as the streaming
                // path: the cell's `.hash` sidecar already validates, so
                // an in-place rewrite killed mid-write would leave a
                // truncated CSV that a later resume trusts.
                let tmp = self.dir.join(format!("{}.csv.tmp", r.recorder.label));
                r.recorder.write_csv(&tmp)?;
                std::fs::rename(&tmp, self.dir.join(format!("{}.csv", r.recorder.label)))?;
            }
        }
        Ok(())
    }
}

/// The seed-aggregated group rows as JSON objects — the one shape shared
/// by `summary.json` ([`SummaryObserver`]) and the `--json` stdout
/// stream ([`JsonObserver`]), so the two can never drift apart.
pub fn groups_json(groups: &[GroupSummary]) -> Vec<Json> {
    groups
        .iter()
        .map(|g| {
            obj(vec![
                ("group", Json::Str(g.group.clone())),
                ("runs", Json::Num(g.runs as f64)),
                ("total_time_s_mean", num_or_null(g.total_time_s.mean)),
                ("total_time_s_std", num_or_null(g.total_time_s.std)),
                ("final_accuracy_mean", num_or_null(g.final_accuracy.mean)),
                ("final_regret_mean", num_or_null(g.final_regret.mean)),
                ("final_regret_std", num_or_null(g.final_regret.std)),
                (
                    "final_regret_online_mean",
                    num_or_null(g.final_regret_online.mean),
                ),
                (
                    "final_regret_online_std",
                    num_or_null(g.final_regret_online.std),
                ),
                (
                    "final_regret_budget_mean",
                    num_or_null(g.final_regret_budget.mean),
                ),
                (
                    "final_regret_budget_std",
                    num_or_null(g.final_regret_budget.std),
                ),
            ])
        })
        .collect()
}

/// Writes the machine-readable aggregate bundle (`summary.json`: group
/// rows, per-run summaries, resumed-cell count) when the grid completes.
#[derive(Debug)]
pub struct SummaryObserver {
    dir: PathBuf,
}

impl SummaryObserver {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into() }
    }
}

impl Observer for SummaryObserver {
    fn on_grid_done(&mut self, summary: &GridSummary<'_>) -> Result<()> {
        let run_summaries: Vec<Json> = summary
            .results
            .iter()
            .map(|r| r.recorder.summary_json())
            .collect();
        std::fs::create_dir_all(&self.dir)?;
        std::fs::write(
            self.dir.join("summary.json"),
            obj(vec![
                ("groups", Json::Arr(groups_json(summary.groups))),
                ("runs", Json::Arr(run_summaries)),
                ("resumed_cells", Json::Num(summary.resumed_cells as f64)),
            ])
            .to_string(),
        )?;
        Ok(())
    }
}

/// Human progress, exactly where the pre-session CLI printed it: the
/// resume partition on stdout, one line per completed cell on stderr —
/// now with measured throughput (rounds/s) and a grid ETA extrapolated
/// from elapsed wall-clock over completed cells.  Every line goes to
/// stderr, so `--json` runs keep a pure-JSON stdout.
#[derive(Debug, Default)]
pub struct ProgressObserver {
    quiet: bool,
    /// Grid start, anchoring the ETA extrapolation.
    started: Option<Instant>,
    /// Cells this run will execute (resume partition applied).
    total: usize,
    done: usize,
}

impl ProgressObserver {
    pub fn new() -> Self {
        Self::default()
    }

    /// Route the resume-partition lines to stderr too — for `--json`
    /// runs, whose stdout must stay a pure JSON stream.
    pub fn quiet(mut self) -> Self {
        self.quiet = true;
        self
    }
}

impl Observer for ProgressObserver {
    fn on_grid_start(&mut self, cells: &[Scenario]) -> Result<()> {
        self.started = Some(Instant::now());
        self.total = cells.len();
        self.done = 0;
        Ok(())
    }

    fn on_resume(&mut self, skipped: usize, to_run: usize) {
        self.total = to_run;
        let line = format!(
            "resume: skipping {skipped} cells with existing CSVs (re-read for the \
             aggregate), running {to_run}"
        );
        if self.quiet {
            eprintln!("{line}");
            if to_run == 0 {
                eprintln!("resume: nothing left to run");
            }
        } else {
            println!("{line}");
            if to_run == 0 {
                println!("resume: nothing left to run");
            }
        }
    }

    fn on_cell_done(&mut self, ev: &CellResult<'_>) -> Result<()> {
        self.done += 1;
        let throughput = ev.recorder.rounds.len() as f64 / ev.wall_s.max(1e-9);
        // Extrapolate the remaining cells from elapsed-per-completed-cell
        // (concurrency-aware: elapsed is shared wall-clock, not cell sum).
        let eta = match (self.started, self.total.checked_sub(self.done)) {
            (Some(t0), Some(left)) if left > 0 && self.done > 0 => {
                let per_cell = t0.elapsed().as_secs_f64() / self.done as f64;
                format!(", ETA {:.0}s", per_cell * left as f64)
            }
            _ => String::new(),
        };
        eprintln!(
            "[exp] {}: {} rounds, modeled {:.1}s, final acc {:.4}, wall {:.1}s, \
             {:.0} rounds/s ({}/{} cells{eta})",
            ev.recorder.label,
            ev.recorder.rounds.len(),
            ev.recorder.total_time_s(),
            ev.recorder.final_accuracy(),
            ev.wall_s,
            throughput,
            self.done,
            self.total.max(self.done),
        );
        Ok(())
    }
}

/// Streams the grid summary to stdout as one JSON object when the grid
/// completes — the machine-readable sibling of the printed table
/// (`lroa sweep --json` / `lroa regret --json`).  Shape:
/// `{"groups": [...], "resumed_cells": N}` with the same group fields as
/// `summary.json` (shared via [`groups_json`]).
///
/// stdout purity is the attacher's contract, not this type's: pair it
/// with stderr-routed chrome ([`ManifestObserver::quiet`],
/// [`ProgressObserver::quiet`], the CLI's `say` helper) so the stream
/// stays exactly one JSON object — `lroa sweep --json | json_tool` is
/// CI-pinned.
#[derive(Debug, Default)]
pub struct JsonObserver;

impl JsonObserver {
    pub fn new() -> Self {
        Self
    }
}

impl Observer for JsonObserver {
    fn on_grid_done(&mut self, summary: &GridSummary<'_>) -> Result<()> {
        println!(
            "{}",
            obj(vec![
                ("groups", Json::Arr(groups_json(summary.groups))),
                ("resumed_cells", Json::Num(summary.resumed_cells as f64)),
            ])
        );
        Ok(())
    }
}

/// Exports the session's trace (`trace.json` + `trace_summary.json`)
/// when the grid completes.  Attached automatically by
/// [`crate::exp::Experiment::trace`]; span *recording* never goes
/// through the observer hub — the workers fill the shared
/// [`TraceHub`] directly, and this observer only triggers the export
/// after every cell has submitted.
pub struct TraceObserver {
    hub: Arc<TraceHub>,
}

impl TraceObserver {
    pub fn new(hub: Arc<TraceHub>) -> Self {
        Self { hub }
    }
}

impl Observer for TraceObserver {
    fn on_grid_done(&mut self, _summary: &GridSummary<'_>) -> Result<()> {
        self.hub.export()?;
        eprintln!(
            "[trace] wrote {} (+ trace_summary.json)",
            self.hub.dir().join("trace.json").display()
        );
        Ok(())
    }
}
