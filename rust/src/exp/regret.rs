//! Regret experiments: every online cell paired with a clairvoyant
//! oracle anchor on the same environment stream.
//!
//! The paper's premise is online control *without knowledge of future
//! dynamics*; the natural question is how much that ignorance costs.
//! Following the clairvoyant-anchor methodology of Shi et al. and Luo
//! et al., `lroa regret` runs a policy × environment grid where every
//! cell is shadowed by an [`Policy::Oracle`] run on the *same* draws:
//! environments are pure functions of `(config, train.seed)` (never of
//! the policy), so building a second server with only `train.policy`
//! changed forks an identical stream.  The selection-reactive `adv`
//! environment is the documented exception — there the oracle faces its
//! own adaptive adversary, the standard convention for adaptive-regret
//! comparisons.
//!
//! Each online cell's CSV gains a populated `regret` column:
//! `regret[t] = total_time_s[t] − total_time_s_oracle[t]`, the
//! cumulative latency the policy has paid for being online.  Oracle
//! cells carry `regret = 0`.  The manifest links each cell to its
//! anchor via `regret_vs`.

use std::collections::BTreeMap;

use super::runner::{run_scenarios, ScenarioResult};
use super::spec::{Scenario, SweepSpec};
use crate::config::Policy;
use crate::Result;

/// Expand a regret grid: the spec's online cells plus one oracle cell
/// per distinct environment stream (dataset × env × K × µ/ν × seed ×
/// rounds), each online cell back-linked to its anchor via
/// [`Scenario::regret_vs`].  Oracle cells come last, with no link.
pub fn plan(spec: &SweepSpec) -> Result<Vec<Scenario>> {
    anyhow::ensure!(
        !spec.policies.contains(&Policy::Oracle),
        "regret: the oracle anchor is added automatically; drop it from --policies"
    );
    let online = spec.expand()?;
    let mut oracle_spec = spec.clone();
    oracle_spec.policies = vec![Policy::Oracle];
    let oracle = oracle_spec.expand()?;

    // Stream key: the cell's config with the policy normalized away —
    // two cells share an environment stream iff everything else matches.
    let stream_key = |sc: &Scenario| -> String {
        let mut cfg = sc.cfg.clone();
        cfg.train.policy = Policy::Oracle;
        cfg.hash_hex()
    };
    let anchors: BTreeMap<String, String> = oracle
        .iter()
        .map(|sc| (stream_key(sc), sc.label.clone()))
        .collect();

    let mut out = Vec::with_capacity(online.len() + oracle.len());
    for mut sc in online {
        let anchor = anchors
            .get(&stream_key(&sc))
            .expect("the oracle grid covers every stream by construction")
            .clone();
        sc.regret_vs = Some(anchor);
        out.push(sc);
    }
    out.extend(oracle);
    Ok(out)
}

/// Run a planned regret grid and populate the `regret` column: oracle
/// cells get 0, online cells get their cumulative latency gap against
/// their anchor, round for round.
pub fn run(scenarios: Vec<Scenario>, threads: usize) -> Result<Vec<ScenarioResult>> {
    let mut results = run_scenarios(scenarios, threads)?;
    let oracle_times: BTreeMap<String, Vec<f64>> = results
        .iter()
        .filter(|r| r.scenario.cfg.train.policy == Policy::Oracle)
        .map(|r| {
            let series = r.recorder.rounds.iter().map(|x| x.total_time_s).collect();
            (r.scenario.label.clone(), series)
        })
        .collect();
    for r in &mut results {
        if r.scenario.cfg.train.policy == Policy::Oracle {
            for rec in &mut r.recorder.rounds {
                rec.regret = 0.0;
            }
            continue;
        }
        let anchor = r
            .scenario
            .regret_vs
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("cell {} has no oracle anchor", r.scenario.label))?;
        let base = oracle_times
            .get(anchor)
            .ok_or_else(|| anyhow::anyhow!("oracle cell {anchor} missing from the grid"))?;
        anyhow::ensure!(
            base.len() == r.recorder.rounds.len(),
            "cell {} and anchor {anchor} ran different horizons",
            r.scenario.label
        );
        for (rec, oracle_total) in r.recorder.rounds.iter_mut().zip(base) {
            rec.regret = rec.total_time_s - oracle_total;
        }
    }
    Ok(results)
}

/// The smallest final regret across online cells — ≥ 0 whenever the
/// oracle is the latency lower bound it is designed to be (exact on
/// action-independent environments; empirical under the adaptive `adv`
/// adversary, where the streams differ by construction).
pub fn min_final_regret(results: &[ScenarioResult]) -> f64 {
    results
        .iter()
        .filter(|r| r.scenario.cfg.train.policy != Policy::Oracle)
        .map(|r| r.recorder.final_regret())
        .fold(f64::INFINITY, f64::min)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnvKind;
    use crate::exp::EnvSel;

    fn small_spec() -> SweepSpec {
        let trace = format!("trace:{}", crate::test_util::campus_fixture());
        SweepSpec {
            datasets: vec!["cifar".into()],
            policies: vec![Policy::Lroa, Policy::GreedyChannel, Policy::PowerOfTwoChoices],
            envs: vec![
                EnvSel::parse(&trace).unwrap(),
                EnvSel::from(EnvKind::Adversarial),
            ],
            seeds: vec![1, 2],
            rounds: Some(30),
            overrides: vec!["--system.num_devices=12".into()],
            ..SweepSpec::default()
        }
    }

    #[test]
    fn plan_pairs_every_online_cell_with_an_anchor() {
        let cells = plan(&small_spec()).unwrap();
        // 3 policies × 2 envs × 2 seeds online + 2 envs × 2 seeds oracle.
        assert_eq!(cells.len(), 3 * 2 * 2 + 2 * 2);
        let oracle_labels: Vec<&str> = cells
            .iter()
            .filter(|c| c.cfg.train.policy == Policy::Oracle)
            .map(|c| c.label.as_str())
            .collect();
        assert_eq!(oracle_labels.len(), 4);
        for c in cells.iter().filter(|c| c.cfg.train.policy != Policy::Oracle) {
            let anchor = c.regret_vs.as_deref().expect("online cell unpaired");
            assert!(oracle_labels.contains(&anchor), "{}: bad anchor {anchor}", c.label);
            // The anchor shares env kind and seed.
            let a = cells.iter().find(|x| x.label == anchor).unwrap();
            assert_eq!(a.cfg.env.kind, c.cfg.env.kind);
            assert_eq!(a.cfg.train.seed, c.cfg.train.seed);
        }
        // Oracle must not be passed as an online policy.
        let mut bad = small_spec();
        bad.policies.push(Policy::Oracle);
        assert!(plan(&bad).is_err());
    }

    #[test]
    fn run_populates_a_consistent_regret_column() {
        let cells = plan(&small_spec()).unwrap();
        let results = run(cells, 2).unwrap();
        for r in &results {
            let is_oracle = r.scenario.cfg.train.policy == Policy::Oracle;
            for rec in &r.recorder.rounds {
                assert!(
                    !rec.regret.is_nan(),
                    "{}: regret column not populated",
                    r.scenario.label
                );
                if is_oracle {
                    assert_eq!(rec.regret, 0.0);
                }
            }
            if !is_oracle {
                // Cumulative latency gap is non-decreasing exactly when
                // the oracle is the per-round lower bound; on the trace
                // env (shared stream) that is a theorem.
                if r.scenario.cfg.env.kind == EnvKind::Trace {
                    let regs: Vec<f64> =
                        r.recorder.rounds.iter().map(|x| x.regret).collect();
                    assert!(
                        regs.windows(2).all(|w| w[1] >= w[0] - 1e-9),
                        "{}: regret decreased on a shared stream",
                        r.scenario.label
                    );
                    assert!(regs[0] >= -1e-9);
                }
                // On the adaptive `adv` stream the bound is empirical,
                // not a theorem (the anchor faces its own adversary) —
                // but this grid is fully seeded, so the check is stable:
                // if it ever fires, the oracle stopped being a usable
                // anchor for these defaults and that *should* be loud.
                assert!(
                    r.recorder.final_regret() >= -1e-9,
                    "{}: oracle not a lower bound (final regret {})",
                    r.scenario.label,
                    r.recorder.final_regret()
                );
            }
        }
        assert!(min_final_regret(&results) >= -1e-9);
    }
}
