//! Regret experiments: every online cell paired with *two* clairvoyant
//! anchors on the same environment stream, and its regret decomposed
//! into online and budget components.
//!
//! The paper's premise is online control *without knowledge of future
//! dynamics and under per-device energy budgets*; those are two separate
//! handicaps, and a single unconstrained oracle anchor conflates them.
//! Following the regret-splitting ideas of the bandit-scheduling line
//! (Shi et al.) and the energy/latency framing of Luo et al., `lroa
//! regret` shadows every online cell with both anchors on the same
//! draws:
//!
//! * [`Policy::Oracle`] — clairvoyant and budget-blind: the latency
//!   floor (`f_max`/`p_max`, fastest device);
//! * [`Policy::OracleEnergy`] — clairvoyant and budget-feasible: the
//!   same per-round energy-constrained problem LROA solves (Theorem 2/3
//!   kernels under queue prices), fastest device afterwards.
//!
//! Environments are pure functions of `(config, train.seed)` (never of
//! the policy), so building servers that differ only in `train.policy`
//! forks identical streams.  The selection-reactive `adv` environment is
//! the documented exception — there every cell faces its own adaptive
//! adversary, the standard convention for adaptive-regret comparisons.
//!
//! Each online cell's CSV gains three populated columns:
//!
//! * `regret_online[t] = total_time_s[t] − total_time_s_oracle_e[t]`
//! * `regret_budget[t] = total_time_s_oracle_e[t] − total_time_s_oracle[t]`
//! * `regret[t]        = regret_online[t] + regret_budget[t]`
//!
//! `regret` is *derived as that sum* — not recomputed as
//! `total − total_oracle`, which would only match up to rounding — so
//! `regret_online + regret_budget == regret` holds **bitwise** by
//! construction, and `regret_budget ≥ 0` on every action-independent
//! environment (per-device latency is monotone in `f` and `p`, so the
//! throttled clairvoyant can never beat the unthrottled one on a shared
//! stream).  Oracle cells carry all-zero columns; oracle-e cells carry
//! their own budget gap (`regret = regret_budget`, `regret_online = 0`)
//! — the price of feasibility in isolation.  The manifest links each
//! online cell to its anchors via `regret_vs` / `regret_vs_e`.

use std::collections::BTreeMap;

use super::runner::{run_scenarios, ScenarioResult};
use super::spec::{Scenario, SweepSpec};
use crate::config::{Config, Policy};
use crate::Result;

/// Expand a regret grid against the paper-default base configs: the
/// spec's online cells plus one `oracle` and one `oracle-e` cell per
/// distinct environment stream (dataset × env × K × µ/ν × seed ×
/// rounds).  Online cells are back-linked to both anchors via
/// [`Scenario::regret_vs`] / [`Scenario::regret_vs_e`]; `oracle-e` cells
/// link to their `oracle` via `regret_vs` (their regret *is* the budget
/// gap).  Anchor cells come last.
pub fn plan(spec: &SweepSpec) -> Result<Vec<Scenario>> {
    plan_with(spec, Config::for_dataset)
}

/// [`plan`] with a caller-supplied base-config builder (called once per
/// cell with the dataset name) — how an anchored
/// [`crate::exp::Experiment`] plans its grid over a custom base.
pub fn plan_with<F>(spec: &SweepSpec, mut base: F) -> Result<Vec<Scenario>>
where
    F: FnMut(&str) -> Result<Config>,
{
    for anchor in [Policy::Oracle, Policy::OracleEnergy] {
        anyhow::ensure!(
            !spec.policies.contains(&anchor),
            "regret: the {anchor} anchor is added automatically; drop it from --policies"
        );
    }
    let online = spec.expand_with(&mut base)?;
    let mut oracle_spec = spec.clone();
    oracle_spec.policies = vec![Policy::Oracle];
    let oracle = oracle_spec.expand_with(&mut base)?;
    let mut oracle_e_spec = spec.clone();
    oracle_e_spec.policies = vec![Policy::OracleEnergy];
    let oracle_e = oracle_e_spec.expand_with(&mut base)?;

    // Stream key: the cell's config with the policy normalized away —
    // two cells share an environment stream iff everything else matches.
    let stream_key = |sc: &Scenario| -> String {
        let mut cfg = sc.cfg.clone();
        cfg.train.policy = Policy::Oracle;
        cfg.hash_hex()
    };
    let anchors: BTreeMap<String, String> = oracle
        .iter()
        .map(|sc| (stream_key(sc), sc.label.clone()))
        .collect();
    let anchors_e: BTreeMap<String, String> = oracle_e
        .iter()
        .map(|sc| (stream_key(sc), sc.label.clone()))
        .collect();

    let mut out = Vec::with_capacity(online.len() + oracle.len() + oracle_e.len());
    for mut sc in online {
        let key = stream_key(&sc);
        let anchor = anchors
            .get(&key)
            .expect("the oracle grid covers every stream by construction")
            .clone();
        let anchor_e = anchors_e
            .get(&key)
            .expect("the oracle-e grid covers every stream by construction")
            .clone();
        sc.regret_vs = Some(anchor);
        sc.regret_vs_e = Some(anchor_e);
        out.push(sc);
    }
    for mut sc in oracle_e {
        // The budget anchor's own regret is measured against the
        // unconstrained oracle on the same stream.
        let anchor = anchors
            .get(&stream_key(&sc))
            .expect("the oracle grid covers every stream by construction")
            .clone();
        sc.regret_vs = Some(anchor);
        out.push(sc);
    }
    out.extend(oracle);
    Ok(out)
}

/// Run a planned regret grid and populate the decomposition columns —
/// [`run_scenarios`] + [`decompose`].  The pre-session compat surface;
/// an anchored [`crate::exp::Experiment`] runs the same two stages with
/// observers streaming in between.
pub fn run(scenarios: Vec<Scenario>, threads: usize) -> Result<Vec<ScenarioResult>> {
    let mut results = run_scenarios(scenarios, threads)?;
    decompose(&mut results)?;
    Ok(results)
}

/// Populate the regret decomposition columns of a completed, planned
/// grid in place: oracle cells get zeros, oracle-e cells their budget
/// gap, online cells `regret` vs the oracle plus the bitwise split
/// `regret = regret_online + regret_budget`.
pub fn decompose(results: &mut [ScenarioResult]) -> Result<()> {
    let collect = |results: &[ScenarioResult], policy: Policy| -> BTreeMap<String, Vec<f64>> {
        results
            .iter()
            .filter(|r| r.scenario.cfg.train.policy == policy)
            .map(|r| {
                let series = r.recorder.rounds.iter().map(|x| x.total_time_s).collect();
                (r.scenario.label.clone(), series)
            })
            .collect()
    };
    let oracle_times = collect(&*results, Policy::Oracle);
    let oracle_e_times = collect(&*results, Policy::OracleEnergy);

    for r in results.iter_mut() {
        let label = r.scenario.label.clone();
        let len = r.recorder.rounds.len();
        match r.scenario.cfg.train.policy {
            Policy::Oracle => {
                for rec in &mut r.recorder.rounds {
                    rec.regret = 0.0;
                    rec.regret_online = 0.0;
                    rec.regret_budget = 0.0;
                }
            }
            Policy::OracleEnergy => {
                let base = anchor_series(&r.scenario.regret_vs, &oracle_times, &label, len)?;
                for (rec, oracle_total) in r.recorder.rounds.iter_mut().zip(&base) {
                    rec.regret = rec.total_time_s - oracle_total;
                    rec.regret_budget = rec.regret;
                    rec.regret_online = 0.0;
                }
            }
            _ => {
                let base_o = anchor_series(&r.scenario.regret_vs, &oracle_times, &label, len)?;
                let base_e =
                    anchor_series(&r.scenario.regret_vs_e, &oracle_e_times, &label, len)?;
                for ((rec, oracle_total), oracle_e_total) in
                    r.recorder.rounds.iter_mut().zip(&base_o).zip(&base_e)
                {
                    rec.regret_online = rec.total_time_s - oracle_e_total;
                    rec.regret_budget = oracle_e_total - oracle_total;
                    // The headline is *derived as the sum*, so the
                    // decomposition is a bitwise identity — computing it
                    // as total − total_oracle would only match up to
                    // floating-point rounding.
                    rec.regret = rec.regret_online + rec.regret_budget;
                }
            }
        }
    }
    Ok(())
}

/// Look up a cell's anchor series by its back-link and check horizons
/// match (anchors and online cells must run identical grids).
fn anchor_series(
    link: &Option<String>,
    table: &BTreeMap<String, Vec<f64>>,
    label: &str,
    len: usize,
) -> Result<Vec<f64>> {
    let anchor = link
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("cell {label} has no anchor link"))?;
    let base = table
        .get(anchor)
        .ok_or_else(|| anyhow::anyhow!("anchor cell {anchor} missing from the grid"))?;
    anyhow::ensure!(
        base.len() == len,
        "cell {label} and anchor {anchor} ran different horizons"
    );
    Ok(base.clone())
}

/// Whether a cell is one of the two clairvoyant anchors.
pub fn is_anchor(policy: Policy) -> bool {
    matches!(policy, Policy::Oracle | Policy::OracleEnergy)
}

/// The smallest final regret across online cells — ≥ 0 whenever the
/// oracle is the latency lower bound it is designed to be (exact on
/// action-independent environments; empirical under the adaptive `adv`
/// adversary, where the streams differ by construction).
pub fn min_final_regret(results: &[ScenarioResult]) -> f64 {
    results
        .iter()
        .filter(|r| !is_anchor(r.scenario.cfg.train.policy))
        .map(|r| r.recorder.final_regret())
        .fold(f64::INFINITY, f64::min)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnvKind;
    use crate::exp::EnvSel;

    fn small_spec() -> SweepSpec {
        let trace = format!("trace:{}", crate::test_util::campus_fixture());
        SweepSpec {
            datasets: vec!["cifar".into()],
            policies: vec![Policy::Lroa, Policy::GreedyChannel, Policy::PowerOfTwoChoices],
            envs: vec![
                EnvSel::parse(&trace).unwrap(),
                EnvSel::from(EnvKind::Adversarial),
            ],
            seeds: vec![1, 2],
            rounds: Some(30),
            overrides: vec!["--system.num_devices=12".into()],
            ..SweepSpec::default()
        }
    }

    #[test]
    fn plan_pairs_every_online_cell_with_both_anchors() {
        let cells = plan(&small_spec()).unwrap();
        // 3 policies × 2 envs × 2 seeds online + 2 anchor policies × 2
        // envs × 2 seeds.
        assert_eq!(cells.len(), 3 * 2 * 2 + 2 * 2 * 2);
        let labels_of = |p: Policy| -> Vec<&str> {
            cells
                .iter()
                .filter(|c| c.cfg.train.policy == p)
                .map(|c| c.label.as_str())
                .collect()
        };
        let oracle_labels = labels_of(Policy::Oracle);
        let oracle_e_labels = labels_of(Policy::OracleEnergy);
        assert_eq!(oracle_labels.len(), 4);
        assert_eq!(oracle_e_labels.len(), 4);
        for c in cells.iter().filter(|c| !is_anchor(c.cfg.train.policy)) {
            let anchor = c.regret_vs.as_deref().expect("online cell unpaired");
            let anchor_e = c.regret_vs_e.as_deref().expect("online cell missing oracle-e");
            assert!(oracle_labels.contains(&anchor), "{}: bad anchor {anchor}", c.label);
            assert!(
                oracle_e_labels.contains(&anchor_e),
                "{}: bad oracle-e anchor {anchor_e}",
                c.label
            );
            // Both anchors share env kind and seed with the online cell.
            for a in [anchor, anchor_e] {
                let ac = cells.iter().find(|x| x.label == a).unwrap();
                assert_eq!(ac.cfg.env.kind, c.cfg.env.kind);
                assert_eq!(ac.cfg.train.seed, c.cfg.train.seed);
            }
        }
        // Oracle-e cells link to their oracle; oracle cells to nothing.
        for c in cells.iter().filter(|c| c.cfg.train.policy == Policy::OracleEnergy) {
            let anchor = c.regret_vs.as_deref().expect("oracle-e cell unpaired");
            assert!(oracle_labels.contains(&anchor));
            assert!(c.regret_vs_e.is_none());
        }
        for c in cells.iter().filter(|c| c.cfg.train.policy == Policy::Oracle) {
            assert!(c.regret_vs.is_none() && c.regret_vs_e.is_none());
        }
        // Neither anchor may be passed as an online policy.
        for anchor in [Policy::Oracle, Policy::OracleEnergy] {
            let mut bad = small_spec();
            bad.policies.push(anchor);
            assert!(plan(&bad).is_err());
        }
    }

    #[test]
    fn run_populates_a_consistent_regret_decomposition() {
        let cells = plan(&small_spec()).unwrap();
        let results = run(cells, 2).unwrap();
        for r in &results {
            let policy = r.scenario.cfg.train.policy;
            for rec in &r.recorder.rounds {
                assert!(
                    !rec.regret.is_nan()
                        && !rec.regret_online.is_nan()
                        && !rec.regret_budget.is_nan(),
                    "{}: decomposition columns not populated",
                    r.scenario.label
                );
                // The decomposition is a bitwise identity everywhere.
                assert_eq!(
                    rec.regret_online + rec.regret_budget,
                    rec.regret,
                    "{}: decomposition broke",
                    r.scenario.label
                );
                if policy == Policy::Oracle {
                    assert_eq!(rec.regret, 0.0);
                }
                if policy == Policy::OracleEnergy {
                    assert_eq!(rec.regret_online, 0.0);
                    assert_eq!(rec.regret_budget, rec.regret);
                }
            }
            if !is_anchor(policy) {
                // Cumulative latency gap is non-decreasing exactly when
                // the oracle is the per-round lower bound; on the trace
                // env (shared stream) that is a theorem — for the budget
                // component too.
                if r.scenario.cfg.env.kind == EnvKind::Trace {
                    let regs: Vec<f64> =
                        r.recorder.rounds.iter().map(|x| x.regret).collect();
                    assert!(
                        regs.windows(2).all(|w| w[1] >= w[0] - 1e-9),
                        "{}: regret decreased on a shared stream",
                        r.scenario.label
                    );
                    assert!(regs[0] >= -1e-9);
                    for rec in &r.recorder.rounds {
                        assert!(
                            rec.regret_budget >= -1e-9,
                            "{}: negative budget regret {} on a shared stream",
                            r.scenario.label,
                            rec.regret_budget
                        );
                    }
                }
                // On the adaptive `adv` stream the bound is empirical,
                // not a theorem (the anchor faces its own adversary) —
                // but this grid is fully seeded, so the check is stable:
                // if it ever fires, the oracle stopped being a usable
                // anchor for these defaults and that *should* be loud.
                assert!(
                    r.recorder.final_regret() >= -1e-9,
                    "{}: oracle not a lower bound (final regret {})",
                    r.scenario.label,
                    r.recorder.final_regret()
                );
            }
        }
        assert!(min_final_regret(&results) >= -1e-9);
    }
}
