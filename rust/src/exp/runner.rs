//! Scenario results and seed aggregation (and the thin pre-session
//! compat runner).

use std::collections::BTreeMap;

use super::session::Session;
use super::spec::Scenario;
use crate::metrics::Recorder;
use crate::Result;

/// One completed scenario: the run's full metrics plus its metadata.
pub struct ScenarioResult {
    pub scenario: Scenario,
    pub recorder: Recorder,
    /// Host wall-clock of this scenario [s].
    pub wall_s: f64,
}

/// Run every scenario, fanned over `threads` workers (0 = one per core).
///
/// Each scenario is an isolated, fully-seeded simulation, so results are
/// deterministic and come back **in scenario order** regardless of the
/// pool width.  The first failing scenario's error is propagated.
///
/// This is the pre-session compat surface: a bare [`Session`] over the
/// given cells, with no observers attached.  New code should build a
/// [`crate::exp::Experiment`] instead — it adds anchors, resume, and the
/// streaming observer sinks on the same engine.
pub fn run_scenarios(scenarios: Vec<Scenario>, threads: usize) -> Result<Vec<ScenarioResult>> {
    Ok(Session::from_cells(scenarios, threads).run()?.results)
}

/// [`crate::metrics::mean_series`] over one derived series per cell,
/// e.g. seed-averaging `time_avg_energy` across a group's repeats.  On
/// a length mismatch (a truncated legacy cell CSV re-read by a resumed
/// grid) the error names every cell label with its series length, so
/// the broken cell is identifiable instead of aborting anonymously.
pub fn mean_series_over<'a, I, F>(results: I, derive: F) -> Result<Vec<f64>>
where
    I: IntoIterator<Item = &'a ScenarioResult>,
    F: Fn(&Recorder) -> Vec<f64>,
{
    let mut labels: Vec<&str> = Vec::new();
    let mut series: Vec<Vec<f64>> = Vec::new();
    for r in results {
        labels.push(r.recorder.label.as_str());
        series.push(derive(&r.recorder));
    }
    crate::metrics::mean_series(&series).map_err(|e| {
        let lens: Vec<String> = labels
            .iter()
            .zip(&series)
            .map(|(l, s)| format!("{l}:{}", s.len()))
            .collect();
        anyhow::anyhow!("{e} (cells: {})", lens.join(", "))
    })
}

/// Mean ± population std over the finite entries of a sample.
#[derive(Clone, Copy, Debug)]
pub struct Stat {
    pub mean: f64,
    pub std: f64,
}

impl Stat {
    pub fn from_values(values: &[f64]) -> Stat {
        let xs: Vec<f64> = values.iter().copied().filter(|x| x.is_finite()).collect();
        if xs.is_empty() {
            return Stat {
                mean: f64::NAN,
                std: f64::NAN,
            };
        }
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        Stat {
            mean,
            std: var.sqrt(),
        }
    }
}

impl std::fmt::Display for Stat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.std > 0.0 {
            write!(f, "{:.3} ± {:.3}", self.mean, self.std)
        } else {
            write!(f, "{:.3}", self.mean)
        }
    }
}

/// Seed-aggregated summary of one sweep cell.
pub struct GroupSummary {
    pub group: String,
    /// Number of seed repeats aggregated.
    pub runs: usize,
    pub total_time_s: Stat,
    pub final_accuracy: Stat,
    pub time_avg_energy: Stat,
    pub time_avg_objective: Stat,
    /// Final cumulative regret vs the oracle anchor (NaN-mean outside
    /// `lroa regret` runs, where the column is unpopulated).
    pub final_regret: Stat,
    /// Final online-component regret (vs the budget-feasible `oracle-e`
    /// anchor); NaN-mean outside `lroa regret` runs.
    pub final_regret_online: Stat,
    /// Final budget-component regret (`oracle-e` vs `oracle`); NaN-mean
    /// outside `lroa regret` runs.
    pub final_regret_budget: Stat,
}

/// Collapse seed repeats: one mean±std row per scenario group, in first-
/// appearance order.
pub fn summarize_groups(results: &[ScenarioResult]) -> Vec<GroupSummary> {
    let mut order: Vec<&str> = Vec::new();
    let mut buckets: BTreeMap<&str, Vec<&ScenarioResult>> = BTreeMap::new();
    for r in results {
        let key = r.scenario.group.as_str();
        if !buckets.contains_key(key) {
            order.push(key);
        }
        buckets.entry(key).or_default().push(r);
    }
    order
        .into_iter()
        .map(|group| {
            let rs = &buckets[group];
            let pick = |f: &dyn Fn(&Recorder) -> f64| -> Vec<f64> {
                rs.iter().map(|r| f(&r.recorder)).collect()
            };
            GroupSummary {
                group: group.to_string(),
                runs: rs.len(),
                total_time_s: Stat::from_values(&pick(&|r| r.total_time_s())),
                final_accuracy: Stat::from_values(&pick(&|r| r.final_accuracy())),
                time_avg_energy: Stat::from_values(&pick(&|r| {
                    r.time_avg_energy().last().copied().unwrap_or(f64::NAN)
                })),
                time_avg_objective: Stat::from_values(&pick(&|r| {
                    r.time_avg_objective().last().copied().unwrap_or(f64::NAN)
                })),
                final_regret: Stat::from_values(&pick(&|r| r.final_regret())),
                final_regret_online: Stat::from_values(&pick(&|r| r.final_regret_online())),
                final_regret_budget: Stat::from_values(&pick(&|r| r.final_regret_budget())),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;
    use crate::exp::SweepSpec;

    fn small_spec() -> SweepSpec {
        SweepSpec {
            datasets: vec!["cifar".into()],
            policies: vec![Policy::Lroa, Policy::UniformStatic],
            seeds: vec![1, 2],
            rounds: Some(15),
            overrides: vec!["--system.num_devices=12".into()],
            ..SweepSpec::default()
        }
    }

    #[test]
    fn parallel_results_match_sequential_and_stay_ordered() {
        let seq = run_scenarios(small_spec().expand().unwrap(), 1).unwrap();
        let par = run_scenarios(small_spec().expand().unwrap(), 4).unwrap();
        assert_eq!(seq.len(), 4);
        assert_eq!(par.len(), 4);
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.scenario.label, b.scenario.label);
            assert_eq!(a.recorder.label, b.recorder.label);
            assert_eq!(a.recorder.total_time_s(), b.recorder.total_time_s());
            assert_eq!(a.recorder.rounds.len(), 15);
        }
    }

    #[test]
    fn groups_aggregate_seed_repeats() {
        let results = run_scenarios(small_spec().expand().unwrap(), 2).unwrap();
        let groups = summarize_groups(&results);
        assert_eq!(groups.len(), 2, "two policies, two groups");
        for g in &groups {
            assert_eq!(g.runs, 2, "{}: two seed repeats", g.group);
            assert!(g.total_time_s.mean > 0.0);
            assert!(g.total_time_s.std >= 0.0);
            // Control-plane runs have no accuracy: NaN-filtered to NaN.
            assert!(g.final_accuracy.mean.is_nan());
        }
        assert_eq!(groups[0].group, "LROA-cifar");
        assert_eq!(groups[1].group, "Uni-S-cifar");
    }

    #[test]
    fn mean_series_over_names_offending_cells() {
        let results = run_scenarios(small_spec().expand().unwrap(), 2).unwrap();
        let ok = mean_series_over(results.iter(), |r| r.time_avg_energy()).unwrap();
        assert_eq!(ok.len(), 15);
        let first = results[0].recorder.label.clone();
        let err = mean_series_over(results.iter(), |r| {
            let mut s = r.time_avg_energy();
            if r.label == first {
                s.truncate(3);
            }
            s
        })
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains(&first), "error names the cell: {msg}");
    }

    #[test]
    fn stat_filters_non_finite() {
        let s = Stat::from_values(&[1.0, 3.0, f64::NAN]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - 1.0).abs() < 1e-12);
        assert!(Stat::from_values(&[f64::NAN]).mean.is_nan());
    }
}
