//! The embeddable experiment session: one typed entry path for every
//! grid this crate runs.
//!
//! [`Experiment`] is a builder over a [`SweepSpec`]-shaped grid (axes,
//! base config, anchors, output directory, observers) that *compiles* to
//! a [`Session`] — the planned, validated cell list plus its sinks.
//! [`Session::run`] executes the grid on the scoped thread pool, streams
//! [`crate::exp::Observer`] events as cells progress (driving each cell
//! through the server's step-wise [`crate::fl::RoundDriver`]), applies
//! the regret decomposition on anchored grids, and returns the
//! [`SessionReport`].
//!
//! The CLI front-ends (`lroa sweep`, `lroa regret`), the figure-example
//! harness, and the examples are all consumers of this one API; their
//! former private plumbing (CSV streaming, resume bookkeeping, manifest
//! emission, summary bundles, progress lines) lives in
//! [`crate::exp::observer`].  Embedding the engine is ten lines:
//!
//! ```no_run
//! use lroa::config::{Config, Policy};
//! use lroa::exp::{Anchors, Experiment};
//!
//! # fn main() -> lroa::Result<()> {
//! let report = Experiment::new(Config::for_dataset("cifar")?)
//!     .policies(&[Policy::Lroa, Policy::UniformStatic])
//!     .seeds(&[1, 2, 3])
//!     .rounds(200)
//!     .anchors(Anchors::Both)
//!     .threads(0)
//!     .run()?;
//! for g in &report.groups {
//!     println!("{}: {} (regret {})", g.group, g.total_time_s, g.final_regret);
//! }
//! # Ok(())
//! # }
//! ```
//!
//! Outputs are bitwise-identical to the pre-session pipeline: same cell
//! CSV bytes, same `summary.json`, same `manifest.json` (pinned by
//! `tests/session_parity.rs`).

use std::collections::BTreeSet;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::observer::{CellResult, CellStart, GridSummary, Observer, RoundEvent, TraceObserver};
use super::regret;
use super::runner::{summarize_groups, GroupSummary, ScenarioResult};
use super::spec::{manifest_json, EnvSel, Scenario, SweepSpec};
use crate::config::{Config, Policy};
use crate::fl::{Server, SimMode};
use crate::json::Json;
use crate::metrics::Recorder;
use crate::par;
use crate::trace::{TraceConfig, TraceHub};
use crate::Result;

/// Which clairvoyant anchors shadow the grid's online cells.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Anchors {
    /// Plain sweep: only the cells you asked for.
    None,
    /// `lroa regret` mode: every environment stream gains an `oracle`
    /// (budget-blind latency floor) and an `oracle-e` (budget-feasible)
    /// cell, online cells are back-linked to both, and the session
    /// populates the `regret`/`regret_online`/`regret_budget` columns
    /// after the grid completes (see [`crate::exp::regret`]).
    Both,
}

/// How each cell's base [`Config`] is built from its dataset name.
enum Base<'a> {
    /// Paper defaults per dataset ([`Config::for_dataset`]).
    Defaults,
    /// One explicit config for every cell (the embedded-use path); the
    /// dataset axis only overrides `train.dataset` on top of it.
    Fixed(Box<Config>),
    /// Caller-supplied builder (e.g. the figure harness's quick-mode
    /// scaling).
    With(Box<dyn FnMut(&str) -> Result<Config> + 'a>),
}

/// Typed builder for an experiment grid.  Compile it to a [`Session`]
/// with [`Experiment::build`] (or run directly via [`Experiment::run`]).
pub struct Experiment<'a> {
    spec: SweepSpec,
    base: Base<'a>,
    anchors: Anchors,
    out_dir: Option<PathBuf>,
    observers: Vec<Box<dyn Observer>>,
    trace: Option<TraceConfig>,
}

impl<'a> Experiment<'a> {
    /// An experiment over one explicit base config: every cell starts
    /// from `cfg` (the dataset axis defaults to `cfg.train.dataset`),
    /// with axis values and overrides applied on top.
    pub fn new(cfg: Config) -> Experiment<'a> {
        let spec = SweepSpec {
            datasets: vec![cfg.train.dataset.clone()],
            ..SweepSpec::default()
        };
        Experiment {
            spec,
            base: Base::Fixed(Box::new(cfg)),
            anchors: Anchors::None,
            out_dir: None,
            observers: Vec::new(),
            trace: None,
        }
    }

    /// An experiment from a declarative [`SweepSpec`] (the CLI path);
    /// cells expand against the paper-default per-dataset base configs.
    ///
    /// The spec is honored in full — including `spec.out_dir`, which
    /// seeds [`Experiment::out_dir`] so a `--resume` spec works without
    /// re-wiring the directory (attach file observers at the same path).
    /// The one exception is `spec.json`: what lands on stdout is the
    /// front-end's choice of observers, not the grid's.
    pub fn from_spec(spec: SweepSpec) -> Experiment<'a> {
        let out_dir = Some(PathBuf::from(&spec.out_dir));
        let trace = spec.trace_out.clone().map(TraceConfig::new);
        Experiment {
            spec,
            base: Base::Defaults,
            anchors: Anchors::None,
            out_dir,
            observers: Vec::new(),
            trace,
        }
    }

    /// Build each cell's base config with `base` (called once per cell
    /// with the dataset name) instead of the paper defaults.
    pub fn base_with<F>(mut self, base: F) -> Self
    where
        F: FnMut(&str) -> Result<Config> + 'a,
    {
        self.base = Base::With(Box::new(base));
        self
    }

    pub fn datasets(mut self, datasets: &[&str]) -> Self {
        self.spec.datasets = datasets.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn policies(mut self, policies: &[Policy]) -> Self {
        self.spec.policies = policies.to_vec();
        self
    }

    pub fn envs(mut self, envs: &[EnvSel]) -> Self {
        self.spec.envs = envs.to_vec();
        self
    }

    pub fn ks(mut self, ks: &[usize]) -> Self {
        self.spec.ks = ks.to_vec();
        self
    }

    pub fn mus(mut self, mus: &[f64]) -> Self {
        self.spec.mus = mus.to_vec();
        self
    }

    pub fn nus(mut self, nus: &[f64]) -> Self {
        self.spec.nus = nus.to_vec();
        self
    }

    pub fn seeds(mut self, seeds: &[u64]) -> Self {
        self.spec.seeds = seeds.to_vec();
        self
    }

    /// Horizon override applied to every cell.
    pub fn rounds(mut self, rounds: usize) -> Self {
        self.spec.rounds = Some(rounds);
        self
    }

    pub fn mode(mut self, mode: SimMode) -> Self {
        self.spec.mode = mode;
        self
    }

    /// Scenario-pool width (0 = one worker per core).
    pub fn threads(mut self, threads: usize) -> Self {
        self.spec.threads = threads;
        self
    }

    /// Per-cell wall-clock budget [s]; exceeding it fails the cell loudly.
    pub fn cell_timeout_s(mut self, timeout_s: f64) -> Self {
        self.spec.cell_timeout_s = Some(timeout_s);
        self
    }

    /// Add one `--section.key=value` override applied to every cell.
    pub fn override_arg(mut self, arg: impl Into<String>) -> Self {
        self.spec.overrides.push(arg.into());
        self
    }

    pub fn anchors(mut self, anchors: Anchors) -> Self {
        self.anchors = anchors;
        self
    }

    /// Output directory: enables the resume scan ([`Experiment::resume`])
    /// and is where the file-writing observers point.  The session itself
    /// writes nothing — attach [`crate::exp::CsvObserver`] /
    /// [`crate::exp::SummaryObserver`] / [`crate::exp::ManifestObserver`]
    /// for files.
    pub fn out_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.out_dir = Some(dir.into());
        self
    }

    /// Skip cells whose CSV (plus a matching `.hash` fingerprint
    /// sidecar) already exists under the out dir; skipped cells are
    /// re-read so the grid summary still aggregates the full grid.
    ///
    /// The scan reads the files a [`crate::exp::CsvObserver`] pointed at
    /// the *same* [`Experiment::out_dir`] writes — attach one, or resume
    /// will find nothing to skip.
    pub fn resume(mut self, resume: bool) -> Self {
        self.spec.resume = resume;
        self
    }

    /// Attach a streaming observer (events in attach order).
    pub fn observe(mut self, observer: impl Observer + 'static) -> Self {
        self.observers.push(Box::new(observer));
        self
    }

    /// Record a structured trace of the session (see [`crate::trace`]):
    /// hierarchical spans for every cell/round/phase, exported as Chrome
    /// trace-event JSON plus `trace_summary.json` under the trace dir,
    /// and a per-cell flight recorder on failure.  Tracing is
    /// determinism-neutral: every CSV/summary/manifest byte is identical
    /// with it on or off (pinned by `tests/trace_parity.rs`).
    pub fn trace(mut self, cfg: TraceConfig) -> Self {
        self.trace = Some(cfg);
        self
    }

    /// Expand, anchor, and validate the grid: the planned [`Session`].
    pub fn build(self) -> Result<Session> {
        let Experiment {
            spec,
            base,
            anchors,
            out_dir,
            mut observers,
            trace,
        } = self;
        anyhow::ensure!(
            !(anchors == Anchors::Both && spec.resume),
            "session: --resume is not supported on anchored grids (the regret \
             decomposition is computed across the whole grid in one invocation)"
        );
        anyhow::ensure!(
            !spec.resume || out_dir.is_some(),
            "session: resume needs an out_dir to scan for finished cells"
        );
        let mut base: Box<dyn FnMut(&str) -> Result<Config> + 'a> = match base {
            Base::Defaults => Box::new(Config::for_dataset),
            Base::Fixed(cfg) => Box::new(move |ds: &str| {
                let mut c = (*cfg).clone();
                c.train.dataset = ds.to_string();
                Ok(c)
            }),
            Base::With(f) => f,
        };
        let cells = match anchors {
            Anchors::None => spec.expand_with(&mut base)?,
            Anchors::Both => regret::plan_with(&spec, &mut base)?,
        };
        anyhow::ensure!(!cells.is_empty(), "session: the grid expanded to zero cells");
        // Streaming CSVs and resume both key on the cell label, so
        // duplicates would race on one file: reject them up front.
        {
            let mut seen = BTreeSet::new();
            for s in &cells {
                anyhow::ensure!(
                    seen.insert(s.label.as_str()),
                    "session: duplicate cell label {:?} (repeated axis value, or an \
                     override clobbering a swept axis?)",
                    s.label
                );
            }
        }
        // The trace hub is shared by every worker; its exporter runs as
        // the *last* observer so `trace.json` lands after the file sinks
        // attached before it (CSVs, summary) have flushed.
        let trace = trace.map(|cfg| Arc::new(TraceHub::new(cfg)));
        if let Some(hub) = &trace {
            observers.push(Box::new(TraceObserver::new(hub.clone())));
        }
        Ok(Session {
            cells,
            threads: spec.threads,
            regret: anchors == Anchors::Both,
            resume: spec.resume,
            out_dir,
            observers,
            trace,
        })
    }

    /// [`Experiment::build`] + [`Session::run`] in one call.
    pub fn run(self) -> Result<SessionReport> {
        self.build()?.run()
    }
}

/// What a completed session hands back: per-cell results in grid order
/// plus the seed-aggregated group rows.
pub struct SessionReport {
    pub results: Vec<ScenarioResult>,
    pub groups: Vec<GroupSummary>,
    /// Cells satisfied from existing CSVs by a resume run.
    pub resumed_cells: usize,
}

/// A planned, validated grid bound to its observers — ready to run.
pub struct Session {
    cells: Vec<Scenario>,
    threads: usize,
    regret: bool,
    resume: bool,
    out_dir: Option<PathBuf>,
    observers: Vec<Box<dyn Observer>>,
    trace: Option<Arc<TraceHub>>,
}

impl Session {
    /// A bare session over pre-expanded cells: no observers, no anchors,
    /// no resume.  This is the compat substrate of
    /// [`crate::exp::run_scenarios`]; prefer [`Experiment`].
    pub fn from_cells(cells: Vec<Scenario>, threads: usize) -> Session {
        Session {
            cells,
            threads,
            regret: false,
            resume: false,
            out_dir: None,
            observers: Vec::new(),
            trace: None,
        }
    }

    /// The planned grid, in execution order (anchors last on anchored
    /// sessions).
    pub fn cells(&self) -> &[Scenario] {
        &self.cells
    }

    /// The machine-readable grid manifest ([`manifest_json`]) for this
    /// session's cells.
    pub fn manifest(&self) -> Json {
        manifest_json(&self.cells)
    }

    /// Execute the grid: resume scan, parallel cell execution with
    /// streaming events, regret decomposition (anchored sessions), seed
    /// aggregation, and the grid-done event — in that order.
    pub fn run(self) -> Result<SessionReport> {
        let Session {
            cells,
            threads,
            regret,
            resume,
            out_dir,
            observers,
            trace,
        } = self;
        let hub = Hub::new(observers);
        hub.grid_start(&cells)?;
        let total = cells.len();

        // Resume scan: a cell is done only if its CSV exists AND its
        // `.hash` sidecar — written at cell *completion* — matches this
        // cell's fingerprint, so stale CSVs from an older config are
        // re-run, never silently kept.  Finished cells are re-read from
        // their CSVs (cheap: no simulation), so the summary always
        // aggregates the full grid.
        let mut resumed: Vec<(usize, ScenarioResult)> = Vec::new();
        let mut to_run: Vec<(usize, Scenario)> = Vec::new();
        if resume {
            let dir = out_dir.as_ref().expect("build() checked resume has an out_dir");
            for (idx, s) in cells.into_iter().enumerate() {
                let csv = dir.join(format!("{}.csv", s.label));
                let done = csv.exists()
                    && std::fs::read_to_string(dir.join(format!("{}.hash", s.label)))
                        .map(|h| h.trim() == s.fingerprint())
                        .unwrap_or(false);
                if done {
                    let mut recorder = Recorder::read_csv(&csv)?;
                    recorder.label = s.label.clone();
                    resumed.push((
                        idx,
                        ScenarioResult {
                            scenario: s,
                            recorder,
                            wall_s: 0.0,
                        },
                    ));
                } else {
                    to_run.push((idx, s));
                }
            }
            hub.resume_note(resumed.len(), to_run.len());
        } else {
            to_run = cells.into_iter().enumerate().collect();
        }
        let resumed_cells = resumed.len();

        // When the scenario pool itself is parallel, cells whose
        // `train.train_threads` is still auto (0) are pinned to
        // sequential local training — otherwise every Full-mode cell
        // would spawn its own per-core training pool on top of the
        // scenario pool.  Training results are bitwise-identical either
        // way (see [`par`]).
        let width = par::effective_threads(threads, to_run.len());
        if width > 1 {
            for (_, sc) in &mut to_run {
                if sc.cfg.train.train_threads == 0 {
                    sc.cfg.train.train_threads = 1;
                }
            }
        }
        // Each worker claims one Chrome `tid` up front, so its cells all
        // land on that worker's track in the exported trace.
        let fresh = par::fan_out(
            to_run,
            width,
            || trace.as_ref().map_or(0, |h| h.register_thread()),
            |tid, (idx, sc)| run_cell(idx, sc, total, &hub, trace.as_deref(), *tid).map(|r| (idx, r)),
        )?;

        // Stitch resumed + fresh results back into grid order.
        let mut combined = resumed;
        combined.extend(fresh);
        combined.sort_by_key(|(i, _)| *i);
        let mut results: Vec<ScenarioResult> = combined.into_iter().map(|(_, r)| r).collect();

        // Anchored grids: populate the regret decomposition columns
        // before aggregation, so group rows and the grid-done event see
        // the final recorders.
        if regret {
            regret::decompose(&mut results)?;
        }
        let groups = summarize_groups(&results);
        hub.grid_done(&GridSummary {
            results: &results,
            groups: &groups,
            resumed_cells,
        })?;
        Ok(SessionReport {
            results,
            groups,
            resumed_cells,
        })
    }
}

/// Execute one cell through the step-wise [`crate::fl::RoundDriver`],
/// streaming events to the hub.
///
/// With tracing on, the server records phase/round spans into a
/// [`crate::trace::CellTrace`] it owns exclusively, and the buffer is
/// submitted to the `trace` hub on success.  On a cell error (e.g. the
/// wall-clock timeout) or a panic inside the drive loop, the flight
/// recorder dumps the last rounds to `<label>.crash-trace.json` before
/// the error/panic propagates.
fn run_cell(
    index: usize,
    scenario: Scenario,
    total: usize,
    hub: &Hub,
    trace: Option<&TraceHub>,
    tid: u64,
) -> Result<ScenarioResult> {
    let t0 = Instant::now();
    hub.cell_start(&CellStart {
        cell: index,
        label: &scenario.label,
        group: &scenario.group,
        cells_total: total,
    });
    let mut server = Server::new(scenario.cfg.clone(), scenario.mode)?;
    if let Some(h) = trace {
        server.trace = Some(h.cell(index, &scenario.label, tid));
    }
    let drive = catch_unwind(AssertUnwindSafe(|| -> Result<()> {
        let mut driver = server.driver_with_timeout(scenario.timeout_s);
        loop {
            let report = driver
                .step()
                .map_err(|e| anyhow::anyhow!("cell {}: {e:#}", scenario.label))?;
            let Some(report) = report else { break };
            if hub.wants_rounds {
                let observe_t0 = trace.map(|_| Instant::now());
                hub.round(&RoundEvent {
                    cell: index,
                    label: &scenario.label,
                    round: report.round,
                    record: &report.record,
                });
                if let Some(from) = observe_t0 {
                    driver.note_observe(report.round, from);
                }
            }
        }
        Ok(())
    }));
    let mut cell_trace = server.trace.take();
    if let Some(ct) = cell_trace.as_mut() {
        ct.finish();
    }
    let flight_dump = |reason: &str| {
        if let (Some(h), Some(ct)) = (trace, cell_trace.as_ref()) {
            match h.crash_dump(ct, reason) {
                Ok(path) => eprintln!("[trace] flight recorder: {}", path.display()),
                Err(e) => eprintln!("[trace] flight-recorder dump failed: {e:#}"),
            }
        }
    };
    match drive {
        Err(payload) => {
            flight_dump("panic during round execution");
            resume_unwind(payload);
        }
        Ok(Err(e)) => {
            flight_dump(&format!("{e:#}"));
            return Err(e);
        }
        Ok(Ok(())) => {}
    }
    let mut recorder = std::mem::take(&mut server.recorder);
    recorder.label = scenario.label.clone();
    if let (Some(h), Some(mut ct)) = (trace, cell_trace) {
        // Attribute the cell's metric-CSV size whether or not a
        // CsvObserver is attached (same bytes either way — the CSV body
        // is a pure function of the recorder).
        ct.set_bytes_written(recorder.csv_string().len() as u64);
        h.submit(ct);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let result = ScenarioResult {
        scenario,
        recorder,
        wall_s,
    };
    hub.cell_done(&CellResult {
        cell: index,
        scenario: &result.scenario,
        recorder: &result.recorder,
        wall_s,
    })?;
    Ok(result)
}

/// The session's event fan-in, sharded **per observer**: each observer
/// sits behind its own mutex, so two workers emitting to *different*
/// observers never contend, and a slow sink (a CSV flush, a terminal
/// write) only stalls workers queued on that one observer — not the
/// whole hub.  Each observer still sees a serialized event stream
/// (its own lock), which is all the [`Observer`] contract promises;
/// there is deliberately no cross-observer ordering.
///
/// Per-round events fire only when some observer opts in
/// (`wants_rounds`, checked lock-free), and round events skip the
/// observers that didn't opt in without ever taking their locks.
struct Hub {
    shards: Vec<ObserverShard>,
    /// Any observer opted into per-round events (checked lock-free on
    /// the per-round fast path).
    wants_rounds: bool,
}

/// One observer and its private lock, plus its cached round opt-in so
/// the per-round path can skip it lock-free.
struct ObserverShard {
    observer: Mutex<Box<dyn Observer>>,
    wants_rounds: bool,
}

impl Hub {
    fn new(observers: Vec<Box<dyn Observer>>) -> Hub {
        let shards: Vec<ObserverShard> = observers
            .into_iter()
            .map(|o| ObserverShard {
                wants_rounds: o.wants_rounds(),
                observer: Mutex::new(o),
            })
            .collect();
        let wants_rounds = shards.iter().any(|s| s.wants_rounds);
        Hub {
            shards,
            wants_rounds,
        }
    }

    fn grid_start(&self, cells: &[Scenario]) -> Result<()> {
        for s in &self.shards {
            s.observer.lock().unwrap().on_grid_start(cells)?;
        }
        Ok(())
    }

    fn resume_note(&self, skipped: usize, to_run: usize) {
        for s in &self.shards {
            s.observer.lock().unwrap().on_resume(skipped, to_run);
        }
    }

    fn cell_start(&self, ev: &CellStart<'_>) {
        for s in &self.shards {
            s.observer.lock().unwrap().on_cell_start(ev);
        }
    }

    fn round(&self, ev: &RoundEvent<'_>) {
        for s in &self.shards {
            if s.wants_rounds {
                s.observer.lock().unwrap().on_round(ev);
            }
        }
    }

    fn cell_done(&self, ev: &CellResult<'_>) -> Result<()> {
        for s in &self.shards {
            s.observer.lock().unwrap().on_cell_done(ev)?;
        }
        Ok(())
    }

    fn grid_done(&self, summary: &GridSummary<'_>) -> Result<()> {
        for s in &self.shards {
            s.observer.lock().unwrap().on_grid_done(summary)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn experiment_over_a_fixed_config_runs_one_cell_per_axis_point() {
        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.system.num_devices = 12;
        cfg.train.rounds = 8;
        let report = Experiment::new(cfg)
            .policies(&[Policy::Lroa, Policy::UniformStatic])
            .seeds(&[1, 2])
            .run()
            .unwrap();
        assert_eq!(report.results.len(), 4);
        assert_eq!(report.groups.len(), 2);
        assert_eq!(report.resumed_cells, 0);
        for r in &report.results {
            assert_eq!(r.scenario.cfg.system.num_devices, 12, "base config kept");
            assert_eq!(r.recorder.rounds.len(), 8);
        }
        assert_eq!(report.results[0].scenario.label, "LROA-cifar-s1");
    }

    #[test]
    fn duplicate_labels_are_rejected_at_build_time() {
        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.train.rounds = 3;
        // A seed override clobbering the seed axis yields duplicate
        // labels; build() must refuse instead of racing two cells on one
        // CSV path.
        let err = Experiment::new(cfg)
            .seeds(&[1, 2])
            .override_arg("--train.seed=7")
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("duplicate cell label"), "{err}");
    }

    #[test]
    fn anchored_sessions_refuse_resume() {
        let cfg = Config::for_dataset("cifar").unwrap();
        let err = Experiment::new(cfg)
            .anchors(Anchors::Both)
            .out_dir(std::env::temp_dir())
            .resume(true)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("resume"), "{err}");
    }
}
