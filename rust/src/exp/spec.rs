//! Sweep specification, grid expansion, and the machine-readable
//! sweep manifest.

use crate::config::{Config, EnvKind, Policy};
use crate::fl::SimMode;
use crate::json::{obj, Json};
use crate::metrics::CSV_COLUMNS;
use crate::Result;

/// One environment-axis entry: a kind plus the per-entry data some kinds
/// carry (today: the trace log path, so `--envs=trace:campus.csv,adv`
/// can put two differently-sourced environments on one axis, and the
/// composite child spec, so `--envs=compose:diurnal,compose:outage` can
/// sweep scenarios).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EnvSel {
    pub kind: EnvKind,
    /// Trace log path; only meaningful for [`EnvKind::Trace`] (a bare
    /// `trace` entry relies on an `--env.trace_path=...` override).
    pub trace_path: Option<String>,
    /// Composite child spec or preset name (stored verbatim, presets
    /// unexpanded); only meaningful for [`EnvKind::Composite`] (a bare
    /// `compose` entry keeps the base config's `env.compose`).
    pub compose: Option<String>,
}

impl From<EnvKind> for EnvSel {
    fn from(kind: EnvKind) -> Self {
        Self {
            kind,
            trace_path: None,
            compose: None,
        }
    }
}

impl EnvSel {
    /// Parse one axis entry: an [`EnvKind`] name/alias, `trace:<path>`,
    /// or `compose:<a>+<b>+...` / `compose:<preset>`.
    pub fn parse(s: &str) -> Result<EnvSel> {
        if let Some(path) = s.strip_prefix("trace:") {
            anyhow::ensure!(!path.is_empty(), "empty path in {s:?}");
            return Ok(EnvSel {
                kind: EnvKind::Trace,
                trace_path: Some(path.to_string()),
                compose: None,
            });
        }
        if let Some(spec) = s.strip_prefix("compose:") {
            // Reject a bad child list at parse time, before a whole grid
            // expands around it; the entry stores the verbatim spec so
            // labels and hashes see exactly what the user typed.
            crate::config::parse_compose_spec(spec)?;
            return Ok(EnvSel {
                kind: EnvKind::Composite,
                trace_path: None,
                compose: Some(spec.to_string()),
            });
        }
        Ok(EnvKind::parse(s)?.into())
    }

    /// Parse a comma list; `all` expands to every synthetic environment
    /// ([`EnvKind::SYNTHETIC`] — trace needs a log, so it is never
    /// implied).
    pub fn parse_list(val: &str) -> Result<Vec<EnvSel>> {
        if val == "all" {
            return Ok(EnvKind::SYNTHETIC.iter().map(|&k| k.into()).collect());
        }
        val.split(',').map(EnvSel::parse).collect()
    }

    /// Pin this environment onto a cell config.
    pub fn apply(&self, cfg: &mut Config) {
        cfg.env.kind = self.kind;
        if let Some(p) = &self.trace_path {
            cfg.env.trace_path = p.clone();
        }
        if let Some(c) = &self.compose {
            cfg.env.compose = c.clone();
        }
    }
}

/// One fully-resolved experiment cell: a config plus naming metadata.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Unique run label (CSV file stem, recorder label).
    pub label: String,
    /// Seed-invariant grouping key: scenarios sharing a `group` are seed
    /// repeats of the same cell and aggregate to one mean±std row.
    pub group: String,
    /// The complete experiment configuration.
    pub cfg: Config,
    /// Full training or control-plane-only.
    pub mode: SimMode,
    /// Per-cell wall-clock budget [s] (`--cell_timeout_s`); exceeding it
    /// fails the cell loudly instead of truncating its series.
    pub timeout_s: Option<f64>,
    /// Label of the oracle cell this cell's `regret` column is measured
    /// against (populated by the `lroa regret` planner; appears in the
    /// manifest so figure scripts can join the pair).
    pub regret_vs: Option<String>,
    /// Label of the *budget-feasible* `oracle-e` cell on the same stream
    /// — the second anchor of the regret decomposition
    /// (`regret_online`/`regret_budget`).  Populated by the `lroa
    /// regret` planner for online cells; anchors themselves carry none.
    pub regret_vs_e: Option<String>,
}

impl Scenario {
    /// Everything that determines this cell's CSV, in one comparable
    /// string: sim mode + the full-precision config hash — plus the
    /// artifacts path for Full mode, where the loaded artifacts shape
    /// the results (a sim-mode resume survives a pure path change).
    /// The runner records it in the `.hash` sidecar at cell completion;
    /// `--resume` re-runs any cell whose recorded fingerprint no longer
    /// matches.
    pub fn fingerprint(&self) -> String {
        match self.mode {
            SimMode::Full => format!("train:{}:{}", self.cfg.artifacts_dir, self.cfg.hash_hex()),
            SimMode::ControlPlaneOnly => format!("sim:{}", self.cfg.hash_hex()),
        }
    }
}

/// A declarative sweep: the cartesian product of every non-empty axis.
///
/// An empty axis means "keep the base config's value" (one grid point,
/// no label segment); an axis with a single entry pins that value without
/// adding a label segment either, so labels only carry the dimensions
/// that actually vary — plus policy and dataset, which always do.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub datasets: Vec<String>,
    pub policies: Vec<Policy>,
    /// Dynamic environments ([`crate::env`]); entries may carry a trace
    /// path (`trace:<file>`).
    pub envs: Vec<EnvSel>,
    /// Sampling frequency `K` values.
    pub ks: Vec<usize>,
    /// λ scale factors µ.
    pub mus: Vec<f64>,
    /// V scale factors ν.
    pub nus: Vec<f64>,
    /// Per-device energy-budget heterogeneity (`system.budget_spread`)
    /// values — first-class axis so budget-heterogeneous fleets can be
    /// swept against the homogeneous paper default in one grid.
    pub budget_spreads: Vec<f64>,
    /// Seed repeats (the paper averages 30).
    pub seeds: Vec<u64>,
    /// Horizon override applied to every cell.
    pub rounds: Option<usize>,
    pub mode: SimMode,
    /// Runner pool width (0 = one per core).
    pub threads: usize,
    /// Output directory for CSV/JSON emission.
    pub out_dir: String,
    /// Skip cells whose CSV already exists under `out_dir`.  Consumed by
    /// the session engine ([`crate::exp::Experiment`] owns the skip
    /// partition and the duplicate-label guard);
    /// `expand()`/`run_scenarios` do not act on it themselves.
    pub resume: bool,
    /// Print the seed-aggregated grid summary as JSON on stdout instead
    /// of the human table (`--json`, via
    /// [`crate::exp::JsonObserver`]).  Consumed by the CLI front-ends.
    pub json: bool,
    /// Per-cell wall-clock timeout [s] (`--cell_timeout_s`); None = no
    /// budget.
    pub cell_timeout_s: Option<f64>,
    /// Structured-trace output directory (`--trace-out`); None = tracing
    /// off.  Deliberately **not** part of any cell's [`Config`] (and so
    /// never hashed into resume fingerprints): tracing is determinism-
    /// neutral observability, and toggling it must not invalidate or
    /// alter a single result byte.
    pub trace_out: Option<String>,
    /// Extra `--section.key=value` overrides applied to every cell.
    pub overrides: Vec<String>,
}

impl Default for SweepSpec {
    fn default() -> Self {
        Self {
            datasets: vec!["cifar".into()],
            policies: Vec::new(),
            envs: Vec::new(),
            ks: Vec::new(),
            mus: Vec::new(),
            nus: Vec::new(),
            budget_spreads: Vec::new(),
            seeds: Vec::new(),
            rounds: None,
            mode: SimMode::ControlPlaneOnly,
            threads: 0,
            out_dir: "runs/sweep".into(),
            resume: false,
            json: false,
            cell_timeout_s: None,
            trace_out: None,
            overrides: Vec::new(),
        }
    }
}

/// An axis iterates its values, or `None` once when empty (= keep base).
fn axis<T: Clone>(values: &[T]) -> Vec<Option<T>> {
    if values.is_empty() {
        vec![None]
    } else {
        values.iter().cloned().map(Some).collect()
    }
}

impl SweepSpec {
    /// Expand against the paper-default base configs
    /// ([`Config::for_dataset`]) plus this spec's overrides.
    pub fn expand(&self) -> Result<Vec<Scenario>> {
        self.expand_with(Config::for_dataset)
    }

    /// Expand the grid, building each cell's base config with `base`
    /// (called once per cell with the dataset name).  Axis values, the
    /// rounds override, and `self.overrides` are applied on top, and the
    /// result is validated.
    pub fn expand_with<F>(&self, mut base: F) -> Result<Vec<Scenario>>
    where
        F: FnMut(&str) -> Result<Config>,
    {
        let mut out = Vec::new();
        let envs = axis(&self.envs);
        for dataset in &self.datasets {
            for &p in &axis(&self.policies) {
                for e in &envs {
                    for &k in &axis(&self.ks) {
                        for &mu in &axis(&self.mus) {
                            for &nu in &axis(&self.nus) {
                                for &bs in &axis(&self.budget_spreads) {
                                for &seed in &axis(&self.seeds) {
                                    let mut cfg = base(dataset)?;
                                    if let Some(p) = p {
                                        cfg.train.policy = p;
                                    }
                                    if let Some(e) = e {
                                        e.apply(&mut cfg);
                                    }
                                    if let Some(k) = k {
                                        cfg.system.k = k;
                                    }
                                    if let Some(mu) = mu {
                                        cfg.control.mu = mu;
                                    }
                                    if let Some(nu) = nu {
                                        cfg.control.nu = nu;
                                    }
                                    if let Some(bs) = bs {
                                        cfg.system.budget_spread = bs;
                                    }
                                    if let Some(seed) = seed {
                                        cfg.train.seed = seed;
                                    }
                                    if let Some(rounds) = self.rounds {
                                        cfg.train.rounds = rounds;
                                    }
                                    cfg.apply_cli(&self.overrides)?;
                                    cfg.validate()?;
                                    let group = self.group_label(&cfg, dataset);
                                    // Label with the *effective* seed (post-
                                    // override): a --train.seed override that
                                    // clobbers the seed axis then yields
                                    // duplicate labels, which the sweep's
                                    // duplicate-label guard rejects instead
                                    // of silently running N identical cells.
                                    let label = match seed {
                                        Some(_) if self.seeds.len() > 1 => {
                                            format!("{group}-s{}", cfg.train.seed)
                                        }
                                        _ => group.clone(),
                                    };
                                    out.push(Scenario {
                                        label,
                                        group,
                                        cfg,
                                        mode: self.mode,
                                        timeout_s: self.cell_timeout_s,
                                        regret_vs: None,
                                        regret_vs_e: None,
                                    });
                                }
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    /// Seed-invariant cell name: policy and dataset always, varying axes
    /// only when they actually vary.
    fn group_label(&self, cfg: &Config, dataset: &str) -> String {
        let mut s = format!("{}-{}", cfg.train.policy.name(), dataset);
        if self.envs.len() > 1 {
            s.push_str(&format!("-{}", cfg.env.kind));
            // Two trace entries with different logs are different
            // environments: disambiguate by the log's file stem so their
            // labels (and CSVs) can never collide or merge as seed
            // repeats of one group.
            if cfg.env.kind == EnvKind::Trace
                && self.envs.iter().filter(|e| e.kind == EnvKind::Trace).count() > 1
            {
                let stem = std::path::Path::new(&cfg.env.trace_path)
                    .file_stem()
                    .map(|t| t.to_string_lossy().into_owned())
                    .unwrap_or_default();
                s.push_str(&format!("-{stem}"));
            }
            // Likewise two composite entries with different child specs:
            // disambiguate by the (verbatim) spec so
            // `compose:diurnal,compose:outage` yields two groups.
            if cfg.env.kind == EnvKind::Composite
                && self
                    .envs
                    .iter()
                    .filter(|e| e.kind == EnvKind::Composite)
                    .count()
                    > 1
            {
                s.push_str(&format!("-{}", cfg.env.compose));
            }
        }
        if self.ks.len() > 1 {
            s.push_str(&format!("-K{}", cfg.system.k));
        }
        if self.mus.len() > 1 {
            s.push_str(&format!("-mu{}", cfg.control.mu));
        }
        if self.nus.len() > 1 {
            s.push_str(&format!("-nu{:e}", cfg.control.nu));
        }
        if self.budget_spreads.len() > 1 {
            s.push_str(&format!("-bs{}", cfg.system.budget_spread));
        }
        s
    }

    /// Parse the `lroa sweep` / `lroa regret` command line.
    ///
    /// Recognized (all `--key=value`): `--datasets`, `--policies`,
    /// `--envs` (comma list of environment names, `trace:<path>` /
    /// `compose:<a>+<b>` / `compose:<preset>` entries, or `all`),
    /// `--ks`, `--mus`, `--nus`, `--budget_spreads`
    /// (energy-budget heterogeneity values), `--seeds` (comma
    /// list or `a..b` inclusive), `--rounds`, `--threads`,
    /// `--cell_timeout_s` (per-cell wall-clock budget),
    /// `--mode=sim|train`, `--out`, `--trace-out` (structured-trace
    /// directory; see [`crate::trace`]), plus the bare flags `--resume` (skip
    /// cells whose CSV already exists) and `--json` (grid summary as
    /// JSON on stdout instead of the table).  Dotted
    /// `--section.key=value` config overrides pass through to every
    /// cell; anything else is an error.
    pub fn from_cli(args: &[String]) -> Result<SweepSpec> {
        let mut spec = SweepSpec::default();
        let mut seen = std::collections::BTreeSet::new();
        for arg in args {
            let Some(rest) = arg.strip_prefix("--") else {
                return Err(crate::usage_error(format!(
                    "sweep: unexpected argument {arg:?}"
                )));
            };
            if rest == "resume" {
                spec.resume = true;
                continue;
            }
            if rest == "json" {
                spec.json = true;
                continue;
            }
            let Some((key, val)) = rest.split_once('=') else {
                return Err(crate::usage_error(format!(
                    "sweep: expected --key=value, got {arg:?}"
                )));
            };
            // A repeated axis flag must error loudly, never last-one-wins:
            // a second --envs (or --seeds, ...) silently replacing the
            // first would hand the figure pipeline a half-grid it cannot
            // detect.  Dotted config overrides are exempt (each names its
            // own key; Config::set already owns that semantics).
            if !(key.contains('.') || seen.insert(key.to_string())) {
                return Err(crate::usage_error(format!(
                    "sweep: --{key} given more than once; pass one combined value list"
                )));
            }
            match key {
                "datasets" => spec.datasets = val.split(',').map(str::to_string).collect(),
                "policies" => {
                    spec.policies = if val == "all" {
                        Policy::ALL.to_vec()
                    } else {
                        val.split(',')
                            .map(Policy::parse)
                            .collect::<Result<Vec<_>>>()?
                    }
                }
                "envs" => spec.envs = EnvSel::parse_list(val)?,
                "ks" => spec.ks = parse_list(val, "ks")?,
                "mus" => spec.mus = parse_list(val, "mus")?,
                "nus" => spec.nus = parse_list(val, "nus")?,
                "budget_spreads" => {
                    spec.budget_spreads = parse_list(val, "budget_spreads")?
                }
                "seeds" => spec.seeds = parse_seeds(val)?,
                "rounds" => spec.rounds = Some(parse_one(val, "rounds")?),
                "threads" => spec.threads = parse_one(val, "threads")?,
                "cell_timeout_s" => {
                    let t: f64 = parse_one(val, "cell_timeout_s")?;
                    anyhow::ensure!(t > 0.0, "sweep: --cell_timeout_s must be > 0");
                    spec.cell_timeout_s = Some(t);
                }
                "out" => spec.out_dir = val.to_string(),
                "trace-out" => spec.trace_out = Some(val.to_string()),
                "mode" => {
                    spec.mode = match val {
                        "sim" => SimMode::ControlPlaneOnly,
                        "train" => SimMode::Full,
                        other => {
                            return Err(crate::usage_error(format!(
                                "sweep: --mode must be sim|train, got {other:?}"
                            )))
                        }
                    }
                }
                _ if key.contains('.') => spec.overrides.push(arg.clone()),
                other => {
                    return Err(crate::usage_error(format!("sweep: unknown flag --{other}")))
                }
            }
        }
        Ok(spec)
    }
}

/// Machine-readable description of every cell in an expanded grid — the
/// contract between `lroa sweep`/`lroa regret` and the figure pipeline.
/// Written to `<out>/manifest.json` right after expansion (before any
/// cell runs), so a crashed or `--resume`d sweep still documents its
/// full grid.  `columns` is the cell-CSV schema
/// ([`crate::metrics::CSV_COLUMNS`], including `regret` and its
/// decomposition `regret_online`/`regret_budget`); regret cells
/// additionally name their clairvoyant anchor under `regret_vs` and
/// their budget-feasible `oracle-e` anchor under `regret_vs_e`.
pub fn manifest_json(scenarios: &[Scenario]) -> Json {
    let cells: Vec<Json> = scenarios
        .iter()
        .map(|s| {
            let mut fields = vec![
                ("group", Json::Str(s.group.clone())),
                ("label", Json::Str(s.label.clone())),
                ("seed", Json::Num(s.cfg.train.seed as f64)),
                ("policy", Json::Str(s.cfg.train.policy.name().to_string())),
                ("env", Json::Str(s.cfg.env.kind.name().to_string())),
                ("dataset", Json::Str(s.cfg.train.dataset.clone())),
                (
                    "mode",
                    Json::Str(
                        match s.mode {
                            SimMode::Full => "train",
                            SimMode::ControlPlaneOnly => "sim",
                        }
                        .to_string(),
                    ),
                ),
                ("rounds", Json::Num(s.cfg.train.rounds as f64)),
                ("config_hash", Json::Str(s.cfg.hash_hex())),
                ("csv", Json::Str(format!("{}.csv", s.label))),
            ];
            if s.cfg.env.kind == EnvKind::Trace {
                fields.push(("env_trace", Json::Str(s.cfg.env.trace_path.clone())));
            }
            if s.cfg.env.kind == EnvKind::Composite {
                fields.push(("env_compose", Json::Str(s.cfg.env.compose.clone())));
            }
            if let Some(anchor) = &s.regret_vs {
                fields.push(("regret_vs", Json::Str(anchor.clone())));
            }
            if let Some(anchor) = &s.regret_vs_e {
                fields.push(("regret_vs_e", Json::Str(anchor.clone())));
            }
            obj(fields)
        })
        .collect();
    obj(vec![
        (
            "columns",
            Json::Arr(
                CSV_COLUMNS
                    .iter()
                    .map(|c| Json::Str(c.to_string()))
                    .collect(),
            ),
        ),
        ("cells", Json::Arr(cells)),
    ])
}

fn parse_one<T: std::str::FromStr>(val: &str, what: &str) -> Result<T> {
    val.parse::<T>()
        .map_err(|_| crate::usage_error(format!("sweep: bad {what} value {val:?}")))
}

fn parse_list<T: std::str::FromStr>(val: &str, what: &str) -> Result<Vec<T>> {
    val.split(',').map(|v| parse_one(v.trim(), what)).collect()
}

/// `"1,2,5"` or `"1..30"` (inclusive).
fn parse_seeds(val: &str) -> Result<Vec<u64>> {
    if let Some((lo, hi)) = val.split_once("..") {
        let lo: u64 = parse_one(lo, "seed range start")?;
        let hi: u64 = parse_one(hi, "seed range end")?;
        anyhow::ensure!(lo <= hi, "sweep: empty seed range {val:?}");
        return Ok((lo..=hi).collect());
    }
    parse_list(val, "seeds")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_axes_expand_to_one_cell_per_dataset() {
        let spec = SweepSpec {
            datasets: vec!["cifar".into(), "femnist".into()],
            ..SweepSpec::default()
        };
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 2);
        // Base config values survive untouched.
        assert_eq!(cells[0].cfg.system.k, 2);
        assert_eq!(cells[0].label, "LROA-cifar");
        assert_eq!(cells[1].label, "LROA-femnist");
    }

    #[test]
    fn grid_is_the_full_cartesian_product() {
        let spec = SweepSpec {
            datasets: vec!["cifar".into()],
            policies: vec![Policy::Lroa, Policy::UniformDynamic],
            ks: vec![2, 4, 6],
            mus: vec![0.1, 1.0],
            seeds: vec![1, 2, 3],
            rounds: Some(10),
            ..SweepSpec::default()
        };
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 2 * 3 * 2 * 3);
        assert!(cells.iter().all(|c| c.cfg.train.rounds == 10));
        // Seed repeats share a group but not a label.
        let first_group = &cells[0].group;
        let repeats: Vec<_> = cells.iter().filter(|c| &c.group == first_group).collect();
        assert_eq!(repeats.len(), 3);
        assert_eq!(repeats[0].label, format!("{first_group}-s1"));
        assert_ne!(repeats[0].label, repeats[1].label);
    }

    #[test]
    fn labels_carry_only_varying_axes() {
        let spec = SweepSpec {
            datasets: vec!["femnist".into()],
            policies: vec![Policy::Lroa],
            nus: vec![1e3, 1e5],
            mus: vec![1.0], // pinned, single value: no label segment
            ..SweepSpec::default()
        };
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].label, "LROA-femnist-nu1e3");
        assert_eq!(cells[1].label, "LROA-femnist-nu1e5");
        assert!(cells.iter().all(|c| c.cfg.control.mu == 1.0));
    }

    #[test]
    fn overrides_apply_to_every_cell() {
        let spec = SweepSpec {
            datasets: vec!["cifar".into()],
            seeds: vec![1, 2],
            overrides: vec!["--system.num_devices=24".into()],
            ..SweepSpec::default()
        };
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 2);
        assert!(cells.iter().all(|c| c.cfg.system.num_devices == 24));
    }

    #[test]
    fn cli_round_trip() {
        let args: Vec<String> = [
            "--policies=lroa,uni-s",
            "--envs=static,ge",
            "--ks=2,4",
            "--nus=1e4,1e5",
            "--seeds=1..3",
            "--rounds=50",
            "--threads=4",
            "--cell_timeout_s=30",
            "--datasets=femnist",
            "--mode=sim",
            "--out=runs/mysweep",
            "--trace-out=runs/mysweep/trace",
            "--resume",
            "--json",
            "--system.num_devices=32",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let spec = SweepSpec::from_cli(&args).unwrap();
        assert_eq!(spec.policies, vec![Policy::Lroa, Policy::UniformStatic]);
        assert_eq!(
            spec.envs,
            vec![EnvSel::from(EnvKind::Static), EnvSel::from(EnvKind::GilbertElliott)]
        );
        assert_eq!(spec.ks, vec![2, 4]);
        assert_eq!(spec.nus, vec![1e4, 1e5]);
        assert_eq!(spec.seeds, vec![1, 2, 3]);
        assert_eq!(spec.rounds, Some(50));
        assert_eq!(spec.threads, 4);
        assert_eq!(spec.cell_timeout_s, Some(30.0));
        assert_eq!(spec.out_dir, "runs/mysweep");
        assert_eq!(spec.trace_out.as_deref(), Some("runs/mysweep/trace"));
        assert!(spec.resume);
        assert!(spec.json);
        assert_eq!(spec.overrides, vec!["--system.num_devices=32".to_string()]);
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 2 * 2 * 2 * 2 * 3);
        assert!(cells.iter().all(|c| c.cfg.system.num_devices == 32));
        assert!(cells.iter().all(|c| c.timeout_s == Some(30.0)));
    }

    #[test]
    fn env_sel_parses_trace_entries_and_pins_the_path() {
        assert_eq!(
            EnvSel::parse("ge").unwrap(),
            EnvSel::from(EnvKind::GilbertElliott)
        );
        let sel = EnvSel::parse("trace:logs/campus.csv").unwrap();
        assert_eq!(sel.kind, EnvKind::Trace);
        assert_eq!(sel.trace_path.as_deref(), Some("logs/campus.csv"));
        assert!(EnvSel::parse("trace:").is_err());
        assert!(EnvSel::parse("nope").is_err());
        // `all` never implies trace.
        let all = EnvSel::parse_list("all").unwrap();
        assert!(all.iter().all(|s| s.kind != EnvKind::Trace));
        assert_eq!(all.len(), EnvKind::SYNTHETIC.len());

        // Expansion pins both the kind and the path into the config.
        let spec = SweepSpec {
            datasets: vec!["cifar".into()],
            envs: vec![
                EnvSel::parse("trace:logs/campus.csv").unwrap(),
                EnvSel::from(EnvKind::Adversarial),
            ],
            rounds: Some(5),
            ..SweepSpec::default()
        };
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].cfg.env.kind, EnvKind::Trace);
        assert_eq!(cells[0].cfg.env.trace_path, "logs/campus.csv");
        assert_eq!(cells[0].label, "LROA-cifar-trace");
        assert_eq!(cells[1].cfg.env.kind, EnvKind::Adversarial);
        assert_eq!(cells[1].label, "LROA-cifar-adv");

        // A bare trace entry without a path (and no override) fails
        // validation at expansion, not inside the round loop.
        let bare = SweepSpec {
            datasets: vec!["cifar".into()],
            envs: vec![EnvSel::from(EnvKind::Trace)],
            ..SweepSpec::default()
        };
        assert!(bare.expand().is_err());
    }

    #[test]
    fn two_traces_on_one_axis_get_distinct_labels() {
        let spec = SweepSpec {
            datasets: vec!["cifar".into()],
            envs: vec![
                EnvSel::parse("trace:logs/campus.csv").unwrap(),
                EnvSel::parse("trace:logs/downtown.csv").unwrap(),
            ],
            rounds: Some(5),
            ..SweepSpec::default()
        };
        let cells = spec.expand().unwrap();
        assert_eq!(cells[0].label, "LROA-cifar-trace-campus");
        assert_eq!(cells[1].label, "LROA-cifar-trace-downtown");
        assert_ne!(cells[0].group, cells[1].group);
        // A single trace entry keeps the plain kind segment.
        let single = SweepSpec {
            datasets: vec!["cifar".into()],
            envs: vec![
                EnvSel::parse("trace:logs/campus.csv").unwrap(),
                EnvSel::from(EnvKind::Adversarial),
            ],
            rounds: Some(5),
            ..SweepSpec::default()
        };
        let cells = single.expand().unwrap();
        assert_eq!(cells[0].label, "LROA-cifar-trace");
    }

    #[test]
    fn cli_rejects_unknown_flags_and_bad_values() {
        let bad = |s: &str| SweepSpec::from_cli(&[s.to_string()]);
        assert!(bad("--bogus=1").is_err());
        assert!(bad("positional").is_err());
        assert!(bad("--ks=two").is_err());
        assert!(bad("--mode=nope").is_err());
        assert!(bad("--policies=nope").is_err());
        assert!(bad("--envs=nope").is_err());
        assert!(bad("--seeds=9..3").is_err());
    }

    #[test]
    fn cli_rejects_repeated_axis_flags_instead_of_last_one_wins() {
        let parse = |args: &[&str]| {
            let args: Vec<String> = args.iter().map(|s| s.to_string()).collect();
            SweepSpec::from_cli(&args)
        };
        let err = parse(&["--envs=static,ge", "--envs=adv"]).unwrap_err();
        assert!(err.to_string().contains("more than once"), "{err}");
        assert!(parse(&["--ks=2", "--ks=4"]).is_err());
        assert!(parse(&["--seeds=1..3", "--seeds=9"]).is_err());
        // Dotted overrides keep Config::set semantics (own keys, may
        // legitimately appear with different keys), and one combined
        // list stays fine.
        let spec = parse(&["--envs=static,ge", "--system.k=4", "--train.seed=2"]).unwrap();
        assert_eq!(spec.envs.len(), 2);
        assert_eq!(spec.overrides.len(), 2);
    }

    #[test]
    fn policies_all_shorthand() {
        let spec = SweepSpec::from_cli(&["--policies=all".to_string()]).unwrap();
        assert_eq!(spec.policies, Policy::ALL.to_vec());
        let spec = SweepSpec::from_cli(&["--envs=all".to_string()]).unwrap();
        let want: Vec<EnvSel> = EnvKind::SYNTHETIC.iter().map(|&k| k.into()).collect();
        assert_eq!(spec.envs, want);
    }

    #[test]
    fn env_axis_expands_and_labels() {
        let spec = SweepSpec {
            datasets: vec!["cifar".into()],
            policies: vec![Policy::Lroa, Policy::UniformStatic],
            envs: EnvKind::SYNTHETIC.iter().map(|&k| k.into()).collect(),
            seeds: vec![1],
            rounds: Some(5),
            ..SweepSpec::default()
        };
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 2 * 5);
        assert_eq!(cells[0].label, "LROA-cifar-static");
        assert_eq!(cells[1].label, "LROA-cifar-ge");
        assert_eq!(cells[2].label, "LROA-cifar-avail");
        assert_eq!(cells[3].label, "LROA-cifar-drift");
        assert_eq!(cells[4].label, "LROA-cifar-adv");
        assert_eq!(cells[3].cfg.env.kind, EnvKind::Drift);
        assert_eq!(cells[4].cfg.env.kind, EnvKind::Adversarial);
        // A single pinned env adds no label segment.
        let pinned = SweepSpec {
            datasets: vec!["cifar".into()],
            envs: vec![EnvKind::GilbertElliott.into()],
            ..SweepSpec::default()
        };
        let cells = pinned.expand().unwrap();
        assert_eq!(cells[0].label, "LROA-cifar");
        assert_eq!(cells[0].cfg.env.kind, EnvKind::GilbertElliott);
    }

    #[test]
    fn manifest_covers_every_cell() {
        let spec = SweepSpec {
            datasets: vec!["cifar".into()],
            policies: vec![Policy::Lroa, Policy::UniformStatic],
            envs: vec![EnvKind::Static.into(), EnvKind::Availability.into()],
            seeds: vec![1, 2],
            rounds: Some(7),
            ..SweepSpec::default()
        };
        let cells = spec.expand().unwrap();
        let manifest = manifest_json(&cells);
        // The CSV schema is published, regret column included.
        let columns: Vec<&str> = manifest
            .get("columns")
            .and_then(|c| c.as_arr())
            .unwrap()
            .iter()
            .filter_map(|c| c.as_str())
            .collect();
        assert_eq!(columns, crate::metrics::CSV_COLUMNS);
        assert!(columns.contains(&"regret"));
        let arr = manifest.get("cells").and_then(|c| c.as_arr()).unwrap();
        assert_eq!(arr.len(), cells.len());
        for (cell, sc) in arr.iter().zip(&cells) {
            assert_eq!(cell.get("label").unwrap().as_str().unwrap(), sc.label);
            assert_eq!(cell.get("group").unwrap().as_str().unwrap(), sc.group);
            assert_eq!(
                cell.get("env").unwrap().as_str().unwrap(),
                sc.cfg.env.kind.name()
            );
            assert_eq!(
                cell.get("policy").unwrap().as_str().unwrap(),
                sc.cfg.train.policy.name()
            );
            assert_eq!(cell.get("mode").unwrap().as_str().unwrap(), "sim");
            assert_eq!(cell.get("rounds").unwrap().as_usize().unwrap(), 7);
            assert_eq!(
                cell.get("csv").unwrap().as_str().unwrap(),
                format!("{}.csv", sc.label)
            );
            assert_eq!(
                cell.get("config_hash").unwrap().as_str().unwrap().len(),
                16
            );
        }
        // The manifest round-trips through the in-tree JSON parser.
        let parsed = crate::json::Json::parse(&manifest.to_string()).unwrap();
        assert_eq!(
            parsed.get("cells").and_then(|c| c.as_arr()).unwrap().len(),
            cells.len()
        );
    }

    #[test]
    fn budget_spread_is_a_sweep_axis_with_resume_safe_fingerprints() {
        let args: Vec<String> = ["--datasets=cifar", "--budget_spreads=0,0.5"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let spec = SweepSpec::from_cli(&args).unwrap();
        assert_eq!(spec.budget_spreads, vec![0.0, 0.5]);
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 2);
        // The axis value lands in the config and the label carries it.
        assert_eq!(cells[0].cfg.system.budget_spread, 0.0);
        assert_eq!(cells[1].cfg.system.budget_spread, 0.5);
        assert_eq!(cells[0].label, "LROA-cifar-bs0");
        assert_eq!(cells[1].label, "LROA-cifar-bs0.5");
        assert_ne!(cells[0].group, cells[1].group);
        // budget_spread is config-hashed, so the two cells have distinct
        // fingerprints: a --resume after editing the axis re-runs the
        // changed cell instead of trusting a stale CSV.
        assert_ne!(cells[0].fingerprint(), cells[1].fingerprint());
        // The manifest documents each heterogeneity cell separately.
        let manifest = manifest_json(&cells);
        let arr = manifest.get("cells").and_then(|c| c.as_arr()).unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(
            arr[0].get("label").unwrap().as_str().unwrap(),
            "LROA-cifar-bs0"
        );
        assert_eq!(
            arr[1].get("label").unwrap().as_str().unwrap(),
            "LROA-cifar-bs0.5"
        );
        assert_ne!(
            arr[0].get("config_hash").unwrap().as_str().unwrap(),
            arr[1].get("config_hash").unwrap().as_str().unwrap()
        );

        // A single-entry axis pins the value without a label segment.
        let pinned = SweepSpec {
            datasets: vec!["cifar".into()],
            budget_spreads: vec![0.25],
            ..SweepSpec::default()
        };
        let cells = pinned.expand().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].cfg.system.budget_spread, 0.25);
        assert_eq!(cells[0].label, "LROA-cifar");
    }

    #[test]
    fn compose_axis_entries_parse_pin_label_and_fingerprint() {
        // Explicit child lists and preset names both parse; a bad child
        // list fails at parse time, before the grid expands.
        let sel = EnvSel::parse("compose:avail+ge+drift").unwrap();
        assert_eq!(sel.kind, EnvKind::Composite);
        assert_eq!(sel.compose.as_deref(), Some("avail+ge+drift"));
        let preset = EnvSel::parse("compose:diurnal").unwrap();
        assert_eq!(preset.compose.as_deref(), Some("diurnal"));
        assert!(EnvSel::parse("compose:").is_err());
        assert!(EnvSel::parse("compose:ge+nope").is_err());
        assert!(EnvSel::parse("compose:ge+ge").is_err());
        // `all` never implies a composite (it needs a child spec).
        assert!(EnvSel::parse_list("all")
            .unwrap()
            .iter()
            .all(|s| s.kind != EnvKind::Composite));

        // Expansion pins kind + spec; two composite entries with
        // different specs get distinct labels, groups, and fingerprints
        // (the spec is config-hashed, so --resume re-runs edits).
        let spec = SweepSpec {
            datasets: vec!["cifar".into()],
            envs: vec![
                EnvSel::parse("compose:diurnal").unwrap(),
                EnvSel::parse("compose:outage").unwrap(),
            ],
            rounds: Some(5),
            ..SweepSpec::default()
        };
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].cfg.env.kind, EnvKind::Composite);
        assert_eq!(cells[0].cfg.env.compose, "diurnal");
        assert_eq!(cells[1].cfg.env.compose, "outage");
        assert_eq!(cells[0].label, "LROA-cifar-compose-diurnal");
        assert_eq!(cells[1].label, "LROA-cifar-compose-outage");
        assert_ne!(cells[0].group, cells[1].group);
        assert_ne!(cells[0].fingerprint(), cells[1].fingerprint());
        // The manifest documents the child spec per composite cell.
        let manifest = manifest_json(&cells);
        let arr = manifest.get("cells").and_then(|c| c.as_arr()).unwrap();
        assert_eq!(
            arr[0].get("env_compose").unwrap().as_str().unwrap(),
            "diurnal"
        );
        assert_eq!(
            arr[1].get("env_compose").unwrap().as_str().unwrap(),
            "outage"
        );

        // A single composite entry alongside another env keeps the plain
        // kind segment, like a single trace entry.
        let mixed = SweepSpec {
            datasets: vec!["cifar".into()],
            envs: vec![
                EnvSel::parse("compose:flashcrowd").unwrap(),
                EnvSel::from(EnvKind::Static),
            ],
            rounds: Some(5),
            ..SweepSpec::default()
        };
        let cells = mixed.expand().unwrap();
        assert_eq!(cells[0].label, "LROA-cifar-compose");
        assert_eq!(cells[1].label, "LROA-cifar-static");
    }
}
