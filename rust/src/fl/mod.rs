//! The federated-learning loop: local training, evaluation, and the
//! server round orchestration of Algorithm 1.
//!
//! * [`trainer`] — per-client local updates (E epochs of minibatch
//!   momentum-SGD through the PJRT `train_step` artifact) and the global
//!   test-set evaluator;
//! * [`server`] — the synchronous FL server: channel observation, control
//!   solve, K-with-replacement sampling, parallel local updates, eq. (4)
//!   aggregation, virtual-queue advance, metric recording.

mod server;
mod trainer;

pub use server::{Server, SimMode};
pub use trainer::{Evaluator, LocalTrainer, LocalUpdate};
