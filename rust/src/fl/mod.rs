//! The federated-learning loop: local training, evaluation, and the
//! server round orchestration of Algorithm 1.
//!
//! * [`trainer`] — per-client local updates (E epochs of minibatch
//!   momentum-SGD through the PJRT `train_step` artifact) and the global
//!   test-set evaluator;
//! * [`server`] — the synchronous FL server as an eight-stage round
//!   pipeline (environment draw → control solve → sample → cost model →
//!   local train → aggregate → queue advance → record/evaluate).  All
//!   scheme-specific behaviour is delegated to a
//!   [`crate::control::RoundPolicy`], all world-specific randomness to a
//!   [`crate::env::Environment`] (channels, availability, drift); local
//!   training fans out over [`crate::par`] worker threads with
//!   bitwise-deterministic results.  Rounds execute through the
//!   step-wise [`RoundDriver`] (`driver.step()? -> RoundReport`), which
//!   embedders — and the `exp` session engine's streaming observers —
//!   drive incrementally; [`Server::run`] is a thin loop over it.

mod server;
mod trainer;

pub use server::{RoundDriver, RoundReport, Server, SimMode};
pub use trainer::{Evaluator, LocalTrainer, LocalUpdate};
