//! The synchronous FL server — Algorithm 1 as a staged round pipeline.
//!
//! Every round flows through the same eight stages; nothing scheme-
//! specific lives here anymore (that moved behind [`RoundPolicy`]), and
//! nothing world-specific either (that lives behind
//! [`crate::env::Environment`]):
//!
//! 1. **environment draw** — the environment realizes `h_n^t`, the
//!    reachable candidate set `N^t`, and any parameter drift;
//! 2. **control solve**  — the policy allocates `(f, p, q)` over `N^t`;
//! 3. **sample**         — the policy draws the participant multiset `K^t`;
//! 4. **cost model**     — eqs. (6)–(15) per device, makespan over `K^t`;
//! 5. **local train**    — participants train in parallel (Full mode),
//!    deltas aggregate via eq. (4);
//! 6. **queue advance**  — virtual energy queues, eqs. (19)–(20);
//! 7. **record**         — the round's metrics ledger entry;
//! 8. **evaluate**       — periodic global test-set evaluation.
//!
//! When the whole fleet is reachable (the static default) stage 2 sees
//! the full problem through a fast path that is bitwise-identical to the
//! pre-env pipeline.  Under partial availability the policy is handed a
//! *compacted* sub-problem (devices, weights, gains, backlogs sliced to
//! `N^t`, with [`RoundContext::ids`] mapping positions back to global
//! ids) and the resulting plan is scattered back to fleet indexing with
//! `q = 0` for unreachable devices — which zeroes their selection
//! probability, expected energy, and objective contribution.
//!
//! Stage 5 fans client updates over scoped worker threads.  The per-client
//! RNG is forked deterministically (keyed by `(t, client)`, in sorted
//! client order, before any worker starts), so the aggregate is **bitwise
//! identical** for any `train.train_threads` value, including sequential.

use std::path::Path;
use std::time::Instant;

use super::trainer::{Evaluator, LocalTrainer};
use crate::config::Config;
use crate::control::{self, policy, Controls, PolicyInit, RoundContext, RoundPlan, RoundPolicy};
use crate::control::{hyper, VirtualQueues};
use crate::data::SyntheticTask;
use crate::env::{self, EnvSoA, Environment};
use crate::metrics::{Recorder, RoundRecord};
use crate::par;
use crate::rng::Rng;
use crate::runtime::{Engine, Manifest};
use crate::sampling::Selection;
use crate::system::{selection_probability, Device, Fleet, RoundCosts};
use crate::trace::{CellTrace, Counters, Phase};
use crate::Result;

/// Whether the server actually trains a model or only exercises the
/// control plane (Fig. 4 and the solver benches need no learning).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimMode {
    /// Full FL: local SGD via PJRT artifacts + aggregation + evaluation.
    Full,
    /// Control plane only: channels, controls, queues, latency/energy.
    ControlPlaneOnly,
}

/// Fallback model sizes (bits) when running control-plane-only without
/// artifacts: the flat-param counts of the two exported variants.
fn default_model_bits(dataset: &str) -> f64 {
    match dataset {
        "femnist" => 32.0 * 111_902.0,
        _ => 32.0 * 136_874.0,
    }
}

/// Persistent gather buffers for partially-available rounds: the
/// compacted sub-problem is index-gathered into these (retained
/// capacity) instead of allocating five fresh Vecs per round.
#[derive(Default)]
struct CompactScratch {
    devices: Vec<Device>,
    weights: Vec<f64>,
    h: Vec<f64>,
    backlogs: Vec<f64>,
    next_h: Vec<f64>,
}

/// The FL server: owns every subsystem and drives the round pipeline.
pub struct Server {
    pub cfg: Config,
    mode: SimMode,
    engine: Option<Engine>,
    task: Option<SyntheticTask>,
    evaluator: Option<Evaluator>,
    fleet: Fleet,
    env: Box<dyn Environment>,
    /// Identity position → id map for full-availability rounds (cached:
    /// the fast path must not allocate per round).
    identity: Vec<usize>,
    /// Per-round environment realization, refilled in place by
    /// [`Environment::step_into`] — stage 1 allocates nothing at steady
    /// state, which is what makes 1M-device rounds tractable.
    env_soa: EnvSoA,
    /// Persistent overlay buffer for drifted rounds: cloned from the
    /// fleet once, then only the drifting columns (`f_max_hz`, `alpha`)
    /// are rewritten per round, so the cost model still sees a plain
    /// `&[Device]` without a per-round fleet clone.
    drift_devices: Vec<Device>,
    /// Persistent cost columns (stage 4 refills them in place).
    costs: RoundCosts,
    /// Gather buffers for partially-available rounds (same rationale).
    compact: CompactScratch,
    queues: VirtualQueues,
    policy: Box<dyn RoundPolicy>,
    sample_rng: Rng,
    /// Effective λ and V after the §VII-B.1 rule.
    pub lambda: f64,
    pub v: f64,
    model_bits: f64,
    theta: Vec<f32>,
    pub recorder: Recorder,
    /// Attached span recorder (`--trace-out`); `None` costs the round
    /// pipeline nothing.  Timestamps never reach the recorder/CSVs, so
    /// tracing cannot perturb any deterministic output.
    pub trace: Option<CellTrace>,
}

/// Close one pipeline phase: record `[mark, now)` against `phase` and
/// advance the mark, so consecutive phases partition the round's
/// wall-clock contiguously.  A free function over the two fields (not a
/// `&mut self` method) so it can run while `round()` still holds shared
/// borrows of other `Server` fields.
fn phase_mark(
    trace: &mut Option<CellTrace>,
    mark: &mut Option<Instant>,
    t: usize,
    phase: Phase,
    counters: Counters,
) {
    if let (Some(tr), Some(m)) = (trace.as_mut(), mark.as_mut()) {
        let now = Instant::now();
        tr.phase(t, phase, *m, now, counters);
        *m = now;
    }
}

impl Server {
    /// Build a server from config. In [`SimMode::Full`] the AOT artifacts
    /// are loaded from `cfg.artifacts_dir` and the synthetic task is
    /// materialized; in control-plane-only mode neither is touched.
    pub fn new(cfg: Config, mode: SimMode) -> Result<Server> {
        cfg.validate()?;
        let n = cfg.system.num_devices;
        let seed = cfg.train.seed;

        // Data + engine (Full mode only).
        let (engine, task) = match mode {
            SimMode::Full => {
                let manifest = Manifest::load(Path::new(&cfg.artifacts_dir))?;
                let engine = Engine::load(&manifest, &cfg.train.dataset)?;
                let v = &engine.variant;
                let geom = (v.input_hw.0, v.input_hw.1, v.input_c);
                let task = match cfg.train.dataset.as_str() {
                    "femnist" => SyntheticTask::writer_shift(
                        n,
                        v.num_classes,
                        geom,
                        cfg.train.samples_per_device,
                        cfg.train.data_snr,
                        seed,
                    ),
                    _ => SyntheticTask::label_skew(
                        n,
                        v.num_classes,
                        geom,
                        0.5, // the paper's Dirichlet concentration
                        cfg.train.samples_per_device,
                        cfg.train.data_snr,
                        seed,
                    ),
                };
                (Some(engine), Some(task))
            }
            SimMode::ControlPlaneOnly => (None, None),
        };

        // Dataset sizes drive the fleet's data weights.
        let mut fleet_rng = Rng::new(seed ^ 0xF1EE_7000);
        let fleet = match &task {
            Some(t) => Fleet::from_data_sizes(&cfg.system, t.sizes(), &mut fleet_rng),
            None => Fleet::generate(&cfg.system, cfg.train.samples_per_device, &mut fleet_rng),
        };

        let model_bits = if cfg.system.model_bits > 0.0 {
            cfg.system.model_bits
        } else if let Some(e) = &engine {
            e.variant.model_bits as f64
        } else {
            default_model_bits(&cfg.train.dataset)
        };

        // §VII-B.1 hyper-parameter rule.
        let est = hyper::estimate(&cfg.system, &fleet.devices, fleet.weights(), model_bits);
        let lambda = if cfg.control.lambda_explicit > 0.0 {
            cfg.control.lambda_explicit
        } else {
            cfg.control.mu * est.lambda0
        };
        let v = if cfg.control.v_explicit > 0.0 {
            cfg.control.v_explicit
        } else {
            cfg.control.nu * est.v0(lambda)
        };

        let evaluator = match (&engine, &task) {
            (Some(e), Some(t)) => Some(Evaluator::new(t, cfg.train.test_samples.min(8192).max(1)).into_checked(e)?),
            _ => None,
        };

        let theta = match &engine {
            Some(e) => e.init_params(seed as i32)?,
            None => Vec::new(),
        };

        // The scheme under test, built through the registry.
        let init = PolicyInit {
            sys: &cfg.system,
            ctl: &cfg.control,
            bandit: cfg.bandit.clone(),
            thompson: cfg.thompson.clone(),
            linucb: cfg.linucb.clone(),
            lambda,
            v,
            model_bits,
            seed,
        };
        let round_policy = policy::build(cfg.train.policy, &init);

        let budgets = fleet.devices.iter().map(|d| d.energy_budget_j).collect();
        // The environment owns the round randomness; it receives the seed
        // the pre-env server gave ChannelProcess, so `env = static`
        // reproduces the paper's gain streams bitwise.  Because the seed
        // depends only on `train.seed` (never on the policy), two servers
        // built from configs differing only in `train.policy` fork
        // *identical* env streams — the property `lroa regret` relies on
        // to run the oracle against the same draws as each online policy
        // (the selection-reactive `adv` environment is the documented
        // exception: each policy faces its own adaptive adversary).
        let environment = env::build(
            cfg.env.kind,
            &env::EnvInit {
                sys: &cfg.system,
                env: &cfg.env,
                seed: seed ^ 0xC4A1,
            },
        )?;

        let label = format!("{}-{}", round_policy.name(), cfg.train.dataset);
        Ok(Server {
            mode,
            engine,
            task,
            evaluator,
            fleet,
            env: environment,
            identity: (0..n).collect(),
            env_soa: EnvSoA::new(),
            drift_devices: Vec::new(),
            costs: RoundCosts::default(),
            compact: CompactScratch::default(),
            queues: VirtualQueues::new(budgets),
            policy: round_policy,
            sample_rng: Rng::new(seed ^ 0x5A3B_1E00),
            lambda,
            v,
            model_bits,
            theta,
            recorder: Recorder::new(label),
            trace: None,
            cfg,
        })
    }

    /// Current global model (empty in control-plane-only mode).
    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    pub fn queues(&self) -> &VirtualQueues {
        &self.queues
    }

    /// Registry name of the scheme this server runs.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Learning rate at round `t` (paper: halve at 50% and 75%).
    pub fn lr_at(&self, t: usize) -> f32 {
        let frac = t as f64 / self.cfg.train.rounds as f64;
        let mut lr = self.cfg.train.lr0;
        if frac >= self.cfg.train.lr_decay_at.0 {
            lr *= 0.5;
        }
        if frac >= self.cfg.train.lr_decay_at.1 {
            lr *= 0.5;
        }
        lr as f32
    }

    /// Run the full training horizon: a thin loop over [`RoundDriver`].
    pub fn run(&mut self) -> Result<()> {
        self.run_with_timeout(None)
    }

    /// Run the horizon with an optional wall-clock budget.  Exceeding it
    /// is an error naming the progress made, so a sweep's
    /// `--cell_timeout_s` guard rail fails loudly instead of silently
    /// truncating a cell's series.
    pub fn run_with_timeout(&mut self, timeout_s: Option<f64>) -> Result<()> {
        self.driver_with_timeout(timeout_s).finish()
    }

    /// Step-wise round execution for embedders: the driver owns the
    /// cursor, so callers advance the horizon one round at a time
    /// ([`RoundDriver::step`]) and observe every [`RoundReport`] as it
    /// lands — the substrate of the streaming `exp::Observer` events and
    /// of future pipelined/service modes that interleave control solves
    /// with training.  Picks up where the recorder stands, so a driver
    /// can be re-created mid-horizon.
    pub fn driver(&mut self) -> RoundDriver<'_> {
        self.driver_with_timeout(None)
    }

    /// [`Server::driver`] with a wall-clock budget [s]: a step past the
    /// budget fails loudly (the `--cell_timeout_s` contract).
    pub fn driver_with_timeout(&mut self, timeout_s: Option<f64>) -> RoundDriver<'_> {
        let next = self.recorder.rounds.len();
        RoundDriver {
            server: self,
            next,
            started: std::time::Instant::now(),
            timeout_s,
        }
    }

    /// Execute one communication round: the eight-stage pipeline.
    ///
    /// With tracing attached, the pipeline is measured as four phase
    /// spans that partition the call contiguously: `env_step` (stage 1),
    /// `solve` (stages 2–4: plan, sample, scatter, cost model), `train`
    /// (stage 5), and `aggregate` (stages 6–8).
    pub fn round(&mut self, t: usize) -> Result<()> {
        let mut mark = self.trace.as_ref().map(|_| Instant::now());
        // (1) The environment realizes this round's randomness straight
        // into the persistent SoA buffers (clear + refill into retained
        // capacity): channel gains, the reachable candidate set N^t, and
        // parameter drift.  Bitwise-identical to the per-`Device`
        // `next_round` path — pinned per env in `env::tests` and end to
        // end in `tests/env_determinism.rs`.
        self.env.step_into(&self.fleet.devices, &mut self.env_soa);
        // Foresight, only when the scheme asks (the oracle anchor) and
        // the environment is previewable — online policies never see it.
        let peeked = if self.policy.wants_peek() {
            self.env.peek(&self.fleet.devices)
        } else {
            None
        };
        let next_h = peeked.as_ref().map(|p| p.gains.as_slice());
        let n = self.fleet.len();
        if self.env_soa.drifted {
            if self.drift_devices.len() != n {
                self.drift_devices = self.fleet.devices.clone();
            }
            for (i, d) in self.drift_devices.iter_mut().enumerate() {
                d.f_max_hz = self.env_soa.f_max_hz[i];
                d.alpha = self.env_soa.alpha[i];
            }
        }
        let devices: &[Device] = if self.env_soa.drifted {
            &self.drift_devices
        } else {
            &self.fleet.devices
        };
        let h: &[f64] = &self.env_soa.gains;
        phase_mark(&mut self.trace, &mut mark, t, Phase::EnvStep, Counters::default());

        // (2)+(3) The policy solves for controls and samples K^t over the
        // reachable sub-problem (the full fleet on the fast path).
        let k = self.cfg.system.k;
        let compacted = !self.env_soa.all_available && self.env_soa.available.len() < n;
        let plan = if compacted {
            // Index-gather the sub-problem straight from the env SoA
            // into the persistent scratch; `Device` is flat, so the
            // clone is a plain copy into retained capacity.
            let avail: &[usize] = &self.env_soa.available;
            let scratch = &mut self.compact;
            scratch.devices.clear();
            scratch
                .devices
                .extend(avail.iter().map(|&i| devices[i].clone()));
            let w = self.fleet.weights();
            let wsum: f64 = avail.iter().map(|&i| w[i]).sum();
            scratch.weights.clear();
            scratch.weights.extend(avail.iter().map(|&i| w[i] / wsum));
            scratch.h.clear();
            scratch.h.extend(avail.iter().map(|&i| h[i]));
            let backlogs = self.queues.backlogs();
            scratch.backlogs.clear();
            scratch.backlogs.extend(avail.iter().map(|&i| backlogs[i]));
            let has_next = next_h.is_some();
            scratch.next_h.clear();
            if let Some(nh) = next_h {
                scratch.next_h.extend(avail.iter().map(|&i| nh[i]));
            }
            let ctx = RoundContext {
                t,
                k,
                devices: &scratch.devices,
                weights: &scratch.weights,
                ids: avail,
                h: &scratch.h,
                backlogs: &scratch.backlogs,
                next_h: if has_next {
                    Some(scratch.next_h.as_slice())
                } else {
                    None
                },
            };
            let sub_plan = self.policy.plan(&ctx, &mut self.sample_rng);
            scatter_plan(sub_plan, avail, &self.fleet.devices)
        } else {
            // Full fleet reachable (no mask, or an explicit full set).
            let ctx = RoundContext {
                t,
                k,
                devices,
                weights: self.fleet.weights(),
                ids: &self.identity,
                h,
                backlogs: self.queues.backlogs(),
                next_h,
            };
            self.policy.plan(&ctx, &mut self.sample_rng)
        };
        let unique = plan.selection.unique_members();
        // Reactive environments (adv) observe what was actually used.
        self.env.observe_selection(&unique);

        // (4) Latency/energy bookkeeping (eqs. 6-15), under the possibly
        // drifted device parameters, refilled into the persistent cost
        // columns (no per-round allocation).
        self.costs.evaluate_into(
            &self.cfg.system,
            devices,
            self.model_bits,
            h,
            &plan.controls.f_hz,
            &plan.controls.p_w,
        );
        let round_time = self.costs.makespan_s(&unique);
        // Context feed: learning policies (the contextual bandit) see
        // the round's realized per-device costs.  Fires in every sim
        // mode, unlike observe_update, which needs local training.
        self.policy.observe_round(&unique, &self.costs);
        phase_mark(
            &mut self.trace,
            &mut mark,
            t,
            Phase::Solve,
            Counters {
                outer_iters: plan.stats.outer_iters as u64,
                inner_iters: plan.stats.inner_iters as u64,
                warm_start_hits: plan.stats.warm_start_hit as u64,
                bytes_written: 0,
            },
        );

        // (5) Local updates + eq. (4) aggregation (Full mode).
        let train_loss = self.train_round(t, &plan, &unique)?;
        phase_mark(&mut self.trace, &mut mark, t, Phase::Train, Counters::default());

        // (6) Advance the virtual queues with this round's expected draws.
        // With the gate on (default), eq. (19) runs only over the round's
        // candidate set: an offline device's backlog is frozen — it draws
        // no energy (q_eff = 0 anyway) but must not bank the `-Ē_n`
        // budget credit either, which would let a long outage launder an
        // earlier overdraw.  `queue_gate_offline = false` restores the
        // old all-devices semantics bitwise.
        let gated = self.cfg.control.queue_gate_offline
            && !self.env_soa.all_available
            && self.env_soa.available.len() < n;
        if gated {
            self.queues.update_candidates(
                &self.env_soa.available,
                &plan.q_eff,
                self.cfg.system.k,
                &self.costs.energy_j,
            );
        } else {
            self.queues
                .update(&plan.q_eff, self.cfg.system.k, &self.costs.energy_j);
        }

        // (7)+(8) Record the ledger entry; evaluate when due.
        self.record_round(t, &plan, unique.len(), round_time, train_loss)?;
        phase_mark(&mut self.trace, &mut mark, t, Phase::Aggregate, Counters::default());
        Ok(())
    }

    /// Stage 5: parallel local training + aggregation.  Returns the mean
    /// training loss (NaN in control-plane-only mode).
    fn train_round(&mut self, t: usize, plan: &RoundPlan, unique: &[usize]) -> Result<f64> {
        if self.mode != SimMode::Full {
            return Ok(f64::NAN);
        }
        let lr = self.lr_at(t);
        let epochs = self.cfg.system.local_epochs;

        // Fork every participant's RNG up front, in sorted client order —
        // exactly the stream the sequential loop consumed, so any thread
        // count reproduces it bitwise.
        let jobs: Vec<(usize, Rng)> = unique
            .iter()
            .map(|&client| {
                let rng = self.sample_rng.fork((t as u64) << 20 | client as u64);
                (client, rng)
            })
            .collect();

        let engine = self.engine.as_ref().expect("engine");
        let task = self.task.as_ref().expect("task");
        let theta = &self.theta;
        let threads = par::effective_threads(self.cfg.train.train_threads, jobs.len());
        let updates = par::fan_out(
            jobs,
            threads,
            || LocalTrainer::new(epochs),
            |trainer, (client, mut rng)| trainer.train(engine, task, client, theta, lr, &mut rng),
        )?;

        // Feed deltas back to stateful selectors, in client order.
        let mut losses = 0.0f64;
        for (pos, &client) in unique.iter().enumerate() {
            losses += updates[pos].mean_loss as f64;
            self.policy.observe_update(client, &updates[pos].delta);
        }

        // Slot -> unique-member delta mapping for eq. (4).
        let slot_refs: Vec<&[f32]> = plan
            .selection
            .members
            .iter()
            .map(|m| {
                let pos = unique.iter().position(|u| u == m).expect("member in unique");
                updates[pos].delta.as_slice()
            })
            .collect();
        let coefs: Vec<f32> = plan.selection.coefs.iter().map(|&c| c as f32).collect();
        let new_theta = engine.aggregate(&self.theta, &slot_refs, &coefs)?;
        self.theta = new_theta;

        // Round through f32 exactly as the pre-refactor server did, so
        // Full-mode ledgers stay bit-identical across the refactor.
        Ok((losses / unique.len() as f64) as f32 as f64)
    }

    /// Stages 7–8: push the round record; evaluate when the schedule says
    /// so.  Reads the round's costs from the persistent `self.costs`
    /// columns stage 4 just refilled.
    fn record_round(
        &mut self,
        t: usize,
        plan: &RoundPlan,
        selected: usize,
        round_time: f64,
        train_loss: f64,
    ) -> Result<()> {
        let n = self.fleet.len();
        let costs = &self.costs;
        let mean_energy = (0..n)
            .map(|i| selection_probability(plan.q_eff[i], self.cfg.system.k) * costs.energy_j[i])
            .sum::<f64>()
            / n as f64;
        // The P1 integrand is evaluated on the *sampling distribution*
        // `controls.q` (uniform for the deterministic selectors), not on
        // the participation marginals `q_eff` the queues/energy ledger
        // use — Greedy's 0/1 marginals would silently drop the λw²/q
        // variance penalty for unselected devices.  Identical to q_eff
        // for every probability-sampling scheme.  Convention: global
        // data weights w_n even in partially-available rounds (the
        // policy optimized renormalized ones); that keeps the column on
        // one absolute scale, and devices outside N^t (q = 0) contribute
        // nothing either way.
        let objective = control::objective_terms(
            &plan.controls.q,
            &costs.time_s,
            self.lambda,
            self.fleet.weights(),
        );
        let prev_total = self.recorder.total_time_s();

        let mut rec = RoundRecord {
            round: t,
            round_time_s: round_time,
            total_time_s: prev_total + round_time,
            objective,
            mean_energy_j: mean_energy,
            mean_queue: self.queues.mean_backlog(),
            max_queue: self.queues.max_backlog(),
            selected,
            train_loss,
            test_accuracy: f64::NAN,
            test_loss: f64::NAN,
            solver_time_s: plan.stats.solve_time_s,
            outer_iters: plan.stats.outer_iters,
            inner_iters: plan.stats.inner_iters,
            // Populated post-hoc by the regret runner (crate::exp).
            regret: f64::NAN,
            regret_online: f64::NAN,
            regret_budget: f64::NAN,
        };

        let is_eval_round = self.mode == SimMode::Full
            && (t % self.cfg.train.eval_every == 0 || t + 1 == self.cfg.train.rounds);
        if is_eval_round {
            let engine = self.engine.as_ref().expect("engine");
            let ev = self.evaluator.as_ref().expect("evaluator");
            let (loss, acc) = ev.evaluate(engine, &self.theta)?;
            rec.test_loss = loss;
            rec.test_accuracy = acc;
        }
        self.recorder.push(rec);
        Ok(())
    }
}

/// One executed round, as returned by [`RoundDriver::step`]: the round
/// index plus a copy of the ledger entry the recorder just captured.
#[derive(Clone, Debug)]
pub struct RoundReport {
    pub round: usize,
    pub record: RoundRecord,
}

/// Incremental round execution over a borrowed [`Server`].
///
/// [`Server::run`] is a thin loop over this driver; embedders (and the
/// `exp` session engine) call [`RoundDriver::step`] themselves to
/// interleave rounds with their own work — streaming metrics out, mixing
/// simulated rounds with external control traffic, or overlapping the
/// next round's control solve with the current round's training.  The
/// driver never changes *what* a round computes (it calls the same
/// [`Server::round`]), so stepping and running are bitwise-identical
/// (pinned by `tests/session_parity.rs`).
pub struct RoundDriver<'s> {
    server: &'s mut Server,
    /// Next round index to execute (== rounds recorded so far).
    next: usize,
    started: std::time::Instant,
    timeout_s: Option<f64>,
}

impl RoundDriver<'_> {
    /// Execute the next round and return its report, or `None` once the
    /// configured horizon is complete.  With a timeout, a step past the
    /// budget is a loud error naming the progress made.
    pub fn step(&mut self) -> Result<Option<RoundReport>> {
        if self.next >= self.server.cfg.train.rounds {
            return Ok(None);
        }
        if let Some(limit) = self.timeout_s {
            if self.started.elapsed().as_secs_f64() > limit {
                anyhow::bail!(
                    "cell timed out after {:.1}s wall-clock ({}/{} rounds done); \
                     raise --cell_timeout_s or shrink the cell",
                    self.started.elapsed().as_secs_f64(),
                    self.next,
                    self.server.cfg.train.rounds
                );
            }
        }
        let t = self.next;
        let span_t0 = self.server.trace.is_some().then(Instant::now);
        self.server.round(t)?;
        self.next += 1;
        let record = self
            .server
            .recorder
            .rounds
            .last()
            .expect("round() pushes a record")
            .clone();
        if let (Some(tr), Some(t0)) = (self.server.trace.as_mut(), span_t0) {
            tr.round_span(t, t0, Instant::now());
        }
        Ok(Some(RoundReport { round: t, record }))
    }

    /// Record an `observe` phase span for round `round` covering
    /// `[from, now)` — the caller's observer dispatch of that round's
    /// event, which happens between `step` calls and therefore outside
    /// [`Server::round`]'s own phases.  No-op without tracing.
    pub fn note_observe(&mut self, round: usize, from: Instant) {
        if let Some(tr) = self.server.trace.as_mut() {
            tr.phase(round, Phase::Observe, from, Instant::now(), Counters::default());
        }
    }

    /// Drive the remaining rounds to completion.
    pub fn finish(mut self) -> Result<()> {
        while self.step()?.is_some() {}
        Ok(())
    }

    /// Rounds executed so far (across the whole server, not this driver).
    pub fn rounds_done(&self) -> usize {
        self.next
    }

    /// The configured horizon `T`.
    pub fn horizon(&self) -> usize {
        self.server.cfg.train.rounds
    }
}

/// Scatter a compact (candidate-set-only) plan back to full-fleet
/// indexing: member positions become global ids, `q`/`q_eff` are zero
/// off-problem, and unreachable devices get floor controls — inert,
/// since a zero selection probability draws no expected energy, adds no
/// objective term, and never enters the makespan.
fn scatter_plan(plan: RoundPlan, avail: &[usize], base: &[Device]) -> RoundPlan {
    let n = base.len();
    let mut f_hz: Vec<f64> = base.iter().map(|d| d.f_min_hz).collect();
    let mut p_w: Vec<f64> = base.iter().map(|d| d.p_min_w).collect();
    let mut q = vec![0.0; n];
    let mut q_eff = vec![0.0; n];
    for (pos, &g) in avail.iter().enumerate() {
        f_hz[g] = plan.controls.f_hz[pos];
        p_w[g] = plan.controls.p_w[pos];
        q[g] = plan.controls.q[pos];
        q_eff[g] = plan.q_eff[pos];
    }
    let members = plan.selection.members.iter().map(|&m| avail[m]).collect();
    RoundPlan {
        controls: Controls { f_hz, p_w, q },
        stats: plan.stats,
        selection: Selection {
            members,
            coefs: plan.selection.coefs,
        },
        q_eff,
    }
}

// Small helper so Evaluator construction stays on one line above.
trait IntoChecked {
    fn into_checked(self, engine: &Engine) -> Result<Evaluator>;
}

impl IntoChecked for Evaluator {
    fn into_checked(self, engine: &Engine) -> Result<Evaluator> {
        anyhow::ensure!(
            engine.variant.eval_batch > 0,
            "engine has zero eval batch size"
        );
        Ok(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;

    fn base_cfg(policy: Policy, rounds: usize) -> Config {
        let mut cfg = Config::for_dataset("femnist").unwrap();
        cfg.system.num_devices = 16;
        cfg.train.rounds = rounds;
        cfg.train.policy = policy;
        cfg.train.samples_per_device = (40, 80);
        cfg.train.test_samples = 64;
        cfg.train.eval_every = 5;
        cfg
    }

    #[test]
    fn control_plane_only_runs_all_policies() {
        for policy in [
            Policy::Lroa,
            Policy::UniformDynamic,
            Policy::UniformStatic,
            Policy::GreedyChannel,
            Policy::RoundRobin,
            Policy::Bandit,
            Policy::OracleEnergy,
        ] {
            let cfg = base_cfg(policy, 30);
            let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
            server.run().unwrap();
            assert_eq!(server.recorder.rounds.len(), 30);
            let total = server.recorder.total_time_s();
            assert!(total > 0.0 && total.is_finite(), "{policy}: total {total}");
            for r in &server.recorder.rounds {
                assert!(r.round_time_s > 0.0);
                assert!(r.mean_energy_j > 0.0);
                assert!((1..=2).contains(&r.selected));
            }
        }
    }

    use crate::test_util::campus_fixture;

    #[test]
    fn every_environment_runs_every_policy() {
        use crate::config::EnvKind;
        for kind in EnvKind::ALL {
            for policy in [
                Policy::Lroa,
                Policy::UniformStatic,
                Policy::RoundRobin,
                Policy::Bandit,
                Policy::Oracle,
                Policy::OracleEnergy,
            ] {
                let mut cfg = base_cfg(policy, 25);
                cfg.env.kind = kind;
                cfg.env.trace_path = campus_fixture();
                cfg.env.avail_p_drop = 0.3; // make dropout actually bite
                let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
                server.run().unwrap();
                assert_eq!(server.recorder.rounds.len(), 25, "{kind}/{policy}");
                for r in &server.recorder.rounds {
                    assert!(
                        r.round_time_s > 0.0 && r.round_time_s.is_finite(),
                        "{kind}/{policy}: round_time {}",
                        r.round_time_s
                    );
                    assert!(r.objective.is_finite(), "{kind}/{policy}");
                    assert!(r.mean_energy_j >= 0.0 && r.mean_energy_j.is_finite());
                    assert!((1..=2).contains(&r.selected), "{kind}/{policy}");
                }
            }
        }
    }

    #[test]
    fn static_env_is_bitwise_identical_to_default() {
        // Explicitly selecting env=static must change nothing at all.
        use crate::config::EnvKind;
        let cfg_a = base_cfg(Policy::Lroa, 20);
        let mut cfg_b = base_cfg(Policy::Lroa, 20);
        cfg_b.env.kind = EnvKind::Static;
        let mut a = Server::new(cfg_a, SimMode::ControlPlaneOnly).unwrap();
        let mut b = Server::new(cfg_b, SimMode::ControlPlaneOnly).unwrap();
        a.run().unwrap();
        b.run().unwrap();
        for (ra, rb) in a.recorder.rounds.iter().zip(&b.recorder.rounds) {
            assert_eq!(ra.round_time_s, rb.round_time_s);
            assert_eq!(ra.objective, rb.objective);
            assert_eq!(ra.mean_energy_j, rb.mean_energy_j);
        }
    }

    #[test]
    fn availability_masks_but_does_not_perturb_channels() {
        // The avail environment reuses the static channel construction,
        // so objective-irrelevant quantities driven purely by gains and
        // static controls line up whenever the full fleet happens to be
        // reachable.  Weak-form check: dropout changes the trajectory,
        // but the run stays healthy and deterministic.
        use crate::config::EnvKind;
        let run = |kind: EnvKind| {
            let mut cfg = base_cfg(Policy::UniformStatic, 40);
            cfg.env.kind = kind;
            cfg.env.avail_p_drop = 0.4;
            cfg.env.avail_p_join = 0.3;
            let mut s = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
            s.run().unwrap();
            s.recorder
                .rounds
                .iter()
                .map(|r| r.round_time_s)
                .collect::<Vec<_>>()
        };
        let stat = run(EnvKind::Static);
        let avail_a = run(EnvKind::Availability);
        let avail_b = run(EnvKind::Availability);
        assert_eq!(avail_a, avail_b, "availability run not deterministic");
        assert_ne!(stat, avail_a, "dropout never changed the trajectory");
    }

    #[test]
    fn server_label_uses_registry_name() {
        for policy in Policy::ALL {
            let cfg = base_cfg(policy, 1);
            let server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
            assert_eq!(server.policy_name(), policy.name());
            assert!(server.recorder.label.starts_with(policy.name()));
        }
    }

    #[test]
    fn divfl_control_plane_selects_distinct() {
        let cfg = base_cfg(Policy::DivFl, 20);
        let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        server.run().unwrap();
        for r in &server.recorder.rounds {
            assert_eq!(r.selected, 2, "DivFL selects K distinct clients");
        }
    }

    #[test]
    fn lr_schedule_halves() {
        let cfg = base_cfg(Policy::Lroa, 100);
        let server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        let lr0 = server.cfg.train.lr0 as f32;
        assert_eq!(server.lr_at(0), lr0);
        assert_eq!(server.lr_at(49), lr0);
        assert_eq!(server.lr_at(50), lr0 * 0.5);
        assert_eq!(server.lr_at(75), lr0 * 0.25);
        assert_eq!(server.lr_at(99), lr0 * 0.25);
    }

    #[test]
    fn lroa_keeps_time_average_energy_near_budget() {
        // The Lyapunov controller must keep the time-average expected
        // energy around Ē_n; run long enough for queues to bite.
        let mut cfg = base_cfg(Policy::Lroa, 400);
        cfg.control.nu = 1e3; // strong constraint enforcement
        let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        server.run().unwrap();
        let avg_series = server.recorder.time_avg_energy();
        let avg = *avg_series.last().unwrap();
        let budget = server.cfg.system.energy_budget_j;
        assert!(
            avg < 3.0 * budget,
            "time-average energy {avg} runs away from budget {budget}"
        );
    }

    #[test]
    fn lroa_beats_static_on_modeled_time() {
        // The paper's headline: LROA completes the horizon faster than
        // Uni-S under identical channel realizations.
        let rounds = 150;
        let run = |policy: Policy| -> f64 {
            let cfg = base_cfg(policy, rounds);
            let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
            server.run().unwrap();
            server.recorder.total_time_s()
        };
        let t_lroa = run(Policy::Lroa);
        let t_unis = run(Policy::UniformStatic);
        assert!(
            t_lroa < t_unis,
            "LROA {t_lroa} should beat Uni-S {t_unis}"
        );
    }

    #[test]
    fn oracle_e_keeps_queues_bounded_where_the_oracle_does_not() {
        // The budget-feasible anchor's whole point: under budgets the
        // clairvoyant `oracle` violates freely, `oracle-e`'s virtual
        // queues (and so its time-average energy) stay bounded by the
        // same Lyapunov mechanism the online policies are held to.  A
        // small V makes the energy price bite within a short horizon.
        let run = |policy: Policy| -> (f64, f64, f64) {
            let mut cfg = Config::for_dataset("cifar").unwrap();
            cfg.system.num_devices = 16;
            cfg.system.energy_budget_j = 2.5;
            cfg.control.v_explicit = 10.0;
            cfg.train.policy = policy;
            cfg.train.rounds = 400;
            cfg.train.samples_per_device = (40, 40);
            let mut s = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
            s.run().unwrap();
            let mid_backlog = s.recorder.rounds[199].max_queue;
            let avg_energy = *s.recorder.time_avg_energy().last().unwrap();
            (s.queues().max_backlog(), mid_backlog, avg_energy)
        };
        let (oracle_end, _, oracle_avg) = run(Policy::Oracle);
        let (oe_end, oe_mid, oe_avg) = run(Policy::OracleEnergy);
        assert!(
            oracle_end > 400.0,
            "unconstrained oracle queues should run away: {oracle_end}"
        );
        assert!(oe_end < 200.0, "oracle-e backlog must stay bounded: {oe_end}");
        // Plateau, not a slower blow-up: the second half adds little.
        assert!(
            oe_end < 2.0 * oe_mid + 50.0,
            "oracle-e backlog still growing: {oe_mid} -> {oe_end}"
        );
        // Time-average expected energy: oracle-e near the budget scale,
        // oracle far above it (budget 2.5 J across 16 devices).
        assert!(oe_avg < 5.0, "oracle-e time-avg energy {oe_avg} off budget scale");
        assert!(
            oracle_avg > oe_avg,
            "oracle should draw more than oracle-e: {oracle_avg} vs {oe_avg}"
        );
    }

    #[test]
    fn oracle_is_the_latency_lower_bound_on_shared_streams() {
        // On any action-independent environment two servers with the
        // same seed see identical draws, so the oracle's per-round
        // pointwise minimum must dominate every policy cumulatively.
        use crate::config::EnvKind;
        for kind in [EnvKind::Static, EnvKind::GilbertElliott, EnvKind::Trace] {
            let run = |policy: Policy| -> f64 {
                let mut cfg = base_cfg(policy, 60);
                cfg.env.kind = kind;
                cfg.env.trace_path = campus_fixture();
                let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
                server.run().unwrap();
                server.recorder.total_time_s()
            };
            let t_oracle = run(Policy::Oracle);
            for policy in [
                Policy::Lroa,
                Policy::UniformStatic,
                Policy::GreedyChannel,
                Policy::PowerOfTwoChoices,
                Policy::RoundRobin,
                Policy::Bandit,
                Policy::OracleEnergy,
            ] {
                let t = run(policy);
                assert!(
                    t_oracle <= t + 1e-9,
                    "{kind}: oracle {t_oracle} must lower-bound {policy} {t}"
                );
            }
        }
    }

    #[test]
    fn adversarial_env_reacts_to_the_policy_but_stays_deterministic() {
        use crate::config::EnvKind;
        let run = |policy: Policy| -> Vec<f64> {
            let mut cfg = base_cfg(policy, 40);
            cfg.env.kind = EnvKind::Adversarial;
            let mut s = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
            s.run().unwrap();
            s.recorder.rounds.iter().map(|r| r.round_time_s).collect()
        };
        assert_eq!(run(Policy::Lroa), run(Policy::Lroa), "adv not deterministic");
        // The adversary punishes greedy's predicted picks, so greedy's
        // trajectory differs from its static-env one.
        let adv_greedy = run(Policy::GreedyChannel);
        let static_greedy = {
            let cfg = base_cfg(Policy::GreedyChannel, 40);
            let mut s = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
            s.run().unwrap();
            s.recorder
                .rounds
                .iter()
                .map(|r| r.round_time_s)
                .collect::<Vec<_>>()
        };
        assert_ne!(adv_greedy, static_greedy, "adversary never bit greedy");
        // And greedy pays for chasing the degraded top channels.
        let sum_adv: f64 = adv_greedy.iter().sum();
        let sum_static: f64 = static_greedy.iter().sum();
        assert!(
            sum_adv > sum_static,
            "adv should slow greedy: {sum_adv} vs {sum_static}"
        );
    }

    #[test]
    fn round_driver_steps_match_run_and_resume_mid_horizon() {
        let cfg = base_cfg(Policy::Lroa, 20);
        let mut via_run = Server::new(cfg.clone(), SimMode::ControlPlaneOnly).unwrap();
        via_run.run().unwrap();

        // Step-wise execution, with the driver dropped and re-created in
        // the middle: the cursor picks up from the recorder.
        let mut via_driver = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        let mut reports = Vec::new();
        {
            let mut d = via_driver.driver();
            assert_eq!(d.horizon(), 20);
            for _ in 0..7 {
                reports.push(d.step().unwrap().expect("horizon not reached"));
            }
            assert_eq!(d.rounds_done(), 7);
        }
        {
            let mut d = via_driver.driver();
            assert_eq!(d.rounds_done(), 7, "driver resumes at the recorder");
            while let Some(rep) = d.step().unwrap() {
                reports.push(rep);
            }
            assert!(d.step().unwrap().is_none(), "horizon stays exhausted");
        }

        assert_eq!(reports.len(), 20);
        assert_eq!(via_run.recorder.rounds.len(), via_driver.recorder.rounds.len());
        for (i, ((a, b), rep)) in via_run
            .recorder
            .rounds
            .iter()
            .zip(&via_driver.recorder.rounds)
            .zip(&reports)
            .enumerate()
        {
            assert_eq!(a.round_time_s, b.round_time_s, "round {i}");
            assert_eq!(a.objective, b.objective, "round {i}");
            assert_eq!(rep.round, i);
            assert_eq!(rep.record.round_time_s, b.round_time_s, "report {i}");
        }
    }

    #[test]
    fn run_with_timeout_fails_loudly_when_exceeded() {
        let cfg = base_cfg(Policy::Lroa, 100_000);
        let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        let err = server.run_with_timeout(Some(0.0)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("timed out"), "unexpected error {msg}");
        // A generous budget completes normally.
        let cfg = base_cfg(Policy::Lroa, 5);
        let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        server.run_with_timeout(Some(3600.0)).unwrap();
        assert_eq!(server.recorder.rounds.len(), 5);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = base_cfg(Policy::Lroa, 25);
        let mut a = Server::new(cfg.clone(), SimMode::ControlPlaneOnly).unwrap();
        let mut b = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        a.run().unwrap();
        b.run().unwrap();
        for (ra, rb) in a.recorder.rounds.iter().zip(&b.recorder.rounds) {
            assert_eq!(ra.round_time_s, rb.round_time_s);
            assert_eq!(ra.objective, rb.objective);
        }
    }

    #[test]
    fn full_mode_trains_when_artifacts_exist() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping full-mode test: run `make artifacts`");
            return;
        }
        let mut cfg = base_cfg(Policy::Lroa, 6);
        cfg.artifacts_dir = dir.to_string_lossy().into_owned();
        cfg.train.eval_every = 2;
        let mut server = Server::new(cfg, SimMode::Full).unwrap();
        server.run().unwrap();
        assert_eq!(server.recorder.rounds.len(), 6);
        // Training losses recorded and finite.
        assert!(server
            .recorder
            .rounds
            .iter()
            .all(|r| r.train_loss.is_finite()));
        // At least one eval produced an accuracy in [0, 1].
        let acc = server.recorder.final_accuracy();
        assert!((0.0..=1.0).contains(&acc), "acc {acc}");
        // Global model actually moved.
        assert!(server.theta().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn parallel_training_matches_sequential_bitwise() {
        // The fan-out contract end to end: same seed, different thread
        // counts, identical model trajectory (needs artifacts).
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping parallel-determinism test: run `make artifacts`");
            return;
        }
        let run = |threads: usize| -> (Vec<f32>, Vec<f64>) {
            let mut cfg = base_cfg(Policy::Lroa, 5);
            cfg.artifacts_dir = dir.to_string_lossy().into_owned();
            cfg.train.train_threads = threads;
            let mut server = Server::new(cfg, SimMode::Full).unwrap();
            server.run().unwrap();
            let losses = server.recorder.rounds.iter().map(|r| r.train_loss).collect();
            (server.theta().to_vec(), losses)
        };
        let (theta_seq, loss_seq) = run(1);
        let (theta_par, loss_par) = run(4);
        assert_eq!(theta_seq, theta_par, "theta diverged under parallel training");
        assert_eq!(loss_seq, loss_par);
    }
}
