//! The synchronous FL server — Algorithm 1 as a staged round pipeline.
//!
//! Every round flows through the same eight stages; nothing scheme-
//! specific lives here anymore (that moved behind [`RoundPolicy`]):
//!
//! 1. **channel report** — devices report `h_n^t`;
//! 2. **control solve**  — the policy allocates `(f, p, q)`;
//! 3. **sample**         — the policy draws the participant multiset `K^t`;
//! 4. **cost model**     — eqs. (6)–(15) per device, makespan over `K^t`;
//! 5. **local train**    — participants train in parallel (Full mode),
//!    deltas aggregate via eq. (4);
//! 6. **queue advance**  — virtual energy queues, eqs. (19)–(20);
//! 7. **record**         — the round's metrics ledger entry;
//! 8. **evaluate**       — periodic global test-set evaluation.
//!
//! Stage 5 fans client updates over scoped worker threads.  The per-client
//! RNG is forked deterministically (keyed by `(t, client)`, in sorted
//! client order, before any worker starts), so the aggregate is **bitwise
//! identical** for any `train.train_threads` value, including sequential.

use std::path::Path;

use super::trainer::{Evaluator, LocalTrainer};
use crate::config::Config;
use crate::control::{self, policy, PolicyInit, RoundContext, RoundPlan, RoundPolicy};
use crate::control::{hyper, VirtualQueues};
use crate::data::SyntheticTask;
use crate::metrics::{Recorder, RoundRecord};
use crate::par;
use crate::rng::Rng;
use crate::runtime::{Engine, Manifest};
use crate::system::{selection_probability, ChannelProcess, Fleet, RoundCosts};
use crate::Result;

/// Whether the server actually trains a model or only exercises the
/// control plane (Fig. 4 and the solver benches need no learning).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimMode {
    /// Full FL: local SGD via PJRT artifacts + aggregation + evaluation.
    Full,
    /// Control plane only: channels, controls, queues, latency/energy.
    ControlPlaneOnly,
}

/// Fallback model sizes (bits) when running control-plane-only without
/// artifacts: the flat-param counts of the two exported variants.
fn default_model_bits(dataset: &str) -> f64 {
    match dataset {
        "femnist" => 32.0 * 111_902.0,
        _ => 32.0 * 136_874.0,
    }
}

/// The FL server: owns every subsystem and drives the round pipeline.
pub struct Server {
    pub cfg: Config,
    mode: SimMode,
    engine: Option<Engine>,
    task: Option<SyntheticTask>,
    evaluator: Option<Evaluator>,
    fleet: Fleet,
    channel: ChannelProcess,
    queues: VirtualQueues,
    policy: Box<dyn RoundPolicy>,
    sample_rng: Rng,
    /// Effective λ and V after the §VII-B.1 rule.
    pub lambda: f64,
    pub v: f64,
    model_bits: f64,
    theta: Vec<f32>,
    pub recorder: Recorder,
}

impl Server {
    /// Build a server from config. In [`SimMode::Full`] the AOT artifacts
    /// are loaded from `cfg.artifacts_dir` and the synthetic task is
    /// materialized; in control-plane-only mode neither is touched.
    pub fn new(cfg: Config, mode: SimMode) -> Result<Server> {
        cfg.validate()?;
        let n = cfg.system.num_devices;
        let seed = cfg.train.seed;

        // Data + engine (Full mode only).
        let (engine, task) = match mode {
            SimMode::Full => {
                let manifest = Manifest::load(Path::new(&cfg.artifacts_dir))?;
                let engine = Engine::load(&manifest, &cfg.train.dataset)?;
                let v = &engine.variant;
                let geom = (v.input_hw.0, v.input_hw.1, v.input_c);
                let task = match cfg.train.dataset.as_str() {
                    "femnist" => SyntheticTask::writer_shift(
                        n,
                        v.num_classes,
                        geom,
                        cfg.train.samples_per_device,
                        cfg.train.data_snr,
                        seed,
                    ),
                    _ => SyntheticTask::label_skew(
                        n,
                        v.num_classes,
                        geom,
                        0.5, // the paper's Dirichlet concentration
                        cfg.train.samples_per_device,
                        cfg.train.data_snr,
                        seed,
                    ),
                };
                (Some(engine), Some(task))
            }
            SimMode::ControlPlaneOnly => (None, None),
        };

        // Dataset sizes drive the fleet's data weights.
        let mut fleet_rng = Rng::new(seed ^ 0xF1EE_7000);
        let fleet = match &task {
            Some(t) => Fleet::from_data_sizes(&cfg.system, t.sizes(), &mut fleet_rng),
            None => Fleet::generate(&cfg.system, cfg.train.samples_per_device, &mut fleet_rng),
        };

        let model_bits = if cfg.system.model_bits > 0.0 {
            cfg.system.model_bits
        } else if let Some(e) = &engine {
            e.variant.model_bits as f64
        } else {
            default_model_bits(&cfg.train.dataset)
        };

        // §VII-B.1 hyper-parameter rule.
        let est = hyper::estimate(&cfg.system, &fleet.devices, fleet.weights(), model_bits);
        let lambda = if cfg.control.lambda_explicit > 0.0 {
            cfg.control.lambda_explicit
        } else {
            cfg.control.mu * est.lambda0
        };
        let v = if cfg.control.v_explicit > 0.0 {
            cfg.control.v_explicit
        } else {
            cfg.control.nu * est.v0(lambda)
        };

        let evaluator = match (&engine, &task) {
            (Some(e), Some(t)) => Some(Evaluator::new(t, cfg.train.test_samples.min(8192).max(1)).into_checked(e)?),
            _ => None,
        };

        let theta = match &engine {
            Some(e) => e.init_params(seed as i32)?,
            None => Vec::new(),
        };

        // The scheme under test, built through the registry.
        let init = PolicyInit {
            sys: &cfg.system,
            ctl: &cfg.control,
            lambda,
            v,
            model_bits,
            seed,
        };
        let round_policy = policy::build(cfg.train.policy, &init);

        let budgets = fleet.devices.iter().map(|d| d.energy_budget_j).collect();
        let channel = ChannelProcess::new(&cfg.system, seed ^ 0xC4A1);

        let label = format!("{}-{}", round_policy.name(), cfg.train.dataset);
        Ok(Server {
            mode,
            engine,
            task,
            evaluator,
            fleet,
            channel,
            queues: VirtualQueues::new(budgets),
            policy: round_policy,
            sample_rng: Rng::new(seed ^ 0x5A3B_1E00),
            lambda,
            v,
            model_bits,
            theta,
            recorder: Recorder::new(label),
            cfg,
        })
    }

    /// Current global model (empty in control-plane-only mode).
    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    pub fn queues(&self) -> &VirtualQueues {
        &self.queues
    }

    /// Registry name of the scheme this server runs.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Learning rate at round `t` (paper: halve at 50% and 75%).
    pub fn lr_at(&self, t: usize) -> f32 {
        let frac = t as f64 / self.cfg.train.rounds as f64;
        let mut lr = self.cfg.train.lr0;
        if frac >= self.cfg.train.lr_decay_at.0 {
            lr *= 0.5;
        }
        if frac >= self.cfg.train.lr_decay_at.1 {
            lr *= 0.5;
        }
        lr as f32
    }

    /// Run the full training horizon.
    pub fn run(&mut self) -> Result<()> {
        for t in 0..self.cfg.train.rounds {
            self.round(t)?;
        }
        Ok(())
    }

    /// Execute one communication round: the eight-stage pipeline.
    pub fn round(&mut self, t: usize) -> Result<()> {
        // (1) Devices report channel states.
        let h = self.channel.next_round();

        // (2)+(3) The policy solves for controls and samples K^t.
        let plan = self.plan_round(t, &h);
        let unique = plan.selection.unique_members();

        // (4) Latency/energy bookkeeping (eqs. 6-15).
        let costs = self.cost_round(&h, &plan);
        let round_time = costs.makespan_s(&unique);

        // (5) Local updates + eq. (4) aggregation (Full mode).
        let train_loss = self.train_round(t, &plan, &unique)?;

        // (6) Advance the virtual queues with this round's expected draws.
        self.queues
            .update(&plan.q_eff, self.cfg.system.k, &costs.energy_j);

        // (7)+(8) Record the ledger entry; evaluate when due.
        self.record_round(t, &plan, &costs, unique.len(), round_time, train_loss)
    }

    /// Stages 2–3: hand the round's observations to the policy.
    fn plan_round(&mut self, t: usize, h: &[f64]) -> RoundPlan {
        let ctx = RoundContext {
            t,
            k: self.cfg.system.k,
            devices: &self.fleet.devices,
            weights: self.fleet.weights(),
            h,
            backlogs: self.queues.backlogs(),
        };
        self.policy.plan(&ctx, &mut self.sample_rng)
    }

    /// Stage 4: evaluate the cost model under the planned controls.
    fn cost_round(&self, h: &[f64], plan: &RoundPlan) -> RoundCosts {
        RoundCosts::evaluate(
            &self.cfg.system,
            &self.fleet.devices,
            self.model_bits,
            h,
            &plan.controls.f_hz,
            &plan.controls.p_w,
        )
    }

    /// Stage 5: parallel local training + aggregation.  Returns the mean
    /// training loss (NaN in control-plane-only mode).
    fn train_round(&mut self, t: usize, plan: &RoundPlan, unique: &[usize]) -> Result<f64> {
        if self.mode != SimMode::Full {
            return Ok(f64::NAN);
        }
        let lr = self.lr_at(t);
        let epochs = self.cfg.system.local_epochs;

        // Fork every participant's RNG up front, in sorted client order —
        // exactly the stream the sequential loop consumed, so any thread
        // count reproduces it bitwise.
        let jobs: Vec<(usize, Rng)> = unique
            .iter()
            .map(|&client| {
                let rng = self.sample_rng.fork((t as u64) << 20 | client as u64);
                (client, rng)
            })
            .collect();

        let engine = self.engine.as_ref().expect("engine");
        let task = self.task.as_ref().expect("task");
        let theta = &self.theta;
        let threads = par::effective_threads(self.cfg.train.train_threads, jobs.len());
        let updates = par::fan_out(
            jobs,
            threads,
            || LocalTrainer::new(epochs),
            |trainer, (client, mut rng)| trainer.train(engine, task, client, theta, lr, &mut rng),
        )?;

        // Feed deltas back to stateful selectors, in client order.
        let mut losses = 0.0f64;
        for (pos, &client) in unique.iter().enumerate() {
            losses += updates[pos].mean_loss as f64;
            self.policy.observe_update(client, &updates[pos].delta);
        }

        // Slot -> unique-member delta mapping for eq. (4).
        let slot_refs: Vec<&[f32]> = plan
            .selection
            .members
            .iter()
            .map(|m| {
                let pos = unique.iter().position(|u| u == m).expect("member in unique");
                updates[pos].delta.as_slice()
            })
            .collect();
        let coefs: Vec<f32> = plan.selection.coefs.iter().map(|&c| c as f32).collect();
        let new_theta = engine.aggregate(&self.theta, &slot_refs, &coefs)?;
        self.theta = new_theta;

        // Round through f32 exactly as the pre-refactor server did, so
        // Full-mode ledgers stay bit-identical across the refactor.
        Ok((losses / unique.len() as f64) as f32 as f64)
    }

    /// Stages 7–8: push the round record; evaluate when the schedule says so.
    fn record_round(
        &mut self,
        t: usize,
        plan: &RoundPlan,
        costs: &RoundCosts,
        selected: usize,
        round_time: f64,
        train_loss: f64,
    ) -> Result<()> {
        let n = self.fleet.len();
        let mean_energy = (0..n)
            .map(|i| selection_probability(plan.q_eff[i], self.cfg.system.k) * costs.energy_j[i])
            .sum::<f64>()
            / n as f64;
        let objective =
            control::objective_terms(&plan.q_eff, &costs.time_s, self.lambda, self.fleet.weights());
        let prev_total = self.recorder.total_time_s();

        let mut rec = RoundRecord {
            round: t,
            round_time_s: round_time,
            total_time_s: prev_total + round_time,
            objective,
            mean_energy_j: mean_energy,
            mean_queue: self.queues.mean_backlog(),
            max_queue: self.queues.max_backlog(),
            selected,
            train_loss,
            test_accuracy: f64::NAN,
            test_loss: f64::NAN,
            solver_time_s: plan.stats.solve_time_s,
        };

        let is_eval_round = self.mode == SimMode::Full
            && (t % self.cfg.train.eval_every == 0 || t + 1 == self.cfg.train.rounds);
        if is_eval_round {
            let engine = self.engine.as_ref().expect("engine");
            let ev = self.evaluator.as_ref().expect("evaluator");
            let (loss, acc) = ev.evaluate(engine, &self.theta)?;
            rec.test_loss = loss;
            rec.test_accuracy = acc;
        }
        self.recorder.push(rec);
        Ok(())
    }
}

// Small helper so Evaluator construction stays on one line above.
trait IntoChecked {
    fn into_checked(self, engine: &Engine) -> Result<Evaluator>;
}

impl IntoChecked for Evaluator {
    fn into_checked(self, engine: &Engine) -> Result<Evaluator> {
        anyhow::ensure!(
            engine.variant.eval_batch > 0,
            "engine has zero eval batch size"
        );
        Ok(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;

    fn base_cfg(policy: Policy, rounds: usize) -> Config {
        let mut cfg = Config::for_dataset("femnist").unwrap();
        cfg.system.num_devices = 16;
        cfg.train.rounds = rounds;
        cfg.train.policy = policy;
        cfg.train.samples_per_device = (40, 80);
        cfg.train.test_samples = 64;
        cfg.train.eval_every = 5;
        cfg
    }

    #[test]
    fn control_plane_only_runs_all_policies() {
        for policy in [
            Policy::Lroa,
            Policy::UniformDynamic,
            Policy::UniformStatic,
        ] {
            let cfg = base_cfg(policy, 30);
            let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
            server.run().unwrap();
            assert_eq!(server.recorder.rounds.len(), 30);
            let total = server.recorder.total_time_s();
            assert!(total > 0.0 && total.is_finite(), "{policy}: total {total}");
            for r in &server.recorder.rounds {
                assert!(r.round_time_s > 0.0);
                assert!(r.mean_energy_j > 0.0);
                assert!((1..=2).contains(&r.selected));
            }
        }
    }

    #[test]
    fn server_label_uses_registry_name() {
        for policy in Policy::ALL {
            let cfg = base_cfg(policy, 1);
            let server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
            assert_eq!(server.policy_name(), policy.name());
            assert!(server.recorder.label.starts_with(policy.name()));
        }
    }

    #[test]
    fn divfl_control_plane_selects_distinct() {
        let cfg = base_cfg(Policy::DivFl, 20);
        let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        server.run().unwrap();
        for r in &server.recorder.rounds {
            assert_eq!(r.selected, 2, "DivFL selects K distinct clients");
        }
    }

    #[test]
    fn lr_schedule_halves() {
        let cfg = base_cfg(Policy::Lroa, 100);
        let server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        let lr0 = server.cfg.train.lr0 as f32;
        assert_eq!(server.lr_at(0), lr0);
        assert_eq!(server.lr_at(49), lr0);
        assert_eq!(server.lr_at(50), lr0 * 0.5);
        assert_eq!(server.lr_at(75), lr0 * 0.25);
        assert_eq!(server.lr_at(99), lr0 * 0.25);
    }

    #[test]
    fn lroa_keeps_time_average_energy_near_budget() {
        // The Lyapunov controller must keep the time-average expected
        // energy around Ē_n; run long enough for queues to bite.
        let mut cfg = base_cfg(Policy::Lroa, 400);
        cfg.control.nu = 1e3; // strong constraint enforcement
        let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        server.run().unwrap();
        let avg_series = server.recorder.time_avg_energy();
        let avg = *avg_series.last().unwrap();
        let budget = server.cfg.system.energy_budget_j;
        assert!(
            avg < 3.0 * budget,
            "time-average energy {avg} runs away from budget {budget}"
        );
    }

    #[test]
    fn lroa_beats_static_on_modeled_time() {
        // The paper's headline: LROA completes the horizon faster than
        // Uni-S under identical channel realizations.
        let rounds = 150;
        let run = |policy: Policy| -> f64 {
            let cfg = base_cfg(policy, rounds);
            let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
            server.run().unwrap();
            server.recorder.total_time_s()
        };
        let t_lroa = run(Policy::Lroa);
        let t_unis = run(Policy::UniformStatic);
        assert!(
            t_lroa < t_unis,
            "LROA {t_lroa} should beat Uni-S {t_unis}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = base_cfg(Policy::Lroa, 25);
        let mut a = Server::new(cfg.clone(), SimMode::ControlPlaneOnly).unwrap();
        let mut b = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        a.run().unwrap();
        b.run().unwrap();
        for (ra, rb) in a.recorder.rounds.iter().zip(&b.recorder.rounds) {
            assert_eq!(ra.round_time_s, rb.round_time_s);
            assert_eq!(ra.objective, rb.objective);
        }
    }

    #[test]
    fn full_mode_trains_when_artifacts_exist() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping full-mode test: run `make artifacts`");
            return;
        }
        let mut cfg = base_cfg(Policy::Lroa, 6);
        cfg.artifacts_dir = dir.to_string_lossy().into_owned();
        cfg.train.eval_every = 2;
        let mut server = Server::new(cfg, SimMode::Full).unwrap();
        server.run().unwrap();
        assert_eq!(server.recorder.rounds.len(), 6);
        // Training losses recorded and finite.
        assert!(server
            .recorder
            .rounds
            .iter()
            .all(|r| r.train_loss.is_finite()));
        // At least one eval produced an accuracy in [0, 1].
        let acc = server.recorder.final_accuracy();
        assert!((0.0..=1.0).contains(&acc), "acc {acc}");
        // Global model actually moved.
        assert!(server.theta().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn parallel_training_matches_sequential_bitwise() {
        // The fan-out contract end to end: same seed, different thread
        // counts, identical model trajectory (needs artifacts).
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping parallel-determinism test: run `make artifacts`");
            return;
        }
        let run = |threads: usize| -> (Vec<f32>, Vec<f64>) {
            let mut cfg = base_cfg(Policy::Lroa, 5);
            cfg.artifacts_dir = dir.to_string_lossy().into_owned();
            cfg.train.train_threads = threads;
            let mut server = Server::new(cfg, SimMode::Full).unwrap();
            server.run().unwrap();
            let losses = server.recorder.rounds.iter().map(|r| r.train_loss).collect();
            (server.theta().to_vec(), losses)
        };
        let (theta_seq, loss_seq) = run(1);
        let (theta_par, loss_par) = run(4);
        assert_eq!(theta_seq, theta_par, "theta diverged under parallel training");
        assert_eq!(loss_seq, loss_par);
    }
}
