//! Client-side local training and global evaluation.

use crate::data::SyntheticTask;
use crate::rng::Rng;
use crate::runtime::Engine;
use crate::Result;

/// Result of one client's local round.
#[derive(Clone, Debug)]
pub struct LocalUpdate {
    /// `θ_n^{t,E} − θ^t`, the model delta the client uploads.
    pub delta: Vec<f32>,
    /// Mean minibatch loss across the client's local steps.
    pub mean_loss: f32,
    /// Number of SGD steps executed.
    pub steps: usize,
}

/// Runs `E` local epochs for one client through the AOT `train_step`.
pub struct LocalTrainer {
    /// Local epochs `E`.
    pub local_epochs: usize,
    // Reused batch buffers (hot path: two clients per round, many rounds).
    x_buf: Vec<f32>,
    y_buf: Vec<i32>,
    idx_buf: Vec<usize>,
}

impl LocalTrainer {
    pub fn new(local_epochs: usize) -> Self {
        Self {
            local_epochs,
            x_buf: Vec::new(),
            y_buf: Vec::new(),
            idx_buf: Vec::new(),
        }
    }

    /// One client's local round: initialize from the global model, run
    /// `E` epochs of shuffled minibatch SGD, return the delta.
    ///
    /// Batching policy: full batches only (drop-last), except that clients
    /// with fewer than one batch of data wrap around so every client takes
    /// at least one step per epoch.
    pub fn train(
        &mut self,
        engine: &Engine,
        task: &SyntheticTask,
        client: usize,
        global: &[f32],
        lr: f32,
        rng: &mut Rng,
    ) -> Result<LocalUpdate> {
        let v = &engine.variant;
        let batch = v.train_batch;
        let feats = v.input_features();
        let d_n = task.sizes()[client];

        let mut theta = global.to_vec();
        let mut momentum = vec![0.0f32; theta.len()];
        let mut loss_acc = 0.0f64;
        let mut steps = 0usize;

        self.x_buf.resize(batch * feats, 0.0);
        self.y_buf.resize(batch, 0);

        for _epoch in 0..self.local_epochs {
            // Shuffled epoch order over the client's local indices.
            self.idx_buf.clear();
            self.idx_buf.extend(0..d_n);
            rng.shuffle(&mut self.idx_buf);
            if d_n < batch {
                // Wrap-around so one full batch exists.
                for i in d_n..batch {
                    let wrapped = self.idx_buf[i % d_n];
                    self.idx_buf.push(wrapped);
                }
            }
            let n_batches = self.idx_buf.len() / batch; // drop-last
            for b in 0..n_batches {
                let ids = &self.idx_buf[b * batch..(b + 1) * batch];
                task.fill_batch(client, ids, &mut self.x_buf, &mut self.y_buf);
                let out = engine.train_step(&theta, &momentum, &self.x_buf, &self.y_buf, lr)?;
                theta = out.params;
                momentum = out.momentum;
                loss_acc += out.loss as f64;
                steps += 1;
            }
        }

        let delta: Vec<f32> = theta.iter().zip(global).map(|(a, b)| a - b).collect();
        Ok(LocalUpdate {
            delta,
            mean_loss: if steps > 0 { (loss_acc / steps as f64) as f32 } else { f32::NAN },
            steps,
        })
    }
}

/// Global test-set evaluator (masked batches through `eval_batch`).
pub struct Evaluator {
    x: Vec<f32>,
    y: Vec<i32>,
    n: usize,
}

impl Evaluator {
    /// Materialize an `n`-sample test set from the task's global distribution.
    pub fn new(task: &SyntheticTask, n: usize) -> Self {
        let (x, y) = task.test_set(n);
        Self { x, y, n }
    }

    /// `(mean_loss, accuracy)` of `theta` on the held-out set.
    pub fn evaluate(&self, engine: &Engine, theta: &[f32]) -> Result<(f64, f64)> {
        let v = &engine.variant;
        let batch = v.eval_batch;
        let feats = v.input_features();
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;

        let mut xb = vec![0.0f32; batch * feats];
        let mut yb = vec![0i32; batch];
        let mut mask = vec![0.0f32; batch];

        let mut i = 0;
        while i < self.n {
            let take = (self.n - i).min(batch);
            xb[..take * feats].copy_from_slice(&self.x[i * feats..(i + take) * feats]);
            yb[..take].copy_from_slice(&self.y[i..i + take]);
            for (slot, m) in mask.iter_mut().enumerate() {
                *m = if slot < take { 1.0 } else { 0.0 };
            }
            // Zero the padded tail to keep inputs finite.
            for v in xb[take * feats..].iter_mut() {
                *v = 0.0;
            }
            for y in yb[take..].iter_mut() {
                *y = 0;
            }
            let (ls, cr) = engine.eval_batch(theta, &xb, &yb, &mask)?;
            loss_sum += ls as f64;
            correct += cr as f64;
            i += take;
        }
        Ok((loss_sum / self.n as f64, correct / self.n as f64))
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
}
