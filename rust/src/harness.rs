//! Shared experiment harness for the figure-regeneration examples.
//!
//! Each `examples/fig*.rs` binary reproduces one figure of the paper's
//! evaluation section; this module holds the common machinery: CLI
//! parsing (`--quick`, `--rounds`, `--dataset`, any `--section.key=value`
//! config override), per-policy runs on **identical channel realizations**
//! (the paper fixes the channel seed across schemes), CSV emission under
//! `runs/<figure>/`, and the comparison tables the paper reports.

use std::path::{Path, PathBuf};

use crate::config::{Config, Policy};
use crate::fl::{Server, SimMode};
use crate::json::{obj, Json};
use crate::metrics::Recorder;
use crate::Result;

/// Parsed harness arguments.
#[derive(Clone, Debug)]
pub struct Args {
    /// Reduced-scale run (default true unless `--full` is given): the
    /// paper's 1000-2000 round horizons are scaled to laptop budgets.
    pub quick: bool,
    /// Override the round count.
    pub rounds: Option<usize>,
    /// Restrict to one dataset (`cifar` / `femnist`).
    pub dataset: Option<String>,
    /// Seed repeats (the paper averages 30; quick default 1).
    pub repeats: usize,
    /// Raw args forwarded into `Config::apply_cli`.
    raw: Vec<String>,
}

impl Args {
    pub fn parse() -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        let mut a = Args {
            quick: !raw.iter().any(|s| s == "--full"),
            rounds: None,
            dataset: None,
            repeats: 1,
            raw: raw.clone(),
        };
        let mut it = raw.iter().peekable();
        while let Some(arg) = it.next() {
            let mut take = |key: &str| -> Option<String> {
                if let Some(v) = arg.strip_prefix(&format!("{key}=")) {
                    return Some(v.to_string());
                }
                if arg == key {
                    return it.peek().map(|s| s.to_string());
                }
                None
            };
            if let Some(v) = take("--rounds") {
                a.rounds = v.parse().ok();
            } else if let Some(v) = take("--dataset") {
                a.dataset = Some(v);
            } else if let Some(v) = take("--repeats") {
                a.repeats = v.parse().unwrap_or(1);
            }
        }
        a
    }

    /// The datasets this invocation covers.
    pub fn datasets(&self) -> Vec<String> {
        match &self.dataset {
            Some(d) => vec![d.clone()],
            None => vec!["cifar".into(), "femnist".into()],
        }
    }

    /// Build the base config for a dataset under these args.
    ///
    /// Quick scaling: horizon 150 rounds (vs 2000/1000), 50-150 samples
    /// per device (bounds local compute), 512-sample test set, eval every
    /// 10 rounds.  Paper-scale values apply under `--full`.
    pub fn config(&self, dataset: &str) -> Result<Config> {
        let mut cfg = Config::for_dataset(dataset)?;
        if self.quick {
            cfg.train.rounds = 150;
            cfg.train.samples_per_device = (50, 150);
            cfg.train.test_samples = 512;
            cfg.train.eval_every = 10;
            // The paper's budgets are calibrated to its data density
            // (~417 samples/device on CIFAR).  Quick mode shrinks D_n for
            // wall-clock reasons, so scale Ē_n by the same factor to keep
            // the energy constraint (16) binding in the same regime.
            cfg.system.energy_budget_j *= 100.0 / 417.0;
        }
        if let Some(r) = self.rounds {
            cfg.train.rounds = r;
        }
        cfg.apply_cli(&self.raw)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn out_dir(&self, figure: &str) -> PathBuf {
        PathBuf::from("runs").join(figure)
    }
}

/// Run one policy to completion and return its recorder.
pub fn run_policy(mut cfg: Config, policy: Policy, mode: SimMode, label: &str) -> Result<Recorder> {
    cfg.train.policy = policy;
    let mut server = Server::new(cfg, mode)?;
    let t0 = std::time::Instant::now();
    server.run()?;
    let mut rec = std::mem::take(&mut server.recorder);
    rec.label = label.to_string();
    eprintln!(
        "[run] {label}: {} rounds, modeled {:.1}s, final acc {:.4}, wall {:.1}s",
        rec.rounds.len(),
        rec.total_time_s(),
        rec.final_accuracy(),
        t0.elapsed().as_secs_f64()
    );
    Ok(rec)
}

/// Write each recorder's CSV plus a JSON summary bundle.
pub fn save_all(dir: &Path, recs: &[Recorder]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut summaries = Vec::new();
    for rec in recs {
        rec.write_csv(&dir.join(format!("{}.csv", sanitize(&rec.label))))?;
        summaries.push(rec.summary_json());
    }
    std::fs::write(
        dir.join("summary.json"),
        obj(vec![("runs", Json::Arr(summaries))]).to_string(),
    )?;
    Ok(())
}

fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
        .collect()
}

/// The paper's headline comparison: total modeled latency per policy plus
/// savings of the first row (LROA) against each baseline.
pub fn print_latency_table(recs: &[Recorder]) {
    println!("\n{:<22} {:>14} {:>12} {:>12}", "policy", "total time [s]", "final acc", "vs LROA");
    let t0 = recs.first().map(|r| r.total_time_s()).unwrap_or(f64::NAN);
    for rec in recs {
        let t = rec.total_time_s();
        let savings = if t > 0.0 { (1.0 - t0 / t) * 100.0 } else { f64::NAN };
        println!(
            "{:<22} {:>14.1} {:>12.4} {:>11.1}%",
            rec.label,
            t,
            rec.final_accuracy(),
            savings
        );
    }
    println!();
}

/// Print an accuracy-vs-time/round series in the shape of the paper's
/// figures (one CSV block per curve, on stdout for quick inspection).
pub fn print_series(recs: &[Recorder]) {
    for rec in recs {
        println!("# {}", rec.label);
        println!("round,total_time_s,test_accuracy");
        for r in rec.rounds.iter().filter(|r| !r.test_accuracy.is_nan()) {
            println!("{},{:.3},{:.4}", r.round, r.total_time_s, r.test_accuracy);
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_labels() {
        assert_eq!(sanitize("LROA-cifar (k=2)"), "LROA-cifar__k_2_");
    }

    #[test]
    fn quick_config_scales_down() {
        let args = Args {
            quick: true,
            rounds: None,
            dataset: None,
            repeats: 1,
            raw: vec![],
        };
        let cfg = args.config("cifar").unwrap();
        assert_eq!(cfg.train.rounds, 150);
        assert!(cfg.train.test_samples <= 1024);
        let full = Args {
            quick: false,
            ..args
        };
        assert_eq!(full.config("cifar").unwrap().train.rounds, 2000);
        assert_eq!(full.config("femnist").unwrap().train.rounds, 1000);
    }

    #[test]
    fn rounds_override_wins() {
        let args = Args {
            quick: true,
            rounds: Some(7),
            dataset: Some("femnist".into()),
            repeats: 1,
            raw: vec![],
        };
        assert_eq!(args.config("femnist").unwrap().train.rounds, 7);
        assert_eq!(args.datasets(), vec!["femnist".to_string()]);
    }
}
