//! Shared experiment harness for the figure-regeneration examples.
//!
//! Each `examples/fig*.rs` binary reproduces one figure of the paper's
//! evaluation section.  This module holds the common machinery on top of
//! the [`crate::exp`] engine: CLI parsing (`--quick`, `--rounds`,
//! `--dataset`, `--repeats`, `--threads`, `--envs`, `--trace-out`, any
//! `--section.key=value` config override — including `--env.kind=...`
//! and the other `[env]` knobs), quick-mode config scaling, CSV emission
//! under `runs/<figure>/`, and the comparison tables the paper reports.
//! Per-policy runs share identical channel realizations (the paper fixes
//! the channel seed across schemes); each figure's grid is one
//! [`crate::exp::Experiment`] ([`Args::experiment`]) run through the
//! session engine.

use std::path::{Path, PathBuf};

use crate::config::Config;
use crate::exp::{self, EnvSel, Experiment, ScenarioResult, SweepSpec};
use crate::json::{obj, Json};
use crate::metrics::Recorder;
use crate::Result;

/// Parsed harness arguments.
#[derive(Clone, Debug)]
pub struct Args {
    /// Reduced-scale run (default true unless `--full` is given): the
    /// paper's 1000-2000 round horizons are scaled to laptop budgets.
    pub quick: bool,
    /// Override the round count.
    pub rounds: Option<usize>,
    /// Restrict to one dataset (`cifar` / `femnist`).
    pub dataset: Option<String>,
    /// Seed repeats (the paper averages 30; quick default 1).
    pub repeats: usize,
    /// Scenario-runner pool width (0 = one per core).
    pub threads: usize,
    /// Environment axis (`--envs=static,ge,avail,drift,adv,
    /// trace:<path>|all`); empty = keep the base config's environment.
    /// Examples that support the axis (fig1_2_baselines) read it through
    /// [`Args::validated_envs`] and feed it into
    /// [`crate::exp::SweepSpec::envs`]; the rest call
    /// [`Args::reject_envs`] so the flag is never silently ignored.
    pub envs: Vec<EnvSel>,
    /// Parse error from `--envs`, surfaced by [`Args::validated_envs`] /
    /// [`Args::reject_envs`] — a typo must never silently shrink a grid.
    envs_err: Option<String>,
    /// Structured-trace output directory (`--trace-out DIR`); wired into
    /// [`Args::experiment`].  Determinism-neutral: figure CSVs are
    /// byte-identical with tracing on or off.
    pub trace_out: Option<String>,
    /// Args not consumed above, forwarded into `Config::apply_cli`
    /// (and inspectable via [`Args::flag`]).
    raw: Vec<String>,
}

impl Args {
    pub fn parse() -> Args {
        Args::from_vec(std::env::args().skip(1).collect())
    }

    /// Parse an argument vector.  Harness flags accept both `--flag=value`
    /// and the two-token `--flag value` form; in the latter the value
    /// token is consumed, so it never leaks into the raw args forwarded
    /// to [`Config::apply_cli`].
    pub fn from_vec(argv: Vec<String>) -> Args {
        let mut a = Args {
            quick: true,
            rounds: None,
            dataset: None,
            repeats: 1,
            threads: 0,
            envs: Vec::new(),
            envs_err: None,
            trace_out: None,
            raw: Vec::new(),
        };
        let mut envs_seen = false;
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--full" {
                a.quick = false;
                continue;
            }
            let (key, inline) = match arg.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (arg.clone(), None),
            };
            if !matches!(
                key.as_str(),
                "--rounds" | "--dataset" | "--repeats" | "--threads" | "--envs" | "--trace-out"
            ) {
                a.raw.push(arg);
                continue;
            }
            // Two-token form: only a non-flag token can be the value —
            // `--rounds --grid` must not swallow `--grid`.
            let value = match inline {
                Some(v) => Some(v),
                None => match it.peek() {
                    Some(next) if !next.starts_with("--") => it.next(),
                    _ => None,
                },
            };
            let Some(value) = value else {
                if key == "--envs" {
                    // An empty --envs must not silently shrink the grid.
                    a.envs_err = Some("missing value for --envs".into());
                }
                continue; // flag without a value: ignore it
            };
            match key.as_str() {
                "--rounds" => a.rounds = value.parse().ok(),
                "--dataset" => a.dataset = Some(value),
                "--repeats" => a.repeats = value.parse().unwrap_or(1),
                "--threads" => a.threads = value.parse().unwrap_or(0),
                "--trace-out" => a.trace_out = Some(value),
                "--envs" => {
                    // Repeats must error loudly, never last-one-wins: a
                    // second --envs silently shrinking the grid to its
                    // own list is exactly the kind of half-run a figure
                    // pipeline cannot detect.
                    if envs_seen {
                        a.envs_err = Some("--envs given more than once".into());
                    } else {
                        envs_seen = true;
                        match EnvSel::parse_list(&value) {
                            Ok(envs) => a.envs = envs,
                            Err(e) => a.envs_err = Some(e.to_string()),
                        }
                    }
                }
                _ => unreachable!("key list above"),
            }
        }
        a
    }

    /// Whether a bare `--name` flag was passed (e.g. `--grid`).
    pub fn flag(&self, name: &str) -> bool {
        self.raw.iter().any(|s| s == name)
    }

    /// The `--envs` axis, validated: a typo is a hard error, never a
    /// silently smaller grid.
    pub fn validated_envs(&self) -> Result<Vec<EnvSel>> {
        if let Some(e) = &self.envs_err {
            anyhow::bail!("bad --envs value: {e}");
        }
        Ok(self.envs.clone())
    }

    /// Examples whose reporting assumes a fixed grid shape call this to
    /// reject the `--envs` axis up front instead of silently ignoring
    /// it.  A *single* environment still works everywhere through the
    /// `--env.kind=...` dotted override.
    pub fn reject_envs(&self, example: &str) -> Result<()> {
        anyhow::ensure!(
            self.envs.is_empty() && self.envs_err.is_none(),
            "{example} does not take the --envs axis; use fig1_2_baselines or \
             `lroa sweep --envs=...` for environment grids, or a single \
             --env.kind=... override here"
        );
        Ok(())
    }

    /// The datasets this invocation covers.
    pub fn datasets(&self) -> Vec<String> {
        match &self.dataset {
            Some(d) => vec![d.clone()],
            None => vec!["cifar".into(), "femnist".into()],
        }
    }

    /// Build the base config for a dataset under these args.
    ///
    /// Quick scaling: horizon 150 rounds (vs 2000/1000), 50-150 samples
    /// per device (bounds local compute), 512-sample test set, eval every
    /// 10 rounds.  Paper-scale values apply under `--full`.
    pub fn config(&self, dataset: &str) -> Result<Config> {
        let mut cfg = Config::for_dataset(dataset)?;
        if self.quick {
            cfg.train.rounds = 150;
            cfg.train.samples_per_device = (50, 150);
            cfg.train.test_samples = 512;
            cfg.train.eval_every = 10;
            // The paper's budgets are calibrated to its data density
            // (~417 samples/device on CIFAR).  Quick mode shrinks D_n for
            // wall-clock reasons, so scale Ē_n by the same factor to keep
            // the energy constraint (16) binding in the same regime.
            cfg.system.energy_budget_j *= 100.0 / 417.0;
        }
        if let Some(r) = self.rounds {
            cfg.train.rounds = r;
        }
        cfg.apply_cli(&self.raw)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn out_dir(&self, figure: &str) -> PathBuf {
        PathBuf::from("runs").join(figure)
    }

    /// An [`Experiment`] over `spec` under this invocation's conventions:
    /// the quick-mode base-config scaling ([`Args::config`], which also
    /// applies the raw `--section.key=value` overrides), this
    /// invocation's pool width, and per-cell progress lines.  Examples
    /// either `.run()` it directly or layer `.base_with(..)` /
    /// `.observe(..)` on top first.
    pub fn experiment(&self, spec: SweepSpec) -> Experiment<'_> {
        let mut e = Experiment::from_spec(spec)
            .base_with(move |ds| self.config(ds))
            .threads(self.threads)
            .observe(exp::ProgressObserver::new());
        if let Some(dir) = &self.trace_out {
            e = e.trace(crate::trace::TraceConfig::new(dir.clone()));
        }
        e
    }
}

/// Strip scenario results down to their recorders (scenario order kept).
pub fn recorders(results: Vec<ScenarioResult>) -> Vec<Recorder> {
    results.into_iter().map(|r| r.recorder).collect()
}

/// Write each recorder's CSV plus a JSON summary bundle.
pub fn save_all(dir: &Path, recs: &[Recorder]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut summaries = Vec::new();
    for rec in recs {
        rec.write_csv(&dir.join(format!("{}.csv", sanitize(&rec.label))))?;
        summaries.push(rec.summary_json());
    }
    std::fs::write(
        dir.join("summary.json"),
        obj(vec![("runs", Json::Arr(summaries))]).to_string(),
    )?;
    Ok(())
}

fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
        .collect()
}

/// The paper's headline comparison: total modeled latency per policy plus
/// savings of the first row (LROA) against each baseline.
pub fn print_latency_table(recs: &[Recorder]) {
    println!("\n{:<22} {:>14} {:>12} {:>12}", "policy", "total time [s]", "final acc", "vs LROA");
    let t0 = recs.first().map(|r| r.total_time_s()).unwrap_or(f64::NAN);
    for rec in recs {
        let t = rec.total_time_s();
        let savings = if t > 0.0 { (1.0 - t0 / t) * 100.0 } else { f64::NAN };
        println!(
            "{:<22} {:>14.1} {:>12.4} {:>11.1}%",
            rec.label,
            t,
            rec.final_accuracy(),
            savings
        );
    }
    println!();
}

/// Print an accuracy-vs-time/round series in the shape of the paper's
/// figures (one CSV block per curve, on stdout for quick inspection).
pub fn print_series(recs: &[Recorder]) {
    for rec in recs {
        println!("# {}", rec.label);
        println!("round,total_time_s,test_accuracy");
        for r in rec.rounds.iter().filter(|r| !r.test_accuracy.is_nan()) {
            println!("{},{:.3},{:.4}", r.round, r.total_time_s, r.test_accuracy);
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn sanitize_labels() {
        assert_eq!(sanitize("LROA-cifar (k=2)"), "LROA-cifar__k_2_");
    }

    #[test]
    fn two_token_flags_consume_their_value() {
        // Regression: `--rounds 100` used to peek at "100" without
        // consuming it, leaking the bare token into the raw args.
        let a = Args::from_vec(argv(&["--rounds", "100", "--dataset", "femnist"]));
        assert_eq!(a.rounds, Some(100));
        assert_eq!(a.dataset.as_deref(), Some("femnist"));
        assert!(a.raw.is_empty(), "raw leaked: {:?}", a.raw);
    }

    #[test]
    fn two_token_flag_never_swallows_a_following_flag() {
        // `--rounds --grid`: no value for --rounds, and --grid must
        // survive into raw instead of being eaten as the "value".
        let a = Args::from_vec(argv(&["--rounds", "--grid", "--dataset", "cifar"]));
        assert_eq!(a.rounds, None);
        assert!(a.flag("--grid"));
        assert_eq!(a.dataset.as_deref(), Some("cifar"));
    }

    #[test]
    fn inline_flags_and_overrides_coexist() {
        let a = Args::from_vec(argv(&[
            "--rounds=7",
            "--threads=3",
            "--control.mu=10",
            "--grid",
            "--full",
        ]));
        assert_eq!(a.rounds, Some(7));
        assert_eq!(a.threads, 3);
        assert!(!a.quick);
        assert!(a.flag("--grid"));
        assert_eq!(a.raw, argv(&["--control.mu=10", "--grid"]));
        // The surviving raw override reaches the config.
        let cfg = a.config("cifar").unwrap();
        assert_eq!(cfg.control.mu, 10.0);
        assert_eq!(cfg.train.rounds, 7);
    }

    #[test]
    fn quick_config_scales_down() {
        let args = Args::from_vec(vec![]);
        assert!(args.quick);
        let cfg = args.config("cifar").unwrap();
        assert_eq!(cfg.train.rounds, 150);
        assert!(cfg.train.test_samples <= 1024);
        let full = Args::from_vec(argv(&["--full"]));
        assert_eq!(full.config("cifar").unwrap().train.rounds, 2000);
        assert_eq!(full.config("femnist").unwrap().train.rounds, 1000);
    }

    #[test]
    fn envs_flag_parses_lists_and_all() {
        use crate::config::EnvKind;
        let a = Args::from_vec(argv(&["--envs=static,ge"]));
        assert_eq!(
            a.envs,
            vec![EnvSel::from(EnvKind::Static), EnvSel::from(EnvKind::GilbertElliott)]
        );
        assert_eq!(a.validated_envs().unwrap().len(), 2);
        let a = Args::from_vec(argv(&["--envs", "all"]));
        let want: Vec<EnvSel> = EnvKind::SYNTHETIC.iter().map(|&k| k.into()).collect();
        assert_eq!(a.envs, want);
        // Trace entries carry their path through the harness axis.
        let a = Args::from_vec(argv(&["--envs=trace:logs/a.csv,adv"]));
        assert_eq!(a.envs.len(), 2);
        assert_eq!(a.envs[0].trace_path.as_deref(), Some("logs/a.csv"));
        assert!(Args::from_vec(vec![]).envs.is_empty());
    }

    #[test]
    fn repeated_envs_flag_errors_instead_of_last_one_wins() {
        let a = Args::from_vec(argv(&["--envs=static", "--envs=ge"]));
        assert!(a.validated_envs().is_err(), "repeat must be loud");
        assert!(a.reject_envs("fig3").is_err());
        // The two-token form repeats the same way.
        let a = Args::from_vec(argv(&["--envs", "static", "--envs", "ge"]));
        assert!(a.validated_envs().is_err());
        // One combined comma list stays fine.
        let a = Args::from_vec(argv(&["--envs=static,ge"]));
        assert_eq!(a.validated_envs().unwrap().len(), 2);
    }

    #[test]
    fn envs_typo_is_a_hard_error_not_a_smaller_grid() {
        let a = Args::from_vec(argv(&["--envs=static,gee"]));
        assert!(a.envs.is_empty(), "typo must not half-populate the axis");
        assert!(a.validated_envs().is_err());
        assert!(a.reject_envs("fig3").is_err());
    }

    #[test]
    fn trace_out_flag_parses_both_forms() {
        let a = Args::from_vec(argv(&["--trace-out=runs/t", "--rounds=5"]));
        assert_eq!(a.trace_out.as_deref(), Some("runs/t"));
        let a = Args::from_vec(argv(&["--trace-out", "runs/t2"]));
        assert_eq!(a.trace_out.as_deref(), Some("runs/t2"));
        assert!(a.raw.is_empty(), "raw leaked: {:?}", a.raw);
        assert!(Args::from_vec(vec![]).trace_out.is_none());
    }

    #[test]
    fn rounds_override_wins() {
        let args = Args::from_vec(argv(&["--rounds=7", "--dataset=femnist"]));
        assert_eq!(args.config("femnist").unwrap().train.rounds, 7);
        assert_eq!(args.datasets(), vec!["femnist".to_string()]);
        assert_eq!(args.repeats, 1);
    }
}
