//! Minimal JSON parser + serializer.
//!
//! The offline registry carries no `serde`/`serde_json`, and the runtime
//! needs to read `artifacts/manifest.json` (written by the python AOT
//! pass) and emit machine-readable metric dumps.  This is a small,
//! dependency-free recursive-descent implementation covering the full
//! JSON grammar (RFC 8259) minus surrogate-pair escapes, which the
//! manifest never contains.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style path lookup.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{item}")?;
                }
                f.write_str("]")
            }
            Json::Obj(map) => {
                f.write_str("{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

/// Convenience builder for metric dumps.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// f64 array -> Json.
pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\\nthere\"").unwrap(),
            Json::Str("hi\nthere".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.path(&["d", "e"]), Some(&Json::Null));
    }

    #[test]
    fn parses_unicode_escapes_and_utf8() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse("\"é\"").unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\":}", "nul", "1 2", "\"unterminated"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"x"],"num":-7,"obj":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "format": "hlo-text",
          "variants": {
            "femnist": {
              "dim": 111902, "model_bits": 3580864,
              "input_hw": [28, 28], "input_c": 1, "num_classes": 62,
              "train_batch": 32, "eval_batch": 64, "k_max": 8,
              "layers": [{"name": "conv0_w", "shape": [25, 8], "size": 200}],
              "artifacts": ["init", "train_step"]
            }
          }
        }"#;
        let v = Json::parse(src).unwrap();
        let fem = v.path(&["variants", "femnist"]).unwrap();
        assert_eq!(fem.get("dim").unwrap().as_usize(), Some(111902));
        assert_eq!(
            fem.get("input_hw").unwrap().as_arr().unwrap()[0].as_usize(),
            Some(28)
        );
    }

    #[test]
    fn display_escapes_control_chars() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(v.to_string(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }
}
