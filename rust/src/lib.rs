//! # LROA — Lyapunov-based Resource-efficient Online Algorithm for Federated Edge Learning
//!
//! Production-grade reproduction of *"Online Client Scheduling and Resource
//! Allocation for Efficient Federated Edge Learning"* (Gao et al., 2024).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer rust + JAX +
//! Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): tiled matmul with
//!   fused bias + activation, fused SGD-momentum update, weighted client-delta
//!   aggregation. Authored in python, lowered at build time.
//! * **L2** — JAX model (`python/compile/model.py`): CNN forward/backward and
//!   the federated train/eval/aggregate steps, lowered once by
//!   `python/compile/aot.py` to HLO text under `artifacts/`.
//! * **L3** — this crate: the FL server (round orchestration, client
//!   sampling, virtual energy queues, and the online control policy from the
//!   paper), a mobile-edge system simulator (channels, device heterogeneity,
//!   latency/energy models) and a PJRT runtime that loads and executes the
//!   AOT artifacts. Python is never on the request path.
//!
//! ## Module map
//!
//! | module | role |
//! |--------|------|
//! | [`rng`] | deterministic PRNG + distributions (offline substrate, no `rand`) |
//! | [`json`] | minimal JSON parser/serializer for manifests + metrics |
//! | [`config`] | experiment configuration (file + CLI overrides) |
//! | [`system`] | device fleet, wireless channel model, latency/energy (eqs. 5–17) |
//! | [`env`] | dynamic edge environments: Markov fading, availability, compute drift, trace replay, adversarial channel, composites (`compose:<a>+<b>` with scenario generators + correlated shadowing), measurement-log import (name → ctor registry; `peek`/`observe_selection` hooks) |
//! | [`control`] | the paper's contribution: queues, Theorems 2–3, SUM, Algorithm 2 |
//! | [`control::policy`] | the [`control::RoundPolicy`] trait, scheme impls, name → ctor registry |
//! | [`sampling`] | client samplers: LROA adaptive, uniform, DivFL |
//! | [`data`] | synthetic non-IID federated datasets (Dirichlet / writer partitions) |
//! | [`runtime`] | PJRT client, artifact manifest, typed executables |
//! | [`fl`] | federated training loop: staged server pipeline, local trainer, evaluator |
//! | [`par`] | deterministic scoped-thread fan-out (client training, scenario pool) |
//! | [`exp`] | declarative scenario sweeps: grid expansion, seed stats, oracle-regret grids |
//! | [`exp::session`] | the embeddable [`exp::Experiment`] builder → [`exp::Session`] engine behind `lroa sweep`/`regret`, the harness, and the examples |
//! | [`exp::observer`] | streaming [`exp::Observer`] sinks: cell CSVs + resume sidecars, manifest, summary.json, progress, `--json` |
//! | [`harness`] | figure-example CLI + reporting glue on top of `exp` |
//! | [`metrics`] | run recorder, CSV emission, summaries |
//! | [`bench`] | self-contained timing harness used by `cargo bench` |
//! | [`trace`] | zero-dependency structured tracing: session → cell → round → phase spans, determinism-safe (`--trace-out`) |
//! | [`trace::hub`] | per-cell lock-free span recording ([`trace::CellTrace`]) merged through the sharded [`trace::TraceHub`]; flight-recorder crash dumps |
//! | [`trace::chrome`] | Chrome trace-event JSON exporter (Perfetto / `chrome://tracing` loadable `trace.json`) |
//! | [`trace::summary`] | per-phase min/p50/p95/max + counter aggregation (`trace_summary.json`, `lroa trace summarize`) |

pub mod bench;
pub mod config;
pub mod harness;
pub mod control;
pub mod data;
pub mod env;
pub mod exp;
pub mod fl;
pub mod json;
pub mod metrics;
pub mod par;
pub mod rng;
pub mod runtime;
pub mod sampling;
pub mod system;
pub mod trace;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Marker for *usage* errors — malformed flags, unknown subcommands,
/// unparseable values — as opposed to runtime/config failures.
///
/// The CLI's exit-code contract (documented under `lroa help`, pinned by
/// `tests/cli_exit_codes.rs`): `0` success, `1` runtime or configuration
/// error (e.g. a missing trace file, a config that fails validation),
/// `2` usage error.  `main` downcasts the error chain for this type to
/// pick the exit code, so any layer can classify an error as misuse by
/// constructing it through [`usage_error`].
#[derive(Debug)]
pub struct UsageError(pub String);

impl std::fmt::Display for UsageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for UsageError {}

/// Build a usage error (CLI exit code 2); interchangeable with
/// `anyhow::anyhow!` at every call site that reports misuse.
pub fn usage_error(msg: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(UsageError(msg.into()))
}

/// Whether any link of `err`'s chain is a [`UsageError`].
pub fn is_usage_error(err: &anyhow::Error) -> bool {
    err.chain().any(|e| e.is::<UsageError>())
}

/// Shared helpers for in-crate unit tests.  The single source of truth
/// is `tests/common.rs` — the integration-test targets pull it in as
/// `mod common;` and the library includes the same file here (they
/// cannot see each other's items), so the fixture paths can never drift
/// between the two test surfaces.
#[cfg(test)]
#[path = "../../tests/common.rs"]
pub(crate) mod test_util;
