//! `lroa` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!
//! * `train`  — run one federated training (full stack through PJRT);
//! * `sim`    — control-plane-only simulation (no artifacts needed);
//! * `sweep`  — run a policy × env × K × µ/ν × seed × dataset grid in parallel;
//! * `regret` — a sweep where every cell is shadowed by the clairvoyant
//!   oracle on the same environment stream (populates the `regret` column);
//! * `bench`  — the criterion-free round-path benchmark with a JSON
//!   emitter and a regression gate (CI's perf trajectory);
//! * `scale`  — the fleet-scale harness: one LROA cell per fleet size
//!   through the full sweep pipeline, emitting the N-vs-round-time
//!   scaling curve (`scaling.json`) plus peak-RSS evidence;
//! * `trace`  — summarize structured traces written by `--trace-out`
//!   (see [`lroa::trace`]), or import an external measurement CSV into
//!   the replay schema (`trace import`, see [`lroa::env::import`]);
//! * `info`   — inspect artifacts, fleet, and the λ/V estimates;
//! * `help`   — this text.
//!
//! Exit codes: `0` success, `1` runtime/configuration error, `2` usage
//! error (unknown subcommand or malformed flags) — pinned by
//! `tests/cli_exit_codes.rs`.
//!
//! Every config knob is overridable as `--section.key=value` (see
//! `config.rs`), e.g.:
//!
//! ```text
//! lroa train  --train.dataset=femnist --train.rounds=200 --control.mu=10
//! lroa sim    --train.policy=uni-s --system.k=4 --train.rounds=1000
//! lroa sweep  --policies=all --ks=2,4,6 --seeds=1..5 --rounds=200
//! lroa regret --envs=trace:tests/fixtures/campus.csv,adv --policies=lroa,greedy,p2c
//! lroa bench  --json --quick --baseline=BENCH_baseline.json
//! ```

use std::path::Path;

use lroa::config::Config;
use lroa::exp::{self, Experiment, SweepSpec};
use lroa::fl::{Server, SimMode};
use lroa::json::{obj, Json};
use lroa::runtime::Manifest;

const HELP: &str = "\
lroa — Lyapunov-based online client scheduling for federated edge learning

USAGE:
    lroa <train|sim|info> [--config FILE] [--section.key=value ...]
    lroa <sweep|regret> [--key=value ...] [--section.key=value ...]
    lroa bench [--json] [--quick] [--out=FILE] [--baseline=FILE] [--max-regress=F]
    lroa scale [--ns=N1,N2,...] [--rounds=R] [--out=DIR] [--json]
    lroa trace summarize [DIR | --dir=DIR]
    lroa trace import <csv> --out=FILE [--round-col=N --device-col=N --gain-col=N
                      --avail-col=N --gain-scale=F --gain-db --round-per=F --json]

SUBCOMMANDS:
    train   full federated training through the AOT artifacts
    sim     control-plane-only simulation (latency/energy/queues)
    sweep   parallel scenario grid; seed repeats aggregate to mean±std,
            manifest.json documents every cell for the figure pipeline
    regret  sweep + two clairvoyant anchors per environment stream: the
            budget-blind latency floor (oracle) and the budget-feasible
            oracle-e (Theorem 2/3 kernels under queue prices); cell CSVs
            gain populated `regret`, `regret_online`, `regret_budget`
            columns with regret_online + regret_budget == regret bitwise,
            and manifest cells link to their anchors via `regret_vs` /
            `regret_vs_e`
    bench   time the round path (control-plane rounds per policy, plus a
            warm-vs-cold round/LROA pair, kernel/lroa-solve rows at
            N=120/10k/100k, alloc-free kernel/env-step rows at
            N=10k/100k/1M, and the 1M-device round/LROA@1M fleet-scale
            row); --json emits a machine-readable report, --out writes
            it to a file, --baseline gates against a committed report
            (fails when round_total — the sum of the paper-scale
            round/* medians, '@'-scale rows excluded — regresses more
            than --max-regress, default 0.25)
    scale   fleet-scale harness: one LROA control-plane cell per fleet
            size (--ns=10000,100000,1000000 default, --rounds=3 default)
            through the same Experiment pipeline as `sweep` (per-N
            manifest.json + cell CSV under --out/n<N>/), then writes the
            N-vs-round-time curve with peak-RSS evidence to
            --out/scaling.json (schema lroa-scale-v1); --json mirrors
            that object to stdout; at N >= 1e6 the q_min floor is
            auto-lowered to stay inside the q_min < 1/N validation bound
    trace   inspect structured traces, or import measurement logs:
            `trace summarize [--dir=DIR]` prints the per-cell phase-timing
            table (env_step/solve/train/aggregate/observe min/p50/p95/max
            plus solver counters) from a --trace-out run's
            trace_summary.json; load the sibling trace.json in Perfetto or
            chrome://tracing for the timeline.
            `trace import <csv> --out=FILE` converts an external
            measurement CSV into the replay schema (tests/fixtures/
            README.md) so it runs under --envs=trace:FILE: --round-col/
            --device-col/--gain-col/--avail-col map source columns by
            header name (device keys may be any string; tracks are
            renumbered from 0), --gain-db converts dB to linear, then
            --gain-scale multiplies, --round-per=F bins raw timestamps
            into rounds of width F (same-bin samples aggregate: mean
            gain, AND availability), rows with an empty gain keep their
            availability and get a linearly interpolated gain, and the
            output is verified against the replay parser before writing;
            --json emits a one-object import report on stdout
    info    print artifact manifest, fleet summary, λ/V estimates

SWEEP / REGRET FLAGS (all --key=value unless noted):
    --policies=lroa,uni-d,uni-s,divfl,greedy,rr,p2c,bandit,thompson,linucb,conv-aware|all
    --datasets=cifar,femnist
    --budget_spreads=0,0.3,0.6  (system.budget_spread heterogeneity axis)
    --envs=static,ge,avail,drift,adv,trace:<log.csv>,compose:<spec>|all  (below)
    --ks=2,4,6       --mus=0.1,1,10          --nus=1e4,1e5,1e6
    --seeds=1..30    --rounds=N              --threads=T (0 = cores)
    --cell_timeout_s=F (per-cell wall-clock budget; exceeding fails loudly)
    --mode=sim|train                         --out=DIR
    --trace-out=DIR  (record a structured trace: trace.json — Chrome
                      trace-event JSON, loadable in Perfetto — plus
                      trace_summary.json per-cell phase timings, and a
                      <cell>.crash-trace.json flight-recorder dump if a
                      cell fails; CSV/summary/manifest bytes are identical
                      with tracing on or off)
    --resume         (sweep only, bare flag: skip cells whose CSV already
                      exists in --out; skipped cells are re-read so
                      summary.json still aggregates the full grid)
    --json           (bare flag: stdout carries exactly one JSON object —
                      the seed-aggregated grid summary, same group fields
                      as summary.json — and all human output moves to
                      stderr; the machine-readable sibling of the table)

ENVIRONMENTS (the --envs axis / --env.kind override):
    static  the paper's IID exponential channel, always-on fleet (default)
    ge      Gilbert-Elliott two-state Markov fading per device
    avail   Markov device dropout/arrival (candidate set varies per round)
    drift   random-walk drift on per-device compute/energy parameters
    trace   replay of a recorded channel/availability CSV; on the --envs
            axis write trace:<path>, standalone use --env.trace_path=FILE
            (schema: round,device,gain[,available] — tests/fixtures/README.md)
    adv     adversarial channel: degrades last round's selection and the
            gains a greedy scheduler would chase (--env.adv_degrade,
            --env.adv_targets)
    compose composite of several mechanisms in one round process: on the
            --envs axis write compose:<a>+<b>+... over children
            static|ge|avail|drift|trace|adv plus the composite-only
            scenario generators diurnal (time-of-day availability cycles),
            flashcrowd (synchronized join bursts), outage (correlated
            regional failures); standalone use --env.kind=compose with
            --env.compose=SPEC.  Merge semantics: availability is the AND
            of the children (with the K-floor repair applied once at the
            end), gains come from the channel-owning child (ge > trace >
            adv > first other) with adv degradation applied to the merged
            vector, drift overlays f_max/alpha, and an optional correlated
            log-normal shadow field multiplies the result
            (--env.shadow_std > 0 turns it on, --env.shadow_rho sets the
            common-vs-private weight).  Named presets expand as
            compose:diurnal = diurnal+ge, compose:flashcrowd =
            flashcrowd+ge, compose:outage = outage+ge+drift.
            `all` expands to every env except trace and compose

POLICIES: lroa uni-d uni-s divfl greedy rr p2c bandit thompson linucb
          conv-aware oracle oracle-e
    bandit     = contextual UCB scheduler: per-device context (gain EMA,
                 availability streak, queue backlog) -> exact softmax
                 sampling marginals, so eq. (4) stays unbiased
                 (knobs: --bandit.ucb_c/temp/eps/gain_ema/ctx_weight)
    thompson   = Gaussian Thompson sampling over the same context:
                 per-device posterior draws -> exact softmax marginals,
                 deterministic given the seed (policy-owned posterior RNG)
                 (knobs: --thompson.prior_std/temp/eps/gain_ema)
    linucb     = ridge-regression contextual UCB over the shared context
                 vector; one d x d design matrix in inverse form with
                 Sherman-Morrison rank-1 updates — O(N d^2) per round, no
                 per-round allocation
                 (knobs: --linucb.alpha/ridge/temp/eps/gain_ema)
    conv-aware = convergence-aware selection (staleness x last-update-norm
                 EMA, Full mode feeds update norms; cold start is uniform)
    oracle     = clairvoyant latency lower bound (budget-blind)
    oracle-e   = clairvoyant AND energy-budget-feasible anchor
    (`regret` adds both anchors automatically — do not list them
     under --policies there)

COMMON OVERRIDES:
    --train.dataset=cifar|femnist   --train.rounds=N     --train.policy=lroa|...|bandit
    --system.k=K                    --control.mu=F       --control.nu=F
    --control.warm_start=true|false (default true: Algorithm 2 resumes from
                                     the previous round's fixed point; false
                                     restores the paper's cold midpoint init)
    --train.seed=N        --env.kind=static|ge|avail|drift|trace|adv|compose
    --env.ge_p_bad=F --env.avail_p_drop=F --env.drift_sigma=F   (see config.rs)
    --env.trace_path=FILE --env.adv_degrade=F --env.adv_targets=N
    --env.compose=SPEC    (composite child list `avail+ge+drift` or preset
                           diurnal|flashcrowd|outage; compose kind only)
    --env.shadow_std=F    (correlated shadow fading on composite gains:
                           log-space std, 0 = off bitwise)
    --env.shadow_rho=F    (shadow correlation in [0,1]: weight of the
                           fleet-common component vs per-device)
    --bandit.ucb_c=F --bandit.temp=F --bandit.eps=F     (bandit policy only)
    --thompson.prior_std=F --thompson.temp=F --thompson.eps=F  (thompson only)
    --linucb.alpha=F --linucb.ridge=F --linucb.temp=F   (linucb only)
    --system.budget_spread=F  (per-device energy-budget jitter in [0,1):
                               budget_i = Ē·(1 ± spread·U); 0 restores the
                               paper's homogeneous fleet bitwise)
    --control.cost_weight=F   (drift-plus-penalty reprice: queues charge
                               V·w·E_total on top of latency — 0 restores
                               the paper objective bitwise; lroa/uni-d/
                               oracle-e only)
    --control.queue_gate_offline=true|false (default true: virtual queues
                               advance only over the round's candidate set,
                               so offline devices cannot launder budget
                               debt during outages; false restores the
                               pre-fix semantics bitwise)
    --run.out_dir=DIR               --run.artifacts_dir=DIR

EXIT CODES:
    0  success
    1  runtime or configuration error (missing trace file, failed
       validation such as --system.num_devices=0, cell timeout, ...)
    2  usage error (unknown subcommand, malformed or unknown flags)
";

fn build_config(args: &[String]) -> lroa::Result<Config> {
    // Optional --config FILE first, then dotted overrides.
    let mut cfg = None;
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if a == "--config" {
            if let Some(path) = it.next() {
                cfg = Some(Config::from_file(Path::new(path))?);
            }
        } else if let Some(path) = a.strip_prefix("--config=") {
            cfg = Some(Config::from_file(Path::new(path))?);
        }
    }
    let mut cfg = match cfg {
        Some(c) => c,
        None => {
            // Respect --train.dataset before defaults resolve.
            let ds = args
                .iter()
                .find_map(|a| a.strip_prefix("--train.dataset="))
                .unwrap_or("cifar");
            Config::for_dataset(ds)?
        }
    };
    cfg.apply_cli(args)?;
    cfg.validate()?;
    Ok(cfg)
}

fn run(mode: SimMode, args: &[String]) -> lroa::Result<()> {
    let cfg = build_config(args)?;
    println!("{}", cfg.dump());
    let out_dir = std::path::PathBuf::from(&cfg.out_dir).join("cli");
    let mut server = Server::new(cfg, mode)?;
    println!("lambda = {:.4e}, V = {:.4e}", server.lambda, server.v);
    // Server::run is itself a thin loop over the step-wise RoundDriver.
    server.run()?;
    let rec = &server.recorder;
    println!(
        "done: {} rounds, modeled total {:.1}s, final accuracy {:.4}",
        rec.rounds.len(),
        rec.total_time_s(),
        rec.final_accuracy()
    );
    std::fs::create_dir_all(&out_dir)?;
    let csv = out_dir.join(format!("{}.csv", rec.label));
    rec.write_csv(&csv)?;
    println!("wrote {}", csv.display());
    Ok(())
}

/// Human chrome goes to stdout normally, to stderr when `--json` owns
/// stdout (which must then carry exactly one JSON object).
fn say(json_out: bool, line: &str) {
    if json_out {
        eprintln!("{line}");
    } else {
        println!("{line}");
    }
}

/// The `lroa sweep`/`lroa regret` observer stack: the manifest lands at
/// grid start (before any cell runs, so crashed or resumed grids still
/// document themselves), each cell's CSV + resume sidecar streams out as
/// it completes, and summary.json aggregates the full grid at the end —
/// each sink one observer.
fn attach_cli_observers<'a>(
    experiment: Experiment<'a>,
    dir: &std::path::Path,
    json_out: bool,
    rewrite_final: bool,
) -> Experiment<'a> {
    let csv = if rewrite_final {
        exp::CsvObserver::new(dir).rewrite_final()
    } else {
        exp::CsvObserver::new(dir)
    };
    let mut experiment = experiment
        .out_dir(dir)
        .observe(csv)
        .observe(exp::SummaryObserver::new(dir));
    if json_out {
        experiment = experiment
            .observe(exp::ManifestObserver::new(dir).quiet())
            .observe(exp::ProgressObserver::new().quiet())
            .observe(exp::JsonObserver::new());
    } else {
        experiment = experiment
            .observe(exp::ManifestObserver::new(dir))
            .observe(exp::ProgressObserver::new());
    }
    experiment
}

fn sweep(args: &[String]) -> lroa::Result<()> {
    let spec = SweepSpec::from_cli(args)?;
    let json_out = spec.json;
    let threads = spec.threads;
    let dir = std::path::PathBuf::from(&spec.out_dir);

    let experiment = attach_cli_observers(Experiment::from_spec(spec), &dir, json_out, false);
    let session = experiment.build()?;
    say(
        json_out,
        &format!(
            "sweep: {} scenarios ({} groups), pool width {}",
            session.cells().len(),
            session
                .cells()
                .iter()
                .map(|s| s.group.as_str())
                .collect::<std::collections::BTreeSet<_>>()
                .len(),
            if threads == 0 { "auto".to_string() } else { threads.to_string() },
        ),
    );

    let report = session.run()?;
    if report.resumed_cells > 0 {
        say(
            json_out,
            &format!(
                "note: {} resumed cells were aggregated from their CSVs; \
                 summary.json covers the full {}-cell grid",
                report.resumed_cells,
                report.results.len()
            ),
        );
    }
    if !json_out {
        print_group_table(&report.groups, false);
    }
    say(json_out, &format!("\nCSV + summary.json under {}", dir.display()));
    Ok(())
}

/// The mean±std table the paper's seed-averaged figures report.
fn print_group_table(groups: &[exp::GroupSummary], with_regret: bool) {
    if with_regret {
        println!(
            "\n{:<28} {:>5} {:>22} {:>20} {:>20} {:>20}",
            "group", "runs", "total time [s]", "regret [s]", "online [s]", "budget [s]"
        );
        for g in groups {
            println!(
                "{:<28} {:>5} {:>22} {:>20} {:>20} {:>20}",
                g.group,
                g.runs,
                g.total_time_s.to_string(),
                g.final_regret.to_string(),
                g.final_regret_online.to_string(),
                g.final_regret_budget.to_string(),
            );
        }
    } else {
        println!(
            "\n{:<28} {:>5} {:>24} {:>20} {:>24}",
            "group", "runs", "total time [s]", "final acc", "time-avg energy [J]"
        );
        for g in groups {
            println!(
                "{:<28} {:>5} {:>24} {:>20} {:>24}",
                g.group,
                g.runs,
                g.total_time_s.to_string(),
                g.final_accuracy.to_string(),
                g.time_avg_energy.to_string(),
            );
        }
    }
}

/// `lroa regret`: a sweep where every online cell is shadowed by the
/// clairvoyant oracle on the same environment stream, and the `regret`
/// column lands in every cell CSV, summary.json, and the manifest.
fn regret(args: &[String]) -> lroa::Result<()> {
    let mut spec = SweepSpec::from_cli(args)?;
    anyhow::ensure!(
        !spec.resume,
        "regret: --resume is not supported (the regret column is computed \
         across the whole grid in one invocation)"
    );
    if !args.iter().any(|a| a.starts_with("--out=")) {
        spec.out_dir = "runs/regret".into();
    }
    let json_out = spec.json;
    let threads = spec.threads;
    let dir = std::path::PathBuf::from(&spec.out_dir);

    // Same Experiment pipeline as `sweep`, plus the two clairvoyant
    // anchors per environment stream.  Cells stream *raw* CSVs as they
    // complete (decomposition columns still empty), so a crashed or
    // timed-out grid keeps every finished cell's evidence; the
    // `rewrite_final` pass lands the populated columns once the whole
    // grid is in, so a *completed* run never ships a CSV without them.
    let experiment = attach_cli_observers(
        Experiment::from_spec(spec).anchors(exp::Anchors::Both),
        &dir,
        json_out,
        true,
    );
    let session = experiment.build()?;
    say(
        json_out,
        &format!(
            "regret: {} cells ({} oracle + oracle-e anchors), pool width {}",
            session.cells().len(),
            session
                .cells()
                .iter()
                .filter(|s| exp::regret::is_anchor(s.cfg.train.policy))
                .count(),
            if threads == 0 { "auto".to_string() } else { threads.to_string() },
        ),
    );

    let report = session.run()?;
    if !json_out {
        print_group_table(&report.groups, true);
    }

    let min_regret = exp::regret::min_final_regret(&report.results);
    let check = format!(
        "\noracle lower-bound check: min final regret across online cells = {min_regret:.4}"
    );
    say(json_out, &check);
    if min_regret < -1e-9 {
        say(
            json_out,
            "warning: a cell finished faster than its oracle anchor — only \
             possible under the adaptive `adv` environment, where the \
             anchor faces its own adversary stream",
        );
    }
    say(json_out, &format!("\nCSV + summary.json under {}", dir.display()));
    Ok(())
}

/// `lroa bench`: the criterion-free round-path benchmark with a JSON
/// report and a regression gate.
///
/// Cases are one full control-plane round (environment draw + control
/// solve + sampling + queues + metrics) per headline policy at paper
/// scale (N = 120), plus sub-round sampling/bandit kernels.
/// `round_total` — the sum of the per-policy `round/*` medians (kernel
/// rows are reported but not gated) — is the gated headline: with
/// `--baseline=FILE`, the run fails when it regresses more than
/// `--max-regress` (default 0.25) over the committed report, which is
/// how CI holds the perf trajectory.
fn bench_cmd(args: &[String]) -> lroa::Result<()> {
    use lroa::config::Policy;

    let mut json_out = false;
    let mut quick = false;
    let mut out_path: Option<String> = None;
    let mut baseline: Option<String> = None;
    let mut max_regress = 0.25f64;
    for a in args {
        match a.as_str() {
            "--json" => json_out = true,
            "--quick" => quick = true,
            _ => {
                if let Some(v) = a.strip_prefix("--out=") {
                    out_path = Some(v.to_string());
                } else if let Some(v) = a.strip_prefix("--baseline=") {
                    baseline = Some(v.to_string());
                } else if let Some(v) = a.strip_prefix("--max-regress=") {
                    max_regress = v.parse().map_err(|e| {
                        lroa::usage_error(format!("bad --max-regress value {v:?}: {e}"))
                    })?;
                    if max_regress <= 0.0 {
                        return Err(lroa::usage_error("--max-regress must be > 0"));
                    }
                } else {
                    return Err(lroa::usage_error(format!(
                        "bench: unknown argument {a:?} \
                         (--json --quick --out=FILE --baseline=FILE --max-regress=F)"
                    )));
                }
            }
        }
    }

    let mut b = if quick {
        lroa::bench::Bencher::quick()
    } else {
        lroa::bench::Bencher::new()
    };
    // The policies whose round paths CI tracks: the paper's solver (the
    // hot path), the cheapest closed-form baseline, a deterministic
    // selector, and the learning bandit — four control-plane profiles.
    for policy in [
        Policy::Lroa,
        Policy::UniformStatic,
        Policy::GreedyChannel,
        Policy::Bandit,
    ] {
        let mut cfg = Config::for_dataset("cifar")?;
        cfg.train.policy = policy;
        cfg.train.rounds = 1_000_000; // never reached; rounds driven manually
        let mut server = Server::new(cfg, SimMode::ControlPlaneOnly)?;
        let mut t = 0usize;
        b.bench(&format!("round/{policy}"), || {
            server.round(t).unwrap();
            t += 1;
        });
    }

    // The same LROA round path with warm starts disabled: the report
    // carries both sides of the warm-vs-cold comparison so the win is
    // measured per commit, not asserted once.
    {
        let mut cfg = Config::for_dataset("cifar")?;
        cfg.train.policy = Policy::Lroa;
        cfg.train.rounds = 1_000_000;
        cfg.control.warm_start = false;
        let mut server = Server::new(cfg, SimMode::ControlPlaneOnly)?;
        let mut t = 0usize;
        b.bench("round/LROA-cold", || {
            server.round(t).unwrap();
            t += 1;
        });
    }

    // The fleet-scale headline: a full 1M-device LROA control-plane
    // round (SoA env step, incremental top-K-free solver path, in-place
    // cost columns).  The default q_min floor sits exactly at 1/N for
    // N = 1e6, so it is lowered to stay inside the q_min < 1/N
    // validation bound.  Reported, but excluded from the gated
    // round_total (the '@' in the name marks off-paper-scale rows).
    {
        let mut cfg = Config::for_dataset("cifar")?;
        cfg.train.policy = Policy::Lroa;
        cfg.train.rounds = 1_000_000;
        cfg.system.num_devices = 1_000_000;
        cfg.control.q_min = 1e-9;
        let mut server = Server::new(cfg, SimMode::ControlPlaneOnly)?;
        let mut t = 0usize;
        b.bench("round/LROA@1M", || {
            server.round(t).unwrap();
            t += 1;
        });
    }

    // The SoA environment step isolated from the round loop: refill the
    // persistent EnvSoA from the static channel at three fleet scales —
    // the alloc-free stage-1 kernel.  Not part of the gated round_total.
    for n in [10_000usize, 100_000, 1_000_000] {
        use lroa::config::{EnvConfig, EnvKind, SystemConfig};
        use lroa::env::{self, EnvSoA};
        let sys = SystemConfig {
            num_devices: n,
            ..SystemConfig::default()
        };
        let env_cfg = EnvConfig::default();
        let mut env = env::build(
            EnvKind::Static,
            &env::EnvInit {
                sys: &sys,
                env: &env_cfg,
                seed: 13,
            },
        )?;
        let base: Vec<lroa::system::Device> = Vec::new();
        let mut soa = EnvSoA::new();
        b.bench(&format!("kernel/env-step/N={n}"), || {
            env.step_into(&base, &mut soa);
        });
    }

    // The composite step at the same scales: the default avail+ge+drift
    // stack with shadowing on — one channel draw plus the availability
    // AND, the drift overlay, and the shadow field, all alloc-free.  The
    // drift child reads base devices, so this row steps a generated
    // fleet.  Not part of the gated round_total.
    for n in [10_000usize, 100_000] {
        use lroa::config::{EnvConfig, EnvKind, SystemConfig};
        use lroa::env::{self, EnvSoA};
        let sys = SystemConfig {
            num_devices: n,
            ..SystemConfig::default()
        };
        let env_cfg = EnvConfig {
            shadow_std: 0.3,
            ..EnvConfig::default()
        };
        let mut env = env::build(
            EnvKind::Composite,
            &env::EnvInit {
                sys: &sys,
                env: &env_cfg,
                seed: 13,
            },
        )?;
        let mut rng = lroa::rng::Rng::new(13);
        let fleet = lroa::system::Fleet::generate(&sys, (50, 400), &mut rng);
        let mut soa = EnvSoA::new();
        b.bench(&format!("kernel/env-step-composite/N={n}"), || {
            env.step_into(&fleet.devices, &mut soa);
        });
    }

    // The Algorithm 2 solve isolated from the round loop, at three
    // fleet scales — the allocation-free SoA port's hot kernel.  Warm
    // starts engage after the first call, so these rows time the
    // steady-state per-round cost.  Not part of the gated round_total.
    for n in [120usize, 10_000, 100_000] {
        use lroa::config::{ControlConfig, SystemConfig};
        use lroa::system::Fleet;
        let sys = SystemConfig {
            num_devices: n,
            ..SystemConfig::default()
        };
        let mut rng = lroa::rng::Rng::new(13);
        let fleet = Fleet::generate(&sys, (50, 400), &mut rng);
        let h: Vec<f64> = (0..n).map(|_| rng.range(0.01, 0.5)).collect();
        let queues: Vec<f64> = (0..n).map(|_| rng.range(0.0, 20.0)).collect();
        let mut solver = lroa::control::LroaSolver::new(
            sys,
            ControlConfig::default(),
            10.0,          // lambda
            1e4,           // V
            32.0 * 140_000.0,
        );
        b.bench(&format!("kernel/lroa-solve/N={n}"), || {
            solver.solve_round(&fleet.devices, fleet.weights(), &h, &queues)
        });
    }

    // Sub-round kernels (ROADMAP perf-trajectory item: report beyond
    // whole control-plane rounds).  Not part of the gated round_total.
    {
        let n = 120usize;
        let mut rng = lroa::rng::Rng::new(7);
        let scores: Vec<f64> = (0..n).map(|_| rng.range(0.0, 1.0)).collect();
        let q = lroa::sampling::softmax_distribution(&scores, 0.25, 0.05);
        let weights = vec![1.0 / n as f64; n];
        b.bench("kernel/sample-with-replacement/N=120/K=2", || {
            lroa::sampling::sample_by_probability(&q, &weights, 2, &mut rng)
        });
        b.bench("kernel/p2c-marginals/N=120", || {
            lroa::sampling::p2c_marginals(&scores)
        });
        b.bench("kernel/bandit-distribution/N=120", || {
            lroa::sampling::softmax_distribution(&scores, 0.25, 0.05)
        });
    }

    // The learned-scheduler kernels, through the registry-built policies
    // so the rows time what the server actually dispatches: one Thompson
    // posterior draw + marginal computation over the fleet, and one
    // LinUCB Sherman–Morrison design-matrix update for a K-selection.
    // Not part of the gated round_total.
    {
        use lroa::control::{policy, PolicyInit, RoundContext};
        use lroa::system::{Fleet, RoundCosts};
        let cfg = Config::for_dataset("cifar")?;
        let mut rng = lroa::rng::Rng::new(21);
        let fleet = Fleet::generate(&cfg.system, (50, 400), &mut rng);
        let n = fleet.devices.len();
        let h: Vec<f64> = (0..n).map(|_| rng.range(0.01, 0.5)).collect();
        let backlogs: Vec<f64> = (0..n).map(|_| rng.range(0.0, 20.0)).collect();
        let ids: Vec<usize> = (0..n).collect();
        let init = PolicyInit {
            sys: &cfg.system,
            ctl: &cfg.control,
            bandit: cfg.bandit.clone(),
            thompson: cfg.thompson.clone(),
            linucb: cfg.linucb.clone(),
            lambda: 10.0,
            v: 1e4,
            model_bits: 32.0 * 140_000.0,
            seed: 21,
        };
        let ctx = RoundContext {
            t: 0,
            k: cfg.system.k,
            devices: &fleet.devices,
            weights: fleet.weights(),
            ids: &ids,
            h: &h,
            backlogs: &backlogs,
            next_h: None,
        };
        let mut thompson = policy::from_name("thompson", &init)?;
        b.bench(&format!("kernel/thompson-draw/N={n}"), || {
            thompson.plan(&ctx, &mut rng)
        });
        let mut linucb = policy::from_name("linucb", &init)?;
        // One plan to latch the round's context vectors, then the row
        // times the pure observe path: reward + rank-1 inverse update.
        let _ = linucb.plan(&ctx, &mut rng);
        let selected: Vec<usize> = (0..cfg.system.k).collect();
        let costs = RoundCosts {
            time_s: (0..n).map(|i| 0.5 + 0.01 * i as f64).collect(),
            energy_j: vec![0.1; n],
            ..RoundCosts::default()
        };
        b.bench(&format!("kernel/linucb-update/N={n}"), || {
            linucb.observe_round(&selected, &costs)
        });
    }

    // The trace-recording fast path: one phase span into an owned
    // CellTrace ring — the per-phase overhead `--trace-out` adds to a
    // cell (two clock reads + a VecDeque push; no locks, no I/O).
    {
        use lroa::trace::{Counters, Phase, TraceConfig, TraceHub};
        let hub = TraceHub::new(TraceConfig::new(std::env::temp_dir().join("lroa-bench-trace")));
        let tid = hub.register_thread();
        let mut ct = hub.cell(0, "bench", tid);
        let mut round = 0usize;
        b.bench("kernel/trace-phase-record", || {
            let now = std::time::Instant::now();
            ct.phase(round, Phase::Solve, now, now, Counters::default());
            round += 1;
        });
    }

    let samples: Vec<(&str, Json)> = b
        .results()
        .iter()
        .map(|s| {
            (
                s.name.as_str(),
                obj(vec![
                    ("median_ns", Json::Num(s.median.as_nanos() as f64)),
                    ("p10_ns", Json::Num(s.p10.as_nanos() as f64)),
                    ("p90_ns", Json::Num(s.p90.as_nanos() as f64)),
                    ("iters", Json::Num(s.iters as f64)),
                ]),
            )
        })
        .collect();
    // The gated headline sums only the paper-scale whole-round cases:
    // kernel rows inform the report without moving the regression gate,
    // and '@'-marked fleet-scale rows (round/LROA@1M is ~3 orders of
    // magnitude above the N=120 rounds) stay out so they cannot swamp
    // the paper-scale signal.
    let round_total_ns: f64 = b
        .results()
        .iter()
        .filter(|s| s.name.starts_with("round/") && !s.name.contains('@'))
        .map(|s| s.median.as_nanos() as f64)
        .sum();
    let report = obj(vec![
        ("schema", Json::Str("lroa-bench-v1".into())),
        ("quick", Json::Bool(quick)),
        (
            "round_total",
            obj(vec![("median_ns", Json::Num(round_total_ns))]),
        ),
        ("samples", obj(samples)),
    ]);

    if json_out {
        println!("{report}");
    } else {
        b.report();
        println!("round_total median: {:.3}ms", round_total_ns / 1e6);
    }
    if let Some(path) = &out_path {
        std::fs::write(path, report.to_string())?;
        eprintln!("wrote {path}");
    }

    // The regression gate: compare against the committed baseline.
    if let Some(path) = &baseline {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("baseline {path}: {e}"))?;
        let base = Json::parse(&text).map_err(|e| anyhow::anyhow!("baseline {path}: {e}"))?;
        let base_total = base
            .path(&["round_total", "median_ns"])
            .and_then(|j| j.as_f64())
            .ok_or_else(|| {
                anyhow::anyhow!("baseline {path}: missing round_total.median_ns")
            })?;
        let ratio = round_total_ns / base_total;
        eprintln!(
            "bench gate: round_total {:.3}ms vs baseline {:.3}ms (x{:.3}, limit x{:.3})",
            round_total_ns / 1e6,
            base_total / 1e6,
            ratio,
            1.0 + max_regress
        );
        anyhow::ensure!(
            ratio <= 1.0 + max_regress,
            "round_total regressed {:.1}% over the baseline (limit {:.0}%): \
             {:.3}ms vs {:.3}ms — if intentional, refresh the committed \
             baseline with `lroa bench --json --quick --out={path}`",
            (ratio - 1.0) * 100.0,
            max_regress * 100.0,
            round_total_ns / 1e6,
            base_total / 1e6
        );
    }
    Ok(())
}

/// Peak resident-set size of this process [bytes], from the kernel's
/// `VmHWM` high-water mark (Linux; `None` elsewhere).  Monotone over the
/// process lifetime, so per-N readings in `lroa scale` are "peak so
/// far" — exactly the ceiling the CI scale job budgets against.
fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line
        .strip_prefix("VmHWM:")?
        .trim()
        .trim_end_matches("kB")
        .trim()
        .parse()
        .ok()?;
    Some(kb * 1024)
}

/// `lroa scale`: the fleet-scale harness — one LROA control-plane cell
/// per fleet size, run through the same `Experiment` pipeline as `lroa
/// sweep` (so each N lands its own manifest.json + cell CSV under
/// `--out/n<N>/`), aggregated into the N-vs-round-time scaling curve at
/// `--out/scaling.json` with peak-RSS evidence per point.  This is what
/// the CI `scale` job runs under an explicit wall-clock budget.
fn scale_cmd(args: &[String]) -> lroa::Result<()> {
    use lroa::config::Policy;

    let mut ns: Vec<usize> = vec![10_000, 100_000, 1_000_000];
    let mut rounds = 3usize;
    let mut out_dir = "runs/scale".to_string();
    let mut json_out = false;
    for a in args {
        if a == "--json" {
            json_out = true;
        } else if let Some(v) = a.strip_prefix("--ns=") {
            ns = v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse::<usize>()
                        .map_err(|_| lroa::usage_error(format!("scale: bad --ns value {x:?}")))
                })
                .collect::<lroa::Result<_>>()?;
        } else if let Some(v) = a.strip_prefix("--rounds=") {
            rounds = v
                .parse()
                .map_err(|_| lroa::usage_error(format!("scale: bad --rounds value {v:?}")))?;
            if rounds == 0 {
                return Err(lroa::usage_error("scale: --rounds must be >= 1"));
            }
        } else if let Some(v) = a.strip_prefix("--out=") {
            out_dir = v.to_string();
        } else {
            return Err(lroa::usage_error(format!(
                "scale: unknown argument {a:?} (--ns=N1,N2,... --rounds=R --out=DIR --json)"
            )));
        }
    }

    let out = std::path::PathBuf::from(&out_dir);
    let mut points: Vec<Json> = Vec::with_capacity(ns.len());
    for &n in &ns {
        let mut cfg = Config::for_dataset("cifar")?;
        cfg.train.policy = Policy::Lroa;
        cfg.train.rounds = rounds;
        cfg.system.num_devices = n;
        // validate() requires q_min < 1/N; the paper-scale default
        // (1e-6) sits exactly at the bound for N = 1e6, so shrink the
        // floor once fleets outgrow it (matches `round/LROA@1M`).
        if cfg.control.q_min >= 1.0 / n as f64 {
            cfg.control.q_min = 0.1 / n as f64;
        }
        cfg.validate()?;

        let dir = out.join(format!("n{n}"));
        say(json_out, &format!("scale: N={n}, {rounds} round(s) ..."));
        // The sweep file pipeline (cell CSV + summary.json +
        // manifest.json per N) minus the stdout observers: scale's own
        // stdout carries at most the scaling JSON (`--json` purity).
        let report = Experiment::new(cfg)
            .mode(SimMode::ControlPlaneOnly)
            .threads(1)
            .out_dir(&dir)
            .observe(exp::CsvObserver::new(&dir))
            .observe(exp::SummaryObserver::new(&dir))
            .observe(exp::ManifestObserver::new(&dir).quiet())
            .observe(exp::ProgressObserver::new().quiet())
            .build()?
            .run()?;
        let cell = report
            .results
            .first()
            .ok_or_else(|| anyhow::anyhow!("scale: N={n} produced no cell result"))?;
        let wall_s = cell.wall_s;
        let s_per_round = wall_s / rounds as f64;
        let rss = peak_rss_bytes();
        say(
            json_out,
            &format!(
                "scale: N={n}: {wall_s:.3}s wall ({s_per_round:.3}s/round), peak RSS {}",
                match rss {
                    Some(b) => format!("{:.1} MiB", b as f64 / (1024.0 * 1024.0)),
                    None => "unavailable".to_string(),
                }
            ),
        );
        points.push(obj(vec![
            ("num_devices", Json::Num(n as f64)),
            ("rounds", Json::Num(rounds as f64)),
            ("wall_s", Json::Num(wall_s)),
            ("s_per_round", Json::Num(s_per_round)),
            (
                "rss_peak_bytes",
                match rss {
                    Some(b) => Json::Num(b as f64),
                    None => Json::Null,
                },
            ),
        ]));
    }

    let curve = obj(vec![
        ("schema", Json::Str("lroa-scale-v1".into())),
        ("policy", Json::Str("LROA".into())),
        ("points", Json::Arr(points)),
    ]);
    std::fs::create_dir_all(&out)?;
    let path = out.join("scaling.json");
    std::fs::write(&path, curve.to_string())?;
    say(json_out, &format!("wrote {}", path.display()));
    if json_out {
        println!("{curve}");
    }
    Ok(())
}

/// `lroa trace import <csv> --out=FILE [...]`: convert an external
/// measurement log into the replay schema ([`lroa::env::import`]) and
/// report what the conversion did.  Flag errors exit 2; unreadable or
/// malformed input exits 1, before any output byte is written.
fn trace_import_cmd(args: &[String]) -> lroa::Result<()> {
    let mut input: Option<String> = None;
    let mut out: Option<String> = None;
    let mut spec = lroa::env::ImportSpec::new("", "");
    let mut json_out = false;
    for a in args {
        if a == "--json" {
            json_out = true;
        } else if a == "--gain-db" {
            spec.gain_db = true;
        } else if let Some(v) = a.strip_prefix("--out=") {
            out = Some(v.to_string());
        } else if let Some(v) = a.strip_prefix("--round-col=") {
            spec.round_col = v.to_string();
        } else if let Some(v) = a.strip_prefix("--device-col=") {
            spec.device_col = v.to_string();
        } else if let Some(v) = a.strip_prefix("--gain-col=") {
            spec.gain_col = v.to_string();
        } else if let Some(v) = a.strip_prefix("--avail-col=") {
            spec.avail_col = Some(v.to_string());
        } else if let Some(v) = a.strip_prefix("--gain-scale=") {
            spec.gain_scale = v.parse().map_err(|e| {
                lroa::usage_error(format!("trace import: bad --gain-scale value {v:?}: {e}"))
            })?;
            if !(spec.gain_scale.is_finite() && spec.gain_scale > 0.0) {
                return Err(lroa::usage_error("trace import: --gain-scale must be > 0"));
            }
        } else if let Some(v) = a.strip_prefix("--round-per=") {
            let per: f64 = v.parse().map_err(|e| {
                lroa::usage_error(format!("trace import: bad --round-per value {v:?}: {e}"))
            })?;
            if !(per.is_finite() && per > 0.0) {
                return Err(lroa::usage_error("trace import: --round-per must be > 0"));
            }
            spec.round_per = Some(per);
        } else if a.starts_with("--") {
            return Err(lroa::usage_error(format!(
                "trace import: unknown flag {a:?} (--out=FILE --round-col=NAME \
                 --device-col=NAME --gain-col=NAME --avail-col=NAME --gain-scale=F \
                 --gain-db --round-per=F --json)"
            )));
        } else if input.is_none() {
            input = Some(a.clone());
        } else {
            return Err(lroa::usage_error(format!(
                "trace import: unexpected argument {a:?} (one input CSV)"
            )));
        }
    }
    let Some(input) = input else {
        return Err(lroa::usage_error(
            "trace import: expected an input CSV — `lroa trace import <csv> --out=FILE`",
        ));
    };
    let Some(out) = out else {
        return Err(lroa::usage_error("trace import: --out=FILE is required"));
    };
    spec.input = input.clone().into();
    spec.output = out.clone().into();
    let stats = lroa::env::import_csv(&spec)?;
    let report = obj(vec![
        ("schema", Json::Str("lroa-trace-import-v1".into())),
        ("input", Json::Str(input)),
        ("output", Json::Str(out.clone())),
        ("devices", Json::Num(stats.devices as f64)),
        ("rounds", Json::Num(stats.rounds as f64)),
        ("rows", Json::Num(stats.rows as f64)),
        ("interpolated", Json::Num(stats.interpolated as f64)),
        ("period", Json::Num(stats.period as f64)),
        ("has_availability", Json::Bool(stats.has_availability)),
    ]);
    if json_out {
        println!("{report}");
    } else {
        println!(
            "imported {} device track(s), {} round(s) (period {}), {} row(s), \
             {} gain(s) gap-interpolated{}",
            stats.devices,
            stats.rounds,
            stats.period,
            stats.rows,
            stats.interpolated,
            if stats.has_availability {
                ", with availability"
            } else {
                ""
            },
        );
        println!("wrote {out} — replay with --envs=trace:{out}");
    }
    Ok(())
}

/// `lroa trace summarize`: the per-cell phase-timing table from a
/// `trace_summary.json` written by a `--trace-out` run.
fn trace_cmd(args: &[String]) -> lroa::Result<()> {
    use lroa::bench::fmt_ns;

    let Some((op, rest)) = args.split_first() else {
        return Err(lroa::usage_error(
            "trace: expected a subcommand — `lroa trace summarize [DIR | --dir=DIR]` \
             or `lroa trace import <csv> --out=FILE`",
        ));
    };
    if op == "import" {
        return trace_import_cmd(rest);
    }
    if op != "summarize" {
        return Err(lroa::usage_error(format!(
            "trace: unknown subcommand {op:?} (expected `summarize` or `import`)"
        )));
    }
    let mut dir = "runs/sweep/trace".to_string();
    for a in rest {
        if let Some(v) = a.strip_prefix("--dir=") {
            dir = v.to_string();
        } else if !a.starts_with("--") {
            dir = a.clone();
        } else {
            return Err(lroa::usage_error(format!(
                "trace summarize: unknown argument {a:?} (DIR or --dir=DIR)"
            )));
        }
    }
    let path = Path::new(&dir).join("trace_summary.json");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        anyhow::anyhow!(
            "{}: {e} (point --dir at a directory a --trace-out run wrote)",
            path.display()
        )
    })?;
    let summary = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    anyhow::ensure!(
        summary.get("schema").and_then(|s| s.as_str()) == Some("lroa-trace-v1"),
        "{}: unexpected schema (want lroa-trace-v1)",
        path.display()
    );
    let cells = summary
        .get("cells")
        .and_then(|c| c.as_arr())
        .ok_or_else(|| anyhow::anyhow!("{}: missing cells array", path.display()))?;
    let session_ns = summary
        .get("session_dur_ns")
        .and_then(|j| j.as_f64())
        .unwrap_or(0.0);
    println!(
        "trace: {} cell(s), session wall {} ({})",
        cells.len(),
        fmt_ns(session_ns),
        path.display()
    );
    for cell in cells {
        let f = |p: &[&str]| cell.path(p).and_then(|j| j.as_f64()).unwrap_or(0.0);
        println!(
            "\n{} (cell {}, tid {}): {} rounds, wall {}, solve {}/{} outer/inner iters, \
             {} warm-start hits, {} CSV bytes",
            cell.get("label").and_then(|s| s.as_str()).unwrap_or("?"),
            f(&["cell"]) as u64,
            f(&["tid"]) as u64,
            f(&["rounds"]) as u64,
            fmt_ns(f(&["dur_ns"])),
            f(&["counters", "outer_iters"]) as u64,
            f(&["counters", "inner_iters"]) as u64,
            f(&["counters", "warm_start_hits"]) as u64,
            f(&["counters", "bytes_written"]) as u64,
        );
        println!(
            "  {:<10} {:>8} {:>10} {:>10} {:>10} {:>10}",
            "phase", "count", "total", "p50", "p95", "max"
        );
        for phase in ["env_step", "solve", "train", "aggregate", "observe", "round"] {
            let stats = |key: &str| {
                if phase == "round" {
                    f(&["round", key])
                } else {
                    f(&["phases", phase, key])
                }
            };
            println!(
                "  {:<10} {:>8} {:>10} {:>10} {:>10} {:>10}",
                phase,
                stats("count") as u64,
                fmt_ns(stats("total_ns")),
                fmt_ns(stats("p50_ns")),
                fmt_ns(stats("p95_ns")),
                fmt_ns(stats("max_ns")),
            );
        }
        let evicted = f(&["spans_evicted"]) as u64;
        if evicted > 0 {
            println!(
                "  note: ring evicted {evicted} spans — phase stats cover the \
                 surviving (most recent) spans; counters stay exact"
            );
        }
    }
    Ok(())
}

fn info(args: &[String]) -> lroa::Result<()> {
    let cfg = build_config(args)?;
    println!("{}", cfg.dump());
    match Manifest::load(Path::new(&cfg.artifacts_dir)) {
        Ok(man) => {
            println!("\nartifacts ({}):", man.root.display());
            for v in &man.variants {
                println!(
                    "  {:8} d={:7}  M={:.2} Mbit  in={}x{}x{} classes={} batch={}/{} k_max={}",
                    v.name,
                    v.dim,
                    v.model_bits as f64 / 1e6,
                    v.input_hw.0,
                    v.input_hw.1,
                    v.input_c,
                    v.num_classes,
                    v.train_batch,
                    v.eval_batch,
                    v.k_max
                );
            }
        }
        Err(e) => println!("\nartifacts: unavailable ({e})"),
    }
    let server = Server::new(cfg, SimMode::ControlPlaneOnly)?;
    println!("\nfleet: {} devices", server.fleet().len());
    println!("lambda = {:.4e}, V = {:.4e}", server.lambda, server.v);
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            print!("{HELP}");
            return;
        }
    };
    let result = match cmd {
        "train" => run(SimMode::Full, &rest),
        "sim" => run(SimMode::ControlPlaneOnly, &rest),
        "sweep" => sweep(&rest),
        "regret" => regret(&rest),
        "bench" => bench_cmd(&rest),
        "scale" => scale_cmd(&rest),
        "trace" => trace_cmd(&rest),
        "info" => info(&rest),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        // The documented exit-code contract (see HELP and
        // tests/cli_exit_codes.rs): misuse exits 2, everything else 1.
        std::process::exit(if lroa::is_usage_error(&e) { 2 } else { 1 });
    }
}
