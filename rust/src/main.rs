//! `lroa` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!
//! * `train`  — run one federated training (full stack through PJRT);
//! * `sim`    — control-plane-only simulation (no artifacts needed);
//! * `sweep`  — run a policy × K × µ/ν × seed × dataset grid in parallel;
//! * `info`   — inspect artifacts, fleet, and the λ/V estimates;
//! * `help`   — this text.
//!
//! Every config knob is overridable as `--section.key=value` (see
//! `config.rs`), e.g.:
//!
//! ```text
//! lroa train --train.dataset=femnist --train.rounds=200 --control.mu=10
//! lroa sim   --train.policy=uni-s --system.k=4 --train.rounds=1000
//! lroa sweep --policies=all --ks=2,4,6 --seeds=1..5 --rounds=200
//! ```

use std::path::Path;

use lroa::config::Config;
use lroa::exp::{self, SweepSpec};
use lroa::fl::{Server, SimMode};
use lroa::json::{obj, Json};
use lroa::metrics::num_or_null;
use lroa::runtime::Manifest;

const HELP: &str = "\
lroa — Lyapunov-based online client scheduling for federated edge learning

USAGE:
    lroa <train|sim|info> [--config FILE] [--section.key=value ...]
    lroa sweep [--key=value ...] [--section.key=value ...]

SUBCOMMANDS:
    train   full federated training through the AOT artifacts
    sim     control-plane-only simulation (latency/energy/queues)
    sweep   parallel scenario grid; seed repeats aggregate to mean±std,
            manifest.json documents every cell for the figure pipeline
    info    print artifact manifest, fleet summary, λ/V estimates

SWEEP FLAGS (all --key=value unless noted):
    --policies=lroa,uni-d,uni-s,divfl,greedy,rr|all   --datasets=cifar,femnist
    --envs=static,ge,avail,drift|all        (dynamic environments, see below)
    --ks=2,4,6      --mus=0.1,1,10          --nus=1e4,1e5,1e6
    --seeds=1..30   --rounds=N              --threads=T (0 = cores)
    --mode=sim|train                        --out=DIR
    --resume        (bare flag: skip cells whose CSV already exists in --out)

ENVIRONMENTS (the --envs axis / --env.kind override):
    static  the paper's IID exponential channel, always-on fleet (default)
    ge      Gilbert-Elliott two-state Markov fading per device
    avail   Markov device dropout/arrival (candidate set varies per round)
    drift   random-walk drift on per-device compute/energy parameters

COMMON OVERRIDES:
    --train.dataset=cifar|femnist   --train.rounds=N     --train.policy=lroa|...|rr
    --system.k=K                    --control.mu=F       --control.nu=F
    --train.seed=N                  --env.kind=static|ge|avail|drift
    --env.ge_p_bad=F --env.avail_p_drop=F --env.drift_sigma=F   (see config.rs)
    --run.out_dir=DIR               --run.artifacts_dir=DIR
";

fn build_config(args: &[String]) -> lroa::Result<Config> {
    // Optional --config FILE first, then dotted overrides.
    let mut cfg = None;
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if a == "--config" {
            if let Some(path) = it.next() {
                cfg = Some(Config::from_file(Path::new(path))?);
            }
        } else if let Some(path) = a.strip_prefix("--config=") {
            cfg = Some(Config::from_file(Path::new(path))?);
        }
    }
    let mut cfg = match cfg {
        Some(c) => c,
        None => {
            // Respect --train.dataset before defaults resolve.
            let ds = args
                .iter()
                .find_map(|a| a.strip_prefix("--train.dataset="))
                .unwrap_or("cifar");
            Config::for_dataset(ds)?
        }
    };
    cfg.apply_cli(args)?;
    cfg.validate()?;
    Ok(cfg)
}

fn run(mode: SimMode, args: &[String]) -> lroa::Result<()> {
    let cfg = build_config(args)?;
    println!("{}", cfg.dump());
    let out_dir = std::path::PathBuf::from(&cfg.out_dir).join("cli");
    let mut server = Server::new(cfg, mode)?;
    println!("lambda = {:.4e}, V = {:.4e}", server.lambda, server.v);
    server.run()?;
    let rec = &server.recorder;
    println!(
        "done: {} rounds, modeled total {:.1}s, final accuracy {:.4}",
        rec.rounds.len(),
        rec.total_time_s(),
        rec.final_accuracy()
    );
    std::fs::create_dir_all(&out_dir)?;
    let csv = out_dir.join(format!("{}.csv", rec.label));
    rec.write_csv(&csv)?;
    println!("wrote {}", csv.display());
    Ok(())
}

fn sweep(args: &[String]) -> lroa::Result<()> {
    let spec = SweepSpec::from_cli(args)?;
    let scenarios = spec.expand()?;
    anyhow::ensure!(!scenarios.is_empty(), "sweep expanded to zero scenarios");
    println!(
        "sweep: {} scenarios ({} groups), pool width {}",
        scenarios.len(),
        scenarios
            .iter()
            .map(|s| s.group.as_str())
            .collect::<std::collections::BTreeSet<_>>()
            .len(),
        if spec.threads == 0 { "auto".to_string() } else { spec.threads.to_string() },
    );

    // Streaming CSVs + resume key on the cell label: duplicates would
    // race on the same file, so reject them up front.
    {
        let mut seen = std::collections::BTreeSet::new();
        for s in &scenarios {
            anyhow::ensure!(
                seen.insert(s.label.as_str()),
                "sweep: duplicate cell label {:?} (repeated axis value, or an \
                 override clobbering a swept axis?)",
                s.label
            );
        }
    }

    let dir = std::path::PathBuf::from(&spec.out_dir);
    std::fs::create_dir_all(&dir)?;
    let manifest_path = dir.join("manifest.json");

    // The grid manifest covers *every* cell and is written before any
    // cell runs, so crashed or resumed sweeps still document their grid.
    std::fs::write(&manifest_path, exp::manifest_json(&scenarios).to_string())?;
    println!("wrote {}", manifest_path.display());

    // Resume: a cell is done only if its CSV exists under --out AND its
    // `.hash` sidecar — written by the runner at cell *completion* —
    // matches this cell's fingerprint (sim mode + config hash), so stale
    // CSVs from an older config (different --rounds, --mode, knobs ...)
    // are re-run, never silently kept.  The groups touched by skipped
    // cells are tracked so the summary never reports a partial seed set
    // under a full group label.
    let mut skipped = 0usize;
    let mut partial_groups = std::collections::BTreeSet::new();
    let mut scenarios = if spec.resume {
        let (done, todo): (Vec<_>, Vec<_>) = scenarios.into_iter().partition(|s| {
            dir.join(format!("{}.csv", s.label)).exists()
                && std::fs::read_to_string(dir.join(format!("{}.hash", s.label)))
                    .map(|h| h.trim() == s.fingerprint())
                    .unwrap_or(false)
        });
        skipped = done.len();
        partial_groups.extend(done.iter().map(|s| s.group.clone()));
        println!(
            "resume: skipping {} cells with existing CSVs, running {}",
            done.len(),
            todo.len()
        );
        if todo.is_empty() {
            println!("resume: nothing left to run");
            if !dir.join("summary.json").exists() {
                println!(
                    "warning: summary.json is missing (it is written by an \
                     invocation that runs at least one cell); re-run without \
                     --resume to regenerate the aggregate"
                );
            }
            return Ok(());
        }
        todo
    } else {
        scenarios
    };
    // Each cell's CSV streams out as it completes, so a killed grid is
    // resumable from exactly where it stopped.
    for s in &mut scenarios {
        s.csv_dir = Some(dir.clone());
    }

    let results = exp::run_scenarios(scenarios, spec.threads)?;

    // Aggregate summary bundle (per-cell CSVs were written by the runner).
    let run_summaries: Vec<Json> = results.iter().map(|r| r.recorder.summary_json()).collect();
    let groups = exp::summarize_groups(&results);
    let group_json: Vec<Json> = groups
        .iter()
        // A group with resumed (not re-aggregated) cells would report
        // statistics over a subset of its seeds: omit it from the
        // machine-readable summary rather than mislabel it.
        .filter(|g| !partial_groups.contains(&g.group))
        .map(|g| {
            obj(vec![
                ("group", Json::Str(g.group.clone())),
                ("runs", Json::Num(g.runs as f64)),
                ("total_time_s_mean", num_or_null(g.total_time_s.mean)),
                ("total_time_s_std", num_or_null(g.total_time_s.std)),
                ("final_accuracy_mean", num_or_null(g.final_accuracy.mean)),
            ])
        })
        .collect();
    std::fs::write(
        dir.join("summary.json"),
        obj(vec![
            ("groups", Json::Arr(group_json)),
            ("runs", Json::Arr(run_summaries)),
            // Cells skipped by --resume are NOT aggregated here; their
            // CSVs (and the full grid) are listed in manifest.json.
            ("skipped_cells", Json::Num(skipped as f64)),
            (
                "partial_groups",
                Json::Arr(
                    partial_groups
                        .iter()
                        .map(|g| Json::Str(g.clone()))
                        .collect(),
                ),
            ),
        ])
        .to_string(),
    )?;
    if skipped > 0 {
        println!(
            "note: summary.json aggregates only the {} cells run in this \
             invocation ({} resumed cells excluded; groups with resumed \
             cells are listed under partial_groups); per-cell CSVs + \
             manifest.json cover the full grid",
            results.len(),
            skipped
        );
    }

    // The mean±std table the paper's seed-averaged figures report.
    println!(
        "\n{:<28} {:>5} {:>24} {:>20} {:>24}",
        "group", "runs", "total time [s]", "final acc", "time-avg energy [J]"
    );
    for g in &groups {
        // A group with resumed cells aggregates only this invocation's
        // seeds — flag it so the number is never mistaken for the full
        // seed average.
        let name = if partial_groups.contains(&g.group) {
            format!("{} (partial)", g.group)
        } else {
            g.group.clone()
        };
        println!(
            "{:<28} {:>5} {:>24} {:>20} {:>24}",
            name,
            g.runs,
            g.total_time_s.to_string(),
            g.final_accuracy.to_string(),
            g.time_avg_energy.to_string(),
        );
    }
    println!("\nCSV + summary.json under {}", dir.display());
    Ok(())
}

fn info(args: &[String]) -> lroa::Result<()> {
    let cfg = build_config(args)?;
    println!("{}", cfg.dump());
    match Manifest::load(Path::new(&cfg.artifacts_dir)) {
        Ok(man) => {
            println!("\nartifacts ({}):", man.root.display());
            for v in &man.variants {
                println!(
                    "  {:8} d={:7}  M={:.2} Mbit  in={}x{}x{} classes={} batch={}/{} k_max={}",
                    v.name,
                    v.dim,
                    v.model_bits as f64 / 1e6,
                    v.input_hw.0,
                    v.input_hw.1,
                    v.input_c,
                    v.num_classes,
                    v.train_batch,
                    v.eval_batch,
                    v.k_max
                );
            }
        }
        Err(e) => println!("\nartifacts: unavailable ({e})"),
    }
    let server = Server::new(cfg, SimMode::ControlPlaneOnly)?;
    println!("\nfleet: {} devices", server.fleet().len());
    println!("lambda = {:.4e}, V = {:.4e}", server.lambda, server.v);
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            print!("{HELP}");
            return;
        }
    };
    let result = match cmd {
        "train" => run(SimMode::Full, &rest),
        "sim" => run(SimMode::ControlPlaneOnly, &rest),
        "sweep" => sweep(&rest),
        "info" => info(&rest),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
