//! `lroa` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!
//! * `train`  — run one federated training (full stack through PJRT);
//! * `sim`    — control-plane-only simulation (no artifacts needed);
//! * `info`   — inspect artifacts, fleet, and the λ/V estimates;
//! * `help`   — this text.
//!
//! Every config knob is overridable as `--section.key=value` (see
//! `config.rs`), e.g.:
//!
//! ```text
//! lroa train --train.dataset=femnist --train.rounds=200 --control.mu=10
//! lroa sim   --train.policy=uni-s --system.k=4 --train.rounds=1000
//! ```

use std::path::Path;

use lroa::config::Config;
use lroa::fl::{Server, SimMode};
use lroa::runtime::Manifest;

const HELP: &str = "\
lroa — Lyapunov-based online client scheduling for federated edge learning

USAGE:
    lroa <train|sim|info> [--config FILE] [--section.key=value ...]

SUBCOMMANDS:
    train   full federated training through the AOT artifacts
    sim     control-plane-only simulation (latency/energy/queues)
    info    print artifact manifest, fleet summary, λ/V estimates

COMMON OVERRIDES:
    --train.dataset=cifar|femnist   --train.rounds=N     --train.policy=lroa|uni-d|uni-s|divfl
    --system.k=K                    --control.mu=F       --control.nu=F
    --train.seed=N                  --run.out_dir=DIR    --run.artifacts_dir=DIR
";

fn build_config(args: &[String]) -> lroa::Result<Config> {
    // Optional --config FILE first, then dotted overrides.
    let mut cfg = None;
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if a == "--config" {
            if let Some(path) = it.next() {
                cfg = Some(Config::from_file(Path::new(path))?);
            }
        } else if let Some(path) = a.strip_prefix("--config=") {
            cfg = Some(Config::from_file(Path::new(path))?);
        }
    }
    let mut cfg = match cfg {
        Some(c) => c,
        None => {
            // Respect --train.dataset before defaults resolve.
            let ds = args
                .iter()
                .find_map(|a| a.strip_prefix("--train.dataset="))
                .unwrap_or("cifar");
            Config::for_dataset(ds)?
        }
    };
    cfg.apply_cli(args)?;
    cfg.validate()?;
    Ok(cfg)
}

fn run(mode: SimMode, args: &[String]) -> lroa::Result<()> {
    let cfg = build_config(args)?;
    println!("{}", cfg.dump());
    let out_dir = std::path::PathBuf::from(&cfg.out_dir).join("cli");
    let mut server = Server::new(cfg, mode)?;
    println!("lambda = {:.4e}, V = {:.4e}", server.lambda, server.v);
    server.run()?;
    let rec = &server.recorder;
    println!(
        "done: {} rounds, modeled total {:.1}s, final accuracy {:.4}",
        rec.rounds.len(),
        rec.total_time_s(),
        rec.final_accuracy()
    );
    std::fs::create_dir_all(&out_dir)?;
    let csv = out_dir.join(format!("{}.csv", rec.label));
    rec.write_csv(&csv)?;
    println!("wrote {}", csv.display());
    Ok(())
}

fn info(args: &[String]) -> lroa::Result<()> {
    let cfg = build_config(args)?;
    println!("{}", cfg.dump());
    match Manifest::load(Path::new(&cfg.artifacts_dir)) {
        Ok(man) => {
            println!("\nartifacts ({}):", man.root.display());
            for v in &man.variants {
                println!(
                    "  {:8} d={:7}  M={:.2} Mbit  in={}x{}x{} classes={} batch={}/{} k_max={}",
                    v.name,
                    v.dim,
                    v.model_bits as f64 / 1e6,
                    v.input_hw.0,
                    v.input_hw.1,
                    v.input_c,
                    v.num_classes,
                    v.train_batch,
                    v.eval_batch,
                    v.k_max
                );
            }
        }
        Err(e) => println!("\nartifacts: unavailable ({e})"),
    }
    let server = Server::new(cfg, SimMode::ControlPlaneOnly)?;
    println!("\nfleet: {} devices", server.fleet().len());
    println!("lambda = {:.4e}, V = {:.4e}", server.lambda, server.v);
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            print!("{HELP}");
            return;
        }
    };
    let result = match cmd {
        "train" => run(SimMode::Full, &rest),
        "sim" => run(SimMode::ControlPlaneOnly, &rest),
        "info" => info(&rest),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
