//! Run metrics: per-round records, time series, CSV / JSON emission.
//!
//! Every figure harness consumes this module: the recorder captures the
//! paper's reported quantities each round (modeled wall-clock, energy,
//! objective value, queue backlogs, accuracy when evaluated) and emits
//! them as CSV series shaped like the paper's plots.

use std::path::Path;

use crate::json::{arr_f64, obj, Json};
use crate::Result;

/// The cell-CSV column set, in emission order — the schema contract
/// shared by [`Recorder::write_csv`], [`Recorder::read_csv`], and the
/// sweep manifest (`lroa sweep`/`lroa regret` publish it under
/// `columns` so figure scripts never hard-code it).
pub const CSV_COLUMNS: &[&str] = &[
    "round",
    "round_time_s",
    "total_time_s",
    "objective",
    "mean_energy_j",
    "mean_queue",
    "max_queue",
    "selected",
    "train_loss",
    "test_accuracy",
    "test_loss",
    "solver_time_s",
    "outer_iters",
    "inner_iters",
    "regret",
    "regret_online",
    "regret_budget",
];

/// One communication round's record.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Modeled wall-clock of this round: `max_{n in K^t} T_n^t` (eq. 10).
    pub round_time_s: f64,
    /// Cumulative modeled time up to and including this round.
    pub total_time_s: f64,
    /// Per-round objective `Σ_n (q_n T_n + λ w_n²/q_n)` (P1 integrand).
    pub objective: f64,
    /// Mean over devices of realized energy draw `1{selected} · E_n^t`.
    pub mean_energy_j: f64,
    /// Mean virtual-queue backlog `mean_n Q_n^t`.
    pub mean_queue: f64,
    /// Max virtual-queue backlog.
    pub max_queue: f64,
    /// Devices selected this round (unique count).
    pub selected: usize,
    /// Mean training loss over the selected clients' local steps.
    pub train_loss: f64,
    /// Test accuracy (NaN when not evaluated this round).
    pub test_accuracy: f64,
    /// Test loss (NaN when not evaluated this round).
    pub test_loss: f64,
    /// Algorithm 2 solve time [s] (control-plane overhead).
    pub solver_time_s: f64,
    /// Algorithm 2 outer iterations this round (0 for non-iterative
    /// policies) — makes warm-start savings visible in sweep output.
    pub outer_iters: usize,
    /// Total SUM inner iterations across the round's outer loop.
    pub inner_iters: usize,
    /// Cumulative latency gap vs the oracle anchor on the same
    /// environment stream: `total_time_s − total_time_s(oracle)` up to
    /// this round.  In `lroa regret` runs it is derived as
    /// `regret_online + regret_budget`, so the decomposition holds
    /// bitwise; NaN (empty CSV field) outside them.
    pub regret: f64,
    /// The online component of `regret`: the gap vs the *budget-feasible*
    /// clairvoyant anchor, `total_time_s − total_time_s(oracle-e)` —
    /// what not knowing the future costs once both sides respect the
    /// energy budgets.  NaN outside `lroa regret` runs.
    pub regret_online: f64,
    /// The budget component of `regret`:
    /// `total_time_s(oracle-e) − total_time_s(oracle)` on the same
    /// stream — what energy feasibility alone costs a clairvoyant
    /// scheduler (≥ 0 on action-independent environments).  NaN outside
    /// `lroa regret` runs.
    pub regret_budget: f64,
}

impl Default for RoundRecord {
    fn default() -> Self {
        Self {
            round: 0,
            round_time_s: 0.0,
            total_time_s: 0.0,
            objective: 0.0,
            mean_energy_j: 0.0,
            mean_queue: 0.0,
            max_queue: 0.0,
            selected: 0,
            train_loss: 0.0,
            test_accuracy: 0.0,
            test_loss: 0.0,
            solver_time_s: 0.0,
            outer_iters: 0,
            inner_iters: 0,
            // "Not a regret run", not "zero regret".
            regret: f64::NAN,
            regret_online: f64::NAN,
            regret_budget: f64::NAN,
        }
    }
}

/// Recorder for a full run.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    pub label: String,
    pub rounds: Vec<RoundRecord>,
}

impl Recorder {
    pub fn new(label: impl Into<String>) -> Self {
        Self {
            label: label.into(),
            rounds: Vec::new(),
        }
    }

    pub fn push(&mut self, rec: RoundRecord) {
        self.rounds.push(rec);
    }

    /// Total modeled training latency (the paper's headline metric).
    pub fn total_time_s(&self) -> f64 {
        self.rounds.last().map(|r| r.total_time_s).unwrap_or(0.0)
    }

    /// Final test accuracy (last evaluated round).
    pub fn final_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .rev()
            .find(|r| !r.test_accuracy.is_nan())
            .map(|r| r.test_accuracy)
            .unwrap_or(f64::NAN)
    }

    /// Modeled time at which test accuracy first reached `target` (NaN if never).
    pub fn time_to_accuracy_s(&self, target: f64) -> f64 {
        self.rounds
            .iter()
            .find(|r| !r.test_accuracy.is_nan() && r.test_accuracy >= target)
            .map(|r| r.total_time_s)
            .unwrap_or(f64::NAN)
    }

    /// Running time-average of per-round mean energy (Fig. 4a/4c series).
    pub fn time_avg_energy(&self) -> Vec<f64> {
        running_average(self.rounds.iter().map(|r| r.mean_energy_j))
    }

    /// Running time-average of the objective (Fig. 4b/4d series).
    pub fn time_avg_objective(&self) -> Vec<f64> {
        running_average(self.rounds.iter().map(|r| r.objective))
    }

    /// The full per-round table as CSV bytes — the single source of the
    /// on-disk format ([`Recorder::write_csv`] writes exactly this
    /// string, and the trace counters size cell output with it).
    pub fn csv_string(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(64 * (self.rounds.len() + 1));
        let _ = writeln!(out, "{}", CSV_COLUMNS.join(","));
        for r in &self.rounds {
            let _ = writeln!(
                out,
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
                r.round,
                r.round_time_s,
                r.total_time_s,
                r.objective,
                r.mean_energy_j,
                r.mean_queue,
                r.max_queue,
                r.selected,
                r.train_loss,
                csv_f64(r.test_accuracy),
                csv_f64(r.test_loss),
                r.solver_time_s,
                r.outer_iters,
                r.inner_iters,
                csv_f64(r.regret),
                csv_f64(r.regret_online),
                csv_f64(r.regret_budget),
            );
        }
        out
    }

    /// Write the full per-round table as CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.csv_string())?;
        Ok(())
    }

    /// Read a cell CSV back into a recorder (the label is the file
    /// stem).  The inverse of [`Recorder::write_csv`]: header-driven, so
    /// column order is free, unknown columns are ignored, and CSVs
    /// written before a column existed (e.g. pre-`regret` cells) load
    /// with that field NaN.  This is what lets a `--resume`d sweep
    /// aggregate *skipped* cells into `summary.json` instead of silently
    /// excluding them.
    pub fn read_csv(path: &Path) -> Result<Recorder> {
        let text = std::fs::read_to_string(path)?;
        let mut lines = text.lines();
        let header = lines
            .next()
            .ok_or_else(|| anyhow::anyhow!("{}: empty CSV", path.display()))?;
        let cols: Vec<&str> = header.split(',').map(str::trim).collect();
        let col = |name: &str| cols.iter().position(|c| *c == name);
        let need = |name: &str| {
            col(name).ok_or_else(|| {
                anyhow::anyhow!("{}: missing CSV column {name:?}", path.display())
            })
        };
        let idx_round = need("round")?;
        let idx_selected = need("selected")?;
        // Every f64 field binds by column *name* (never by position in
        // CSV_COLUMNS), so reordering or inserting columns can never
        // silently misbind a resumed cell; absent columns load NaN.
        let f64_col = |r: &[&str], name: &str| -> f64 {
            match col(name).and_then(|i| r.get(i)) {
                Some(s) if !s.is_empty() => s.parse().unwrap_or(f64::NAN),
                _ => f64::NAN,
            }
        };
        // Iteration counters came later than the f64 columns: CSVs
        // written before them load 0 ("not recorded"), keeping legacy
        // cells resumable.
        let int_col = |r: &[&str], name: &str| -> usize {
            col(name)
                .and_then(|i| r.get(i))
                .and_then(|s| s.parse().ok())
                .unwrap_or(0)
        };
        let mut rec = Recorder::new(
            path.file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        );
        for (lineno, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split(',').map(str::trim).collect();
            let int = |i: usize| -> Result<usize> {
                fields
                    .get(i)
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| {
                        anyhow::anyhow!("{}: line {}: bad integer", path.display(), lineno + 2)
                    })
            };
            rec.push(RoundRecord {
                round: int(idx_round)?,
                round_time_s: f64_col(&fields, "round_time_s"),
                total_time_s: f64_col(&fields, "total_time_s"),
                objective: f64_col(&fields, "objective"),
                mean_energy_j: f64_col(&fields, "mean_energy_j"),
                mean_queue: f64_col(&fields, "mean_queue"),
                max_queue: f64_col(&fields, "max_queue"),
                selected: int(idx_selected)?,
                train_loss: f64_col(&fields, "train_loss"),
                test_accuracy: f64_col(&fields, "test_accuracy"),
                test_loss: f64_col(&fields, "test_loss"),
                solver_time_s: f64_col(&fields, "solver_time_s"),
                outer_iters: int_col(&fields, "outer_iters"),
                inner_iters: int_col(&fields, "inner_iters"),
                regret: f64_col(&fields, "regret"),
                regret_online: f64_col(&fields, "regret_online"),
                regret_budget: f64_col(&fields, "regret_budget"),
            });
        }
        Ok(rec)
    }

    /// Final cumulative regret vs the oracle anchor (NaN outside
    /// `lroa regret` runs).
    pub fn final_regret(&self) -> f64 {
        self.rounds.last().map(|r| r.regret).unwrap_or(f64::NAN)
    }

    /// Final online-component regret (vs the budget-feasible `oracle-e`
    /// anchor); NaN outside `lroa regret` runs.
    pub fn final_regret_online(&self) -> f64 {
        self.rounds
            .last()
            .map(|r| r.regret_online)
            .unwrap_or(f64::NAN)
    }

    /// Final budget-component regret (`oracle-e` vs `oracle`); NaN
    /// outside `lroa regret` runs.
    pub fn final_regret_budget(&self) -> f64 {
        self.rounds
            .last()
            .map(|r| r.regret_budget)
            .unwrap_or(f64::NAN)
    }

    /// Summary as JSON (for EXPERIMENTS.md extraction).
    pub fn summary_json(&self) -> Json {
        obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("rounds", Json::Num(self.rounds.len() as f64)),
            ("total_time_s", Json::Num(self.total_time_s())),
            ("final_accuracy", num_or_null(self.final_accuracy())),
            ("final_regret", num_or_null(self.final_regret())),
            (
                "final_regret_online",
                num_or_null(self.final_regret_online()),
            ),
            (
                "final_regret_budget",
                num_or_null(self.final_regret_budget()),
            ),
            (
                "final_time_avg_energy",
                num_or_null(self.time_avg_energy().last().copied().unwrap_or(f64::NAN)),
            ),
            (
                "final_time_avg_objective",
                num_or_null(self.time_avg_objective().last().copied().unwrap_or(f64::NAN)),
            ),
            (
                "accuracy_series",
                arr_f64(
                    &self
                        .rounds
                        .iter()
                        .filter(|r| !r.test_accuracy.is_nan())
                        .map(|r| r.test_accuracy)
                        .collect::<Vec<_>>(),
                ),
            ),
        ])
    }
}

/// Non-finite metric values (unevaluated accuracy, runaway objectives)
/// must serialize as JSON `null`, never as bare `NaN`/`inf` tokens.
pub fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

fn csv_f64(x: f64) -> String {
    if x.is_nan() {
        String::new()
    } else {
        format!("{x}")
    }
}

/// Running mean of a sequence: out[t] = mean(xs[0..=t]).
pub fn running_average<I: IntoIterator<Item = f64>>(xs: I) -> Vec<f64> {
    let mut out = Vec::new();
    let mut sum = 0.0;
    for (i, x) in xs.into_iter().enumerate() {
        sum += x;
        out.push(sum / (i + 1) as f64);
    }
    out
}

/// Aggregate several repeats of the same series (mean per index).
///
/// Series must share one length; a mismatch — e.g. a truncated legacy
/// cell CSV re-read by a `--resume`d grid — is a recoverable `Err`
/// naming the offending index and lengths, not a panic that aborts the
/// whole summary ([`crate::exp::mean_series_over`] adds cell labels).
pub fn mean_series(series: &[Vec<f64>]) -> Result<Vec<f64>> {
    if series.is_empty() {
        return Ok(Vec::new());
    }
    let len = series[0].len();
    if let Some((i, bad)) = series.iter().enumerate().find(|(_, s)| s.len() != len) {
        anyhow::bail!(
            "mean_series: series 0 has {len} entries but series {i} has {} — \
             refusing to aggregate repeats of unequal length",
            bad.len()
        );
    }
    Ok((0..len)
        .map(|i| series.iter().map(|s| s[i]).sum::<f64>() / series.len() as f64)
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, time: f64, acc: f64) -> RoundRecord {
        RoundRecord {
            round,
            round_time_s: time,
            total_time_s: 0.0,
            test_accuracy: acc,
            ..RoundRecord::default()
        }
    }

    #[test]
    fn running_average_basic() {
        assert_eq!(running_average([2.0, 4.0, 6.0]), vec![2.0, 3.0, 4.0]);
        assert!(running_average(std::iter::empty()).is_empty());
    }

    #[test]
    fn mean_series_basic() {
        let out = mean_series(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        assert_eq!(out, vec![2.0, 3.0]);
        assert!(mean_series(&[]).unwrap().is_empty());
    }

    #[test]
    fn mean_series_rejects_unequal_lengths() {
        let err = mean_series(&[vec![1.0, 2.0], vec![3.0]]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("series 1"), "error names the series: {msg}");
        assert!(msg.contains("2 entries"), "error names the lengths: {msg}");
    }

    #[test]
    fn csv_string_matches_written_file() {
        let mut r = Recorder::new("csv-string");
        r.push(rec(0, 1.5, f64::NAN));
        r.push(rec(1, 2.5, 0.25));
        let dir = std::env::temp_dir().join(format!("lroa-metrics-{}", std::process::id()));
        let path = dir.join("csv-string.csv");
        r.write_csv(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), r.csv_string());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recorder_summaries() {
        let mut r = Recorder::new("test");
        let mut total = 0.0;
        for i in 0..5 {
            let mut rr = rec(i, 1.0, if i >= 3 { 0.5 + i as f64 / 10.0 } else { f64::NAN });
            total += rr.round_time_s;
            rr.total_time_s = total;
            rr.mean_energy_j = 2.0;
            rr.objective = 10.0;
            r.push(rr);
        }
        assert_eq!(r.total_time_s(), 5.0);
        assert!((r.final_accuracy() - 0.9).abs() < 1e-12);
        assert_eq!(r.time_to_accuracy_s(0.8), 4.0);
        assert!(r.time_to_accuracy_s(0.99).is_nan());
        assert_eq!(r.time_avg_energy(), vec![2.0; 5]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let dir = std::env::temp_dir().join("lroa_metrics_test");
        let path = dir.join("run.csv");
        let mut r = Recorder::new("csv");
        r.push(rec(0, 1.5, f64::NAN));
        r.push(rec(1, 2.5, 0.4));
        r.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("round,"));
        // NaN accuracy serializes as empty field.
        assert!(lines[1].contains(",,"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_round_trips_through_the_reader() {
        let dir = std::env::temp_dir().join("lroa_metrics_roundtrip");
        let path = dir.join("cell-label.csv");
        let mut w = Recorder::new("cell-label");
        for i in 0..4 {
            w.push(RoundRecord {
                round: i,
                round_time_s: 1.5 + i as f64,
                total_time_s: 10.0 * (i + 1) as f64,
                objective: 3.25,
                mean_energy_j: 0.5,
                mean_queue: 1.0,
                max_queue: 2.0,
                selected: 2,
                train_loss: f64::NAN,
                test_accuracy: if i == 3 { 0.75 } else { f64::NAN },
                test_loss: f64::NAN,
                solver_time_s: 1e-4,
                outer_iters: 3 + i,
                inner_iters: 40 + i,
                regret: if i % 2 == 0 { i as f64 } else { f64::NAN },
                regret_online: if i % 2 == 0 { 0.25 * i as f64 } else { f64::NAN },
                regret_budget: if i % 2 == 0 { 0.75 * i as f64 } else { f64::NAN },
            });
        }
        w.write_csv(&path).unwrap();
        let r = Recorder::read_csv(&path).unwrap();
        assert_eq!(r.label, "cell-label");
        assert_eq!(r.rounds.len(), 4);
        for (a, b) in w.rounds.iter().zip(&r.rounds) {
            assert_eq!(a.round, b.round);
            assert_eq!(a.round_time_s, b.round_time_s);
            assert_eq!(a.total_time_s, b.total_time_s);
            assert_eq!(a.selected, b.selected);
            assert_eq!(a.outer_iters, b.outer_iters);
            assert_eq!(a.inner_iters, b.inner_iters);
            assert_eq!(a.test_accuracy.is_nan(), b.test_accuracy.is_nan());
            assert_eq!(a.regret.is_nan(), b.regret.is_nan());
            if !a.regret.is_nan() {
                assert_eq!(a.regret, b.regret);
                assert_eq!(a.regret_online, b.regret_online);
                assert_eq!(a.regret_budget, b.regret_budget);
            }
        }
        assert_eq!(r.total_time_s(), 40.0);
        assert_eq!(r.final_accuracy(), 0.75);
        // A pre-regret CSV (no such column) still loads, regret = NaN.
        let legacy = dir.join("legacy.csv");
        std::fs::write(
            &legacy,
            "round,round_time_s,total_time_s,objective,mean_energy_j,mean_queue,\
             max_queue,selected,train_loss,test_accuracy,test_loss,solver_time_s\n\
             0,1,1,0,0,0,0,2,,,,0\n",
        )
        .unwrap();
        let r = Recorder::read_csv(&legacy).unwrap();
        assert_eq!(r.rounds.len(), 1);
        assert!(r.rounds[0].regret.is_nan());
        assert!(r.rounds[0].regret_online.is_nan());
        assert!(r.rounds[0].regret_budget.is_nan());
        // Pre-iteration-counter CSVs load those as 0 ("not recorded").
        assert_eq!(r.rounds[0].outer_iters, 0);
        assert_eq!(r.rounds[0].inner_iters, 0);
        // Garbage is rejected, not silently zeroed.
        let bad = dir.join("bad.csv");
        std::fs::write(&bad, "nope,cols\n1,2\n").unwrap();
        assert!(Recorder::read_csv(&bad).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn summary_json_is_valid() {
        let mut r = Recorder::new("j");
        r.push(rec(0, 1.0, 0.25));
        let j = r.summary_json().to_string();
        let parsed = crate::json::Json::parse(&j).unwrap();
        assert_eq!(parsed.get("label").unwrap().as_str(), Some("j"));
    }
}
