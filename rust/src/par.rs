//! Deterministic scoped-thread fan-out.
//!
//! Both hot fan-out points of the coordinator go through this module: the
//! FL server spreads per-client local training over worker threads, and
//! the [`crate::exp`] engine spreads whole scenarios.  Determinism is the
//! contract: every job carries its own pre-forked state (e.g. an RNG), the
//! result of job `i` always lands in slot `i`, and the output is therefore
//! **bitwise identical** for any thread count — `threads = 1` is plain
//! sequential execution with zero synchronization.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::Result;

/// Number of workers the machine supports (fallback 1).
pub fn auto_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a requested thread count: `0` means auto, and the pool is never
/// wider than the job list.
pub fn effective_threads(requested: usize, jobs: usize) -> usize {
    let t = if requested == 0 {
        auto_threads()
    } else {
        requested
    };
    t.clamp(1, jobs.max(1))
}

/// Run every job through `f`, fanned over `threads` scoped workers.
///
/// * `init` builds one per-worker scratch state `S` (reused across that
///   worker's jobs — e.g. a [`crate::fl::LocalTrainer`]'s batch buffers);
/// * `f(state, job)` consumes one job and produces its result;
/// * results come back in job order regardless of scheduling.
///
/// On a job error the pool stops claiming further jobs (in-flight jobs
/// finish) and the first error in job order is propagated, mirroring the
/// sequential path's stop-at-first-failure behaviour.
pub fn fan_out<J, S, T, I, F>(jobs: Vec<J>, threads: usize, init: I, f: F) -> Result<Vec<T>>
where
    J: Send,
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, J) -> Result<T> + Sync,
{
    let n = jobs.len();
    let threads = effective_threads(threads, n);
    if threads <= 1 {
        let mut state = init();
        return jobs.into_iter().map(|j| f(&mut state, j)).collect();
    }

    let jobs: Vec<Mutex<Option<J>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let slots: Vec<Mutex<Option<Result<T>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut state = init();
                while !failed.load(Ordering::Relaxed) {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let job = jobs[i]
                        .lock()
                        .unwrap()
                        .take()
                        .expect("each job index is claimed exactly once");
                    let res = f(&mut state, job);
                    if res.is_err() {
                        failed.store(true, Ordering::Relaxed);
                    }
                    *slots[i].lock().unwrap() = Some(res);
                }
            });
        }
    });

    // Claims are issued in index order, so visited slots form a prefix:
    // the first error (if any) appears before any unvisited slot.
    let mut out = Vec::with_capacity(n);
    for s in slots {
        match s.into_inner().expect("no fan-out worker panicked") {
            Some(Ok(v)) => out.push(v),
            Some(Err(e)) => return Err(e),
            None => anyhow::bail!("fan-out aborted after an earlier job failed"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn jobs(n: usize) -> Vec<(usize, Rng)> {
        (0..n).map(|i| (i, Rng::new(1000 + i as u64))).collect()
    }

    fn work(_state: &mut (), (id, mut rng): (usize, Rng)) -> Result<u64> {
        // Enough draws that interleaving mistakes would surface.
        let mut acc = id as u64;
        for _ in 0..257 {
            acc = acc.wrapping_add(rng.next_u64());
        }
        Ok(acc)
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let base = fan_out(jobs(13), 1, || (), work).unwrap();
        for threads in [2, 3, 4, 8] {
            let par = fan_out(jobs(13), threads, || (), work).unwrap();
            assert_eq!(par, base, "threads = {threads}");
        }
    }

    #[test]
    fn results_are_in_job_order() {
        let out = fan_out(
            (0..32).collect::<Vec<usize>>(),
            4,
            || (),
            |_, j| Ok(j * 10),
        )
        .unwrap();
        assert_eq!(out, (0..32).map(|j| j * 10).collect::<Vec<_>>());
    }

    #[test]
    fn per_worker_state_is_reused() {
        // Each worker counts its own jobs; the grand total must be n.
        let counts: Vec<usize> = fan_out(
            (0..20).collect::<Vec<usize>>(),
            3,
            || 0usize,
            |seen, _| {
                *seen += 1;
                Ok(*seen)
            },
        )
        .unwrap();
        assert_eq!(counts.len(), 20);
        assert!(counts.iter().all(|&c| c >= 1));
    }

    #[test]
    fn errors_propagate() {
        let res = fan_out(
            (0..8).collect::<Vec<usize>>(),
            2,
            || (),
            |_, j| {
                if j == 5 {
                    anyhow::bail!("job {j} failed")
                } else {
                    Ok(j)
                }
            },
        );
        assert!(res.is_err());
    }

    #[test]
    fn effective_threads_clamps() {
        assert_eq!(effective_threads(4, 2), 2);
        assert_eq!(effective_threads(1, 100), 1);
        assert_eq!(effective_threads(0, 0), 1);
        assert!(effective_threads(0, 64) >= 1);
    }
}
