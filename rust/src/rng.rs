//! Deterministic PRNG + sampling substrate.
//!
//! The vendored offline registry has no `rand` crate, so the simulator's
//! randomness is built here from scratch: a [`SplitMix64`]-seeded
//! [`Xoshiro256pp`] generator plus the distributions the paper's
//! experiment section needs — exponential channel gains, Gaussian data
//! clusters, Gamma/Dirichlet partitions, and categorical /
//! with-replacement client sampling.
//!
//! Everything is reproducible: a run is fully determined by its seed, and
//! independent sub-streams (per device, per round) are derived with
//! [`Rng::fork`] so policies can be compared on *identical* channel
//! realizations, as the paper does ("we fix the random seed of random
//! channel gain across different runnings").

/// SplitMix64: seed expander (Vigna). Used to initialize xoshiro state and
/// to derive fork keys.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 (Blackman & Vigna): fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }
}

/// The simulator-facing RNG: xoshiro core + distribution methods.
#[derive(Clone, Debug)]
pub struct Rng {
    core: Xoshiro256pp,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self {
            core: Xoshiro256pp::new(seed),
        }
    }

    /// Derive an independent sub-stream keyed by `key` (order-free: the
    /// fork depends only on the parent's seed material, not on how many
    /// draws happened — callers should fork from a dedicated root).
    pub fn fork(&mut self, key: u64) -> Rng {
        let base = self.next_u64();
        let mut sm = SplitMix64::new(base ^ key.wrapping_mul(0xA24B_AED4_963E_E407));
        Rng::new(sm.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.core.next_u64()
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our n sizes).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply trick; bias < 2^-64 * n, negligible for n <= 2^32.
        let m = (self.next_u64() as u128).wrapping_mul(n as u128);
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (polar-free, uses both uniforms).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential with mean `mean` (inverse CDF).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        debug_assert!(mean > 0.0);
        loop {
            let u = self.f64();
            if u < 1.0 {
                return -mean * (1.0 - u).ln();
            }
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (2000); boosts shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        debug_assert!(shape > 0.0);
        if shape < 1.0 {
            // Boost: X(a) = X(a+1) * U^{1/a}
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v3;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// Dirichlet(alpha * 1) over `n` categories (normalized Gammas).
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..n).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let sum: f64 = g.iter().sum();
        for v in &mut g {
            *v /= sum;
        }
        g
    }

    /// One draw from a categorical distribution given by `probs`
    /// (need not be exactly normalized; linear scan).
    pub fn categorical(&mut self, probs: &[f64]) -> usize {
        let total: f64 = probs.iter().sum();
        let mut u = self.f64() * total;
        for (i, &p) in probs.iter().enumerate() {
            u -= p;
            if u <= 0.0 {
                return i;
            }
        }
        probs.len() - 1
    }

    /// Sample `k` indices **with replacement** from `probs` — the paper's
    /// Algorithm 1 line 5 ("samples K times by {q_n}").
    pub fn sample_with_replacement(&mut self, probs: &[f64], k: usize) -> Vec<usize> {
        (0..k).map(|_| self.categorical(probs)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random f32 vector of standard normals (data generation helper).
    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn deterministic_across_clones() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_streams_are_independent_of_parent_draws() {
        // Forking twice with different keys gives different streams.
        let mut root = Rng::new(7);
        let mut f1 = root.fork(1);
        let mut f2 = root.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn uniform_unit_interval() {
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            let u = rng.f64();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(4);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(5);
        let xs: Vec<f64> = (0..200_000).map(|_| rng.normal()).collect();
        let (mean, var) = moments(&xs);
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_moments() {
        let mut rng = Rng::new(6);
        let mean_target = 0.1; // the paper's channel-gain mean
        let xs: Vec<f64> = (0..200_000).map(|_| rng.exponential(mean_target)).collect();
        let (mean, var) = moments(&xs);
        assert!((mean - mean_target).abs() < 0.002, "mean {mean}");
        // Var of Exp(mean m) is m^2.
        assert!((var - mean_target * mean_target).abs() < 0.002, "var {var}");
        assert!(xs.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn gamma_moments() {
        let mut rng = Rng::new(7);
        for &shape in &[0.5, 1.0, 2.5, 7.0] {
            let xs: Vec<f64> = (0..100_000).map(|_| rng.gamma(shape)).collect();
            let (mean, var) = moments(&xs);
            assert!((mean - shape).abs() < 0.08 * shape.max(1.0), "shape {shape} mean {mean}");
            assert!((var - shape).abs() < 0.2 * shape.max(1.0), "shape {shape} var {var}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_is_positive() {
        let mut rng = Rng::new(8);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let p = rng.dirichlet(alpha, 120);
            let sum: f64 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn dirichlet_concentration_controls_skew() {
        // Small alpha -> spikier vectors (larger max component), on average.
        let mut rng = Rng::new(9);
        let avg_max = |rng: &mut Rng, alpha: f64| -> f64 {
            (0..200)
                .map(|_| {
                    rng.dirichlet(alpha, 10)
                        .into_iter()
                        .fold(0.0f64, f64::max)
                })
                .sum::<f64>()
                / 200.0
        };
        let spiky = avg_max(&mut rng, 0.1);
        let flat = avg_max(&mut rng, 10.0);
        assert!(spiky > flat + 0.2, "spiky {spiky} flat {flat}");
    }

    #[test]
    fn categorical_matches_probs() {
        let mut rng = Rng::new(10);
        let probs = [0.5, 0.25, 0.125, 0.125];
        let mut counts = [0usize; 4];
        for _ in 0..100_000 {
            counts[rng.categorical(&probs)] += 1;
        }
        for (i, &p) in probs.iter().enumerate() {
            let f = counts[i] as f64 / 100_000.0;
            assert!((f - p).abs() < 0.01, "idx {i}: {f} vs {p}");
        }
    }

    #[test]
    fn with_replacement_selection_probability() {
        // P(selected at least once in K draws) = 1 - (1-q)^K — the exact
        // expression the paper's energy constraint (16) uses.
        let mut rng = Rng::new(11);
        let probs = [0.4, 0.3, 0.2, 0.1];
        let k = 2;
        let trials = 200_000;
        let mut hit = [0usize; 4];
        for _ in 0..trials {
            let sel = rng.sample_with_replacement(&probs, k);
            let mut seen = [false; 4];
            for s in sel {
                seen[s] = true;
            }
            for i in 0..4 {
                if seen[i] {
                    hit[i] += 1;
                }
            }
        }
        for i in 0..4 {
            let emp = hit[i] as f64 / trials as f64;
            let theory = 1.0 - (1.0 - probs[i]).powi(k as i32);
            assert!((emp - theory).abs() < 0.005, "idx {i}: {emp} vs {theory}");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::new(12);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
