//! Typed execution of the AOT artifacts on the PJRT CPU client.

use std::path::Path;

use anyhow::Context;

use super::manifest::{Manifest, VariantInfo};
// `xla::` is the engine's single binding point.  Without the `pjrt`
// feature it is the in-tree API-compatible stub; with the feature it is
// *still the stub* until the real bindings crate is vendored into the
// offline registry — the alias below is the one line to swap then.
// Keeping the feature compilable either way lets CI's feature-matrix
// job (`cargo check --features pjrt`) guard the gated code path today,
// instead of a compile_error! tripping before anything is checked.
#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;
#[cfg(feature = "pjrt")]
use super::xla_stub as xla; // TODO(vendoring): `use ::xla;` once the crate lands
use crate::Result;

/// Output of one local SGD step.
#[derive(Clone, Debug)]
pub struct TrainOutput {
    pub params: Vec<f32>,
    pub momentum: Vec<f32>,
    pub loss: f32,
}

/// Compiled executables for one model variant, plus the PJRT client.
///
/// Loading compiles each HLO module once; every later call is pure
/// execution (no python, no recompilation).
pub struct Engine {
    client: xla::PjRtClient,
    pub variant: VariantInfo,
    init_exe: xla::PjRtLoadedExecutable,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
    agg_exe: xla::PjRtLoadedExecutable,
}

impl Engine {
    /// Load + compile all artifacts of `variant` from the manifest root.
    pub fn load(manifest: &Manifest, variant: &str) -> Result<Engine> {
        let info = manifest.variant(variant)?.clone();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |fn_name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = info.artifact_path(&manifest.root, fn_name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
            )
            .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {fn_name} for {variant}"))
        };
        Ok(Engine {
            init_exe: compile("init")?,
            train_exe: compile("train_step")?,
            eval_exe: compile("eval_batch")?,
            agg_exe: compile("aggregate")?,
            client,
            variant: info,
        })
    }

    /// Convenience: load straight from an artifacts directory.
    pub fn from_dir(artifacts_dir: &Path, variant: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        Engine::load(&manifest, variant)
    }

    pub fn dim(&self) -> usize {
        self.variant.dim
    }

    /// `init(seed) -> theta` (flat He-initialized parameters).
    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let seed_lit = xla::Literal::scalar(seed);
        let out = self.run1(&self.init_exe, &[seed_lit])?;
        let theta = out.to_vec::<f32>()?;
        anyhow::ensure!(theta.len() == self.variant.dim, "init returned wrong dim");
        Ok(theta)
    }

    /// One momentum-SGD minibatch step.
    ///
    /// `x` is `[train_batch * H * W * C]` row-major, `y` is
    /// `[train_batch]` labels.
    pub fn train_step(
        &self,
        theta: &[f32],
        momentum: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<TrainOutput> {
        let v = &self.variant;
        let b = v.train_batch;
        debug_assert_eq!(theta.len(), v.dim);
        debug_assert_eq!(momentum.len(), v.dim);
        debug_assert_eq!(x.len(), b * v.input_features());
        debug_assert_eq!(y.len(), b);
        let args = [
            xla::Literal::vec1(theta),
            xla::Literal::vec1(momentum),
            xla::Literal::vec1(x).reshape(&[
                b as i64,
                v.input_hw.0 as i64,
                v.input_hw.1 as i64,
                v.input_c as i64,
            ])?,
            xla::Literal::vec1(y),
            xla::Literal::scalar(lr),
        ];
        let result = self.exec(&self.train_exe, &args)?;
        let (p, m, l) = result.to_tuple3()?;
        Ok(TrainOutput {
            params: p.to_vec::<f32>()?,
            momentum: m.to_vec::<f32>()?,
            loss: l.get_first_element::<f32>()?,
        })
    }

    /// Masked evaluation over one padded batch: `(loss_sum, correct)`.
    pub fn eval_batch(&self, theta: &[f32], x: &[f32], y: &[i32], mask: &[f32]) -> Result<(f32, f32)> {
        let v = &self.variant;
        let b = v.eval_batch;
        debug_assert_eq!(x.len(), b * v.input_features());
        debug_assert_eq!(y.len(), b);
        debug_assert_eq!(mask.len(), b);
        let args = [
            xla::Literal::vec1(theta),
            xla::Literal::vec1(x).reshape(&[
                b as i64,
                v.input_hw.0 as i64,
                v.input_hw.1 as i64,
                v.input_c as i64,
            ])?,
            xla::Literal::vec1(y),
            xla::Literal::vec1(mask),
        ];
        let result = self.exec(&self.eval_exe, &args)?;
        let (loss_sum, correct) = result.to_tuple2()?;
        Ok((
            loss_sum.get_first_element::<f32>()?,
            correct.get_first_element::<f32>()?,
        ))
    }

    /// Eq. (4) aggregation via the Pallas kernel artifact.
    ///
    /// `deltas[k]` are client model deltas; unused slots (up to `k_max`)
    /// are zero-padded with zero coefficients.
    pub fn aggregate(&self, theta: &[f32], deltas: &[&[f32]], coefs: &[f32]) -> Result<Vec<f32>> {
        let v = &self.variant;
        let d = v.dim;
        anyhow::ensure!(
            deltas.len() == coefs.len() && deltas.len() <= v.k_max,
            "aggregate: {} deltas / {} coefs vs k_max {}",
            deltas.len(),
            coefs.len(),
            v.k_max
        );
        let mut stacked = vec![0.0f32; v.k_max * d];
        for (k, delta) in deltas.iter().enumerate() {
            debug_assert_eq!(delta.len(), d);
            stacked[k * d..(k + 1) * d].copy_from_slice(delta);
        }
        let mut coefs_pad = vec![0.0f32; v.k_max];
        coefs_pad[..coefs.len()].copy_from_slice(coefs);

        let args = [
            xla::Literal::vec1(theta),
            xla::Literal::vec1(&stacked).reshape(&[v.k_max as i64, d as i64])?,
            xla::Literal::vec1(&coefs_pad),
        ];
        let out = self.run1(&self.agg_exe, &args)?;
        Ok(out.to_vec::<f32>()?)
    }

    // -- internals --------------------------------------------------------

    fn exec(&self, exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
        let buffers = exe.execute::<xla::Literal>(args)?;
        let lit = buffers[0][0].to_literal_sync()?;
        Ok(lit)
    }

    /// Execute and unwrap a 1-tuple result.
    fn run1(&self, exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
        Ok(self.exec(exe, args)?.to_tuple1()?)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

// Local training fans one engine out across scoped worker threads (the
// executables are only ever *read* after load, and PJRT CPU execution is
// internally synchronized per the PJRT API contract).  The stub build
// derives these automatically; the real bindings hold opaque handles, so
// the claim is asserted here once for the whole crate.
#[cfg(feature = "pjrt")]
unsafe impl Send for Engine {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Engine {}

#[cfg(test)]
mod tests {
    //! Integration tests against the real AOT artifacts; each test skips
    //! (with a notice) when `make artifacts` has not run yet.

    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("skipping engine test: run `make artifacts` first");
            None
        }
    }

    fn engine(variant: &str) -> Option<Engine> {
        artifacts_dir().map(|d| Engine::from_dir(&d, variant).expect("engine load"))
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let Some(eng) = engine("femnist") else { return };
        let a = eng.init_params(0).unwrap();
        let b = eng.init_params(0).unwrap();
        let c = eng.init_params(1).unwrap();
        assert_eq!(a.len(), eng.dim());
        assert_eq!(a, b);
        assert_ne!(a, c);
        // He init: roughly zero-mean, finite std.
        let mean: f32 = a.iter().sum::<f32>() / a.len() as f32;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn train_step_learns_fixed_batch() {
        let Some(eng) = engine("femnist") else { return };
        let v = eng.variant.clone();
        let theta0 = eng.init_params(7).unwrap();
        let mut theta = theta0.clone();
        let mut mom = vec![0.0; eng.dim()];
        // Deterministic synthetic batch.
        let feats = v.input_features();
        let x: Vec<f32> = (0..v.train_batch * feats)
            .map(|i| ((i as f32 * 0.037).sin()) * 0.5)
            .collect();
        let y: Vec<i32> = (0..v.train_batch).map(|i| (i % v.num_classes) as i32).collect();
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for step in 0..10 {
            let out = eng.train_step(&theta, &mom, &x, &y, 0.05).unwrap();
            theta = out.params;
            mom = out.momentum;
            if step == 0 {
                first = out.loss;
            }
            last = out.loss;
        }
        assert!(first.is_finite() && last.is_finite());
        assert!(
            last < first * 0.9,
            "loss should fall on a fixed batch: {first} -> {last}"
        );
        assert_ne!(theta, theta0);
    }

    #[test]
    fn eval_counts_respect_mask() {
        let Some(eng) = engine("femnist") else { return };
        let v = eng.variant.clone();
        let theta = eng.init_params(3).unwrap();
        let feats = v.input_features();
        let x: Vec<f32> = vec![0.1; v.eval_batch * feats];
        let y: Vec<i32> = vec![0; v.eval_batch];
        let ones = vec![1.0f32; v.eval_batch];
        let zeros = vec![0.0f32; v.eval_batch];
        let (loss_all, correct_all) = eng.eval_batch(&theta, &x, &y, &ones).unwrap();
        let (loss_none, correct_none) = eng.eval_batch(&theta, &x, &y, &zeros).unwrap();
        assert!(loss_all > 0.0);
        assert!(correct_all >= 0.0 && correct_all <= v.eval_batch as f32);
        assert_eq!(loss_none, 0.0);
        assert_eq!(correct_none, 0.0);
        // Half mask = strictly between.
        let mut half = zeros.clone();
        for m in half.iter_mut().take(v.eval_batch / 2) {
            *m = 1.0;
        }
        let (loss_half, _) = eng.eval_batch(&theta, &x, &y, &half).unwrap();
        assert!(loss_half > 0.0 && loss_half < loss_all);
    }

    #[test]
    fn aggregate_matches_cpu_reference() {
        let Some(eng) = engine("femnist") else { return };
        let d = eng.dim();
        let theta: Vec<f32> = (0..d).map(|i| (i as f32 * 1e-3).sin()).collect();
        let d0: Vec<f32> = (0..d).map(|i| (i as f32 * 2e-3).cos() * 0.1).collect();
        let d1: Vec<f32> = (0..d).map(|i| (i as f32 * 3e-3).sin() * -0.2).collect();
        let coefs = [0.7f32, 1.4f32];
        let out = eng.aggregate(&theta, &[&d0, &d1], &coefs).unwrap();
        for i in (0..d).step_by(997) {
            let expect = theta[i] + 0.7 * d0[i] + 1.4 * d1[i];
            assert!(
                (out[i] - expect).abs() < 1e-4,
                "i={i}: {} vs {expect}",
                out[i]
            );
        }
    }

    #[test]
    fn cifar_variant_loads_too() {
        let Some(eng) = engine("cifar") else { return };
        assert!(eng.dim() > 100_000);
        let theta = eng.init_params(0).unwrap();
        assert_eq!(theta.len(), eng.dim());
    }
}
