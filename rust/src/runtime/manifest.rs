//! Artifact manifest: the model geometry the AOT pass exports.

use std::path::{Path, PathBuf};

use crate::json::Json;
use crate::Result;

/// One named parameter tensor (mirrors `model.LayerSpec`).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
}

/// Geometry of one model variant.
#[derive(Clone, Debug)]
pub struct VariantInfo {
    pub name: String,
    /// Flat parameter count `d`.
    pub dim: usize,
    /// Model update size in bits (the paper's `M = 32 d`).
    pub model_bits: usize,
    pub input_hw: (usize, usize),
    pub input_c: usize,
    pub num_classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub k_max: usize,
    pub layers: Vec<LayerInfo>,
    /// Exported computation names.
    pub artifacts: Vec<String>,
}

impl VariantInfo {
    /// Per-example input feature count `H*W*C`.
    pub fn input_features(&self) -> usize {
        self.input_hw.0 * self.input_hw.1 * self.input_c
    }

    /// Path of one HLO artifact under `root`.
    pub fn artifact_path(&self, root: &Path, fn_name: &str) -> PathBuf {
        root.join(&self.name).join(format!("{fn_name}.hlo.txt"))
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub variants: Vec<VariantInfo>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?} (run `make artifacts`): {e}"))?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        Self::from_json(artifacts_dir.to_path_buf(), &json)
    }

    pub fn from_json(root: PathBuf, json: &Json) -> Result<Manifest> {
        let fmt = json.get("format").and_then(Json::as_str).unwrap_or("");
        anyhow::ensure!(fmt == "hlo-text", "unsupported artifact format {fmt:?}");
        let vars = json
            .get("variants")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing `variants`"))?;
        let mut variants = Vec::new();
        for (name, v) in vars {
            variants.push(parse_variant(name, v)?);
        }
        anyhow::ensure!(!variants.is_empty(), "manifest has no variants");
        Ok(Manifest { root, variants })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantInfo> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "variant {name:?} not in manifest (have: {:?})",
                    self.variants.iter().map(|v| &v.name).collect::<Vec<_>>()
                )
            })
    }
}

fn field_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("manifest variant missing `{key}`"))
}

fn parse_variant(name: &str, v: &Json) -> Result<VariantInfo> {
    let hw = v
        .get("input_hw")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing input_hw"))?;
    anyhow::ensure!(hw.len() == 2, "input_hw must have 2 entries");
    let layers = v
        .get("layers")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing layers"))?
        .iter()
        .map(|l| -> Result<LayerInfo> {
            Ok(LayerInfo {
                name: l
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("layer missing name"))?
                    .to_string(),
                shape: l
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("layer missing shape"))?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect(),
                size: field_usize(l, "size")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let artifacts = v
        .get("artifacts")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing artifacts"))?
        .iter()
        .filter_map(Json::as_str)
        .map(str::to_string)
        .collect::<Vec<_>>();

    let info = VariantInfo {
        name: name.to_string(),
        dim: field_usize(v, "dim")?,
        model_bits: field_usize(v, "model_bits")?,
        input_hw: (
            hw[0].as_usize().unwrap_or_default(),
            hw[1].as_usize().unwrap_or_default(),
        ),
        input_c: field_usize(v, "input_c")?,
        num_classes: field_usize(v, "num_classes")?,
        train_batch: field_usize(v, "train_batch")?,
        eval_batch: field_usize(v, "eval_batch")?,
        k_max: field_usize(v, "k_max")?,
        layers,
        artifacts,
    };
    // Cross-check: layer sizes must sum to dim.
    let sum: usize = info.layers.iter().map(|l| l.size).sum();
    anyhow::ensure!(
        sum == info.dim,
        "layer sizes sum {sum} != dim {} for {name}",
        info.dim
    );
    Ok(info)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(
            r#"{
              "format": "hlo-text",
              "variants": {
                "femnist": {
                  "dim": 300, "model_bits": 9600,
                  "input_hw": [28, 28], "input_c": 1, "num_classes": 62,
                  "train_batch": 32, "eval_batch": 64, "k_max": 8,
                  "layers": [
                    {"name": "a", "shape": [10, 10], "size": 100},
                    {"name": "b", "shape": [200], "size": 200}
                  ],
                  "artifacts": ["init", "train_step", "eval_batch", "aggregate"]
                }
              }
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(PathBuf::from("/tmp/a"), &sample_json()).unwrap();
        let v = m.variant("femnist").unwrap();
        assert_eq!(v.dim, 300);
        assert_eq!(v.input_hw, (28, 28));
        assert_eq!(v.input_features(), 784);
        assert_eq!(v.layers.len(), 2);
        assert_eq!(
            v.artifact_path(&m.root, "init"),
            PathBuf::from("/tmp/a/femnist/init.hlo.txt")
        );
        assert!(m.variant("nope").is_err());
    }

    #[test]
    fn rejects_dim_mismatch() {
        let mut text = sample_json().to_string();
        text = text.replace("\"dim\":300", "\"dim\":999");
        let json = Json::parse(&text).unwrap();
        assert!(Manifest::from_json(PathBuf::from("/x"), &json).is_err());
    }

    #[test]
    fn rejects_wrong_format() {
        let json = Json::parse(r#"{"format": "proto", "variants": {}}"#).unwrap();
        assert!(Manifest::from_json(PathBuf::from("/x"), &json).is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // Integration against the actual AOT output when it exists.
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            for v in &m.variants {
                assert!(v.dim > 0);
                assert_eq!(v.model_bits, 32 * v.dim);
                for a in &v.artifacts {
                    assert!(
                        v.artifact_path(&m.root, a).exists(),
                        "missing artifact {a} for {}",
                        v.name
                    );
                }
            }
        }
    }
}
