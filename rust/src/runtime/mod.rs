//! PJRT runtime: load AOT artifacts once, execute them on the hot path.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (model geometry);
//! * [`engine`] — wraps the `xla` crate: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `compile` → typed `execute`
//!   helpers for the four exported computations.
//!
//! Python is never on this path: once `make artifacts` has produced the
//! HLO text files, the rust binary is self-contained.

mod engine;
mod manifest;
#[cfg(not(feature = "pjrt"))]
pub(crate) mod xla_stub;

// The feature flips engine.rs from the stub to the real bindings, which
// are not in the offline registry yet — fail with the instruction
// instead of an opaque unresolved-crate error.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires the `xla` PJRT bindings crate: add it to \
     [dependencies] in Cargo.toml and delete this guard (runtime/mod.rs)"
);

pub use engine::{Engine, TrainOutput};
pub use manifest::{LayerInfo, Manifest, VariantInfo};
