//! PJRT runtime: load AOT artifacts once, execute them on the hot path.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (model geometry);
//! * [`engine`] — wraps the `xla` crate: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `compile` → typed `execute`
//!   helpers for the four exported computations.
//!
//! Python is never on this path: once `make artifacts` has produced the
//! HLO text files, the rust binary is self-contained.

mod engine;
mod manifest;
pub(crate) mod xla_stub;

pub use engine::{Engine, TrainOutput};
pub use manifest::{LayerInfo, Manifest, VariantInfo};
