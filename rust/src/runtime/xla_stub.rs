//! Offline stand-in for the `xla` PJRT bindings crate.
//!
//! The vendored registry carries no PJRT bindings, so default builds
//! compile [`super::engine`] against this API-compatible stub instead
//! (`engine.rs` aliases it to `xla` when the `pjrt` feature is off).
//! Creating the client fails immediately with a clear message, which
//! surfaces through `Server::new(_, SimMode::Full)`; control-plane-only
//! simulation never touches this module.  Enabling the `pjrt` feature
//! (plus adding the real `xla` dependency) swaps the stub out without
//! touching the engine code.

use std::fmt;

/// Stub error type (mirrors the binding crate's error surface enough for
/// `anyhow` context chaining).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT runtime not linked: this build uses the offline stub. \
         Rebuild with `--features pjrt` and the `xla` bindings crate \
         available, then run `make artifacts`."
            .to_string(),
    ))
}

/// Element types the engine moves across the boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side literal (stub: carries nothing).
pub struct Literal;

impl Literal {
    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal
    }

    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(self)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T, Error> {
        unavailable()
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal), Error> {
        unavailable()
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal), Error> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// Computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-side buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// PJRT client (stub: construction always fails, so every Full-mode path
/// reports the missing runtime before touching anything else).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_missing_runtime() {
        let err = match PjRtClient::cpu() {
            Err(e) => e.to_string(),
            Ok(_) => panic!("stub client must not construct"),
        };
        assert!(err.contains("PJRT runtime not linked"), "{err}");
    }

    #[test]
    fn literal_constructors_are_inert() {
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert!(Literal::scalar(3i32).get_first_element::<i32>().is_err());
    }
}
