//! Client samplers: probability-driven K-with-replacement (Algorithm 1,
//! line 5), uniform baselines, and the DivFL submodular baseline.
//!
//! A sampler turns per-round state into the selected multiset `K^t` plus
//! the aggregation coefficients `w_n / (K q_n)` of eq. (4).  DivFL is the
//! paper's third baseline: greedy facility-location maximization over
//! (stale) client update embeddings, adapted — as in the paper — to select
//! `K` distinct clients with uniform aggregation semantics.

use crate::rng::Rng;

/// The first `k` positions of `sort_by(cmp)` over `0..n`, found with a
/// bounded heap in `O(n log k)` instead of a full `O(n log n)` sort —
/// the fleet-scale replacement for "sort the whole pool, truncate to K"
/// in the deterministic selectors (at 1M devices and K=10 the full sort
/// dominates the round).
///
/// `cmp` must be a **total order** over positions (every comparator in
/// this crate breaks score ties by position precisely so this holds).
/// Under that contract the returned vector is *identical* — same ids,
/// same order — to `(0..n).collect::<Vec<_>>()` sorted by `cmp` and
/// truncated to `k`, pinned by the equality tests below.
pub fn top_k_by<F>(n: usize, k: usize, mut cmp: F) -> Vec<usize>
where
    F: FnMut(usize, usize) -> std::cmp::Ordering,
{
    use std::cmp::Ordering;
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    // Seed the heap with the first k positions, root = the worst kept
    // candidate (greatest under `cmp`), via Floyd heapify.
    let mut heap: Vec<usize> = (0..k).collect();
    for pos in (0..k / 2).rev() {
        sift_down(&mut heap, pos, &mut cmp);
    }
    for i in k..n {
        if cmp(i, heap[0]) == Ordering::Less {
            heap[0] = i;
            sift_down(&mut heap, 0, &mut cmp);
        }
    }
    heap.sort_unstable_by(|&a, &b| cmp(a, b));
    heap
}

/// Restore the max-heap property (w.r.t. `cmp`) below `pos`.
fn sift_down<F>(heap: &mut [usize], mut pos: usize, cmp: &mut F)
where
    F: FnMut(usize, usize) -> std::cmp::Ordering,
{
    use std::cmp::Ordering;
    let len = heap.len();
    loop {
        let left = 2 * pos + 1;
        if left >= len {
            break;
        }
        let mut worst = left;
        let right = left + 1;
        if right < len && cmp(heap[right], heap[left]) == Ordering::Greater {
            worst = right;
        }
        if cmp(heap[worst], heap[pos]) == Ordering::Greater {
            heap.swap(pos, worst);
            pos = worst;
        } else {
            break;
        }
    }
}

/// One round's selection: the sampled multiset and eq. (4) coefficients.
#[derive(Clone, Debug)]
pub struct Selection {
    /// Sampled device ids, **with multiplicity** (K entries).
    pub members: Vec<usize>,
    /// Aggregation coefficient per member slot: `w_n / (K q_n)`.
    pub coefs: Vec<f64>,
}

impl Selection {
    /// Unique device ids (each trains once even if drawn twice; its
    /// delta is weighted by the slot multiplicity via repeated coefs).
    pub fn unique_members(&self) -> Vec<usize> {
        let mut u = self.members.clone();
        u.sort_unstable();
        u.dedup();
        u
    }
}

/// Sample `K` times with replacement from `q`, producing eq. (4) coefs.
pub fn sample_by_probability(q: &[f64], weights: &[f64], k: usize, rng: &mut Rng) -> Selection {
    let members = rng.sample_with_replacement(q, k);
    let coefs = members
        .iter()
        .map(|&n| weights[n] / (k as f64 * q[n]))
        .collect();
    Selection { members, coefs }
}

/// Uniform sampling (`q = 1/N`), the FedAvg default.
pub fn sample_uniform(n: usize, weights: &[f64], k: usize, rng: &mut Rng) -> Selection {
    let q = vec![1.0 / n as f64; n];
    sample_by_probability(&q, weights, k, rng)
}

/// Exact per-slot marginals of the power-of-two-choices draw over
/// `scores`: pick two devices uniformly with replacement, keep the
/// better score (ties: lower position wins).  `P(n) = (1 + 2·worse_n) /
/// N²` where `worse_n` counts the devices `n` beats — a proper
/// distribution (sums to 1), so eq. (4) coefficients `w_n / (K q_n)`
/// keep the aggregate unbiased.
pub fn p2c_marginals(scores: &[f64]) -> Vec<f64> {
    let n = scores.len();
    // Ascending in the "beats" total order: worse scores first; among
    // equals the larger position first (the lower position wins ties).
    // The position tie-break makes this a total order, so the unstable
    // sort is deterministic (and avoids the stable sort's scratch
    // allocation).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_unstable_by(|&a, &b| {
        scores[a]
            .partial_cmp(&scores[b])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(b.cmp(&a))
    });
    let mut q = vec![0.0; n];
    for (rank, &i) in order.iter().enumerate() {
        q[i] = (1 + 2 * rank) as f64 / (n * n) as f64;
    }
    q
}

/// Power-of-two-choices sampling: `k` slots, each the better-scored of
/// two independent uniform draws.  `marginals` must be
/// [`p2c_marginals`]`(scores)` (passed in so callers can reuse it as the
/// round's sampling distribution without recomputing).
pub fn sample_power_of_two(
    scores: &[f64],
    marginals: &[f64],
    weights: &[f64],
    k: usize,
    rng: &mut Rng,
) -> Selection {
    let n = scores.len();
    let members: Vec<usize> = (0..k)
        .map(|_| {
            let a = rng.below(n);
            let b = rng.below(n);
            let a_wins = scores[a] > scores[b] || (scores[a] == scores[b] && a <= b);
            if a_wins {
                a
            } else {
                b
            }
        })
        .collect();
    let coefs = members
        .iter()
        .map(|&m| weights[m] / (k as f64 * marginals[m]))
        .collect();
    Selection { members, coefs }
}

/// Exact sampling distribution of the contextual-bandit scheduler: a
/// temperature-`temp` softmax over the per-device scores, mixed with a
/// uniform exploration floor `eps`.  The result is renormalized exactly,
/// so it is a proper distribution (sums to 1, every entry strictly
/// positive) and can serve directly as both the round's sampling
/// distribution and the eq. (4) marginals — the same unbiasedness
/// contract [`p2c_marginals`] provides for P2C.
pub fn softmax_distribution(scores: &[f64], temp: f64, eps: f64) -> Vec<f64> {
    let n = scores.len();
    assert!(n > 0, "empty score vector");
    assert!(temp > 0.0 && (0.0..1.0).contains(&eps), "bad temp/eps");
    // Max-shifted for overflow safety; the shift cancels in the ratio.
    let m = scores.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let w: Vec<f64> = scores.iter().map(|s| ((s - m) / temp).exp()).collect();
    let wsum: f64 = w.iter().sum();
    let mut q: Vec<f64> = w
        .iter()
        .map(|x| (1.0 - eps) * x / wsum + eps / n as f64)
        .collect();
    // Exact renormalization: floating error in the mixture must not
    // leak a bias into the `w_n / (K q_n)` coefficients.
    let total: f64 = q.iter().sum();
    for v in &mut q {
        *v /= total;
    }
    q
}

/// FedAvg-style aggregation over a *distinct* member set: slot
/// coefficient `w_n / Σ_{m∈S} w_m` (the DivFL convention, shared by the
/// deterministic greedy-channel and round-robin baselines).
pub fn fedavg_selection(members: Vec<usize>, weights: &[f64]) -> Selection {
    assert!(!members.is_empty(), "fedavg_selection: empty member set");
    let wsum: f64 = members.iter().map(|&m| weights[m]).sum();
    // A zero/non-finite weight mass would emit coefs summing to ~0 and
    // silently shrink the aggregate toward the origin; every caller
    // passes strictly-positive data weights, so this is corruption, not
    // a state to paper over.
    assert!(
        wsum > 0.0 && wsum.is_finite(),
        "fedavg_selection: member weights sum to {wsum}, cannot normalize"
    );
    let coefs = members.iter().map(|&m| weights[m] / wsum).collect();
    Selection { members, coefs }
}

/// DivFL: greedy facility-location selection over client embeddings.
///
/// The paper adapts DivFL [42] to this setting: the server keeps an
/// embedding per client (here: the client's last observed model-update
/// direction, compressed by random projection; clients never seen yet are
/// cold-started round-robin).  Greedy maximization of
/// `F(S) = Σ_i max_{j∈S} sim(i, j)` picks the `K` most representative
/// clients.  Selected clients aggregate with FedAvg weights (the DivFL
/// convention), i.e. coef = `w_n / Σ_{m∈S} w_m` per *unique* member.
pub struct DivFlState {
    /// Per-client embedding (zero until first participation).
    pub embeddings: Vec<Vec<f32>>,
    /// Whether the client has ever reported an update.
    pub seen: Vec<bool>,
    /// Round-robin cursor for cold-start probing.
    cursor: usize,
    dim: usize,
}

impl DivFlState {
    pub fn new(n: usize, dim: usize) -> Self {
        Self {
            embeddings: vec![vec![0.0; dim]; n],
            seen: vec![false; n],
            cursor: 0,
            dim,
        }
    }

    /// Record a client's update embedding after it trains.
    pub fn observe(&mut self, client: usize, embedding: Vec<f32>) {
        debug_assert_eq!(embedding.len(), self.dim);
        self.embeddings[client] = embedding;
        self.seen[client] = true;
    }

    /// Greedy facility-location selection of `k` distinct clients over
    /// the whole fleet.
    pub fn select(&mut self, weights: &[f64], k: usize) -> Selection {
        let ids: Vec<usize> = (0..self.embeddings.len()).collect();
        self.select_among(&ids, weights, k)
    }

    /// Greedy facility-location selection restricted to a candidate set.
    ///
    /// `ids[pos]` is the *global* client id at position `pos` (the
    /// environment's reachable set `N^t`); `weights[pos]` is that
    /// client's data weight.  Returned members are **positions** into
    /// `ids`, matching the rest of the policy interface.  With the
    /// identity mapping this is exactly the original full-fleet selector
    /// (same comparisons, same floating-point operations).
    pub fn select_among(&mut self, ids: &[usize], weights: &[f64], k: usize) -> Selection {
        let n = ids.len();
        let k = k.min(n);
        let unseen: Vec<usize> = (0..n).filter(|&pos| !self.seen[ids[pos]]).collect();
        let mut chosen: Vec<usize> = Vec::with_capacity(k);

        // Cold start: probe unseen clients round-robin first so every
        // client eventually contributes an embedding.
        if !unseen.is_empty() {
            for _ in 0..k.min(unseen.len()) {
                let idx = unseen[self.cursor % unseen.len()];
                self.cursor += 1;
                if !chosen.contains(&idx) {
                    chosen.push(idx);
                }
            }
        }

        // Greedy facility location on similarity = -||e_i - e_j||².
        // gain(j | S) = Σ_i [ max(best_i, sim(i,j)) - best_i ].
        if chosen.len() < k {
            let mut best = vec![f64::NEG_INFINITY; n];
            for &j in &chosen {
                for i in 0..n {
                    best[i] = best[i].max(self.sim(ids[i], ids[j]));
                }
            }
            while chosen.len() < k {
                let mut best_j = usize::MAX;
                let mut best_gain = f64::NEG_INFINITY;
                for j in 0..n {
                    if chosen.contains(&j) {
                        continue;
                    }
                    let mut gain = 0.0;
                    for i in 0..n {
                        let s = self.sim(ids[i], ids[j]);
                        if s > best[i] {
                            gain += s - best[i].max(-1e30);
                        }
                    }
                    if gain > best_gain {
                        best_gain = gain;
                        best_j = j;
                    }
                }
                let j = if best_j == usize::MAX { chosen.len() } else { best_j };
                for i in 0..n {
                    best[i] = best[i].max(self.sim(ids[i], ids[j]));
                }
                chosen.push(j);
            }
        }

        fedavg_selection(chosen, weights)
    }

    fn sim(&self, i: usize, j: usize) -> f64 {
        // Negative squared distance; i == j gives 0 (the max).
        let (a, b) = (&self.embeddings[i], &self.embeddings[j]);
        let mut d2 = 0.0f64;
        for t in 0..self.dim {
            let d = (a[t] - b[t]) as f64;
            d2 += d * d;
        }
        -d2
    }
}

/// Random-projection compressor for update embeddings (d -> dim), seeded
/// so every client is projected identically.
pub struct Projector {
    dim: usize,
    seed: u64,
}

impl Projector {
    pub fn new(dim: usize, seed: u64) -> Self {
        Self { dim, seed }
    }

    /// Project a flat model delta to the embedding space with a
    /// pseudo-random ±1 matrix generated on the fly (no d×dim storage).
    pub fn project(&self, delta: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        // Hash-based signs: cheap, deterministic, storage-free.
        for (i, &x) in delta.iter().enumerate() {
            if x == 0.0 {
                continue;
            }
            let mut h = (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ self.seed;
            h ^= h >> 33;
            h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
            let slot = (h % self.dim as u64) as usize;
            let sign = if (h >> 63) == 0 { 1.0 } else { -1.0 };
            out[slot] += sign * x;
        }
        let norm = (delta.len() as f32).sqrt();
        for v in &mut out {
            *v /= norm;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The greedy-channel comparator shape: descending score, ascending
    /// position among ties — a total order.
    fn desc_score_cmp(scores: &[f64]) -> impl FnMut(usize, usize) -> std::cmp::Ordering + '_ {
        |a, b| {
            scores[b]
                .partial_cmp(&scores[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        }
    }

    #[test]
    fn top_k_matches_full_sort_truncate_under_ties() {
        // Deterministic pseudo-random scores with deliberate heavy ties:
        // quantizing to a handful of levels forces the position
        // tie-break to decide most comparisons.
        let mut rng = Rng::new(77);
        for n in [1usize, 2, 3, 7, 17, 64, 257] {
            let scores: Vec<f64> = (0..n).map(|_| (rng.f64() * 4.0).floor() / 4.0).collect();
            for k in [0usize, 1, 2, 3, n / 2, n.saturating_sub(1), n, n + 5] {
                let mut cmp = desc_score_cmp(&scores);
                let mut full: Vec<usize> = (0..n).collect();
                full.sort_by(|&a, &b| cmp(a, b));
                full.truncate(k.min(n));
                let fast = top_k_by(n, k, desc_score_cmp(&scores));
                assert_eq!(fast, full, "n={n} k={k} scores={scores:?}");
            }
        }
    }

    #[test]
    fn top_k_matches_full_sort_on_ascending_keys() {
        // The round-robin comparator shape: ascending wrap-distance keys
        // (all distinct).
        for cursor in 0..10usize {
            let n = 10;
            let key = |pos: usize| (pos + n - cursor) % n;
            let mut full: Vec<usize> = (0..n).collect();
            full.sort_by_key(|&pos| key(pos));
            full.truncate(3);
            let fast = top_k_by(n, 3, |a, b| key(a).cmp(&key(b)));
            assert_eq!(fast, full, "cursor={cursor}");
        }
    }

    #[test]
    fn top_k_all_equal_scores_resolve_by_position() {
        // Fully tied scores: the position tie-break alone must order the
        // result 0..k, exactly like the full sort.
        let scores = vec![0.5; 20];
        let fast = top_k_by(20, 6, desc_score_cmp(&scores));
        assert_eq!(fast, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn selection_has_k_members_and_correct_coefs() {
        let mut rng = Rng::new(1);
        let q = vec![0.25; 4];
        let w = vec![0.1, 0.2, 0.3, 0.4];
        let sel = sample_by_probability(&q, &w, 2, &mut rng);
        assert_eq!(sel.members.len(), 2);
        for (slot, &n) in sel.members.iter().enumerate() {
            let expect = w[n] / (2.0 * q[n]);
            assert!((sel.coefs[slot] - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn aggregation_is_unbiased() {
        // E[Σ_slots coef_slot · v_{n_slot}] = Σ_n w_n v_n  (Appendix A).
        let mut rng = Rng::new(2);
        let q = vec![0.5, 0.3, 0.2];
        let w = vec![0.2, 0.3, 0.5];
        let v = [1.0, 10.0, 100.0];
        let k = 2;
        let trials = 400_000;
        let mut acc = 0.0;
        for _ in 0..trials {
            let sel = sample_by_probability(&q, &w, k, &mut rng);
            for (slot, &n) in sel.members.iter().enumerate() {
                acc += sel.coefs[slot] * v[n];
            }
        }
        let emp = acc / trials as f64;
        let expect: f64 = w.iter().zip(&v).map(|(wn, vn)| wn * vn).sum();
        assert!(
            (emp - expect).abs() / expect < 0.01,
            "empirical {emp} vs {expect}"
        );
    }

    #[test]
    fn uniform_sampler_is_uniform() {
        let mut rng = Rng::new(3);
        let w = vec![0.25; 4];
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            let sel = sample_uniform(4, &w, 1, &mut rng);
            counts[sel.members[0]] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn divfl_cold_start_probes_everyone() {
        let mut st = DivFlState::new(6, 4);
        let w = vec![1.0 / 6.0; 6];
        let mut probed = std::collections::BTreeSet::new();
        for _ in 0..3 {
            let sel = st.select(&w, 2);
            for &m in &sel.members {
                probed.insert(m);
                st.observe(m, vec![0.1; 4]);
            }
        }
        assert_eq!(probed.len(), 6, "round-robin should cover all clients");
    }

    #[test]
    fn divfl_picks_diverse_clients() {
        // Two clusters of embeddings; k=2 must pick one from each.
        let mut st = DivFlState::new(6, 2);
        let w = vec![1.0 / 6.0; 6];
        for i in 0..6 {
            let e = if i < 3 { vec![1.0, 0.0] } else { vec![0.0, 1.0] };
            st.observe(i, e);
        }
        let sel = st.select(&w, 2);
        let a = sel.members[0] < 3;
        let b = sel.members[1] < 3;
        assert_ne!(a, b, "selected {:?} — should span both clusters", sel.members);
        // FedAvg coefs over the distinct set sum to 1.
        let s: f64 = sel.coefs.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn divfl_members_are_distinct() {
        let mut st = DivFlState::new(10, 3);
        let w = vec![0.1; 10];
        for i in 0..10 {
            st.observe(i, vec![i as f32, 0.0, 0.0]);
        }
        let sel = st.select(&w, 4);
        let uniq = sel.unique_members();
        assert_eq!(uniq.len(), 4);
    }

    #[test]
    fn p2c_marginals_are_a_distribution_favoring_high_scores() {
        let scores = vec![0.1, 0.4, 0.2, 0.3];
        let q = p2c_marginals(&scores);
        assert!((q.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // N=4: worst gets 1/16, best gets 7/16.
        assert!((q[0] - 1.0 / 16.0).abs() < 1e-12);
        assert!((q[1] - 7.0 / 16.0).abs() < 1e-12);
        assert!((q[2] - 3.0 / 16.0).abs() < 1e-12);
        assert!((q[3] - 5.0 / 16.0).abs() < 1e-12);
        // Ties resolve deterministically: lower position wins, so it
        // takes the higher marginal.
        let tied = p2c_marginals(&[0.2, 0.2]);
        assert!(tied[0] > tied[1]);
        assert!((tied.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn p2c_empirical_frequencies_match_the_marginals() {
        let scores = vec![0.05, 0.3, 0.1, 0.2, 0.15];
        let q = p2c_marginals(&scores);
        let w = vec![0.2; 5];
        let mut rng = Rng::new(11);
        let mut counts = [0usize; 5];
        let trials = 100_000;
        for _ in 0..trials {
            let sel = sample_power_of_two(&scores, &q, &w, 1, &mut rng);
            counts[sel.members[0]] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let emp = c as f64 / trials as f64;
            assert!(
                (emp - q[i]).abs() < 0.01,
                "device {i}: empirical {emp} vs marginal {}",
                q[i]
            );
        }
    }

    #[test]
    fn p2c_aggregation_is_unbiased() {
        // Same contract as sample_by_probability: eq. (4) coefficients
        // make the aggregate unbiased for any sampling distribution.
        let scores = vec![0.4, 0.1, 0.25];
        let q = p2c_marginals(&scores);
        let w = vec![0.2, 0.3, 0.5];
        let v = [1.0, 10.0, 100.0];
        let k = 2;
        let mut rng = Rng::new(21);
        let trials = 400_000;
        let mut acc = 0.0;
        for _ in 0..trials {
            let sel = sample_power_of_two(&scores, &q, &w, k, &mut rng);
            for (slot, &n) in sel.members.iter().enumerate() {
                acc += sel.coefs[slot] * v[n];
            }
        }
        let emp = acc / trials as f64;
        let expect: f64 = w.iter().zip(&v).map(|(wn, vn)| wn * vn).sum();
        assert!(
            (emp - expect).abs() / expect < 0.01,
            "empirical {emp} vs {expect}"
        );
    }

    #[test]
    fn softmax_distribution_is_a_proper_floored_distribution() {
        let scores = vec![0.1, 0.9, 0.5, 0.3];
        let q = softmax_distribution(&scores, 0.25, 0.05);
        assert!((q.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // Strictly positive everywhere, floored near eps/n.
        for &v in &q {
            assert!(v > 0.04 / 4.0, "floor violated: {v}");
        }
        // Monotone: better scores carry strictly larger marginals.
        let mut idx: Vec<usize> = (0..4).collect();
        idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
        for w in idx.windows(2) {
            assert!(q[w[0]] < q[w[1]]);
        }
        // Temperature → 0 concentrates on the argmax; eps keeps the floor.
        let cold = softmax_distribution(&scores, 0.01, 0.05);
        assert!(cold[1] > 0.9);
        // eps = 0 degenerates to the plain softmax, still a distribution.
        let plain = softmax_distribution(&scores, 0.25, 0.0);
        assert!((plain.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn softmax_empirical_frequencies_match_the_marginals() {
        // The bandit's sampling path is sample_by_probability over the
        // softmax marginals: 1e5 draws must reproduce them within 1%.
        let scores = vec![0.2, 0.7, 0.45, 0.1, 0.55];
        let q = softmax_distribution(&scores, 0.3, 0.05);
        let w = vec![0.2; 5];
        let mut rng = Rng::new(17);
        let mut counts = [0usize; 5];
        let trials = 100_000;
        for _ in 0..trials {
            let sel = sample_by_probability(&q, &w, 1, &mut rng);
            counts[sel.members[0]] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let emp = c as f64 / trials as f64;
            assert!(
                (emp - q[i]).abs() < 0.01,
                "device {i}: empirical {emp} vs marginal {}",
                q[i]
            );
        }
    }

    #[test]
    fn softmax_aggregation_is_unbiased() {
        // Same eq. (4) contract as the p2c test: coefficients w/(Kq)
        // make the aggregate unbiased under the softmax marginals.
        let scores = vec![0.5, 0.1, 0.3];
        let q = softmax_distribution(&scores, 0.25, 0.1);
        let w = vec![0.2, 0.3, 0.5];
        let v = [1.0, 10.0, 100.0];
        let k = 2;
        let mut rng = Rng::new(29);
        let trials = 400_000;
        let mut acc = 0.0;
        for _ in 0..trials {
            let sel = sample_by_probability(&q, &w, k, &mut rng);
            for (slot, &n) in sel.members.iter().enumerate() {
                acc += sel.coefs[slot] * v[n];
            }
        }
        let emp = acc / trials as f64;
        let expect: f64 = w.iter().zip(&v).map(|(wn, vn)| wn * vn).sum();
        assert!(
            (emp - expect).abs() / expect < 0.01,
            "empirical {emp} vs {expect}"
        );
    }

    #[test]
    fn fedavg_selection_normalizes_over_members() {
        let w = vec![0.1, 0.2, 0.3, 0.4];
        let sel = fedavg_selection(vec![1, 3], &w);
        assert_eq!(sel.members, vec![1, 3]);
        assert!((sel.coefs[0] - 0.2 / 0.6).abs() < 1e-12);
        assert!((sel.coefs[1] - 0.4 / 0.6).abs() < 1e-12);
    }

    #[test]
    fn fedavg_selection_coefs_sum_to_one_for_any_nonempty_member_set() {
        // The eq. (4) aggregation contract: for every non-empty member
        // set the coefs must form a convex combination, including with
        // multiplicity and tiny (but positive) weights.
        let w = vec![1e-12, 0.2, 1e-300, 0.4, 0.1];
        for members in [vec![0], vec![2], vec![1, 1, 3], vec![0, 2, 4], vec![3, 3, 3]] {
            let sel = fedavg_selection(members.clone(), &w);
            let s: f64 = sel.coefs.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "members {members:?}: coef sum {s}");
            assert!(sel.coefs.iter().all(|&c| c >= 0.0 && c.is_finite()));
        }
    }

    #[test]
    #[should_panic(expected = "cannot normalize")]
    fn fedavg_selection_panics_on_zero_weight_members() {
        // Pre-fix this silently produced coefs summing to ~0 (divide by
        // the 1e-300 floor), corrupting the aggregate.
        let w = vec![0.0, 0.5, 0.0, 0.5];
        fedavg_selection(vec![0, 2], &w);
    }

    #[test]
    fn select_among_identity_matches_select() {
        let build = || {
            let mut st = DivFlState::new(8, 2);
            for i in 0..8 {
                st.observe(i, vec![i as f32, (8 - i) as f32]);
            }
            st
        };
        let w = vec![0.125; 8];
        let ids: Vec<usize> = (0..8).collect();
        let a = build().select(&w, 3);
        let b = build().select_among(&ids, &w, 3);
        assert_eq!(a.members, b.members);
        assert_eq!(a.coefs, b.coefs);
    }

    #[test]
    fn select_among_subset_returns_positions() {
        let mut st = DivFlState::new(10, 2);
        for i in 0..10 {
            st.observe(i, vec![i as f32, 0.0]);
        }
        // Candidate set {2, 5, 9}: members must be positions 0..3.
        let ids = vec![2, 5, 9];
        let w = vec![0.5, 0.3, 0.2];
        let sel = st.select_among(&ids, &w, 2);
        assert_eq!(sel.members.len(), 2);
        assert!(sel.members.iter().all(|&m| m < 3));
        assert_eq!(sel.unique_members().len(), 2);
        let s: f64 = sel.coefs.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn projector_is_deterministic_and_norm_bounded() {
        let p = Projector::new(16, 42);
        let delta: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.01).sin()).collect();
        let a = p.project(&delta);
        let b = p.project(&delta);
        assert_eq!(a, b);
        // Similar inputs -> similar projections; different -> different.
        let delta2: Vec<f32> = delta.iter().map(|x| -x).collect();
        let c = p.project(&delta2);
        let dot: f32 = a.iter().zip(&c).map(|(x, y)| x * y).sum();
        assert!(dot < 0.0, "negated input should anti-correlate, dot={dot}");
    }
}
