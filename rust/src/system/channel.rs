//! Wireless channel process.
//!
//! The paper models the uplink channel gain `h_n^t` as an IID discrete-time
//! random process, generated "following the exponential distribution with a
//! mean value of 0.1", with outliers "greater than 0.5 or smaller than
//! 0.01" filtered out, and the random seed fixed across runs so competing
//! policies see identical channel realizations.
//!
//! This module is the channel *kernel*: [`ChannelProcess`] generates the
//! IID streams, and [`draw_clipped_exponential`] is the single-draw
//! primitive the dynamic environments in [`crate::env`] (Gilbert–Elliott
//! fading, availability masking, parameter drift) also draw through — so
//! every environment's gains share the same distributional shape.

use crate::config::SystemConfig;
use crate::rng::Rng;

/// One clipped-exponential gain draw.
///
/// Outlier handling is rejection (re-draw), which keeps samples inside
/// the paper's band while preserving the exponential shape within it.
#[inline]
pub fn draw_clipped_exponential(rng: &mut Rng, mean: f64, clip: (f64, f64)) -> f64 {
    let (lo, hi) = clip;
    loop {
        let h = rng.exponential(mean);
        if h >= lo && h <= hi {
            return h;
        }
    }
}

/// Per-device IID exponential channel-gain streams with outlier rejection.
#[derive(Clone, Debug)]
pub struct ChannelProcess {
    streams: Vec<Rng>,
    mean: f64,
    clip: (f64, f64),
}

impl ChannelProcess {
    /// One independent stream per device, all derived from `seed` — so a
    /// policy change never perturbs the channel sequence of any device.
    pub fn new(cfg: &SystemConfig, seed: u64) -> Self {
        let mut root = Rng::new(seed ^ 0xC0FF_EE00_D15E_A5E5);
        let streams = (0..cfg.num_devices).map(|i| root.fork(i as u64)).collect();
        Self {
            streams,
            mean: cfg.channel_mean,
            clip: cfg.channel_clip,
        }
    }

    /// Draw the round-`t` gain for every device.
    pub fn next_round(&mut self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.streams.len());
        self.next_round_into(&mut out);
        out
    }

    /// [`ChannelProcess::next_round`] into a caller-owned buffer
    /// (clear + extend into retained capacity): the fleet-scale env-step
    /// path draws a million gains per round without touching the heap.
    /// Same streams, same draw order — the returned values are bitwise
    /// identical to `next_round`.
    pub fn next_round_into(&mut self, out: &mut Vec<f64>) {
        let clip = self.clip;
        let mean = self.mean;
        out.clear();
        out.extend(
            self.streams
                .iter_mut()
                .map(|rng| draw_clipped_exponential(rng, mean, clip)),
        );
    }

    pub fn num_devices(&self) -> usize {
        self.streams.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn gains_respect_clip_band() {
        let mut ch = ChannelProcess::new(&cfg(), 1);
        for _ in 0..200 {
            for h in ch.next_round() {
                assert!((0.01..=0.5).contains(&h), "gain {h} outside band");
            }
        }
    }

    #[test]
    fn mean_is_close_to_configured() {
        let mut ch = ChannelProcess::new(&cfg(), 2);
        let mut sum = 0.0;
        let mut count = 0usize;
        for _ in 0..500 {
            for h in ch.next_round() {
                sum += h;
                count += 1;
            }
        }
        let mean = sum / count as f64;
        // Truncation to [0.01, 0.5] pulls the mean slightly below 0.1.
        assert!((0.08..0.12).contains(&mean), "mean {mean}");
    }

    #[test]
    fn same_seed_same_realization() {
        let mut a = ChannelProcess::new(&cfg(), 42);
        let mut b = ChannelProcess::new(&cfg(), 42);
        for _ in 0..10 {
            assert_eq!(a.next_round(), b.next_round());
        }
    }

    #[test]
    fn different_seed_different_realization() {
        let mut a = ChannelProcess::new(&cfg(), 1);
        let mut b = ChannelProcess::new(&cfg(), 2);
        assert_ne!(a.next_round(), b.next_round());
    }

    #[test]
    fn streams_are_per_device_independent() {
        // Device i's sequence must not depend on how many devices exist.
        let mut big = ChannelProcess::new(&cfg(), 7);
        let small_cfg = SystemConfig {
            num_devices: 10,
            ..cfg()
        };
        let mut small = ChannelProcess::new(&small_cfg, 7);
        let hb = big.next_round();
        let hs = small.next_round();
        assert_eq!(&hb[..10], &hs[..]);
    }
}
