//! Edge-device parameters and fleet generation.

use crate::config::SystemConfig;
use crate::rng::Rng;

/// Static (per-run) parameters of one edge device — the quantities the
/// paper's server "collects ... from devices before the training starts".
#[derive(Clone, Debug)]
pub struct Device {
    /// Device index `n`.
    pub id: usize,
    /// Local dataset size `D_n` [samples].
    pub data_size: usize,
    /// CPU cycles per sample `c_n`.
    pub cycles_per_sample: f64,
    /// Effective capacitance coefficient `alpha_n`.
    pub alpha: f64,
    /// CPU frequency bounds [Hz].
    pub f_min_hz: f64,
    pub f_max_hz: f64,
    /// Transmit power bounds [W].
    pub p_min_w: f64,
    pub p_max_w: f64,
    /// Per-round energy budget `Ē_n` [J].
    pub energy_budget_j: f64,
}

impl Device {
    /// Data weight `w_n = D_n / D` needs the fleet total; see [`Fleet::weights`].
    pub fn cycles_per_round(&self, local_epochs: usize) -> f64 {
        local_epochs as f64 * self.cycles_per_sample * self.data_size as f64
    }
}

/// The set of `N` devices participating in the FL system.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub devices: Vec<Device>,
    /// Cached data weights `w_n` (sum to 1).
    weights: Vec<f64>,
}

impl Fleet {
    /// Generate a fleet from the system config.
    ///
    /// * dataset sizes `D_n` ~ Uniform[lo, hi] (FEMNIST's ">= 50 samples"
    ///   filter corresponds to `lo >= 50`),
    /// * hardware parameters are the config values scaled per-device by
    ///   Uniform[1-s, 1+s] with `s = hardware_spread` (0 reproduces the
    ///   paper's homogeneous default).
    pub fn generate(cfg: &SystemConfig, samples_range: (usize, usize), rng: &mut Rng) -> Fleet {
        let n = cfg.num_devices;
        let (lo, hi) = samples_range;
        let s = cfg.hardware_spread.clamp(0.0, 0.9);
        // The energy budget gets its own (wider) spread so budget
        // heterogeneity can be swept independently of hardware
        // heterogeneity; same single uniform draw, so budget_spread = 0
        // reproduces the old fleet bitwise.
        let sb = (s + cfg.budget_spread.max(0.0)).clamp(0.0, 0.95);
        let devices: Vec<Device> = (0..n)
            .map(|id| {
                let jitter = |rng: &mut Rng| 1.0 + s * (2.0 * rng.f64() - 1.0);
                let data_size = lo + rng.below(hi - lo + 1);
                Device {
                    id,
                    data_size,
                    cycles_per_sample: cfg.cycles_per_sample * jitter(rng),
                    alpha: cfg.alpha * jitter(rng),
                    f_min_hz: cfg.f_min_hz,
                    f_max_hz: cfg.f_max_hz * jitter(rng).max(cfg.f_min_hz / cfg.f_max_hz + 0.05),
                    p_min_w: cfg.p_min_w,
                    p_max_w: cfg.p_max_w * jitter(rng),
                    energy_budget_j: cfg.energy_budget_j
                        * (1.0 + sb * (2.0 * rng.f64() - 1.0)),
                }
            })
            .collect();
        let total: f64 = devices.iter().map(|d| d.data_size as f64).sum();
        let weights = devices.iter().map(|d| d.data_size as f64 / total).collect();
        Fleet { devices, weights }
    }

    /// Build directly from known dataset sizes (used when the data
    /// partition, not the config range, determines `D_n`).
    pub fn from_data_sizes(cfg: &SystemConfig, sizes: &[usize], rng: &mut Rng) -> Fleet {
        assert_eq!(sizes.len(), cfg.num_devices);
        let mut fleet = Fleet::generate(cfg, (1, 1), rng);
        for (dev, &sz) in fleet.devices.iter_mut().zip(sizes) {
            dev.data_size = sz;
        }
        let total: f64 = sizes.iter().map(|&s| s as f64).sum();
        fleet.weights = sizes.iter().map(|&s| s as f64 / total).collect();
        fleet
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Data weights `w_n = D_n / D`, summing to 1 (eq. context of (2)).
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    #[test]
    fn weights_sum_to_one() {
        let cfg = SystemConfig::default();
        let mut rng = Rng::new(1);
        let fleet = Fleet::generate(&cfg, (50, 400), &mut rng);
        assert_eq!(fleet.len(), 120);
        let sum: f64 = fleet.weights().iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!(fleet.weights().iter().all(|&w| w > 0.0));
    }

    #[test]
    fn homogeneous_when_spread_zero() {
        let cfg = SystemConfig::default();
        let mut rng = Rng::new(2);
        let fleet = Fleet::generate(&cfg, (100, 100), &mut rng);
        for d in &fleet.devices {
            assert_eq!(d.cycles_per_sample, cfg.cycles_per_sample);
            assert_eq!(d.alpha, cfg.alpha);
            assert_eq!(d.energy_budget_j, cfg.energy_budget_j);
            assert_eq!(d.data_size, 100);
        }
    }

    #[test]
    fn heterogeneous_when_spread_positive() {
        let cfg = SystemConfig {
            hardware_spread: 0.3,
            ..SystemConfig::default()
        };
        let mut rng = Rng::new(3);
        let fleet = Fleet::generate(&cfg, (50, 400), &mut rng);
        let c0 = fleet.devices[0].cycles_per_sample;
        assert!(fleet.devices.iter().any(|d| d.cycles_per_sample != c0));
        // All scaled values stay within the jitter band.
        for d in &fleet.devices {
            assert!(d.cycles_per_sample >= cfg.cycles_per_sample * 0.7 - 1.0);
            assert!(d.cycles_per_sample <= cfg.cycles_per_sample * 1.3 + 1.0);
            assert!(d.f_max_hz > d.f_min_hz);
            assert!(d.p_max_w > d.p_min_w);
        }
    }

    #[test]
    fn budget_spread_jitters_only_the_energy_budget() {
        let base = SystemConfig::default();
        let cfg = SystemConfig {
            budget_spread: 0.5,
            ..SystemConfig::default()
        };
        let fleet_a = Fleet::generate(&base, (100, 100), &mut Rng::new(7));
        let fleet_b = Fleet::generate(&cfg, (100, 100), &mut Rng::new(7));
        // Same rng consumption: everything but the budget is untouched.
        for (a, b) in fleet_a.devices.iter().zip(&fleet_b.devices) {
            assert_eq!(a.cycles_per_sample, b.cycles_per_sample);
            assert_eq!(a.alpha, b.alpha);
            assert_eq!(a.f_max_hz, b.f_max_hz);
        }
        let e0 = fleet_b.devices[0].energy_budget_j;
        assert!(fleet_b.devices.iter().any(|d| d.energy_budget_j != e0));
        for d in &fleet_b.devices {
            assert!(d.energy_budget_j > 0.0);
            assert!((d.energy_budget_j - base.energy_budget_j).abs() <= base.energy_budget_j * 0.5 + 1e-9);
        }
        // budget_spread = 0 is bitwise the old fleet.
        let fleet_c = Fleet::generate(&base, (100, 100), &mut Rng::new(7));
        for (a, c) in fleet_a.devices.iter().zip(&fleet_c.devices) {
            assert_eq!(a.energy_budget_j, c.energy_budget_j);
        }
    }

    #[test]
    fn from_data_sizes_overrides_weights() {
        let cfg = SystemConfig {
            num_devices: 4,
            ..SystemConfig::default()
        };
        let mut rng = Rng::new(4);
        let fleet = Fleet::from_data_sizes(&cfg, &[100, 200, 300, 400], &mut rng);
        assert_eq!(fleet.weights(), &[0.1, 0.2, 0.3, 0.4]);
        assert_eq!(fleet.devices[2].data_size, 300);
    }

    #[test]
    fn cycles_per_round_matches_formula() {
        let d = Device {
            id: 0,
            data_size: 200,
            cycles_per_sample: 3.0e9,
            alpha: 2e-28,
            f_min_hz: 1e9,
            f_max_hz: 2e9,
            p_min_w: 0.001,
            p_max_w: 0.1,
            energy_budget_j: 15.0,
        };
        // E * c_n * D_n  (eq. 8 numerator)
        assert_eq!(d.cycles_per_round(2), 2.0 * 3.0e9 * 200.0);
    }
}
