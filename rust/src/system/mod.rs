//! Mobile-edge system substrate: devices, channels, latency & energy models.
//!
//! Implements §III of the paper — eqs. (5)–(17) — as pure functions over
//! per-device parameters, control decisions `(f, p, q)` and the round's
//! channel realization, plus the stochastic processes that drive them
//! (exponential channel gains, heterogeneous fleet generation).

mod channel;
mod device;
mod model;
mod soa;

pub use channel::{draw_clipped_exponential, ChannelProcess};
pub use device::{Device, Fleet};
pub use model::{
    comm_energy_j, comp_energy_j, comp_time_s, download_time_s, expected_round_time_s,
    round_costs_into, round_time_s, selection_probability, total_energy_j, uplink_rate_bps,
    upload_time_s, RoundCosts,
};
pub use soa::FleetSoA;
