//! Latency and energy equations — eqs. (5)–(17) of the paper.
//!
//! All functions are pure; the FL server and the LROA solver call them
//! with per-round control decisions `(f, p, q)` and the channel draw.

use super::Device;
use crate::config::SystemConfig;

/// Eq. (5): achievable uplink rate [bit/s] under FDMA with `B_n = B/K`.
#[inline]
pub fn uplink_rate_bps(cfg: &SystemConfig, h: f64, p_w: f64) -> f64 {
    let b_n = cfg.bandwidth_hz / cfg.k as f64;
    b_n * (1.0 + h * p_w / cfg.noise_w).log2()
}

/// Eq. (6): model-upload time [s] = `M / r_up`.
#[inline]
pub fn upload_time_s(cfg: &SystemConfig, model_bits: f64, h: f64, p_w: f64) -> f64 {
    model_bits / uplink_rate_bps(cfg, h, p_w)
}

/// Eq. (7): model-download time [s]; the paper's experiments ignore it
/// (`downlink_bps = 0` disables the term).
#[inline]
pub fn download_time_s(cfg: &SystemConfig, model_bits: f64) -> f64 {
    if cfg.downlink_bps > 0.0 {
        model_bits / cfg.downlink_bps
    } else {
        0.0
    }
}

/// Eq. (8): local computation time [s] = `E c_n D_n / f_n`.
#[inline]
pub fn comp_time_s(cfg: &SystemConfig, dev: &Device, f_hz: f64) -> f64 {
    dev.cycles_per_round(cfg.local_epochs) / f_hz
}

/// Eq. (9): per-round time of one device (download + compute + upload).
#[inline]
pub fn round_time_s(cfg: &SystemConfig, dev: &Device, model_bits: f64, h: f64, f_hz: f64, p_w: f64) -> f64 {
    comp_time_s(cfg, dev, f_hz)
        + upload_time_s(cfg, model_bits, h, p_w)
        + download_time_s(cfg, model_bits)
}

/// Eq. (11): the tractable surrogate `Σ_n q_n T_n` for the per-round
/// makespan `max_{n in K^t} T_n`.
pub fn expected_round_time_s(times: &[f64], q: &[f64]) -> f64 {
    times.iter().zip(q).map(|(t, qn)| t * qn).sum()
}

/// Eq. (12): local computation energy [J] = `E α_n c_n D_n f² / 2`.
#[inline]
pub fn comp_energy_j(cfg: &SystemConfig, dev: &Device, f_hz: f64) -> f64 {
    dev.alpha * dev.cycles_per_round(cfg.local_epochs) * f_hz * f_hz / 2.0
}

/// Eq. (14): uplink communication energy [J] = `p · T_up`.
#[inline]
pub fn comm_energy_j(cfg: &SystemConfig, model_bits: f64, h: f64, p_w: f64) -> f64 {
    p_w * upload_time_s(cfg, model_bits, h, p_w)
}

/// Eq. (15): total per-round energy if the device participates.
#[inline]
pub fn total_energy_j(cfg: &SystemConfig, dev: &Device, model_bits: f64, h: f64, f_hz: f64, p_w: f64) -> f64 {
    comp_energy_j(cfg, dev, f_hz) + comm_energy_j(cfg, model_bits, h, p_w)
}

/// The likelihood of being chosen at least once in `K` draws with
/// replacement: `1 - (1 - q)^K` (used by constraint (16) and the queues).
#[inline]
pub fn selection_probability(q: f64, k: usize) -> f64 {
    1.0 - (1.0 - q).powi(k as i32)
}

/// All per-device costs of one round under given controls — what the
/// server records and what the queues consume.
#[derive(Clone, Debug, Default)]
pub struct RoundCosts {
    /// `T_n^t` per device [s] (eq. 9).
    pub time_s: Vec<f64>,
    /// `E_n^t` per device [J] (eq. 15).
    pub energy_j: Vec<f64>,
    /// `T_n^{t,cmp}` per device [s].
    pub comp_time_s: Vec<f64>,
    /// `T_{n,u}^{t,com}` per device [s].
    pub upload_time_s: Vec<f64>,
    /// `E_n^{t,cmp}` per device [J].
    pub comp_energy_j: Vec<f64>,
    /// `E_n^{t,com}` per device [J].
    pub comm_energy_j: Vec<f64>,
}

impl RoundCosts {
    /// Evaluate eqs. (6)–(15) for every device under controls `(f, p)`
    /// and channel draw `h`.
    pub fn evaluate(
        cfg: &SystemConfig,
        devices: &[Device],
        model_bits: f64,
        h: &[f64],
        f_hz: &[f64],
        p_w: &[f64],
    ) -> RoundCosts {
        let mut out = RoundCosts::default();
        out.evaluate_into(cfg, devices, model_bits, h, f_hz, p_w);
        out
    }

    /// In-place [`RoundCosts::evaluate`]: refill every column via
    /// clear + push into retained capacity, so the server's per-round
    /// cost pass allocates nothing at steady state (the fleet-scale
    /// sibling of [`round_costs_into`], keeping all six columns).  Same
    /// arithmetic, same expression order — bitwise identical results.
    pub fn evaluate_into(
        &mut self,
        cfg: &SystemConfig,
        devices: &[Device],
        model_bits: f64,
        h: &[f64],
        f_hz: &[f64],
        p_w: &[f64],
    ) {
        let n = devices.len();
        assert!(h.len() == n && f_hz.len() == n && p_w.len() == n);
        self.time_s.clear();
        self.energy_j.clear();
        self.comp_time_s.clear();
        self.upload_time_s.clear();
        self.comp_energy_j.clear();
        self.comm_energy_j.clear();
        for i in 0..n {
            let dev = &devices[i];
            let tcmp = comp_time_s(cfg, dev, f_hz[i]);
            let tup = upload_time_s(cfg, model_bits, h[i], p_w[i]);
            let ecmp = comp_energy_j(cfg, dev, f_hz[i]);
            let ecom = p_w[i] * tup;
            self.comp_time_s.push(tcmp);
            self.upload_time_s.push(tup);
            self.comp_energy_j.push(ecmp);
            self.comm_energy_j.push(ecom);
            self.time_s.push(tcmp + tup + download_time_s(cfg, model_bits));
            self.energy_j.push(ecmp + ecom);
        }
    }

    /// Eq. (10): makespan over the selected set.
    pub fn makespan_s(&self, selected: &[usize]) -> f64 {
        selected
            .iter()
            .map(|&i| self.time_s[i])
            .fold(0.0, f64::max)
    }
}

/// Slice-oriented port of [`RoundCosts::evaluate`] for the solver hot
/// loop: only the `time_s`/`energy_j` aggregates (all Algorithm 2
/// needs), written into caller-owned scratch so an outer iteration
/// allocates nothing.  The arithmetic — expression order included — is
/// identical to `evaluate`, which the `soa_port_is_bitwise_identical`
/// test pins.
#[allow(clippy::too_many_arguments)]
pub fn round_costs_into(
    cfg: &SystemConfig,
    soa: &super::FleetSoA,
    model_bits: f64,
    h: &[f64],
    f_hz: &[f64],
    p_w: &[f64],
    time_s: &mut Vec<f64>,
    energy_j: &mut Vec<f64>,
) {
    let n = soa.len();
    assert!(h.len() == n && f_hz.len() == n && p_w.len() == n);
    time_s.clear();
    energy_j.clear();
    for i in 0..n {
        let tcmp = soa.ecd[i] / f_hz[i];
        let tup = upload_time_s(cfg, model_bits, h[i], p_w[i]);
        let ecmp = soa.alpha[i] * soa.ecd[i] * f_hz[i] * f_hz[i] / 2.0;
        let ecom = p_w[i] * tup;
        time_s.push(tcmp + tup + download_time_s(cfg, model_bits));
        energy_j.push(ecmp + ecom);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::system::FleetSoA;

    fn dev() -> Device {
        Device {
            id: 0,
            data_size: 200,
            cycles_per_sample: 3.0e9,
            alpha: 2e-28,
            f_min_hz: 1e9,
            f_max_hz: 2e9,
            p_min_w: 0.001,
            p_max_w: 0.1,
            energy_budget_j: 15.0,
        }
    }

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn shannon_rate_matches_hand_calc() {
        // B/K = 0.5 MHz; h p / N0 = 0.1*0.1/0.01 = 1 -> log2(2) = 1.
        let r = uplink_rate_bps(&cfg(), 0.1, 0.1);
        assert!((r - 0.5e6).abs() < 1e-6, "r = {r}");
    }

    #[test]
    fn upload_time_scales_inversely_with_rate() {
        let c = cfg();
        let m = 3.2e6; // bits
        let t_good = upload_time_s(&c, m, 0.5, 0.1);
        let t_bad = upload_time_s(&c, m, 0.01, 0.1);
        assert!(t_bad > t_good * 5.0, "bad {t_bad} vs good {t_good}");
        // Hand-check: t = M / (B/K log2(1 + h p/N0)).
        let expect = m / (0.5e6 * (1.0f64 + 0.5 * 0.1 / 0.01).log2());
        assert!((t_good - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn comp_time_and_energy_formulas() {
        let c = cfg();
        let d = dev();
        let f = 1.5e9;
        // T = E c D / f
        let t = comp_time_s(&c, &d, f);
        assert!((t - (2.0 * 3.0e9 * 200.0) / 1.5e9).abs() < 1e-12);
        // E = alpha E c D f^2 / 2
        let e = comp_energy_j(&c, &d, f);
        let expect = 2e-28 * (2.0 * 3.0e9 * 200.0) * 1.5e9 * 1.5e9 / 2.0;
        assert!((e - expect).abs() / expect < 1e-12);
        // Sanity: sub-Joule to tens-of-Joules range at paper constants.
        assert!(e > 0.01 && e < 1000.0, "e = {e}");
    }

    #[test]
    fn energy_monotone_in_frequency_and_power_behaviour() {
        let c = cfg();
        let d = dev();
        assert!(comp_energy_j(&c, &d, 2e9) > comp_energy_j(&c, &d, 1e9));
        assert!(comp_time_s(&c, &d, 2e9) < comp_time_s(&c, &d, 1e9));
        // Comm energy p*T(p) is NOT monotone decreasing: check both ends finite.
        let m = 3.2e6;
        let e_lo = comm_energy_j(&c, m, 0.1, 0.001);
        let e_hi = comm_energy_j(&c, m, 0.1, 0.1);
        assert!(e_lo.is_finite() && e_hi.is_finite());
        assert!(e_lo > 0.0 && e_hi > 0.0);
    }

    #[test]
    fn selection_probability_matches_definition() {
        assert!((selection_probability(0.5, 2) - 0.75).abs() < 1e-12);
        assert!((selection_probability(1.0, 3) - 1.0).abs() < 1e-12);
        assert!(selection_probability(0.0, 5).abs() < 1e-12);
        // Monotone in both q and K.
        assert!(selection_probability(0.3, 4) > selection_probability(0.3, 2));
        assert!(selection_probability(0.4, 2) > selection_probability(0.2, 2));
    }

    #[test]
    fn expected_round_time_is_weighted_sum() {
        let t = [1.0, 2.0, 4.0];
        let q = [0.5, 0.25, 0.25];
        assert!((expected_round_time_s(&t, &q) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn round_costs_consistency() {
        let c = cfg();
        let devs: Vec<Device> = (0..3)
            .map(|id| Device {
                id,
                data_size: 100 * (id + 1),
                ..dev()
            })
            .collect();
        let h = [0.1, 0.05, 0.3];
        let f = [1e9, 1.5e9, 2e9];
        let p = [0.01, 0.05, 0.1];
        let m = 3.58e6;
        let rc = RoundCosts::evaluate(&c, &devs, m, &h, &f, &p);
        for i in 0..3 {
            assert!((rc.time_s[i] - (rc.comp_time_s[i] + rc.upload_time_s[i])).abs() < 1e-12);
            assert!((rc.energy_j[i] - (rc.comp_energy_j[i] + rc.comm_energy_j[i])).abs() < 1e-12);
            assert!(rc.time_s[i] > 0.0 && rc.energy_j[i] > 0.0);
        }
        // Makespan = max over the selected subset.
        let ms = rc.makespan_s(&[0, 2]);
        assert!((ms - rc.time_s[0].max(rc.time_s[2])).abs() < 1e-15);
    }

    #[test]
    fn soa_port_is_bitwise_identical() {
        let c = cfg();
        let devs: Vec<Device> = (0..4)
            .map(|id| Device {
                id,
                data_size: 120 * (id + 1),
                alpha: 2e-28 * (1.0 + id as f64 * 0.1),
                ..dev()
            })
            .collect();
        let weights = [0.1, 0.2, 0.3, 0.4];
        let h = [0.1, 0.05, 0.3, 0.02];
        let f = [1e9, 1.5e9, 2e9, 1.2e9];
        let p = [0.01, 0.05, 0.1, 0.003];
        let m = 3.58e6;
        let mut soa = FleetSoA::new();
        soa.fill(&devs, &weights, c.local_epochs, 1e4, 10.0);
        let rc = RoundCosts::evaluate(&c, &devs, m, &h, &f, &p);
        let (mut t, mut e) = (Vec::new(), Vec::new());
        round_costs_into(&c, &soa, m, &h, &f, &p, &mut t, &mut e);
        assert_eq!(t, rc.time_s, "time_s must match the AoS path bit-for-bit");
        assert_eq!(e, rc.energy_j, "energy_j must match the AoS path bit-for-bit");
    }

    #[test]
    fn paper_scale_sanity() {
        // At paper defaults per-round participation costs exceed the 5-15 J
        // budgets by 10-20x (e.g. ~270 J at midpoint f with D_n = 200): the
        // time-average constraint (16) therefore binds through low selection
        // probabilities, which is exactly the regime the paper studies.
        let c = cfg();
        let d = dev();
        let m = 32.0 * 140_000.0; // our cifar model bits
        let t = round_time_s(&c, &d, m, 0.1, 1.5e9, 0.05);
        let e = total_energy_j(&c, &d, m, 0.1, 1.5e9, 0.05);
        assert!(t > 0.1 && t < 3600.0, "t = {t}");
        assert!(e > 1.0 && e < 1000.0, "e = {e}");
        // Uniform sampling keeps the expected draw near/below budget scale.
        let sel = selection_probability(1.0 / 120.0, 2);
        assert!(sel * e < 3.0 * d.energy_budget_j, "expected draw {}", sel * e);
    }
}
