//! Struct-of-arrays fleet state for the allocation-free control plane.
//!
//! The Algorithm-2 hot loop touches every device's box bounds, effective
//! capacitance, per-round cycle count and data weight on every outer
//! iteration.  Walking a `&[Device]` for that means strided loads over
//! 9-field structs; at the ROADMAP's 100k–1M device scale the solve is
//! memory-bound, so the solver kernels (`control::freq`,
//! `control::power`, `control::sum`, [`super::round_costs_into`]) instead
//! operate over the contiguous per-field slices gathered here.
//!
//! [`FleetSoA::fill`] is a gather, not an owner: it mirrors whatever
//! (possibly compacted, possibly drifted) device slice the caller hands
//! it, reusing its buffers so a per-round refill allocates nothing once
//! the capacity high-water mark is reached.

use super::Device;

/// Contiguous per-field views of a device slice, plus the solver's
/// round-invariant precomputations (`w²` and `V·λ·w²` — the P2.2 `A₃`
/// coefficients).
#[derive(Clone, Debug, Default)]
pub struct FleetSoA {
    /// CPU frequency bounds [Hz].
    pub f_min_hz: Vec<f64>,
    pub f_max_hz: Vec<f64>,
    /// Transmit power bounds [W].
    pub p_min_w: Vec<f64>,
    pub p_max_w: Vec<f64>,
    /// Effective capacitance `α_n`.
    pub alpha: Vec<f64>,
    /// Cycles per round `E·c_n·D_n` (eq. 8 numerator).
    pub ecd: Vec<f64>,
    /// Per-round energy budget `Ē_n` [J].
    pub energy_budget_j: Vec<f64>,
    /// Data weights squared `w_n²`.
    pub w2: Vec<f64>,
    /// `V·λ·w_n²` — the P2.2 `A₃_n` coefficients, fixed across the
    /// outer loop.
    pub vlw2: Vec<f64>,
}

impl FleetSoA {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.alpha.len()
    }

    pub fn is_empty(&self) -> bool {
        self.alpha.is_empty()
    }

    /// Mirror `devices`/`weights` into the per-field slices.  Buffers are
    /// cleared and re-extended, so repeated fills at a stable fleet size
    /// never touch the allocator.
    pub fn fill(
        &mut self,
        devices: &[Device],
        weights: &[f64],
        local_epochs: usize,
        v: f64,
        lambda: f64,
    ) {
        assert_eq!(devices.len(), weights.len(), "FleetSoA: devices/weights length mismatch");
        self.f_min_hz.clear();
        self.f_max_hz.clear();
        self.p_min_w.clear();
        self.p_max_w.clear();
        self.alpha.clear();
        self.ecd.clear();
        self.energy_budget_j.clear();
        self.w2.clear();
        self.vlw2.clear();
        for d in devices {
            self.f_min_hz.push(d.f_min_hz);
            self.f_max_hz.push(d.f_max_hz);
            self.p_min_w.push(d.p_min_w);
            self.p_max_w.push(d.p_max_w);
            self.alpha.push(d.alpha);
            self.ecd.push(d.cycles_per_round(local_epochs));
            self.energy_budget_j.push(d.energy_budget_j);
        }
        for &w in weights {
            self.w2.push(w * w);
            // Same association order as the AoS solver's A3 scratch
            // (`v * lambda * w * w`) so the port is bitwise-neutral.
            self.vlw2.push(v * lambda * w * w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::rng::Rng;
    use crate::system::Fleet;

    #[test]
    fn fill_mirrors_the_device_slice() {
        let sys = SystemConfig {
            num_devices: 12,
            hardware_spread: 0.3,
            ..SystemConfig::default()
        };
        let mut rng = Rng::new(3);
        let fleet = Fleet::generate(&sys, (50, 400), &mut rng);
        let (v, lambda) = (1e4, 10.0);
        let mut soa = FleetSoA::new();
        soa.fill(&fleet.devices, fleet.weights(), sys.local_epochs, v, lambda);
        assert_eq!(soa.len(), 12);
        for (i, d) in fleet.devices.iter().enumerate() {
            assert_eq!(soa.f_min_hz[i], d.f_min_hz);
            assert_eq!(soa.f_max_hz[i], d.f_max_hz);
            assert_eq!(soa.p_min_w[i], d.p_min_w);
            assert_eq!(soa.p_max_w[i], d.p_max_w);
            assert_eq!(soa.alpha[i], d.alpha);
            assert_eq!(soa.ecd[i], d.cycles_per_round(sys.local_epochs));
            assert_eq!(soa.energy_budget_j[i], d.energy_budget_j);
            let w = fleet.weights()[i];
            assert_eq!(soa.w2[i], w * w);
            assert_eq!(soa.vlw2[i], v * lambda * w * w);
        }
    }

    #[test]
    fn refill_reuses_buffers_and_tracks_the_new_set() {
        let sys = SystemConfig {
            num_devices: 8,
            ..SystemConfig::default()
        };
        let mut rng = Rng::new(4);
        let fleet = Fleet::generate(&sys, (50, 400), &mut rng);
        let mut soa = FleetSoA::new();
        soa.fill(&fleet.devices, fleet.weights(), sys.local_epochs, 1e4, 1.0);
        let cap = soa.alpha.capacity();
        // A compacted refill (fewer devices) must shrink the view without
        // reallocating.
        let sub = &fleet.devices[..3];
        let w = &fleet.weights()[..3];
        soa.fill(sub, w, sys.local_epochs, 1e4, 1.0);
        assert_eq!(soa.len(), 3);
        assert_eq!(soa.alpha.capacity(), cap);
        assert_eq!(soa.alpha[2], fleet.devices[2].alpha);
    }
}
