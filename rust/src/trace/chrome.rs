//! Chrome trace-event exporter.
//!
//! Emits `"X"` (complete) events in the trace-event JSON format that
//! Perfetto and `chrome://tracing` load directly.  Internal times are
//! nanoseconds; the format wants microseconds, so `ts`/`dur` are f64 µs
//! and sub-microsecond precision survives as fractional digits.
//! Events are sorted by `(tid, ts, dur desc)`: timestamps are monotone
//! per thread and an enclosing span always precedes its children.

use super::hub::CellTrace;
use super::span::{Phase, Span, SpanKind};
use crate::json::{obj, Json};

fn us(ns: u64) -> Json {
    Json::Num(ns as f64 / 1000.0)
}

fn num(x: u64) -> Json {
    Json::Num(x as f64)
}

fn event(
    name: &str,
    cat: &str,
    tid: u64,
    ts_ns: u64,
    dur_ns: u64,
    args: Vec<(&str, Json)>,
) -> Json {
    obj(vec![
        ("args", obj(args)),
        ("cat", Json::Str(cat.into())),
        ("dur", us(dur_ns)),
        ("name", Json::Str(name.into())),
        ("ph", Json::Str("X".into())),
        ("pid", Json::Num(1.0)),
        ("tid", num(tid)),
        ("ts", us(ts_ns)),
    ])
}

/// One ring-buffered span as a Chrome event (also used verbatim by the
/// flight recorder's crash dumps).
pub(super) fn span_event(tid: u64, s: &Span) -> Json {
    match s.kind {
        SpanKind::Round => event(
            "round",
            "round",
            tid,
            s.ts_ns,
            s.dur_ns,
            vec![("round", num(s.round as u64))],
        ),
        SpanKind::Phase(p) => {
            let mut args = vec![("round", num(s.round as u64))];
            if p == Phase::Solve {
                args.push(("inner_iters", num(s.counters.inner_iters)));
                args.push(("outer_iters", num(s.counters.outer_iters)));
                args.push(("warm_start", Json::Bool(s.counters.warm_start_hits > 0)));
            }
            event(p.name(), "phase", tid, s.ts_ns, s.dur_ns, args)
        }
    }
}

/// The full session as one trace-event document.
pub(super) fn trace_json(session_dur_ns: u64, cells: &[CellTrace]) -> Json {
    // (tid, ts, dur) sort keys ride alongside each rendered event.
    let mut events: Vec<(u64, u64, u64, Json)> = Vec::new();
    events.push((
        0,
        0,
        session_dur_ns,
        event(
            "session",
            "session",
            0,
            0,
            session_dur_ns,
            vec![("cells", num(cells.len() as u64))],
        ),
    ));
    for c in cells {
        events.push((
            c.tid(),
            c.start_ns(),
            c.dur_ns(),
            event(
                c.label(),
                "cell",
                c.tid(),
                c.start_ns(),
                c.dur_ns(),
                vec![
                    ("cell", num(c.cell() as u64)),
                    ("rounds", num(c.rounds_done() as u64)),
                    ("spans_evicted", num(c.spans_evicted())),
                ],
            ),
        ));
        for s in c.spans() {
            events.push((c.tid(), s.ts_ns, s.dur_ns, span_event(c.tid(), s)));
        }
    }
    events.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)).then(b.2.cmp(&a.2)));
    obj(vec![
        ("displayTimeUnit", Json::Str("ms".into())),
        (
            "traceEvents",
            Json::Arr(events.into_iter().map(|e| e.3).collect()),
        ),
    ])
}
