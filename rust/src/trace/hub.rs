//! Trace collection: the per-cell recorder and the sharded session hub.
//!
//! A worker thread records into a [`CellTrace`] it owns exclusively —
//! no locking per span — and hands the whole buffer to the
//! [`TraceHub`] once, when the cell finishes.  Submission is sharded
//! over a small set of mutexes so concurrent cell completions don't
//! serialize on one lock; the shards are only merged at export time.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::ring::Ring;
use super::span::{Counters, Phase, Span, SpanKind};
use super::{chrome, summary, TraceConfig};
use crate::json::{obj, Json};
use crate::Result;

const SHARDS: usize = 8;

/// Session-wide trace state shared (via `Arc`) by every worker.
pub struct TraceHub {
    cfg: TraceConfig,
    /// Session epoch: `t = 0` of every exported timestamp.
    epoch: Instant,
    next_tid: AtomicU64,
    shards: Vec<Mutex<Vec<CellTrace>>>,
}

impl TraceHub {
    pub fn new(cfg: TraceConfig) -> TraceHub {
        TraceHub {
            cfg,
            epoch: Instant::now(),
            next_tid: AtomicU64::new(0),
            shards: (0..SHARDS).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    /// Trace output directory.
    pub fn dir(&self) -> &Path {
        &self.cfg.dir
    }

    /// Claim a Chrome `tid` for one worker thread (tid 0 is the
    /// synthesized session track).
    pub fn register_thread(&self) -> u64 {
        self.next_tid.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Open a cell recorder; the cell span starts now.
    pub fn cell(&self, cell: usize, label: &str, tid: u64) -> CellTrace {
        let mut ct = CellTrace {
            cell,
            label: label.to_string(),
            tid,
            epoch: self.epoch,
            start_ns: 0,
            end_ns: 0,
            rounds_done: 0,
            ring: Ring::new(self.cfg.ring_spans),
            counters: Counters::default(),
        };
        ct.start_ns = ct.ns(Instant::now());
        ct.end_ns = ct.start_ns;
        ct
    }

    /// Park a finished cell's buffer for export.
    pub fn submit(&self, trace: CellTrace) {
        let shard = trace.cell % self.shards.len();
        self.shards[shard].lock().unwrap().push(trace);
    }

    /// Flight recorder: dump the last [`TraceConfig::flight_rounds`]
    /// rounds of a failed cell to `<label>.crash-trace.json`.  The dump
    /// is itself a loadable Chrome trace with `label`/`reason`/
    /// `rounds_done` metadata at the top level (viewers ignore the
    /// extra keys).
    pub fn crash_dump(&self, trace: &CellTrace, reason: &str) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.cfg.dir)?;
        let cutoff = trace.rounds_done.saturating_sub(self.cfg.flight_rounds);
        let events: Vec<Json> = trace
            .spans()
            .filter(|s| s.round >= cutoff)
            .map(|s| chrome::span_event(trace.tid, s))
            .collect();
        let dump = obj(vec![
            ("displayTimeUnit", Json::Str("ms".into())),
            ("first_round", Json::Num(cutoff as f64)),
            ("label", Json::Str(trace.label.clone())),
            ("reason", Json::Str(reason.to_string())),
            ("rounds_done", Json::Num(trace.rounds_done as f64)),
            ("schema", Json::Str("lroa-crash-trace-v1".into())),
            ("traceEvents", Json::Arr(events)),
        ]);
        let path = self.cfg.dir.join(format!("{}.crash-trace.json", trace.label));
        std::fs::write(&path, dump.to_string())?;
        Ok(path)
    }

    /// Drain every shard and write `trace.json` (Chrome trace-event
    /// JSON) plus `trace_summary.json` to the configured directory.
    pub fn export(&self) -> Result<()> {
        let mut cells: Vec<CellTrace> = Vec::new();
        for shard in &self.shards {
            cells.append(&mut shard.lock().unwrap());
        }
        cells.sort_by_key(|c| c.cell);
        let session_dur_ns = cells
            .iter()
            .map(|c| c.end_ns)
            .max()
            .unwrap_or_else(|| self.epoch.elapsed().as_nanos() as u64);
        std::fs::create_dir_all(&self.cfg.dir)?;
        std::fs::write(
            self.cfg.dir.join("trace.json"),
            chrome::trace_json(session_dur_ns, &cells).to_string(),
        )?;
        std::fs::write(
            self.cfg.dir.join("trace_summary.json"),
            summary::summary_json(session_dur_ns, &cells).to_string(),
        )?;
        Ok(())
    }
}

/// One cell's span recorder, owned by its worker thread for the cell's
/// whole lifetime — recording never locks.
#[derive(Clone, Debug)]
pub struct CellTrace {
    cell: usize,
    label: String,
    tid: u64,
    epoch: Instant,
    start_ns: u64,
    end_ns: u64,
    rounds_done: usize,
    ring: Ring<Span>,
    counters: Counters,
}

impl CellTrace {
    fn ns(&self, at: Instant) -> u64 {
        // `duration_since` saturates to zero for pre-epoch instants.
        at.duration_since(self.epoch).as_nanos() as u64
    }

    /// Record one phase interval `[from, to)` and fold its counters
    /// into the cell totals.
    pub fn phase(&mut self, round: usize, phase: Phase, from: Instant, to: Instant, counters: Counters) {
        self.counters.add(&counters);
        let ts_ns = self.ns(from);
        self.ring.push(Span {
            kind: SpanKind::Phase(phase),
            round,
            ts_ns,
            dur_ns: self.ns(to).saturating_sub(ts_ns),
            counters,
        });
    }

    /// Record one full `Server::round` interval.
    pub fn round_span(&mut self, round: usize, from: Instant, to: Instant) {
        self.rounds_done = self.rounds_done.max(round + 1);
        let ts_ns = self.ns(from);
        self.ring.push(Span {
            kind: SpanKind::Round,
            round,
            ts_ns,
            dur_ns: self.ns(to).saturating_sub(ts_ns),
            counters: Counters::default(),
        });
    }

    /// Close the cell span (call once, after the drive loop).
    pub fn finish(&mut self) {
        self.end_ns = self.ns(Instant::now());
    }

    /// Attribute the cell's metric-CSV output size.
    pub fn set_bytes_written(&mut self, bytes: u64) {
        self.counters.bytes_written += bytes;
    }

    pub fn cell(&self) -> usize {
        self.cell
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn tid(&self) -> u64 {
        self.tid
    }

    pub fn start_ns(&self) -> u64 {
        self.start_ns
    }

    pub fn end_ns(&self) -> u64 {
        self.end_ns
    }

    pub fn dur_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }

    pub fn rounds_done(&self) -> usize {
        self.rounds_done
    }

    pub fn counters(&self) -> &Counters {
        &self.counters
    }

    pub fn spans_evicted(&self) -> u64 {
        self.ring.evicted()
    }

    /// Surviving spans, in recording order.
    pub fn spans(&self) -> impl Iterator<Item = &Span> {
        self.ring.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lroa-trace-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn record_cell(hub: &TraceHub, cell: usize, label: &str, rounds: usize) -> CellTrace {
        let tid = hub.register_thread();
        let mut ct = hub.cell(cell, label, tid);
        for round in 0..rounds {
            let t0 = Instant::now();
            let mid = Instant::now();
            ct.phase(
                round,
                Phase::Solve,
                t0,
                mid,
                Counters {
                    outer_iters: 2,
                    inner_iters: 5,
                    warm_start_hits: 1,
                    bytes_written: 0,
                },
            );
            ct.phase(round, Phase::Train, mid, Instant::now(), Counters::default());
            ct.round_span(round, t0, Instant::now());
        }
        ct.finish();
        ct
    }

    #[test]
    fn record_export_parse_roundtrip() {
        let dir = scratch_dir("roundtrip");
        let hub = TraceHub::new(TraceConfig::new(&dir));
        let ct = record_cell(&hub, 0, "cell-a", 3);
        assert_eq!(ct.rounds_done(), 3);
        assert_eq!(ct.counters().outer_iters, 6);
        assert_eq!(ct.counters().warm_start_hits, 3);
        hub.submit(ct);
        hub.export().unwrap();

        let trace =
            Json::parse(&std::fs::read_to_string(dir.join("trace.json")).unwrap()).unwrap();
        let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
        let cats: std::collections::BTreeSet<&str> = events
            .iter()
            .map(|e| e.get("cat").unwrap().as_str().unwrap())
            .collect();
        for cat in ["session", "cell", "round", "phase"] {
            assert!(cats.contains(cat), "missing {cat} events");
        }

        let summary =
            Json::parse(&std::fs::read_to_string(dir.join("trace_summary.json")).unwrap())
                .unwrap();
        assert_eq!(summary.get("schema").unwrap().as_str(), Some("lroa-trace-v1"));
        let cell = &summary.get("cells").unwrap().as_arr().unwrap()[0];
        assert_eq!(cell.get("label").unwrap().as_str(), Some("cell-a"));
        assert_eq!(cell.path(&["counters", "outer_iters"]).unwrap().as_usize(), Some(6));
        assert_eq!(cell.path(&["phases", "solve", "count"]).unwrap().as_usize(), Some(3));
        assert_eq!(cell.path(&["phases", "observe", "count"]).unwrap().as_usize(), Some(0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crash_dump_keeps_last_n_rounds() {
        let dir = scratch_dir("crash");
        let hub = TraceHub::new(TraceConfig::new(&dir).flight_rounds(2));
        let ct = record_cell(&hub, 4, "doomed", 5);
        let path = hub.crash_dump(&ct, "synthetic failure").unwrap();
        let dump = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(dump.get("reason").unwrap().as_str(), Some("synthetic failure"));
        assert_eq!(dump.get("first_round").unwrap().as_usize(), Some(3));
        let events = dump.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        for ev in events {
            let round = ev.path(&["args", "round"]).unwrap().as_usize().unwrap();
            assert!(round >= 3, "round {round} survived a 2-round flight window");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn registered_tids_are_unique_and_nonzero() {
        let hub = TraceHub::new(TraceConfig::new("/tmp/unused"));
        let a = hub.register_thread();
        let b = hub.register_thread();
        assert!(a >= 1 && b >= 1 && a != b);
    }
}
