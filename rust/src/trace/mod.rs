//! Zero-dependency structured tracing: where a session's wall-clock goes.
//!
//! The metrics pipeline reports *modeled* time (the paper's objective);
//! this module reports *measured* time, so perf work on the solver, the
//! env step, or observer dispatch is gated by data instead of guesses.
//! Spans nest through four hierarchical scopes:
//!
//! ```text
//! session                      one per exported trace
//! └─ cell                      one per scenario (grid cell)
//!    └─ round                  one per RoundDriver::step
//!       └─ phase               env_step | solve | train | aggregate
//!    └─ observe                round-event observer dispatch (per round)
//! ```
//!
//! The four in-round phases partition `Server::round`'s wall-clock
//! contiguously (each starts where the previous ended), so per-phase
//! totals sum to the round span up to a few function-call nanoseconds —
//! the property the CI trace-validation step asserts.
//!
//! Recording is lock-free on the hot path: each cell owns a
//! [`CellTrace`] ring buffer on its worker thread and only touches the
//! sharded [`TraceHub`] once, at submit time.  Two exporters run at
//! grid end: Chrome trace-event JSON (`trace.json`, loadable in
//! Perfetto or `chrome://tracing`) and the compact per-cell
//! `trace_summary.json` (`lroa trace summarize` pretty-prints it).  On
//! a cell timeout or panic the flight recorder dumps the last
//! [`TraceConfig::flight_rounds`] rounds of spans to
//! `<label>.crash-trace.json` — itself a loadable Chrome trace.
//!
//! Tracing is determinism-safe by construction: timestamps exist only
//! in trace output, never in CSV/summary/manifest bytes, and the trace
//! directory is not part of any cell fingerprint
//! (`tests/trace_parity.rs` pins byte identity with tracing on vs off).

use std::path::PathBuf;

pub mod chrome;
pub mod hub;
pub mod ring;
pub mod span;
pub mod summary;

pub use hub::{CellTrace, TraceHub};
pub use ring::Ring;
pub use span::{Counters, Phase, Span, SpanKind};
pub use summary::PhaseStats;

/// How a session records and exports its trace.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Directory receiving `trace.json`, `trace_summary.json`, and any
    /// `<label>.crash-trace.json` flight-recorder dumps.
    pub dir: PathBuf,
    /// Per-cell span-ring capacity; on overflow the **oldest** spans are
    /// evicted (the eviction count is exported, never hidden).
    pub ring_spans: usize,
    /// How many trailing rounds a crash dump keeps.
    pub flight_rounds: usize,
}

impl TraceConfig {
    pub fn new(dir: impl Into<PathBuf>) -> TraceConfig {
        TraceConfig {
            dir: dir.into(),
            ring_spans: 1 << 16,
            flight_rounds: 64,
        }
    }

    pub fn ring_spans(mut self, n: usize) -> TraceConfig {
        self.ring_spans = n;
        self
    }

    pub fn flight_rounds(mut self, n: usize) -> TraceConfig {
        self.flight_rounds = n;
        self
    }
}
