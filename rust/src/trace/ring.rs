//! Fixed-capacity ring: the per-cell span buffer.

use std::collections::VecDeque;

/// Bounded FIFO that drops the **oldest** entries on overflow, counting
/// evictions so exports can report what was lost instead of silently
/// truncating.
#[derive(Clone, Debug)]
pub struct Ring<T> {
    buf: VecDeque<T>,
    cap: usize,
    evicted: u64,
}

impl<T> Ring<T> {
    /// `cap` is clamped to at least 1; storage grows lazily, so a large
    /// capacity costs nothing for short cells.
    pub fn new(cap: usize) -> Ring<T> {
        let cap = cap.max(1);
        Ring {
            buf: VecDeque::with_capacity(cap.min(1024)),
            cap,
            evicted: 0,
        }
    }

    pub fn push(&mut self, item: T) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.evicted += 1;
        }
        self.buf.push_back(item);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Entries dropped to stay within capacity.
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    /// Surviving entries, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.buf.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_newest_and_counts_evictions() {
        let mut r = Ring::new(3);
        for i in 0..7 {
            r.push(i);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.evicted(), 4);
        assert_eq!(r.iter().copied().collect::<Vec<i32>>(), vec![4, 5, 6]);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut r = Ring::new(0);
        r.push('a');
        r.push('b');
        assert_eq!(r.iter().copied().collect::<Vec<char>>(), vec!['b']);
        assert_eq!(r.evicted(), 1);
    }
}
