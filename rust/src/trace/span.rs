//! Span model: the intervals a cell records.
//!
//! Only round and phase spans live in the per-cell ring — session and
//! cell spans are synthesized at export from [`super::CellTrace`]
//! bookkeeping — so the hot path stores one fixed-size `Copy` record
//! per measured interval and never allocates.

use std::fmt;

/// One phase of the server's round pipeline (plus the observer dispatch
/// that happens between rounds).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    /// Environment step: channel draw, availability, parameter drift.
    EnvStep,
    /// Scheduling + resource allocation: the policy's plan (Algorithm 2
    /// for LROA), client sampling, plan scatter, and the cost model.
    Solve,
    /// Local training (or modeled compute) for the selected clients.
    Train,
    /// Post-train bookkeeping: virtual-queue update and metric record.
    Aggregate,
    /// Observer dispatch of the round's streamed `RoundEvent`.
    Observe,
}

impl Phase {
    pub const ALL: [Phase; 5] = [
        Phase::EnvStep,
        Phase::Solve,
        Phase::Train,
        Phase::Aggregate,
        Phase::Observe,
    ];

    /// Snake-case name used in Chrome `name` fields and summary keys.
    pub fn name(self) -> &'static str {
        match self {
            Phase::EnvStep => "env_step",
            Phase::Solve => "solve",
            Phase::Train => "train",
            Phase::Aggregate => "aggregate",
            Phase::Observe => "observe",
        }
    }
}

impl fmt::Display for Phase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// What kind of interval a ring-buffered span covers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// One full `Server::round` call (emitted by `RoundDriver::step`).
    Round,
    /// One pipeline phase inside (or, for observe, right after) a round.
    Phase(Phase),
}

/// Monotonic counters: attached to solve spans, summed per cell.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    /// Algorithm 2 outer (alternating-minimization) iterations.
    pub outer_iters: u64,
    /// SUM inner iterations across all outer passes.
    pub inner_iters: u64,
    /// Rounds whose solve started from the previous round's fixed point
    /// (`SolverStats::warm_start_hit`).
    pub warm_start_hits: u64,
    /// Bytes of metric CSV the cell produced (counted once, at submit).
    pub bytes_written: u64,
}

impl Counters {
    pub fn add(&mut self, other: &Counters) {
        self.outer_iters += other.outer_iters;
        self.inner_iters += other.inner_iters;
        self.warm_start_hits += other.warm_start_hits;
        self.bytes_written += other.bytes_written;
    }
}

/// One recorded interval, relative to the session epoch.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub kind: SpanKind,
    /// Round index the interval belongs to.
    pub round: usize,
    /// Start, nanoseconds since the session epoch.
    pub ts_ns: u64,
    pub dur_ns: u64,
    /// Solve spans carry the round's solver counters; zeroed elsewhere.
    pub counters: Counters,
}
