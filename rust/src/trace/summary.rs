//! Compact per-cell aggregation: `trace_summary.json`.
//!
//! One object per cell with order statistics (min/p50/p95/max, count,
//! total) for every phase and for whole rounds, plus the cell's summed
//! counters.  This is the machine-readable companion to the Chrome
//! trace — `lroa trace summarize` pretty-prints it, and CI asserts its
//! solve-phase totals against the metric CSV's `solver_time_s`.

use super::hub::CellTrace;
use super::span::{Phase, SpanKind};
use crate::json::{obj, Json};

pub const SCHEMA: &str = "lroa-trace-v1";

/// Order statistics over one span population's durations [ns].
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseStats {
    pub count: u64,
    pub total_ns: u64,
    pub min_ns: u64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub max_ns: u64,
}

impl PhaseStats {
    /// Sorts `durs` in place; all-zero stats for an empty population.
    pub fn from_durations(durs: &mut [u64]) -> PhaseStats {
        if durs.is_empty() {
            return PhaseStats::default();
        }
        durs.sort_unstable();
        let pct = |q: f64| durs[((durs.len() - 1) as f64 * q).round() as usize];
        PhaseStats {
            count: durs.len() as u64,
            total_ns: durs.iter().sum(),
            min_ns: durs[0],
            p50_ns: pct(0.5),
            p95_ns: pct(0.95),
            max_ns: durs[durs.len() - 1],
        }
    }

    fn json(&self) -> Json {
        obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("max_ns", Json::Num(self.max_ns as f64)),
            ("min_ns", Json::Num(self.min_ns as f64)),
            ("p50_ns", Json::Num(self.p50_ns as f64)),
            ("p95_ns", Json::Num(self.p95_ns as f64)),
            ("total_ns", Json::Num(self.total_ns as f64)),
        ])
    }
}

fn stats_for(cell: &CellTrace, kind: SpanKind) -> PhaseStats {
    let mut durs: Vec<u64> = cell
        .spans()
        .filter(|s| s.kind == kind)
        .map(|s| s.dur_ns)
        .collect();
    PhaseStats::from_durations(&mut durs)
}

fn cell_json(cell: &CellTrace) -> Json {
    let phases: Vec<(&str, Json)> = Phase::ALL
        .iter()
        .map(|&p| (p.name(), stats_for(cell, SpanKind::Phase(p)).json()))
        .collect();
    let c = cell.counters();
    obj(vec![
        ("cell", Json::Num(cell.cell() as f64)),
        (
            "counters",
            obj(vec![
                ("bytes_written", Json::Num(c.bytes_written as f64)),
                ("inner_iters", Json::Num(c.inner_iters as f64)),
                ("outer_iters", Json::Num(c.outer_iters as f64)),
                ("warm_start_hits", Json::Num(c.warm_start_hits as f64)),
            ]),
        ),
        ("dur_ns", Json::Num(cell.dur_ns() as f64)),
        ("label", Json::Str(cell.label().to_string())),
        ("phases", obj(phases)),
        ("round", stats_for(cell, SpanKind::Round).json()),
        ("rounds", Json::Num(cell.rounds_done() as f64)),
        ("spans_evicted", Json::Num(cell.spans_evicted() as f64)),
        ("tid", Json::Num(cell.tid() as f64)),
    ])
}

/// The whole session's summary document.
pub(super) fn summary_json(session_dur_ns: u64, cells: &[CellTrace]) -> Json {
    obj(vec![
        ("cells", Json::Arr(cells.iter().map(cell_json).collect())),
        ("schema", Json::Str(SCHEMA.into())),
        ("session_dur_ns", Json::Num(session_dur_ns as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_order_statistics() {
        let mut durs = vec![50, 10, 30, 20, 40];
        let s = PhaseStats::from_durations(&mut durs);
        assert_eq!(s.count, 5);
        assert_eq!(s.total_ns, 150);
        assert_eq!(s.min_ns, 10);
        assert_eq!(s.p50_ns, 30);
        assert_eq!(s.max_ns, 50);
        assert_eq!(s.p95_ns, 50);
    }

    #[test]
    fn empty_population_is_all_zero() {
        let s = PhaseStats::from_durations(&mut []);
        assert_eq!(s.count, 0);
        assert_eq!(s.total_ns, 0);
        assert_eq!(s.max_ns, 0);
    }
}
