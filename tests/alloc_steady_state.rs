//! Steady-state allocation audit for the fleet-scale hot path.
//!
//! The 1M-device round is only tractable because stage 1 (environment
//! step) and stage 4 (cost evaluation) refill persistent buffers
//! instead of allocating per round.  This target installs a counting
//! `#[global_allocator]` (per-thread counter, `System` underneath) and
//! pins **zero** heap allocations at steady state — after a short
//! warmup that grows every buffer to capacity — for:
//!
//! * `Environment::step_into` of all four ported synthetic envs
//!   (`static`, `ge`, `avail`, `drift`),
//! * `ChannelProcess::next_round_into`,
//! * `RoundCosts::evaluate_into`.
//!
//! A separate `[[test]]` target so the counting allocator never leaks
//! into the other suites.  The counter is thread-local and `Cell<u64>`
//! is `const`-initialized (no lazy init, no destructor), so counting
//! itself cannot recurse into the allocator.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use lroa::config::{EnvConfig, EnvKind, SystemConfig};
use lroa::env::{self, EnvSoA};
use lroa::rng::Rng;
use lroa::system::{ChannelProcess, Device, Fleet, RoundCosts};

struct CountingAlloc;

thread_local! {
    static ALLOC_CALLS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn alloc_calls() -> u64 {
    ALLOC_CALLS.with(|c| c.get())
}

fn sys(n: usize) -> SystemConfig {
    SystemConfig {
        num_devices: n,
        ..SystemConfig::default()
    }
}

/// Dynamics cranked up so every env's buffers actually churn (gain
/// redraws, availability transitions, drift walks) while we count.
fn env_cfg() -> EnvConfig {
    EnvConfig {
        ge_p_bad: 0.3,
        ge_p_good: 0.4,
        avail_p_drop: 0.3,
        avail_p_join: 0.3,
        drift_sigma: 0.05,
        ..EnvConfig::default()
    }
}

#[test]
fn env_step_into_is_alloc_free_at_steady_state() {
    let sys = sys(64);
    let ecfg = env_cfg();
    let mut rng = Rng::new(3);
    let fleet = Fleet::generate(&sys, (50, 100), &mut rng);
    for kind in [
        EnvKind::Static,
        EnvKind::GilbertElliott,
        EnvKind::Availability,
        EnvKind::Drift,
    ] {
        let mut env = env::build(
            kind,
            &env::EnvInit {
                sys: &sys,
                env: &ecfg,
                seed: 11,
            },
        )
        .unwrap();
        let mut soa = EnvSoA::new();
        // Warmup: grow every buffer (gains, availability, drift
        // columns) to steady-state capacity.
        for _ in 0..3 {
            env.step_into(&fleet.devices, &mut soa);
        }
        let before = alloc_calls();
        for _ in 0..50 {
            env.step_into(&fleet.devices, &mut soa);
        }
        let after = alloc_calls();
        assert_eq!(
            after - before,
            0,
            "{kind}: step_into allocated {} time(s) over 50 steady-state rounds",
            after - before
        );
    }
}

/// The composite combinator must stay alloc-free even though it layers
/// several child mechanisms plus the correlated-shadowing field: every
/// child steps into persistent scratch columns and the merge writes the
/// output SoA in place.  Pinned at population scale (100k devices) with
/// the default `avail+ge+drift` stack and shadowing on so the gain
/// merge, the AND-availability repair, and the shadow walk all churn.
#[test]
fn composite_step_into_is_alloc_free_at_100k_devices() {
    let sys = sys(100_000);
    let ecfg = EnvConfig {
        shadow_std: 0.3,
        shadow_rho: 0.5,
        ..env_cfg()
    };
    let mut rng = Rng::new(5);
    let fleet = Fleet::generate(&sys, (50, 100), &mut rng);
    let mut env = env::build(
        EnvKind::Composite,
        &env::EnvInit {
            sys: &sys,
            env: &ecfg,
            seed: 23,
        },
    )
    .unwrap();
    let mut soa = EnvSoA::new();
    for _ in 0..3 {
        env.step_into(&fleet.devices, &mut soa);
    }
    let before = alloc_calls();
    for _ in 0..25 {
        env.step_into(&fleet.devices, &mut soa);
    }
    let after = alloc_calls();
    assert_eq!(
        after - before,
        0,
        "composite step_into allocated {} time(s) over 25 steady-state rounds",
        after - before
    );
}

#[test]
fn channel_next_round_into_is_alloc_free_at_steady_state() {
    let sys = sys(128);
    let mut channel = ChannelProcess::new(&sys, 29);
    let mut gains: Vec<f64> = Vec::new();
    channel.next_round_into(&mut gains);
    assert_eq!(gains.len(), 128);
    let before = alloc_calls();
    for _ in 0..100 {
        channel.next_round_into(&mut gains);
    }
    assert_eq!(alloc_calls() - before, 0, "next_round_into allocated");
}

#[test]
fn evaluate_into_is_alloc_free_at_steady_state() {
    let sys = sys(64);
    let mut rng = Rng::new(7);
    let fleet = Fleet::generate(&sys, (50, 100), &mut rng);
    let model_bits = 32.0 * 136_874.0;
    let h: Vec<f64> = (0..64).map(|_| rng.range(0.01, 0.5)).collect();
    let f_hz: Vec<f64> = fleet.devices.iter().map(|d| d.f_max_hz).collect();
    let p_w: Vec<f64> = fleet.devices.iter().map(|d| d.p_max_w).collect();
    let mut costs = RoundCosts::default();
    costs.evaluate_into(&sys, &fleet.devices, model_bits, &h, &f_hz, &p_w);
    let before = alloc_calls();
    for _ in 0..100 {
        costs.evaluate_into(&sys, &fleet.devices, model_bits, &h, &f_hz, &p_w);
    }
    assert_eq!(alloc_calls() - before, 0, "evaluate_into allocated");
    // And the refill really recomputed: same inputs, same outputs as a
    // fresh evaluation.
    let fresh = RoundCosts::evaluate(&sys, &fleet.devices, model_bits, &h, &f_hz, &p_w);
    assert_eq!(costs.time_s, fresh.time_s);
    assert_eq!(costs.energy_j, fresh.energy_j);
}

#[test]
fn counting_allocator_actually_counts() {
    // Sanity: the audit above is meaningless if the counter is dead.
    let before = alloc_calls();
    let v: Vec<Device> = Vec::with_capacity(16);
    assert!(alloc_calls() > before, "allocator counter never fired");
    drop(v);
}
