//! Black-box CLI contract tests: the `lroa` binary's documented exit
//! codes (`0` success, `1` runtime/config error, `2` usage error) and
//! the `--json` stdout-purity guarantee, pinned by driving the real
//! executable via `CARGO_BIN_EXE_lroa`.
//!
//! These are the codes scripts and CI steps branch on; a silent change
//! (e.g. a usage error collapsing into the generic `1`) must fail here,
//! not in a downstream pipeline.

use std::process::{Command, Output};

fn lroa(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_lroa"))
        .args(args)
        .output()
        .expect("spawn lroa")
}

fn exit_code(out: &Output) -> i32 {
    out.status.code().expect("lroa terminated by signal")
}

#[test]
fn help_exits_zero() {
    let out = lroa(&["help"]);
    assert_eq!(exit_code(&out), 0);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("EXIT CODES"), "help must document exit codes");
    assert!(text.contains("scale"), "help must document the scale subcommand");
}

#[test]
fn unknown_subcommand_is_a_usage_error() {
    let out = lroa(&["frobnicate"]);
    assert_eq!(exit_code(&out), 2);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown subcommand"), "stderr: {err}");
}

#[test]
fn bad_sweep_flag_is_a_usage_error() {
    let out = lroa(&["sweep", "--bogus=1"]);
    assert_eq!(exit_code(&out), 2);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown flag"), "stderr: {err}");

    // Same contract for the non-sweep arg parsers.
    assert_eq!(exit_code(&lroa(&["bench", "--nope"])), 2);
    assert_eq!(exit_code(&lroa(&["scale", "--nope=1"])), 2);
    assert_eq!(exit_code(&lroa(&["scale", "--ns=abc"])), 2);
    assert_eq!(exit_code(&lroa(&["trace", "mangle"])), 2);
}

#[test]
fn missing_trace_file_is_a_runtime_error() {
    // `trace summarize` on a directory that was never written: a
    // runtime failure (the invocation itself is well-formed), so 1.
    let out = lroa(&["trace", "summarize", "/definitely/not/a/trace/dir"]);
    assert_eq!(exit_code(&out), 1);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("trace_summary.json"), "stderr: {err}");

    // Likewise a trace *environment* pointed at a missing replay log.
    let out = lroa(&[
        "sim",
        "--env.kind=trace",
        "--env.trace_path=/definitely/not/a/trace.csv",
        "--train.rounds=1",
        "--system.num_devices=8",
    ]);
    assert_eq!(exit_code(&out), 1);
}

#[test]
fn invalid_config_is_a_runtime_error() {
    // Well-formed flag, invalid value: config validation fails, exit 1
    // (not 2 — the command line itself parsed fine).
    let out = lroa(&["sim", "--system.num_devices=0"]);
    assert_eq!(exit_code(&out), 1);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("num_devices"), "stderr: {err}");
}

#[test]
fn sweep_json_stdout_is_exactly_one_json_object() {
    let dir = std::env::temp_dir().join(format!("lroa-exit-codes-{}", std::process::id()));
    let out_flag = format!("--out={}", dir.display());
    let out = lroa(&[
        "sweep",
        "--json",
        "--policies=uni-s",
        "--seeds=1",
        "--rounds=2",
        "--system.num_devices=8",
        &out_flag,
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(exit_code(&out), 0, "stdout: {stdout}\nstderr: {stderr}");
    // Exactly one JSON value on stdout, nothing else: the whole stream
    // must parse in one shot.
    let parsed = lroa::json::Json::parse(stdout.trim())
        .unwrap_or_else(|e| panic!("stdout is not one JSON object: {e}\n---\n{stdout}"));
    assert!(
        parsed.get("groups").is_some(),
        "grid summary JSON missing groups: {stdout}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
