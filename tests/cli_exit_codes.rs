//! Black-box CLI contract tests: the `lroa` binary's documented exit
//! codes (`0` success, `1` runtime/config error, `2` usage error) and
//! the `--json` stdout-purity guarantee, pinned by driving the real
//! executable via `CARGO_BIN_EXE_lroa`.
//!
//! These are the codes scripts and CI steps branch on; a silent change
//! (e.g. a usage error collapsing into the generic `1`) must fail here,
//! not in a downstream pipeline.

use std::process::{Command, Output};

fn lroa(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_lroa"))
        .args(args)
        .output()
        .expect("spawn lroa")
}

fn exit_code(out: &Output) -> i32 {
    out.status.code().expect("lroa terminated by signal")
}

#[test]
fn help_exits_zero() {
    let out = lroa(&["help"]);
    assert_eq!(exit_code(&out), 0);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("EXIT CODES"), "help must document exit codes");
    assert!(text.contains("scale"), "help must document the scale subcommand");
}

#[test]
fn unknown_subcommand_is_a_usage_error() {
    let out = lroa(&["frobnicate"]);
    assert_eq!(exit_code(&out), 2);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown subcommand"), "stderr: {err}");
}

#[test]
fn bad_sweep_flag_is_a_usage_error() {
    let out = lroa(&["sweep", "--bogus=1"]);
    assert_eq!(exit_code(&out), 2);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown flag"), "stderr: {err}");

    // Same contract for the non-sweep arg parsers.
    assert_eq!(exit_code(&lroa(&["bench", "--nope"])), 2);
    assert_eq!(exit_code(&lroa(&["scale", "--nope=1"])), 2);
    assert_eq!(exit_code(&lroa(&["scale", "--ns=abc"])), 2);
    assert_eq!(exit_code(&lroa(&["trace", "mangle"])), 2);
}

#[test]
fn missing_trace_file_is_a_runtime_error() {
    // `trace summarize` on a directory that was never written: a
    // runtime failure (the invocation itself is well-formed), so 1.
    let out = lroa(&["trace", "summarize", "/definitely/not/a/trace/dir"]);
    assert_eq!(exit_code(&out), 1);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("trace_summary.json"), "stderr: {err}");

    // Likewise a trace *environment* pointed at a missing replay log.
    let out = lroa(&[
        "sim",
        "--env.kind=trace",
        "--env.trace_path=/definitely/not/a/trace.csv",
        "--train.rounds=1",
        "--system.num_devices=8",
    ]);
    assert_eq!(exit_code(&out), 1);
}

#[test]
fn invalid_config_is_a_runtime_error() {
    // Well-formed flag, invalid value: config validation fails, exit 1
    // (not 2 — the command line itself parsed fine).
    let out = lroa(&["sim", "--system.num_devices=0"]);
    assert_eq!(exit_code(&out), 1);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("num_devices"), "stderr: {err}");
}

#[test]
fn trace_import_usage_errors_exit_two() {
    // Missing --out: the invocation shape is wrong, so 2.
    let out = lroa(&["trace", "import", "in.csv"]);
    assert_eq!(exit_code(&out), 2);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--out"), "stderr: {err}");

    // Missing input positional.
    assert_eq!(exit_code(&lroa(&["trace", "import", "--out=x.csv"])), 2);
    // Unknown flag.
    assert_eq!(
        exit_code(&lroa(&["trace", "import", "in.csv", "--out=x.csv", "--bogus=1"])),
        2
    );
    // Well-formed flags with out-of-domain values are still usage errors.
    assert_eq!(
        exit_code(&lroa(&["trace", "import", "in.csv", "--out=x.csv", "--gain-scale=0"])),
        2
    );
    assert_eq!(
        exit_code(&lroa(&["trace", "import", "in.csv", "--out=x.csv", "--round-per=-1"])),
        2
    );
}

#[test]
fn trace_import_runtime_errors_exit_one() {
    // Missing input file: well-formed invocation, runtime failure.
    let out = lroa(&["trace", "import", "/definitely/not/a/log.csv", "--out=/tmp/x.csv"]);
    assert_eq!(exit_code(&out), 1);

    // Present but malformed input (no mappable gain column).
    let dir = std::env::temp_dir().join(format!("lroa-import-bad-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let input = dir.join("bad.csv");
    std::fs::write(&input, "round,device\n0,0\n").unwrap();
    let out_flag = format!("--out={}", dir.join("out.csv").display());
    let out = lroa(&["trace", "import", input.to_str().unwrap(), &out_flag]);
    assert_eq!(exit_code(&out), 1);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("no column"), "stderr: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trace_import_json_is_one_object_and_the_output_replays() {
    let dir = std::env::temp_dir().join(format!("lroa-import-rt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let input = dir.join("field_log.csv");
    // Foreign schema: renamed columns, string device keys, a gap row.
    std::fs::write(
        &input,
        "ts,node,rssi,up\n\
         0,gw-a,0.25,1\n\
         0,gw-b,0.5,1\n\
         1,gw-a,,1\n\
         1,gw-b,0.25,0\n\
         2,gw-a,0.75,1\n\
         2,gw-b,0.5,1\n",
    )
    .unwrap();
    let imported = dir.join("imported.csv");
    let out_flag = format!("--out={}", imported.display());
    let out = lroa(&[
        "trace",
        "import",
        input.to_str().unwrap(),
        &out_flag,
        "--round-col=ts",
        "--device-col=node",
        "--gain-col=rssi",
        "--avail-col=up",
        "--json",
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(exit_code(&out), 0, "stdout: {stdout}\nstderr: {stderr}");
    // Exactly one JSON object on stdout.
    let report = lroa::json::Json::parse(stdout.trim())
        .unwrap_or_else(|e| panic!("stdout is not one JSON object: {e}\n---\n{stdout}"));
    assert_eq!(
        report.get("schema").and_then(|s| s.as_str()),
        Some("lroa-trace-import-v1")
    );
    assert_eq!(report.get("devices").and_then(|d| d.as_f64()), Some(2.0));
    assert_eq!(report.get("interpolated").and_then(|d| d.as_f64()), Some(1.0));

    // Round-trip: the imported log must drive a trace environment sweep.
    let sweep_dir = dir.join("sweep");
    let envs_flag = format!("--envs=trace:{}", imported.display());
    let sweep_out_flag = format!("--out={}", sweep_dir.display());
    let out = lroa(&[
        "sweep",
        "--json",
        &envs_flag,
        "--policies=uni-s",
        "--seeds=1",
        "--rounds=3",
        "--system.num_devices=4",
        &sweep_out_flag,
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(exit_code(&out), 0, "stdout: {stdout}\nstderr: {stderr}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_json_stdout_is_exactly_one_json_object() {
    let dir = std::env::temp_dir().join(format!("lroa-exit-codes-{}", std::process::id()));
    let out_flag = format!("--out={}", dir.display());
    let out = lroa(&[
        "sweep",
        "--json",
        "--policies=uni-s",
        "--seeds=1",
        "--rounds=2",
        "--system.num_devices=8",
        &out_flag,
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(exit_code(&out), 0, "stdout: {stdout}\nstderr: {stderr}");
    // Exactly one JSON value on stdout, nothing else: the whole stream
    // must parse in one shot.
    let parsed = lroa::json::Json::parse(stdout.trim())
        .unwrap_or_else(|e| panic!("stdout is not one JSON object: {e}\n---\n{stdout}"));
    assert!(
        parsed.get("groups").is_some(),
        "grid summary JSON missing groups: {stdout}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
