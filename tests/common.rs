//! Helpers shared by the integration-test targets (each pulls this in
//! with `mod common;` — explicit `[[test]]` targets in Cargo.toml keep
//! Cargo from treating this file as a test target of its own) **and** by
//! the library's in-crate unit tests, which include the same file as
//! `lroa::test_util` (`#[path]` module in `rust/src/lib.rs`).  One
//! source, two inclusion paths: the fixture locations can never drift.

/// Absolute path of the recorded-trace fixture
/// (`tests/fixtures/campus.csv`; schema in `tests/fixtures/README.md`).
pub fn campus_fixture() -> String {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/campus.csv")
        .to_string_lossy()
        .into_owned()
}
