//! Golden determinism tests for the `env` subsystem.
//!
//! Every environment must be a pure function of its seed: the same seed
//! yields the same gain/availability/drift trajectory in any process, at
//! any scenario-pool width, and `env = static` must reproduce the
//! pre-env [`ChannelProcess`] stream bitwise (the policy-parity suite in
//! `tests/policy_parity.rs` extends that proof to full server
//! trajectories).

use lroa::config::{Config, EnvConfig, EnvKind, Policy, SystemConfig};
use lroa::env::{self, EnvInit, Environment};
use lroa::exp::{self, EnvSel, SweepSpec};
use lroa::rng::Rng;
use lroa::system::{ChannelProcess, Fleet};

mod common;
use common::campus_fixture as fixture_path;

fn sys(n: usize) -> SystemConfig {
    SystemConfig {
        num_devices: n,
        ..SystemConfig::default()
    }
}

fn env_cfg() -> EnvConfig {
    EnvConfig {
        // Crank the dynamics so short test horizons exercise them.
        ge_p_bad: 0.3,
        ge_p_good: 0.4,
        avail_p_drop: 0.3,
        avail_p_join: 0.3,
        drift_sigma: 0.05,
        trace_path: fixture_path(),
        ..EnvConfig::default()
    }
}

fn build(kind: EnvKind, sys: &SystemConfig, ecfg: &EnvConfig, seed: u64) -> Box<dyn Environment> {
    env::build(
        kind,
        &EnvInit {
            sys,
            env: ecfg,
            seed,
        },
    )
    .unwrap()
}

/// One round's observable environment trace, for exact comparison.
#[derive(Debug, PartialEq)]
struct Trace {
    gains: Vec<f64>,
    /// `None` = whole fleet reachable (always-on environments).
    available: Option<Vec<usize>>,
    f_max: Option<Vec<f64>>,
}

fn trajectory(kind: EnvKind, seed: u64, rounds: usize) -> Vec<Trace> {
    let sys = sys(14);
    let ecfg = env_cfg();
    let mut rng = Rng::new(4);
    let fleet = Fleet::generate(&sys, (50, 150), &mut rng);
    let mut e = build(kind, &sys, &ecfg, seed);
    (0..rounds)
        .map(|_| {
            let re = e.next_round(&fleet.devices);
            Trace {
                gains: re.gains,
                available: re.available,
                f_max: re
                    .devices
                    .map(|ds| ds.iter().map(|d| d.f_max_hz).collect()),
            }
        })
        .collect()
}

/// Same trajectory shape as [`trajectory`], realized through the
/// fleet-scale [`env::EnvSoA`] path instead of the per-[`Device`] one.
/// Identical construction (fleet seed, sizes) so the two are directly
/// comparable.
///
/// [`Device`]: lroa::system::Device
fn soa_trajectory(kind: EnvKind, seed: u64, rounds: usize) -> Vec<Trace> {
    let sys = sys(14);
    let ecfg = env_cfg();
    let mut rng = Rng::new(4);
    let fleet = Fleet::generate(&sys, (50, 150), &mut rng);
    let mut e = build(kind, &sys, &ecfg, seed);
    let mut soa = env::EnvSoA::new();
    (0..rounds)
        .map(|_| {
            e.step_into(&fleet.devices, &mut soa);
            Trace {
                gains: soa.gains.clone(),
                available: if soa.all_available {
                    None
                } else {
                    Some(soa.available.clone())
                },
                f_max: if soa.drifted {
                    Some(soa.f_max_hz.clone())
                } else {
                    None
                },
            }
        })
        .collect()
}

#[test]
fn every_environment_is_a_pure_function_of_its_seed() {
    for kind in EnvKind::ALL {
        let a = trajectory(kind, 11, 80);
        let b = trajectory(kind, 11, 80);
        assert_eq!(a, b, "{kind}: same seed diverged");
        let c = trajectory(kind, 12, 80);
        if kind == EnvKind::Trace {
            // Replay consumes no randomness at all: any seed yields the
            // recorded log, bitwise.
            assert_eq!(a, c, "{kind}: replay must be seed-independent");
        } else {
            assert_ne!(a, c, "{kind}: different seeds coincided");
        }
    }
}

#[test]
fn static_env_reproduces_the_pre_env_channel_stream_bitwise() {
    let sys = sys(14);
    let ecfg = EnvConfig::default();
    let mut e = build(EnvKind::Static, &sys, &ecfg, 0xC4A1 ^ 7);
    let mut reference = ChannelProcess::new(&sys, 0xC4A1 ^ 7);
    let base: Vec<lroa::system::Device> = Vec::new();
    for _ in 0..60 {
        let re = e.next_round(&base);
        assert_eq!(re.gains, reference.next_round());
        assert!(re.available.is_none(), "static = whole fleet reachable");
        assert!(re.devices.is_none());
    }
}

#[test]
fn soa_stepping_matches_the_per_device_path_for_every_registry_env() {
    // The fleet-scale `step_into` path is the parity anchor's sibling:
    // same seed, same rounds, bitwise-identical trajectory for every
    // registered environment — including `trace` and `adv`, which ride
    // the default `set_from_round` adapter.
    for kind in EnvKind::ALL {
        let aos = trajectory(kind, 31, 60);
        let soa = soa_trajectory(kind, 31, 60);
        assert_eq!(aos, soa, "{kind}: SoA stepping diverged from per-Device path");
    }
}

#[test]
fn soa_stepping_is_thread_count_invariant() {
    // Trajectories realized on worker threads (2-wide pool) must match
    // the main-thread realization bitwise — environments own their RNG
    // streams, so nothing about the executing thread may leak in.
    let reference: Vec<(EnvKind, Vec<Trace>)> = EnvKind::ALL
        .into_iter()
        .map(|kind| (kind, soa_trajectory(kind, 17, 40)))
        .collect();
    let mid = reference.len() / 2;
    let (left, right) = reference.split_at(mid);
    std::thread::scope(|scope| {
        let workers = [
            scope.spawn(|| {
                for (kind, expected) in left {
                    assert_eq!(&soa_trajectory(*kind, 17, 40), expected, "{kind}");
                }
            }),
            scope.spawn(|| {
                for (kind, expected) in right {
                    assert_eq!(&soa_trajectory(*kind, 17, 40), expected, "{kind}");
                }
            }),
        ];
        for w in workers {
            w.join().expect("worker trajectory diverged");
        }
    });
}

#[test]
fn gain_streams_are_independent_of_the_availability_trajectory() {
    // avail and drift reuse the static channel construction: identical
    // gains round for round, whatever the mask/walk does.
    let stat = trajectory(EnvKind::Static, 21, 50);
    for kind in [EnvKind::Availability, EnvKind::Drift] {
        let dynamic = trajectory(kind, 21, 50);
        for (s, d) in stat.iter().zip(&dynamic) {
            assert_eq!(s.gains, d.gains, "{kind}: gains diverged from static");
        }
    }
}

#[test]
fn availability_varies_but_respects_the_k_floor() {
    let traces = trajectory(EnvKind::Availability, 5, 200);
    let k = sys(14).k;
    let mut saw_partial = false;
    for t in &traces {
        let av = t.available.as_ref().expect("avail env always reports N^t");
        assert!(av.len() >= k);
        saw_partial |= av.len() < 14;
    }
    assert!(saw_partial, "dropout never removed a device in 200 rounds");
}

/// A composite layered over every built-in mechanism behaves as one
/// environment: a single-child `compose:<x>` must reproduce `<x>`'s
/// trajectory bitwise through both realization paths (the composite
/// materializes `next_round` via its own `step_into`, and each child is
/// built with the same `EnvInit` it gets standalone).
#[test]
fn single_child_composite_matches_its_child_bitwise() {
    let sys = sys(14);
    let mut rng = Rng::new(4);
    let fleet = Fleet::generate(&sys, (50, 150), &mut rng);
    for child in ["static", "ge", "avail", "drift", "trace"] {
        let mut ecfg = env_cfg();
        ecfg.compose = child.into();
        let kind = EnvKind::parse(child).unwrap();
        let mut solo = build(kind, &sys, &ecfg, 9);
        let mut comp = build(EnvKind::Composite, &sys, &ecfg, 9);
        for t in 0..50 {
            let a = solo.next_round(&fleet.devices);
            let b = comp.next_round(&fleet.devices);
            assert_eq!(a.gains, b.gains, "compose:{child} gains diverged at t={t}");
            assert_eq!(
                a.available, b.available,
                "compose:{child} availability diverged at t={t}"
            );
            let overlay = |ds: Option<Vec<lroa::system::Device>>| {
                ds.map(|ds| {
                    ds.iter()
                        .map(|d| (d.f_max_hz, d.alpha))
                        .collect::<Vec<(f64, f64)>>()
                })
            };
            assert_eq!(
                overlay(a.devices),
                overlay(b.devices),
                "compose:{child} drift overlay diverged at t={t}"
            );
        }
    }
}

fn grid_spec() -> SweepSpec {
    let mut envs: Vec<EnvSel> = EnvKind::SYNTHETIC.iter().map(|&k| k.into()).collect();
    envs.push(EnvSel::parse(&format!("trace:{}", fixture_path())).unwrap());
    envs.push(EnvSel::parse("compose:diurnal").unwrap());
    SweepSpec {
        datasets: vec!["cifar".into()],
        policies: vec![Policy::Lroa, Policy::RoundRobin],
        envs,
        seeds: vec![1],
        rounds: Some(12),
        overrides: vec![
            "--system.num_devices=10".into(),
            "--env.avail_p_drop=0.3".into(),
        ],
        ..SweepSpec::default()
    }
}

#[test]
fn policy_by_env_grid_is_thread_count_invariant() {
    // The full policy × environment grid must produce bitwise-identical
    // trajectories at any scenario-pool width.  Since the server rounds
    // here run entirely through `step_into` + SoA compaction, this also
    // pins the fleet-scale stepping path at two pool widths end to end.
    let seq = exp::run_scenarios(grid_spec().expand().unwrap(), 1).unwrap();
    let par = exp::run_scenarios(grid_spec().expand().unwrap(), 4).unwrap();
    assert_eq!(seq.len(), 2 * 7);
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.scenario.label, b.scenario.label);
        for (ra, rb) in a.recorder.rounds.iter().zip(&b.recorder.rounds) {
            assert_eq!(ra.round_time_s, rb.round_time_s, "{}", a.scenario.label);
            assert_eq!(ra.objective, rb.objective, "{}", a.scenario.label);
            assert_eq!(ra.mean_energy_j, rb.mean_energy_j, "{}", a.scenario.label);
        }
    }
    // Environments actually differ from one another under a shared seed
    // (compare (time, energy) — drift may leave an interior f untouched
    // in a single round, but energy moves with the drifted alpha).
    let series = |r: &exp::ScenarioResult| -> Vec<(f64, f64)> {
        r.recorder
            .rounds
            .iter()
            .map(|x| (x.round_time_s, x.mean_energy_j))
            .collect()
    };
    let stat = &seq[0];
    assert_eq!(stat.scenario.cfg.env.kind, EnvKind::Static);
    for r in &seq[1..7] {
        assert_ne!(
            series(stat),
            series(r),
            "{} coincides with static",
            r.scenario.label
        );
    }
}

#[test]
fn sweep_manifest_covers_the_whole_env_grid() {
    let spec = grid_spec();
    let cells = spec.expand().unwrap();
    let manifest = exp::manifest_json(&cells);
    let arr = manifest.get("cells").and_then(|c| c.as_arr()).unwrap();
    assert_eq!(arr.len(), 14);
    let envs: Vec<&str> = arr
        .iter()
        .map(|c| c.get("env").unwrap().as_str().unwrap())
        .collect();
    for name in ["static", "ge", "avail", "drift", "trace", "adv", "compose"] {
        assert_eq!(
            envs.iter().filter(|&&e| e == name).count(),
            2,
            "{name} cells missing from manifest"
        );
    }
    // Trace cells record their log; the schema names the regret column.
    let trace_cell = arr
        .iter()
        .find(|c| c.get("env").unwrap().as_str() == Some("trace"))
        .unwrap();
    assert!(trace_cell
        .get("env_trace")
        .and_then(|t| t.as_str())
        .unwrap()
        .ends_with("campus.csv"));
    // Composite cells record their child spec verbatim (preset unexpanded).
    let compose_cell = arr
        .iter()
        .find(|c| c.get("env").unwrap().as_str() == Some("compose"))
        .unwrap();
    assert_eq!(
        compose_cell.get("env_compose").and_then(|t| t.as_str()),
        Some("diurnal")
    );
    let columns = manifest.get("columns").and_then(|c| c.as_arr()).unwrap();
    assert!(columns.iter().any(|c| c.as_str() == Some("regret")));
}

#[test]
fn explicit_env_static_config_round_trips() {
    let mut cfg = Config::for_dataset("cifar").unwrap();
    cfg.apply_cli(&["--env.kind=avail", "--env.avail_p_drop=0.2"]).unwrap();
    assert_eq!(cfg.env.kind, EnvKind::Availability);
    assert!(cfg.validate().is_ok());
    assert!(cfg.dump().contains("kind=avail"));
}
