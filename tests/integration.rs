//! Cross-module integration tests: config → server → metrics, Lyapunov
//! behaviour over long horizons, policy comparisons on shared channels,
//! the sweep engine, and failure injection.  All control-plane-only (no
//! artifacts needed), so they run in CI without `make artifacts`.

use lroa::config::{Config, Policy};
use lroa::exp::{self, SweepSpec};
use lroa::fl::{Server, SimMode};
use lroa::metrics::mean_series;

fn cfg(policy: Policy, rounds: usize, nu: f64) -> Config {
    let mut cfg = Config::for_dataset("cifar").unwrap();
    cfg.system.num_devices = 40;
    cfg.train.rounds = rounds;
    cfg.train.policy = policy;
    cfg.control.nu = nu;
    cfg.train.samples_per_device = (50, 200);
    cfg
}

#[test]
fn v_controls_energy_vs_objective_tradeoff() {
    // Theorem 4's O(1/V) objective / O(V) queue split, empirically:
    // larger V => lower time-averaged objective; smaller V => the
    // time-averaged energy approaches the budget faster/lower.
    let run = |nu: f64| {
        let mut s = Server::new(cfg(Policy::Lroa, 600, nu), SimMode::ControlPlaneOnly).unwrap();
        s.run().unwrap();
        (
            *s.recorder.time_avg_energy().last().unwrap(),
            *s.recorder.time_avg_objective().last().unwrap(),
        )
    };
    let (e_small_v, obj_small_v) = run(1e2);
    let (e_large_v, obj_large_v) = run(1e6);
    assert!(
        obj_large_v <= obj_small_v * 1.001,
        "large V should not worsen the objective: {obj_large_v} vs {obj_small_v}"
    );
    assert!(
        e_small_v <= e_large_v * 1.001,
        "small V should enforce energy at least as tightly: {e_small_v} vs {e_large_v}"
    );
}

#[test]
fn queues_stabilize_under_small_v() {
    // With a small V, queue backlogs must not grow linearly forever.
    let mut s = Server::new(cfg(Policy::Lroa, 800, 1e2), SimMode::ControlPlaneOnly).unwrap();
    s.run().unwrap();
    let q_mid = s.recorder.rounds[400].mean_queue;
    let q_end = s.recorder.rounds[799].mean_queue;
    // Growth in the second half must be well below the first half's level
    // (i.e. sub-linear), or the backlog is outright shrinking.
    assert!(
        q_end < q_mid * 1.75 + 1.0,
        "queues appear unstable: mid {q_mid}, end {q_end}"
    );
}

#[test]
fn policies_share_identical_channels() {
    // The channel realization must be identical across policies for the
    // same seed (the paper's comparison methodology).
    let run = |policy: Policy| {
        let mut s = Server::new(cfg(policy, 5, 1e5), SimMode::ControlPlaneOnly).unwrap();
        s.run().unwrap();
        s
    };
    // Identical seeds => Uni-D and Uni-S rounds see the same channel, so
    // their *static-policy-independent* quantities line up: compare the
    // makespans of Uni-S across two constructions.
    let a = run(Policy::UniformStatic);
    let b = run(Policy::UniformStatic);
    for (ra, rb) in a.recorder.rounds.iter().zip(&b.recorder.rounds) {
        assert_eq!(ra.round_time_s, rb.round_time_s);
    }
}

#[test]
fn lroa_latency_beats_baselines_on_average() {
    // Average over several seeds: the paper's headline ordering
    // LROA < Uni-D < Uni-S in total modeled latency.  µ is set latency-
    // dominant (0.1): at larger µ LROA intentionally trades per-round
    // makespan for data-representative sampling (the Fig. 3 trade-off),
    // and its win shows up in time-to-accuracy rather than raw makespan.
    let total = |policy: Policy, seed: u64| {
        let mut c = cfg(policy, 120, 1e5);
        c.control.mu = 0.1;
        c.train.seed = seed;
        let mut s = Server::new(c, SimMode::ControlPlaneOnly).unwrap();
        s.run().unwrap();
        s.recorder.total_time_s()
    };
    let mean = |policy: Policy| -> f64 {
        (1..=5).map(|s| total(policy, s)).sum::<f64>() / 5.0
    };
    let (lroa, unid, unis) = (mean(Policy::Lroa), mean(Policy::UniformDynamic), mean(Policy::UniformStatic));
    assert!(lroa < unid, "LROA {lroa} should beat Uni-D {unid}");
    assert!(unid < unis, "Uni-D {unid} should beat Uni-S {unis}");
}

#[test]
fn k_increases_round_time() {
    // §VII-B.3: larger K splits bandwidth and exposes stragglers — the
    // per-round time grows with K.
    let total = |k: usize| {
        let mut c = cfg(Policy::Lroa, 100, 1e5);
        c.system.k = k;
        let mut s = Server::new(c, SimMode::ControlPlaneOnly).unwrap();
        s.run().unwrap();
        s.recorder.total_time_s()
    };
    let t2 = total(2);
    let t6 = total(6);
    assert!(t6 > t2, "K=6 time {t6} should exceed K=2 time {t2}");
}

#[test]
fn recorder_series_are_consistent() {
    let mut s = Server::new(cfg(Policy::Lroa, 50, 1e5), SimMode::ControlPlaneOnly).unwrap();
    s.run().unwrap();
    let rec = &s.recorder;
    // total_time is the prefix sum of round_time.
    let mut acc = 0.0;
    for r in &rec.rounds {
        acc += r.round_time_s;
        assert!((r.total_time_s - acc).abs() < 1e-9);
        assert!(r.solver_time_s >= 0.0);
        assert!(r.mean_queue <= r.max_queue + 1e-12);
    }
    // Running averages agree with a direct computation.
    let direct: Vec<f64> = {
        let xs: Vec<f64> = rec.rounds.iter().map(|r| r.mean_energy_j).collect();
        (0..xs.len())
            .map(|i| xs[..=i].iter().sum::<f64>() / (i + 1) as f64)
            .collect()
    };
    let series = rec.time_avg_energy();
    for (a, b) in series.iter().zip(&direct) {
        assert!((a - b).abs() < 1e-12);
    }
    let mean = mean_series(&[series.clone(), series.clone()]).unwrap();
    assert_eq!(mean, series);
    // Unequal repeat lengths are a recoverable error, not a panic.
    assert!(mean_series(&[series.clone(), series[..1].to_vec()]).is_err());
}

#[test]
fn hardware_heterogeneity_slows_static_more_than_lroa() {
    // With heterogeneous hardware, adaptive sampling should help more:
    // LROA's advantage over Uni-S does not shrink when spread increases.
    let ratio = |spread: f64| {
        let run = |policy: Policy| {
            let mut c = cfg(policy, 100, 1e5);
            c.system.hardware_spread = spread;
            let mut s = Server::new(c, SimMode::ControlPlaneOnly).unwrap();
            s.run().unwrap();
            s.recorder.total_time_s()
        };
        run(Policy::UniformStatic) / run(Policy::Lroa)
    };
    let r_homo = ratio(0.0);
    let r_hetero = ratio(0.4);
    assert!(
        r_hetero > 0.8 * r_homo,
        "heterogeneity collapsed LROA's advantage: {r_hetero} vs {r_homo}"
    );
}

#[test]
fn bad_config_is_rejected_before_running() {
    let mut c = cfg(Policy::Lroa, 10, 1e5);
    c.system.k = 0;
    assert!(Server::new(c, SimMode::ControlPlaneOnly).is_err());

    let mut c = cfg(Policy::Lroa, 10, 1e5);
    c.system.channel_clip = (0.5, 0.01); // inverted
    assert!(Server::new(c, SimMode::ControlPlaneOnly).is_err());
}

#[test]
fn sweep_engine_matches_direct_server_runs() {
    // A policy × seed sweep through the exp engine must reproduce what a
    // hand-rolled loop over Server::run produces, cell for cell.
    let spec = SweepSpec {
        datasets: vec!["cifar".into()],
        policies: vec![Policy::Lroa, Policy::UniformStatic],
        seeds: vec![1, 2],
        rounds: Some(12),
        overrides: vec!["--system.num_devices=10".into()],
        ..SweepSpec::default()
    };
    let results = exp::run_scenarios(spec.expand().unwrap(), 3).unwrap();
    assert_eq!(results.len(), 4);

    for r in &results {
        let mut server =
            Server::new(r.scenario.cfg.clone(), SimMode::ControlPlaneOnly).unwrap();
        server.run().unwrap();
        assert_eq!(server.recorder.rounds.len(), r.recorder.rounds.len());
        for (a, b) in server.recorder.rounds.iter().zip(&r.recorder.rounds) {
            assert_eq!(a.round_time_s, b.round_time_s, "{}", r.scenario.label);
            assert_eq!(a.objective, b.objective, "{}", r.scenario.label);
        }
    }

    // Seed repeats collapse to one summary row per policy.
    let groups = exp::summarize_groups(&results);
    assert_eq!(groups.len(), 2);
    assert!(groups.iter().all(|g| g.runs == 2));
}

#[test]
fn full_mode_without_artifacts_fails_cleanly() {
    let mut c = cfg(Policy::Lroa, 5, 1e5);
    c.artifacts_dir = "/nonexistent/path".into();
    let err = match Server::new(c, SimMode::Full) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected missing-artifacts error"),
    };
    assert!(err.contains("artifacts") || err.contains("manifest"), "{err}");
}
