//! Property-style invariant suite over the full policy × environment
//! registry cross-product.
//!
//! Every `(RoundPolicy, Environment)` pair — taken from the two
//! name→constructor registries, so a policy or environment **cannot** be
//! added without being covered here — is driven through a seeded round
//! loop that mirrors the server pipeline (environment draw → compaction
//! → plan → scatter → cost model → queue advance), and every round's
//! plan is checked against the structural invariants the rest of the
//! system relies on:
//!
//! * the sampling distribution `controls.q` is a proper distribution
//!   over the compacted candidate set (strictly positive, sums to 1);
//! * the participation marginals `q_eff` are either a distribution or a
//!   0/1 indicator (the deterministic selectors), never outside [0, 1];
//! * the participant multiset fills every one of the `K` slots with a
//!   position that is reachable in the compacted `RoundContext.ids`;
//! * per-device `f`/`p` stay inside `[f_min, f_max]`/`[p_min, p_max]`
//!   of the (possibly drifted) device parameters the policy was handed;
//! * virtual energy queues stay non-negative and finite after the
//!   round's update.
//!
//! The generator loop is plain seeded iteration (no external property-
//! testing dependency); failures name the offending
//! `(policy, env, seed, round)` tuple.  Conventions for extending this
//! suite live in `tests/README.md`.

use lroa::config::{Config, ControlConfig, EnvKind, Policy};
use lroa::control::policy::{self, PolicyInit, RoundContext};
use lroa::control::{LroaSolver, VirtualQueues};
use lroa::env::{self, EnvInit};
use lroa::fl::{Server, SimMode};
use lroa::rng::Rng;
use lroa::system::{selection_probability, Device, Fleet, RoundCosts};

mod common;

/// Rounds driven per (policy, env, seed) case.
const ROUNDS: usize = 25;

/// Seeds of the generator loop; each also perturbs the scenario shape
/// (fleet size, sampling frequency) so one pass covers several problem
/// geometries.
const SEEDS: [u64; 3] = [1, 2, 6];

#[test]
fn registries_cover_every_enum_variant() {
    // A new `Policy`/`EnvKind` variant that is not registered would
    // silently escape the cross-product below — make that impossible.
    for p in Policy::ALL {
        assert!(
            policy::REGISTRY.iter().any(|s| s.id == p),
            "{p} missing from the policy registry"
        );
    }
    for e in EnvKind::ALL {
        assert!(
            env::REGISTRY.iter().any(|s| s.id == e),
            "{e} missing from the env registry"
        );
    }
}

#[test]
fn every_policy_env_pair_upholds_the_round_invariants() {
    // Both solver initializations — the warm-started default and the
    // paper's cold restart — must uphold the invariants on every pair.
    for pspec in policy::REGISTRY {
        for espec in env::REGISTRY {
            for &seed in &SEEDS {
                for warm in [false, true] {
                    check_pair(pspec, espec, seed, warm);
                }
            }
        }
    }
}

fn check_pair(pspec: &policy::PolicySpec, espec: &env::EnvSpec, seed: u64, warm: bool) {
    let tag = format!(
        "(policy={}, env={}, seed={seed}, warm_start={warm})",
        pspec.name, espec.name
    );

    // Scenario generator: the seed perturbs the problem geometry.
    let mut cfg = Config::for_dataset("cifar").unwrap();
    cfg.system.num_devices = 10 + (seed as usize % 3) * 4; // 10 | 14 | 18
    cfg.system.k = 2 + (seed as usize % 2); //                2 | 3
    cfg.train.seed = seed;
    cfg.train.policy = pspec.id;
    cfg.env.kind = espec.id;
    cfg.env.trace_path = common::campus_fixture();
    cfg.env.avail_p_drop = 0.35; // make the candidate set actually move
    cfg.env.avail_p_join = 0.3;
    if espec.id == EnvKind::Composite {
        // Rotate the child spec with the seed so the cross-product also
        // covers the scenario presets, and turn correlated shadowing on
        // so the merged gain field runs under the invariants too.
        cfg.env.compose = match seed % 3 {
            0 => "flashcrowd".into(),
            1 => "diurnal".into(),
            _ => "outage".into(),
        };
        cfg.env.shadow_std = 0.2;
    }
    cfg.control.warm_start = warm;
    cfg.validate().unwrap_or_else(|e| panic!("{tag}: bad scenario config: {e:#}"));

    let n = cfg.system.num_devices;
    let k = cfg.system.k;
    let model_bits = 32.0 * 136_874.0;
    let mut fleet_rng = Rng::new(seed ^ 0xF1EE_7000);
    let fleet = Fleet::generate(&cfg.system, (40, 120), &mut fleet_rng);

    let init = PolicyInit {
        sys: &cfg.system,
        ctl: &cfg.control,
        bandit: cfg.bandit.clone(),
        thompson: cfg.thompson.clone(),
        linucb: cfg.linucb.clone(),
        lambda: 1.0,
        v: 1e4,
        model_bits,
        seed,
    };
    let mut round_policy = (pspec.build)(&init);
    let mut environment = (espec.build)(&EnvInit {
        sys: &cfg.system,
        env: &cfg.env,
        seed: seed ^ 0xC4A1,
    })
    .unwrap_or_else(|e| panic!("{tag}: env build failed: {e:#}"));
    let mut queues =
        VirtualQueues::new(fleet.devices.iter().map(|d| d.energy_budget_j).collect());
    assert_eq!(queues.budgets().len(), n, "{tag}: queue budgets sized to the fleet");
    let mut sample_rng = Rng::new(seed ^ 0x5A3B_1E00);
    let identity: Vec<usize> = (0..n).collect();

    for t in 0..ROUNDS {
        let round = environment.next_round(&fleet.devices);
        let devices: &[Device] = round.devices.as_deref().unwrap_or(&fleet.devices);
        let h = &round.gains;
        let peeked = if round_policy.wants_peek() {
            environment.peek(&fleet.devices)
        } else {
            None
        };
        let next_gains = peeked.map(|p| p.gains);

        // Compact to the reachable candidate set, as the server does.
        let avail: Vec<usize> = match &round.available {
            Some(a) if a.len() < n => a.clone(),
            _ => identity.clone(),
        };
        let m = avail.len();
        assert!(
            m >= k,
            "{tag} round={t}: environment left fewer than K candidates ({m} < {k})"
        );
        let sub_devices: Vec<Device> = avail.iter().map(|&i| devices[i].clone()).collect();
        let w = fleet.weights();
        let wsum: f64 = avail.iter().map(|&i| w[i]).sum();
        let sub_weights: Vec<f64> = avail.iter().map(|&i| w[i] / wsum).collect();
        let sub_h: Vec<f64> = avail.iter().map(|&i| h[i]).collect();
        let backlogs = queues.backlogs().to_vec();
        let sub_backlogs: Vec<f64> = avail.iter().map(|&i| backlogs[i]).collect();
        let sub_next: Option<Vec<f64>> = next_gains
            .as_ref()
            .map(|nh| avail.iter().map(|&i| nh[i]).collect());
        let ctx = RoundContext {
            t,
            k,
            devices: &sub_devices,
            weights: &sub_weights,
            ids: &avail,
            h: &sub_h,
            backlogs: &sub_backlogs,
            next_h: sub_next.as_deref(),
        };
        let plan = round_policy.plan(&ctx, &mut sample_rng);

        // --- plan shape --------------------------------------------------
        assert_eq!(plan.controls.q.len(), m, "{tag} round={t}: q length");
        assert_eq!(plan.controls.f_hz.len(), m, "{tag} round={t}: f length");
        assert_eq!(plan.controls.p_w.len(), m, "{tag} round={t}: p length");
        assert_eq!(plan.q_eff.len(), m, "{tag} round={t}: q_eff length");

        // --- sampling distribution ---------------------------------------
        let qsum: f64 = plan.controls.q.iter().sum();
        assert!(
            (qsum - 1.0).abs() < 1e-6,
            "{tag} round={t}: sampling distribution sums to {qsum}, not 1"
        );
        for (i, &qv) in plan.controls.q.iter().enumerate() {
            assert!(
                qv > 0.0 && qv <= 1.0 + 1e-12,
                "{tag} round={t}: q[{i}] = {qv} outside (0, 1]"
            );
        }

        // --- participation marginals -------------------------------------
        let esum: f64 = plan.q_eff.iter().sum();
        let indicator = plan.q_eff.iter().all(|&v| v == 0.0 || v == 1.0);
        assert!(
            (esum - 1.0).abs() < 1e-6 || indicator,
            "{tag} round={t}: q_eff is neither a distribution nor a 0/1 \
             indicator (sum {esum})"
        );
        for (i, &v) in plan.q_eff.iter().enumerate() {
            assert!(
                (0.0..=1.0).contains(&v),
                "{tag} round={t}: q_eff[{i}] = {v} outside [0, 1]"
            );
        }

        // --- selection ---------------------------------------------------
        assert_eq!(
            plan.selection.members.len(),
            k,
            "{tag} round={t}: K slots must all be filled"
        );
        for &member in &plan.selection.members {
            assert!(
                member < m,
                "{tag} round={t}: member {member} not reachable in the \
                 compacted candidate set (|N^t| = {m})"
            );
        }
        for (slot, c) in plan.selection.coefs.iter().enumerate() {
            assert!(
                c.is_finite() && *c >= 0.0,
                "{tag} round={t}: coef[{slot}] = {c} not finite/non-negative"
            );
        }

        // --- resource boxes (against the drifted parameters) -------------
        for (i, d) in sub_devices.iter().enumerate() {
            let f = plan.controls.f_hz[i];
            let p = plan.controls.p_w[i];
            assert!(
                f >= d.f_min_hz - 1e-9 && f <= d.f_max_hz + 1e-9,
                "{tag} round={t}: f[{i}] = {f} outside [{}, {}]",
                d.f_min_hz,
                d.f_max_hz
            );
            assert!(
                p >= d.p_min_w - 1e-12 && p <= d.p_max_w + 1e-12,
                "{tag} round={t}: p[{i}] = {p} outside [{}, {}]",
                d.p_min_w,
                d.p_max_w
            );
        }

        // --- scatter + world advance, mirroring the server ---------------
        let mut f_full: Vec<f64> = devices.iter().map(|d| d.f_min_hz).collect();
        let mut p_full: Vec<f64> = devices.iter().map(|d| d.p_min_w).collect();
        let mut q_eff_full = vec![0.0; n];
        for (pos, &g) in avail.iter().enumerate() {
            f_full[g] = plan.controls.f_hz[pos];
            p_full[g] = plan.controls.p_w[pos];
            q_eff_full[g] = plan.q_eff[pos];
        }
        let costs = RoundCosts::evaluate(&cfg.system, devices, model_bits, h, &f_full, &p_full);
        let mut unique: Vec<usize> =
            plan.selection.members.iter().map(|&mm| avail[mm]).collect();
        unique.sort_unstable();
        unique.dedup();
        let makespan = costs.makespan_s(&unique);
        assert!(
            makespan.is_finite() && makespan > 0.0,
            "{tag} round={t}: makespan {makespan}"
        );
        environment.observe_selection(&unique);
        round_policy.observe_round(&unique, &costs);
        // Mirror the server's offline gating: eq. (19) only advances the
        // round's candidates (default `queue_gate_offline = true`).
        if cfg.control.queue_gate_offline && m < n {
            queues.update_candidates(&avail, &q_eff_full, k, &costs.energy_j);
        } else {
            queues.update(&q_eff_full, k, &costs.energy_j);
        }
        for (i, &b) in queues.backlogs().iter().enumerate() {
            assert!(
                b >= 0.0 && b.is_finite(),
                "{tag} round={t}: virtual queue[{i}] = {b} went negative/non-finite"
            );
        }
    }
}

/// Golden warm-vs-cold agreement with real queue feedback: a warm and a
/// cold solver walk the same 30-round trajectory (queues advanced by the
/// *cold* controls so both always see identical inputs) and must land on
/// the same per-round fixed point within the outer tolerance, while the
/// warm path stays feasible and spends strictly fewer outer iterations.
#[test]
fn warm_and_cold_lroa_reach_the_same_fixed_point_with_queue_feedback() {
    let mut cfg = Config::for_dataset("cifar").unwrap();
    cfg.system.num_devices = 40;
    let n = cfg.system.num_devices;
    let k = cfg.system.k;
    let model_bits = 32.0 * 136_874.0;
    let mut rng = Rng::new(0xA11CE);
    let fleet = Fleet::generate(&cfg.system, (40, 120), &mut rng);

    let warm_ctl = ControlConfig::default();
    assert!(warm_ctl.warm_start, "warm start must be the default");
    let cold_ctl = ControlConfig {
        warm_start: false,
        ..ControlConfig::default()
    };
    // An outer-loop stop at `eps_outer` bounds the iterate *change*, not
    // the distance to the fixed point — allow a generous multiple.
    let tol = 100.0 * warm_ctl.eps_outer;

    let mut warm = LroaSolver::new(cfg.system.clone(), warm_ctl, 1.0, 1e4, model_bits);
    let mut cold = LroaSolver::new(cfg.system.clone(), cold_ctl, 1.0, 1e4, model_bits);

    let mut queues =
        VirtualQueues::new(fleet.devices.iter().map(|d| d.energy_budget_j).collect());
    let (mut warm_iters, mut cold_iters) = (0usize, 0usize);
    for t in 0..30 {
        let h: Vec<f64> = (0..n).map(|_| rng.range(0.01, 0.5)).collect();
        let backlogs = queues.backlogs().to_vec();
        let (cw, sw) = warm.solve_round(&fleet.devices, fleet.weights(), &h, &backlogs);
        let (cc, sc) = cold.solve_round(&fleet.devices, fleet.weights(), &h, &backlogs);
        warm_iters += sw.outer_iters;
        cold_iters += sc.outer_iters;

        for i in 0..n {
            assert!(
                (cw.q[i] - cc.q[i]).abs() <= tol,
                "round {t}: q[{i}] warm {} vs cold {}",
                cw.q[i],
                cc.q[i]
            );
            assert!(
                ((cw.f_hz[i] - cc.f_hz[i]) / cc.f_hz[i]).abs() <= tol,
                "round {t}: f[{i}] warm {} vs cold {}",
                cw.f_hz[i],
                cc.f_hz[i]
            );
            assert!(
                ((cw.p_w[i] - cc.p_w[i]) / cc.p_w[i]).abs() <= tol,
                "round {t}: p[{i}] warm {} vs cold {}",
                cw.p_w[i],
                cc.p_w[i]
            );
        }

        // The warm path must be feasible on its own terms, not merely
        // close to a feasible cold solution.
        let qsum: f64 = cw.q.iter().sum();
        assert!((qsum - 1.0).abs() < 1e-6, "round {t}: warm q sums to {qsum}");
        for (i, d) in fleet.devices.iter().enumerate() {
            assert!(
                cw.f_hz[i] >= d.f_min_hz && cw.f_hz[i] <= d.f_max_hz,
                "round {t}: warm f[{i}] outside the box"
            );
            assert!(
                cw.p_w[i] >= d.p_min_w && cw.p_w[i] <= d.p_max_w,
                "round {t}: warm p[{i}] outside the box"
            );
        }

        // Advance the queues with the COLD controls so the two solvers
        // keep seeing identical inputs.
        let costs =
            RoundCosts::evaluate(&cfg.system, &fleet.devices, model_bits, &h, &cc.f_hz, &cc.p_w);
        let q_eff: Vec<f64> = cc.q.iter().map(|&q| selection_probability(q, k)).collect();
        queues.update(&q_eff, k, &costs.energy_j);
    }
    assert!(
        warm_iters < cold_iters,
        "warm start should cut outer iterations: warm {warm_iters} vs cold {cold_iters}"
    );
}

/// Golden offline-queue semantics: across a real availability outage the
/// gated queues freeze an offline device's backlog exactly, while the
/// old all-devices update (`queue_gate_offline = false`, kept as the
/// parity anchor) lets the backlog drain by `Ē_n` per offline round —
/// the overdraw-laundering bug the gate fixes.
#[test]
fn offline_queue_gating_freezes_backlogs_across_outages() {
    let mut cfg = Config::for_dataset("cifar").unwrap();
    cfg.system.num_devices = 12;
    cfg.system.k = 2;
    // Tight budgets so backlogs actually build and the drain is visible.
    cfg.system.energy_budget_j = 1e-3;
    cfg.train.seed = 3;
    cfg.env.kind = EnvKind::Availability;
    cfg.env.avail_p_drop = 0.35;
    cfg.env.avail_p_join = 0.3;
    cfg.validate().unwrap();
    assert!(
        cfg.control.queue_gate_offline,
        "offline gating must be the default"
    );

    let n = cfg.system.num_devices;
    let k = cfg.system.k;
    let model_bits = 32.0 * 136_874.0;
    let mut fleet_rng = Rng::new(3 ^ 0xF1EE_7000);
    let fleet = Fleet::generate(&cfg.system, (40, 120), &mut fleet_rng);
    let init = PolicyInit {
        sys: &cfg.system,
        ctl: &cfg.control,
        bandit: cfg.bandit.clone(),
        thompson: cfg.thompson.clone(),
        linucb: cfg.linucb.clone(),
        lambda: 1.0,
        v: 1e4,
        model_bits,
        seed: 3,
    };
    let mut round_policy = policy::build(Policy::PowerOfTwoChoices, &init);
    let mut environment = env::build(
        EnvKind::Availability,
        &EnvInit {
            sys: &cfg.system,
            env: &cfg.env,
            seed: 3 ^ 0xC4A1,
        },
    )
    .unwrap();
    let budgets: Vec<f64> = fleet.devices.iter().map(|d| d.energy_budget_j).collect();
    let mut gated = VirtualQueues::new(budgets.clone());
    let mut ungated = VirtualQueues::new(budgets.clone());
    let mut sample_rng = Rng::new(3 ^ 0x5A3B_1E00);
    let identity: Vec<usize> = (0..n).collect();

    let mut offline_rounds = 0usize;
    let mut drains_seen = 0usize;
    for t in 0..40 {
        let round = environment.next_round(&fleet.devices);
        let h = &round.gains;
        let avail: Vec<usize> = match &round.available {
            Some(a) if a.len() < n => a.clone(),
            _ => identity.clone(),
        };
        let sub_devices: Vec<Device> =
            avail.iter().map(|&i| fleet.devices[i].clone()).collect();
        let w = fleet.weights();
        let wsum: f64 = avail.iter().map(|&i| w[i]).sum();
        let sub_weights: Vec<f64> = avail.iter().map(|&i| w[i] / wsum).collect();
        let sub_h: Vec<f64> = avail.iter().map(|&i| h[i]).collect();
        let backlogs = gated.backlogs().to_vec();
        let sub_backlogs: Vec<f64> = avail.iter().map(|&i| backlogs[i]).collect();
        let ctx = RoundContext {
            t,
            k,
            devices: &sub_devices,
            weights: &sub_weights,
            ids: &avail,
            h: &sub_h,
            backlogs: &sub_backlogs,
            next_h: None,
        };
        let plan = round_policy.plan(&ctx, &mut sample_rng);
        let mut f_full: Vec<f64> = fleet.devices.iter().map(|d| d.f_min_hz).collect();
        let mut p_full: Vec<f64> = fleet.devices.iter().map(|d| d.p_min_w).collect();
        let mut q_eff_full = vec![0.0; n];
        for (pos, &g) in avail.iter().enumerate() {
            f_full[g] = plan.controls.f_hz[pos];
            p_full[g] = plan.controls.p_w[pos];
            q_eff_full[g] = plan.q_eff[pos];
        }
        let costs =
            RoundCosts::evaluate(&cfg.system, &fleet.devices, model_bits, h, &f_full, &p_full);

        let before_gated = gated.backlogs().to_vec();
        let before_ungated = ungated.backlogs().to_vec();
        if avail.len() < n {
            gated.update_candidates(&avail, &q_eff_full, k, &costs.energy_j);
        } else {
            gated.update(&q_eff_full, k, &costs.energy_j);
        }
        ungated.update(&q_eff_full, k, &costs.energy_j);

        let online: std::collections::BTreeSet<usize> = avail.iter().copied().collect();
        for g in 0..n {
            if online.contains(&g) {
                continue;
            }
            offline_rounds += 1;
            // Gated: an offline backlog is exactly flat.
            assert_eq!(
                gated.backlogs()[g],
                before_gated[g],
                "round {t}: offline device {g} backlog moved under gating"
            );
            // Ungated (old semantics): a positive backlog drains by Ē.
            if before_ungated[g] > 0.0 {
                assert!(
                    ungated.backlogs()[g] < before_ungated[g],
                    "round {t}: offline device {g} failed to drain ungated"
                );
                drains_seen += 1;
            }
        }
    }
    assert!(
        offline_rounds > 0,
        "scenario produced no outages — the golden checks nothing"
    );
    assert!(
        drains_seen > 0,
        "no positive backlog was ever exposed to an outage — tighten the scenario"
    );
}

/// With every device always reachable (`static` env) the gate can never
/// fire: toggling `queue_gate_offline` must leave the recorded
/// trajectory byte-identical — the knob only changes behavior where
/// candidacy actually varies.
#[test]
fn queue_gate_is_inert_when_the_fleet_is_always_available() {
    let run = |gate: bool| {
        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.system.num_devices = 10;
        cfg.system.k = 2;
        cfg.train.rounds = 15;
        cfg.train.seed = 4;
        cfg.train.policy = Policy::Lroa;
        cfg.control.queue_gate_offline = gate;
        let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        server.run().unwrap();
        server
            .recorder
            .rounds
            .iter()
            .map(|r| {
                format!(
                    "{:?}|{:?}|{:?}|{}",
                    r.round_time_s, r.mean_queue, r.max_queue, r.selected
                )
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(run(true), run(false));
}

/// The warm-started round path is bitwise deterministic: same config →
/// same recorded trajectory, across reruns *and* across worker thread
/// counts (the warm store lives in the single-threaded control plane and
/// must never observe scheduling order).  `solver_time_s` is wall-clock
/// and excluded.
#[test]
fn the_warm_lroa_round_path_is_bitwise_deterministic() {
    let run = |threads: usize| {
        let mut cfg = Config::for_dataset("cifar").unwrap();
        cfg.system.num_devices = 14;
        cfg.system.k = 2;
        cfg.train.rounds = 20;
        cfg.train.seed = 5;
        cfg.train.policy = Policy::Lroa;
        cfg.train.train_threads = threads;
        cfg.env.kind = EnvKind::Availability;
        cfg.env.avail_p_drop = 0.3; // exercise warm-store renormalization
        cfg.env.avail_p_join = 0.3;
        assert!(cfg.control.warm_start, "warm start must be the default");
        let mut server = Server::new(cfg, SimMode::ControlPlaneOnly).unwrap();
        server.run().unwrap();
        server
            .recorder
            .rounds
            .iter()
            .map(|r| {
                format!(
                    "{:?}|{:?}|{:?}|{:?}|{:?}|{:?}|{}|{}|{}",
                    r.round_time_s,
                    r.total_time_s,
                    r.objective,
                    r.mean_energy_j,
                    r.mean_queue,
                    r.max_queue,
                    r.selected,
                    r.outer_iters,
                    r.inner_iters
                )
            })
            .collect::<Vec<_>>()
    };
    let a = run(1);
    assert_eq!(a, run(1), "rerun with the same thread count diverged");
    assert_eq!(a, run(4), "thread count leaked into the warm control plane");
}
